//! Minimal offline stand-in for the `anyhow` crate (see Cargo.toml).
//!
//! API-compatible with the subset the workspace uses:
//!
//! * [`Error`] — a boxed message; displays like `anyhow::Error` for both
//!   `{e}` and `{e:#}` (no cause chain, so they render identically).
//! * [`Result`] with the `E = Error` default.
//! * `?` conversion from any `std::error::Error` (mirrors the real
//!   crate's blanket `From` — `Error` itself deliberately does NOT
//!   implement `std::error::Error`, exactly like upstream, so the
//!   blanket impl does not collide with the reflexive `From`).
//! * [`anyhow!`], [`bail!`], [`ensure!`] in their format-string forms.

use std::fmt;

/// An error message. The real crate stores a boxed dyn error + backtrace;
/// callers here only ever format it, so a `String` suffices.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result<T, anyhow::Error>` with the upstream default-parameter shape.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }

    #[test]
    fn question_mark_propagates_own_error() {
        fn inner() -> crate::Result<()> {
            crate::bail!("inner failed: {}", 7)
        }
        fn outer() -> crate::Result<()> {
            inner()?;
            Ok(())
        }
        let e = outer().unwrap_err();
        assert_eq!(format!("{e}"), "inner failed: 7");
        assert_eq!(format!("{e:#}"), "inner failed: 7");
    }

    #[test]
    fn ensure_both_arms() {
        fn check(v: u32) -> crate::Result<()> {
            crate::ensure!(v < 10);
            crate::ensure!(v != 3, "three is right out (got {v})");
            Ok(())
        }
        assert!(check(2).is_ok());
        assert!(check(3).is_err());
        assert!(check(11).is_err());
    }
}
