//! Integration: the whole co-design pipeline without artifacts —
//! compile → codegen → config round-trip → simulator instantiation →
//! serving — across models and devices.

use vaqf::compiler::{compile, emit_config_json, emit_hls_cpp, CompileRequest};
use vaqf::config::{load_target, target_from_json};
use vaqf::coordinator::{serve, FrameSource, ServeConfig};
use vaqf::hw::{zcu102, zcu111};
use vaqf::model::{deit_small, VitConfig};
use vaqf::runtime::SimBackend;
use vaqf::sim::{generate_weights, ModelExecutor};
use vaqf::util::json::Json;

#[test]
fn compile_codegen_simulate_roundtrip() {
    // 1. Compile for a mid target.
    let req = CompileRequest {
        model: deit_small(),
        device: zcu102(),
        target_fps: 30.0,
    };
    let out = compile(&req).expect("deit-small @30FPS must be feasible on zcu102");
    assert!(out.design.summary.fps >= 30.0);

    // 2. Codegen both artifacts.
    let s = req.model.structure(Some(out.act_bits));
    let cpp = emit_hls_cpp(&out, &s, &req.device);
    assert!(cpp.contains("vit_layer") && cpp.contains("compute_engine"));
    let cfg_json = emit_config_json(&out, &req.device);

    // 3. Round-trip the config through text and rebuild the params.
    let text = cfg_json.pretty();
    let parsed = Json::parse(&text).unwrap();
    let params = vaqf::compiler::params_from_json(&parsed).unwrap();
    assert_eq!(params, out.design.params);

    // 4. Instantiate a (micro) simulator with a same-precision design and
    //    serve frames through it — the accelerator the codegen describes.
    let micro = VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 1,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    };
    let weights = generate_weights(&micro, 3);
    let g_q = vaqf::perf::AcceleratorParams::g_q_for(64, out.act_bits);
    let sim_params = vaqf::perf::AcceleratorParams {
        t_m: 16,
        t_n: 2,
        t_m_q: 16,
        t_n_q: (2 * g_q / 4).max(1),
        g: 4,
        g_q,
        p_h: 4,
        act_bits: Some(out.act_bits),
    };
    let exec = ModelExecutor::new(weights, Some(out.act_bits), sim_params, zcu102());
    let serve_cfg = ServeConfig {
        offered_fps: 300.0,
        frames: 12,
        queue_depth: 12,
        source_seed: 5,
    };
    let source = FrameSource::new(micro, 5, Some(serve_cfg.offered_fps));
    let report = serve(
        source,
        Box::new(SimBackend {
            executor: exec,
            realtime: false,
        }),
        &serve_cfg,
    )
    .unwrap();
    assert_eq!(report.completed, 12);
}

#[test]
fn config_file_to_compile() {
    let doc = r#"{"model": "deit-tiny", "device": "zcu111", "target_fps": 60}"#;
    let t = target_from_json(&Json::parse(doc).unwrap()).unwrap();
    let out = compile(&CompileRequest {
        model: t.model,
        device: t.device,
        target_fps: t.target_fps,
    })
    .expect("deit-tiny @60FPS on zcu111");
    assert!(out.design.summary.fps >= 60.0);
}

#[test]
fn config_file_loading_from_disk() {
    let dir = std::env::temp_dir().join("vaqf_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("target.json");
    std::fs::write(
        &path,
        r#"{"model": "deit-small", "device": "zcu102", "target_fps": 12}"#,
    )
    .unwrap();
    let t = load_target(&path).unwrap();
    assert_eq!(t.model.name, "deit-small");
    assert_eq!(t.target_fps, 12.0);
}

#[test]
fn cross_device_feasibility_is_consistent() {
    // Anything feasible on zcu102 must be feasible on the larger zcu111
    // at the same target.
    for fps in [10.0, 24.0] {
        let on102 = compile(&CompileRequest {
            model: deit_small(),
            device: zcu102(),
            target_fps: fps,
        });
        let on111 = compile(&CompileRequest {
            model: deit_small(),
            device: zcu111(),
            target_fps: fps,
        });
        if on102.is_ok() {
            assert!(on111.is_ok(), "zcu111 ⊇ zcu102 at {fps} FPS");
        }
    }
}
