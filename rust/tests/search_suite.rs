//! Design-space-search suite: the pruned + deduplicated + parallel
//! engine (`compiler::engine`) against the literal exhaustive oracle,
//! and the incremental `SearchCtx` memo across structure mutations.
//!
//! The contract under test is *bit-identical results*: pruning only
//! skips points that provably cannot win (resource monotonicity),
//! container-width dedup only collapses `(G_q, lcm)` classes that cost
//! identically, and the parallel fold selects by a total order on
//! `(cycles, legacy index)` — so the chosen `DesignPoint` (params,
//! summary, adjustment count) must equal the oracle's everywhere, for
//! every thread count.

use vaqf::compiler::{
    compile, compile_with_ctx, optimize_baseline, optimize_for_bits,
    optimize_for_bits_exhaustive, CompileRequest, SearchCtx,
};
use vaqf::hw::{zcu102, zcu111, Device};
use vaqf::model::VitConfig;
use vaqf::util::rng::SplitMix64;

fn gen_tiny_vit(rng: &mut SplitMix64, trial: u64) -> VitConfig {
    let heads = 1 + rng.next_below(4) as usize;
    let head_dim = *[2usize, 4, 8].get(rng.next_below(3) as usize).unwrap();
    let patch = *[4usize, 8].get(rng.next_below(2) as usize).unwrap();
    let grid = 1 + rng.next_below(3) as usize;
    VitConfig {
        name: format!("search-prop-{trial}"),
        image_size: patch * grid,
        patch_size: patch,
        in_chans: 3,
        embed_dim: heads * head_dim,
        depth: 1 + rng.next_below(2) as usize,
        num_heads: heads,
        mlp_ratio: 2 + 2 * rng.next_below(2) as usize,
        num_classes: 3 + rng.next_below(8) as usize,
    }
}

fn devices() -> Vec<Device> {
    vec![zcu102(), zcu111()]
}

/// The tentpole property: over random tiny models × both boards × every
/// activation precision 1..=8 × thread counts {1, 2, 8}, the pruned
/// parallel search returns exactly what the exhaustive oracle returns —
/// same params, same cycle count, same adjustment count — and an
/// infeasible case errors on both sides.
#[test]
fn prop_pruned_search_matches_exhaustive_oracle() {
    let mut rng = SplitMix64::new(0x5EA8C);
    for trial in 0..8u64 {
        let cfg = gen_tiny_vit(&mut rng, trial);
        for dev in devices() {
            let baseline = optimize_baseline(&cfg.structure(None), &dev);
            for bits in 1..=8u8 {
                let s = cfg.structure(Some(bits));
                let oracle = optimize_for_bits_exhaustive(&s, &baseline, &dev, bits);
                // The ctx-free pruned path…
                let pruned = optimize_for_bits(&s, &baseline, &dev, bits);
                // …and the ctx path at several thread counts.
                for threads in [1usize, 2, 8] {
                    let ctx = SearchCtx::with_threads(threads);
                    let got = ctx.optimize_for_bits(&s, &baseline, &dev, bits);
                    match (&oracle, &got) {
                        (Ok(want), Ok(d)) => {
                            assert_eq!(
                                d.params, want.params,
                                "trial {trial} {} b{bits} t{threads}: params diverged",
                                dev.name
                            );
                            assert_eq!(
                                d.summary.cycles_per_frame, want.summary.cycles_per_frame,
                                "trial {trial} {} b{bits} t{threads}: cycles diverged",
                                dev.name
                            );
                            assert_eq!(
                                d.adjustments, want.adjustments,
                                "trial {trial} {} b{bits} t{threads}: adjustments diverged",
                                dev.name
                            );
                        }
                        (Err(_), Err(_)) => {}
                        (want, d) => panic!(
                            "trial {trial} {} b{bits} t{threads}: feasibility disagreement \
                             oracle {want:?} vs pruned {d:?}",
                            dev.name
                        ),
                    }
                }
                match (&oracle, &pruned) {
                    (Ok(want), Ok(d)) => {
                        assert_eq!(d.params, want.params, "ctx-free pruned path diverged");
                        assert_eq!(d.adjustments, want.adjustments);
                    }
                    (Err(_), Err(_)) => {}
                    (want, d) => {
                        panic!("ctx-free feasibility disagreement: {want:?} vs {d:?}")
                    }
                }
            }
        }
    }
}

/// Thread count must not leak into the result even when the class list
/// is long (bits 8 on a 64-bit port ⇒ 5 dedup classes fanned out).
#[test]
fn thread_count_never_changes_the_winner() {
    let cfg = vaqf::model::deit_tiny();
    let dev = zcu102();
    let baseline = optimize_baseline(&cfg.structure(None), &dev);
    let s = cfg.structure(Some(8));
    let want = SearchCtx::with_threads(1).optimize_for_bits(&s, &baseline, &dev, 8).unwrap();
    for threads in [2usize, 3, 4, 8, 16] {
        let got = SearchCtx::with_threads(threads)
            .optimize_for_bits(&s, &baseline, &dev, 8)
            .unwrap();
        assert_eq!(got.params, want.params, "threads {threads}");
        assert_eq!(got.adjustments, want.adjustments, "threads {threads}");
    }
}

/// Incremental re-search: warm results are bit-identical to cold ones,
/// surviving an interleaved search of a *mutated* structure (different
/// shape key ⇒ different memo rows; the original rows must be untouched
/// and still replay without a single grid-point evaluation).
#[test]
fn warm_ctx_equals_cold_after_structure_mutation() {
    let mut rng = SplitMix64::new(0xCAFE);
    let cfg = gen_tiny_vit(&mut rng, 99);
    let dev = zcu102();
    let ctx = SearchCtx::new();
    let baseline = ctx.optimize_baseline(&cfg.structure(None), &dev);
    let s = cfg.structure(Some(6));
    let cold = ctx.optimize_for_bits(&s, &baseline, &dev, 6).unwrap();

    // Mutate the model (one more encoder block): a different shape, so
    // its search shares nothing with the first one's design memo row.
    let mut bigger = cfg.clone();
    bigger.depth += 1;
    let sb = bigger.structure(Some(6));
    let base_b = ctx.optimize_baseline(&bigger.structure(None), &dev);
    let other = ctx.optimize_for_bits(&sb, &base_b, &dev, 6).unwrap();
    assert_ne!(
        cold.summary.cycles_per_frame, other.summary.cycles_per_frame,
        "mutated model should not cost the same"
    );

    // Re-searching the ORIGINAL structure is a pure memo replay.
    let before = ctx.stats();
    let warm = ctx.optimize_for_bits(&s, &baseline, &dev, 6).unwrap();
    let after = ctx.stats();
    assert_eq!(warm.params, cold.params);
    assert_eq!(warm.adjustments, cold.adjustments);
    assert_eq!(warm.summary.cycles_per_frame, cold.summary.cycles_per_frame);
    assert_eq!(after.design_hits, before.design_hits + 1);
    assert_eq!(
        after.point_evals, before.point_evals,
        "warm replay must not re-evaluate any grid point"
    );

    // And a fresh cold ctx still agrees — the memo changed nothing.
    let fresh = SearchCtx::new().optimize_for_bits(&s, &baseline, &dev, 6).unwrap();
    assert_eq!(fresh.params, cold.params);
}

/// The ctx-carrying compile entry point returns exactly what the
/// ctx-free `compile` returns, and a second identical request is served
/// from the memo.
#[test]
fn compile_with_ctx_matches_compile_and_memoizes() {
    let req = CompileRequest {
        model: vaqf::model::micro(),
        device: zcu102(),
        target_fps: 100.0,
    };
    let want = compile(&req).unwrap();
    let ctx = SearchCtx::new();
    let got = compile_with_ctx(&req, &ctx).unwrap();
    assert_eq!(got.act_bits, want.act_bits);
    assert_eq!(got.design.params, want.design.params);
    assert_eq!(got.rounds.len(), want.rounds.len());

    let before = ctx.stats();
    let again = compile_with_ctx(&req, &ctx).unwrap();
    assert_eq!(again.design.params, want.design.params);
    let after = ctx.stats();
    assert!(
        after.design_hits > before.design_hits,
        "second compile should hit the design memo ({before:?} → {after:?})"
    );
    assert_eq!(
        after.point_evals, before.point_evals,
        "second compile must not re-evaluate grid points"
    );
}

/// Sharded co-search under a shared ctx is identical to the ctx-free
/// path, and a repeated co-search over the same shards is warm.
#[test]
fn co_search_with_ctx_matches_and_warms() {
    use std::sync::Arc;
    use vaqf::shard::{co_search, co_search_with_ctx, ShardPolicy};
    let model = vaqf::model::micro();
    let dev = zcu102();
    let baseline = optimize_baseline(&model.structure(None), &dev);
    let reference = optimize_for_bits(&model.structure(Some(8)), &baseline, &dev, 8).unwrap();

    let want = co_search(&model, &dev, Some(8), &reference, 2, ShardPolicy::Balanced).unwrap();
    let ctx = Arc::new(SearchCtx::new());
    let got = co_search_with_ctx(
        &model,
        &dev,
        Some(8),
        &reference,
        2,
        ShardPolicy::Balanced,
        ctx.clone(),
    )
    .unwrap();
    for (g, w) in got.stages.iter().zip(&want.stages) {
        assert_eq!(g.layer_range, w.layer_range);
        assert_eq!(g.params, w.params);
        assert_eq!(g.compute_cycles, w.compute_cycles);
    }

    let before = ctx.stats();
    let again = co_search_with_ctx(
        &model,
        &dev,
        Some(8),
        &reference,
        2,
        ShardPolicy::Balanced,
        ctx.clone(),
    )
    .unwrap();
    let after = ctx.stats();
    for (g, w) in again.stages.iter().zip(&want.stages) {
        assert_eq!(g.params, w.params);
    }
    assert!(
        after.design_hits > before.design_hits,
        "repartition over the same shards should be memo-served"
    );
    assert_eq!(after.point_evals, before.point_evals);
}
