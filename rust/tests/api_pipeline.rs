//! Integration: the `vaqf::api` facade end to end —
//! `TargetSpec → Session → CompiledDesign → codegen / simulator / server`
//! on the micro model, plus the layered-resolution precedence contract
//! (explicit setter > env > config file > default) and typed-error
//! matching from outside the crate.

use vaqf::api::{ServeClock, TargetSpec, VaqfError};
use vaqf::model::micro;
use vaqf::sim::Backend;
use vaqf::util::json::Json;

fn no_env(_: &str) -> Option<String> {
    None
}

#[test]
fn pipeline_target_spec_to_serving() {
    // Every field is set explicitly so ambient VAQF_* env vars (which the
    // explicit layer outranks) cannot perturb this test.
    let session = TargetSpec::new()
        .model(micro())
        .device_preset("zcu102")
        .target_fps(100.0)
        .backend(Backend::Packed)
        .threads(1)
        .session()
        .expect("spec resolves");
    let design = session.compile().expect("micro @100FPS is feasible on zcu102");
    assert_eq!(design.target().model.name, "micro");
    assert!(design.summary().fps >= 100.0);
    assert!(design.act_bits().is_some(), "quantized precision chosen");
    let outcome = design.outcome().expect("search outcome recorded");
    assert!(outcome.fr_max >= 100.0);

    // Codegen artifacts land on disk and round-trip to the same params.
    let dir = std::env::temp_dir().join("vaqf_api_pipeline_test");
    let art = design.codegen(&dir).expect("codegen writes artifacts");
    let cpp = std::fs::read_to_string(&art.cpp_path).unwrap();
    assert!(cpp.contains("compute_engine") && cpp.contains("vit_layer"));
    let text = std::fs::read_to_string(&art.json_path).unwrap();
    let params = vaqf::compiler::params_from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(&params, design.params());

    // The simulator is wired with the *compiled* parameters and runs.
    let mut exec = design.simulator_with_seed(7);
    assert_eq!(&exec.engine.params, design.params());
    let patches = exec.weights().synthetic_patches(0);
    let (logits, trace) = exec.run_frame(&patches);
    assert_eq!(logits.len(), 10);
    assert!(trace.total_cycles > 0);

    // Serving end to end through the same design.
    let report = design
        .server()
        .simulated(false)
        .offered_fps(500.0)
        .frames(12)
        .queue_depth(12)
        .source_seed(5)
        .weights_seed(7)
        .run()
        .expect("sim serving succeeds");
    assert_eq!(report.aggregate.completed, 12);
    assert_eq!(report.aggregate.dropped, 0);

    // Multi-stream scheduling over the deterministic virtual clock: the
    // report is a pure function of the configuration.
    let run = || {
        design
            .server()
            .streams(3)
            .workers(2)
            .policy("weighted-sla")
            .offered_fps(400.0)
            .frames(20)
            .queue_depth(3)
            .sla_ms(20.0)
            .analytic()
            .clock(ServeClock::Virtual)
            .run()
            .expect("virtual serving succeeds")
    };
    let (a, b) = (run(), run());
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    assert_eq!(a.aggregate.offered, 60);
    assert_eq!(a.aggregate.completed + a.aggregate.dropped, 60);

    // Unknown policies are a typed config error.
    let err = design.server().policy("fifo?").run().unwrap_err();
    assert!(matches!(err, VaqfError::Config { .. }), "got {err:?}");
}

#[test]
fn precedence_explicit_beats_env_beats_file_beats_default() {
    let doc = Json::parse(
        r#"{"model": "deit-small", "device": "zcu111", "target_fps": 40,
            "backend": "scalar", "threads": 3}"#,
    )
    .unwrap();
    let spec = TargetSpec::new().config_json(&doc).unwrap();

    // Config file beats the built-in defaults.
    let t = spec.resolve_with(&no_env).unwrap();
    assert_eq!(t.model.name, "deit-small");
    assert_eq!(t.device.name, "zcu111");
    assert_eq!(t.target_fps, 40.0);
    assert_eq!(t.backend, Backend::Scalar);
    assert_eq!(t.threads, 3);

    // Environment beats the config file.
    let env = |key: &str| match key {
        "VAQF_MODEL" => Some("deit-base".to_string()),
        "VAQF_DEVICE" => Some("zcu102".to_string()),
        "VAQF_TARGET_FPS" => Some("33.5".to_string()),
        "VAQF_BACKEND" => Some("packed".to_string()),
        "VAQF_THREADS" => Some("5".to_string()),
        _ => None,
    };
    let t = spec.resolve_with(&env).unwrap();
    assert_eq!(t.model.name, "deit-base");
    assert_eq!(t.device.name, "zcu102");
    assert_eq!(t.target_fps, 33.5);
    assert_eq!(t.backend, Backend::Packed);
    assert_eq!(t.threads, 5);

    // Explicit setters beat the environment.
    let spec = spec
        .model_preset("deit-tiny")
        .device_preset("zcu111")
        .target_fps(60.0)
        .backend(Backend::Scalar)
        .threads(9);
    let t = spec.resolve_with(&env).unwrap();
    assert_eq!(t.model.name, "deit-tiny");
    assert_eq!(t.device.name, "zcu111");
    assert_eq!(t.target_fps, 60.0);
    assert_eq!(t.backend, Backend::Scalar);
    assert_eq!(t.threads, 9);

    // Nothing set ⇒ built-in defaults.
    let t = TargetSpec::new().resolve_with(&no_env).unwrap();
    assert_eq!(t.model.name, "deit-base");
    assert_eq!(t.device.name, "zcu102");
    assert_eq!(t.target_fps, 24.0);
    assert_eq!(t.backend, Backend::Packed);
    assert_eq!(t.threads, 0);
}

#[test]
fn process_environment_feeds_the_env_layer() {
    // Touches only VAQF_TARGET_FPS; the other tests in this binary set
    // their frame-rate targets explicitly, which outranks this layer.
    std::env::set_var("VAQF_TARGET_FPS", "41.5");
    let t = TargetSpec::new().resolve();
    std::env::remove_var("VAQF_TARGET_FPS");
    assert_eq!(t.unwrap().target_fps, 41.5);
}

#[test]
fn config_file_layer_loads_from_disk() {
    let dir = std::env::temp_dir().join("vaqf_api_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("target.json");
    std::fs::write(
        &path,
        r#"{"device": {"preset": "zcu102", "clock_mhz": 300}, "threads": 2}"#,
    )
    .unwrap();
    let t = TargetSpec::new()
        .config_file(&path)
        .unwrap()
        .resolve_with(&no_env)
        .unwrap();
    assert_eq!(t.device.clock_mhz, 300, "partial preset override applied");
    assert_eq!(t.device.name, "zcu102");
    assert_eq!(t.threads, 2);
    assert_eq!(t.model.name, "deit-base", "unset sections fall to defaults");

    let missing = TargetSpec::new().config_file(dir.join("nope.json"));
    assert!(matches!(missing, Err(VaqfError::Io { .. })));
}

#[test]
fn unknown_preset_errors_are_matchable() {
    let err = TargetSpec::new().model_preset("resnet50").session().unwrap_err();
    assert!(err.to_string().contains("unknown model `resnet50`"));
    match err {
        VaqfError::UnknownPreset { ref name, .. } => assert_eq!(name, "resnet50"),
        other => panic!("expected UnknownPreset, got {other:?}"),
    }

    let err = TargetSpec::new().device_preset("virtex9000").session().unwrap_err();
    assert!(matches!(err, VaqfError::UnknownPreset { .. }));
}

#[test]
fn infeasible_targets_are_matchable() {
    let session = TargetSpec::new()
        .model(micro())
        .device_preset("zcu102")
        .target_fps(1e9)
        .backend(Backend::Packed)
        .threads(1)
        .session()
        .unwrap();
    match session.compile() {
        Err(VaqfError::Infeasible { target_fps, fr_max, .. }) => {
            assert_eq!(target_fps, 1e9);
            assert!(fr_max < target_fps);
        }
        other => panic!("expected Infeasible, got {other:?}"),
    }
}
