//! Concurrency stress tests for the serving-path primitives.
//!
//! The invariant under attack is conservation: for a `BoundedQueue`,
//! every admitted item is accounted for exactly once —
//!
//! ```text
//! pushed (admitted)  ==  popped + dropped (evicted) + still queued
//! ```
//!
//! — under multi-producer races, producer/consumer races, and close()
//! racing in-flight pushes. `close()` must never discard items that were
//! already admitted (they drain), and must never lose or double-count a
//! rejection.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use vaqf::coordinator::{BoundedQueue, PushOutcome};

/// Encode (producer, sequence) into one u64 payload so every item is
/// globally unique and its provenance is recoverable.
fn item(producer: u64, seq: u64) -> u64 {
    producer << 32 | seq
}

#[test]
fn multi_producer_single_consumer_conserves_items() {
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 2000;
    let q: Arc<BoundedQueue<u64>> = Arc::new(BoundedQueue::new(8));

    let handles: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut admitted = 0u64;
                for i in 0..PER_PRODUCER {
                    if q.push(item(p, i)).admitted() {
                        admitted += 1;
                    }
                }
                admitted
            })
        })
        .collect();

    // Single consumer drains concurrently; close() arrives only after
    // every producer is done, so nothing is ever rejected.
    let consumer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            let mut seen: Vec<u64> = Vec::new();
            while let Some(v) = q.pop() {
                seen.push(v);
            }
            seen
        })
    };

    let admitted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    q.close();
    let seen = consumer.join().unwrap();

    assert_eq!(admitted, PRODUCERS * PER_PRODUCER, "no rejections before close");
    assert_eq!(q.pushed(), admitted);
    assert_eq!(q.popped(), seen.len() as u64);
    assert_eq!(q.len(), 0, "closed queue drains fully");
    // Conservation: admitted == popped + evicted.
    assert_eq!(q.pushed(), q.popped() + q.dropped(), "conservation violated");
    // No duplicates: every popped item is a distinct admitted item.
    let unique: HashSet<u64> = seen.iter().copied().collect();
    assert_eq!(unique.len(), seen.len(), "an item was delivered twice");
}

#[test]
fn close_racing_pushes_never_loses_admitted_items() {
    // Producers hammer the queue until the closer slams the door on each
    // of them (push-until-rejected, so the race is exercised on every
    // run). Whatever was admitted must come out (pop or eviction);
    // whatever was rejected must have moved no counter.
    const PRODUCERS: u64 = 4;
    const SAFETY_CAP: u64 = 10_000_000;
    let q: Arc<BoundedQueue<u64>> = Arc::new(BoundedQueue::new(4));
    let admitted_ids: Arc<std::sync::Mutex<HashSet<u64>>> =
        Arc::new(std::sync::Mutex::new(HashSet::new()));
    let rejected: Arc<AtomicU64> = Arc::new(AtomicU64::new(0));
    let attempts: Arc<AtomicU64> = Arc::new(AtomicU64::new(0));

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = Arc::clone(&q);
            let admitted_ids = Arc::clone(&admitted_ids);
            let rejected = Arc::clone(&rejected);
            let attempts = Arc::clone(&attempts);
            std::thread::spawn(move || {
                for i in 0..SAFETY_CAP {
                    attempts.fetch_add(1, Ordering::Relaxed);
                    match q.push(item(p, i)) {
                        PushOutcome::Admitted | PushOutcome::AdmittedDroppedOldest => {
                            admitted_ids.lock().unwrap().insert(item(p, i));
                        }
                        PushOutcome::RejectedClosed => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                            return;
                        }
                    }
                }
                panic!("closer never closed the queue");
            })
        })
        .collect();

    let closer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            // Let some traffic through, then close mid-stream.
            while q.pushed() < 512 {
                std::hint::spin_loop();
            }
            q.close();
        })
    };

    for h in producers {
        h.join().unwrap();
    }
    closer.join().unwrap();

    // Drain what close() preserved.
    let mut drained: Vec<u64> = Vec::new();
    while let Some(v) = q.pop() {
        drained.push(v);
    }

    let admitted_ids = admitted_ids.lock().unwrap();
    assert_eq!(
        admitted_ids.len() as u64 + rejected.load(Ordering::Relaxed),
        attempts.load(Ordering::Relaxed),
        "every push is exactly admitted or rejected"
    );
    assert_eq!(q.pushed(), admitted_ids.len() as u64);
    assert_eq!(
        rejected.load(Ordering::Relaxed),
        PRODUCERS,
        "every producer must observe exactly one rejection"
    );
    // close() preserved already-admitted items: everything drained was
    // admitted, and admitted == drained + evicted.
    for v in &drained {
        assert!(admitted_ids.contains(v), "popped an item that was never admitted");
    }
    assert_eq!(
        q.pushed(),
        q.popped() + q.dropped(),
        "conservation after close: admitted != popped + evicted"
    );
    assert_eq!(q.popped(), drained.len() as u64);
}

#[test]
fn multi_consumer_delivery_is_exactly_once() {
    // 2 producers × 2 consumers: with no close-race and a deep queue,
    // every admitted item is delivered to exactly one consumer.
    const PRODUCERS: u64 = 2;
    const PER_PRODUCER: u64 = 3000;
    let q: Arc<BoundedQueue<u64>> = Arc::new(BoundedQueue::new(64));

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    q.push(item(p, i));
                }
            })
        })
        .collect();
    let consumers: Vec<_> = (0..2)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(v) = q.pop() {
                    seen.push(v);
                }
                seen
            })
        })
        .collect();

    for h in producers {
        h.join().unwrap();
    }
    q.close();
    let mut all: Vec<u64> = Vec::new();
    for c in consumers {
        all.extend(c.join().unwrap());
    }

    let unique: HashSet<u64> = all.iter().copied().collect();
    assert_eq!(unique.len(), all.len(), "an item was delivered twice");
    assert_eq!(q.popped(), all.len() as u64);
    assert_eq!(q.pushed(), q.popped() + q.dropped());
    assert_eq!(q.pushed(), PRODUCERS * PER_PRODUCER);
}

#[test]
fn blocking_pop_wakes_on_late_push_and_close() {
    let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(2));
    let consumer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            let first = q.pop(); // blocks until the late push
            let second = q.pop(); // blocks until close
            (first, second)
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(20));
    q.push(42);
    std::thread::sleep(std::time::Duration::from_millis(20));
    q.close();
    let (first, second) = consumer.join().unwrap();
    assert_eq!(first, Some(42));
    assert_eq!(second, None);
}

#[test]
fn queue_survives_a_panic_under_its_lock() {
    // Panic-injection: a consumer that panics inside a `peek_front`
    // closure dies holding the queue mutex, poisoning it. Every queue
    // operation must recover the lock (PR 8's poison-recovering locks —
    // previously each of these calls would cascade-panic on
    // `PoisonError`) and the conservation invariant must still hold.
    let q: Arc<BoundedQueue<u64>> = Arc::new(BoundedQueue::new(4));
    q.push(item(0, 0));
    q.push(item(0, 1));

    let victim = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            q.peek_front(|_| panic!("injected panic under the queue lock"));
        })
    };
    assert!(victim.join().is_err(), "the injected panic must propagate to its own thread");

    // Full API sweep over the poisoned-then-recovered queue.
    assert_eq!(q.len(), 2);
    assert!(!q.is_closed());
    assert_eq!(q.peek_front(|&v| v), Some(item(0, 0)));
    assert!(q.push(item(0, 2)).admitted());
    assert_eq!(q.try_pop(), Some(item(0, 0)));
    assert_eq!(q.pop(), Some(item(0, 1)));

    // A late blocking pop still wakes on push after the poisoning.
    let consumer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || (q.pop(), q.pop()))
    };
    std::thread::sleep(std::time::Duration::from_millis(20));
    q.push(item(0, 3));
    q.close();
    let (first, second) = consumer.join().unwrap();
    assert_eq!(first, Some(item(0, 2)));
    assert_eq!(second, Some(item(0, 3)));

    assert_eq!(q.pushed(), 4);
    assert_eq!(q.pushed(), q.popped() + q.dropped() + q.len() as u64, "conservation violated");
}
