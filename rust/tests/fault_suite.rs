//! Fault-injection suite: conservation, determinism, hysteresis and
//! availability invariants of `vaqf::fault` across the serving scheduler
//! and the shard pipeline.
//!
//! The load-bearing properties:
//!
//! * **conservation** — no frame is ever silently lost: every offered
//!   frame is completed, dropped (backpressure) or failed (retry budget),
//!   under *any* sampled fault plan;
//! * **determinism** — a fault-injected virtual-clock run is exactly as
//!   byte-reproducible as a fault-free one;
//! * **hysteresis** — the degrade controller never flaps: switches are
//!   at least one observation window apart, and a monotone-worsening
//!   trace can only ever demote;
//! * **availability** — a single crash with a hot spare keeps pipeline
//!   availability at three nines over a steady run.

use vaqf::api::{
    FailoverStrategy, FaultPlan, GeneratorSpec, HysteresisConfig, RecoveryConfig, TargetSpec,
};
use vaqf::coordinator::HysteresisController;
use vaqf::util::prop;

fn micro_design() -> vaqf::api::CompiledDesign {
    TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .target_fps(100.0)
        .session()
        .expect("micro session resolves")
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102")
}

// ---------------------------------------------------------------------------
// Conservation: offered == completed + dropped + failed, always.
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_conserves_frames_under_sampled_fault_plans() {
    // Random scripted fault plans (crashes that may never recover,
    // throttles, corruption) over a 3-stream × 3-worker analytic run:
    // the ledger must balance no matter what dies when. Failing plans
    // shrink to a minimal event script.
    let design = micro_design();
    // 3 streams × 15 frames at 200 fps ≈ a 75 ms run; a 100 ms horizon
    // keeps most sampled events inside it.
    let strat = prop::fault_events(3, 0.1, 12);
    let cfg = prop::Config {
        trials: 30,
        ..Default::default()
    };
    prop::check_with(&cfg, "scheduler_frame_conservation", &strat, |events| {
        let mut plan = FaultPlan::new();
        plan.events = events.clone();
        let report = design
            .server()
            .streams(3)
            .workers(3)
            .policy("least-loaded")
            .offered_fps(200.0)
            .frames(15)
            .queue_depth(4)
            .sla_ms(30.0)
            .analytic()
            .virtual_clock()
            .faults(plan)
            .run()
            .map_err(|e| e.to_string())?;
        let a = &report.aggregate;
        if a.offered != 45 {
            return Err(format!("offered {} != 3 streams × 15 frames", a.offered));
        }
        if a.offered != a.completed + a.dropped + a.failed {
            return Err(format!(
                "conservation broke: offered {} != completed {} + dropped {} + failed {}",
                a.offered, a.completed, a.dropped, a.failed
            ));
        }
        if report.faults.is_none() {
            return Err("fault plan attached but report carries no fault block".into());
        }
        Ok(())
    });
}

#[test]
fn scheduler_survives_unrecovered_crash() {
    // One of two workers dies early and never comes back: the survivor
    // absorbs the load, in-flight work re-dispatches, and nothing leaks.
    let design = micro_design();
    let plan = FaultPlan::new().crash_at(0.005, 1);
    let report = design
        .server()
        .streams(2)
        .workers(2)
        .policy("round-robin")
        .offered_fps(150.0)
        .frames(40)
        .queue_depth(8)
        .analytic()
        .virtual_clock()
        .faults(plan)
        .run()
        .expect("crashed run completes");
    let a = &report.aggregate;
    assert_eq!(a.offered, 80);
    assert_eq!(a.offered, a.completed + a.dropped + a.failed);
    assert!(a.completed > 0, "survivor worker served nothing");
    let f = report.faults.expect("fault block present");
    assert_eq!(f.injected_crashes, 1);
    assert!(
        f.availability < 1.0,
        "a dead worker must dent availability (got {})",
        f.availability
    );
}

#[test]
fn scheduler_frame_timeout_exhausts_retry_budget() {
    // A frame timeout shorter than the service time forces every
    // dispatch through the retry ladder until the budget runs out: all
    // frames end up `failed`, none vanish.
    let design = micro_design();
    let latency = design.frame_latency_s();
    let plan = FaultPlan::new().recovery(RecoveryConfig {
        frame_timeout_s: Some(latency / 4.0),
        max_retries: 2,
        ..Default::default()
    });
    let report = design
        .server()
        .streams(1)
        .workers(1)
        .offered_fps(50.0)
        .frames(10)
        .queue_depth(10)
        .analytic()
        .virtual_clock()
        .faults(plan)
        .run()
        .expect("timeout run completes");
    let a = &report.aggregate;
    assert_eq!(a.offered, a.completed + a.dropped + a.failed);
    assert_eq!(a.completed, 0, "no dispatch can beat a timeout < service");
    let f = report.faults.expect("fault block present");
    assert!(f.timeouts > 0, "timeouts should have fired");
    assert!(f.retries > 0, "retries should have been scheduled");
}

// ---------------------------------------------------------------------------
// Determinism: identical runs, identical bytes.
// ---------------------------------------------------------------------------

#[test]
fn scheduler_fault_run_byte_reproducible() {
    // Scripted events, a seeded generator AND a degrade ladder at once —
    // two executions must render byte-identical JSON.
    let design = micro_design();
    let base = design.frame_latency_s();
    let run = || {
        let plan = FaultPlan::new()
            .crash_at(0.004, 0)
            .recover_at(0.02, 0)
            .slow_down_at(0.01, 1, 3.0)
            .corrupt_at(0.015, 1)
            .generator(GeneratorSpec {
                seed: 7,
                units: 2,
                horizon_s: 0.3,
                crash_rate_hz: 15.0,
                mttr_s: 0.01,
                slow_rate_hz: 8.0,
                slow_factor: 2.5,
                corrupt_rate_hz: 20.0,
            });
        design
            .server()
            .streams(3)
            .workers(2)
            .policy("weighted-sla")
            .offered_fps(250.0)
            .frames(30)
            .queue_depth(4)
            .sla_ms(20.0)
            .analytic()
            .virtual_clock()
            .faults(plan)
            .degrade_ladder(vec![
                ("w1a8".to_string(), base),
                ("w1a6".to_string(), base * 0.8),
                ("w1a4".to_string(), base * 0.6),
            ])
            .run()
            .expect("fault+ladder run completes")
            .to_json()
            .pretty()
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "fault-injected run is not byte-reproducible");
}

#[test]
fn pipeline_fault_run_byte_reproducible() {
    let design = micro_design();
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    // Fault times scale with the design's own frame latency so the
    // events land mid-run whatever micro's absolute throughput is (a
    // 64-frame 2-stage run lasts roughly 32 frame-times).
    let base = design.frame_latency_s();
    let run = |strategy: FailoverStrategy| {
        let plan = FaultPlan::new()
            .crash_at(5.0 * base, 0)
            .slow_down_at(2.0 * base, 1, 2.0)
            .slow_end_at(8.0 * base, 1)
            .recovery(RecoveryConfig {
                spares: 1,
                swap_s: base,
                ..Default::default()
            });
        sharded
            .report_with_faults(64, &plan, strategy)
            .expect("faulty pipeline completes")
            .to_json()
            .pretty()
    };
    for strategy in [FailoverStrategy::Spare, FailoverStrategy::Repartition] {
        assert_eq!(
            run(strategy),
            run(strategy),
            "{strategy:?} pipeline run is not byte-reproducible"
        );
    }
}

// ---------------------------------------------------------------------------
// Pipeline failover: both strategies finish every frame.
// ---------------------------------------------------------------------------

#[test]
fn pipeline_failover_completes_all_frames() {
    let design = micro_design();
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    let base = design.frame_latency_s();
    for strategy in [FailoverStrategy::Spare, FailoverStrategy::Repartition] {
        let plan = FaultPlan::new()
            .crash_at(5.0 * base, 0)
            .recovery(RecoveryConfig {
                spares: 1,
                swap_s: base,
                ..Default::default()
            });
        let report = sharded
            .report_with_faults(48, &plan, strategy)
            .expect("faulty pipeline completes");
        let p = &report.pipeline;
        assert_eq!(p.frames, 48, "{strategy:?}: frame count off");
        assert!(p.elapsed_cycles > 0 && p.steady_fps > 0.0);
        let f = p.faults.as_ref().expect("fault block present");
        assert_eq!(f.injected_crashes, 1);
        match strategy {
            FailoverStrategy::Spare => {
                assert_eq!(f.hot_swaps, 1, "spare strategy should hot-swap");
                assert_eq!(f.final_stages, 2, "swap keeps the stage count");
            }
            FailoverStrategy::Repartition => {
                assert_eq!(f.repartitions, 1, "should re-partition once");
                assert_eq!(f.final_stages, 1, "2-stage pipeline collapses to 1");
            }
        }
    }
}

#[test]
fn pipeline_last_board_crash_without_spare_is_typed_error() {
    let design = micro_design();
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    let base = design.frame_latency_s();
    // Two crashes, no spares: the first re-partitions onto the survivor
    // (short reconfig so the pipeline is back up), the second takes the
    // last board.
    let plan = FaultPlan::new()
        .crash_at(2.0 * base, 0)
        .crash_at(10.0 * base, 1)
        .recovery(RecoveryConfig {
            reconfig_s: base,
            ..Default::default()
        });
    let err = sharded
        .report_with_faults(64, &plan, FailoverStrategy::Repartition)
        .expect_err("losing every board must error, not hang");
    assert!(
        format!("{err:#}").contains("last board"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn prop_pipeline_conserves_frames_under_sampled_fault_plans() {
    // Random plans against the 2-stage pipeline: either the run finishes
    // with every frame accounted for, or it fails with the typed
    // last-board error — never a stall, never a lost frame.
    let design = micro_design();
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    // A 24-frame 2-stage run lasts ~12 frame-times.
    let strat = prop::fault_events(2, 12.0 * design.frame_latency_s(), 8);
    let cfg = prop::Config {
        trials: 25,
        ..Default::default()
    };
    prop::check_with(&cfg, "pipeline_frame_conservation", &strat, |events| {
        let mut plan = FaultPlan::new().recovery(RecoveryConfig {
            spares: 1,
            ..Default::default()
        });
        plan.events = events.clone();
        match sharded.report_with_faults(24, &plan, FailoverStrategy::Spare) {
            Ok(report) => {
                if report.pipeline.frames != 24 {
                    return Err(format!("frames {} != 24", report.pipeline.frames));
                }
                Ok(())
            }
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains("last board") {
                    Ok(()) // all boards dead: typed refusal is the contract
                } else {
                    Err(format!("unexpected pipeline error: {msg}"))
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Availability: one crash + hot spare stays ≥ 99%.
// ---------------------------------------------------------------------------

#[test]
fn pipeline_single_crash_with_spare_keeps_three_nines() {
    let design = micro_design();
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    let base = design.frame_latency_s();
    // Swap cost = one frame-time against a ~1000-frame-time run: the
    // crashed slot's downtime is a fraction of a percent of unit-time.
    let plan = FaultPlan::new()
        .crash_at(100.0 * base, 0)
        .recovery(RecoveryConfig {
            spares: 1,
            swap_s: base,
            ..Default::default()
        });
    let report = sharded
        .report_with_faults(2000, &plan, FailoverStrategy::Spare)
        .expect("spare failover completes");
    let f = report.pipeline.faults.as_ref().expect("fault block present");
    assert_eq!(f.hot_swaps, 1);
    assert!(
        f.availability >= 0.99,
        "single crash with a hot spare must stay ≥ 99% available, got {}",
        f.availability
    );
    assert!(f.mttr_s > 0.0, "a completed swap has a measurable MTTR");
}

// ---------------------------------------------------------------------------
// Hysteresis: the degrade controller never flaps.
// ---------------------------------------------------------------------------

#[test]
fn prop_hysteresis_monotone_trace_only_demotes() {
    // On a non-decreasing latency trace a promote can never follow a
    // demote (promotion needs a full window of headroom, but misses only
    // accumulate), and any two switches sit ≥ window_len observations
    // apart — the "no demote→promote→demote inside one window" contract.
    let strat = prop::vec_of(prop::f64s(0.0, 2.0), 1, 120);
    let cfg = prop::Config {
        trials: 200,
        ..Default::default()
    };
    prop::check_with(&cfg, "hysteresis_monotone_no_flap", &strat, |trace| {
        let mut trace = trace.clone();
        trace.sort_by(|a, b| a.total_cmp(b));
        let hcfg = HysteresisConfig {
            window_len: 4,
            down_frac: 0.5,
            up_margin: 0.5,
        };
        let mut ctl =
            HysteresisController::new(3, hcfg).map_err(|e| e.to_string())?;
        for &lat in &trace {
            ctl.observe(lat, 1.0);
        }
        let switches = ctl.switches();
        for pair in switches.windows(2) {
            let (o1, r1) = pair[0];
            let (o2, r2) = pair[1];
            if r2 <= r1 {
                return Err(format!(
                    "promote on a monotone-worsening trace: rung {r1} → {r2} at obs {o2}"
                ));
            }
            if o2 - o1 < hcfg.window_len as u64 {
                return Err(format!(
                    "switches {o1} and {o2} closer than one window ({})",
                    hcfg.window_len
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hysteresis_switch_spacing_on_arbitrary_traces() {
    // Even on adversarial (unsorted) traces, consecutive switches are
    // always at least one full observation window apart.
    let strat = prop::vec_of(prop::f64s(0.0, 2.0), 1, 200);
    let cfg = prop::Config {
        trials: 200,
        ..Default::default()
    };
    prop::check_with(&cfg, "hysteresis_switch_spacing", &strat, |trace| {
        let hcfg = HysteresisConfig {
            window_len: 5,
            down_frac: 0.6,
            up_margin: 0.4,
        };
        let mut ctl =
            HysteresisController::new(4, hcfg).map_err(|e| e.to_string())?;
        for &lat in trace {
            ctl.observe(lat, 1.0);
        }
        for pair in ctl.switches().windows(2) {
            if pair[1].0 - pair[0].0 < hcfg.window_len as u64 {
                return Err(format!(
                    "switches at obs {} and {} inside one window",
                    pair[0].0, pair[1].0
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Plan round-trips and builder validation.
// ---------------------------------------------------------------------------

#[test]
fn fault_plan_json_roundtrip_preserves_schedule() {
    let plan = FaultPlan::new()
        .crash_at(0.01, 0)
        .recover_at(0.03, 0)
        .slow_down_at(0.02, 1, 4.0)
        .slow_end_at(0.05, 1)
        .corrupt_at(0.04, 2)
        .recovery(RecoveryConfig {
            max_retries: 5,
            spares: 2,
            frame_timeout_s: Some(0.01),
            ..Default::default()
        })
        .generator(GeneratorSpec {
            seed: 42,
            units: 3,
            horizon_s: 1.0,
            crash_rate_hz: 2.0,
            mttr_s: 0.05,
            slow_rate_hz: 1.0,
            slow_factor: 3.0,
            corrupt_rate_hz: 4.0,
        });
    let back = FaultPlan::from_json(&plan.to_json()).expect("roundtrip parses");
    assert_eq!(back, plan);
    assert_eq!(back.sorted_events(), plan.sorted_events());
}

#[test]
fn server_rejects_faults_and_ladders_on_wall_clock() {
    let design = micro_design();
    let err = design
        .server()
        .analytic()
        .faults(FaultPlan::new().crash_at(0.01, 0))
        .run()
        .expect_err("wall clock + faults must be rejected");
    assert!(err.to_string().contains("virtual_clock"), "got: {err}");

    let err = design
        .server()
        .analytic()
        .degrade_ladder(vec![("full".to_string(), 0.01)])
        .run()
        .expect_err("wall clock + ladder must be rejected");
    assert!(err.to_string().contains("virtual_clock"), "got: {err}");
}

#[test]
fn server_rejects_malformed_ladders() {
    let design = micro_design();
    assert!(design
        .server()
        .analytic()
        .virtual_clock()
        .degrade_ladder(vec![])
        .run()
        .is_err());
    assert!(design
        .server()
        .analytic()
        .virtual_clock()
        .degrade_ladder(vec![("full".to_string(), 0.0)])
        .run()
        .is_err());
    assert!(HysteresisConfig {
        window_len: 0,
        ..Default::default()
    }
    .validate()
    .is_err());
}

// ---------------------------------------------------------------------------
// Degrade-via-ladder beats drop-frames on SLA violations.
// ---------------------------------------------------------------------------

#[test]
fn degrade_ladder_beats_plain_drop_under_throttle() {
    // A sustained 4× throttle on the only worker overloads the stream.
    // With a degrade ladder the scheduler sheds precision instead of
    // deadline: SLA violations must not exceed the drop-frames baseline.
    let design = micro_design();
    let base = design.frame_latency_s();
    let sla_ms = base * 2.0 * 1e3;
    let run = |ladder: bool| {
        let plan = FaultPlan::new().slow_down_at(base, 0, 4.0);
        let mut b = design
            .server()
            .streams(2)
            .workers(1)
            .offered_fps(0.5 / base)
            .frames(60)
            .queue_depth(2)
            .sla_ms(sla_ms)
            .analytic()
            .virtual_clock()
            .faults(plan);
        if ladder {
            b = b.degrade_ladder(vec![
                ("full".to_string(), base),
                ("half".to_string(), base * 0.5),
                ("quarter".to_string(), base * 0.25),
            ]);
        }
        b.run().expect("throttled run completes")
    };
    let degrade = run(true);
    let drop = run(false);
    assert!(
        degrade.aggregate.sla_violations <= drop.aggregate.sla_violations,
        "ladder ({}) should not violate SLA more than plain dropping ({})",
        degrade.aggregate.sla_violations,
        drop.aggregate.sla_violations
    );
    let f = degrade.faults.expect("fault block present");
    assert!(
        !f.precision_switches.is_empty(),
        "the throttle should push the ladder down at least once"
    );
}
