//! Observability suite: trace determinism, ledger cross-checks and the
//! zero-overhead-when-disabled contract of `vaqf::obs`.
//!
//! The load-bearing property is *byte-identical traces*: every traced
//! simulator is a single-threaded discrete-event loop on the virtual
//! clock, so the exported Perfetto JSON must be a pure function of the
//! scenario — across repeated runs AND across executor thread counts
//! (threads parallelize the design-space search and kernel inner loops,
//! never event order).

use vaqf::api::{FaultPlan, RecoveryConfig, TargetSpec, Trace, TraceConfig};
use vaqf::fleet::{FleetTopology, TraceSpec};

fn micro_design(threads: usize) -> vaqf::api::CompiledDesign {
    TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .target_fps(100.0)
        .threads(threads)
        .session()
        .expect("micro session resolves")
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102")
}

/// The determinism workout: a flash-crowd burst over a mixed
/// replica + pipeline fleet, with a mid-burst crash and spare failover.
fn fleet_trace(threads: usize) -> (vaqf::api::FleetReport, Trace) {
    let design = micro_design(threads);
    let base = design.frame_latency_s();
    let trace = TraceSpec::flash_crowd(
        1.0 / base,
        8.0 / base,
        60.0 * base,
        10.0 * base,
        40.0 * base,
        200.0 * base,
        13,
    );
    let plan = FaultPlan::new().crash_at(70.0 * base, 0).recovery(RecoveryConfig {
        spares: 1,
        swap_s: 2.0 * base,
        ..Default::default()
    });
    design
        .fleet()
        .layout(FleetTopology::new().replicas(2).pipeline(2))
        .balancer("sla-weighted")
        .streams(2)
        .sla_ms(6.0 * base * 1e3)
        .trace(trace)
        .faults(plan)
        .run_traced()
        .expect("fleet run completes")
}

#[test]
fn fleet_trace_is_byte_identical_across_runs_and_threads() {
    let (r1, t1) = fleet_trace(1);
    let (r2, t2) = fleet_trace(1);
    let base = t1.to_perfetto().pretty();
    assert!(!t1.is_empty(), "the scenario produces events");
    assert_eq!(
        base,
        t2.to_perfetto().pretty(),
        "two identical runs must export byte-identical traces"
    );
    assert_eq!(r1.to_json().pretty(), r2.to_json().pretty());
    for threads in [2usize, 8] {
        let (_, t) = fleet_trace(threads);
        assert_eq!(
            base,
            t.to_perfetto().pretty(),
            "trace must not depend on the thread budget ({threads} threads)"
        );
    }
}

#[test]
fn fleet_trace_ledger_matches_report() {
    let (report, trace) = fleet_trace(1);
    let a = &report.aggregate;
    assert_eq!(trace.count("emit"), a.offered, "one emit per offered frame");
    assert_eq!(trace.count("complete"), a.completed);
    assert_eq!(trace.count("drop"), a.dropped);
    assert_eq!(trace.count("fail"), a.failed);
    assert_eq!(
        a.offered,
        a.completed + a.dropped + a.failed,
        "frame conservation"
    );
    // The crash actually showed up on the control track.
    assert_eq!(trace.count("fault_crash"), 1);
    assert!(trace.count("service") > 0, "replica service spans recorded");
}

#[test]
fn serving_trace_ledger_matches_report() {
    let design = micro_design(1);
    let base = design.frame_latency_s();
    let plan = FaultPlan::new()
        .crash_at(0.01, 0)
        .recover_at(0.05, 0)
        .slow_down_at(0.03, 1, 3.0)
        .slow_end_at(0.08, 1)
        .corrupt_at(0.06, 1);
    let (report, trace) = design
        .server()
        .streams(2)
        .workers(2)
        .policy("weighted-sla")
        .offered_fps(200.0)
        .frames(25)
        .queue_depth(4)
        .sla_ms(base * 2.0 * 1e3)
        .analytic()
        .virtual_clock()
        .faults(plan)
        .run_traced()
        .expect("fault-injected serving run completes");
    let a = &report.aggregate;
    assert_eq!(trace.count("emit"), a.offered);
    assert_eq!(trace.count("complete"), a.completed);
    assert_eq!(trace.count("drop"), a.dropped);
    assert_eq!(trace.count("fail"), a.failed);
    assert_eq!(a.offered, a.completed + a.dropped + a.failed);
    assert_eq!(trace.count("fault_crash"), 1);
    assert_eq!(trace.count("corrupt_detected"), 1);
}

#[test]
fn serving_trace_is_byte_identical_across_runs() {
    let run = || {
        let design = micro_design(1);
        design
            .server()
            .streams(3)
            .workers(2)
            .policy("least-loaded")
            .offered_fps(300.0)
            .frames(40)
            .queue_depth(2)
            .analytic()
            .virtual_clock()
            .run_traced()
            .expect("serving run completes")
    };
    let (_, t1) = run();
    let (_, t2) = run();
    assert!(!t1.is_empty());
    assert_eq!(t1.to_perfetto().pretty(), t2.to_perfetto().pretty());
    assert_eq!(t1.to_timeline(), t2.to_timeline());
    assert_eq!(t1.to_folded(), t2.to_folded());
}

#[test]
fn service_spans_nest_into_the_layer_template() {
    let design = micro_design(1);
    let layers = design.layer_template();
    assert!(!layers.is_empty(), "micro model has layers");
    let (_, trace) = design
        .server()
        .streams(1)
        .workers(1)
        .offered_fps(100.0)
        .frames(5)
        .analytic()
        .virtual_clock()
        .trace_config(TraceConfig {
            layer_detail_every: 1,
            ..TraceConfig::default()
        })
        .run_traced()
        .expect("serving run completes");
    let services = trace.count("service");
    assert!(services > 0);
    // Every service span opened into one child span per model layer.
    let first_layer = layers[0].0.as_str();
    assert_eq!(trace.count(first_layer), services);
    // And sampling turns them off without touching the parent spans.
    let (_, sampled) = design
        .server()
        .streams(1)
        .workers(1)
        .offered_fps(100.0)
        .frames(5)
        .analytic()
        .virtual_clock()
        .trace_config(TraceConfig {
            layer_detail_every: 0,
            ..TraceConfig::default()
        })
        .run_traced()
        .expect("serving run completes");
    assert_eq!(sampled.count("service"), services);
    assert_eq!(sampled.count(first_layer), 0);
}

#[test]
fn tracing_does_not_change_the_report() {
    let design = micro_design(1);
    let build = || {
        design
            .server()
            .streams(2)
            .workers(2)
            .offered_fps(250.0)
            .frames(30)
            .queue_depth(2)
            .analytic()
            .virtual_clock()
    };
    let plain = build().run().expect("plain run completes");
    let (traced, _) = build().run_traced().expect("traced run completes");
    assert_eq!(plain.to_json().pretty(), traced.to_json().pretty());
}

#[test]
fn run_traced_rejects_the_wall_clock() {
    let design = micro_design(1);
    let err = design
        .server()
        .frames(1)
        .analytic()
        .run_traced()
        .expect_err("tracing under the wall clock is a config error");
    assert!(
        err.to_string().contains("virtual_clock"),
        "error should point at .virtual_clock(): {err}"
    );
}

#[test]
fn empty_run_is_a_well_formed_zero_report() {
    // Zero offered frames: every rate field must be a finite zero, not
    // NaN, and the trace must be empty of lifecycle events.
    let design = micro_design(1);
    let (report, trace) = design
        .server()
        .streams(1)
        .workers(1)
        .offered_fps(30.0)
        .frames(0)
        .analytic()
        .virtual_clock()
        .run_traced()
        .expect("empty run completes");
    let a = &report.aggregate;
    assert_eq!(a.offered, 0);
    assert_eq!(a.drop_rate, 0.0);
    assert!(a.drop_rate.is_finite() && a.achieved_fps.is_finite());
    for s in &report.streams {
        assert!(s.drop_rate.is_finite());
    }
    assert_eq!(trace.count("emit"), 0);
}

#[test]
fn sharded_pipeline_trace_counts_match_the_report() {
    let design = micro_design(1);
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    let frames = 32;
    let (report, trace) = sharded.simulate_pipeline_with_trace(frames, TraceConfig::default());
    assert_eq!(report.frames, frames);
    assert_eq!(trace.count("emit"), frames);
    assert_eq!(trace.count("complete"), frames);
    // One service span per frame per stage.
    assert_eq!(trace.count("service"), frames * sharded.shards() as u64);
    // Deterministic too.
    let (_, again) = sharded.simulate_pipeline_with_trace(frames, TraceConfig::default());
    assert_eq!(trace.to_perfetto().pretty(), again.to_perfetto().pretty());
}

#[test]
fn metrics_registry_snapshots_the_fleet_run() {
    let (report, _) = fleet_trace(1);
    let mut reg = vaqf::api::MetricsRegistry::new();
    reg.publish_fleet(&report);
    let json = reg.to_json().pretty();
    assert!(json.contains("offered"), "snapshot carries counters: {json}");
    assert_eq!(
        reg.counter("fleet.offered"),
        Some(report.aggregate.offered),
        "published counter mirrors the report"
    );
}
