//! Integration: the cycle-level simulator's integer datapath vs the
//! AOT-compiled JAX/Pallas model executed through PJRT — same weights
//! (shared SplitMix64 stream), same inputs, logits must agree to
//! fixed-point tolerance and rank identically.
//!
//! Requires `make artifacts`; the test skips (passes with a notice)
//! otherwise so `cargo test` works on a fresh checkout.
//!
//! All checks live in ONE #[test]: the PJRT CPU client wraps non-thread-
//! safe C state, and Rust's parallel test runner would otherwise create
//! several clients concurrently (observed SIGSEGV).

use vaqf::runtime::{InferenceEngine, Manifest};
use vaqf::sim::{generate_weights, ModelExecutor};

fn micro_params(bits: Option<u8>) -> vaqf::perf::AcceleratorParams {
    use vaqf::perf::AcceleratorParams;
    match bits {
        None => AcceleratorParams::baseline(16, 2, 4, 4),
        Some(b) => {
            let g_q = AcceleratorParams::g_q_for(64, b);
            AcceleratorParams {
                t_m: 16,
                t_n: 2,
                t_m_q: 16,
                t_n_q: (2 * g_q / 4).max(1),
                g: 4,
                g_q,
                p_h: 4,
                act_bits: Some(b),
            }
        }
    }
}

fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

fn dist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
}

#[test]
fn sim_vs_pjrt_cross_checks() {
    let Ok(man) = Manifest::load("artifacts") else {
        eprintln!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    // Artifacts can exist in a build without the `pjrt` feature (the
    // stub engine's constructor errors) — skip rather than panic.
    let mut engine = match InferenceEngine::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("PJRT unavailable ({e}); skipping");
            return;
        }
    };
    for v in &man.variants {
        engine.load_variant(v).expect("load variant");
    }

    // --- 1. quantized variants agree with the integer-datapath simulator.
    for tag in ["micro_w1a8", "micro_w1a6", "micro_w1a4"] {
        let Some(entry) = man.find(tag) else { continue };
        let weights = generate_weights(&entry.config, entry.seed);
        let mut exec = ModelExecutor::new(
            weights.clone(),
            entry.act_bits_opt(),
            micro_params(entry.act_bits_opt()),
            vaqf::hw::zcu102(),
        );
        for fid in 0..4u64 {
            let patches = weights.synthetic_patches(fid);
            let (sim, _) = exec.run_frame(&patches);
            let pjrt = engine.infer(tag, &patches).expect("pjrt infer");
            assert_eq!(sim.len(), pjrt.len());
            let scale = pjrt.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            let max_rel = sim
                .iter()
                .zip(&pjrt)
                .map(|(a, b)| (a - b).abs() / scale)
                .fold(0.0f32, f32::max);
            // Tolerance grows with quantization-step size: the fixed16
            // rounding in the simulator's unquantized layers (patch embed,
            // head) shifts tensors by ~2⁻¹⁰, which coarser activation
            // grids amplify into different grid points.
            let bits = entry.act_bits_opt().unwrap_or(16);
            let tol = 0.05 + 4.0 / (1u32 << bits) as f32;
            assert!(
                max_rel < tol,
                "{tag} frame {fid}: max rel err {max_rel} exceeds tolerance {tol}"
            );
            assert_eq!(argmax(&sim), argmax(&pjrt), "{tag} frame {fid}: top-1 mismatch");
        }
        println!("{tag}: 4/4 frames agree");
    }

    // --- 2. fp32 variant agrees with the fixed16 simulator datapath.
    if let Some(entry) = man.find("micro_w32a32") {
        let weights = generate_weights(&entry.config, entry.seed);
        let mut exec =
            ModelExecutor::new(weights.clone(), None, micro_params(None), vaqf::hw::zcu102());
        let patches = weights.synthetic_patches(0);
        let (sim, _) = exec.run_frame(&patches);
        let pjrt = engine.infer("micro_w32a32", &patches).expect("infer");
        let scale = pjrt.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
        let max_rel = sim
            .iter()
            .zip(&pjrt)
            .map(|(a, b)| (a - b).abs() / scale)
            .fold(0.0f32, f32::max);
        assert!(max_rel < 0.08, "fp32 vs fixed16: max rel err {max_rel}");
        assert_eq!(argmax(&sim), argmax(&pjrt));
        println!("micro_w32a32: fixed16 datapath agrees (max rel {max_rel:.4})");
    }

    // --- 3. PJRT inference is deterministic.
    if let Some(entry) = man.find("micro_w1a8") {
        let weights = generate_weights(&entry.config, entry.seed);
        let patches = weights.synthetic_patches(9);
        let a = engine.infer("micro_w1a8", &patches).unwrap();
        let b = engine.infer("micro_w1a8", &patches).unwrap();
        assert_eq!(a, b);
    }

    // --- 4. the activation-precision ladder converges (6-bit closer to
    //        8-bit than 4-bit is), measured end-to-end through PJRT.
    if let (Some(e), Some(_), Some(_)) = (
        man.find("micro_w32a32"),
        man.find("micro_w1a6"),
        man.find("micro_w1a4"),
    ) {
        let weights = generate_weights(&e.config, e.seed);
        let patches = weights.synthetic_patches(2);
        let l8 = engine.infer("micro_w1a8", &patches).unwrap();
        let l6 = engine.infer("micro_w1a6", &patches).unwrap();
        let l4 = engine.infer("micro_w1a4", &patches).unwrap();
        assert!(
            dist(&l6, &l8) < dist(&l4, &l8),
            "6-bit ({}) should be closer to 8-bit than 4-bit ({})",
            dist(&l6, &l8),
            dist(&l4, &l8)
        );
    }
}
