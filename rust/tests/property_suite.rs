//! Property-based test suite. Each property sweeps a randomized space of
//! layers / parameter sets / devices and asserts an invariant of the
//! analytical model, the quantization math, the compiler, or the
//! simulator.
//!
//! Two idioms coexist (the offline build has no proptest):
//!
//! * hand-rolled `for trial in 0..N` sweeps over `SplitMix64` — failures
//!   print the seed for replay;
//! * the `vaqf::util::prop` strategy+shrink mini-framework — failures
//!   shrink to a minimal counterexample before panicking. The packing,
//!   quantizer, binarizer and queue-model properties below are ported
//!   onto it.

use vaqf::coordinator::{BoundedQueue, PushOutcome};
use vaqf::hw::{zcu102, Device, ResourceBudget};
use vaqf::model::{HostOp, LayerDesc, LayerKind, Precision, VitConfig};
use vaqf::perf::{
    layer_cycles, layer_cycles_opt, model_cycles, resources_for, AcceleratorParams, ModelOptions,
};
use vaqf::quant::{
    binarize, pack_bit_planes, pack_bit_planes_into, pack_sign_bits, pack_words,
    padded_lane_words, popcount_and_dot, unpack_bit_planes, unpack_words, xnor_sign_dot,
    ActQuantizer, BitPlanes,
};
use vaqf::sim::{
    generate_weights, layer_timing, reference_forward, Backend, ComputeEngine, FcScratch,
    ModelExecutor, PreparedFc,
};
use vaqf::util::prop::{self, QueueOp};
use vaqf::util::rng::SplitMix64;
use vaqf::util::simd::{self, SimdTier};

// ---------------------------------------------------------------------------
// Generators.
// ---------------------------------------------------------------------------

fn gen_layer(rng: &mut SplitMix64) -> LayerDesc {
    let heads = *[1usize, 2, 3, 4, 6, 8, 12]
        .get(rng.next_below(7) as usize)
        .unwrap();
    let kind = match rng.next_below(4) {
        0 => LayerKind::Fc,
        1 => LayerKind::AttnQk,
        2 => LayerKind::AttnSv,
        _ => LayerKind::PatchEmbed,
    };
    let quantized = rng.next_below(2) == 1 && kind != LayerKind::PatchEmbed;
    let bits = 1 + rng.next_below(16) as u8;
    let (inputs, weights, outputs) = if quantized {
        (
            Precision::Int(bits),
            if kind.is_attention() {
                Precision::Int(bits)
            } else {
                Precision::Binary
            },
            if rng.next_below(2) == 1 {
                Precision::Int(bits)
            } else {
                Precision::Fixed16
            },
        )
    } else {
        (Precision::Fixed16, Precision::Fixed16, Precision::Fixed16)
    };
    LayerDesc {
        name: format!("rand{}", rng.next_u64() % 1000),
        kind,
        m: 1 + rng.next_below(512) as usize,
        n: 1 + rng.next_below(512) as usize,
        f: 1 + rng.next_below(256) as usize,
        heads,
        inputs,
        weights,
        outputs,
        host_ops: if rng.next_below(2) == 1 {
            vec![HostOp::LayerNorm]
        } else {
            vec![]
        },
    }
}

fn gen_params(rng: &mut SplitMix64, quantized: bool) -> AcceleratorParams {
    let g = 4;
    let bits = 1 + rng.next_below(16) as u8;
    let g_q = if quantized {
        AcceleratorParams::g_q_for(64, bits)
    } else {
        g
    };
    let step = {
        // lcm(g, g_q)
        fn gcd(a: u64, b: u64) -> u64 {
            if b == 0 {
                a
            } else {
                gcd(b, a % b)
            }
        }
        g / gcd(g, g_q) * g_q
    };
    AcceleratorParams {
        t_m: step * (1 + rng.next_below(6)),
        t_n: 1 + rng.next_below(16),
        t_m_q: step * (1 + rng.next_below(8)),
        t_n_q: 1 + rng.next_below(32),
        g,
        g_q,
        p_h: *[1u64, 2, 4].get(rng.next_below(3) as usize).unwrap(),
        act_bits: if quantized { Some(bits) } else { None },
    }
}

fn gen_device(rng: &mut SplitMix64) -> Device {
    let mut d = zcu102();
    d.axi_ports_in = 1 + rng.next_below(4);
    d.axi_ports_wgt = 1 + rng.next_below(4);
    d.axi_ports_out = 1 + rng.next_below(4);
    d.budget = ResourceBudget {
        dsp: 500 + rng.next_below(4000),
        lut: 100_000 + rng.next_below(400_000),
        bram18k: 500 + rng.next_below(2000),
        ff: 200_000 + rng.next_below(600_000),
    };
    d
}

// ---------------------------------------------------------------------------
// Latency-model properties (Eqs. 7–11).
// ---------------------------------------------------------------------------

#[test]
fn prop_cycles_positive_and_finite() {
    let mut rng = SplitMix64::new(100);
    for trial in 0..300 {
        let layer = gen_layer(&mut rng);
        let params = gen_params(&mut rng, layer.alpha());
        let dev = gen_device(&mut rng);
        let c = layer_cycles(&layer, &params, &dev);
        assert!(c.total > 0, "trial {trial}: {layer:?} {params:?}");
        // The layer can never finish faster than one tile-group compute
        // pass (j_out is the FULL-tile store; a ragged last tile stores
        // less, so total ≥ j_out need not hold).
        assert!(c.total >= c.j_cmpt, "trial {trial}: total < one compute pass");
    }
}

#[test]
fn prop_cycles_monotone_in_dimensions() {
    // Growing M, N or F (all else fixed) never makes a layer faster.
    let mut rng = SplitMix64::new(101);
    for trial in 0..200 {
        let layer = gen_layer(&mut rng);
        let params = gen_params(&mut rng, layer.alpha());
        let dev = gen_device(&mut rng);
        let base = layer_cycles(&layer, &params, &dev).total;
        for grow in [
            {
                let mut l = layer.clone();
                l.m *= 2;
                l
            },
            {
                let mut l = layer.clone();
                l.n *= 2;
                l
            },
            {
                let mut l = layer.clone();
                l.f *= 2;
                l
            },
        ] {
            let grown = layer_cycles(&grow, &params, &dev).total;
            assert!(
                grown >= base,
                "trial {trial}: doubling a dimension sped the layer up\n{layer:?}\n{grow:?}"
            );
        }
    }
}

#[test]
fn prop_data_packing_never_hurts() {
    let mut rng = SplitMix64::new(102);
    for trial in 0..200 {
        let layer = gen_layer(&mut rng);
        let params = gen_params(&mut rng, layer.alpha());
        let dev = gen_device(&mut rng);
        let with = layer_cycles_opt(&layer, &params, &dev, &ModelOptions::default()).total;
        let without = layer_cycles_opt(
            &layer,
            &params,
            &dev,
            &ModelOptions {
                data_packing: false,
                ..Default::default()
            },
        )
        .total;
        assert!(with <= without, "trial {trial}: packing hurt ({with} > {without})");
    }
}

#[test]
fn prop_double_buffering_never_hurts() {
    let mut rng = SplitMix64::new(103);
    for trial in 0..200 {
        let layer = gen_layer(&mut rng);
        let params = gen_params(&mut rng, layer.alpha());
        let dev = gen_device(&mut rng);
        let with = layer_cycles_opt(&layer, &params, &dev, &ModelOptions::default()).total;
        let without = layer_cycles_opt(
            &layer,
            &params,
            &dev,
            &ModelOptions {
                double_buffering: false,
                ..Default::default()
            },
        )
        .total;
        assert!(with <= without, "trial {trial}");
    }
}

#[test]
fn prop_more_axi_ports_never_hurt() {
    let mut rng = SplitMix64::new(104);
    for trial in 0..200 {
        let layer = gen_layer(&mut rng);
        let params = gen_params(&mut rng, layer.alpha());
        let dev = gen_device(&mut rng);
        let base = layer_cycles(&layer, &params, &dev).total;
        let mut more = dev.clone();
        more.axi_ports_in += 1;
        more.axi_ports_wgt += 1;
        more.axi_ports_out += 1;
        let faster = layer_cycles(&layer, &params, &more).total;
        assert!(faster <= base, "trial {trial}: extra ports slowed things down");
    }
}

#[test]
fn prop_timeline_tracks_analytic_model() {
    // The event-timeline walk and the closed form agree to ~3% on the
    // real designs (sim::tests); the random space below includes
    // degenerate tilings (tile ≫ layer, γ-inflated stores on 3-token
    // attention) where the closed form's full-tile rounding diverges, so
    // the band here is deliberately wide — the property is "same order,
    // same direction", not "same value".
    let mut rng = SplitMix64::new(105);
    let mut checked = 0;
    for _ in 0..300 {
        let layer = gen_layer(&mut rng);
        let params = gen_params(&mut rng, layer.alpha());
        let dev = gen_device(&mut rng);
        if layer.f < 8 {
            continue; // f≈1 degenerate corner: constant terms dominate both
        }
        let analytic = layer_cycles(&layer, &params, &dev);
        let timeline = layer_timing(&layer, &params, &dev);
        if analytic.total < 5000 {
            continue; // tiny layers: constant effects dominate, skip
        }
        checked += 1;
        let ratio = timeline.total as f64 / analytic.total as f64;
        assert!(
            (0.4..=1.6).contains(&ratio),
            "ratio {ratio:.3}\nlayer {layer:?}\nparams {params:?}"
        );
    }
    assert!(checked > 50, "space too degenerate ({checked} checked)");
}

#[test]
fn prop_resources_monotone_in_tiles() {
    let mut rng = SplitMix64::new(106);
    let cfg = VitConfig {
        name: "p".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 192,
        depth: 2,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    };
    for _ in 0..100 {
        let quantized = rng.next_below(2) == 1;
        let s = cfg.structure(quantized.then_some(8));
        let params = gen_params(&mut rng, quantized);
        let dev = gen_device(&mut rng);
        let base = resources_for(&s, &params, &dev);
        let mut bigger = params;
        bigger.t_m += params.g * params.g_q; // keep divisibility
        bigger.t_m_q += params.g * params.g_q;
        let grown = resources_for(&s, &bigger, &dev);
        assert!(grown.dsp >= base.dsp);
        assert!(grown.lut >= base.lut);
        assert!(grown.total_bram() >= base.total_bram());
    }
}

// ---------------------------------------------------------------------------
// Quantization properties.
// ---------------------------------------------------------------------------

/// Center a raw `[0, 65535]` value into the signed range of `bits`
/// (`±1` for the binary width).
fn to_width(raw: u64, bits: u32) -> i32 {
    if bits == 1 {
        if raw % 2 == 1 {
            1
        } else {
            -1
        }
    } else {
        let span = 1u64 << bits;
        let lo = -(1i64 << (bits - 1));
        (lo + (raw % span) as i64) as i32
    }
}

#[test]
fn prop_pack_unpack_roundtrip_all_widths() {
    // Ported onto util::prop: a failure shrinks (bits, values) to a
    // minimal counterexample instead of dumping a 200-element vector.
    let strat = prop::tuple2(prop::bit_widths(), prop::vec_of(prop::u64s(0, 65535), 1, 200));
    let cfg = prop::Config {
        trials: 300,
        ..Default::default()
    };
    prop::check_with(&cfg, "pack_unpack_roundtrip", &strat, |(bits, raw)| {
        let bits = *bits as u32;
        let vals: Vec<i32> = raw.iter().map(|&r| to_width(r, bits)).collect();
        let packed = pack_words(&vals, bits, 64);
        if unpack_words(&packed) != vals {
            return Err(format!("roundtrip mismatch (bits={bits}, n={})", vals.len()));
        }
        // Word count is the packing-factor ceiling.
        let factor = (64 / bits) as usize;
        if packed.words.len() != vals.len().div_ceil(factor) {
            return Err(format!(
                "word count {} != ceil({} / {factor})",
                packed.words.len(),
                vals.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_error_bound_random() {
    let strat = prop::tuple2(prop::u64s(2, 16), prop::vec_of(prop::f64s(-50.0, 50.0), 1, 500));
    prop::check("quantizer_error_bound", &strat, |(bits, data)| {
        let bits = *bits as u8;
        let data: Vec<f32> = data.iter().map(|&x| x as f32).collect();
        let q = ActQuantizer::calibrate(bits, &data);
        for &x in &data {
            let y = q.dequantize_one(q.quantize_one(x));
            if (x - y).abs() > q.step() / 2.0 + 1e-4 {
                return Err(format!("bits={bits} x={x} → {y} (step {})", q.step()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_binarize_scale_bounds() {
    // The ℓ1/n scale is ≤ max|w| and ≥ 0; dense reconstruction preserves
    // the sign pattern. Shape shrinks toward 1×1 on failure.
    let strat = prop::tuple3(prop::dims(20), prop::dims(20), prop::seeds());
    prop::check("binarize_scale_bounds", &strat, |&(r, c, seed)| {
        let (r, c) = (r as usize, c as usize);
        let mut rng = SplitMix64::new(seed);
        let w: Vec<f32> = (0..r * c).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let b = binarize(&w, r, c);
        let max = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        if !(b.scale >= 0.0 && b.scale <= max + 1e-6) {
            return Err(format!("scale {} outside [0, {max}]", b.scale));
        }
        for (i, &orig) in w.iter().enumerate() {
            let sign = if b.signs[i] { 1.0f32 } else { -1.0 };
            let want = if orig > 0.0 { 1.0 } else { -1.0 };
            if sign != want {
                return Err(format!("sign flip at {i}: w={orig} sign={sign}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_queue_matches_reference_model() {
    // Model-based check of BoundedQueue against a VecDeque reference:
    // random push/pop/close scripts must agree on every outcome and on
    // the conservation counters. Failing scripts shrink to a minimal
    // operation sequence.
    use std::collections::VecDeque;
    const CAP: usize = 4;
    let strat = prop::queue_ops(200);
    let cfg = prop::Config {
        trials: 300,
        ..Default::default()
    };
    prop::check_with(&cfg, "queue_matches_reference_model", &strat, |ops| {
        let q: BoundedQueue<u32> = BoundedQueue::new(CAP);
        let mut model: VecDeque<u32> = VecDeque::new();
        let mut closed = false;
        let (mut pushed, mut dropped, mut popped) = (0u64, 0u64, 0u64);
        for (i, op) in ops.iter().enumerate() {
            match *op {
                QueueOp::Push(v) => {
                    let got = q.push(v);
                    let want = if closed {
                        PushOutcome::RejectedClosed
                    } else if model.len() == CAP {
                        model.pop_front();
                        model.push_back(v);
                        pushed += 1;
                        dropped += 1;
                        PushOutcome::AdmittedDroppedOldest
                    } else {
                        model.push_back(v);
                        pushed += 1;
                        PushOutcome::Admitted
                    };
                    if got != want {
                        return Err(format!("op {i}: push({v}) → {got:?}, model says {want:?}"));
                    }
                }
                QueueOp::Pop => {
                    let got = q.try_pop();
                    let want = model.pop_front();
                    if want.is_some() {
                        popped += 1;
                    }
                    if got != want {
                        return Err(format!("op {i}: pop → {got:?}, model says {want:?}"));
                    }
                }
                QueueOp::Close => {
                    q.close();
                    closed = true;
                }
            }
        }
        if (q.pushed(), q.dropped(), q.popped()) != (pushed, dropped, popped) {
            return Err(format!(
                "counters diverge: queue ({}, {}, {}) vs model ({pushed}, {dropped}, {popped})",
                q.pushed(),
                q.dropped(),
                q.popped()
            ));
        }
        if q.len() != model.len() {
            return Err(format!("len {} != model {}", q.len(), model.len()));
        }
        if q.pushed() != q.popped() + q.dropped() + q.len() as u64 {
            return Err("conservation: pushed != popped + dropped + len".into());
        }
        Ok(())
    });
}

#[test]
fn prop_engine_binary_matches_dense_fake_quant() {
    // The integer add/sub datapath equals x_fq @ dense(W_b) for random
    // shapes — the correctness contract between engine and oracle.
    let mut rng = SplitMix64::new(110);
    for trial in 0..40 {
        let f = 1 + rng.next_below(12) as usize;
        let n = 1 + rng.next_below(48) as usize;
        let m = 1 + rng.next_below(24) as usize;
        let bits = 4 + rng.next_below(12) as u8;
        let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        let wb = binarize(&w, n, m);
        let params = AcceleratorParams {
            t_m: 8,
            t_n: 2,
            t_m_q: 8,
            t_n_q: 2,
            g: 4,
            g_q: AcceleratorParams::g_q_for(64, bits),
            p_h: 1,
            act_bits: Some(bits),
        };
        let engine = ComputeEngine::new(params, zcu102());
        let got = engine.fc_binary(&x, &wb, f);
        let q = ActQuantizer::calibrate(bits, &x);
        let xf = q.fake_quantize(&x);
        let want = ComputeEngine::reference(&xf, &wb.to_dense(), f, n, m);
        for (i, (a, b)) in got.out.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-2 * (1.0 + b.abs()),
                "trial {trial} elem {i}: {a} vs {b} (bits={bits} f={f} n={n} m={m})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Packed-backend properties: the bit-plane encodings round-trip, and the
// packed XNOR/popcount kernels are BIT-EXACT against the scalar oracle
// over random shapes, precisions, seeds and thread counts.
// ---------------------------------------------------------------------------

fn engine_with(bits: u8, backend: Backend, threads: usize) -> ComputeEngine {
    let g_q = AcceleratorParams::g_q_for(64, bits);
    let params = AcceleratorParams {
        t_m: 8,
        t_n: 2,
        t_m_q: 8,
        t_n_q: 2,
        g: 4,
        g_q,
        p_h: 1,
        act_bits: Some(bits),
    };
    ComputeEngine::new(params, zcu102())
        .with_backend(backend)
        .with_threads(threads)
}

#[test]
fn prop_bitplane_roundtrip_all_widths() {
    let mut rng = SplitMix64::new(200);
    for bits in 1..=16u32 {
        for _ in 0..20 {
            let n = 1 + rng.next_below(300) as usize;
            let vals: Vec<i32> = (0..n)
                .map(|_| {
                    if bits == 1 {
                        if rng.next_below(2) == 1 {
                            1
                        } else {
                            -1
                        }
                    } else {
                        let hi = (1i64 << (bits - 1)) - 1;
                        let lo = -(1i64 << (bits - 1));
                        (lo + rng.next_below((hi - lo + 1) as u64) as i64) as i32
                    }
                })
                .collect();
            let bp = pack_bit_planes(&vals, bits);
            assert_eq!(unpack_bit_planes(&bp), vals, "bits={bits} n={n}");
        }
    }
}

#[test]
fn prop_packed_fc_binary_bitexact_vs_scalar() {
    let mut rng = SplitMix64::new(201);
    for trial in 0..60 {
        let f = 1 + rng.next_below(24) as usize;
        let n = 1 + rng.next_below(200) as usize; // crosses the 64-lane boundary
        let m = 1 + rng.next_below(48) as usize;
        let bits = 1 + rng.next_below(16) as u8;
        let threads = 1 + rng.next_below(4) as usize;
        let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        let wb = binarize(&w, n, m);
        let scalar = engine_with(bits, Backend::Scalar, 1).fc_binary(&x, &wb, f);
        let packed = engine_with(bits, Backend::Packed, threads).fc_binary(&x, &wb, f);
        assert_eq!(
            scalar.out, packed.out,
            "trial {trial}: f={f} n={n} m={m} bits={bits} threads={threads}"
        );
        assert_eq!(scalar.macs, packed.macs);
    }
}

#[test]
fn prop_packed_qq_matmul_bitexact_vs_scalar() {
    // Sweeps both sides of the bits² crossover (packed planes vs internal
    // scalar fallback) — results must be identical everywhere.
    let mut rng = SplitMix64::new(202);
    for trial in 0..60 {
        let f = 1 + rng.next_below(16) as usize;
        let k = 1 + rng.next_below(200) as usize;
        let m = 1 + rng.next_below(40) as usize;
        let bits = 1 + rng.next_below(16) as u8;
        let threads = 1 + rng.next_below(4) as usize;
        let a: Vec<f32> = (0..f * k).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let b: Vec<f32> = (0..k * m).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let scalar = engine_with(bits, Backend::Scalar, 1).qq_matmul(&a, &b, f, k, m);
        let packed = engine_with(bits, Backend::Packed, threads).qq_matmul(&a, &b, f, k, m);
        assert_eq!(
            scalar.out, packed.out,
            "trial {trial}: f={f} k={k} m={m} bits={bits} threads={threads}"
        );
    }
}

#[test]
fn prop_row_parallel_fixed16_bitexact_vs_serial() {
    let mut rng = SplitMix64::new(203);
    for trial in 0..40 {
        let f = 1 + rng.next_below(32) as usize;
        let n = 1 + rng.next_below(64) as usize;
        let m = 1 + rng.next_below(32) as usize;
        let threads = 2 + rng.next_below(7) as usize;
        let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        let serial = engine_with(8, Backend::Packed, 1).fc_fixed16(&x, &w, f, n, m);
        let parallel = engine_with(8, Backend::Packed, threads).fc_fixed16(&x, &w, f, n, m);
        assert_eq!(serial.out, parallel.out, "trial {trial}: f={f} threads={threads}");
    }
}

// ---------------------------------------------------------------------------
// SIMD-tier properties (PR 8): every tier the machine supports must be
// BIT-IDENTICAL to the scalar tier (and a bit-by-bit reference) on the
// popcount primitives the packed kernels are built from — over random
// lane lengths that land on the n % 64 ∈ {0, 1, 63} tail boundaries,
// bit widths 1–8 through the real pack→dot pipeline with a dirty reused
// scratch, and at the u32-accumulator overflow boundary. `VAQF_SIMD` in
// CI additionally pins the *dispatched* (cached) path to each tier
// end-to-end; these in-process sweeps force every tier explicitly.
// ---------------------------------------------------------------------------

#[test]
fn prop_simd_tiers_bitexact_on_random_lane_lengths() {
    let tiers = SimdTier::supported_tiers();
    let strat = prop::tuple2(prop::lane_lens(24), prop::seeds());
    prop::check("simd_tiers_bitexact", &strat, |&(n, seed)| {
        let n = n as usize;
        let mut rng = SplitMix64::new(seed);
        // Padded operand slices as the packers emit them — but with
        // RANDOM garbage in the pad words past ⌈n/64⌉, which the masked
        // XNOR contract must never read.
        let words = padded_lane_words(n);
        let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let want_and: u64 =
            a.iter().zip(&b).map(|(&x, &y)| u64::from((x & y).count_ones())).sum();
        let want_xnor = (0..n)
            .filter(|&p| (a[p / 64] >> (p % 64)) & 1 == (b[p / 64] >> (p % 64)) & 1)
            .count() as u64;
        for &tier in &tiers {
            let got = simd::and_popcount_with(tier, &a, &b);
            if got != want_and {
                return Err(format!("and tier {tier}: {got} != {want_and} (n={n})"));
            }
            let got = simd::xnor_popcount_with(tier, &a, &b, n);
            if got != want_xnor {
                return Err(format!("xnor tier {tier}: {got} != {want_xnor} (n={n})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simd_dots_bitexact_all_widths_on_dirty_scratch() {
    // Bit widths 1–8 through the real pack → dot pipeline, with ONE
    // BitPlanes scratch reused dirty across every trial and shape: pack
    // a random row, dot each plane against a random packed ±1 column on
    // every supported tier, and check against integer plane arithmetic
    // (and the exact ±1 dot for bits == 1).
    let tiers = SimdTier::supported_tiers();
    let strat = prop::tuple3(prop::lane_lens(4), prop::u64s(1, 8), prop::seeds());
    let scratch = std::cell::RefCell::new(BitPlanes::empty());
    prop::check("simd_dots_all_widths", &strat, |&(n, bits, seed)| {
        let n = n as usize;
        let bits = bits as u32;
        let mut rng = SplitMix64::new(seed);
        let vals: Vec<i32> = (0..n)
            .map(|_| {
                if bits == 1 {
                    if rng.next_below(2) == 1 {
                        1
                    } else {
                        -1
                    }
                } else {
                    let hi = (1i64 << (bits - 1)) - 1;
                    let lo = -(1i64 << (bits - 1));
                    (lo + rng.next_below((hi - lo + 1) as u64) as i64) as i32
                }
            })
            .collect();
        let wsigns: Vec<i32> =
            (0..n).map(|_| if rng.next_below(2) == 1 { 1 } else { -1 }).collect();
        let mut bp = scratch.borrow_mut();
        pack_bit_planes_into(&vals, bits, &mut bp);
        let wcol = pack_sign_bits(&wsigns);
        if bits == 1 {
            let want: i64 = vals.iter().zip(&wsigns).map(|(&a, &w)| (a * w) as i64).sum();
            let got = xnor_sign_dot(bp.plane(0), &wcol, n);
            if got != want {
                return Err(format!("xnor_sign_dot dispatched: {got} != {want} (n={n})"));
            }
            for &tier in &tiers {
                let got =
                    2 * simd::xnor_popcount_with(tier, bp.plane(0), &wcol, n) as i64 - n as i64;
                if got != want {
                    return Err(format!("sign dot tier {tier}: {got} != {want} (n={n})"));
                }
            }
            return Ok(());
        }
        for b in 0..bits {
            // Lanes where bit b of the two's-complement encoding is set
            // AND the weight sign bit is set.
            let want = vals
                .iter()
                .zip(&wsigns)
                .filter(|&(&v, &w)| (v as i64 as u64) >> b & 1 == 1 && w > 0)
                .count() as i64;
            let got = popcount_and_dot(bp.plane(b), &wcol);
            if got != want {
                return Err(format!("plane {b} dispatched: {got} != {want} (bits={bits} n={n})"));
            }
            for &tier in &tiers {
                let got = simd::and_popcount_with(tier, bp.plane(b), &wcol) as i64;
                if got != want {
                    return Err(format!(
                        "plane {b} tier {tier}: {got} != {want} (bits={bits} n={n})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn popcount_accumulator_survives_u32_overflow_boundary() {
    // Regression for the pre-PR8 u32 accumulators: 2²⁶ + 1 all-ones
    // words hold 2³² + 64 set bits — one word past what a u32 can count
    // (the old loop wrapped to 64 in release and panicked in debug).
    // The widened u64/i64 sums must be exact on the dispatched path and
    // on every supported tier.
    let words = vec![u64::MAX; (1usize << 26) + 1];
    let lanes = words.len() * 64; // 2³² + 64
    assert!(lanes as u64 > u32::MAX as u64);
    assert_eq!(popcount_and_dot(&words, &words), lanes as i64);
    assert_eq!(xnor_sign_dot(&words, &words, lanes), lanes as i64);
    for tier in SimdTier::supported_tiers() {
        assert_eq!(simd::and_popcount_with(tier, &words, &words), lanes as u64, "and {tier}");
        assert_eq!(
            simd::xnor_popcount_with(tier, &words, &words, lanes),
            lanes as u64,
            "xnor {tier}"
        );
    }
}

// ---------------------------------------------------------------------------
// Prepared-plan / workspace properties: the executor's cached-weight +
// reused-buffer path (and its batched form) must be bit-identical to the
// original allocating per-call path (`sim::reference_forward` — the
// pre-plan `run_frame`, kept verbatim as the oracle).
// ---------------------------------------------------------------------------

fn sim_params(bits: Option<u8>) -> AcceleratorParams {
    match bits {
        None => AcceleratorParams::baseline(16, 2, 4, 4),
        Some(b) => {
            let g_q = AcceleratorParams::g_q_for(64, b);
            AcceleratorParams {
                t_m: 16,
                t_n: 2,
                t_m_q: 16,
                t_n_q: 2 * g_q / 4,
                g: 4,
                g_q,
                p_h: 4,
                act_bits: Some(b),
            }
        }
    }
}

fn gen_tiny_vit(rng: &mut SplitMix64, trial: u64) -> VitConfig {
    let heads = 1 + rng.next_below(4) as usize;
    let head_dim = *[2usize, 4, 8].get(rng.next_below(3) as usize).unwrap();
    let patch = *[4usize, 8].get(rng.next_below(2) as usize).unwrap();
    let grid = 1 + rng.next_below(3) as usize; // 1..=3 patches per side
    VitConfig {
        name: format!("prop{trial}"),
        image_size: patch * grid,
        patch_size: patch,
        in_chans: 3,
        embed_dim: heads * head_dim,
        depth: 1 + rng.next_below(2) as usize,
        num_heads: heads,
        mlp_ratio: 2 + 2 * rng.next_below(2) as usize,
        num_classes: 3 + rng.next_below(8) as usize,
    }
}

#[test]
fn prop_prepared_workspace_path_matches_legacy_allocating_path() {
    // Random tiny ViTs × precisions (incl. unquantized) × backends ×
    // thread counts: the prepared+workspace executor must reproduce the
    // old allocating forward pass bit-for-bit — and stay identical on a
    // reused (dirty) workspace.
    let mut rng = SplitMix64::new(300);
    for trial in 0..12u64 {
        let cfg = gen_tiny_vit(&mut rng, trial);
        let bits = match rng.next_below(5) {
            0 => None,
            1 => Some(1),
            2 => Some(4),
            3 => Some(8),
            _ => Some(1 + rng.next_below(16) as u8),
        };
        let threads = 1 + rng.next_below(4) as usize;
        let w = generate_weights(&cfg, 40 + trial);
        let patches = w.synthetic_patches(trial);
        let params = sim_params(bits);

        let oracle_engine = ComputeEngine::new(params, zcu102())
            .with_backend(Backend::Scalar)
            .with_threads(1);
        let want = reference_forward(&oracle_engine, &w, &patches);

        for backend in [Backend::Scalar, Backend::Packed] {
            let mut exec = ModelExecutor::new(w.clone(), bits, params, zcu102())
                .with_backend(backend)
                .with_threads(threads);
            let (got, _) = exec.run_frame(&patches);
            assert_eq!(
                got, want,
                "trial {trial}: prepared {backend} path diverged \
                 (cfg {cfg:?}, bits {bits:?}, threads {threads})"
            );
            // Second frame on the now-dirty workspace: state must not leak.
            let (again, _) = exec.run_frame(&patches);
            assert_eq!(again, want, "trial {trial}: workspace reuse leaked state");
        }
    }
}

#[test]
fn prop_run_batch_equals_n_run_frames() {
    let mut rng = SplitMix64::new(301);
    for trial in 0..8u64 {
        let cfg = gen_tiny_vit(&mut rng, 100 + trial);
        let bits = if rng.next_below(4) == 0 {
            None
        } else {
            Some(1 + rng.next_below(12) as u8)
        };
        let threads = 1 + rng.next_below(4) as usize;
        let n_frames = 1 + rng.next_below(6) as usize;
        let w = generate_weights(&cfg, 70 + trial);
        let frames: Vec<Vec<f32>> = (0..n_frames as u64)
            .map(|i| w.synthetic_patches(i))
            .collect();
        let params = sim_params(bits);
        let mut seq = ModelExecutor::new(w.clone(), bits, params, zcu102()).with_threads(threads);
        let mut batch = ModelExecutor::new(w, bits, params, zcu102()).with_threads(threads);
        let want: Vec<_> = frames.iter().map(|p| seq.run_frame(p)).collect();
        let got = batch.run_batch(&frames);
        assert_eq!(got.len(), want.len(), "trial {trial}");
        for (i, ((gl, gt), (wl, wt))) in got.iter().zip(&want).enumerate() {
            assert_eq!(gl, wl, "trial {trial} frame {i} (threads {threads})");
            assert_eq!(gt.total_cycles, wt.total_cycles, "trial {trial} frame {i}");
        }
    }
}

#[test]
fn prop_fc_prepared_matches_allocating_call_with_reused_scratch() {
    // Engine level: one FcScratch reused across random shapes/precisions
    // must give exactly what the self-contained calls give.
    let mut rng = SplitMix64::new(302);
    let mut scratch = FcScratch::default();
    for trial in 0..40 {
        let f = 1 + rng.next_below(12) as usize;
        let n = 1 + rng.next_below(96) as usize;
        let m = 1 + rng.next_below(48) as usize;
        let bits = 1 + rng.next_below(16) as u8;
        let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
        let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
        for backend in [Backend::Scalar, Backend::Packed] {
            let engine = engine_with(bits, backend, 1);
            let wb = binarize(&w, n, m);
            let want = engine.fc_binary(&x, &wb, f);
            let prepared = PreparedFc::binary(&wb, backend);
            let mut out = vec![0.0f32; f * m];
            let macs = engine.fc_prepared(&x, &prepared, f, &mut scratch, &mut out);
            assert_eq!(out, want.out, "trial {trial} {backend} f={f} n={n} m={m} bits={bits}");
            assert_eq!(macs, want.macs);

            let want16 = engine.fc_fixed16(&x, &w, f, n, m);
            let prepared16 = PreparedFc::fixed16(&w, n, m);
            let mut out16 = vec![0.0f32; f * m];
            engine.fc_prepared(&x, &prepared16, f, &mut scratch, &mut out16);
            assert_eq!(out16, want16.out, "trial {trial} fixed16");
        }
    }
}

// ---------------------------------------------------------------------------
// Compiler properties.
// ---------------------------------------------------------------------------

#[test]
fn prop_compiled_designs_meet_target_or_error() {
    use vaqf::compiler::{compile, CompileRequest};
    let mut rng = SplitMix64::new(111);
    let model = vaqf::model::deit_small();
    for _ in 0..12 {
        let dev = gen_device(&mut rng);
        let target = 1.0 + rng.next_f64() * 60.0;
        match compile(&CompileRequest {
            model: model.clone(),
            device: dev.clone(),
            target_fps: target,
        }) {
            Ok(out) => {
                assert!(
                    out.design.summary.fps >= target,
                    "design missed its own target: {} < {target}",
                    out.design.summary.fps
                );
                assert!(out.rounds.len() - 1 <= 4, "search overran");
                assert!(out.design.params.validate().is_ok());
                let res = resources_for(
                    &model.structure(Some(out.act_bits)),
                    &out.design.params,
                    &dev,
                );
                assert!(res.feasible(&dev), "chosen design does not fit");
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("FR_max") || msg.contains("no feasible"),
                    "unexpected error: {msg}"
                );
            }
        }
    }
}

#[test]
fn prop_compile_multi_consistent_with_single() {
    use vaqf::compiler::{compile, compile_multi, CompileRequest};
    let model = vaqf::model::deit_base();
    let dev = zcu102();
    let targets = [8.0, 20.0, 26.0];
    let multi = compile_multi(&model, &dev, &targets).unwrap();
    for (target, outcome) in multi {
        let single = compile(&CompileRequest {
            model: model.clone(),
            device: dev.clone(),
            target_fps: target,
        });
        match (outcome, single) {
            (Some(m), Ok(s)) => {
                assert_eq!(
                    m.act_bits, s.act_bits,
                    "multi and single disagree at {target} FPS"
                );
            }
            (None, Err(_)) => {}
            (m, s) => panic!("feasibility disagreement at {target}: {m:?} vs {s:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Model-structure properties.
// ---------------------------------------------------------------------------

#[test]
fn prop_structure_macs_invariant_under_quantization() {
    // Quantization changes datapaths, not arithmetic: MAC totals match.
    let mut rng = SplitMix64::new(112);
    for _ in 0..30 {
        let heads = *[2usize, 3, 4].get(rng.next_below(3) as usize).unwrap();
        let cfg = VitConfig {
            name: "p".into(),
            image_size: 32,
            patch_size: 8,
            in_chans: 3,
            embed_dim: heads * (4 + rng.next_below(12) as usize),
            depth: 1 + rng.next_below(4) as usize,
            num_heads: heads,
            mlp_ratio: 4,
            num_classes: 2 + rng.next_below(100) as usize,
        };
        let fp = cfg.structure(None).total_macs();
        for bits in [1u8, 6, 8, 16] {
            assert_eq!(cfg.structure(Some(bits)).total_macs(), fp);
        }
        // Space usage shrinks under binarization.
        assert!(cfg.structure(Some(8)).space_usage_bits() < cfg.structure(None).space_usage_bits());
    }
}

// ---------------------------------------------------------------------------
// Sharding properties: the partitioner and the pipelined functional path.
// ---------------------------------------------------------------------------

/// Every policy must produce exactly `n` contiguous, non-empty ranges
/// covering `[0, len)` in order.
#[test]
fn prop_partition_contiguous_cover_no_empty_shard() {
    use vaqf::shard::{partition, ShardPolicy};
    let strat = prop::tuple2(prop::vec_of(prop::u64s(1, 1_000_000), 1, 16), prop::u64s(1, 16));
    prop::check("partition_cover", &strat, |(costs, n_raw)| {
        let n = (*n_raw as usize).clamp(1, costs.len());
        for policy in [ShardPolicy::Balanced, ShardPolicy::Even, ShardPolicy::MinLatency] {
            let ranges = partition(costs, n, policy).map_err(|e| e.to_string())?;
            if ranges.len() != n {
                return Err(format!("{policy:?}: {} ranges, wanted {n}", ranges.len()));
            }
            let mut next = 0usize;
            for r in &ranges {
                if r.start != next {
                    return Err(format!("{policy:?}: gap/overlap at {}", r.start));
                }
                if r.is_empty() {
                    return Err(format!("{policy:?}: empty shard {r:?}"));
                }
                next = r.end;
            }
            if next != costs.len() {
                return Err(format!("{policy:?}: covered {next} of {}", costs.len()));
            }
        }
        Ok(())
    });
}

/// The balanced partition's bottleneck equals the true optimum over all
/// contiguous partitions (brute-forced over every cut combination).
#[test]
fn prop_balanced_partition_bottleneck_is_optimal() {
    use vaqf::shard::{max_stage_cost, partition, ShardPolicy};

    fn brute_force_best(costs: &[u64], n: usize) -> u64 {
        // Enumerate every way to place n-1 cuts in the len-1 gaps.
        fn rec(costs: &[u64], start: usize, stages_left: usize, cur_max: u64, best: &mut u64) {
            if stages_left == 1 {
                let last: u64 = costs[start..].iter().sum();
                *best = (*best).min(cur_max.max(last));
                return;
            }
            // The next stage must leave at least stages_left-1 segments.
            for end in (start + 1)..=(costs.len() - (stages_left - 1)) {
                let stage: u64 = costs[start..end].iter().sum();
                if cur_max.max(stage) >= *best {
                    continue; // prune: cannot improve
                }
                rec(costs, end, stages_left - 1, cur_max.max(stage), best);
            }
        }
        let mut best = u64::MAX;
        rec(costs, 0, n, 0, &mut best);
        best
    }

    let strat = prop::tuple2(prop::vec_of(prop::u64s(1, 10_000), 2, 10), prop::u64s(2, 5));
    prop::check("balanced_optimal", &strat, |(costs, n_raw)| {
        let n = (*n_raw as usize).clamp(2, costs.len());
        let ranges = partition(costs, n, vaqf::shard::ShardPolicy::Balanced)
            .map_err(|e| e.to_string())?;
        let got = max_stage_cost(costs, &ranges);
        let best = brute_force_best(costs, n);
        if got != best {
            return Err(format!("bottleneck {got} vs optimal {best}"));
        }
        // min-latency may trade bottleneck for smoothness, but never
        // below the provable lower bound (and even must be no better
        // than optimal).
        for policy in [ShardPolicy::Even, ShardPolicy::MinLatency] {
            let r = partition(costs, n, policy).map_err(|e| e.to_string())?;
            if max_stage_cost(costs, &r) < best {
                return Err(format!("{policy:?} beat the proven optimum"));
            }
        }
        Ok(())
    });
}

/// The partition (and the whole per-shard co-search) is a pure function
/// of its inputs: identical across repeated runs and across concurrent
/// threads.
#[test]
fn prop_partition_deterministic_across_threads() {
    use vaqf::compiler::{optimize_baseline, optimize_for_bits};
    use vaqf::shard::{co_search, ShardPolicy};
    let model = vaqf::model::micro();
    let dev = zcu102();
    let baseline = optimize_baseline(&model.structure(None), &dev);
    let reference = optimize_for_bits(&model.structure(Some(8)), &baseline, &dev, 8).unwrap();

    let run = {
        let model = model.clone();
        let dev = dev.clone();
        let reference = reference.clone();
        move || {
            let d = co_search(&model, &dev, Some(8), &reference, 2, ShardPolicy::Balanced)
                .unwrap();
            d.stages
                .iter()
                .map(|s| (s.layer_range.clone(), s.params, s.compute_cycles))
                .collect::<Vec<_>>()
        }
    };
    let first = run();
    let handles: Vec<_> = (0..3)
        .map(|_| {
            let run = run.clone();
            std::thread::spawn(run)
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), first, "co-search must be deterministic");
    }
}

/// Pushing a frame through the sharded pipeline's stages one by one is
/// bit-identical to `run_frame` on the unsharded model, for every
/// backend, thread count, precision and shard count.
#[test]
fn prop_sharded_execution_bit_identical_to_unsharded() {
    use vaqf::compiler::{optimize_baseline, optimize_for_bits, DesignPoint};
    use vaqf::perf::summarize;
    use vaqf::shard::{co_search, ShardPolicy, ShardedExecutor};

    let dev = zcu102();
    let mut rng = SplitMix64::new(0xD15C);
    for trial in 0..6 {
        let heads = *[2usize, 4].get(rng.next_below(2) as usize).unwrap();
        let cfg = VitConfig {
            name: format!("shard-prop-{trial}"),
            image_size: 32,
            patch_size: 8,
            in_chans: 3,
            embed_dim: heads * (4 + rng.next_below(6) as usize),
            depth: 1 + rng.next_below(2) as usize,
            num_heads: heads,
            mlp_ratio: 4,
            num_classes: 2 + rng.next_below(8) as usize,
        };
        let act_bits = match rng.next_below(3) {
            0 => None,
            1 => Some(4u8),
            _ => Some(8u8),
        };
        let baseline = optimize_baseline(&cfg.structure(None), &dev);
        let reference = match act_bits {
            None => DesignPoint {
                params: baseline,
                summary: summarize(&cfg.structure(None), &baseline, &dev),
                adjustments: 0,
            },
            Some(b) => {
                optimize_for_bits(&cfg.structure(Some(b)), &baseline, &dev, b).unwrap()
            }
        };
        let seed = rng.next_u64();
        let weights = generate_weights(&cfg, seed);
        // One shard count ≥ 2 per trial (n = 1 is covered by unit tests);
        // the trials between them sweep 2..=4 stages.
        let max_shards = cfg.depth + 2;
        let n = 2 + rng.next_below(max_shards as u64 - 1) as usize;
        let design =
            co_search(&cfg, &dev, act_bits, &reference, n, ShardPolicy::Balanced).unwrap();
        for backend in [Backend::Packed, Backend::Scalar] {
            let threads = 1 + rng.next_below(2) as usize;
            let mut whole =
                ModelExecutor::new(weights.clone(), act_bits, reference.params, dev.clone())
                    .with_backend(backend)
                    .with_threads(threads);
            let mut sharded = ShardedExecutor::new(&design, backend, threads, seed);
            let patches = weights.synthetic_patches(rng.next_below(100));
            let (expect, _) = whole.run_frame(&patches);
            let (got, trace) = sharded.run_frame(&patches);
            assert_eq!(
                got, expect,
                "trial {trial} bits {act_bits:?} n {n} backend {backend:?}"
            );
            assert_eq!(trace.stages.len(), n);
        }
    }
}
