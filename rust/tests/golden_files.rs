//! Golden-file snapshot tests for emitted artifacts.
//!
//! The HLS codegen and the `report` table renderer are pure functions of
//! the compiled design, so their exact text is pinned under
//! `rust/tests/golden/`. A refactor that changes emitted artifacts now
//! fails loudly with a diff location instead of silently shifting output.
//!
//! Workflow:
//! * first run on a fresh checkout bootstraps any missing golden file
//!   (and passes) — commit the generated files;
//! * `VAQF_REGEN_GOLDEN=1 cargo test` rewrites them after an intentional
//!   change — review the diff and commit;
//! * otherwise the comparison is byte-exact.

use std::path::PathBuf;

use vaqf::api::{FailoverStrategy, FaultPlan, RecoveryConfig, TargetSpec};
use vaqf::compiler::render_table5;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

fn regen_requested() -> bool {
    std::env::var("VAQF_REGEN_GOLDEN").map(|v| v == "1").unwrap_or(false)
}

/// Compare `actual` against the checked-in golden `name`, bootstrapping
/// or regenerating per the workflow above.
fn check_golden(name: &str, actual: &str) {
    let path = golden_dir().join(name);
    if regen_requested() || !path.exists() {
        std::fs::create_dir_all(golden_dir()).expect("create golden dir");
        std::fs::write(&path, actual).expect("write golden file");
        eprintln!(
            "golden: wrote {} ({}) — commit it",
            path.display(),
            if regen_requested() { "regen" } else { "bootstrap" }
        );
        return;
    }
    let expected = std::fs::read_to_string(&path).expect("read golden file");
    if expected != actual {
        let line = expected
            .lines()
            .zip(actual.lines())
            .position(|(e, a)| e != a)
            .map(|i| i + 1)
            .unwrap_or_else(|| expected.lines().count().min(actual.lines().count()) + 1);
        panic!(
            "golden mismatch for {name} (first differing line: {line}).\n\
             If the change is intentional, regenerate with \
             `VAQF_REGEN_GOLDEN=1 cargo test --test golden_files` and commit.\n\
             --- expected ({path}) ---\n{expected}\n--- actual ---\n{actual}",
            path = path.display(),
        );
    }
}

fn micro_session() -> vaqf::api::Session {
    TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .target_fps(100.0)
        .session()
        .expect("micro session resolves")
}

#[test]
fn golden_hls_codegen_micro_w1a8() {
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    check_golden("hls_micro_w1a8.cpp", &design.hls_source());
}

#[test]
fn golden_config_json_micro_w1a8() {
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    check_golden("config_micro_w1a8.json", &design.config_json().pretty());
}

#[test]
fn golden_shard_report_micro_w1a8() {
    // The sharded report is a pure function of the design and the frame
    // count: deterministic partition, per-shard co-search, and the
    // virtual-clock pipeline DES — so its JSON pins byte-exact.
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    let report = sharded.report(32);
    check_golden("shard_report_micro_w1a8.json", &report.to_json().pretty());
}

#[test]
fn golden_serving_report_faults_micro_w1a8() {
    // A scripted (generator-free) fault plan plus a degrade ladder over
    // the analytic virtual-clock scheduler: the whole run — fault block
    // included — is a pure function of the design, so it pins byte-exact.
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    let base = design.frame_latency_s();
    let plan = FaultPlan::new()
        .crash_at(0.01, 0)
        .recover_at(0.05, 0)
        .slow_down_at(0.03, 1, 3.0)
        .slow_end_at(0.08, 1)
        .corrupt_at(0.06, 1);
    let report = design
        .server()
        .streams(2)
        .workers(2)
        .policy("weighted-sla")
        .offered_fps(200.0)
        .frames(25)
        .queue_depth(4)
        .sla_ms(base * 2.0 * 1e3)
        .analytic()
        .virtual_clock()
        .faults(plan)
        .degrade_ladder(vec![
            ("w1a8".to_string(), base),
            ("w1a4".to_string(), base * 0.6),
        ])
        .run()
        .expect("fault-injected serving run completes");
    check_golden(
        "serving_report_faults_micro_w1a8.json",
        &report.to_json().pretty(),
    );
}

#[test]
fn golden_trace_micro_serving() {
    // The exported Perfetto trace (and its plain-text timeline) of a
    // small virtual-clock serving run: integer-cycle timestamps and
    // deterministic event order make both exports pure functions of the
    // configuration, so they pin byte-exact.
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    let (_, trace) = design
        .server()
        .streams(2)
        .workers(2)
        .policy("round-robin")
        .offered_fps(150.0)
        .frames(10)
        .queue_depth(2)
        .analytic()
        .virtual_clock()
        .trace_config(vaqf::api::TraceConfig {
            layer_detail_every: 4,
            ..Default::default()
        })
        .run_traced()
        .expect("traced serving run completes");
    check_golden("trace_micro_serving.json", &trace.to_perfetto().pretty());
    check_golden("trace_micro_serving_timeline.txt", &trace.to_timeline());
}

#[test]
fn golden_shard_report_faults_micro_w1a8() {
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    let base = design.frame_latency_s();
    let sharded = design.shards(2).expect("micro splits across 2 shards");
    let plan = FaultPlan::new()
        .crash_at(4.0 * base, 0)
        .recovery(RecoveryConfig {
            spares: 1,
            swap_s: base,
            ..Default::default()
        });
    let report = sharded
        .report_with_faults(32, &plan, FailoverStrategy::Spare)
        .expect("spare failover completes");
    check_golden(
        "shard_report_faults_micro_w1a8.json",
        &report.to_json().pretty(),
    );
}

#[test]
fn golden_fleet_report_micro() {
    // A scripted flash-crowd trace with a mid-burst crash against a
    // mixed 2-replica + 2-shard-pipeline fleet on the virtual clock:
    // topology carving, balancing, trace sampling, failover and the
    // report are all pure functions of the design, so the JSON pins
    // byte-exact.
    let design = micro_session()
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102");
    let base = design.frame_latency_s();
    let trace = vaqf::fleet::TraceSpec::flash_crowd(
        1.0 / base,       // baseline: one board's worth
        8.0 / base,       // burst beyond the fleet's capacity
        60.0 * base,      // burst onset
        10.0 * base,      // ramp
        40.0 * base,      // hold
        200.0 * base,     // horizon
        13,
    );
    let plan = FaultPlan::new()
        .crash_at(70.0 * base, 0)
        .recovery(RecoveryConfig {
            spares: 1,
            swap_s: 2.0 * base,
            ..Default::default()
        });
    let report = design
        .fleet()
        .layout(vaqf::fleet::FleetTopology::new().replicas(2).pipeline(2))
        .balancer("sla-weighted")
        .streams(2)
        .sla_ms(6.0 * base * 1e3)
        .trace(trace)
        .faults(plan)
        .run()
        .expect("fleet run completes");
    check_golden("fleet_report_micro.json", &report.to_json().pretty());
}

#[test]
fn golden_report_table5_micro() {
    let session = micro_session();
    let rows = session.table5(&[8, 6]).expect("table5 precisions compile");
    let text = render_table5(&rows, &session.target().device);
    check_golden("report_table5_micro.txt", &text);
}
