//! Fleet suite: conservation, reproducibility and API invariants of
//! `vaqf::fleet` end to end through the facade.
//!
//! The load-bearing properties:
//!
//! * **conservation** — every trace arrival is completed, dropped
//!   (admission shed) or failed (retry budget), summed across serving
//!   units, under *any* sampled fault plan and every trace generator;
//! * **round-trip** — a trace spec survives JSON emit → parse → emit
//!   byte-identically, so recorded traffic is a portable artifact;
//! * **reproducibility** — two identical fleet runs render
//!   byte-identical report JSON (the one-clock design is deterministic);
//! * **scaling** — four balanced replicas complete ≥ 3× what one board
//!   completes under the same per-board offered load.

use vaqf::api::{FaultPlan, RecoveryConfig, TargetSpec, VaqfError};
use vaqf::fleet::{FleetTopology, TraceSpec};
use vaqf::util::json::Json;
use vaqf::util::prop;

fn micro_design() -> vaqf::api::CompiledDesign {
    TargetSpec::new()
        .model(vaqf::model::micro())
        .device_preset("zcu102")
        .target_fps(100.0)
        .session()
        .expect("micro session resolves")
        .compile_for_bits(Some(8))
        .expect("micro W1A8 compiles on zcu102")
}

// ---------------------------------------------------------------------------
// Conservation under sampled traces and fault plans.
// ---------------------------------------------------------------------------

#[test]
fn prop_fleet_conserves_frames_under_sampled_traces_and_faults() {
    // Random scripted fault plans (crashes that may never recover,
    // throttles, corruption) against a mixed 2-replica + 2-shard fleet,
    // each trial on a different trace generator: the ledger must balance
    // no matter what dies when. Failing plans shrink to a minimal script.
    let design = micro_design();
    let lat = design.frame_latency_s();
    let rate = 2.0 / lat; // ~2 boards' worth offered to a 4-board fleet
    let horizon = 400.0 * lat;
    let traces = [
        TraceSpec::poisson(rate, horizon, 21),
        TraceSpec::diurnal(rate, 0.8 * rate, horizon / 2.0, horizon, 22),
        TraceSpec::flash_crowd(
            0.5 * rate,
            4.0 * rate,
            0.3 * horizon,
            0.05 * horizon,
            0.2 * horizon,
            horizon,
            23,
        ),
        TraceSpec::on_off(2.0 * rate, 0.1 * horizon, 0.1 * horizon, horizon, 24),
    ];
    let strat = prop::fault_events(3, horizon, 10);
    let cfg = prop::Config {
        trials: 24,
        ..Default::default()
    };
    let trial = std::cell::Cell::new(0usize);
    prop::check_with(&cfg, "fleet_frame_conservation", &strat, |events| {
        let mut plan = FaultPlan::new();
        plan.events = events.clone();
        plan.recovery = RecoveryConfig {
            spares: events.len() % 2,
            ..RecoveryConfig::default()
        };
        let trace = traces[trial.get() % traces.len()].clone();
        trial.set(trial.get() + 1);
        let report = design
            .fleet()
            .layout(FleetTopology::new().replicas(2).pipeline(2))
            .balancer("least-outstanding")
            .streams(3)
            .trace(trace)
            .faults(plan)
            .run()
            .map_err(|e| format!("fleet run failed: {e}"))?;
        let a = &report.aggregate;
        if a.offered != a.completed + a.dropped + a.failed {
            return Err(format!(
                "aggregate ledger broke: {} offered != {} + {} + {}",
                a.offered, a.completed, a.dropped, a.failed
            ));
        }
        for s in &report.streams {
            if s.offered != s.completed + s.dropped + s.failed {
                return Err(format!("stream {} ledger broke", s.stream));
            }
        }
        // Completions are exactly the frames the units served.
        let served: u64 = report.units.iter().map(|u| u.served).sum();
        if served != a.completed {
            return Err(format!(
                "units served {served} != aggregate completed {}",
                a.completed
            ));
        }
        Ok(())
    });
}

#[test]
fn offered_equals_trace_arrivals() {
    let design = micro_design();
    let lat = design.frame_latency_s();
    let trace = TraceSpec::poisson(1.0 / lat, 200.0 * lat, 9);
    let n = vaqf::fleet::TraceSource::from_spec(trace.clone())
        .expect("valid spec")
        .len() as u64;
    let report = design
        .fleet()
        .boards(2)
        .topology("replicated")
        .trace(trace)
        .run()
        .expect("fleet runs");
    assert_eq!(report.aggregate.offered, n, "every arrival is offered exactly once");
}

// ---------------------------------------------------------------------------
// Trace JSON round-trip.
// ---------------------------------------------------------------------------

#[test]
fn trace_specs_round_trip_through_json_byte_identically() {
    let specs = [
        TraceSpec::poisson(120.0, 2.0, 1),
        TraceSpec::diurnal(60.0, 30.0, 1.0, 3.0, 2),
        TraceSpec::flash_crowd(40.0, 400.0, 0.5, 0.1, 0.3, 2.0, 3),
        TraceSpec::on_off(200.0, 0.2, 0.3, 2.5, 4),
        TraceSpec::explicit(vec![0.4, 0.1, 0.1, 0.25]),
    ];
    for spec in &specs {
        let text = spec.to_json().pretty();
        let parsed = TraceSpec::from_json(&Json::parse(&text).expect("emitted JSON parses"))
            .expect("emitted JSON round-trips");
        assert_eq!(&parsed, spec, "parse(emit(spec)) == spec");
        assert_eq!(parsed.to_json().pretty(), text, "emit is a fixed point");
    }
}

// ---------------------------------------------------------------------------
// Byte-reproducibility through the facade.
// ---------------------------------------------------------------------------

#[test]
fn fleet_runs_are_byte_reproducible_through_the_api() {
    let design = micro_design();
    let lat = design.frame_latency_s();
    let run = || {
        design
            .fleet()
            .boards(4)
            .topology("mixed")
            .balancer("sla-weighted")
            .streams(2)
            .sla_ms(8.0 * lat * 1e3)
            .trace(TraceSpec::flash_crowd(
                1.0 / lat,
                6.0 / lat,
                100.0 * lat,
                10.0 * lat,
                50.0 * lat,
                300.0 * lat,
                5,
            ))
            .faults(FaultPlan::new().crash_at(120.0 * lat, 0).recovery(RecoveryConfig {
                spares: 1,
                ..RecoveryConfig::default()
            }))
            .run()
            .expect("fleet runs")
            .to_json()
            .pretty()
    };
    assert_eq!(run(), run(), "identical inputs must render identical JSON");
}

// ---------------------------------------------------------------------------
// Scaling and topology sanity.
// ---------------------------------------------------------------------------

#[test]
fn four_replicas_complete_at_least_three_times_one_board() {
    let design = micro_design();
    let lat = design.frame_latency_s();
    let horizon = 500.0 * lat;
    // Per-board offered load is identical; only the board count changes.
    let completed = |boards: usize| {
        design
            .fleet()
            .boards(boards)
            .topology("replicated")
            .balancer("least-outstanding")
            .trace(TraceSpec::poisson(
                0.95 * boards as f64 / lat,
                horizon,
                42,
            ))
            .run()
            .expect("fleet runs")
            .aggregate
            .completed
    };
    let one = completed(1);
    let four = completed(4);
    assert!(
        four as f64 >= 3.0 * one as f64,
        "4 boards completed {four}, expected ≥ 3× single board ({one})"
    );
}

#[test]
fn topology_presets_conserve_boards_in_reports() {
    let design = micro_design();
    let lat = design.frame_latency_s();
    for preset in ["replicated", "pipelined", "mixed"] {
        let report = design
            .fleet()
            .boards(4)
            .topology(preset)
            .trace(TraceSpec::poisson(1.0 / lat, 100.0 * lat, 6))
            .run()
            .expect("fleet runs");
        assert_eq!(report.boards, 4, "{preset} must spend exactly 4 boards");
        let unit_boards: usize = report.units.iter().map(|u| u.boards).sum();
        assert_eq!(unit_boards, 4, "{preset} unit boards must sum to the budget");
    }
}

// ---------------------------------------------------------------------------
// Error paths.
// ---------------------------------------------------------------------------

#[test]
fn unknown_names_fail_with_listed_alternatives() {
    let design = micro_design();
    let err = design.fleet().balancer("random").run().unwrap_err();
    match err {
        VaqfError::Config { message } => {
            assert!(message.contains("unknown balancer policy `random`"), "{message}");
            assert!(message.contains("round-robin"), "{message}");
        }
        other => panic!("expected Config error, got {other}"),
    }
    let err = design.fleet().topology("torus").run().unwrap_err();
    match err {
        VaqfError::Config { message } => {
            assert!(message.contains("unknown fleet topology `torus`"), "{message}");
            assert!(message.contains("replicated"), "{message}");
        }
        other => panic!("expected Config error, got {other}"),
    }
    let err = design.fleet().boards(0).run().unwrap_err();
    assert!(matches!(err, VaqfError::Config { .. }), "0 boards is a config error");
}
