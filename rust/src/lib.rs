//! # VAQF — automatic software–hardware co-design for low-bit Vision Transformers
//!
//! Rust reproduction of *"VAQF: Fully Automatic Software-Hardware Co-Design
//! Framework for Low-Bit Vision Transformer"* (Sun et al., 2022).
//!
//! VAQF takes a ViT structure and a target frame rate and automatically
//! produces:
//!
//! 1. the **activation quantization precision** (weights are binary) required
//!    to hit the frame-rate target, found with a ≤4-round binary search over
//!    1..=16 bits (paper §3), and
//! 2. the **accelerator parameter settings** — tiling sizes `T_m`/`T_n`
//!    (and the quantized-path `T_m^q`/`T_n^q`), data-packing factors
//!    `G`/`G^q`, and head parallelism `P_h` — that realize it on a given
//!    FPGA device (paper §5.3).
//!
//! The physical Xilinx ZCU102 board and Vivado HLS flow of the paper are
//! replaced by two substrates built in this crate (see `DESIGN.md` §5):
//!
//! * [`perf`] — the paper's analytical resource/latency model (Eqs. 7–14),
//! * [`sim`]  — a cycle-level, *functional* simulator of the generated
//!   accelerator (Fig. 3) whose numerics are cross-checked against the
//!   AOT-compiled JAX model executed through [`runtime`] (PJRT CPU).
//!
//! The crate layout mirrors the paper:
//!
//! | module | paper section |
//! |---|---|
//! | [`model`] | §4.1 ViT structure, Fig. 2, Fig. 4 conv→FC |
//! | [`quant`] | §4.2 binarization, activation quantization, §5.3.1 packing |
//! | [`hw`] | §6.1 device inventories (ZCU102 et al.) |
//! | [`perf`] | §5.3.3 Eqs. 7–14 + throughput/power models |
//! | [`compiler`] | §3 + §5.3.2 the VAQF compilation step |
//! | [`sim`] | §5.1/§5.2 compute engine + layer processing |
//! | [`runtime`] | PJRT execution of AOT artifacts (functional reference) |
//! | [`shard`] | pipeline-parallel multi-accelerator sharding (partition → per-shard co-search → pipeline DES) |
//! | [`coordinator`] | serving: bounded queues, multi-stream scheduler, wall/virtual clocks |
//! | [`fault`] | deterministic fault injection: crash/recover/throttle/corrupt plans, failover, availability accounting |
//! | [`fleet`] | fleet-scale serving: replica/pipeline topologies, load balancers, trace-driven one-clock simulation |
//! | [`obs`] | observability: deterministic trace events, metrics registry, Perfetto/flamegraph/timeline exporters |
//! | [`config`] | TOML/JSON config system for models/devices/targets |
//!
//! [`api`] is the front door: a typed facade (`TargetSpec → Session →
//! CompiledDesign → codegen / simulator / server`) over all of the above,
//! with the matchable [`api::VaqfError`] at the boundary. The CLI, the
//! examples and the benches are thin layers over it; embedders should
//! start there.

pub mod api;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod fleet;
pub mod hw;
pub mod model;
pub mod obs;
pub mod perf;
pub mod quant;
pub mod runtime;
pub mod shard;
pub mod sim;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Clock cycles — the unit of the analytical model and the simulator.
pub type Cycles = u64;
