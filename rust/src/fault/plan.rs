//! Fault plans: what breaks, when, and how recovery is parameterized.
//!
//! A [`FaultPlan`] is a *schedule* — explicit scripted [`FaultEvent`]s
//! plus an optional seeded [`GeneratorSpec`] that samples more — and a
//! [`RecoveryConfig`] describing retry budgets, failover costs and spare
//! inventory. The plan itself is plain data: both the serving scheduler
//! and the shard pipeline interpret the same plan against their own unit
//! index space (workers, stages). Everything is timestamped in clock
//! seconds and converted to cycles by the consuming simulator, so the
//! injected run is exactly as byte-reproducible as a fault-free one.

use crate::util::json::Json;
use crate::util::rng::{poisson_arrivals, SplitMix64};

/// What happens to a unit (a worker in the scheduler, a stage board in
/// the shard pipeline) at a fault event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The board goes hard down: in-flight work is lost.
    Crash,
    /// A crashed board comes back (scheduler: worker rejoins the pool;
    /// pipeline hot-swap: the board returns to the spare inventory).
    Recover,
    /// Thermal throttle: service times are multiplied by `factor`
    /// until the matching [`FaultKind::SlowEnd`].
    SlowDown { factor: f64 },
    /// End of a throttle episode.
    SlowEnd,
    /// The unit's next completed frame is corrupted and must be
    /// re-executed (transient bit-flip, parity error on the link).
    Corrupt,
}

impl FaultKind {
    fn tag(&self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::Recover => "recover",
            FaultKind::SlowDown { .. } => "slow-down",
            FaultKind::SlowEnd => "slow-end",
            FaultKind::Corrupt => "corrupt",
        }
    }

    /// Stable discriminant for the deterministic event sort.
    fn order(&self) -> u8 {
        match self {
            FaultKind::Crash => 0,
            FaultKind::Recover => 1,
            FaultKind::SlowDown { .. } => 2,
            FaultKind::SlowEnd => 3,
            FaultKind::Corrupt => 4,
        }
    }
}

/// One scheduled fault against one unit.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Clock seconds after the run epoch.
    pub at_s: f64,
    /// Worker index (scheduler) or stage index (pipeline).
    pub unit: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("at_s", self.at_s)
            .set("unit", self.unit)
            .set("kind", self.kind.tag());
        if let FaultKind::SlowDown { factor } = self.kind {
            j = j.set("factor", factor);
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FaultEvent> {
        let at_s = j
            .get("at_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("fault event needs numeric `at_s`"))?;
        anyhow::ensure!(at_s >= 0.0 && at_s.is_finite(), "at_s must be ≥ 0");
        let unit = j
            .get("unit")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("fault event needs integer `unit`"))?
            as usize;
        let kind = match j.get("kind").and_then(Json::as_str) {
            Some("crash") => FaultKind::Crash,
            Some("recover") => FaultKind::Recover,
            Some("slow-down") => {
                let factor = j.get("factor").and_then(Json::as_f64).unwrap_or(2.0);
                anyhow::ensure!(factor >= 1.0, "slow-down factor must be ≥ 1");
                FaultKind::SlowDown { factor }
            }
            Some("slow-end") => FaultKind::SlowEnd,
            Some("corrupt") => FaultKind::Corrupt,
            other => anyhow::bail!(
                "unknown fault kind {other:?} (crash/recover/slow-down/slow-end/corrupt)"
            ),
        };
        Ok(FaultEvent { at_s, unit, kind })
    }
}

/// Retry budgets and failover costs applied while recovering from the
/// plan's events. All durations are clock seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryConfig {
    /// Re-dispatch attempts per frame before it is counted `failed`.
    pub max_retries: u32,
    /// First retry backoff; attempt `k` waits `backoff_base_s · 2^(k-1)`.
    pub backoff_base_s: f64,
    /// Give up on a dispatched frame after this long (None ⇒ wait for
    /// the worker, however slow).
    pub frame_timeout_s: Option<f64>,
    /// Pipeline hot-swap: time to power a spare board into a stage slot
    /// (FIFO re-fill transfer cost is added on top, per queued frame).
    pub swap_s: f64,
    /// Pipeline live re-partition: drain + reprogram transition time.
    pub reconfig_s: f64,
    /// Spare boards available for hot-swap.
    pub spares: usize,
}

impl Default for RecoveryConfig {
    fn default() -> RecoveryConfig {
        RecoveryConfig {
            max_retries: 3,
            backoff_base_s: 0.002,
            frame_timeout_s: None,
            swap_s: 0.005,
            reconfig_s: 0.050,
            spares: 0,
        }
    }
}

impl RecoveryConfig {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("max_retries", u64::from(self.max_retries))
            .set("backoff_base_s", self.backoff_base_s)
            .set("swap_s", self.swap_s)
            .set("reconfig_s", self.reconfig_s)
            .set("spares", self.spares);
        if let Some(t) = self.frame_timeout_s {
            j = j.set("frame_timeout_s", t);
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RecoveryConfig> {
        let d = RecoveryConfig::default();
        let f = |key: &str, dflt: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dflt);
        let cfg = RecoveryConfig {
            max_retries: j
                .get("max_retries")
                .and_then(Json::as_u64)
                .unwrap_or(u64::from(d.max_retries)) as u32,
            backoff_base_s: f("backoff_base_s", d.backoff_base_s),
            frame_timeout_s: j.get("frame_timeout_s").and_then(Json::as_f64),
            swap_s: f("swap_s", d.swap_s),
            reconfig_s: f("reconfig_s", d.reconfig_s),
            spares: j.get("spares").and_then(Json::as_u64).unwrap_or(0) as usize,
        };
        anyhow::ensure!(cfg.backoff_base_s >= 0.0, "backoff_base_s must be ≥ 0");
        anyhow::ensure!(cfg.swap_s >= 0.0 && cfg.reconfig_s >= 0.0, "costs must be ≥ 0");
        if let Some(t) = cfg.frame_timeout_s {
            anyhow::ensure!(t > 0.0, "frame_timeout_s must be positive");
        }
        Ok(cfg)
    }
}

/// A seeded fault generator: Poisson-like crash/throttle/corruption
/// arrivals over a horizon, each crash paired with a recovery after an
/// exponential repair time. Sampling is a pure function of the spec
/// (SplitMix64 + deterministic `ln`), so a generated plan replays
/// byte-identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeneratorSpec {
    pub seed: u64,
    /// How many units the generator targets (events hit `0..units`).
    pub units: usize,
    /// Horizon in clock seconds events are sampled over.
    pub horizon_s: f64,
    /// Mean crashes per second across all units.
    pub crash_rate_hz: f64,
    /// Mean repair time after a crash.
    pub mttr_s: f64,
    /// Mean throttle episodes per second (each `mttr_s` long).
    pub slow_rate_hz: f64,
    /// Cycle-multiplier applied during throttle episodes.
    pub slow_factor: f64,
    /// Mean corruption events per second.
    pub corrupt_rate_hz: f64,
}

impl GeneratorSpec {
    pub fn sample(&self) -> Vec<FaultEvent> {
        // Arrival sampling lives in util::rng (shared with the fleet trace
        // generators); the draw sequence is unchanged, so sampled plans
        // replay byte-identically across the refactor.
        let mut rng = SplitMix64::new(self.seed ^ 0xFA_17_F1A6);
        let mut out = Vec::new();
        for t in poisson_arrivals(&mut rng, self.crash_rate_hz, self.horizon_s) {
            let unit = rng.next_below(self.units.max(1) as u64) as usize;
            let repair = rng.next_exp_mean(self.mttr_s.max(1e-6));
            out.push(FaultEvent { at_s: t, unit, kind: FaultKind::Crash });
            out.push(FaultEvent {
                at_s: t + repair,
                unit,
                kind: FaultKind::Recover,
            });
        }
        for t in poisson_arrivals(&mut rng, self.slow_rate_hz, self.horizon_s) {
            let unit = rng.next_below(self.units.max(1) as u64) as usize;
            out.push(FaultEvent {
                at_s: t,
                unit,
                kind: FaultKind::SlowDown { factor: self.slow_factor.max(1.0) },
            });
            out.push(FaultEvent {
                at_s: t + self.mttr_s.max(1e-6),
                unit,
                kind: FaultKind::SlowEnd,
            });
        }
        for t in poisson_arrivals(&mut rng, self.corrupt_rate_hz, self.horizon_s) {
            let unit = rng.next_below(self.units.max(1) as u64) as usize;
            out.push(FaultEvent { at_s: t, unit, kind: FaultKind::Corrupt });
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("seed", self.seed)
            .set("units", self.units)
            .set("horizon_s", self.horizon_s)
            .set("crash_rate_hz", self.crash_rate_hz)
            .set("mttr_s", self.mttr_s)
            .set("slow_rate_hz", self.slow_rate_hz)
            .set("slow_factor", self.slow_factor)
            .set("corrupt_rate_hz", self.corrupt_rate_hz)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GeneratorSpec> {
        let f = |key: &str, dflt: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dflt);
        let spec = GeneratorSpec {
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(11),
            units: j.get("units").and_then(Json::as_u64).unwrap_or(1) as usize,
            horizon_s: f("horizon_s", 1.0),
            crash_rate_hz: f("crash_rate_hz", 0.0),
            mttr_s: f("mttr_s", 0.05),
            slow_rate_hz: f("slow_rate_hz", 0.0),
            slow_factor: f("slow_factor", 2.0),
            corrupt_rate_hz: f("corrupt_rate_hz", 0.0),
        };
        anyhow::ensure!(spec.horizon_s > 0.0, "generator horizon_s must be positive");
        anyhow::ensure!(spec.units > 0, "generator units must be ≥ 1");
        Ok(spec)
    }
}

/// The full injection schedule handed to a simulator.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Explicitly scripted events.
    pub events: Vec<FaultEvent>,
    /// Optional seeded generator whose samples are merged with `events`.
    pub generator: Option<GeneratorSpec>,
    pub recovery: RecoveryConfig,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn recovery(mut self, recovery: RecoveryConfig) -> FaultPlan {
        self.recovery = recovery;
        self
    }

    pub fn generator(mut self, spec: GeneratorSpec) -> FaultPlan {
        self.generator = Some(spec);
        self
    }

    pub fn crash_at(mut self, at_s: f64, unit: usize) -> FaultPlan {
        self.events.push(FaultEvent { at_s, unit, kind: FaultKind::Crash });
        self
    }

    pub fn recover_at(mut self, at_s: f64, unit: usize) -> FaultPlan {
        self.events.push(FaultEvent { at_s, unit, kind: FaultKind::Recover });
        self
    }

    pub fn slow_down_at(mut self, at_s: f64, unit: usize, factor: f64) -> FaultPlan {
        self.events.push(FaultEvent {
            at_s,
            unit,
            kind: FaultKind::SlowDown { factor },
        });
        self
    }

    pub fn slow_end_at(mut self, at_s: f64, unit: usize) -> FaultPlan {
        self.events.push(FaultEvent { at_s, unit, kind: FaultKind::SlowEnd });
        self
    }

    pub fn corrupt_at(mut self, at_s: f64, unit: usize) -> FaultPlan {
        self.events.push(FaultEvent { at_s, unit, kind: FaultKind::Corrupt });
        self
    }

    /// Scripted events merged with the generator's samples, in the
    /// deterministic injection order: `(at_s, unit, kind)` ascending.
    pub fn sorted_events(&self) -> Vec<FaultEvent> {
        let mut all = self.events.clone();
        if let Some(spec) = &self.generator {
            all.extend(spec.sample());
        }
        all.sort_by(|a, b| {
            a.at_s
                .total_cmp(&b.at_s)
                .then(a.unit.cmp(&b.unit))
                .then(a.kind.order().cmp(&b.kind.order()))
        });
        all
    }

    /// True when the plan injects nothing and recovery is all defaults —
    /// a simulator may take its unperturbed fast path.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.generator.is_none()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set(
                "events",
                Json::Arr(self.events.iter().map(FaultEvent::to_json).collect()),
            )
            .set("recovery", self.recovery.to_json());
        if let Some(g) = &self.generator {
            j = j.set("generator", g.to_json());
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<FaultPlan> {
        let events = match j.get("events").and_then(Json::as_arr) {
            Some(items) => items
                .iter()
                .map(FaultEvent::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => Vec::new(),
        };
        let recovery = match j.get("recovery") {
            Some(r) => RecoveryConfig::from_json(r)?,
            None => RecoveryConfig::default(),
        };
        let generator = match j.get("generator") {
            Some(g) => Some(GeneratorSpec::from_json(g)?),
            None => None,
        };
        Ok(FaultPlan { events, generator, recovery })
    }

    /// Load a plan from a JSON file (the `--faults <plan.json>` path).
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<FaultPlan> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        FaultPlan::from_json(&Json::parse(&text)?)
    }
}

/// Runtime health of one unit, as tracked by the simulators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Up,
    /// Serving, but thermally throttled (service times scaled).
    Degraded,
    Down,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_json_round_trips() {
        let plan = FaultPlan::new()
            .crash_at(0.5, 1)
            .recover_at(0.6, 1)
            .slow_down_at(0.1, 0, 2.5)
            .slow_end_at(0.2, 0)
            .corrupt_at(0.3, 1)
            .recovery(RecoveryConfig {
                max_retries: 5,
                backoff_base_s: 0.001,
                frame_timeout_s: Some(0.02),
                swap_s: 0.004,
                reconfig_s: 0.1,
                spares: 2,
            })
            .generator(GeneratorSpec {
                seed: 7,
                units: 3,
                horizon_s: 2.0,
                crash_rate_hz: 1.5,
                mttr_s: 0.05,
                slow_rate_hz: 0.5,
                slow_factor: 3.0,
                corrupt_rate_hz: 0.25,
            });
        let back = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn sorted_events_are_deterministic_and_ordered() {
        let plan = FaultPlan::new().crash_at(0.9, 0).crash_at(0.1, 2).generator(
            GeneratorSpec {
                seed: 3,
                units: 2,
                horizon_s: 1.0,
                crash_rate_hz: 4.0,
                mttr_s: 0.02,
                slow_rate_hz: 1.0,
                slow_factor: 2.0,
                corrupt_rate_hz: 1.0,
            },
        );
        let a = plan.sorted_events();
        let b = plan.sorted_events();
        assert_eq!(a, b, "sampling must be a pure function of the plan");
        assert!(a.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        assert!(a.len() >= 2, "scripted events survive the merge");
    }

    #[test]
    fn generator_pairs_every_crash_with_a_recovery() {
        let spec = GeneratorSpec {
            seed: 42,
            units: 4,
            horizon_s: 10.0,
            crash_rate_hz: 2.0,
            mttr_s: 0.1,
            slow_rate_hz: 0.0,
            slow_factor: 2.0,
            corrupt_rate_hz: 0.0,
        };
        let events = spec.sample();
        let crashes = events.iter().filter(|e| e.kind == FaultKind::Crash).count();
        let recovers = events
            .iter()
            .filter(|e| e.kind == FaultKind::Recover)
            .count();
        assert!(crashes > 0, "10 s at 2 Hz should crash at least once");
        assert_eq!(crashes, recovers);
    }

    #[test]
    fn rejects_malformed_events() {
        assert!(FaultEvent::from_json(&Json::obj().set("unit", 0u64)).is_err());
        let bad_kind = Json::obj().set("at_s", 0.1).set("unit", 0u64).set("kind", "melt");
        assert!(FaultEvent::from_json(&bad_kind).is_err());
        let neg = Json::obj().set("at_s", -1.0).set("unit", 0u64).set("kind", "crash");
        assert!(FaultEvent::from_json(&neg).is_err());
    }

    #[test]
    fn empty_plan_is_empty() {
        assert!(FaultPlan::new().is_empty());
        assert!(!FaultPlan::new().crash_at(0.0, 0).is_empty());
    }
}
