//! Deterministic fault injection, failover and graceful degradation
//! (the ROADMAP's "board failure/hot-swap events" direction).
//!
//! Real deployments lose boards, throttle under heat, and flip bits on
//! links; a serving stack whose numbers only hold while everything is
//! healthy has not measured availability at all. This module makes
//! failure a first-class, *reproducible* input:
//!
//! ```text
//! FaultPlan (scripted events + seeded GeneratorSpec, JSON-loadable)
//!     │ crash / recover / slow-down / corrupt, per unit, at clock seconds
//!     ├─► coordinator::Scheduler      workers gain Up/Degraded/Down health,
//!     │   (run_virtual)               retry + backoff + timeout re-dispatch,
//!     │                               precision demotion via the adaptive
//!     │                               hysteresis ladder
//!     └─► shard pipeline DES          stage crash → hot-swap from a spare
//!         (simulate_pipeline_faulty)  (FIFO re-fill costed) or live
//!                                     re-partition via the min-max DP
//! ```
//!
//! Both consumers interpret the same [`FaultPlan`] on the shared
//! `VirtualClock`, so an injected run is byte-reproducible exactly like
//! a fault-free one — the determinism protocol CI gates on. Reports
//! grow a fault block ([`FaultSummary`] / [`PipelineFaultSummary`]):
//! availability (`1 − Σ downtime / (units × elapsed)`), MTTR, retries,
//! re-dispatches and degraded-frame counts next to the latency
//! percentiles.

mod plan;
mod report;

pub use plan::{
    FaultEvent, FaultKind, FaultPlan, GeneratorSpec, Health, RecoveryConfig,
};
pub use report::{DowntimeTracker, FaultSummary, PipelineFaultSummary};
