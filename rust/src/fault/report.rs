//! Recovery accounting shared by both injected simulators: per-unit
//! downtime → availability, repair durations → MTTR, plus the summary
//! blocks the serving and shard reports embed (rendered only when a
//! fault plan was actually attached, so fault-free report JSON is
//! byte-identical to pre-fault builds).

use crate::util::json::Json;

/// Tracks per-unit down intervals on the simulation clock.
#[derive(Debug, Clone)]
pub struct DowntimeTracker {
    down_since: Vec<Option<f64>>,
    downtime_s: Vec<f64>,
    /// Completed crash→restore durations (feeds MTTR).
    repairs: Vec<f64>,
    crashes: u64,
}

impl DowntimeTracker {
    pub fn new(units: usize) -> DowntimeTracker {
        DowntimeTracker {
            down_since: vec![None; units],
            downtime_s: vec![0.0; units],
            repairs: Vec::new(),
            crashes: 0,
        }
    }

    pub fn mark_down(&mut self, unit: usize, now_s: f64) {
        if self.down_since[unit].is_none() {
            self.down_since[unit] = Some(now_s);
            self.crashes += 1;
        }
    }

    /// Unit restored to service: closes its down interval and records
    /// the repair duration.
    pub fn mark_up(&mut self, unit: usize, now_s: f64) {
        if let Some(since) = self.down_since[unit].take() {
            let d = (now_s - since).max(0.0);
            self.downtime_s[unit] += d;
            self.repairs.push(d);
        }
    }

    pub fn is_down(&self, unit: usize) -> bool {
        self.down_since[unit].is_some()
    }

    /// Close any still-open down interval at the end of the run (no
    /// repair recorded — the unit never came back).
    pub fn finish(&mut self, end_s: f64) {
        for unit in 0..self.down_since.len() {
            if let Some(since) = self.down_since[unit].take() {
                self.downtime_s[unit] += (end_s - since).max(0.0);
            }
        }
    }

    pub fn crashes(&self) -> u64 {
        self.crashes
    }

    /// `1 − Σ unit downtime / (units × elapsed)` — fraction of unit-time
    /// the fleet was serving.
    pub fn availability(&self, elapsed_s: f64) -> f64 {
        let units = self.down_since.len().max(1) as f64;
        if elapsed_s <= 0.0 {
            return 1.0;
        }
        (1.0 - self.downtime_s.iter().sum::<f64>() / (units * elapsed_s)).clamp(0.0, 1.0)
    }

    /// Mean time to repair over completed crash→restore cycles (0 when
    /// nothing was repaired).
    pub fn mttr_s(&self) -> f64 {
        if self.repairs.is_empty() {
            0.0
        } else {
            self.repairs.iter().sum::<f64>() / self.repairs.len() as f64
        }
    }
}

/// Fault-and-recovery block of a scheduler [`MultiServingReport`].
///
/// [`MultiServingReport`]: crate::coordinator::MultiServingReport
#[derive(Debug, Clone, Default)]
pub struct FaultSummary {
    pub injected_crashes: u64,
    pub injected_slowdowns: u64,
    pub injected_corruptions: u64,
    /// Re-dispatch attempts scheduled (backoff path), any cause.
    pub retries: u64,
    /// Frames pulled off a crashed worker and re-dispatched.
    pub redispatches: u64,
    /// Dispatches abandoned at the per-frame timeout.
    pub timeouts: u64,
    /// Completions discarded as corrupted (frame re-ran).
    pub corrupted_frames: u64,
    /// Frames served below the top precision rung.
    pub degraded_frames: u64,
    /// Precision-ladder moves as (frames-seen, new-rung) pairs.
    pub precision_switches: Vec<(u64, usize)>,
    /// Rung in effect when the run ended (0 = full precision).
    pub final_rung: usize,
    pub availability: f64,
    pub mttr_s: f64,
}

impl FaultSummary {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("injected_crashes", self.injected_crashes)
            .set("injected_slowdowns", self.injected_slowdowns)
            .set("injected_corruptions", self.injected_corruptions)
            .set("retries", self.retries)
            .set("redispatches", self.redispatches)
            .set("timeouts", self.timeouts)
            .set("corrupted_frames", self.corrupted_frames)
            .set("degraded_frames", self.degraded_frames)
            .set(
                "precision_switches",
                Json::Arr(
                    self.precision_switches
                        .iter()
                        .map(|&(frame, rung)| {
                            Json::obj().set("at_frame", frame).set("rung", rung)
                        })
                        .collect(),
                ),
            )
            .set("final_rung", self.final_rung)
            .set("availability", self.availability)
            .set("mttr_ms", self.mttr_s * 1e3)
    }

    pub fn render(&self) -> String {
        format!(
            "  faults: {c} crashes, {s} slowdowns, {k} corruptions injected — \
             availability {a:.4}, MTTR {m:.2} ms\n  \
             recovery: {r} retries ({rd} off crashed workers), {t} timeouts, \
             {cf} corrupted re-runs, {df} degraded frames, {sw} precision switches\n",
            c = self.injected_crashes,
            s = self.injected_slowdowns,
            k = self.injected_corruptions,
            a = self.availability,
            m = self.mttr_s * 1e3,
            r = self.retries,
            rd = self.redispatches,
            t = self.timeouts,
            cf = self.corrupted_frames,
            df = self.degraded_frames,
            sw = self.precision_switches.len(),
        )
    }
}

/// Fault-and-recovery block of a shard [`PipelineReport`].
///
/// [`PipelineReport`]: crate::shard::PipelineReport
#[derive(Debug, Clone, Default)]
pub struct PipelineFaultSummary {
    /// `"spare"` or `"repartition"`.
    pub strategy: String,
    pub injected_crashes: u64,
    pub injected_slowdowns: u64,
    pub injected_corruptions: u64,
    /// Crashed stages restored from the spare inventory.
    pub hot_swaps: u64,
    /// Live re-partitions of the surviving boards (min-max DP re-run).
    pub repartitions: u64,
    /// Frames pulled back for re-execution (lost in-flight work +
    /// corrupted completions).
    pub rerun_frames: u64,
    /// Stages in the final configuration (≠ initial after repartition).
    pub final_stages: usize,
    pub spares_remaining: usize,
    pub availability: f64,
    pub mttr_s: f64,
}

impl PipelineFaultSummary {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("strategy", self.strategy.as_str())
            .set("injected_crashes", self.injected_crashes)
            .set("injected_slowdowns", self.injected_slowdowns)
            .set("injected_corruptions", self.injected_corruptions)
            .set("hot_swaps", self.hot_swaps)
            .set("repartitions", self.repartitions)
            .set("rerun_frames", self.rerun_frames)
            .set("final_stages", self.final_stages)
            .set("spares_remaining", self.spares_remaining)
            .set("availability", self.availability)
            .set("mttr_ms", self.mttr_s * 1e3)
    }

    pub fn render(&self) -> String {
        format!(
            "  faults: {c} crashes injected ({strat} failover) — availability {a:.4}, \
             MTTR {m:.2} ms\n  \
             recovery: {hs} hot-swaps, {rp} re-partitions, {rr} re-run frames, \
             {fs} final stages, {sp} spares left\n",
            c = self.injected_crashes,
            strat = self.strategy,
            a = self.availability,
            m = self.mttr_s * 1e3,
            hs = self.hot_swaps,
            rp = self.repartitions,
            rr = self.rerun_frames,
            fs = self.final_stages,
            sp = self.spares_remaining,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn availability_integrates_down_intervals() {
        let mut t = DowntimeTracker::new(2);
        t.mark_down(0, 1.0);
        t.mark_up(0, 2.0); // 1 s down out of 2 units × 10 s
        t.finish(10.0);
        assert!((t.availability(10.0) - 0.95).abs() < 1e-12);
        assert!((t.mttr_s() - 1.0).abs() < 1e-12);
        assert_eq!(t.crashes(), 1);
    }

    #[test]
    fn unrepaired_unit_counts_until_the_end() {
        let mut t = DowntimeTracker::new(1);
        t.mark_down(0, 4.0);
        t.finish(10.0);
        assert!((t.availability(10.0) - 0.4).abs() < 1e-12);
        assert_eq!(t.mttr_s(), 0.0, "no completed repair");
    }

    #[test]
    fn double_down_is_idempotent() {
        let mut t = DowntimeTracker::new(1);
        t.mark_down(0, 1.0);
        t.mark_down(0, 2.0);
        assert!(t.is_down(0));
        t.mark_up(0, 3.0);
        assert!(!t.is_down(0));
        t.finish(10.0);
        assert!((t.availability(10.0) - 0.8).abs() < 1e-12);
        assert_eq!(t.crashes(), 1);
    }
}
