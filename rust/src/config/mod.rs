//! JSON config system: custom models, devices and compile targets.
//!
//! Presets cover the paper's setups; this module lets a downstream user
//! describe *their* ViT variant and FPGA without recompiling:
//!
//! ```json
//! {
//!   "model": { "name": "my-vit", "image_size": 224, "patch_size": 16,
//!              "in_chans": 3, "embed_dim": 512, "depth": 8,
//!              "num_heads": 8, "mlp_ratio": 4, "num_classes": 100 },
//!   "device": { "name": "my-board", "dsp": 1728, "lut": 230400,
//!               "bram18k": 1248, "ff": 460800, "clock_mhz": 200,
//!               "axi_port_bits": 64, "axi_ports_in": 2,
//!               "axi_ports_wgt": 2, "axi_ports_out": 2 },
//!   "target_fps": 20.0,
//!   "backend": "packed",
//!   "threads": 8
//! }
//! ```
//!
//! Missing sections fall back to presets (`deit-base`, `zcu102`).
//! `backend` selects the simulator's kernel implementation
//! (`"scalar"` | `"packed"`, default packed — bit-exact either way) and
//! `threads` its row-parallel fan-out (`0` ⇒ `VAQF_THREADS` /
//! available parallelism).

use std::path::Path;

use crate::hw::{Device, DevicePreset, ResourceBudget};
use crate::model::{VitConfig, VitPreset};
use crate::sim::Backend;
use crate::util::json::Json;

/// A fully-resolved compile target.
#[derive(Debug, Clone)]
pub struct Target {
    pub model: VitConfig,
    pub device: Device,
    pub target_fps: f64,
    /// Simulator kernel backend (throughput choice, never results).
    pub backend: Backend,
    /// Simulator row-parallel worker count (`0` ⇒ environment default).
    pub threads: usize,
}

impl Default for Target {
    fn default() -> Self {
        Target {
            model: VitPreset::DeiTBase.config(),
            device: DevicePreset::Zcu102.device(),
            target_fps: 24.0,
            backend: Backend::from_env(),
            threads: 0,
        }
    }
}

fn get_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(Json::as_u64)
        .map(|v| v as usize)
        .ok_or_else(|| anyhow::anyhow!("missing field `{key}`"))
}

fn get_u64(j: &Json, key: &str) -> anyhow::Result<u64> {
    j.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing field `{key}`"))
}

/// Parse a model section. A bare string selects a preset.
pub fn model_from_json(j: &Json) -> anyhow::Result<VitConfig> {
    if let Some(name) = j.as_str() {
        return VitPreset::from_name(name)
            .map(|p| p.config())
            .ok_or_else(|| anyhow::anyhow!("unknown model preset `{name}`"));
    }
    Ok(VitConfig {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("custom")
            .to_string(),
        image_size: get_usize(j, "image_size")?,
        patch_size: get_usize(j, "patch_size")?,
        in_chans: get_usize(j, "in_chans")?,
        embed_dim: get_usize(j, "embed_dim")?,
        depth: get_usize(j, "depth")?,
        num_heads: get_usize(j, "num_heads")?,
        mlp_ratio: get_usize(j, "mlp_ratio")?,
        num_classes: get_usize(j, "num_classes")?,
    })
}

/// Parse a device section. A bare string selects a preset.
pub fn device_from_json(j: &Json) -> anyhow::Result<Device> {
    if let Some(name) = j.as_str() {
        return DevicePreset::from_name(name)
            .map(|p| p.device())
            .ok_or_else(|| anyhow::anyhow!("unknown device preset `{name}`"));
    }
    let defaults = DevicePreset::Zcu102.device();
    Ok(Device {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("custom")
            .to_string(),
        budget: ResourceBudget {
            dsp: get_u64(j, "dsp")?,
            lut: get_u64(j, "lut")?,
            bram18k: get_u64(j, "bram18k")?,
            ff: get_u64(j, "ff")?,
        },
        clock_mhz: get_u64(j, "clock_mhz")?,
        axi_port_bits: get_u64(j, "axi_port_bits")? as u32,
        axi_ports_in: j.get("axi_ports_in").and_then(Json::as_u64).unwrap_or(2),
        axi_ports_wgt: j.get("axi_ports_wgt").and_then(Json::as_u64).unwrap_or(2),
        axi_ports_out: j.get("axi_ports_out").and_then(Json::as_u64).unwrap_or(2),
        r_dsp: j
            .get("r_dsp")
            .and_then(Json::as_f64)
            .unwrap_or(defaults.r_dsp),
        r_lut: j
            .get("r_lut")
            .and_then(Json::as_f64)
            .unwrap_or(defaults.r_lut),
        static_power_w: j
            .get("static_power_w")
            .and_then(Json::as_f64)
            .unwrap_or(defaults.static_power_w),
    })
}

/// Parse a full target document.
pub fn target_from_json(j: &Json) -> anyhow::Result<Target> {
    let mut t = Target::default();
    if let Some(m) = j.get("model") {
        t.model = model_from_json(m)?;
    }
    if let Some(d) = j.get("device") {
        t.device = device_from_json(d)?;
    }
    if let Some(f) = j.get("target_fps").and_then(Json::as_f64) {
        t.target_fps = f;
    }
    if let Some(b) = j.get("backend").and_then(Json::as_str) {
        t.backend = Backend::from_name(b)
            .ok_or_else(|| anyhow::anyhow!("unknown backend `{b}` (scalar|packed)"))?;
    }
    if let Some(n) = j.get("threads").and_then(Json::as_u64) {
        t.threads = n as usize;
    }
    Ok(t)
}

/// Load a target config file.
pub fn load_target(path: impl AsRef<Path>) -> anyhow::Result<Target> {
    let text = std::fs::read_to_string(path.as_ref())?;
    target_from_json(&Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_by_string() {
        let j = Json::parse(r#"{"model": "deit-small", "device": "zcu111", "target_fps": 40}"#)
            .unwrap();
        let t = target_from_json(&j).unwrap();
        assert_eq!(t.model.name, "deit-small");
        assert_eq!(t.device.name, "zcu111");
        assert_eq!(t.target_fps, 40.0);
    }

    #[test]
    fn custom_model_and_device() {
        let j = Json::parse(
            r#"{
              "model": {"name": "my-vit", "image_size": 64, "patch_size": 8,
                        "in_chans": 3, "embed_dim": 128, "depth": 4,
                        "num_heads": 4, "mlp_ratio": 4, "num_classes": 10},
              "device": {"name": "b", "dsp": 900, "lut": 100000,
                         "bram18k": 600, "ff": 200000, "clock_mhz": 100,
                         "axi_port_bits": 64}
            }"#,
        )
        .unwrap();
        let t = target_from_json(&j).unwrap();
        assert_eq!(t.model.embed_dim, 128);
        assert_eq!(t.model.tokens(), 65);
        assert_eq!(t.device.budget.dsp, 900);
        assert_eq!(t.target_fps, 24.0); // default
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"model": {"name": "x"}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
    }

    #[test]
    fn defaults() {
        let t = target_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(t.model.name, "deit-base");
        assert_eq!(t.device.name, "zcu102");
        assert_eq!(t.threads, 0);
    }

    #[test]
    fn backend_and_threads_parse() {
        let t = target_from_json(&Json::parse(r#"{"backend": "scalar", "threads": 4}"#).unwrap())
            .unwrap();
        assert_eq!(t.backend, Backend::Scalar);
        assert_eq!(t.threads, 4);
        let t = target_from_json(&Json::parse(r#"{"backend": "packed"}"#).unwrap()).unwrap();
        assert_eq!(t.backend, Backend::Packed);
        assert!(target_from_json(&Json::parse(r#"{"backend": "simd"}"#).unwrap()).is_err());
    }
}
