//! JSON config system: custom models, devices and compile targets.
//!
//! Presets cover the paper's setups; this module lets a downstream user
//! describe *their* ViT variant and FPGA without recompiling:
//!
//! ```json
//! {
//!   "model": { "name": "my-vit", "image_size": 224, "patch_size": 16,
//!              "in_chans": 3, "embed_dim": 512, "depth": 8,
//!              "num_heads": 8, "mlp_ratio": 4, "num_classes": 100 },
//!   "device": { "name": "my-board", "dsp": 1728, "lut": 230400,
//!               "bram18k": 1248, "ff": 460800, "clock_mhz": 200,
//!               "axi_port_bits": 64, "axi_ports_in": 2,
//!               "axi_ports_wgt": 2, "axi_ports_out": 2 },
//!   "target_fps": 20.0,
//!   "backend": "packed",
//!   "threads": 8
//! }
//! ```
//!
//! Sections may also name a preset (`"device": "zcu102"`) or *layer partial
//! overrides on a preset* via a `preset` key — e.g.
//! `"device": {"preset": "zcu102", "clock_mhz": 300}` is the ZCU102
//! inventory overclocked to 300 MHz; any field not listed falls back to the
//! preset's value. Without a `preset` key, the structural fields are all
//! required (a typo'd field name errors instead of silently defaulting).
//!
//! Missing sections fall back to presets (`deit-base`, `zcu102`).
//! `backend` selects the simulator's kernel implementation
//! (`"scalar"` | `"packed"`, default packed — bit-exact either way) and
//! `threads` its row-parallel fan-out (`0` ⇒ `VAQF_THREADS` /
//! available parallelism).
//!
//! [`Target::to_json`] is the exact inverse of [`target_from_json`]
//! (parse → emit → parse is the identity; property-tested below), so
//! resolved targets can be archived next to codegen artifacts and re-used
//! as config files.

use std::path::Path;

use crate::hw::{Device, DevicePreset, ResourceBudget};
use crate::model::{VitConfig, VitPreset};
use crate::sim::Backend;
use crate::util::json::Json;

/// A fully-resolved compile target.
#[derive(Debug, Clone)]
pub struct Target {
    pub model: VitConfig,
    pub device: Device,
    pub target_fps: f64,
    /// Simulator kernel backend (throughput choice, never results).
    pub backend: Backend,
    /// Simulator row-parallel worker count (`0` ⇒ environment default).
    pub threads: usize,
}

impl Default for Target {
    fn default() -> Self {
        Target {
            model: VitPreset::DeiTBase.config(),
            device: DevicePreset::Zcu102.device(),
            target_fps: 24.0,
            backend: Backend::from_env(),
            threads: 0,
        }
    }
}

impl Target {
    /// Emit the target as a full JSON config document — the inverse of
    /// [`target_from_json`].
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("model", model_to_json(&self.model))
            .set("device", device_to_json(&self.device))
            .set("target_fps", self.target_fps)
            .set("backend", self.backend.name())
            .set("threads", self.threads)
    }
}

/// A partially-specified target: exactly the fields a config document
/// provided, with no defaults filled in. The `api::TargetSpec` layering
/// needs to know which fields the file actually set so that environment
/// variables and explicit setters can take their documented precedence.
#[derive(Debug, Clone, Default)]
pub struct PartialTarget {
    pub model: Option<VitConfig>,
    pub device: Option<Device>,
    pub target_fps: Option<f64>,
    pub backend: Option<Backend>,
    pub threads: Option<usize>,
}

/// Reject object keys outside `allowed` — with preset layering every field
/// is optional, so a typo'd field name would otherwise silently fall back
/// to the preset value instead of erroring.
fn reject_unknown_keys(j: &Json, allowed: &[&str], what: &str) -> anyhow::Result<()> {
    if let Json::Obj(map) = j {
        for key in map.keys() {
            anyhow::ensure!(
                allowed.contains(&key.as_str()),
                "unknown {what} field `{key}` (allowed: {})",
                allowed.join(", ")
            );
        }
    }
    Ok(())
}

const MODEL_KEYS: &[&str] = &[
    "preset",
    "name",
    "image_size",
    "patch_size",
    "in_chans",
    "embed_dim",
    "depth",
    "num_heads",
    "mlp_ratio",
    "num_classes",
];

const DEVICE_KEYS: &[&str] = &[
    "preset",
    "name",
    "dsp",
    "lut",
    "bram18k",
    "ff",
    "clock_mhz",
    "axi_port_bits",
    "axi_ports_in",
    "axi_ports_wgt",
    "axi_ports_out",
    "r_dsp",
    "r_lut",
    "static_power_w",
];

const TARGET_KEYS: &[&str] = &["model", "device", "target_fps", "backend", "threads"];

/// Typed field access: a present key of the wrong JSON type errors instead
/// of silently falling back (same bug class as a typo'd key).
fn num_u64(j: &Json, key: &str) -> anyhow::Result<Option<u64>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a number"))?;
            // Json::as_u64's saturating cast would silently turn -300 into
            // 0 and 2.9 into 2 — reject instead.
            anyhow::ensure!(
                f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64,
                "field `{key}` must be a non-negative integer"
            );
            Ok(Some(f as u64))
        }
    }
}

fn num_f64(j: &Json, key: &str) -> anyhow::Result<Option<f64>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a number")),
    }
}

fn str_key<'a>(j: &'a Json, key: &str) -> anyhow::Result<Option<&'a str>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a string")),
    }
}

fn override_usize(j: &Json, key: &str, base: Option<usize>) -> anyhow::Result<usize> {
    match num_u64(j, key)? {
        Some(v) => Ok(v as usize),
        None => base.ok_or_else(|| anyhow::anyhow!("missing field `{key}`")),
    }
}

fn override_u64(j: &Json, key: &str, base: Option<u64>) -> anyhow::Result<u64> {
    match num_u64(j, key)? {
        Some(v) => Ok(v),
        None => base.ok_or_else(|| anyhow::anyhow!("missing field `{key}`")),
    }
}

/// Parse a model section. A bare string selects a preset; an object with a
/// `preset` key starts from that preset and overrides only the fields
/// present; otherwise every structural field is required.
pub fn model_from_json(j: &Json) -> anyhow::Result<VitConfig> {
    if let Some(name) = j.as_str() {
        return VitPreset::from_name(name)
            .map(|p| p.config())
            .ok_or_else(|| anyhow::anyhow!("unknown model preset `{name}`"));
    }
    anyhow::ensure!(
        matches!(j, Json::Obj(_)),
        "model section must be a preset name or an object"
    );
    reject_unknown_keys(j, MODEL_KEYS, "model")?;
    let base = match str_key(j, "preset")? {
        Some(name) => Some(
            VitPreset::from_name(name)
                .map(|p| p.config())
                .ok_or_else(|| anyhow::anyhow!("unknown model preset `{name}`"))?,
        ),
        None => None,
    };
    let b = base.as_ref();
    Ok(VitConfig {
        name: str_key(j, "name")?
            .map(str::to_string)
            .unwrap_or_else(|| b.map(|c| c.name.clone()).unwrap_or_else(|| "custom".into())),
        image_size: override_usize(j, "image_size", b.map(|c| c.image_size))?,
        patch_size: override_usize(j, "patch_size", b.map(|c| c.patch_size))?,
        in_chans: override_usize(j, "in_chans", b.map(|c| c.in_chans))?,
        embed_dim: override_usize(j, "embed_dim", b.map(|c| c.embed_dim))?,
        depth: override_usize(j, "depth", b.map(|c| c.depth))?,
        num_heads: override_usize(j, "num_heads", b.map(|c| c.num_heads))?,
        mlp_ratio: override_usize(j, "mlp_ratio", b.map(|c| c.mlp_ratio))?,
        num_classes: override_usize(j, "num_classes", b.map(|c| c.num_classes))?,
    })
}

/// Emit a model section ([`model_from_json`]'s inverse).
pub fn model_to_json(c: &VitConfig) -> Json {
    Json::obj()
        .set("name", c.name.as_str())
        .set("image_size", c.image_size)
        .set("patch_size", c.patch_size)
        .set("in_chans", c.in_chans)
        .set("embed_dim", c.embed_dim)
        .set("depth", c.depth)
        .set("num_heads", c.num_heads)
        .set("mlp_ratio", c.mlp_ratio)
        .set("num_classes", c.num_classes)
}

/// Parse a device section. A bare string selects a preset; an object with a
/// `preset` key starts from that preset and overrides only the fields
/// present; otherwise the inventory fields are required (the calibration
/// fields `r_dsp`/`r_lut`/`static_power_w` and the per-direction AXI port
/// counts always default — to the preset's values when layering, else to
/// the ZCU102 calibration).
pub fn device_from_json(j: &Json) -> anyhow::Result<Device> {
    if let Some(name) = j.as_str() {
        return DevicePreset::from_name(name)
            .map(|p| p.device())
            .ok_or_else(|| anyhow::anyhow!("unknown device preset `{name}`"));
    }
    anyhow::ensure!(
        matches!(j, Json::Obj(_)),
        "device section must be a preset name or an object"
    );
    reject_unknown_keys(j, DEVICE_KEYS, "device")?;
    let base = match str_key(j, "preset")? {
        Some(name) => Some(
            DevicePreset::from_name(name)
                .map(|p| p.device())
                .ok_or_else(|| anyhow::anyhow!("unknown device preset `{name}`"))?,
        ),
        None => None,
    };
    let b = base.as_ref();
    let calib = DevicePreset::Zcu102.device();
    let soft = b.unwrap_or(&calib);
    Ok(Device {
        name: str_key(j, "name")?
            .map(str::to_string)
            .unwrap_or_else(|| b.map(|d| d.name.clone()).unwrap_or_else(|| "custom".into())),
        budget: ResourceBudget {
            dsp: override_u64(j, "dsp", b.map(|d| d.budget.dsp))?,
            lut: override_u64(j, "lut", b.map(|d| d.budget.lut))?,
            bram18k: override_u64(j, "bram18k", b.map(|d| d.budget.bram18k))?,
            ff: override_u64(j, "ff", b.map(|d| d.budget.ff))?,
        },
        clock_mhz: override_u64(j, "clock_mhz", b.map(|d| d.clock_mhz))?,
        axi_port_bits: override_u64(j, "axi_port_bits", b.map(|d| u64::from(d.axi_port_bits)))?
            as u32,
        axi_ports_in: num_u64(j, "axi_ports_in")?
            .unwrap_or_else(|| b.map(|d| d.axi_ports_in).unwrap_or(2)),
        axi_ports_wgt: num_u64(j, "axi_ports_wgt")?
            .unwrap_or_else(|| b.map(|d| d.axi_ports_wgt).unwrap_or(2)),
        axi_ports_out: num_u64(j, "axi_ports_out")?
            .unwrap_or_else(|| b.map(|d| d.axi_ports_out).unwrap_or(2)),
        r_dsp: num_f64(j, "r_dsp")?.unwrap_or(soft.r_dsp),
        r_lut: num_f64(j, "r_lut")?.unwrap_or(soft.r_lut),
        static_power_w: num_f64(j, "static_power_w")?.unwrap_or(soft.static_power_w),
    })
}

/// Emit a device section ([`device_from_json`]'s inverse).
pub fn device_to_json(d: &Device) -> Json {
    Json::obj()
        .set("name", d.name.as_str())
        .set("dsp", d.budget.dsp)
        .set("lut", d.budget.lut)
        .set("bram18k", d.budget.bram18k)
        .set("ff", d.budget.ff)
        .set("clock_mhz", d.clock_mhz)
        .set("axi_port_bits", d.axi_port_bits)
        .set("axi_ports_in", d.axi_ports_in)
        .set("axi_ports_wgt", d.axi_ports_wgt)
        .set("axi_ports_out", d.axi_ports_out)
        .set("r_dsp", d.r_dsp)
        .set("r_lut", d.r_lut)
        .set("static_power_w", d.static_power_w)
}

/// Parse a target document into exactly the fields it provides (no
/// defaults) — the config-file layer of `api::TargetSpec`.
pub fn partial_from_json(j: &Json) -> anyhow::Result<PartialTarget> {
    anyhow::ensure!(
        matches!(j, Json::Obj(_)),
        "target config must be a JSON object (see README.md for the schema)"
    );
    reject_unknown_keys(j, TARGET_KEYS, "target")?;
    let mut p = PartialTarget::default();
    if let Some(m) = j.get("model") {
        p.model = Some(model_from_json(m)?);
    }
    if let Some(d) = j.get("device") {
        p.device = Some(device_from_json(d)?);
    }
    if let Some(f) = num_f64(j, "target_fps")? {
        p.target_fps = Some(f);
    }
    if let Some(b) = str_key(j, "backend")? {
        p.backend = Some(
            Backend::from_name(b)
                .ok_or_else(|| anyhow::anyhow!("unknown backend `{b}` (scalar|packed)"))?,
        );
    }
    if let Some(n) = num_u64(j, "threads")? {
        p.threads = Some(n as usize);
    }
    Ok(p)
}

/// Parse a full target document (missing sections fall back to defaults).
pub fn target_from_json(j: &Json) -> anyhow::Result<Target> {
    let p = partial_from_json(j)?;
    let mut t = Target::default();
    if let Some(m) = p.model {
        t.model = m;
    }
    if let Some(d) = p.device {
        t.device = d;
    }
    if let Some(f) = p.target_fps {
        t.target_fps = f;
    }
    if let Some(b) = p.backend {
        t.backend = b;
    }
    if let Some(n) = p.threads {
        t.threads = n;
    }
    Ok(t)
}

/// Load a target config file.
pub fn load_target(path: impl AsRef<Path>) -> anyhow::Result<Target> {
    let text = std::fs::read_to_string(path.as_ref())?;
    target_from_json(&Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn presets_by_string() {
        let j = Json::parse(r#"{"model": "deit-small", "device": "zcu111", "target_fps": 40}"#)
            .unwrap();
        let t = target_from_json(&j).unwrap();
        assert_eq!(t.model.name, "deit-small");
        assert_eq!(t.device.name, "zcu111");
        assert_eq!(t.target_fps, 40.0);
    }

    #[test]
    fn custom_model_and_device() {
        let j = Json::parse(
            r#"{
              "model": {"name": "my-vit", "image_size": 64, "patch_size": 8,
                        "in_chans": 3, "embed_dim": 128, "depth": 4,
                        "num_heads": 4, "mlp_ratio": 4, "num_classes": 10},
              "device": {"name": "b", "dsp": 900, "lut": 100000,
                         "bram18k": 600, "ff": 200000, "clock_mhz": 100,
                         "axi_port_bits": 64}
            }"#,
        )
        .unwrap();
        let t = target_from_json(&j).unwrap();
        assert_eq!(t.model.embed_dim, 128);
        assert_eq!(t.model.tokens(), 65);
        assert_eq!(t.device.budget.dsp, 900);
        assert_eq!(t.target_fps, 24.0); // default
    }

    #[test]
    fn missing_fields_error() {
        let j = Json::parse(r#"{"model": {"name": "x"}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
    }

    #[test]
    fn defaults() {
        let t = target_from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(t.model.name, "deit-base");
        assert_eq!(t.device.name, "zcu102");
        assert_eq!(t.threads, 0);
    }

    #[test]
    fn backend_and_threads_parse() {
        let t = target_from_json(&Json::parse(r#"{"backend": "scalar", "threads": 4}"#).unwrap())
            .unwrap();
        assert_eq!(t.backend, Backend::Scalar);
        assert_eq!(t.threads, 4);
        let t = target_from_json(&Json::parse(r#"{"backend": "packed"}"#).unwrap()).unwrap();
        assert_eq!(t.backend, Backend::Packed);
        assert!(target_from_json(&Json::parse(r#"{"backend": "simd"}"#).unwrap()).is_err());
    }

    #[test]
    fn device_partial_override_on_preset() {
        let j = Json::parse(r#"{"device": {"preset": "zcu102", "clock_mhz": 300}}"#).unwrap();
        let t = target_from_json(&j).unwrap();
        let base = DevicePreset::Zcu102.device();
        assert_eq!(t.device.clock_mhz, 300);
        assert_eq!(t.device.name, "zcu102");
        assert_eq!(t.device.budget, base.budget);
        assert_eq!(t.device.axi_port_bits, base.axi_port_bits);
        assert_eq!(t.device.axi_ports_in, base.axi_ports_in);
        assert_eq!(t.device.r_lut, base.r_lut);
    }

    #[test]
    fn model_partial_override_on_preset() {
        let j = Json::parse(r#"{"model": {"preset": "deit-base", "depth": 6, "name": "half"}}"#)
            .unwrap();
        let t = target_from_json(&j).unwrap();
        assert_eq!(t.model.depth, 6);
        assert_eq!(t.model.name, "half");
        assert_eq!(t.model.embed_dim, 768); // inherited from deit-base
    }

    #[test]
    fn typoed_field_names_error_instead_of_silently_defaulting() {
        let j = Json::parse(r#"{"device": {"preset": "zcu102", "clock_mzh": 300}}"#).unwrap();
        let e = target_from_json(&j).unwrap_err().to_string();
        assert!(e.contains("unknown device field `clock_mzh`"), "{e}");
        let j = Json::parse(r#"{"model": {"preset": "deit-base", "depht": 6}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
        let j = Json::parse(r#"{"target_fsp": 30}"#).unwrap();
        assert!(target_from_json(&j).is_err());
    }

    #[test]
    fn wrong_typed_values_error_instead_of_silently_defaulting() {
        let j = Json::parse(r#"{"device": {"preset": "zcu102", "clock_mhz": "300"}}"#).unwrap();
        let e = target_from_json(&j).unwrap_err().to_string();
        assert!(e.contains("`clock_mhz` must be a number"), "{e}");
        let j = Json::parse(r#"{"target_fps": "30"}"#).unwrap();
        assert!(target_from_json(&j).is_err());
        let j = Json::parse(r#"{"backend": 5}"#).unwrap();
        assert!(target_from_json(&j).is_err());
        let j = Json::parse(r#"{"device": {"preset": "zcu102", "r_dsp": "half"}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
        // Negative / fractional integer fields are rejected, not coerced.
        let j = Json::parse(r#"{"device": {"preset": "zcu102", "clock_mhz": -300}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
        let j = Json::parse(r#"{"threads": 2.9}"#).unwrap();
        assert!(target_from_json(&j).is_err());
    }

    #[test]
    fn unknown_preset_in_partial_override_errors() {
        let j = Json::parse(r#"{"device": {"preset": "nope", "clock_mhz": 300}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
        let j = Json::parse(r#"{"model": {"preset": "nope", "depth": 6}}"#).unwrap();
        assert!(target_from_json(&j).is_err());
    }

    #[test]
    fn target_to_json_roundtrips_presets() {
        let t = Target::default();
        let back = target_from_json(&Json::parse(&t.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back.model, t.model);
        assert_eq!(back.device, t.device);
        assert_eq!(back.target_fps, t.target_fps);
        assert_eq!(back.backend, t.backend);
        assert_eq!(back.threads, t.threads);
    }

    fn random_target(rng: &mut SplitMix64) -> Target {
        Target {
            model: VitConfig {
                name: format!("m{}", rng.next_below(1000)),
                image_size: 32 + 16 * rng.next_below(14) as usize,
                patch_size: 8,
                in_chans: 3,
                embed_dim: 32 * (1 + rng.next_below(16) as usize),
                depth: 1 + rng.next_below(16) as usize,
                num_heads: 1 + rng.next_below(12) as usize,
                mlp_ratio: 1 + rng.next_below(4) as usize,
                num_classes: 2 + rng.next_below(1000) as usize,
            },
            device: Device {
                name: format!("d{}", rng.next_below(1000)),
                budget: ResourceBudget {
                    dsp: 100 + rng.next_below(5000),
                    lut: 10_000 + rng.next_below(500_000),
                    bram18k: 100 + rng.next_below(4000),
                    ff: 10_000 + rng.next_below(1_000_000),
                },
                clock_mhz: 50 + rng.next_below(400),
                axi_port_bits: 64,
                axi_ports_in: 1 + rng.next_below(4),
                axi_ports_wgt: 1 + rng.next_below(4),
                axi_ports_out: 1 + rng.next_below(4),
                r_dsp: (rng.next_below(60) as f64 + 20.0) / 100.0,
                r_lut: (rng.next_below(60) as f64 + 20.0) / 100.0,
                static_power_w: rng.next_below(1000) as f64 / 128.0,
            },
            target_fps: rng.next_below(100_000) as f64 / 7.0,
            backend: if rng.next_below(2) == 0 {
                Backend::Scalar
            } else {
                Backend::Packed
            },
            threads: rng.next_below(32) as usize,
        }
    }

    /// Property: parse → emit → parse is the identity, and emission is a
    /// fixed point (emit(parse(emit(t))) == emit(t)), across a randomized
    /// space of custom models/devices including fractional calibration
    /// fields.
    #[test]
    fn target_json_roundtrip_property() {
        let mut rng = SplitMix64::new(0x7A86_E7);
        for case in 0..64 {
            let t = random_target(&mut rng);
            let text = t.to_json().pretty();
            let parsed = Json::parse(&text).expect("emitted JSON parses");
            let back = target_from_json(&parsed).expect("emitted JSON resolves");
            assert_eq!(back.model, t.model, "case {case}");
            assert_eq!(back.device, t.device, "case {case}");
            assert_eq!(back.target_fps, t.target_fps, "case {case}");
            assert_eq!(back.backend, t.backend, "case {case}");
            assert_eq!(back.threads, t.threads, "case {case}");
            assert_eq!(back.to_json(), t.to_json(), "case {case}: emit not a fixed point");
        }
    }
}
