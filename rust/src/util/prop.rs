//! Minimal proptest-style property harness (offline build: no proptest).
//!
//! The crate's property suites hand-rolled `for trial in 0..N` sweeps
//! over `SplitMix64`; this module factors that idiom into the two pieces
//! a real property framework adds:
//!
//! * **strategies** — composable generators ([`Strategy::generate`])
//!   with value-space *shrinking* ([`Strategy::shrink`]), so a failure
//!   is reported as a minimal counterexample, not a 500-element vector;
//! * **a driver** — [`check`] / [`check_with`] run the property over a
//!   seeded trial budget and, on failure, greedily shrink before
//!   panicking with the seed, the trial index and the shrunk input.
//!
//! Built-in strategies cover what the suites sweep: integer ranges
//! (shapes, bit-widths, seeds), floats, choices, tuples, vectors, and
//! queue-operation scripts for model-based [`BoundedQueue`] testing.
//!
//! ```no_run
//! use vaqf::util::prop;
//!
//! let strat = prop::tuple2(prop::bit_widths(), prop::u64s(1, 200));
//! prop::check("width_times_len_fits", &strat, |&(bits, n)| {
//!     if bits * n < u64::MAX / 2 { Ok(()) } else { Err("overflow".into()) }
//! });
//! ```
//!
//! [`BoundedQueue`]: crate::coordinator::BoundedQueue

use std::fmt::Debug;

use super::rng::SplitMix64;

/// A value generator with shrinking. `shrink` returns *simpler*
/// candidates (each strictly smaller by some well-founded measure, so
/// shrinking terminates); an empty vec means fully shrunk.
pub trait Strategy {
    type Value: Clone + Debug;

    fn generate(&self, rng: &mut SplitMix64) -> Self::Value;

    fn shrink(&self, _value: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Driver configuration; [`check`] uses the defaults.
#[derive(Debug, Clone)]
pub struct Config {
    pub trials: u64,
    pub seed: u64,
    /// Upper bound on accepted shrink steps (defense against a
    /// non-well-founded custom `shrink`).
    pub max_shrink_steps: u64,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            trials: 100,
            seed: 0x5EED,
            max_shrink_steps: 10_000,
        }
    }
}

/// Run `prop` over `cfg.trials` generated values; on failure, shrink to
/// a minimal counterexample and panic with a replayable report.
pub fn check_with<S: Strategy>(
    cfg: &Config,
    name: &str,
    strategy: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) {
    let mut rng = SplitMix64::new(cfg.seed);
    for trial in 0..cfg.trials {
        let value = strategy.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            let (min, min_msg, steps) = shrink_failure(cfg, strategy, value, msg, &prop);
            panic!(
                "property `{name}` failed (seed {seed:#x}, trial {trial}, \
                 {steps} shrink steps)\n  counterexample: {min:?}\n  cause: {min_msg}",
                seed = cfg.seed,
            );
        }
    }
}

/// [`check_with`] under the default [`Config`].
pub fn check<S: Strategy>(
    name: &str,
    strategy: &S,
    prop: impl Fn(&S::Value) -> Result<(), String>,
) {
    check_with(&Config::default(), name, strategy, prop);
}

/// Greedy shrink: repeatedly take the first shrink candidate that still
/// fails, until none do (or the step budget runs out).
fn shrink_failure<S: Strategy>(
    cfg: &Config,
    strategy: &S,
    mut value: S::Value,
    mut msg: String,
    prop: &impl Fn(&S::Value) -> Result<(), String>,
) -> (S::Value, String, u64) {
    let mut steps = 0;
    'outer: while steps < cfg.max_shrink_steps {
        for candidate in strategy.shrink(&value) {
            if let Err(m) = prop(&candidate) {
                value = candidate;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (value, msg, steps)
}

// ---------------------------------------------------------------------------
// Integer / float ranges.
// ---------------------------------------------------------------------------

/// Uniform `u64` in `[lo, hi]`, shrinking toward `lo`.
#[derive(Debug, Clone)]
pub struct U64Range {
    pub lo: u64,
    pub hi: u64,
}

/// Uniform `u64` in `[lo, hi]` (inclusive).
pub fn u64s(lo: u64, hi: u64) -> U64Range {
    assert!(lo <= hi);
    U64Range { lo, hi }
}

/// Bit-width strategy: the quantizer's full 1..=16 range.
pub fn bit_widths() -> U64Range {
    u64s(1, 16)
}

/// Matrix/tensor dimension in `[1, max]`.
pub fn dims(max: u64) -> U64Range {
    u64s(1, max)
}

/// Full-range PRNG seed.
pub fn seeds() -> U64Range {
    u64s(0, u64::MAX - 1)
}

impl Strategy for U64Range {
    type Value = u64;

    fn generate(&self, rng: &mut SplitMix64) -> u64 {
        self.lo + rng.next_below(self.hi - self.lo + 1)
    }

    fn shrink(&self, value: &u64) -> Vec<u64> {
        let v = *value;
        let mut out = Vec::new();
        if v > self.lo {
            out.push(self.lo);
            let mid = self.lo + (v - self.lo) / 2;
            if mid != self.lo && mid != v {
                out.push(mid);
            }
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Bit-lane counts for the SIMD-vs-scalar popcount sweeps: lengths
/// biased onto the 64-lane word edges (`n % 64 ∈ {0, 1, 63}`, where tail
/// masking breaks) mixed with uniform lengths, up to `max_words` lane
/// words; shrinks toward 1, preferring candidates snapped to the word
/// edges so boundary counterexamples stay boundary cases as they shrink.
#[derive(Debug, Clone)]
pub struct LaneLen {
    pub max_words: u64,
}

pub fn lane_lens(max_words: u64) -> LaneLen {
    assert!(max_words >= 1);
    LaneLen { max_words }
}

impl Strategy for LaneLen {
    type Value = u64;

    fn generate(&self, rng: &mut SplitMix64) -> u64 {
        let words = 1 + rng.next_below(self.max_words);
        match rng.next_below(4) {
            0 => words * 64,                     // exact multiple: no tail
            1 => words * 64 - 1,                 // 63-lane tail
            2 => (words - 1) * 64 + 1,           // 1-lane tail
            _ => 1 + rng.next_below(words * 64), // anywhere in range
        }
    }

    fn shrink(&self, value: &u64) -> Vec<u64> {
        let v = *value;
        if v <= 1 {
            return Vec::new();
        }
        let down = v / 64 * 64;
        let mut out = vec![1];
        for c in [down.saturating_sub(1), down, down + 1, v / 2, v - 1] {
            if c >= 1 && c < v {
                out.push(c);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Uniform `f64` in `[lo, hi)`, shrinking toward zero / the bounds.
#[derive(Debug, Clone)]
pub struct F64Range {
    pub lo: f64,
    pub hi: f64,
}

pub fn f64s(lo: f64, hi: f64) -> F64Range {
    assert!(lo < hi);
    F64Range { lo, hi }
}

impl Strategy for F64Range {
    type Value = f64;

    fn generate(&self, rng: &mut SplitMix64) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }

    fn shrink(&self, value: &f64) -> Vec<f64> {
        let v = *value;
        let mut out = Vec::new();
        if self.lo <= 0.0 && 0.0 < self.hi && v != 0.0 {
            out.push(0.0);
        }
        let half = v / 2.0;
        if half != v && half >= self.lo && half < self.hi {
            out.push(half);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Choice, tuples, vectors.
// ---------------------------------------------------------------------------

/// Uniform pick from a fixed list, shrinking toward earlier entries.
#[derive(Debug, Clone)]
pub struct Choice<T: Clone + Debug> {
    pub items: Vec<T>,
}

pub fn choice<T: Clone + Debug>(items: &[T]) -> Choice<T> {
    assert!(!items.is_empty());
    Choice {
        items: items.to_vec(),
    }
}

impl<T: Clone + Debug + PartialEq> Strategy for Choice<T> {
    type Value = T;

    fn generate(&self, rng: &mut SplitMix64) -> T {
        self.items[rng.next_below(self.items.len() as u64) as usize].clone()
    }

    fn shrink(&self, value: &T) -> Vec<T> {
        // Earlier entries are "simpler"; propose everything before the
        // current one, nearest-first.
        match self.items.iter().position(|i| i == value) {
            Some(pos) => self.items[..pos].iter().rev().cloned().collect(),
            None => Vec::new(),
        }
    }
}

/// Pair of independent strategies; shrinks one component at a time.
#[derive(Debug, Clone)]
pub struct Tuple2<A, B> {
    pub a: A,
    pub b: B,
}

pub fn tuple2<A: Strategy, B: Strategy>(a: A, b: B) -> Tuple2<A, B> {
    Tuple2 { a, b }
}

impl<A: Strategy, B: Strategy> Strategy for Tuple2<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        (self.a.generate(rng), self.b.generate(rng))
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.a.shrink(&value.0) {
            out.push((a, value.1.clone()));
        }
        for b in self.b.shrink(&value.1) {
            out.push((value.0.clone(), b));
        }
        out
    }
}

/// Triple of independent strategies; shrinks one component at a time.
#[derive(Debug, Clone)]
pub struct Tuple3<A, B, C> {
    pub a: A,
    pub b: B,
    pub c: C,
}

pub fn tuple3<A: Strategy, B: Strategy, C: Strategy>(a: A, b: B, c: C) -> Tuple3<A, B, C> {
    Tuple3 { a, b, c }
}

impl<A: Strategy, B: Strategy, C: Strategy> Strategy for Tuple3<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);

    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        (
            self.a.generate(rng),
            self.b.generate(rng),
            self.c.generate(rng),
        )
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.a.shrink(&value.0) {
            out.push((a, value.1.clone(), value.2.clone()));
        }
        for b in self.b.shrink(&value.1) {
            out.push((value.0.clone(), b, value.2.clone()));
        }
        for c in self.c.shrink(&value.2) {
            out.push((value.0.clone(), value.1.clone(), c));
        }
        out
    }
}

/// Vector of `min_len..=max_len` elements; shrinks by halving the
/// length, dropping single elements, and shrinking elements in place.
#[derive(Debug, Clone)]
pub struct VecOf<S> {
    pub elem: S,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn vec_of<S: Strategy>(elem: S, min_len: usize, max_len: usize) -> VecOf<S> {
    assert!(min_len <= max_len);
    VecOf {
        elem,
        min_len,
        max_len,
    }
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;

    fn generate(&self, rng: &mut SplitMix64) -> Self::Value {
        let span = (self.max_len - self.min_len) as u64;
        let len = self.min_len + rng.next_below(span + 1) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = Vec::new();
        let n = value.len();
        // Halve: first half, second half.
        if n / 2 >= self.min_len && n > 1 {
            out.push(value[..n / 2].to_vec());
            out.push(value[n - n / 2..].to_vec());
        }
        // Drop single elements (bounded fan-out: first 8 positions).
        if n > self.min_len {
            for i in 0..n.min(8) {
                let mut v = value.clone();
                v.remove(i);
                out.push(v);
            }
        }
        // Shrink elements in place (bounded fan-out).
        for i in 0..n.min(4) {
            for e in self.elem.shrink(&value[i]) {
                let mut v = value.clone();
                v[i] = e;
                out.push(v);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Queue-operation scripts (model-based BoundedQueue testing).
// ---------------------------------------------------------------------------

/// One operation against a bounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueOp {
    Push(u32),
    Pop,
    Close,
}

/// Weighted mix of queue operations (pushes dominate so scripts actually
/// fill queues); `Push` payloads shrink toward zero.
#[derive(Debug, Clone)]
pub struct QueueOpStrategy;

impl Strategy for QueueOpStrategy {
    type Value = QueueOp;

    fn generate(&self, rng: &mut SplitMix64) -> QueueOp {
        match rng.next_below(10) {
            0..=5 => QueueOp::Push(rng.next_below(1000) as u32),
            6..=8 => QueueOp::Pop,
            _ => QueueOp::Close,
        }
    }

    fn shrink(&self, value: &QueueOp) -> Vec<QueueOp> {
        match value {
            QueueOp::Push(v) if *v > 0 => vec![QueueOp::Push(0), QueueOp::Push(v / 2)],
            _ => Vec::new(),
        }
    }
}

/// A script of up to `max_ops` queue operations.
pub fn queue_ops(max_ops: usize) -> VecOf<QueueOpStrategy> {
    vec_of(QueueOpStrategy, 0, max_ops)
}

// ---------------------------------------------------------------------------
// Fault-event scripts (fault-injection property testing).
// ---------------------------------------------------------------------------

/// One fault-injection event against `units` simulated boards/workers
/// inside a `horizon_s`-second run. Weighted toward crashes (the
/// interesting case), with recovers so runs usually heal; times shrink
/// toward zero, units toward zero, kinds toward plain crash/recover.
#[derive(Debug, Clone)]
pub struct FaultEventStrategy {
    pub units: usize,
    pub horizon_s: f64,
}

impl Strategy for FaultEventStrategy {
    type Value = crate::fault::FaultEvent;

    fn generate(&self, rng: &mut SplitMix64) -> crate::fault::FaultEvent {
        use crate::fault::{FaultEvent, FaultKind};
        let at_s = self.horizon_s * rng.next_f64();
        let unit = rng.next_below(self.units.max(1) as u64) as usize;
        let kind = match rng.next_below(10) {
            0..=3 => FaultKind::Crash,
            4..=6 => FaultKind::Recover,
            7 => FaultKind::SlowDown {
                factor: 1.0 + 7.0 * rng.next_f64(),
            },
            8 => FaultKind::SlowEnd,
            _ => FaultKind::Corrupt,
        };
        FaultEvent { at_s, unit, kind }
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        use crate::fault::FaultKind;
        let mut out = Vec::new();
        if value.at_s > 0.0 {
            let mut v = value.clone();
            v.at_s = 0.0;
            out.push(v);
            let mut v = value.clone();
            v.at_s /= 2.0;
            out.push(v);
        }
        if value.unit > 0 {
            let mut v = value.clone();
            v.unit = 0;
            out.push(v);
        }
        match value.kind {
            FaultKind::SlowDown { factor } if factor > 1.0 => {
                let mut v = value.clone();
                v.kind = FaultKind::SlowDown {
                    factor: 1.0 + (factor - 1.0) / 2.0,
                };
                out.push(v);
                let mut v = value.clone();
                v.kind = FaultKind::Recover;
                out.push(v);
            }
            FaultKind::Corrupt => {
                let mut v = value.clone();
                v.kind = FaultKind::Recover;
                out.push(v);
            }
            _ => {}
        }
        out
    }
}

/// A script of up to `max_events` fault events over `units` units within
/// `horizon_s` seconds — feed the result into a
/// [`FaultPlan`](crate::fault::FaultPlan)'s `events`.
pub fn fault_events(
    units: usize,
    horizon_s: f64,
    max_events: usize,
) -> VecOf<FaultEventStrategy> {
    vec_of(FaultEventStrategy { units, horizon_s }, 0, max_events)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_trials() {
        let seen = std::cell::Cell::new(0u64);
        check("always_holds", &u64s(0, 100), |v| {
            seen.set(seen.get() + 1);
            if *v <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(seen.get(), Config::default().trials);
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failing_property_panics_with_name() {
        check("always_fails", &u64s(0, 100), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_finds_the_boundary() {
        // Property "v < 40" over [0, 1000]: greedy shrinking must land
        // exactly on the minimal counterexample, 40.
        let strat = u64s(0, 1000);
        let prop = |v: &u64| {
            if *v < 40 {
                Ok(())
            } else {
                Err("too big".to_string())
            }
        };
        let (min, _, _) = shrink_failure(&Config::default(), &strat, 700, "seed".into(), &prop);
        assert_eq!(min, 40);
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let strat = vec_of(u64s(0, 9), 0, 50);
        // Property: no element equals 7.
        let prop = |v: &Vec<u64>| {
            if v.contains(&7) {
                Err("has 7".to_string())
            } else {
                Ok(())
            }
        };
        let failing = vec![1, 2, 7, 3, 4, 7, 5];
        let (min, _, _) = shrink_failure(&Config::default(), &strat, failing, "x".into(), &prop);
        assert_eq!(min, vec![7], "minimal script is the single offending element");
    }

    #[test]
    fn generate_respects_bounds() {
        let mut rng = SplitMix64::new(1);
        let strat = u64s(5, 9);
        for _ in 0..200 {
            let v = strat.generate(&mut rng);
            assert!((5..=9).contains(&v));
        }
        let vs = vec_of(u64s(0, 3), 2, 6);
        for _ in 0..50 {
            let v = vs.generate(&mut rng);
            assert!((2..=6).contains(&v.len()));
        }
    }

    #[test]
    fn choice_shrinks_toward_earlier_entries() {
        let c = choice(&[1u32, 2, 3, 4]);
        assert_eq!(c.shrink(&4), vec![3, 2, 1]);
        assert!(c.shrink(&1).is_empty());
    }
}
