//! Small statistics helpers shared by benches and the coordinator metrics.

/// Five-number-ish summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| sorted[(((n - 1) as f64) * p) as usize];
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: q(0.5),
            p95: q(0.95),
            p99: q(0.99),
            max: sorted[n - 1],
        }
    }
}

impl Summary {
    /// Latency block in milliseconds (`{p50, p95, p99, mean, max}`) —
    /// the one JSON shape every simulator report shares (serving,
    /// shard-pipeline, fleet), so aggregation code sees a single type.
    pub fn to_ms_json(&self) -> super::json::Json {
        super::json::Json::obj()
            .set("p50", self.p50 * 1e3)
            .set("p95", self.p95 * 1e3)
            .set("p99", self.p99 * 1e3)
            .set("mean", self.mean * 1e3)
            .set("max", self.max * 1e3)
    }
}

/// Online histogram with fixed log-spaced buckets (latencies in seconds).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    samples: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 100 µs .. ~100 s, quarter-decade steps.
        let mut bounds = Vec::new();
        let mut b = 1e-4;
        while b < 100.0 {
            bounds.push(b);
            b *= 1.7782794; // 10^(1/4)
        }
        let n = bounds.len();
        LatencyHistogram {
            bounds,
            counts: vec![0; n + 1],
            samples: Vec::new(),
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, seconds: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| seconds <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.samples.push(seconds);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn summary(&self) -> Summary {
        Summary::from(&self.samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_data() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_summary_is_zeros() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = LatencyHistogram::default();
        for v in [0.001, 0.002, 0.5, 10.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.summary().max - 10.0).abs() < 1e-12);
    }
}
