//! Self-contained utilities (the build is fully offline, so anything not in
//! the xla crate's vendored dependency closure is implemented here).

pub mod bench;
pub mod cli;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod stats;
