//! In-tree micro/macro benchmark harness (offline build: no criterion).
//!
//! Every `benches/*.rs` target uses [`Bench`] to time closures with warmup,
//! report mean/p50/p99, and emit machine-readable JSON next to the
//! human-readable table so EXPERIMENTS.md can quote exact numbers.

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

/// One timed benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Machine-readable view (seconds as f64).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_s", self.mean.as_secs_f64())
            .set("p50_s", self.p50.as_secs_f64())
            .set("p99_s", self.p99.as_secs_f64())
            .set("min_s", self.min.as_secs_f64())
    }
}

/// Bench harness: fixed warmup, then either a fixed iteration count or a
/// time budget.
pub struct Bench {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub max_iters: u64,
    pub budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget: Duration::from_secs(2),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        Bench::default()
    }

    /// Quick configuration for expensive (multi-ms) benchmarks.
    pub fn heavy() -> Bench {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget: Duration::from_secs(3),
            results: Vec::new(),
        }
    }

    /// Time `f`, recording the result under `name`. Returns the result.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while iters < self.min_iters
            || (start.elapsed() < self.budget && iters < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            iters += 1;
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean,
            p50: pick(0.5),
            p99: pick(0.99),
            min: samples[0],
        };
        println!(
            "  {:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p99  ({} iters)",
            result.name, result.mean, result.p50, result.p99, result.iters
        );
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Format a throughput-style derived metric line.
pub fn report_metric(name: &str, value: f64, unit: &str) {
    println!("  {name:<44} {value:>12.3} {unit}");
}

/// Repo-root location for a `BENCH_*.json` file. Cargo runs bench
/// binaries with the working directory set to the *package* dir
/// (`rust/`), so a bare relative write would land the report one level
/// too deep; resolve against the manifest dir's parent instead.
pub fn bench_output_path(file: &str) -> std::path::PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(file)
}

/// Collector pairing timed results with derived metrics, persisted as a
/// `BENCH_*.json` next to the human-readable table so EXPERIMENTS.md (and
/// the perf trajectory across PRs) can quote exact numbers.
#[derive(Default)]
pub struct JsonReport {
    bench: String,
    mode: String,
    results: Vec<BenchResult>,
    metrics: Vec<(String, f64, String)>,
}

impl JsonReport {
    pub fn new(bench: &str, mode: &str) -> JsonReport {
        JsonReport {
            bench: bench.to_string(),
            mode: mode.to_string(),
            ..Default::default()
        }
    }

    /// Record a timed result (typically right after `Bench::run`).
    pub fn result(&mut self, r: &BenchResult) {
        self.results.push(r.clone());
    }

    /// Print a derived metric line AND record it.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        report_metric(name, value, unit);
        self.metrics.push((name.to_string(), value, unit.to_string()));
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("bench", self.bench.as_str())
            .set("mode", self.mode.as_str())
            .set(
                "results",
                Json::Arr(self.results.iter().map(BenchResult::to_json).collect()),
            )
            .set(
                "metrics",
                Json::Arr(
                    self.metrics
                        .iter()
                        .map(|(n, v, u)| {
                            Json::obj()
                                .set("name", n.as_str())
                                .set("value", *v)
                                .set("unit", u.as_str())
                        })
                        .collect(),
                ),
            )
    }

    /// Write the report to `path` (pretty JSON + trailing newline).
    pub fn write(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().pretty() + "\n")?;
        println!("\nwrote {}", path.display());
        Ok(())
    }
}

/// Summarize a vector of f64 samples (for non-time metrics).
pub fn summarize_f64(samples: &[f64]) -> Summary {
    Summary::from(samples)
}
