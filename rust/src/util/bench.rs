//! In-tree micro/macro benchmark harness (offline build: no criterion).
//!
//! Every `benches/*.rs` target uses [`Bench`] to time closures with warmup,
//! report mean/p50/p99, and emit machine-readable JSON next to the
//! human-readable table so EXPERIMENTS.md can quote exact numbers.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// One timed benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Bench harness: fixed warmup, then either a fixed iteration count or a
/// time budget.
pub struct Bench {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub max_iters: u64,
    pub budget: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget: Duration::from_secs(2),
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Bench {
        Bench::default()
    }

    /// Quick configuration for expensive (multi-ms) benchmarks.
    pub fn heavy() -> Bench {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget: Duration::from_secs(3),
            results: Vec::new(),
        }
    }

    /// Time `f`, recording the result under `name`. Returns the result.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while iters < self.min_iters
            || (start.elapsed() < self.budget && iters < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
            iters += 1;
        }
        samples.sort();
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean,
            p50: pick(0.5),
            p99: pick(0.99),
            min: samples[0],
        };
        println!(
            "  {:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p99  ({} iters)",
            result.name, result.mean, result.p50, result.p99, result.iters
        );
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Format a throughput-style derived metric line.
pub fn report_metric(name: &str, value: f64, unit: &str) {
    println!("  {name:<44} {value:>12.3} {unit}");
}

/// Summarize a vector of f64 samples (for non-time metrics).
pub fn summarize_f64(samples: &[f64]) -> Summary {
    Summary::from(samples)
}
