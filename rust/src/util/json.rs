//! Minimal JSON value tree + writer (offline build: no serde_json).
//!
//! Only what the report generator, codegen and config loader need: build a
//! [`Json`] tree, pretty-print it, and parse the subset we emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — construction bug).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  \"{}\": ", escape(k));
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }

    /// Parse a JSON document (the subset we emit: no \u escapes beyond
    /// BMP passthrough, no scientific notation corner cases we don't use).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at {}", p.pos);
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected '{}' at {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> anyhow::Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "bad literal at {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full codepoint.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] got {other:?}"),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected , or }} got {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let j = Json::obj()
            .set("name", "vaqf")
            .set("fps", 24.8)
            .set("feasible", true)
            .set("bits", vec![1u64, 6, 8, 16])
            .set("nested", Json::obj().set("t_m", 96u64));
        let text = j.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#"{"s": "a\"b\\c\nd", "u": "éé"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\"b\\c\nd");
        assert_eq!(j.get("u").unwrap().as_str().unwrap(), "éé");
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(42.0).pretty(), "42");
        assert_eq!(Json::Num(42.5).pretty(), "42.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }
}
