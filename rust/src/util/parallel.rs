//! Row-parallel execution over the frame dimension (offline build: no
//! rayon — scoped `std::thread` fan-out).
//!
//! The simulator's matmuls are embarrassingly parallel across output rows
//! (each frame token's output row depends only on that token's inputs),
//! so all three datapaths split the output matrix into contiguous row
//! chunks and run one chunk per thread. Chunks are disjoint and every
//! per-row computation is identical to the serial order, so parallel
//! results are bit-for-bit the serial results.
//!
//! Thread count resolution (highest priority first): explicit engine
//! override → `VAQF_THREADS` env var → `std::thread::available_parallelism`,
//! clamped to [`MAX_THREADS`].

/// Upper bound on the fan-out — beyond this, chunk sizes drop below the
/// per-thread spawn cost for every model in the preset zoo.
pub const MAX_THREADS: usize = 64;

/// Minimum estimated scalar ops per worker before spawning pays: threads
/// are spawned fresh per matmul call (no pool), so a worker must amortize
/// ~tens of µs of spawn/join cost. Below this the call runs inline —
/// micro-model layers stay serial, DeiT-scale layers fan out.
pub const MIN_WORK_PER_THREAD: u64 = 1 << 21;

/// Resolve the default worker count: `VAQF_THREADS` if set and parseable,
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("VAQF_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, MAX_THREADS);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, MAX_THREADS)
}

/// Split `out` (row-major `rows × cols`) into contiguous row chunks and
/// invoke `body(first_row, chunk)` on each — across up to `threads`
/// scoped threads, inline when one worker suffices. `work` is the
/// caller's estimate of total scalar ops (e.g. `f·n·m` MACs); the actual
/// fan-out is capped so each worker gets at least
/// [`MIN_WORK_PER_THREAD`], which keeps small layers on the calling
/// thread instead of paying per-call spawn cost. `body` must fill its
/// chunk purely from `first_row..first_row + chunk.len() / cols`; chunk
/// boundaries never change numeric results.
pub fn for_each_row_chunk<F>(
    out: &mut [f32],
    rows: usize,
    cols: usize,
    threads: usize,
    work: u64,
    body: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), rows * cols, "output shape mismatch");
    if out.is_empty() {
        return;
    }
    let worth = (work / MIN_WORK_PER_THREAD).min(MAX_THREADS as u64) as usize;
    let threads = threads.clamp(1, MAX_THREADS).min(worth.max(1)).min(rows);
    if threads == 1 {
        body(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(threads);
    let body = &body;
    std::thread::scope(|scope| {
        let mut rest = out;
        let mut row0 = 0usize;
        while !rest.is_empty() {
            let take_rows = chunk_rows.min(rows - row0);
            let (head, tail) = rest.split_at_mut(take_rows * cols);
            if tail.is_empty() {
                // Run the last chunk on the calling thread instead of
                // idling while workers finish.
                body(row0, head);
            } else {
                scope.spawn(move || body(row0, head));
            }
            rest = tail;
            row0 += take_rows;
        }
    });
}

/// Fan independent work items out across up to `threads` scoped threads:
/// contiguous chunks of `items`, one chunk per worker, `body(index, item)`
/// per item. The same work cutoff as [`for_each_row_chunk`] applies
/// (`work_per_item · items` vs [`MIN_WORK_PER_THREAD`]), so small task
/// sets run inline on the calling thread. Items are disjoint and the
/// per-item computation is independent of chunking, so results are
/// bit-for-bit the serial results — this is the driver the executor uses
/// to parallelize attention across heads (each item owns one head's
/// scratch + output slice).
pub fn for_each_task<T, F>(items: &mut [T], threads: usize, work_per_item: u64, body: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let total = work_per_item.saturating_mul(n as u64);
    let worth = (total / MIN_WORK_PER_THREAD).min(MAX_THREADS as u64) as usize;
    let threads = threads.clamp(1, MAX_THREADS).min(worth.max(1)).min(n);
    if threads == 1 {
        for (i, item) in items.iter_mut().enumerate() {
            body(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let body = &body;
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut i0 = 0usize;
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            if tail.is_empty() {
                // Last chunk runs on the calling thread instead of idling.
                for (j, item) in head.iter_mut().enumerate() {
                    body(i0 + j, item);
                }
            } else {
                scope.spawn(move || {
                    for (j, item) in head.iter_mut().enumerate() {
                        body(i0 + j, item);
                    }
                });
            }
            rest = tail;
            i0 += take;
        }
    });
}

/// [`for_each_task`] with a produced value per index: runs
/// `f(0..n)` across up to `threads` workers and collects the results in
/// index order. Same work cutoff and chunking as [`for_each_task`]; `f`
/// must be pure in its index, so the output `Vec` is bit-for-bit the
/// serial result for every thread count — the design-space search relies
/// on that to keep its winner byte-identical under parallel candidate
/// evaluation.
pub fn map_tasks<R, F>(n: usize, threads: usize, work_per_item: u64, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for_each_task(&mut slots, threads, work_per_item, |i, slot| {
        *slot = Some(f(i));
    });
    slots
        .into_iter()
        .map(|s| s.expect("map_tasks worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial_for_all_thread_counts() {
        let rows = 37;
        let cols = 5;
        let fill = |row0: usize, chunk: &mut [f32]| {
            for (i, v) in chunk.iter_mut().enumerate() {
                let r = row0 + i / cols;
                let c = i % cols;
                *v = (r * 1000 + c) as f32;
            }
        };
        let mut want = vec![0.0f32; rows * cols];
        fill(0, &mut want);
        for threads in [1, 2, 3, 8, 37, 64] {
            // Large `work` forces real fan-out; tiny `work` must stay
            // serial — results identical either way.
            for work in [u64::MAX, 1] {
                let mut got = vec![0.0f32; rows * cols];
                for_each_row_chunk(&mut got, rows, cols, threads, work, fill);
                assert_eq!(got, want, "threads={threads} work={work}");
            }
        }
    }

    #[test]
    fn empty_and_single_row_edges() {
        let mut empty: Vec<f32> = vec![];
        for_each_row_chunk(&mut empty, 0, 4, 8, u64::MAX, |_, _| panic!("no chunks expected"));
        let mut one = vec![0.0f32; 3];
        for_each_row_chunk(&mut one, 1, 3, 8, u64::MAX, |row0, chunk| {
            assert_eq!(row0, 0);
            chunk.fill(1.0);
        });
        assert_eq!(one, vec![1.0; 3]);
    }

    #[test]
    fn default_threads_is_bounded() {
        let n = default_threads();
        assert!((1..=MAX_THREADS).contains(&n));
    }

    #[test]
    fn tasks_match_serial_for_all_thread_counts() {
        let want: Vec<u64> = (0..23).map(|i| i * i + 7).collect();
        for threads in [1, 2, 3, 8, 23, 64] {
            for work in [u64::MAX / 64, 0] {
                let mut items = vec![0u64; 23];
                for_each_task(&mut items, threads, work, |i, v| {
                    *v = (i as u64) * (i as u64) + 7;
                });
                assert_eq!(items, want, "threads={threads} work={work}");
            }
        }
        let mut empty: Vec<u64> = vec![];
        for_each_task(&mut empty, 8, u64::MAX, |_, _| panic!("no items expected"));
    }

    #[test]
    fn map_tasks_collects_in_index_order() {
        let want: Vec<String> = (0..11).map(|i| format!("r{i}")).collect();
        for threads in [1, 3, 11, 64] {
            let got = map_tasks(11, threads, u64::MAX / 64, |i| format!("r{i}"));
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(map_tasks(0, 8, u64::MAX, |_| 0u8).is_empty());
    }
}
