//! Runtime-dispatched SIMD popcount primitives — the inner loop of every
//! packed XNOR/popcount kernel (`sim::kernels` via `quant::packing`).
//!
//! The packed backend reduces each matmul output to sums of
//! `popcount(a ∧ b)` / `popcount(XNOR(a, b))` over `u64` lane-word
//! slices. This module is the one place those word loops live, at three
//! dispatch tiers selected once per process:
//!
//! * **`scalar`** — the plain `count_ones()` loop with a `u64`
//!   accumulator: always available, and the in-module reference the
//!   vector tiers are property-tested against (the *kernel*-level oracle
//!   remains `Backend::Scalar`, which never touches this module's vector
//!   paths).
//! * **`avx2`** — 256-bit `vpshufb` nibble-LUT popcount (Muła's
//!   algorithm) with per-vector `vpsadbw` reduction into 64-bit lanes,
//!   so no intermediate accumulator can wrap at any input length.
//! * **`avx512`** — native `vpopcntq` (`_mm512_popcnt_epi64`) over
//!   512-bit words. Compile-time opt-in via the `avx512` cargo feature
//!   (the intrinsics need rustc ≥ 1.89); runtime-gated on
//!   `avx512f` + `avx512vpopcntdq`.
//!
//! Selection: the best tier the CPU (and build) supports, clamped by the
//! `VAQF_SIMD=scalar|avx2|avx512` environment override (requesting a
//! tier the machine lacks falls back to the best supported one — the
//! override can only *lower* the tier, never fake one). CI runs the test
//! suite under `VAQF_SIMD=scalar` and the auto-detected best tier so a
//! divergence cannot hide behind either (see EXPERIMENTS.md §Perf).
//!
//! All tiers are bit-identical by contract: exact `u64` popcounts, no
//! rounding anywhere. `rust/tests/property_suite.rs` sweeps every
//! supported tier against the scalar tier over random lane lengths
//! (including the `n % 64 ∈ {0, 1, 63}` tail boundaries) and the
//! `u32`-accumulator overflow boundary that motivated the widened sums.

use std::fmt;
use std::sync::OnceLock;

/// One SIMD dispatch tier, ordered weakest → strongest (so clamping an
/// environment request to hardware support is just `min`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SimdTier {
    Scalar = 0,
    Avx2 = 1,
    Avx512 = 2,
}

impl SimdTier {
    /// Tier-name hint for error messages (keep in sync with
    /// [`SimdTier::from_name`]).
    pub const NAMES: &'static str = "scalar|avx2|avx512";

    /// Parse a tier name (the `VAQF_SIMD` env surface).
    pub fn from_name(name: &str) -> Option<SimdTier> {
        match name.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(SimdTier::Scalar),
            "avx2" => Some(SimdTier::Avx2),
            "avx512" => Some(SimdTier::Avx512),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
        }
    }

    /// Every tier this machine (and build) can actually run, weakest
    /// first — the sweep axis for per-tier property tests and benches.
    pub fn supported_tiers() -> Vec<SimdTier> {
        let best = supported();
        let mut tiers = vec![SimdTier::Scalar];
        if best >= SimdTier::Avx2 {
            tiers.push(SimdTier::Avx2);
        }
        if best >= SimdTier::Avx512 {
            tiers.push(SimdTier::Avx512);
        }
        tiers
    }
}

impl fmt::Display for SimdTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Best tier the CPU supports (cached; pure in the hardware).
pub fn supported() -> SimdTier {
    static SUPPORTED: OnceLock<SimdTier> = OnceLock::new();
    *SUPPORTED.get_or_init(detect)
}

/// The tier every dispatched call runs: `min(VAQF_SIMD request,
/// supported)`, defaulting to the best supported tier. Cached on first
/// use (the kernels are hot enough that even an env read per call would
/// show up).
pub fn active() -> SimdTier {
    static ACTIVE: OnceLock<SimdTier> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let best = supported();
        match std::env::var("VAQF_SIMD").ok().and_then(|v| SimdTier::from_name(&v)) {
            Some(requested) => requested.min(best),
            None => best,
        }
    })
}

#[cfg(target_arch = "x86_64")]
fn detect() -> SimdTier {
    #[cfg(feature = "avx512")]
    {
        if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq") {
            return SimdTier::Avx512;
        }
    }
    if is_x86_feature_detected!("avx2") {
        SimdTier::Avx2
    } else {
        SimdTier::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect() -> SimdTier {
    SimdTier::Scalar
}

/// `Σ popcount(aᵢ ∧ bᵢ)` over two equal-length lane-word slices, on the
/// process-wide [`active`] tier. Exact `u64` accumulation at every tier
/// (the pre-PR8 `u32` accumulator wrapped past 2³² set bits).
#[inline]
pub fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
    and_popcount_with(active(), a, b)
}

/// [`and_popcount`] on an explicit tier (tests/benches force each
/// supported tier through this). Panics if `tier` exceeds what the CPU
/// supports — the caller cannot conjure instructions the machine lacks.
pub fn and_popcount_with(tier: SimdTier, a: &[u64], b: &[u64]) -> u64 {
    assert!(tier <= supported(), "SIMD tier {tier} unsupported on this CPU");
    debug_assert_eq!(a.len(), b.len());
    match tier {
        SimdTier::Scalar => and_popcount_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::and_popcount(a, b) },
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        SimdTier::Avx512 => unsafe { avx512::and_popcount(a, b) },
        #[allow(unreachable_patterns)]
        _ => and_popcount_scalar(a, b),
    }
}

/// `Σ popcount(XNOR(aᵢ, bᵢ))` over the first `n` *bit lanes* (the ±1
/// sign-dot popcount), on the process-wide [`active`] tier.
///
/// Only `⌈n/64⌉` words are read and the final partial word is masked to
/// its `n % 64` valid low bits — trailing padding words (the 64-byte
/// panel alignment of the packed layouts) are ignored entirely, so the
/// XNOR of two zero pad words (= all ones) can never leak into the
/// count. Requires `a.len() == b.len() ≥ ⌈n/64⌉`.
#[inline]
pub fn xnor_popcount(a: &[u64], b: &[u64], n: usize) -> u64 {
    xnor_popcount_with(active(), a, b, n)
}

/// [`xnor_popcount`] on an explicit tier; panics if `tier` exceeds CPU
/// support.
pub fn xnor_popcount_with(tier: SimdTier, a: &[u64], b: &[u64], n: usize) -> u64 {
    assert!(tier <= supported(), "SIMD tier {tier} unsupported on this CPU");
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.len() >= n.div_ceil(64), "slice shorter than {n} lanes");
    match tier {
        SimdTier::Scalar => xnor_popcount_scalar(a, b, n),
        #[cfg(target_arch = "x86_64")]
        SimdTier::Avx2 => unsafe { avx2::xnor_popcount(a, b, n) },
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        SimdTier::Avx512 => unsafe { avx512::xnor_popcount(a, b, n) },
        #[allow(unreachable_patterns)]
        _ => xnor_popcount_scalar(a, b, n),
    }
}

// ---------------------------------------------------------------------------
// Scalar tier — the always-available fallback and in-module reference.
// ---------------------------------------------------------------------------

fn and_popcount_scalar(a: &[u64], b: &[u64]) -> u64 {
    let mut pop = 0u64;
    for (&x, &y) in a.iter().zip(b) {
        pop += u64::from((x & y).count_ones());
    }
    pop
}

fn xnor_popcount_scalar(a: &[u64], b: &[u64], n: usize) -> u64 {
    let full = n / 64;
    let rem = n % 64;
    let mut pop = 0u64;
    for i in 0..full {
        pop += u64::from((!(a[i] ^ b[i])).count_ones());
    }
    if rem > 0 {
        let mask = (1u64 << rem) - 1;
        pop += u64::from(((!(a[full] ^ b[full])) & mask).count_ones());
    }
    pop
}

// ---------------------------------------------------------------------------
// AVX2 tier: vpshufb nibble-LUT popcount (Muła), vpsadbw-reduced per
// vector so every accumulator is 64-bit from the first add.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Per-byte popcount of a 256-bit vector: each nibble indexes a
    /// 16-entry popcount LUT via `vpshufb`, low + high nibble summed.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn popcount_bytes(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(v), low);
        _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
    }

    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn hsum_epi64(acc: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
        let words = a.len();
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        let chunks = words / 4;
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(4 * c) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(4 * c) as *const __m256i);
            let bytes = popcount_bytes(_mm256_and_si256(va, vb));
            // vpsadbw against zero: 8-byte group sums into the four
            // 64-bit lanes — ≤ 64 per lane per vector, so the epi64
            // accumulator is exact at any slice length.
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(bytes, zero));
        }
        let mut pop = hsum_epi64(acc);
        for i in 4 * chunks..words {
            pop += u64::from((a[i] & b[i]).count_ones());
        }
        pop
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn xnor_popcount(a: &[u64], b: &[u64], n: usize) -> u64 {
        let full = n / 64;
        let rem = n % 64;
        let zero = _mm256_setzero_si256();
        let ones = _mm256_set1_epi8(-1);
        let mut acc = zero;
        let chunks = full / 4;
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(4 * c) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(4 * c) as *const __m256i);
            let xnor = _mm256_xor_si256(_mm256_xor_si256(va, vb), ones);
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(popcount_bytes(xnor), zero));
        }
        let mut pop = hsum_epi64(acc);
        for i in 4 * chunks..full {
            pop += u64::from((!(a[i] ^ b[i])).count_ones());
        }
        if rem > 0 {
            pop += u64::from(((!(a[full] ^ b[full])) & ((1u64 << rem) - 1)).count_ones());
        }
        pop
    }
}

// ---------------------------------------------------------------------------
// AVX-512 tier: native vpopcntq. Opt-in (`--features avx512`, rustc ≥
// 1.89 for the stabilized intrinsics); runtime-gated in `detect`.
// ---------------------------------------------------------------------------

#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
mod avx512 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx512f,avx512vpopcntdq")]
    pub unsafe fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
        let words = a.len();
        let mut acc = _mm512_setzero_si512();
        let chunks = words / 8;
        for c in 0..chunks {
            let va = _mm512_loadu_si512(a.as_ptr().add(8 * c) as *const _);
            let vb = _mm512_loadu_si512(b.as_ptr().add(8 * c) as *const _);
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(_mm512_and_si512(va, vb)));
        }
        let mut pop = _mm512_reduce_add_epi64(acc) as u64;
        for i in 8 * chunks..words {
            pop += u64::from((a[i] & b[i]).count_ones());
        }
        pop
    }

    #[target_feature(enable = "avx512f,avx512vpopcntdq")]
    pub unsafe fn xnor_popcount(a: &[u64], b: &[u64], n: usize) -> u64 {
        let full = n / 64;
        let rem = n % 64;
        let ones = _mm512_set1_epi64(-1);
        let mut acc = _mm512_setzero_si512();
        let chunks = full / 8;
        for c in 0..chunks {
            let va = _mm512_loadu_si512(a.as_ptr().add(8 * c) as *const _);
            let vb = _mm512_loadu_si512(b.as_ptr().add(8 * c) as *const _);
            let xnor = _mm512_xor_si512(_mm512_xor_si512(va, vb), ones);
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(xnor));
        }
        let mut pop = _mm512_reduce_add_epi64(acc) as u64;
        for i in 8 * chunks..full {
            pop += u64::from((!(a[i] ^ b[i])).count_ones());
        }
        if rem > 0 {
            pop += u64::from(((!(a[full] ^ b[full])) & ((1u64 << rem) - 1)).count_ones());
        }
        pop
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    /// Bit-by-bit reference counts, independent of any word loop.
    fn ref_and(a: &[u64], b: &[u64]) -> u64 {
        a.iter().zip(b).map(|(&x, &y)| u64::from((x & y).count_ones())).sum()
    }

    fn ref_xnor(a: &[u64], b: &[u64], n: usize) -> u64 {
        (0..n)
            .filter(|&p| (a[p / 64] >> (p % 64)) & 1 == (b[p / 64] >> (p % 64)) & 1)
            .count() as u64
    }

    fn rand_words(rng: &mut SplitMix64, len: usize) -> Vec<u64> {
        (0..len).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn tier_names_round_trip_and_order() {
        for tier in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Avx512] {
            assert_eq!(SimdTier::from_name(tier.name()), Some(tier));
        }
        assert_eq!(SimdTier::from_name(" AVX2 "), Some(SimdTier::Avx2));
        assert_eq!(SimdTier::from_name("neon"), None);
        assert!(SimdTier::Scalar < SimdTier::Avx2 && SimdTier::Avx2 < SimdTier::Avx512);
    }

    #[test]
    fn active_never_exceeds_supported() {
        assert!(active() <= supported());
        let tiers = SimdTier::supported_tiers();
        assert_eq!(tiers[0], SimdTier::Scalar);
        assert!(tiers.contains(&supported()));
        assert!(tiers.windows(2).all(|w| w[0] < w[1]), "tiers must be sorted");
    }

    #[test]
    fn all_supported_tiers_match_reference_counts() {
        let mut rng = SplitMix64::new(0x51D);
        for trial in 0..200 {
            // Lengths hammer the 4/8-word vector chunk boundaries and
            // the empty slice.
            let words = (rng.next_below(40)) as usize;
            let a = rand_words(&mut rng, words);
            let b = rand_words(&mut rng, words);
            let want = ref_and(&a, &b);
            for tier in SimdTier::supported_tiers() {
                assert_eq!(
                    and_popcount_with(tier, &a, &b),
                    want,
                    "trial {trial}: and tier {tier} words {words}"
                );
            }
            if words == 0 {
                continue;
            }
            // Lane counts stress the n % 64 ∈ {0, 1, 63} tail masks and
            // ignored padding words beyond ⌈n/64⌉.
            let max = words * 64;
            for n in [
                max,
                max - 1,
                (words - 1) * 64 + 1,
                1 + rng.next_below(max as u64) as usize,
            ] {
                let want = ref_xnor(&a, &b, n);
                for tier in SimdTier::supported_tiers() {
                    assert_eq!(
                        xnor_popcount_with(tier, &a, &b, n),
                        want,
                        "trial {trial}: xnor tier {tier} n {n} words {words}"
                    );
                }
            }
        }
    }

    #[test]
    fn xnor_ignores_padding_words_past_the_lane_count() {
        // Zero pad words XNOR to all-ones; they must contribute nothing.
        let a = vec![u64::MAX, 0, 0, 0, 0, 0, 0, 0];
        let b = vec![u64::MAX, 0, 0, 0, 0, 0, 0, 0];
        for tier in SimdTier::supported_tiers() {
            assert_eq!(xnor_popcount_with(tier, &a, &b, 64), 64, "tier {tier}");
            assert_eq!(xnor_popcount_with(tier, &a, &b, 1), 1, "tier {tier}");
        }
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn forcing_an_unsupported_tier_panics() {
        if supported() >= SimdTier::Avx512 {
            return; // everything is supported here; nothing to force
        }
        let r = std::panic::catch_unwind(|| {
            and_popcount_with(SimdTier::Avx512, &[1], &[1]);
        });
        assert!(r.is_err(), "unsupported tier must refuse to run");
    }
}
