//! Tiny argv parser (offline build: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args; the
//! `vaqf` binary builds its subcommand dispatch on top.

use std::collections::BTreeMap;

/// Parsed command line: positionals in order + `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str) -> anyhow::Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn get_u64(&self, key: &str) -> anyhow::Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse::<u64>().map_err(|e| anyhow::anyhow!("--{key}: {e}")))
            .transpose()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_forms() {
        // Convention: positionals before bare flags (a bare `--flag` eats a
        // following non-dashed token as its value, so flags go last or use
        // `--key=value`).
        let a = argv("compile out.json --model deit-base --target-fps=30 --verbose");
        assert_eq!(a.positional, vec!["compile", "out.json"]);
        assert_eq!(a.get("model"), Some("deit-base"));
        assert_eq!(a.get("target-fps"), Some("30"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = argv("serve --fps 24 --sim");
        assert_eq!(a.get_f64("fps").unwrap(), Some(24.0));
        assert!(a.has_flag("sim"));
    }

    #[test]
    fn numeric_errors_are_reported() {
        let a = argv("--fps abc");
        assert!(a.get_f64("fps").is_err());
    }
}
