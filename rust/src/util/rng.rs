//! Deterministic PRNG (SplitMix64) used everywhere randomness is needed.
//!
//! Implemented in-tree (no `rand` offline) and mirrored bit-for-bit by
//! `python/compile/prng.py`, so the progressive-binarization masks chosen by
//! the Rust compiler and the Python QAT harness are identical for a given
//! seed — a cross-language reproducibility requirement of Eq. 6.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes; trivially
/// portable to Python.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    /// Next 64 uniform bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` via Lemire-style rejection-free mapping (biased
    /// by < 2⁻³² for our n; acceptable and portable).
    pub fn next_below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        ((self.next_u64() >> 11) as u128 * n as u128 >> 53) as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn next_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle (deterministic for a given seed & length).
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            data.swap(i, j);
        }
    }

    /// Exponential variate with rate `rate_hz` (inverse-CDF; consumes
    /// exactly one `next_f64`). Used for Poisson inter-arrival times by
    /// both the fault generators and the fleet trace generators, so all
    /// stochastic schedules are pure functions of (spec, seed).
    pub fn next_exp(&mut self, rate_hz: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / rate_hz
    }

    /// Exponential variate with mean `mean_s`. Kept as a multiply (not
    /// `next_exp(1.0 / mean_s)`) so existing sampled schedules stay
    /// bit-identical after the fault-plan refactor onto this module.
    pub fn next_exp_mean(&mut self, mean_s: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() * mean_s
    }
}

/// Homogeneous Poisson arrival timestamps on `[0, horizon_s)`.
///
/// Exactly the loop `fault::GeneratorSpec` has always used, extracted so
/// trace generators share it: each arrival consumes one `next_f64`.
pub fn poisson_arrivals(rng: &mut SplitMix64, rate_hz: f64, horizon_s: f64) -> Vec<f64> {
    let mut ts = Vec::new();
    if rate_hz <= 0.0 {
        return ts;
    }
    let mut t = 0.0_f64;
    loop {
        t += rng.next_exp(rate_hz);
        if t >= horizon_s {
            return ts;
        }
        ts.push(t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector() {
        // Pinned outputs — python/compile/prng.py asserts the same values.
        let mut r = SplitMix64::new(42);
        assert_eq!(r.next_u64(), 13679457532755275413);
        assert_eq!(r.next_u64(), 2949826092126892291);
        assert_eq!(r.next_u64(), 5139283748462763858);
    }

    #[test]
    fn determinism() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn exp_mean_matches_multiplied_rate_form_bitwise() {
        // next_exp_mean(m) must be the literal multiply-by-mean expression
        // (the historical fault-plan form), byte-for-byte.
        let mut a = SplitMix64::new(11);
        let mut b = SplitMix64::new(11);
        for _ in 0..64 {
            let m = 0.0123;
            let got = a.next_exp_mean(m);
            let want = -(1.0 - b.next_f64()).ln() * m;
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn poisson_arrivals_sorted_and_bounded() {
        let mut r = SplitMix64::new(5);
        let ts = poisson_arrivals(&mut r, 100.0, 1.0);
        assert!(!ts.is_empty());
        assert!(ts.windows(2).all(|w| w[0] < w[1]));
        assert!(ts.iter().all(|&t| t > 0.0 && t < 1.0));
        assert!(poisson_arrivals(&mut r, 0.0, 1.0).is_empty());
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = SplitMix64::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
