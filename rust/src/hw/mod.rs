//! FPGA device models (paper §6.1: Xilinx ZCU102, 150 MHz; generalizable to
//! other devices — we also ship ZCU111 for the Table 6 comparison point).

mod device;
mod presets;

pub use device::{Device, ResourceBudget, Utilization, UtilizationPct};
pub use presets::{generic_edge, zcu102, zcu111, DevicePreset};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu102_inventory_matches_paper() {
        let d = zcu102();
        // §6.1: "Xilinx ZCU102 FPGA platform with 2520 DSPs and 274k LUTs".
        assert_eq!(d.budget.dsp, 2520);
        assert_eq!(d.budget.lut, 274_080);
        assert_eq!(d.clock_mhz, 150);
        // ZCU102 has 912 BRAM36 = 1824 BRAM18k blocks.
        assert_eq!(d.budget.bram18k, 1824);
    }

    #[test]
    fn axi_word_capacity() {
        let d = zcu102();
        assert_eq!(d.axi_port_bits, 64);
    }

    #[test]
    fn utilization_percentages() {
        let d = zcu102();
        let u = Utilization {
            dsp: 1564,
            lut: 143_000,
            bram18k: 1131,
            ff: 110_000,
        };
        let pct = u.percent(&d.budget);
        assert!((pct.dsp - 62.06).abs() < 0.1);
        assert!((pct.lut - 52.17).abs() < 0.2);
    }

    #[test]
    fn fits_checks_every_resource() {
        let d = generic_edge();
        let ok = Utilization { dsp: 1, lut: 1, bram18k: 1, ff: 1 };
        assert!(ok.fits(&d.budget));
        let over = Utilization { dsp: d.budget.dsp + 1, ..ok };
        assert!(!over.fits(&d.budget));
    }

    #[test]
    fn preset_lookup() {
        assert_eq!(DevicePreset::from_name("zcu102").unwrap().device().name, "zcu102");
        assert!(DevicePreset::from_name("nonexistent").is_none());
    }
}
