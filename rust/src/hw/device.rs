//! Resource inventories and utilization accounting.



/// Countable resources on an FPGA fabric.
///
/// BRAMs are counted in 18k-bit blocks (the unit of Eq. 12); Table 5 of the
/// paper reports BRAM36 (= 2 × BRAM18k), and the report generator converts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceBudget {
    pub dsp: u64,
    pub lut: u64,
    pub bram18k: u64,
    pub ff: u64,
}

/// A concrete utilization (same units as [`ResourceBudget`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Utilization {
    pub dsp: u64,
    pub lut: u64,
    pub bram18k: u64,
    pub ff: u64,
}

/// Utilization as percentages of a budget.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UtilizationPct {
    pub dsp: f64,
    pub lut: f64,
    pub bram18k: f64,
    pub ff: f64,
}

impl Utilization {
    pub fn percent(&self, b: &ResourceBudget) -> UtilizationPct {
        let pct = |u: u64, t: u64| 100.0 * u as f64 / t as f64;
        UtilizationPct {
            dsp: pct(self.dsp, b.dsp),
            lut: pct(self.lut, b.lut),
            bram18k: pct(self.bram18k, b.bram18k),
            ff: pct(self.ff, b.ff),
        }
    }

    /// Whether this utilization fits within the raw budget.
    pub fn fits(&self, b: &ResourceBudget) -> bool {
        self.dsp <= b.dsp && self.lut <= b.lut && self.bram18k <= b.bram18k && self.ff <= b.ff
    }

    /// Component-wise addition.
    pub fn plus(&self, other: &Utilization) -> Utilization {
        Utilization {
            dsp: self.dsp + other.dsp,
            lut: self.lut + other.lut,
            bram18k: self.bram18k + other.bram18k,
            ff: self.ff + other.ff,
        }
    }
}

/// An FPGA device the accelerator is compiled for.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: String,
    pub budget: ResourceBudget,
    /// Operating frequency in MHz (§6.1: 150 MHz on ZCU102 to avoid timing
    /// violations).
    pub clock_mhz: u64,
    /// Width of one AXI data port in bits (`S_port`, §5.3.1; 64 on ZCU102).
    pub axi_port_bits: u32,
    /// AXI ports available for input tiles (`p_in` of Eq. 7).
    pub axi_ports_in: u64,
    /// AXI ports for weight tiles (`p_wgt`).
    pub axi_ports_wgt: u64,
    /// AXI ports for output tiles (`p_out`).
    pub axi_ports_out: u64,
    /// Max fraction of DSPs usable for MAC arrays (`r_dsp`, Eq. 14) —
    /// leaves headroom for address generation and control.
    pub r_dsp: f64,
    /// Max fraction of LUTs usable for quantized MAC arrays (`r_lut`).
    /// Exceeding this is how placement/routing failures manifest (§3:
    /// "usually resulting from overutilization of LUTs").
    pub r_lut: f64,
    /// Static (idle) power draw in watts, for the Table 6 power model.
    pub static_power_w: f64,
}

impl Device {
    /// Clock period in seconds.
    pub fn clock_period_s(&self) -> f64 {
        1.0 / (self.clock_mhz as f64 * 1e6)
    }

    /// Cycles → seconds at this device's clock.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 * self.clock_period_s()
    }

    /// Seconds → frame rate.
    pub fn fps(&self, cycles_per_frame: u64) -> f64 {
        if cycles_per_frame == 0 {
            return f64::INFINITY;
        }
        1.0 / self.cycles_to_seconds(cycles_per_frame)
    }
}
