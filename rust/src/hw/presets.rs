//! Known device inventories.

use super::device::{Device, ResourceBudget};

/// Named device presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DevicePreset {
    Zcu102,
    Zcu111,
    GenericEdge,
}

impl DevicePreset {
    /// Preset-name hint for error messages (keep in sync with
    /// [`DevicePreset::from_name`]).
    pub const NAMES: &'static str = "zcu102/zcu111/generic-edge";

    pub fn device(self) -> Device {
        match self {
            DevicePreset::Zcu102 => zcu102(),
            DevicePreset::Zcu111 => zcu111(),
            DevicePreset::GenericEdge => generic_edge(),
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "zcu102" => Some(DevicePreset::Zcu102),
            "zcu111" => Some(DevicePreset::Zcu111),
            "generic" | "generic-edge" => Some(DevicePreset::GenericEdge),
            _ => None,
        }
    }
}

/// Xilinx ZCU102 (XCZU9EG) — the paper's evaluation board (§6.1):
/// 2520 DSP48E2, 274,080 LUTs, 548,160 FFs, 912 BRAM36 (= 1824 BRAM18k),
/// 150 MHz accelerator clock.
pub fn zcu102() -> Device {
    Device {
        name: "zcu102".into(),
        budget: ResourceBudget {
            dsp: 2520,
            lut: 274_080,
            bram18k: 1824,
            ff: 548_160,
        },
        clock_mhz: 150,
        axi_port_bits: 64,
        axi_ports_in: 4,
        axi_ports_wgt: 2,
        axi_ports_out: 2,
        r_dsp: 0.65,
        // Fraction of LUTs the MAC arrays may claim. Well below 1.0: the
        // remainder covers load/store units, per-partition address
        // generation and the routing-congestion headroom whose exhaustion
        // is exactly the paper's placement/routing failure mode (§3).
        // Calibrated so the generated W32A32/W1A8/W1A6 trio lands on the
        // paper's Table 5 FPS ratios (see EXPERIMENTS.md §Calibration).
        r_lut: 0.45,
        // Table 6 reports 9.8–9.9 W total at ~60% utilization ⇒ a few watts
        // static; calibrated in perf::power.
        static_power_w: 3.0,
    }
}

/// Xilinx ZCU111 (XCZU28DR) — larger RFSoC used by the BERT accelerator the
/// paper compares against in Table 6: 4272 DSPs, 425,280 LUTs, 1080 BRAM36.
pub fn zcu111() -> Device {
    Device {
        name: "zcu111".into(),
        budget: ResourceBudget {
            dsp: 4272,
            lut: 425_280,
            bram18k: 2160,
            ff: 850_560,
        },
        clock_mhz: 150,
        axi_port_bits: 64,
        axi_ports_in: 4,
        axi_ports_wgt: 2,
        axi_ports_out: 2,
        r_dsp: 0.65,
        // Fraction of LUTs the MAC arrays may claim. Well below 1.0: the
        // remainder covers load/store units, per-partition address
        // generation and the routing-congestion headroom whose exhaustion
        // is exactly the paper's placement/routing failure mode (§3).
        // Calibrated so the generated W32A32/W1A8/W1A6 trio lands on the
        // paper's Table 5 FPS ratios (see EXPERIMENTS.md §Calibration).
        r_lut: 0.45,
        static_power_w: 4.0,
    }
}

/// A deliberately small edge device, used in tests and the co-design
/// exploration example to exercise infeasibility paths (FR_tgt > FR_max).
pub fn generic_edge() -> Device {
    Device {
        name: "generic-edge".into(),
        budget: ResourceBudget {
            dsp: 360,
            lut: 140_160,
            bram18k: 432,
            ff: 280_320,
        },
        clock_mhz: 100,
        axi_port_bits: 64,
        axi_ports_in: 1,
        axi_ports_wgt: 1,
        axi_ports_out: 1,
        r_dsp: 0.65,
        // Fraction of LUTs the MAC arrays may claim. Well below 1.0: the
        // remainder covers load/store units, per-partition address
        // generation and the routing-congestion headroom whose exhaustion
        // is exactly the paper's placement/routing failure mode (§3).
        // Calibrated so the generated W32A32/W1A8/W1A6 trio lands on the
        // paper's Table 5 FPS ratios (see EXPERIMENTS.md §Calibration).
        r_lut: 0.45,
        static_power_w: 1.5,
    }
}
