//! Progressive binary training mask (paper Eq. 6).
//!
//! During QAT, `p%` of the weight elements are binarized and the rest stay
//! full-precision: `W_p = M_p · W_b + (1 − M_p) · W_r`. `p` starts at 0,
//! grows linearly with the epoch, and reaches 100% at the end of training.
//! The Python training harness mirrors this implementation exactly (same
//! hash-based element ordering) so both sides select identical masks for a
//! given seed — see `python/compile/quantize.py`.

use crate::util::rng::SplitMix64;

/// A progressive-binarization mask over a flat weight tensor.
#[derive(Debug, Clone)]
pub struct ProgressiveMask {
    /// Element indices in the (seeded) order they get binarized.
    order: Vec<u32>,
    /// Currently binarized prefix length.
    binarized: usize,
}

impl ProgressiveMask {
    /// Create a mask for `len` elements with a deterministic shuffle.
    pub fn new(len: usize, seed: u64) -> ProgressiveMask {
        let mut order: Vec<u32> = (0..len as u32).collect();
        let mut rng = SplitMix64::new(seed);
        rng.shuffle(&mut order);
        ProgressiveMask { order, binarized: 0 }
    }

    /// Set the binarized fraction `p ∈ [0, 1]`. Monotone: lowering `p`
    /// does not un-binarize already-selected elements (matching the paper's
    /// "grows linearly ... achieves 100%" schedule, which never regresses).
    pub fn set_fraction(&mut self, p: f64) {
        let target = ((self.order.len() as f64) * p.clamp(0.0, 1.0)).round() as usize;
        self.binarized = self.binarized.max(target.min(self.order.len()));
    }

    /// Current fraction binarized.
    pub fn fraction(&self) -> f64 {
        if self.order.is_empty() {
            return 1.0;
        }
        self.binarized as f64 / self.order.len() as f64
    }

    /// Dense 0/1 mask (`1` = binarized), Eq. 6's `M_p`.
    pub fn dense(&self) -> Vec<bool> {
        let mut m = vec![false; self.order.len()];
        for &i in &self.order[..self.binarized] {
            m[i as usize] = true;
        }
        m
    }

    /// Apply Eq. 6: blend binary and real weights under the current mask.
    pub fn blend(&self, real: &[f32], binary: &[f32]) -> Vec<f32> {
        assert_eq!(real.len(), self.order.len());
        assert_eq!(binary.len(), self.order.len());
        let mask = self.dense();
        real.iter()
            .zip(binary)
            .zip(mask)
            .map(|((&r, &b), m)| if m { b } else { r })
            .collect()
    }
}

/// The paper's linear schedule: fraction binarized at `epoch` of
/// `total_epochs` (0 at start, 1.0 at the last epoch).
pub fn progressive_schedule(epoch: usize, total_epochs: usize) -> f64 {
    if total_epochs <= 1 {
        return 1.0;
    }
    (epoch as f64 / (total_epochs - 1) as f64).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_endpoints() {
        assert_eq!(progressive_schedule(0, 300), 0.0);
        assert_eq!(progressive_schedule(299, 300), 1.0);
        assert!(progressive_schedule(150, 300) > 0.49);
        assert!(progressive_schedule(150, 300) < 0.52);
    }

    #[test]
    fn mask_is_monotone() {
        let mut m = ProgressiveMask::new(100, 42);
        m.set_fraction(0.5);
        let d1 = m.dense();
        m.set_fraction(0.75);
        let d2 = m.dense();
        for (a, b) in d1.iter().zip(&d2) {
            assert!(!a || *b, "binarized element got un-binarized");
        }
        // Lowering p is a no-op.
        m.set_fraction(0.1);
        assert_eq!(m.dense(), d2);
    }

    #[test]
    fn blend_selects_per_mask() {
        let mut m = ProgressiveMask::new(4, 7);
        m.set_fraction(0.5);
        let real = [1.0f32, 2.0, 3.0, 4.0];
        let bin = [-1.0f32, -1.0, -1.0, -1.0];
        let out = m.blend(&real, &bin);
        let n_bin = out.iter().filter(|&&v| v == -1.0).count();
        assert_eq!(n_bin, 2);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = ProgressiveMask::new(64, 123);
        let mut b = ProgressiveMask::new(64, 123);
        a.set_fraction(0.3);
        b.set_fraction(0.3);
        assert_eq!(a.dense(), b.dense());
        let mut c = ProgressiveMask::new(64, 124);
        c.set_fraction(0.3);
        assert_ne!(a.dense(), c.dense());
    }
}
