//! Cross-cutting quantization tests: binary-weight matmul as add/sub, the
//! end-to-end property the accelerator datapath relies on.

use super::*;

/// Reference f32 matmul.
fn matmul_f32(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a[i * k + p] * b[p * n + j];
            }
            out[i * n + j] = acc;
        }
    }
    out
}

#[test]
fn binary_matmul_reduces_to_add_sub() {
    // x @ W_b == scale * Σ ±x — the LUT add/sub datapath (paper §5.1).
    let k = 16;
    let n = 8;
    let x: Vec<f32> = (0..k).map(|i| (i as f32 - 8.0) / 4.0).collect();
    let w: Vec<f32> = (0..k * n).map(|i| ((i * 31 % 17) as f32 - 8.0) / 5.0).collect();
    let wb = binarize(&w, k, n);

    // Dense path: x @ dense(W_b).
    let dense = matmul_f32(&x, &wb.to_dense(), 1, k, n);

    // Add/sub path: accumulate ±x_p per output channel, scale once.
    for j in 0..n {
        let mut acc = 0.0f32;
        for p in 0..k {
            if wb.sign_at(p, j) > 0 {
                acc += x[p];
            } else {
                acc -= x[p];
            }
        }
        let got = acc * wb.scale;
        assert!((got - dense[j]).abs() < 1e-4, "col {j}: {got} vs {}", dense[j]);
    }
}

#[test]
fn quantized_binary_matmul_integer_datapath() {
    // Full integer pipeline: quantize activations to b bits, accumulate
    // integer ±q, dequantize with act_scale · w_scale. Error must be
    // bounded by the activation quantization error propagated through the
    // matmul (k · step/2 · scale per output).
    let k = 32;
    let n = 4;
    let x: Vec<f32> = (0..k).map(|i| ((i * 13 % 29) as f32 - 14.0) / 7.0).collect();
    let w: Vec<f32> = (0..k * n).map(|i| ((i * 7 % 23) as f32 - 11.0) / 9.0).collect();
    let wb = binarize(&w, k, n);

    for bits in [6u8, 8] {
        let aq = ActQuantizer::calibrate(bits, &x);
        let xq = aq.quantize(&x);
        let exact = matmul_f32(&aq.fake_quantize(&x), &wb.to_dense(), 1, k, n);
        for j in 0..n {
            let mut acc: i64 = 0;
            for p in 0..k {
                acc += (xq.q[p] as i64) * (wb.sign_at(p, j) as i64);
            }
            let got = acc as f32 * aq.scale * wb.scale;
            assert!(
                (got - exact[j]).abs() < 1e-3,
                "bits={bits} col {j}: {got} vs {}", exact[j]
            );
        }
    }
}

#[test]
fn packed_transport_preserves_matmul_result() {
    // Pack quantized activations into AXI words, unpack, matmul — results
    // must be identical to the unpacked integer path.
    let k = 60; // exercises the 6-bit 10-per-word remainder case
    let x: Vec<f32> = (0..k).map(|i| ((i * 11 % 19) as f32 - 9.0) / 3.0).collect();
    let aq = ActQuantizer::calibrate(6, &x);
    let xq = aq.quantize(&x);
    let packed = pack_words(&xq.q, 6, 64);
    assert_eq!(unpack_words(&packed), xq.q);
}

#[test]
fn fixed16_baseline_represents_unquantized_path() {
    // §5.3: W16A16 on hardware represents W32A32 on software "without
    // accuracy loss" — check a small matmul agrees to Q10 resolution.
    let k = 8;
    let x: Vec<f32> = (0..k).map(|i| (i as f32) / 4.0 - 1.0).collect();
    let w: Vec<f32> = (0..k).map(|i| ((i * 3 % 5) as f32) / 2.0 - 1.0).collect();
    let exact: f32 = x.iter().zip(&w).map(|(a, b)| a * b).sum();
    let mut acc = 0i64;
    for (&a, &b) in x.iter().zip(&w) {
        acc = fixed_mac(acc, to_fixed16(a), to_fixed16(b));
    }
    let got = from_fixed16(acc_to_fixed16(acc));
    assert!((got - exact).abs() < 0.02, "{got} vs {exact}");
}
