//! 16-bit fixed point — the hardware representation of "unquantized" data.
//!
//! Paper §5.3: "a baseline accelerator is realized for unquantized models,
//! whose 32-bit floating-point parameters and activations are represented
//! with 16-bit fixed-point numbers ... without accuracy loss on hardware."
//! We use Q6.10 (1 sign + 5 integer + 10 fractional bits): ViT activations
//! after LayerNorm are O(1–10), and 2⁻¹⁰ ≈ 1e-3 resolution loses no top-1
//! accuracy — matching the paper's claim.

/// Fractional bits of the Q-format.
pub const FIXED16_FRAC_BITS: u32 = 10;

/// A 16-bit fixed-point value (Q6.10).
pub type Fixed16 = i16;

/// Convert f32 → Q6.10 with saturation.
pub fn to_fixed16(x: f32) -> Fixed16 {
    let scaled = (x * (1 << FIXED16_FRAC_BITS) as f32).round();
    scaled.clamp(i16::MIN as f32, i16::MAX as f32) as i16
}

/// Convert Q6.10 → f32.
pub fn from_fixed16(q: Fixed16) -> f32 {
    q as f32 / (1 << FIXED16_FRAC_BITS) as f32
}

/// Convert a whole f32 slice to Q6.10 into a reusable buffer (cleared and
/// refilled — no reallocation once `out`'s capacity has warmed up).
pub fn to_fixed16_into(x: &[f32], out: &mut Vec<Fixed16>) {
    out.clear();
    out.extend(x.iter().map(|&v| to_fixed16(v)));
}

/// Fixed-point multiply-accumulate into a 32-bit accumulator (what one DSP
/// slice does per cycle in the unquantized datapath).
#[inline]
pub fn fixed_mac(acc: i64, a: Fixed16, b: Fixed16) -> i64 {
    acc + (a as i64) * (b as i64)
}

/// Renormalize a Q20 accumulator (product of two Q10s) back to Q10.
#[inline]
pub fn acc_to_fixed16(acc: i64) -> Fixed16 {
    let shifted = acc >> FIXED16_FRAC_BITS;
    shifted.clamp(i16::MIN as i64, i16::MAX as i64) as i16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_resolution() {
        for x in [-3.25f32, 0.0, 0.5, 1.0 / 1024.0, 7.9] {
            let err = (from_fixed16(to_fixed16(x)) - x).abs();
            assert!(err <= 0.5 / 1024.0 + 1e-7, "x={x} err={err}");
        }
    }

    #[test]
    fn saturates_out_of_range() {
        assert_eq!(to_fixed16(1e6), i16::MAX);
        assert_eq!(to_fixed16(-1e6), i16::MIN);
    }

    #[test]
    fn mac_matches_float_within_resolution() {
        let a = [0.5f32, -1.25, 2.0, 0.125];
        let b = [1.5f32, 0.75, -0.5, 3.0];
        let mut acc = 0i64;
        for (&x, &y) in a.iter().zip(&b) {
            acc = fixed_mac(acc, to_fixed16(x), to_fixed16(y));
        }
        let float: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        let fx = from_fixed16(acc_to_fixed16(acc));
        assert!((fx - float).abs() < 0.01, "fx={fx} float={float}");
    }
}
