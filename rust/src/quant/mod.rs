//! Quantization library (paper §4.2 + §5.3.1).
//!
//! Everything numeric the accelerator and the training recipe need:
//!
//! * [`binarize`] — XNOR-Net-style weight binarization with the ℓ1 scaling
//!   factor (Eq. 5),
//! * [`activation`] — uniform b-bit activation quantization,
//! * [`fixed`] — the 16-bit fixed-point representation used for
//!   "unquantized" data on hardware (§5.3),
//! * [`packing`] — the AXI-word data-packing scheme (§5.3.1) including the
//!   `S_port` non-divisible case (`G^q = ⌊64/6⌋ = 10`, 60 of 64 bits used),
//!   plus the bit-plane packing + popcount dot kernels the packed compute
//!   backend (`sim::kernels`) is built on,
//! * [`progressive`] — the progressive binarization mask of Eq. 6.

mod activation;
mod binarize;
mod fixed;
mod packing;
mod progressive;

pub use activation::{ActQuantizer, QuantizedTensor};
pub use binarize::{binarize, BinaryMatrix};
pub use fixed::{
    acc_to_fixed16, fixed_mac, from_fixed16, to_fixed16, to_fixed16_into, Fixed16,
    FIXED16_FRAC_BITS,
};
pub use packing::{
    field_mask, lane_words, pack_bit_planes, pack_bit_planes_into, pack_col_planes,
    pack_col_planes_into, pack_factor, pack_sign_bits, pack_sign_bits_into, pack_sign_planes,
    pack_words, padded_lane_words, plane_coeff, popcount_and_dot, unpack_bit_planes, unpack_words,
    xnor_sign_dot, BitPlanes, ColPlanes, PackedBuffer, SignPlanes, SIMD_PAD_WORDS,
};
pub use progressive::{progressive_schedule, ProgressiveMask};

#[cfg(test)]
mod tests;
