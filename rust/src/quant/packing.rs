//! Data packing (paper §5.3.1).
//!
//! Multiple low-precision values are concatenated into one AXI word so
//! BRAM usage drops by up to `G×` and input/output transfer cycles by `G×`.
//! The packing factor is `G = ⌊S_port / bits⌋`; when `S_port` is not
//! divisible by the bit width, the remainder bits go unused — the paper's
//! 6-bit example: `G^q = ⌊64/6⌋ = 10`, only 60 of the 64 bits exploited.

/// Packing factor for `bits`-wide values on a `port_bits`-wide AXI port.
pub fn pack_factor(port_bits: u32, bits: u32) -> u32 {
    assert!(bits >= 1 && bits <= port_bits, "bits={bits} port={port_bits}");
    port_bits / bits
}

/// A buffer of packed AXI words plus the packing geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBuffer {
    pub words: Vec<u64>,
    pub bits: u32,
    pub factor: u32,
    /// Number of logical values packed (≤ words.len() · factor).
    pub len: usize,
}

/// Pack signed integers (must fit in `bits` two's-complement) into 64-bit
/// AXI words, `factor` per word, LSB-first.
pub fn pack_words(values: &[i32], bits: u32, port_bits: u32) -> PackedBuffer {
    let factor = pack_factor(port_bits, bits);
    let mask: u64 = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    let mut words = Vec::with_capacity(values.len().div_ceil(factor as usize));
    for chunk in values.chunks(factor as usize) {
        let mut w = 0u64;
        for (i, &v) in chunk.iter().enumerate() {
            debug_assert!(
                (v as i64) >= lo && (v as i64) <= hi || bits == 1,
                "value {v} out of {bits}-bit range"
            );
            let enc = if bits == 1 {
                // 1-bit encoding: sign bit (1 ⇒ +1, 0 ⇒ −1).
                u64::from(v > 0)
            } else {
                (v as i64 as u64) & mask
            };
            w |= enc << (i as u32 * bits);
        }
        words.push(w);
    }
    PackedBuffer {
        words,
        bits,
        factor,
        len: values.len(),
    }
}

/// Unpack back to signed integers (sign-extending each field).
pub fn unpack_words(buf: &PackedBuffer) -> Vec<i32> {
    let mut out = Vec::with_capacity(buf.len);
    let bits = buf.bits;
    'outer: for &w in &buf.words {
        for i in 0..buf.factor {
            if out.len() == buf.len {
                break 'outer;
            }
            let field = (w >> (i * bits)) & if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
            let v = if bits == 1 {
                if field == 1 {
                    1
                } else {
                    -1
                }
            } else {
                // Sign-extend.
                let shift = 64 - bits;
                (((field << shift) as i64) >> shift) as i32
            };
            out.push(v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_packing_factors() {
        // §5.3.1: S_port=64 ⇒ G=4 for 16-bit, G^q=8 for 8-bit,
        // G^q=10 for 6-bit (60/64 bits used).
        assert_eq!(pack_factor(64, 16), 4);
        assert_eq!(pack_factor(64, 8), 8);
        assert_eq!(pack_factor(64, 6), 10);
        assert_eq!(pack_factor(64, 1), 64);
        assert_eq!(pack_factor(64, 4), 16);
    }

    #[test]
    fn roundtrip_8bit() {
        let vals: Vec<i32> = (-128..128).collect();
        let packed = pack_words(&vals, 8, 64);
        assert_eq!(packed.words.len(), 32);
        assert_eq!(unpack_words(&packed), vals);
    }

    #[test]
    fn roundtrip_6bit_with_remainder_bits() {
        let vals: Vec<i32> = (0..23).map(|i| (i % 63) - 32).collect();
        let packed = pack_words(&vals, 6, 64);
        // 23 values at 10/word ⇒ 3 words.
        assert_eq!(packed.words.len(), 3);
        assert_eq!(unpack_words(&packed), vals);
    }

    #[test]
    fn roundtrip_1bit_signs() {
        let vals = vec![1, -1, -1, 1, 1, 1, -1];
        let packed = pack_words(&vals, 1, 64);
        assert_eq!(packed.words.len(), 1);
        assert_eq!(unpack_words(&packed), vals);
    }

    #[test]
    fn bram_reduction_is_factor_g() {
        // 1024 8-bit values: unpacked they'd need 1024 words; packed, 128.
        let vals = vec![7i32; 1024];
        let packed = pack_words(&vals, 8, 64);
        assert_eq!(packed.words.len() * packed.factor as usize, 1024);
    }
}
