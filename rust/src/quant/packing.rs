//! Data packing (paper §5.3.1) — both flavours the accelerator exploits:
//!
//! * **AXI-word packing** ([`pack_words`]/[`unpack_words`]): multiple
//!   low-precision values concatenated into one AXI word so BRAM usage
//!   drops by up to `G×` and input/output transfer cycles by `G×`. The
//!   packing factor is `G = ⌊S_port / bits⌋`; when `S_port` is not
//!   divisible by the bit width the remainder bits go unused — the paper's
//!   6-bit example: `G^q = ⌊64/6⌋ = 10`, only 60 of the 64 bits exploited.
//! * **Bit-plane packing** ([`SignPlanes`], [`BitPlanes`], [`ColPlanes`]):
//!   the compute-path view of the same idea. Binary weights are 64 signs
//!   per `u64` lane word; a `b`-bit activation vector is `b` bit-planes of
//!   lane words. A multiply-accumulate against ±1 weights then collapses
//!   to AND/XNOR + `count_ones()` with a per-plane shift-accumulate —
//!   exactly the LUT add/sub datapath of §5.1, and the kernel the packed
//!   simulator backend (`sim::kernels`) runs on. Every plane/column is
//!   allocated at the [`padded_lane_words`] stride (zero-padded to whole
//!   64-byte vectors) so the `util::simd` popcount tiers run tail-free,
//!   and the `ExecPlan`-prepared weights land in that SIMD-friendly
//!   layout once at prepare time.
//!
//! All bit-plane encodings are exact over the quantizer's integer range,
//! so the packed kernels are bit-identical to the scalar reference
//! (asserted by `rust/tests/property_suite.rs`).

/// Packing factor for `bits`-wide values on a `port_bits`-wide AXI port.
pub fn pack_factor(port_bits: u32, bits: u32) -> u32 {
    assert!(bits >= 1 && bits <= port_bits, "bits={bits} port={port_bits}");
    port_bits / bits
}

/// Mask selecting the low `bits` of a `u64` field, handling the
/// `bits == 64` case where `(1 << bits) - 1` would overflow.
pub fn field_mask(bits: u32) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// A buffer of packed AXI words plus the packing geometry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBuffer {
    pub words: Vec<u64>,
    pub bits: u32,
    pub factor: u32,
    /// Number of logical values packed (≤ words.len() · factor).
    pub len: usize,
}

/// Pack signed integers (must fit in `bits` two's-complement) into 64-bit
/// AXI words, `factor` per word, LSB-first.
pub fn pack_words(values: &[i32], bits: u32, port_bits: u32) -> PackedBuffer {
    let factor = pack_factor(port_bits, bits);
    let mask = field_mask(bits);
    let lo = -(1i64 << (bits - 1));
    let hi = (1i64 << (bits - 1)) - 1;
    let mut words = Vec::with_capacity(values.len().div_ceil(factor as usize));
    for chunk in values.chunks(factor as usize) {
        let mut w = 0u64;
        for (i, &v) in chunk.iter().enumerate() {
            debug_assert!(
                (v as i64) >= lo && (v as i64) <= hi || bits == 1,
                "value {v} out of {bits}-bit range"
            );
            let enc = if bits == 1 {
                // 1-bit encoding: sign bit (1 ⇒ +1, 0 ⇒ −1).
                u64::from(v > 0)
            } else {
                (v as i64 as u64) & mask
            };
            w |= enc << (i as u32 * bits);
        }
        words.push(w);
    }
    PackedBuffer {
        words,
        bits,
        factor,
        len: values.len(),
    }
}

/// Unpack back to signed integers (sign-extending each field).
pub fn unpack_words(buf: &PackedBuffer) -> Vec<i32> {
    let mut out = Vec::with_capacity(buf.len);
    let bits = buf.bits;
    let mask = field_mask(bits);
    'outer: for &w in &buf.words {
        for i in 0..buf.factor {
            if out.len() == buf.len {
                break 'outer;
            }
            let field = (w >> (i * bits)) & mask;
            let v = if bits == 1 {
                if field == 1 {
                    1
                } else {
                    -1
                }
            } else {
                // Sign-extend.
                let shift = 64 - bits;
                (((field << shift) as i64) >> shift) as i32
            };
            out.push(v);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Bit-plane packing: the compute-path kernels.
// ---------------------------------------------------------------------------

/// Number of 64-lane words covering `n` elements.
#[inline]
pub fn lane_words(n: usize) -> usize {
    n.div_ceil(64)
}

/// Lane-word alignment of every packed bit-plane: 8 words = 64 bytes,
/// one full AVX-512 vector (two AVX2 vectors, a cache line). Padding
/// each plane/column up to this multiple means the SIMD kernels never
/// need a sub-vector tail loop, and plane starts stay cache-line
/// aligned within their buffer.
pub const SIMD_PAD_WORDS: usize = 8;

/// [`lane_words`] rounded up to the [`SIMD_PAD_WORDS`] alignment — the
/// allocated stride of every packed plane/column. Pad words are zero:
/// harmless under AND-popcount, and [`xnor_sign_dot`] never reads past
/// `lane_words(n)`, so the padding is invisible to every kernel.
#[inline]
pub fn padded_lane_words(n: usize) -> usize {
    lane_words(n).next_multiple_of(SIMD_PAD_WORDS)
}

/// Shift-accumulate coefficient of two's-complement plane `b` out of
/// `bits`: `+2^b` for the magnitude planes, `−2^(bits−1)` for the sign
/// plane (so `q = Σ_b coeff(b) · bit_b(q)` exactly).
#[inline]
pub fn plane_coeff(b: u32, bits: u32) -> i64 {
    debug_assert!(b < bits && bits >= 2);
    if b == bits - 1 {
        -(1i64 << b)
    } else {
        1i64 << b
    }
}

/// Σ popcount(a & b) over two equal-length lane-word slices — the packed
/// dot product of two 0/1 bit vectors, dispatched to the active SIMD
/// tier (`util::simd`). The accumulator is 64-bit at every tier: the
/// pre-PR8 `u32` sum wrapped silently past 2³² set bits (and panicked in
/// debug), which the regression suite now pins.
#[inline]
pub fn popcount_and_dot(a: &[u64], b: &[u64]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    crate::util::simd::and_popcount(a, b) as i64
}

/// Dot product of two ±1 vectors stored as sign bitmaps (bit = 1 ⇒ +1)
/// over `n` valid lanes: XNOR matches signs, so the dot is
/// `2·popcount(XNOR) − n`. Invalid high lanes of the last word must be
/// masked because XNOR sets them (0 ⊕̄ 0 = 1); for the same reason the
/// zero pad words past `lane_words(n)` (the [`SIMD_PAD_WORDS`]
/// alignment) are never read at all. Dispatched to the active SIMD tier
/// with a 64-bit accumulator (see [`popcount_and_dot`]).
#[inline]
pub fn xnor_sign_dot(a: &[u64], b: &[u64], n: usize) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.len() >= lane_words(n));
    2 * crate::util::simd::xnor_popcount(a, b, n) as i64 - n as i64
}

/// Pack the signs of an integer slice (> 0 ⇒ bit set) into lane words —
/// the 1-bit activation encoding (±1, matching `ActQuantizer` at
/// `bits == 1`, which never produces 0).
pub fn pack_sign_bits(q: &[i32]) -> Vec<u64> {
    let mut words = Vec::new();
    pack_sign_bits_into(q, &mut words);
    words
}

/// [`pack_sign_bits`] into a reusable buffer (cleared and refilled) — the
/// one definition of the 1-bit sign/lane layout, shared by the allocating
/// and in-place packers.
pub fn pack_sign_bits_into(q: &[i32], words: &mut Vec<u64>) {
    words.clear();
    words.resize(padded_lane_words(q.len()), 0);
    for (p, &v) in q.iter().enumerate() {
        if v > 0 {
            words[p / 64] |= 1 << (p % 64);
        }
    }
}

/// Binary-weight sign planes packed column-major in 64-wide lanes: for
/// output column `j`, `col(j)` holds the sign bits of all `rows` weights
/// feeding that output (bit = 1 ⇒ +1), ready for a popcount dot against
/// activation bit-planes. This is the layout the BRAM-resident LUT array
/// holds on the board. Columns are strided at [`padded_lane_words`]
/// (zero-padded), so `col(j)` is always a whole number of SIMD vectors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SignPlanes {
    words: Vec<u64>,
    words_per_col: usize,
    pub rows: usize,
    pub cols: usize,
}

/// Pack a row-major `rows × cols` sign matrix (`true` ⇒ +1) column-major.
pub fn pack_sign_planes(signs: &[bool], rows: usize, cols: usize) -> SignPlanes {
    assert_eq!(signs.len(), rows * cols, "shape mismatch");
    let wpc = padded_lane_words(rows);
    let mut words = vec![0u64; cols * wpc];
    for p in 0..rows {
        let row = &signs[p * cols..(p + 1) * cols];
        let word = p / 64;
        let bit = 1u64 << (p % 64);
        for (j, &s) in row.iter().enumerate() {
            if s {
                words[j * wpc + word] |= bit;
            }
        }
    }
    SignPlanes {
        words,
        words_per_col: wpc,
        rows,
        cols,
    }
}

impl SignPlanes {
    /// Lane words of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[u64] {
        &self.words[j * self.words_per_col..(j + 1) * self.words_per_col]
    }

    pub fn words_per_col(&self) -> usize {
        self.words_per_col
    }
}

/// Two's-complement bit-planes of one integer vector (an activation row):
/// plane `b` is the lane-word bitmap of bit `b` of each element's `bits`-
/// wide encoding. `q = Σ_b plane_coeff(b) · plane_b` exactly, so packed
/// kernels reconstruct the scalar accumulator bit-for-bit.
///
/// `bits == 1` uses the ±1 sign encoding instead (bit = 1 ⇒ +1), matching
/// `ActQuantizer`'s 1-bit convention; consumers dot it with
/// [`xnor_sign_dot`] rather than plane accumulation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitPlanes {
    planes: Vec<u64>,
    words_per_plane: usize,
    pub bits: u32,
    pub len: usize,
    /// Per-plane popcount Σ_p bit_b(q_p) — the column-independent term of
    /// the ±1-weight dot (`Σ q·s = Σ_b coeff·(2·pop(plane∧W) − total)`).
    pub totals: Vec<i64>,
}

/// Decompose `q` into [`BitPlanes`] (values must fit `bits`
/// two's-complement for `bits ≥ 2`; ±1 for `bits == 1`).
pub fn pack_bit_planes(q: &[i32], bits: u32) -> BitPlanes {
    let mut bp = BitPlanes::empty();
    pack_bit_planes_into(q, bits, &mut bp);
    bp
}

/// [`pack_bit_planes`] into a reusable [`BitPlanes`]: the plane/total
/// buffers are cleared and refilled in place, so repeated packs of
/// same-shaped rows (the per-row inner loop of the packed kernels) cost
/// zero heap traffic after the first call.
pub fn pack_bit_planes_into(q: &[i32], bits: u32, bp: &mut BitPlanes) {
    assert!((1..=16).contains(&bits), "activation bits must be 1..=16");
    let wpp = padded_lane_words(q.len());
    bp.bits = bits;
    bp.len = q.len();
    bp.words_per_plane = wpp;
    if bits == 1 {
        pack_sign_bits_into(q, &mut bp.planes);
        bp.totals.clear();
        bp.totals.push(bp.planes.iter().map(|w| w.count_ones() as i64).sum());
        return;
    }
    let mask = field_mask(bits);
    bp.planes.clear();
    bp.planes.resize(bits as usize * wpp, 0);
    bp.totals.clear();
    bp.totals.resize(bits as usize, 0);
    for (p, &v) in q.iter().enumerate() {
        debug_assert!(
            (v as i64) >= -(1i64 << (bits - 1)) && (v as i64) <= (1i64 << (bits - 1)) - 1,
            "value {v} out of {bits}-bit range"
        );
        let mut enc = (v as i64 as u64) & mask;
        let word = p / 64;
        let bit = 1u64 << (p % 64);
        while enc != 0 {
            let b = enc.trailing_zeros();
            bp.planes[b as usize * wpp + word] |= bit;
            bp.totals[b as usize] += 1;
            enc &= enc - 1;
        }
    }
}

impl BitPlanes {
    /// An empty decomposition to feed [`pack_bit_planes_into`] — the
    /// reusable-scratch idiom of the packed kernels.
    pub fn empty() -> BitPlanes {
        BitPlanes {
            planes: Vec::new(),
            words_per_plane: 0,
            bits: 1,
            len: 0,
            totals: Vec::new(),
        }
    }

    /// Lane words of plane `b`.
    #[inline]
    pub fn plane(&self, b: u32) -> &[u64] {
        &self.planes[b as usize * self.words_per_plane..(b as usize + 1) * self.words_per_plane]
    }

    pub fn words_per_plane(&self) -> usize {
        self.words_per_plane
    }
}

/// Reconstruct the integer vector from its bit-planes (inverse of
/// [`pack_bit_planes`] — the round-trip property the test suite sweeps).
pub fn unpack_bit_planes(bp: &BitPlanes) -> Vec<i32> {
    let mut out = Vec::with_capacity(bp.len);
    if bp.bits == 1 {
        let plane = bp.plane(0);
        for p in 0..bp.len {
            let set = plane[p / 64] >> (p % 64) & 1 == 1;
            out.push(if set { 1 } else { -1 });
        }
        return out;
    }
    for p in 0..bp.len {
        let mut v = 0i64;
        for b in 0..bp.bits {
            if bp.plane(b)[p / 64] >> (p % 64) & 1 == 1 {
                v += plane_coeff(b, bp.bits);
            }
        }
        out.push(v as i32);
    }
    out
}

/// A quantized matrix packed as per-column bit-planes: for output column
/// `j` and plane `b`, `col_plane(j, b)` is the lane-word bitmap of bit `b`
/// of all `rows` elements of that column. The right-hand operand layout of
/// the packed quantized×quantized matmul: the product of two exact
/// two's-complement decompositions is a double sum of AND-popcount dots.
///
/// `bits == 1` stores the ±1 sign bitmap (one plane per column).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColPlanes {
    words: Vec<u64>,
    words_per_col: usize,
    pub bits: u32,
    pub rows: usize,
    pub cols: usize,
}

/// Pack a row-major `rows × cols` integer matrix into per-column planes.
pub fn pack_col_planes(q: &[i32], rows: usize, cols: usize, bits: u32) -> ColPlanes {
    let mut cp = ColPlanes::empty();
    pack_col_planes_into(q, rows, cols, bits, &mut cp);
    cp
}

/// [`pack_col_planes`] into a reusable [`ColPlanes`] (cleared and
/// refilled in place — the attention workspace repacks the right-hand
/// operand every call without heap traffic once warmed up).
pub fn pack_col_planes_into(q: &[i32], rows: usize, cols: usize, bits: u32, cp: &mut ColPlanes) {
    assert_eq!(q.len(), rows * cols, "shape mismatch");
    assert!((1..=16).contains(&bits), "activation bits must be 1..=16");
    let planes = if bits == 1 { 1 } else { bits as usize };
    let wpc = padded_lane_words(rows);
    cp.words.clear();
    cp.words.resize(cols * planes * wpc, 0);
    cp.words_per_col = wpc;
    cp.bits = bits;
    cp.rows = rows;
    cp.cols = cols;
    let mask = field_mask(bits);
    for p in 0..rows {
        let row = &q[p * cols..(p + 1) * cols];
        let word = p / 64;
        let bit = 1u64 << (p % 64);
        for (j, &v) in row.iter().enumerate() {
            if bits == 1 {
                if v > 0 {
                    cp.words[j * wpc + word] |= bit;
                }
                continue;
            }
            let mut enc = (v as i64 as u64) & mask;
            let base = j * planes * wpc + word;
            while enc != 0 {
                let b = enc.trailing_zeros() as usize;
                cp.words[base + b * wpc] |= bit;
                enc &= enc - 1;
            }
        }
    }
}

impl ColPlanes {
    /// An empty packing to feed [`pack_col_planes_into`].
    pub fn empty() -> ColPlanes {
        ColPlanes {
            words: Vec::new(),
            words_per_col: 0,
            bits: 1,
            rows: 0,
            cols: 0,
        }
    }

    /// Lane words of plane `b` of column `j`.
    #[inline]
    pub fn col_plane(&self, j: usize, b: u32) -> &[u64] {
        let planes = if self.bits == 1 { 1 } else { self.bits as usize };
        debug_assert!((b as usize) < planes);
        let start = (j * planes + b as usize) * self.words_per_col;
        &self.words[start..start + self.words_per_col]
    }

    pub fn words_per_col(&self) -> usize {
        self.words_per_col
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_packing_factors() {
        // §5.3.1: S_port=64 ⇒ G=4 for 16-bit, G^q=8 for 8-bit,
        // G^q=10 for 6-bit (60/64 bits used).
        assert_eq!(pack_factor(64, 16), 4);
        assert_eq!(pack_factor(64, 8), 8);
        assert_eq!(pack_factor(64, 6), 10);
        assert_eq!(pack_factor(64, 1), 64);
        assert_eq!(pack_factor(64, 4), 16);
    }

    #[test]
    fn field_mask_covers_full_word() {
        assert_eq!(field_mask(1), 1);
        assert_eq!(field_mask(8), 0xFF);
        assert_eq!(field_mask(63), u64::MAX >> 1);
        assert_eq!(field_mask(64), u64::MAX);
    }

    #[test]
    fn roundtrip_8bit() {
        let vals: Vec<i32> = (-128..128).collect();
        let packed = pack_words(&vals, 8, 64);
        assert_eq!(packed.words.len(), 32);
        assert_eq!(unpack_words(&packed), vals);
    }

    #[test]
    fn roundtrip_6bit_with_remainder_bits() {
        let vals: Vec<i32> = (0..23).map(|i| (i % 63) - 32).collect();
        let packed = pack_words(&vals, 6, 64);
        // 23 values at 10/word ⇒ 3 words.
        assert_eq!(packed.words.len(), 3);
        assert_eq!(unpack_words(&packed), vals);
    }

    #[test]
    fn roundtrip_1bit_signs() {
        let vals = vec![1, -1, -1, 1, 1, 1, -1];
        let packed = pack_words(&vals, 1, 64);
        assert_eq!(packed.words.len(), 1);
        assert_eq!(unpack_words(&packed), vals);
    }

    #[test]
    fn bram_reduction_is_factor_g() {
        // 1024 8-bit values: unpacked they'd need 1024 words; packed, 128.
        let vals = vec![7i32; 1024];
        let packed = pack_words(&vals, 8, 64);
        assert_eq!(packed.words.len() * packed.factor as usize, 1024);
    }

    #[test]
    fn bit_planes_roundtrip_and_totals() {
        let vals: Vec<i32> = (-64..64).chain([127, -128, 0, 1, -1]).collect();
        let bp = pack_bit_planes(&vals, 8);
        assert_eq!(unpack_bit_planes(&bp), vals);
        // Plane totals count set bits per plane.
        for b in 0..8 {
            let want = vals
                .iter()
                .filter(|&&v| (v as i64 as u64 & field_mask(8)) >> b & 1 == 1)
                .count() as i64;
            assert_eq!(bp.totals[b as usize], want, "plane {b}");
        }
    }

    #[test]
    fn sign_planes_match_row_major_signs() {
        // 3×5 matrix with a recognizable pattern.
        let rows = 3;
        let cols = 5;
        let signs: Vec<bool> = (0..rows * cols).map(|i| i % 3 == 0).collect();
        let sp = pack_sign_planes(&signs, rows, cols);
        // 3 rows need one lane word, padded to the SIMD stride.
        assert_eq!(sp.words_per_col(), SIMD_PAD_WORDS);
        for j in 0..cols {
            for p in 0..rows {
                let bit = sp.col(j)[p / 64] >> (p % 64) & 1 == 1;
                assert_eq!(bit, signs[p * cols + j], "({p},{j})");
            }
        }
    }

    #[test]
    fn popcount_dot_equals_scalar_dot() {
        // 0/1 vectors of length 150 (crosses a word boundary).
        let n = 150;
        let a: Vec<i32> = (0..n).map(|i| (i * 7 % 3 == 0) as i32).collect();
        let b: Vec<i32> = (0..n).map(|i| (i * 5 % 4 == 0) as i32).collect();
        let pa = {
            let mut w = vec![0u64; lane_words(n)];
            for (i, &v) in a.iter().enumerate() {
                if v == 1 {
                    w[i / 64] |= 1 << (i % 64);
                }
            }
            w
        };
        let pb = {
            let mut w = vec![0u64; lane_words(n)];
            for (i, &v) in b.iter().enumerate() {
                if v == 1 {
                    w[i / 64] |= 1 << (i % 64);
                }
            }
            w
        };
        let want: i64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as i64).sum();
        assert_eq!(popcount_and_dot(&pa, &pb), want);
    }

    #[test]
    fn xnor_dot_equals_sign_dot() {
        let n = 100;
        let a: Vec<i32> = (0..n).map(|i| if i % 3 == 0 { 1 } else { -1 }).collect();
        let b: Vec<i32> = (0..n).map(|i| if i % 7 < 3 { 1 } else { -1 }).collect();
        let want: i64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as i64).sum();
        let got = xnor_sign_dot(&pack_sign_bits(&a), &pack_sign_bits(&b), n);
        assert_eq!(got, want);
    }

    #[test]
    fn xnor_dot_exact_at_tail_lane_boundaries() {
        // n % 64 ∈ {0, 1, 63} around every word edge up to three words,
        // the masks the old per-word `valid = n - w*64` code got right
        // only for unpadded slices.
        for n in [1usize, 63, 64, 65, 127, 128, 129, 191, 192, 193] {
            let a: Vec<i32> = (0..n).map(|i| if i % 5 < 2 { 1 } else { -1 }).collect();
            let b: Vec<i32> = (0..n).map(|i| if (i / 3) % 2 == 0 { 1 } else { -1 }).collect();
            let want: i64 = a.iter().zip(&b).map(|(&x, &y)| (x * y) as i64).sum();
            assert_eq!(xnor_sign_dot(&pack_sign_bits(&a), &pack_sign_bits(&b), n), want, "n={n}");
        }
    }

    #[test]
    fn padded_words_are_zero_and_invisible_to_the_dots() {
        let n = 70; // 2 lane words, padded to 8
        let a: Vec<i32> = (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
        let pa = pack_sign_bits(&a);
        assert_eq!(pa.len(), padded_lane_words(n as usize));
        assert!(pa[lane_words(n as usize)..].iter().all(|&w| w == 0));
        // Self XNOR-dot over n lanes must be exactly +n: pad words XNOR
        // to all-ones and would inflate the count if they were read.
        assert_eq!(xnor_sign_dot(&pa, &pa, n as usize), n as i64);
        // AND-popcount tolerates the pad because it is zero.
        assert_eq!(popcount_and_dot(&pa, &pa), (n as i64 + 1) / 2);
    }

    #[test]
    fn col_planes_reconstruct_matrix() {
        let rows = 70; // crosses a word boundary
        let cols = 3;
        let bits = 5;
        let q: Vec<i32> = (0..rows * cols).map(|i| (i as i32 * 11 % 31) - 15).collect();
        let cp = pack_col_planes(&q, rows, cols, bits);
        for j in 0..cols {
            for p in 0..rows {
                let mut v = 0i64;
                for b in 0..bits {
                    if cp.col_plane(j, b)[p / 64] >> (p % 64) & 1 == 1 {
                        v += plane_coeff(b, bits);
                    }
                }
                assert_eq!(v as i32, q[p * cols + j], "({p},{j})");
            }
        }
    }
}
