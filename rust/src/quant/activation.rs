//! Uniform b-bit activation quantization (paper §4.2: "reduce the
//! activations into low-precision", b chosen by the compiler from 1..=16).



/// A symmetric uniform quantizer for activations.
///
/// Values are mapped to signed integers in `[-2^(b-1), 2^(b-1) - 1]` with a
/// single power-free scale (`x ≈ q · scale`). Symmetric signed quantization
/// matches what the accelerator's add/sub datapath expects: a binary weight
/// flips the sign of the integer activation and the scales fold together at
/// output dequantization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActQuantizer {
    pub bits: u8,
    pub scale: f32,
}

/// A quantized activation tensor: integers plus the quantizer that made
/// them.
#[derive(Debug, Clone)]
pub struct QuantizedTensor {
    pub q: Vec<i32>,
    pub quantizer: ActQuantizer,
}

impl ActQuantizer {
    /// Calibrate a quantizer for `bits`-wide signed storage over `data`
    /// (max-abs calibration, the standard QAT forward-pass choice).
    pub fn calibrate(bits: u8, data: &[f32]) -> ActQuantizer {
        assert!((1..=16).contains(&bits), "activation bits must be 1..=16");
        let max_abs = data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let qmax = ((1i64 << (bits - 1)) - 1).max(1) as f32;
        let scale = if max_abs > 0.0 { max_abs / qmax } else { 1.0 };
        ActQuantizer { bits, scale }
    }

    /// Integer range limits for this width.
    pub fn qrange(&self) -> (i32, i32) {
        if self.bits == 1 {
            // 1-bit activations are ±1 (binary activations, the FR_max case).
            (-1, 1)
        } else {
            let hi = (1i64 << (self.bits - 1)) - 1;
            (-(hi as i32) - 1, hi as i32)
        }
    }

    /// Quantize one value to its integer grid point.
    pub fn quantize_one(&self, x: f32) -> i32 {
        let (lo, hi) = self.qrange();
        if self.bits == 1 {
            return if x > 0.0 { 1 } else { -1 };
        }
        let q = (x / self.scale).round() as i64;
        q.clamp(lo as i64, hi as i64) as i32
    }

    /// Dequantize an integer grid point.
    pub fn dequantize_one(&self, q: i32) -> f32 {
        q as f32 * self.scale
    }

    /// Quantize a whole tensor.
    pub fn quantize(&self, data: &[f32]) -> QuantizedTensor {
        QuantizedTensor {
            q: data.iter().map(|&x| self.quantize_one(x)).collect(),
            quantizer: *self,
        }
    }

    /// Quantize into a reusable buffer (cleared and refilled — no
    /// reallocation once `out`'s capacity has warmed up). Element-for-
    /// element identical to [`ActQuantizer::quantize`].
    pub fn quantize_into(&self, data: &[f32], out: &mut Vec<i32>) {
        out.clear();
        out.extend(data.iter().map(|&x| self.quantize_one(x)));
    }

    /// Fake-quantization: quantize then dequantize (the QAT forward pass).
    pub fn fake_quantize(&self, data: &[f32]) -> Vec<f32> {
        data.iter()
            .map(|&x| self.dequantize_one(self.quantize_one(x)))
            .collect()
    }

    /// Worst-case absolute rounding error (half a step, plus clipping which
    /// max-abs calibration avoids).
    pub fn step(&self) -> f32 {
        self.scale
    }
}

impl QuantizedTensor {
    pub fn dequantize(&self) -> Vec<f32> {
        self.q.iter().map(|&q| self.quantizer.dequantize_one(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        let data: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) / 100.0).collect();
        for bits in [4u8, 6, 8, 12, 16] {
            let q = ActQuantizer::calibrate(bits, &data);
            let deq = q.fake_quantize(&data);
            for (x, y) in data.iter().zip(&deq) {
                assert!(
                    (x - y).abs() <= q.step() / 2.0 + 1e-6,
                    "bits={bits} x={x} y={y} step={}",
                    q.step()
                );
            }
        }
    }

    #[test]
    fn higher_precision_is_never_worse() {
        let data: Vec<f32> = (0..512).map(|i| ((i * 97 % 31) as f32 - 15.0) / 7.0).collect();
        let mse = |bits: u8| -> f64 {
            let q = ActQuantizer::calibrate(bits, &data);
            q.fake_quantize(&data)
                .iter()
                .zip(&data)
                .map(|(y, x)| ((x - y) as f64).powi(2))
                .sum::<f64>()
        };
        assert!(mse(8) <= mse(6));
        assert!(mse(6) <= mse(4));
        assert!(mse(4) <= mse(2));
    }

    #[test]
    fn one_bit_activations_are_signs() {
        let q = ActQuantizer::calibrate(1, &[0.3, -0.7, 2.0]);
        assert_eq!(q.quantize(&[0.3, -0.7, 2.0, 0.0]).q, vec![1, -1, 1, -1]);
    }

    #[test]
    fn qrange_widths() {
        assert_eq!(ActQuantizer { bits: 8, scale: 1.0 }.qrange(), (-128, 127));
        assert_eq!(ActQuantizer { bits: 6, scale: 1.0 }.qrange(), (-32, 31));
        assert_eq!(ActQuantizer { bits: 16, scale: 1.0 }.qrange(), (-32768, 32767));
    }

    #[test]
    fn zero_data_does_not_panic() {
        let q = ActQuantizer::calibrate(8, &[0.0; 16]);
        assert_eq!(q.quantize(&[0.0; 4]).q, vec![0; 4]);
    }
}
