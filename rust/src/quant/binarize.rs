//! Weight binarization (paper Eq. 5, following XNOR-Net / ReActNet).

use super::packing::{pack_sign_planes, SignPlanes};

/// A binarized weight matrix: signs plus one ℓ1 scaling factor.
///
/// `w_b = (‖W_r‖₁ / n) · sign(w_r)` — the scaling factor minimizes the ℓ2
/// difference between the binary and real-valued matrices. On hardware only
/// the sign bits travel (1 bit/weight); the scale folds into the output
/// dequantization, which is exactly why quantized MACs reduce to additions
/// and subtractions (paper §1, §5.1).
#[derive(Debug, Clone)]
pub struct BinaryMatrix {
    /// Row-major sign bits; `true` ⇒ +1, `false` ⇒ −1.
    pub signs: Vec<bool>,
    /// `‖W_r‖₁ / n`.
    pub scale: f32,
    pub rows: usize,
    pub cols: usize,
}

impl BinaryMatrix {
    /// Reconstruct the dense ±scale matrix (the dequantized view used by
    /// functional references).
    pub fn to_dense(&self) -> Vec<f32> {
        self.signs
            .iter()
            .map(|&s| if s { self.scale } else { -self.scale })
            .collect()
    }

    /// Sign at `(row, col)` as ±1.
    pub fn sign_at(&self, row: usize, col: usize) -> i32 {
        if self.signs[row * self.cols + col] {
            1
        } else {
            -1
        }
    }

    /// Storage cost in bits (1 per weight + one f32 scale).
    pub fn storage_bits(&self) -> u64 {
        self.signs.len() as u64 + 32
    }

    /// Column-major 64-lane packed view of the signs — the operand layout
    /// of the packed XNOR/popcount compute backend (`sim::kernels`).
    pub fn packed_signs(&self) -> SignPlanes {
        pack_sign_planes(&self.signs, self.rows, self.cols)
    }
}

/// Binarize a row-major `rows × cols` real-valued matrix per Eq. 5.
///
/// Note the paper's convention: `w_r > 0 → +scale`, `w_r ≤ 0 → −scale`
/// (zero maps to −scale).
pub fn binarize(weights: &[f32], rows: usize, cols: usize) -> BinaryMatrix {
    assert_eq!(weights.len(), rows * cols, "shape mismatch");
    let n = weights.len() as f32;
    let l1: f32 = weights.iter().map(|w| w.abs()).sum();
    let scale = if n > 0.0 { l1 / n } else { 0.0 };
    let signs = weights.iter().map(|&w| w > 0.0).collect();
    BinaryMatrix {
        signs,
        scale,
        rows,
        cols,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_is_l1_over_n() {
        let w = [1.0f32, -2.0, 3.0, -4.0];
        let b = binarize(&w, 2, 2);
        assert!((b.scale - 2.5).abs() < 1e-6);
    }

    #[test]
    fn signs_follow_eq5_zero_maps_negative() {
        let w = [0.5f32, -0.5, 0.0, 2.0];
        let b = binarize(&w, 2, 2);
        assert_eq!(b.to_dense().iter().map(|v| v.signum()).collect::<Vec<_>>(), vec![1.0, -1.0, -1.0, 1.0]);
    }

    #[test]
    fn binarization_minimizes_l2_among_scales() {
        // The l1/n scale is the analytic argmin of ‖W − s·sign(W)‖₂;
        // perturbing it must not reduce the error.
        let w: Vec<f32> = (0..64).map(|i| ((i * 37 % 13) as f32 - 6.0) / 3.0).collect();
        let b = binarize(&w, 8, 8);
        let err = |s: f32| -> f32 {
            w.iter()
                .zip(&b.signs)
                .map(|(&wr, &sg)| {
                    let wb = if sg { s } else { -s };
                    (wr - wb) * (wr - wb)
                })
                .sum()
        };
        let e0 = err(b.scale);
        assert!(e0 <= err(b.scale * 1.1) + 1e-5);
        assert!(e0 <= err(b.scale * 0.9) + 1e-5);
    }
}
