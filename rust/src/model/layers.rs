//! Layer descriptors consumed by the perf model, compiler, and simulator.



/// What kind of matmul a layer performs (paper §5.1).
///
/// An FC layer performs a single `F×N @ N×M` matrix multiplication; a
/// multi-head attention layer repeats an `F×N @ N×M` multiplication across
/// `heads` attention heads. The compute engine is shared: FC inputs are split
/// into `N_h` channel groups, `P_h` of which are processed in parallel, and
/// the per-group partial sums are accumulated (attention keeps them
/// separate). A control signal selects the behaviour — here that signal is
/// the `LayerKind`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Patch-embedding convolution, converted to an FC layer (Fig. 4): the
    /// kernel size equals the stride equals the patch size, so every input
    /// pixel is read exactly once and the conv degenerates to a matmul over
    /// flattened patches.
    PatchEmbed,
    /// A plain fully-connected layer (QKV projections, attention output
    /// projection, the two MLP linears, the classifier head).
    Fc,
    /// Scaled dot-product `Q @ K^T` — per-head `F×M_h @ M_h×F`.
    AttnQk,
    /// Attention-weighted value gather `S @ V` — per-head `F×F @ F×M_h`.
    AttnSv,
}

impl LayerKind {
    /// `true` for the multi-head attention matmuls, where the compute
    /// engine's γ term (Eq. 7) is `N_h − 1` and per-head results are kept.
    pub fn is_attention(self) -> bool {
        matches!(self, LayerKind::AttnQk | LayerKind::AttnSv)
    }
}

/// Numeric precision of a tensor as seen by the accelerator.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 16-bit fixed point — the on-hardware representation of "unquantized"
    /// (software fp32) data in the baseline accelerator (paper §5.3).
    Fixed16,
    /// Binary (±scale) weights — 1 bit on the wire (paper Eq. 5).
    Binary,
    /// Uniform `bits`-wide quantized activations, 1..=16.
    Int(u8),
}

impl Precision {
    /// Bit width on the wire / in BRAM.
    pub fn bits(self) -> u32 {
        match self {
            Precision::Fixed16 => 16,
            Precision::Binary => 1,
            Precision::Int(b) => b as u32,
        }
    }

    /// Whether this operand takes the quantized (LUT add/sub) datapath
    /// rather than the 16-bit DSP datapath.
    pub fn is_quantized(self) -> bool {
        !matches!(self, Precision::Fixed16)
    }
}

/// Host-side operation between matmul layers (paper §5.2: scaling, softmax
/// and GELU run on the host CPU; LayerNorm params stay 16-bit on hardware).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HostOp {
    LayerNorm,
    Softmax,
    Gelu,
    /// Skip-connection addition with the stored normalization input
    /// (paper §5.2.1).
    SkipAdd,
    /// `1/sqrt(D)` attention scaling.
    Scale,
}

/// One matmul layer as the accelerator sees it.
///
/// Dimension conventions follow Table 1 of the paper:
/// * `m` — number of output channels (columns of the weight matrix),
/// * `n` — number of input channels (rows of the weight matrix),
/// * `f` — number of token sequences (rows of the activation matrix),
/// * `heads` — `N_h` for this layer: the true head count for attention
///   matmuls, and the channel-group count the engine splits FC inputs into.
#[derive(Debug, Clone)]
pub struct LayerDesc {
    /// Human-readable name, e.g. `enc3.mlp1`.
    pub name: String,
    pub kind: LayerKind,
    /// Output channels `M`.
    pub m: usize,
    /// Input channels `N`.
    pub n: usize,
    /// Token sequences `F`.
    pub f: usize,
    /// Head count `N_h` (see struct docs).
    pub heads: usize,
    /// Precision of the input activations (α in Eq. 7 is 1 iff this and
    /// `weights` are quantized).
    pub inputs: Precision,
    /// Precision of the weights (binary for quantized encoder layers; for
    /// the attention matmuls the "weight" operand is itself a quantized
    /// activation tile — K or V).
    pub weights: Precision,
    /// Precision of the output activations (β in Eq. 7).
    pub outputs: Precision,
    /// Host ops executed after this layer (latency accounted separately).
    pub host_ops: Vec<HostOp>,
}

impl LayerDesc {
    /// α of Eqs. 7/10: 1 iff inputs *and* weights take the quantized path.
    pub fn alpha(&self) -> bool {
        self.inputs.is_quantized() && self.weights.is_quantized()
    }

    /// β of Eqs. 7/11: 1 iff outputs are stored quantized.
    pub fn beta(&self) -> bool {
        self.outputs.is_quantized()
    }

    /// γ of Eq. 7: `N_h − 1` for attention layers (per-head outputs are all
    /// stored), else 0.
    pub fn gamma(&self) -> usize {
        if self.kind.is_attention() {
            self.heads - 1
        } else {
            0
        }
    }

    /// Multiply-accumulate count for one inference of this layer.
    ///
    /// For FC layers the `N` input channels cover all heads (the engine
    /// splits them), so the MAC count is simply `F·N·M`. For attention
    /// layers each of the `heads` heads performs an independent `F×N @ N×M`
    /// product.
    pub fn macs(&self) -> u64 {
        let per_head = self.f as u64 * self.n as u64 * self.m as u64;
        if self.kind.is_attention() {
            per_head * self.heads as u64
        } else {
            per_head
        }
    }

    /// Operation count (1 MAC = 2 ops), the unit of the paper's GOPS numbers.
    pub fn ops(&self) -> u64 {
        self.macs() * 2
    }

    /// Number of weight elements (0 weight *parameters* for attention
    /// matmuls — their "weights" are activations).
    pub fn weight_params(&self) -> u64 {
        match self.kind {
            LayerKind::AttnQk | LayerKind::AttnSv => 0,
            _ => self.n as u64 * self.m as u64,
        }
    }
}
