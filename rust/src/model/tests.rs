use super::*;

#[test]
fn deit_base_dimensions_match_paper() {
    let cfg = deit_base();
    assert_eq!(cfg.num_patches(), 196);
    assert_eq!(cfg.tokens(), 197);
    assert_eq!(cfg.head_dim(), 64);
}

#[test]
fn deit_param_counts_match_published_sizes() {
    // Paper: DeiT-base 86M, DeiT-small 22M, DeiT-tiny 5M.
    let base = deit_base().param_count() as f64 / 1e6;
    let small = deit_small().param_count() as f64 / 1e6;
    let tiny = deit_tiny().param_count() as f64 / 1e6;
    assert!((base - 86.0).abs() < 1.5, "base = {base}M");
    assert!((small - 22.0).abs() < 0.8, "small = {small}M");
    assert!((tiny - 5.0).abs() < 0.8, "tiny = {tiny}M");
}

#[test]
fn deit_base_macs_match_published_flops() {
    // DeiT-base @224 is ~17.6 GMACs ⇒ ~35.2 GOPs, consistent with the
    // paper's 345.8 GOPS at 10.0 FPS (= 34.6 GOP/frame).
    let s = deit_base().structure(None);
    let gmacs = s.total_macs() as f64 / 1e9;
    assert!((gmacs - 17.6).abs() < 0.5, "gmacs = {gmacs}");
    let gops_frame = s.total_ops() as f64 / 1e9;
    assert!((gops_frame - 34.6).abs() < 1.5, "gop/frame = {gops_frame}");
}

#[test]
fn structure_layer_count() {
    // patch embed + 6 matmuls per encoder layer × 12 + head.
    let s = deit_base().structure(Some(8));
    assert_eq!(s.layers.len(), 1 + 6 * 12 + 1);
}

#[test]
fn quantization_assignment_follows_paper() {
    let s = deit_base().structure(Some(8));
    // First and last layers are unquantized (§4.2 Implementation Details).
    assert!(!s.layers.first().unwrap().alpha());
    assert!(!s.layers.last().unwrap().alpha());
    // All encoder matmuls are quantized.
    for l in &s.layers[1..s.layers.len() - 1] {
        assert!(l.alpha(), "{} should be quantized", l.name);
    }
    // Layers feeding LayerNorm/skip store unquantized outputs (§5.2.1).
    for l in &s.layers {
        if l.host_ops.contains(&HostOp::SkipAdd) {
            assert!(!l.beta(), "{} feeds a skip-add; outputs must be 16-bit", l.name);
        }
    }
}

#[test]
fn unquantized_structure_has_no_quantized_layers() {
    let s = deit_base().structure(None);
    assert_eq!(s.quantized_layers().count(), 0);
}

#[test]
fn attention_gamma_and_macs() {
    let s = deit_base().structure(Some(8));
    let qk = s.layers.iter().find(|l| l.name == "enc0.attn_qk").unwrap();
    assert_eq!(qk.gamma(), 11);
    assert_eq!(qk.m, 197);
    assert_eq!(qk.n, 64);
    // 12 heads × 197×64×197 MACs.
    assert_eq!(qk.macs(), 12 * 197 * 64 * 197);
    let qkv = s.layers.iter().find(|l| l.name == "enc0.qkv").unwrap();
    assert_eq!(qkv.gamma(), 0);
    assert_eq!(qkv.macs(), 197 * 768 * (3 * 768));
}

#[test]
fn patch_embed_conv_to_fc_dims() {
    let l = patch_embed_as_fc(&deit_base());
    // 3·16² = 768 input channels, M=768 outputs, 196 patches.
    assert_eq!(l.n, 768);
    assert_eq!(l.m, 768);
    assert_eq!(l.f, 196);
}

#[test]
fn space_usage_reproduces_32x_reduction() {
    // Table 2: 86M×32 → 86M×1. Binarization shrinks the encoder weights
    // (the overwhelming majority) by 32×; total must shrink by >20×.
    let fp = deit_base().structure(None).space_usage_bits() as f64;
    let bin = deit_base().structure(Some(8)).space_usage_bits() as f64;
    assert!(fp / bin > 20.0, "reduction = {}", fp / bin);
}

#[test]
fn presets_roundtrip_names() {
    for p in VitPreset::all() {
        let cfg = p.config();
        assert_eq!(VitPreset::from_name(&cfg.name), Some(p));
    }
}
