//! ViT configuration → accelerator layer sequence (paper §4.1, §5.2).



use super::layers::{HostOp, LayerDesc, LayerKind, Precision};

/// Architectural hyper-parameters of a ViT (DeiT) classifier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VitConfig {
    /// Model family name, e.g. `deit-base`.
    pub name: String,
    /// Input image height/width (images are resized to squares, §6.1).
    pub image_size: usize,
    /// Patch size `P`; the patch-embed conv has kernel = stride = `P`.
    pub patch_size: usize,
    /// Input channels (3 for RGB).
    pub in_chans: usize,
    /// Hidden (embedding) dimension `M`.
    pub embed_dim: usize,
    /// Number of encoder layers `L`.
    pub depth: usize,
    /// Attention heads `N_h`.
    pub num_heads: usize,
    /// MLP expansion ratio (4 for DeiT).
    pub mlp_ratio: usize,
    /// Classifier classes `C`.
    pub num_classes: usize,
}

impl VitConfig {
    /// Number of image patches `N_p = H·W / P²`.
    pub fn num_patches(&self) -> usize {
        (self.image_size / self.patch_size) * (self.image_size / self.patch_size)
    }

    /// Token count `F = N_p + 1` (CLS token prepended, Eq. 1).
    pub fn tokens(&self) -> usize {
        self.num_patches() + 1
    }

    /// Per-head dimension `M_h = M / N_h`.
    pub fn head_dim(&self) -> usize {
        self.embed_dim / self.num_heads
    }

    /// Total trainable parameters (approximate, matches the usual "86M for
    /// DeiT-base" accounting: embeddings + encoder weights/biases + head).
    pub fn param_count(&self) -> u64 {
        let m = self.embed_dim as u64;
        let f = self.tokens() as u64;
        let patch_in = (self.in_chans * self.patch_size * self.patch_size) as u64;
        let mlp_hidden = (self.embed_dim * self.mlp_ratio) as u64;
        let classes = self.num_classes as u64;

        let patch_embed = patch_in * m + m; // conv weight + bias
        let pos_cls = f * m + m; // positional embedding + CLS token
        // Per encoder layer: QKV (3·M·M + 3·M), proj (M·M + M),
        // MLP (M·4M + 4M + 4M·M + M), two LayerNorms (2·2M).
        let per_layer = 3 * (m * m + m)
            + (m * m + m)
            + (m * mlp_hidden + mlp_hidden)
            + (mlp_hidden * m + m)
            + 2 * 2 * m;
        let head = m * classes + classes + 2 * m; // final LN + classifier
        patch_embed + pos_cls + self.depth as u64 * per_layer + head
    }

    /// Expand into the full accelerator layer sequence, with quantization
    /// assignments for activation precision `act_bits` (`None` ⇒ unquantized
    /// W32A32-on-software / W16A16-on-hardware baseline).
    ///
    /// Per paper §4.2 *Implementation Details*: the patch embedding and the
    /// output head stay full-precision; every matmul inside the encoder
    /// (QKV, Q·Kᵀ, S·V, projection, MLP1, MLP2) is quantized — binary
    /// weights, `act_bits` activations. LayerNorm inputs stay 16-bit
    /// (§5.2.1), which is why layers feeding a LayerNorm/skip store
    /// *unquantized* outputs.
    pub fn structure(&self, act_bits: Option<u8>) -> VitStructure {
        let m = self.embed_dim;
        let f = self.tokens();
        let nh = self.num_heads;
        let mh = self.head_dim();
        let mlp_hidden = m * self.mlp_ratio;

        let (act, wgt) = match act_bits {
            Some(b) => (Precision::Int(b), Precision::Binary),
            None => (Precision::Fixed16, Precision::Fixed16),
        };

        let mut layers = Vec::new();

        // Patch embedding: conv(P×P, stride P) ≡ FC over flattened patches
        // (Fig. 4). Never quantized. Its output feeds the first LayerNorm,
        // so outputs are stored 16-bit.
        layers.push(patch_embed_as_fc(self));

        for l in 0..self.depth {
            let p = |s: &str| format!("enc{l}.{s}");
            // QKV: inputs are the (quantized) LayerNorm outputs. Outputs Q,K,V
            // feed the attention matmuls, so they are stored quantized.
            layers.push(LayerDesc {
                name: p("qkv"),
                kind: LayerKind::Fc,
                m: 3 * m,
                n: m,
                f,
                heads: nh,
                inputs: act,
                weights: wgt,
                outputs: act,
                host_ops: vec![],
            });
            // Q·Kᵀ per head: F×M_h @ M_h×F. The "weight" operand is the
            // quantized K tile. Softmax + 1/sqrt(D) scaling run on the host,
            // and the softmax output is re-quantized for S·V.
            layers.push(LayerDesc {
                name: p("attn_qk"),
                kind: LayerKind::AttnQk,
                m: f,
                n: mh,
                f,
                heads: nh,
                inputs: act,
                weights: act,
                outputs: act,
                host_ops: vec![HostOp::Scale, HostOp::Softmax],
            });
            // S·V per head: F×F @ F×M_h.
            layers.push(LayerDesc {
                name: p("attn_sv"),
                kind: LayerKind::AttnSv,
                m: mh,
                n: f,
                f,
                heads: nh,
                inputs: act,
                weights: act,
                outputs: act,
                host_ops: vec![],
            });
            // Output projection. Its result enters the skip-add + LayerNorm,
            // so it is stored 16-bit (unquantized outputs, §5.2.1).
            layers.push(LayerDesc {
                name: p("proj"),
                kind: LayerKind::Fc,
                m,
                n: m,
                f,
                heads: nh,
                inputs: act,
                weights: wgt,
                outputs: Precision::Fixed16,
                host_ops: vec![HostOp::SkipAdd, HostOp::LayerNorm],
            });
            // MLP1 expands M → 4M; GELU on host; output re-quantized for MLP2.
            layers.push(LayerDesc {
                name: p("mlp1"),
                kind: LayerKind::Fc,
                m: mlp_hidden,
                n: m,
                f,
                heads: nh,
                inputs: act,
                weights: wgt,
                outputs: act,
                host_ops: vec![HostOp::Gelu],
            });
            // MLP2 reduces 4M → M; feeds skip-add + next LayerNorm ⇒ 16-bit out.
            layers.push(LayerDesc {
                name: p("mlp2"),
                kind: LayerKind::Fc,
                m,
                n: mlp_hidden,
                f,
                heads: nh,
                inputs: act,
                weights: wgt,
                outputs: Precision::Fixed16,
                host_ops: vec![HostOp::SkipAdd, HostOp::LayerNorm],
            });
        }

        // Classifier head on the CLS token (F = 1). Never quantized.
        layers.push(LayerDesc {
            name: "head".into(),
            kind: LayerKind::Fc,
            m: self.num_classes,
            n: m,
            f: 1,
            heads: nh,
            inputs: Precision::Fixed16,
            weights: Precision::Fixed16,
            outputs: Precision::Fixed16,
            host_ops: vec![],
        });

        VitStructure {
            config: self.clone(),
            act_bits,
            layers,
        }
    }
}

/// Patch-embed conv expressed as an FC layer (paper Fig. 4).
///
/// Kernel size = stride = patch size ⇒ each input element is used exactly
/// once as the kernel slides, so reshaping the input to
/// `N_p × (C·P²)` and the kernel to `(C·P²) × M` yields an exactly
/// equivalent matrix multiplication.
pub fn patch_embed_as_fc(cfg: &VitConfig) -> LayerDesc {
    LayerDesc {
        name: "patch_embed".into(),
        kind: LayerKind::PatchEmbed,
        m: cfg.embed_dim,
        n: cfg.in_chans * cfg.patch_size * cfg.patch_size,
        f: cfg.num_patches(),
        heads: cfg.num_heads,
        inputs: Precision::Fixed16,
        weights: Precision::Fixed16,
        outputs: Precision::Fixed16,
        host_ops: vec![HostOp::LayerNorm],
    }
}

/// A fully-expanded model: the accelerator's view of one ViT variant.
#[derive(Debug, Clone)]
pub struct VitStructure {
    pub config: VitConfig,
    /// Activation precision (None = unquantized baseline).
    pub act_bits: Option<u8>,
    pub layers: Vec<LayerDesc>,
}

impl VitStructure {
    /// Total MACs for one inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total operations (2·MACs) — the paper's GOPS accounting unit.
    pub fn total_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    /// Model size in bits given this quantization regime (Table 2 "Space
    /// Usage" column): binary weights cost 1 bit each; unquantized models
    /// cost 32 bits per parameter. The non-binarized parameters (patch
    /// embed, head, LayerNorm, biases, embeddings) are counted at full
    /// precision in both regimes.
    pub fn space_usage_bits(&self) -> u64 {
        let total = self.config.param_count();
        match self.act_bits {
            None => total * 32,
            Some(_) => {
                // Binarized: the encoder linear weights (QKV, proj, MLP).
                let m = self.config.embed_dim as u64;
                let hidden = (self.config.embed_dim * self.config.mlp_ratio) as u64;
                let per_layer = 3 * m * m + m * m + m * hidden + hidden * m;
                let binarized = self.config.depth as u64 * per_layer;
                let rest = total - binarized;
                binarized + rest * 32
            }
        }
    }

    /// Layers that take the quantized datapath.
    pub fn quantized_layers(&self) -> impl Iterator<Item = &LayerDesc> {
        self.layers.iter().filter(|l| l.alpha())
    }
}
