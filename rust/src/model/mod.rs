//! ViT model structure descriptions (paper §4.1, Figs. 2 & 4).
//!
//! The accelerator sees a ViT as a *sequence of matrix-multiply layers*
//! interleaved with cheap host-side ops (LayerNorm, softmax, GELU, scaling,
//! skip-additions — paper §5.2 runs these on the host CPU of the FPGA).
//! This module turns a [`VitConfig`] into that sequence: one
//! [`LayerDesc`] per matmul with the `(M, N, F, heads)` dimensions the
//! performance model (Eqs. 7–12) and the simulator consume.

mod layers;
mod presets;
mod vit;

pub use layers::{HostOp, LayerDesc, LayerKind, Precision};
pub use presets::{deit_base, deit_small, deit_tiny, micro, VitPreset};
pub use vit::{patch_embed_as_fc, VitConfig, VitStructure};

#[cfg(test)]
mod tests;
