//! Named model presets (paper §6.1/§6.2.2: DeiT-tiny/small/base without the
//! distillation token, 224×224 inputs, ImageNet-1K head).

use super::vit::VitConfig;

/// A named, ready-made ViT configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VitPreset {
    DeiTTiny,
    DeiTSmall,
    DeiTBase,
}

impl VitPreset {
    pub fn config(self) -> VitConfig {
        match self {
            VitPreset::DeiTTiny => deit_tiny(),
            VitPreset::DeiTSmall => deit_small(),
            VitPreset::DeiTBase => deit_base(),
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "deit-tiny" | "tiny" => Some(VitPreset::DeiTTiny),
            "deit-small" | "small" => Some(VitPreset::DeiTSmall),
            "deit-base" | "base" => Some(VitPreset::DeiTBase),
            _ => None,
        }
    }

    pub fn all() -> [VitPreset; 3] {
        [VitPreset::DeiTTiny, VitPreset::DeiTSmall, VitPreset::DeiTBase]
    }
}

/// DeiT-tiny: M=192, L=12, N_h=3 (~5M params).
pub fn deit_tiny() -> VitConfig {
    VitConfig {
        name: "deit-tiny".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 192,
        depth: 12,
        num_heads: 3,
        mlp_ratio: 4,
        num_classes: 1000,
    }
}

/// DeiT-small: M=384, L=12, N_h=6 (~22M params).
pub fn deit_small() -> VitConfig {
    VitConfig {
        name: "deit-small".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 384,
        depth: 12,
        num_heads: 6,
        mlp_ratio: 4,
        num_classes: 1000,
    }
}

/// DeiT-base: M=768, L=12, N_h=12 (~86M params) — the paper's default.
pub fn deit_base() -> VitConfig {
    VitConfig {
        name: "deit-base".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 768,
        depth: 12,
        num_heads: 12,
        mlp_ratio: 4,
        num_classes: 1000,
    }
}
