//! Named model presets (paper §6.1/§6.2.2: DeiT-tiny/small/base without the
//! distillation token, 224×224 inputs, ImageNet-1K head).

use super::vit::VitConfig;

/// A named, ready-made ViT configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VitPreset {
    DeiTTiny,
    DeiTSmall,
    DeiTBase,
    /// The tiny in-repo test model (32×32 inputs, 2 layers) used by the
    /// functional simulator, the AOT artifacts and the serving demos.
    Micro,
}

impl VitPreset {
    /// Preset-name hint for error messages (keep in sync with
    /// [`VitPreset::from_name`]).
    pub const NAMES: &'static str = "deit-tiny/small/base/micro";

    pub fn config(self) -> VitConfig {
        match self {
            VitPreset::DeiTTiny => deit_tiny(),
            VitPreset::DeiTSmall => deit_small(),
            VitPreset::DeiTBase => deit_base(),
            VitPreset::Micro => micro(),
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "deit-tiny" | "tiny" => Some(VitPreset::DeiTTiny),
            "deit-small" | "small" => Some(VitPreset::DeiTSmall),
            "deit-base" | "base" => Some(VitPreset::DeiTBase),
            "micro" | "deit-micro" => Some(VitPreset::Micro),
            _ => None,
        }
    }

    /// The paper's DeiT family — the sweep set for tables and exploration.
    /// `Micro` is addressable by name but deliberately excluded (it is a
    /// test model, not a paper workload).
    pub fn all() -> [VitPreset; 3] {
        [VitPreset::DeiTTiny, VitPreset::DeiTSmall, VitPreset::DeiTBase]
    }
}

/// DeiT-tiny: M=192, L=12, N_h=3 (~5M params).
pub fn deit_tiny() -> VitConfig {
    VitConfig {
        name: "deit-tiny".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 192,
        depth: 12,
        num_heads: 3,
        mlp_ratio: 4,
        num_classes: 1000,
    }
}

/// DeiT-small: M=384, L=12, N_h=6 (~22M params).
pub fn deit_small() -> VitConfig {
    VitConfig {
        name: "deit-small".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 384,
        depth: 12,
        num_heads: 6,
        mlp_ratio: 4,
        num_classes: 1000,
    }
}

/// Micro: M=32, L=2, N_h=4 on 32×32 inputs — the in-repo test model whose
/// AOT artifacts (`make artifacts`) and simulator runs are fast enough for
/// CI. Dimensions must match `python/compile`'s micro variant.
pub fn micro() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 2,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

/// DeiT-base: M=768, L=12, N_h=12 (~86M params) — the paper's default.
pub fn deit_base() -> VitConfig {
    VitConfig {
        name: "deit-base".into(),
        image_size: 224,
        patch_size: 16,
        in_chans: 3,
        embed_dim: 768,
        depth: 12,
        num_heads: 12,
        mlp_ratio: 4,
        num_classes: 1000,
    }
}
