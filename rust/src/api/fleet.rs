//! Fleet facade.
//!
//! [`CompiledDesign::fleet`] — a builder over the fleet simulator:
//! `design.fleet().boards(4).topology("mixed").balancer("sla-weighted")
//! .trace(TraceSpec::flash_crowd(...)).run()` carves a board budget into
//! serving units (replicas and/or shard pipelines), fronts them with a
//! load balancer, replays a trace through them on one virtual clock and
//! returns a [`FleetReport`]. [`Session::compile_fleet`] is the one-call
//! shortcut (compile, then fleet-builder with defaults).

use std::path::PathBuf;

use crate::coordinator::VirtualClock;
use crate::fault::FaultPlan;
use crate::fleet::{
    balancer_for, simulate_fleet_traced, FleetConfig, FleetReport, FleetTopology, ServingUnit,
    StageSpec, TraceSource, TraceSpec, UnitKind, BALANCER_NAMES, TOPOLOGY_PRESETS,
};
use crate::obs::{MetricsRegistry, Trace, TraceConfig, TraceSink};
use crate::shard::ShardPolicy;

use super::error::{Result, VaqfError};
use super::session::{CompiledDesign, Session};

/// Builder for a trace-driven fleet run over a compiled design.
/// Constructed by [`CompiledDesign::fleet`]; defaults to 4 boards,
/// `replicated` topology, `round-robin` balancing and a Poisson trace
/// offering 80% of the fleet's aggregate throughput for one second.
#[derive(Debug, Clone)]
pub struct FleetBuilder {
    design: CompiledDesign,
    boards: usize,
    preset: String,
    layout: Option<FleetTopology>,
    balancer: String,
    trace: Option<TraceSpec>,
    streams: usize,
    queue_depth: usize,
    sla_ms: Option<f64>,
    source_seed: u64,
    faults: Option<FaultPlan>,
    shard_policy: ShardPolicy,
    trace_out: Option<PathBuf>,
    metrics_out: Option<PathBuf>,
    trace_cfg: TraceConfig,
}

impl CompiledDesign {
    /// Configure a fleet run of this design; finish with
    /// [`FleetBuilder::run`].
    pub fn fleet(&self) -> FleetBuilder {
        FleetBuilder {
            design: self.clone(),
            boards: 4,
            preset: "replicated".to_string(),
            layout: None,
            balancer: "round-robin".to_string(),
            trace: None,
            streams: 1,
            queue_depth: 2,
            sla_ms: None,
            source_seed: 11,
            faults: None,
            shard_policy: ShardPolicy::Balanced,
            trace_out: None,
            metrics_out: None,
            trace_cfg: TraceConfig::default(),
        }
    }
}

impl Session {
    /// Compile this session's design and hand back a fleet builder over
    /// it — the one-call path from a target spec to a fleet run.
    pub fn compile_fleet(&self) -> Result<FleetBuilder> {
        Ok(self.compile()?.fleet())
    }
}

impl FleetBuilder {
    /// Total board budget the topology preset carves up (ignored when an
    /// explicit [`FleetBuilder::layout`] is set).
    pub fn boards(mut self, n: usize) -> Self {
        self.boards = n;
        self
    }

    /// Topology preset by name: `replicated`, `pipelined`, `mixed`
    /// (validated at [`FleetBuilder::run`]).
    pub fn topology(mut self, name: &str) -> Self {
        self.preset = name.to_string();
        self
    }

    /// Explicit unit-by-unit topology; overrides
    /// [`FleetBuilder::topology`] and [`FleetBuilder::boards`].
    pub fn layout(mut self, topology: FleetTopology) -> Self {
        self.layout = Some(topology);
        self
    }

    /// Balancer policy by name: `round-robin`, `least-outstanding`,
    /// `join-shortest-queue`, `sla-weighted` (validated at
    /// [`FleetBuilder::run`]).
    pub fn balancer(mut self, name: &str) -> Self {
        self.balancer = name.to_string();
        self
    }

    /// Arrival trace (recorded timestamps or a seeded generator).
    /// Default: Poisson at 80% of the fleet's aggregate single-board
    /// throughput for 1 s.
    pub fn trace(mut self, spec: TraceSpec) -> Self {
        self.trace = Some(spec);
        self
    }

    /// Number of logical streams arrivals are assigned to (round-robin).
    pub fn streams(mut self, n: usize) -> Self {
        self.streams = n;
        self
    }

    /// Admission-queue depth per serving unit.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    /// End-to-end latency SLA in milliseconds.
    pub fn sla_ms(mut self, ms: f64) -> Self {
        self.sla_ms = Some(ms);
        self
    }

    /// Seed for the per-stream frame sources.
    pub fn seed(mut self, seed: u64) -> Self {
        self.source_seed = seed;
        self
    }

    /// Inject a deterministic fault plan; event `unit` indices address
    /// serving units in topology order.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Partition policy used when a pipeline unit shards the design.
    pub fn shard_policy(mut self, policy: ShardPolicy) -> Self {
        self.shard_policy = policy;
        self
    }

    /// Write a Chrome/Perfetto `trace_event` JSON of the run to `path`:
    /// one track per stream, per serving unit (and per pipeline stage),
    /// replica service spans nesting into the per-layer breakdown.
    /// (`.trace(..)` is the arrival-trace knob, hence the `_out` name.)
    pub fn trace_out(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace_out = Some(path.into());
        self
    }

    /// Buffering and layer-detail sampling controls for
    /// [`FleetBuilder::trace_out`] / [`FleetBuilder::run_traced`].
    pub fn trace_config(mut self, cfg: TraceConfig) -> Self {
        self.trace_cfg = cfg;
        self
    }

    /// Write a JSON metrics snapshot (counters, gauges, latency
    /// histograms from the final report) to `path`.
    pub fn metrics_json(mut self, path: impl Into<PathBuf>) -> Self {
        self.metrics_out = Some(path.into());
        self
    }

    /// Execute the run; returns the deterministic fleet report. Writes
    /// the artifacts requested with [`FleetBuilder::trace_out`] /
    /// [`FleetBuilder::metrics_json`].
    pub fn run(mut self) -> Result<FleetReport> {
        let trace_out = self.trace_out.take();
        let metrics_out = self.metrics_out.take();
        let (report, trace) = if trace_out.is_some() {
            let (report, trace) = self.run_traced()?;
            (report, Some(trace))
        } else {
            (self.launch(None)?, None)
        };
        if let (Some(path), Some(trace)) = (&trace_out, &trace) {
            trace.save_perfetto(path).map_err(VaqfError::runtime)?;
        }
        if let Some(path) = &metrics_out {
            let mut reg = MetricsRegistry::new();
            reg.publish_fleet(&report);
            std::fs::write(path, reg.to_json().pretty())
                .map_err(|e| VaqfError::io(path.display().to_string(), e))?;
        }
        Ok(report)
    }

    /// [`FleetBuilder::run`], also returning the collected [`Trace`].
    /// The fleet simulator is always virtual-clocked, so every
    /// configuration traces deterministically.
    pub fn run_traced(mut self) -> Result<(FleetReport, Trace)> {
        // Artifact paths are run()'s concern; a direct run_traced()
        // caller gets the Trace and writes what it wants.
        self.trace_out = None;
        self.metrics_out = None;
        let mut sink =
            TraceSink::with_config(self.design.target().device.clock_mhz, self.trace_cfg);
        sink.set_layer_template(self.design.layer_template());
        let report = self.launch(Some(&mut sink))?;
        Ok((report, sink.finish()))
    }

    /// Validate the configuration and run the simulator, recording into
    /// `sink` when given.
    fn launch(self, sink: Option<&mut TraceSink>) -> Result<FleetReport> {
        if self.streams == 0 {
            return Err(VaqfError::config("fleet needs at least 1 stream"));
        }
        if self.queue_depth == 0 {
            return Err(VaqfError::config("queue_depth must be at least 1"));
        }
        let topology = match &self.layout {
            Some(t) => {
                if t.is_empty() {
                    return Err(VaqfError::config(
                        "explicit fleet layout must have at least one unit",
                    ));
                }
                t.clone()
            }
            None => {
                if self.boards == 0 {
                    return Err(VaqfError::config("fleet needs at least 1 board"));
                }
                FleetTopology::preset(&self.preset, self.boards).ok_or_else(|| {
                    VaqfError::config(format!(
                        "unknown fleet topology `{}` (expected one of: {})",
                        self.preset,
                        TOPOLOGY_PRESETS.join(", ")
                    ))
                })?
            }
        };
        let balancer = balancer_for(&self.balancer).ok_or_else(|| {
            VaqfError::config(format!(
                "unknown balancer policy `{}` (expected one of: {})",
                self.balancer,
                BALANCER_NAMES.join(", ")
            ))
        })?;

        let clock_mhz = self.design.target().device.clock_mhz;
        let clock = VirtualClock::new(clock_mhz);
        let frame_latency_s = self.design.frame_latency_s();

        let spec = self.trace.clone().unwrap_or_else(|| {
            // Offer 80% of what `boards` independent replicas of this
            // design could serve: loaded but not saturated.
            let fleet_fps = topology.boards() as f64 / frame_latency_s;
            TraceSpec::poisson(0.8 * fleet_fps, 1.0, self.source_seed)
        });
        let source = TraceSource::from_spec(spec)
            .map_err(|e| VaqfError::config(format!("invalid trace: {e}")))?;

        let mut units: Vec<ServingUnit> = Vec::with_capacity(topology.len());
        for kind in &topology.units {
            match kind {
                UnitKind::Replica => units.push(ServingUnit::replica(
                    clock.seconds_to_cycles(frame_latency_s).max(1),
                    self.queue_depth,
                )),
                UnitKind::Pipeline { depth } => {
                    let sharded = self.design.shards_with(*depth, self.shard_policy)?;
                    let stages: Vec<StageSpec> = sharded
                        .stages
                        .iter()
                        .enumerate()
                        .map(|(i, st)| StageSpec {
                            service_cycles: st.service_cycles().max(1),
                            capacity: if i == 0 {
                                self.queue_depth
                            } else {
                                (st.fifo.frames as usize).max(1)
                            },
                        })
                        .collect();
                    units.push(ServingUnit::pipeline(*depth, stages));
                }
            }
        }

        let cfg = FleetConfig {
            backend: format!("analytic:{}", self.design.summary().label),
            topology: topology.label(),
            streams: self.streams,
            sla_ms: self.sla_ms,
            source_seed: self.source_seed,
        };
        simulate_fleet_traced(
            &self.design.target().model,
            clock_mhz,
            &units,
            &source,
            balancer,
            &cfg,
            self.faults.as_ref(),
            sink,
        )
        .map_err(VaqfError::runtime)
    }
}
