//! The library-boundary error type.
//!
//! Everything `vaqf::api` returns fails with [`VaqfError`], so embedders can
//! match on *what* went wrong (unknown preset, infeasible target, broken
//! config, …) instead of parsing message strings. Lower layers of the crate
//! keep using `anyhow` internally; the facade converts at the boundary and
//! preserves the original message text verbatim (the CLI prints these, so
//! they stay what the pre-facade binary printed).

use std::fmt;

/// Boundary result type for the [`crate::api`] facade.
pub type Result<T> = std::result::Result<T, VaqfError>;

/// Why a facade call failed.
///
/// Marked `#[non_exhaustive]`: new failure classes may be added without a
/// breaking change, so downstream matches need a wildcard arm.
#[derive(Debug)]
#[non_exhaustive]
pub enum VaqfError {
    /// A model / device / kernel-backend name did not resolve to a preset.
    UnknownPreset {
        /// What kind of name failed to resolve: `"model"`, `"device"` or
        /// `"kernel backend"`.
        kind: &'static str,
        name: String,
    },
    /// The §3 infeasibility case: `FR_tgt > FR_max` — no activation
    /// precision can satisfy the requested frame rate on this device.
    Infeasible {
        model: String,
        device: String,
        target_fps: f64,
        fr_max: f64,
    },
    /// A config document, CLI flag or environment variable failed to parse.
    Config { message: String },
    /// Filesystem failure (config files, codegen artifacts).
    Io {
        context: String,
        source: std::io::Error,
    },
    /// The artifacts manifest is missing or malformed.
    Manifest { message: String },
    /// The design-space optimizer found no feasible accelerator at a
    /// requested precision (distinct from [`VaqfError::Infeasible`], which
    /// is about the frame-rate target).
    Search { message: String },
    /// A runtime or serving failure (PJRT engine, serving loop).
    Runtime { message: String },
}

impl VaqfError {
    /// Unknown model preset name.
    pub fn unknown_model(name: impl Into<String>) -> VaqfError {
        VaqfError::UnknownPreset {
            kind: "model",
            name: name.into(),
        }
    }

    /// Unknown device preset name.
    pub fn unknown_device(name: impl Into<String>) -> VaqfError {
        VaqfError::UnknownPreset {
            kind: "device",
            name: name.into(),
        }
    }

    /// Unknown simulator kernel backend name.
    pub fn unknown_backend(name: impl Into<String>) -> VaqfError {
        VaqfError::UnknownPreset {
            kind: "kernel backend",
            name: name.into(),
        }
    }

    /// Configuration / flag / env-var parse failure.
    pub fn config(message: impl Into<String>) -> VaqfError {
        VaqfError::Config {
            message: message.into(),
        }
    }

    /// Filesystem failure with the path (or operation) as context.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> VaqfError {
        VaqfError::Io {
            context: context.into(),
            source,
        }
    }

    /// Wrap a lower-layer manifest error, keeping its message.
    pub fn manifest(error: anyhow::Error) -> VaqfError {
        VaqfError::Manifest {
            message: error.to_string(),
        }
    }

    /// Wrap a lower-layer design-search error, keeping its message.
    pub fn search(error: anyhow::Error) -> VaqfError {
        VaqfError::Search {
            message: error.to_string(),
        }
    }

    /// Wrap a lower-layer runtime/serving error, keeping its message.
    pub fn runtime(error: anyhow::Error) -> VaqfError {
        VaqfError::Runtime {
            message: error.to_string(),
        }
    }
}

impl fmt::Display for VaqfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VaqfError::UnknownPreset { kind, name } => {
                let known = match *kind {
                    "model" => crate::model::VitPreset::NAMES,
                    "device" => crate::hw::DevicePreset::NAMES,
                    _ => crate::sim::Backend::NAMES,
                };
                write!(f, "unknown {kind} `{name}` ({known})")
            }
            VaqfError::Infeasible { model, device, target_fps, fr_max } => write!(
                f,
                "target {target_fps:.1} FPS exceeds FR_max = {fr_max:.1} FPS for {model} on \
                 {device} — no activation precision can satisfy it"
            ),
            VaqfError::Config { message }
            | VaqfError::Manifest { message }
            | VaqfError::Search { message }
            | VaqfError::Runtime { message } => f.write_str(message),
            VaqfError::Io { context, source } => write!(f, "{context}: {source}"),
        }
    }
}

impl std::error::Error for VaqfError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            VaqfError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_messages() {
        assert_eq!(
            VaqfError::unknown_model("resnet").to_string(),
            "unknown model `resnet` (deit-tiny/small/base/micro)"
        );
        assert_eq!(
            VaqfError::unknown_device("virtex").to_string(),
            "unknown device `virtex` (zcu102/zcu111/generic-edge)"
        );
        assert_eq!(
            VaqfError::unknown_backend("simd").to_string(),
            "unknown kernel backend `simd` (scalar|packed)"
        );
        let inf = VaqfError::Infeasible {
            model: "deit-base".into(),
            device: "generic-edge".into(),
            target_fps: 60.0,
            fr_max: 12.3,
        };
        assert_eq!(
            inf.to_string(),
            "target 60.0 FPS exceeds FR_max = 12.3 FPS for deit-base on generic-edge — \
             no activation precision can satisfy it"
        );
    }

    #[test]
    fn search_wrapper_preserves_message() {
        let e = VaqfError::search(anyhow::anyhow!("no feasible design"));
        assert_eq!(e.to_string(), "no feasible design");
    }
}
