//! The compile session: a resolved [`Target`] plus every operation the
//! co-design pipeline hangs off it.
//!
//! ```text
//! Session::compile()           FR_tgt-driven precision search  → CompiledDesign
//! Session::compile_for_bits()  fixed-precision optimization    → CompiledDesign
//! Session::sweep()             the `vaqf search` table
//! Session::table5()            the `vaqf report` rows
//!
//! CompiledDesign::codegen()    HLS C++ + simulator JSON on disk
//! CompiledDesign::simulator()  a wired cycle-level ModelExecutor
//! CompiledDesign::server()     serving builder — streams × workers ×
//!                              dispatch policy over a wall or virtual
//!                              clock (api::serve)
//! ```

use std::cell::OnceCell;
use std::sync::Arc;

use crate::compiler::{self, CompileOutcome, CompileRequest, DesignPoint, SearchCtx};
use crate::config::Target;
use crate::perf::{summarize, AcceleratorParams, PerfSummary};
use crate::shard::{ShardPolicy, ShardedDesign};
use crate::sim::{generate_weights, ModelExecutor};
use crate::util::json::Json;

use super::error::{Result, VaqfError};

/// A resolved co-design session over one `(model, device, target)` triple.
#[derive(Debug, Clone)]
pub struct Session {
    target: Target,
    /// The baseline design-space search is pure in (model, device), so one
    /// session computes it at most once across compile/sweep/probe calls.
    baseline: OnceCell<AcceleratorParams>,
    /// The incremental design-space-search context: every search this
    /// session (or a design/shard derived from it) runs shares these memo
    /// tables, so repeated and overlapping searches — precision sweeps,
    /// co-search stages, live repartitions — re-optimize warm. Cloned
    /// sessions share the same context.
    ctx: Arc<SearchCtx>,
}

impl Session {
    pub fn new(target: Target) -> Session {
        Session {
            target,
            baseline: OnceCell::new(),
            ctx: Arc::new(SearchCtx::new()),
        }
    }

    /// The resolved target this session compiles for.
    pub fn target(&self) -> &Target {
        &self.target
    }

    /// This session's shared design-space-search context (memo stats,
    /// thread budget) — hand it to `compiler::*_with_ctx` entry points to
    /// keep external searches warm too.
    pub fn search_ctx(&self) -> &Arc<SearchCtx> {
        &self.ctx
    }

    /// A snapshot of the session's cumulative design-space-search
    /// counters: point evaluations vs memo hits, pruned parallelism
    /// planes, deduplicated layer classes. Grows monotonically across
    /// every compile/sweep/shard call on this session.
    pub fn search_stats(&self) -> crate::compiler::SearchStats {
        self.ctx.stats()
    }

    fn baseline_params(&self) -> AcceleratorParams {
        *self.baseline.get_or_init(|| {
            self.ctx
                .optimize_baseline(&self.target.model.structure(None), &self.target.device)
        })
    }

    /// The full VAQF compilation step (paper §3): feasibility against
    /// `FR_max`, then the ≤4-round binary search for the highest activation
    /// precision meeting the session's frame-rate target.
    pub fn compile(&self) -> Result<CompiledDesign> {
        self.compile_at(self.target.target_fps)
    }

    /// [`Session::compile`] at an explicit frame-rate target, reusing this
    /// session's cached baseline — for callers sweeping a ladder of
    /// targets over one (model, device) pair.
    pub fn compile_at(&self, target_fps: f64) -> Result<CompiledDesign> {
        let mut target = self.target.clone();
        target.target_fps = target_fps;
        let req = CompileRequest {
            model: target.model.clone(),
            device: target.device.clone(),
            target_fps,
        };
        // `compile_seconds` reports the whole compilation step, so the
        // baseline search is timed too — at its true cost: full on the
        // session's first compile, ~0 once cached.
        let t0 = std::time::Instant::now();
        let baseline = self.baseline_params();
        let baseline_seconds = t0.elapsed().as_secs_f64();
        match compiler::compile_with_baseline_ctx(&req, baseline, &self.ctx) {
            Ok(mut outcome) => {
                outcome.compile_seconds += baseline_seconds;
                Ok(CompiledDesign::from_outcome(&target, outcome, self.ctx.clone()))
            }
            Err(e) => Err(self.classify_compile_error(target_fps, e)),
        }
    }

    /// Distinguish the §3 infeasibility case (`FR_tgt > FR_max`) from
    /// design-space failures, so callers can match
    /// [`VaqfError::Infeasible`] instead of parsing message strings. Runs
    /// only on the error path, so the success path pays no extra probes.
    fn classify_compile_error(&self, target_fps: f64, e: anyhow::Error) -> VaqfError {
        let baseline = self.baseline_params();
        let s1 = self.target.model.structure(Some(1));
        if let Ok(d1) = self.ctx.optimize_for_bits(&s1, &baseline, &self.target.device, 1) {
            if target_fps > d1.summary.fps {
                return VaqfError::Infeasible {
                    model: self.target.model.name.clone(),
                    device: self.target.device.name.clone(),
                    target_fps,
                    fr_max: d1.summary.fps,
                };
            }
        }
        VaqfError::search(e)
    }

    /// Optimize at a fixed activation precision, skipping the frame-rate
    /// search (`None` ⇒ the unquantized W16A16 baseline accelerator). This
    /// is how `simulate`/`serve` wire the simulator with a *compiled*
    /// parameterization instead of hardcoded tiles.
    pub fn compile_for_bits(&self, act_bits: Option<u8>) -> Result<CompiledDesign> {
        let baseline = self.baseline_params();
        let design = match act_bits {
            None => DesignPoint {
                params: baseline,
                summary: summarize(
                    &self.target.model.structure(None),
                    &baseline,
                    &self.target.device,
                ),
                adjustments: 0,
            },
            Some(bits) => {
                let s = self.target.model.structure(Some(bits));
                self.ctx
                    .optimize_for_bits(&s, &baseline, &self.target.device, bits)
                    .map_err(VaqfError::search)?
            }
        };
        Ok(CompiledDesign {
            target: self.target.clone(),
            act_bits,
            design,
            baseline,
            outcome: None,
            ctx: self.ctx.clone(),
        })
    }

    /// Compile each precision in `bits` and return `(label, frame
    /// latency seconds)` rungs for a graceful-degradation ladder —
    /// feed the result to
    /// [`ServerBuilder::degrade_ladder`](super::ServerBuilder::degrade_ladder).
    /// Order is preserved; put the serving design's own precision first
    /// (rung 0) and coarser, faster precisions after it.
    pub fn precision_ladder(&self, bits: &[u8]) -> Result<Vec<(String, f64)>> {
        if bits.is_empty() {
            return Err(VaqfError::config(
                "precision ladder needs at least one precision",
            ));
        }
        bits.iter()
            .map(|&b| {
                let d = self.compile_for_bits(Some(b))?;
                Ok((d.summary().label.clone(), d.frame_latency_s()))
            })
            .collect()
    }

    /// Evaluate every precision in `bits` once (the `vaqf search` table):
    /// baseline summary plus one design — or a typed failure — per
    /// precision.
    pub fn sweep(&self, bits: std::ops::RangeInclusive<u8>) -> PrecisionSweep {
        let baseline = self.baseline_params();
        let unquant = self.target.model.structure(None);
        let baseline_summary = summarize(&unquant, &baseline, &self.target.device);
        let points = bits
            .map(|b| {
                let s = self.target.model.structure(Some(b));
                SweepPoint {
                    bits: b,
                    design: self
                        .ctx
                        .optimize_for_bits(&s, &baseline, &self.target.device, b)
                        .map_err(VaqfError::search),
                }
            })
            .collect();
        PrecisionSweep {
            baseline: baseline_summary,
            points,
        }
    }

    /// Compile for the session's frame-rate target, then partition the
    /// model across `n` pipeline stages with per-shard parameter
    /// co-search (balanced min-max partition; see
    /// [`Session::compile_sharded_with`] for other policies).
    pub fn compile_sharded(&self, n: usize) -> Result<ShardedDesign> {
        self.compile_sharded_with(n, ShardPolicy::Balanced)
    }

    /// [`Session::compile_sharded`] under an explicit partition policy.
    pub fn compile_sharded_with(&self, n: usize, policy: ShardPolicy) -> Result<ShardedDesign> {
        self.compile()?.shards_with(n, policy)
    }

    /// Paper Table 5 rows for this session's (model, device): the baseline
    /// design plus one design per requested precision. Unlike
    /// `compiler::table5_rows` (which expects the paper's board and
    /// panics otherwise), an infeasible precision on an arbitrary device
    /// surfaces as a matchable [`VaqfError::Search`].
    pub fn table5(&self, precisions: &[u8]) -> Result<Vec<PerfSummary>> {
        let baseline = self.baseline_params();
        compiler::table5_rows_with_baseline_ctx(
            &self.target.model,
            &self.target.device,
            &baseline,
            precisions,
            &self.ctx,
        )
        .map_err(VaqfError::search)
    }
}

/// The `vaqf search` sweep: baseline summary + per-precision outcomes.
/// (The baseline *parameters* are available as `baseline.params`.)
#[derive(Debug)]
pub struct PrecisionSweep {
    pub baseline: PerfSummary,
    pub points: Vec<SweepPoint>,
}

/// One precision's outcome in a [`PrecisionSweep`].
#[derive(Debug)]
pub struct SweepPoint {
    pub bits: u8,
    pub design: Result<DesignPoint>,
}

/// A compiled accelerator design: chosen precision, optimized parameters
/// and predicted performance, with codegen, the cycle-level simulator and
/// the serving loop hanging off it.
#[derive(Debug, Clone)]
pub struct CompiledDesign {
    target: Target,
    act_bits: Option<u8>,
    design: DesignPoint,
    baseline: AcceleratorParams,
    outcome: Option<CompileOutcome>,
    /// The session's search context, carried so sharding (and the live
    /// repartitions a sharded pipeline may run after board crashes)
    /// re-searches warm.
    ctx: Arc<SearchCtx>,
}

/// Files written by [`CompiledDesign::codegen`].
#[derive(Debug, Clone)]
pub struct CodegenArtifacts {
    /// `<dir>/<model>_<precision>` — the stem both files share.
    pub base: String,
    pub cpp_path: String,
    pub json_path: String,
}

impl CompiledDesign {
    fn from_outcome(
        target: &Target,
        outcome: CompileOutcome,
        ctx: Arc<SearchCtx>,
    ) -> CompiledDesign {
        CompiledDesign {
            target: target.clone(),
            act_bits: Some(outcome.act_bits),
            design: outcome.design.clone(),
            baseline: outcome.baseline,
            outcome: Some(outcome),
            ctx,
        }
    }

    pub fn target(&self) -> &Target {
        &self.target
    }

    /// Chosen activation precision (`None` = unquantized baseline design).
    pub fn act_bits(&self) -> Option<u8> {
        self.act_bits
    }

    pub fn params(&self) -> &AcceleratorParams {
        &self.design.params
    }

    pub fn summary(&self) -> &PerfSummary {
        &self.design.summary
    }

    pub fn design_point(&self) -> &DesignPoint {
        &self.design
    }

    /// The search record — `Some` when this design came from
    /// [`Session::compile`], `None` from [`Session::compile_for_bits`].
    pub fn outcome(&self) -> Option<&CompileOutcome> {
        self.outcome.as_ref()
    }

    /// The outcome to feed the emitters: the real search record, or a
    /// synthesized one for fixed-precision designs (no search rounds, the
    /// design's own rate as both target and `FR_max`).
    fn outcome_view(&self) -> CompileOutcome {
        match &self.outcome {
            Some(o) => o.clone(),
            None => CompileOutcome {
                act_bits: self.act_bits.unwrap_or(16),
                design: self.design.clone(),
                baseline: self.baseline,
                fr_max: self.design.summary.fps,
                target_fps: self.design.summary.fps,
                rounds: Vec::new(),
                compile_seconds: 0.0,
            },
        }
    }

    /// The Vivado-HLS-style C++ accelerator description.
    pub fn hls_source(&self) -> String {
        let structure = self.target.model.structure(self.act_bits);
        compiler::emit_hls_cpp(&self.outcome_view(), &structure, &self.target.device)
    }

    /// The JSON accelerator config the simulator consumes
    /// (round-trippable via `compiler::params_from_json`).
    pub fn config_json(&self) -> Json {
        compiler::emit_config_json(&self.outcome_view(), &self.target.device)
    }

    /// Write both codegen artifacts (`.cpp` + `.json`) into `dir`,
    /// creating it if needed.
    pub fn codegen(&self, dir: impl AsRef<std::path::Path>) -> Result<CodegenArtifacts> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).map_err(|e| VaqfError::io(dir.display().to_string(), e))?;
        let tag = match self.act_bits {
            Some(b) => format!("w1a{b}"),
            None => "w16a16".to_string(),
        };
        let base = format!("{}/{}_{tag}", dir.display(), self.target.model.name);
        let cpp_path = format!("{base}.cpp");
        let json_path = format!("{base}.json");
        std::fs::write(&cpp_path, self.hls_source())
            .map_err(|e| VaqfError::io(cpp_path.clone(), e))?;
        std::fs::write(&json_path, self.config_json().pretty())
            .map_err(|e| VaqfError::io(json_path.clone(), e))?;
        Ok(CodegenArtifacts {
            base,
            cpp_path,
            json_path,
        })
    }

    /// A functional cycle-level simulator of this design — a
    /// [`ModelExecutor`] wired with the *compiled* parameters plus the
    /// target's kernel backend and thread fan-out. Weights are generated
    /// deterministically from `seed`. The executor performs its one-time
    /// per-model preparation (packed weight layout + cycle accounting)
    /// lazily before the first frame, then streams frames through its
    /// reusable workspace (`run_frame` / `run_batch`) without re-doing
    /// any of it.
    pub fn simulator_with_seed(&self, seed: u64) -> ModelExecutor {
        let weights = generate_weights(&self.target.model, seed);
        let device = self.target.device.clone();
        ModelExecutor::new(weights, self.act_bits, self.design.params, device)
            .with_backend(self.target.backend)
            .with_threads(self.target.threads)
    }

    /// [`CompiledDesign::simulator_with_seed`] with the crate's
    /// conventional demo seed (11).
    pub fn simulator(&self) -> ModelExecutor {
        self.simulator_with_seed(11)
    }

    /// Predicted per-frame service latency (seconds) of this design —
    /// the analytical `perf::cycles` total at the device clock. This is
    /// what analytic serving workers charge per frame.
    pub fn frame_latency_s(&self) -> f64 {
        1.0 / self.design.summary.fps
    }

    /// The analytic per-layer cycle breakdown `(layer name, cycles)` of
    /// one frame through this design, in execution order — the template
    /// trace sinks nest service spans into
    /// ([`TraceSink::set_layer_template`](crate::obs::TraceSink::set_layer_template)).
    pub fn layer_template(&self) -> Vec<(String, u64)> {
        let structure = self.target.model.structure(self.act_bits);
        let (_, per_layer) =
            crate::perf::model_cycles(&structure, &self.design.params, &self.target.device);
        structure
            .layers
            .iter()
            .zip(per_layer)
            .map(|(l, c)| (l.name.clone(), c.total + c.host))
            .collect()
    }

    /// Cumulative design-space-search statistics of the session context
    /// this design came from (memo hits, evaluations, pruned planes,
    /// dedup classes) — see [`Session::search_stats`].
    pub fn search_stats(&self) -> crate::compiler::SearchStats {
        self.ctx.stats()
    }

    /// Partition this design's model across `n` pipeline stages
    /// (balanced min-max) and co-search each stage's accelerator
    /// parameters under the per-shard budget. The returned
    /// [`ShardedDesign`] carries one `AcceleratorParams` + analytic
    /// summary per stage, sized inter-stage FIFOs, the steady-state
    /// throughput bound, and hangs the discrete-event pipeline
    /// simulation (`.simulate_pipeline(frames)` / `.report(frames)`) and
    /// the functional stage-by-stage executor off it.
    pub fn shards(&self, n: usize) -> Result<ShardedDesign> {
        self.shards_with(n, ShardPolicy::Balanced)
    }

    /// [`CompiledDesign::shards`] under an explicit partition policy.
    pub fn shards_with(&self, n: usize, policy: ShardPolicy) -> Result<ShardedDesign> {
        crate::shard::co_search_with_ctx(
            &self.target.model,
            &self.target.device,
            self.act_bits,
            &self.design,
            n,
            policy,
            self.ctx.clone(),
        )
        .map_err(VaqfError::search)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::TargetSpec;

    fn micro_session() -> Session {
        TargetSpec::new()
            .model(crate::model::micro())
            .device_preset("zcu102")
            .target_fps(100.0)
            .session()
            .unwrap()
    }

    #[test]
    fn compile_for_bits_matches_requested_precision() {
        let session = micro_session();
        let d8 = session.compile_for_bits(Some(8)).unwrap();
        assert_eq!(d8.act_bits(), Some(8));
        assert_eq!(d8.params().act_bits, Some(8));
        let base = session.compile_for_bits(None).unwrap();
        assert_eq!(base.act_bits(), None);
        assert_eq!(base.params().act_bits, None);
        assert!(base.outcome().is_none());
    }

    #[test]
    fn fixed_precision_designs_still_emit_artifacts() {
        let session = micro_session();
        let d8 = session.compile_for_bits(Some(8)).unwrap();
        let cpp = d8.hls_source();
        assert!(cpp.contains("compute_engine"));
        let json = d8.config_json();
        let params = compiler::params_from_json(&json).unwrap();
        assert_eq!(&params, d8.params());
    }

    #[test]
    fn simulator_is_wired_with_compiled_params() {
        let session = micro_session();
        let d8 = session.compile_for_bits(Some(8)).unwrap();
        let exec = d8.simulator_with_seed(3);
        assert_eq!(exec.engine.params, d8.design.params);
        assert_eq!(exec.device().name, "zcu102");
    }

    #[test]
    fn sweep_reports_every_precision() {
        let sweep = micro_session().sweep(1..=4);
        assert_eq!(sweep.points.len(), 4);
        assert_eq!(sweep.baseline.label, "W32A32");
        for p in &sweep.points {
            if let Ok(d) = &p.design {
                assert_eq!(d.params.act_bits, Some(p.bits));
            }
        }
    }
}
