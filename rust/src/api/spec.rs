//! Layered target resolution — the single place where "which model, which
//! device, which knobs" is decided.
//!
//! Precedence, lowest to highest:
//!
//! 1. **defaults** — deit-base on zcu102 @ 24 FPS, packed kernels,
//!    environment thread fan-out (overridable per-spec via
//!    [`TargetSpec::default_model`], e.g. `vaqf simulate` falls back to the
//!    micro model);
//! 2. **config file** — a `config::Target` JSON document (only the fields
//!    the document actually sets participate);
//! 3. **environment** — `VAQF_MODEL`, `VAQF_DEVICE`, `VAQF_TARGET_FPS`,
//!    `VAQF_BACKEND`, `VAQF_THREADS`;
//! 4. **explicit setters** — builder methods / CLI flags.
//!
//! Resolution is a pure function of the spec and an environment lookup
//! ([`TargetSpec::resolve_with`]), so the precedence rules are directly
//! testable without mutating process-global state.

use std::path::Path;

use crate::config::{self, Target};
use crate::hw::{Device, DevicePreset};
use crate::model::{VitConfig, VitPreset};
use crate::sim::Backend;
use crate::util::cli::Args;
use crate::util::json::Json;

use super::error::{Result, VaqfError};
use super::session::Session;

/// Model selection: a preset name (resolved at [`TargetSpec::resolve`]
/// time, so typos surface as [`VaqfError::UnknownPreset`]) or a concrete
/// configuration.
#[derive(Debug, Clone)]
enum ModelSel {
    Preset(String),
    Config(VitConfig),
}

#[derive(Debug, Clone)]
enum DeviceSel {
    Preset(String),
    Device(Device),
}

/// One precedence layer of partially-specified settings.
#[derive(Debug, Clone, Default)]
struct SpecLayer {
    model: Option<ModelSel>,
    device: Option<DeviceSel>,
    target_fps: Option<f64>,
    backend: Option<Backend>,
    threads: Option<usize>,
}

/// Builder for a compile [`Target`] with layered precedence (see the
/// module docs). The typed entry point of the whole pipeline:
/// `TargetSpec → Session → CompiledDesign → codegen / simulator / server`.
#[derive(Debug, Clone, Default)]
pub struct TargetSpec {
    defaults: SpecLayer,
    file: SpecLayer,
    explicit: SpecLayer,
}

impl TargetSpec {
    pub fn new() -> TargetSpec {
        TargetSpec::default()
    }

    // ---- explicit setters (highest precedence) -----------------------------

    /// Use a concrete model configuration.
    pub fn model(mut self, config: VitConfig) -> TargetSpec {
        self.explicit.model = Some(ModelSel::Config(config));
        self
    }

    /// Select a model preset by name (validated at resolve time).
    pub fn model_preset(mut self, name: impl Into<String>) -> TargetSpec {
        self.explicit.model = Some(ModelSel::Preset(name.into()));
        self
    }

    /// Use a concrete device inventory.
    pub fn device(mut self, device: Device) -> TargetSpec {
        self.explicit.device = Some(DeviceSel::Device(device));
        self
    }

    /// Select a device preset by name (validated at resolve time).
    pub fn device_preset(mut self, name: impl Into<String>) -> TargetSpec {
        self.explicit.device = Some(DeviceSel::Preset(name.into()));
        self
    }

    /// The frame-rate target `FR_tgt`.
    pub fn target_fps(mut self, fps: f64) -> TargetSpec {
        self.explicit.target_fps = Some(fps);
        self
    }

    /// Simulator kernel backend (throughput choice, never results).
    pub fn backend(mut self, backend: Backend) -> TargetSpec {
        self.explicit.backend = Some(backend);
        self
    }

    /// [`TargetSpec::backend`] by name, erroring on unknown names.
    pub fn backend_name(self, name: &str) -> Result<TargetSpec> {
        match Backend::from_name(name) {
            Some(b) => Ok(self.backend(b)),
            None => Err(VaqfError::unknown_backend(name)),
        }
    }

    /// Simulator row-parallel worker count (`0` ⇒ environment default).
    pub fn threads(mut self, threads: usize) -> TargetSpec {
        self.explicit.threads = Some(threads);
        self
    }

    // ---- fallback layer (lowest precedence) --------------------------------

    /// Replace the built-in fallback model (deit-base) without outranking
    /// config files, env vars or explicit setters — e.g. `vaqf simulate`
    /// falls back to the micro model, `vaqf serve` to the manifest
    /// variant's model.
    pub fn default_model(mut self, config: VitConfig) -> TargetSpec {
        self.defaults.model = Some(ModelSel::Config(config));
        self
    }

    // ---- config-file layer -------------------------------------------------

    /// Layer a `config::Target` JSON file under env vars and explicit
    /// setters. Only the fields the file sets participate; calling this
    /// again layers later files over earlier ones field-by-field.
    pub fn config_file(self, path: impl AsRef<Path>) -> Result<TargetSpec> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| VaqfError::io(path.display().to_string(), e))?;
        let doc = Json::parse(&text)
            .map_err(|e| VaqfError::config(format!("{}: {e}", path.display())))?;
        self.config_json(&doc)
    }

    /// [`TargetSpec::config_file`] for an already-parsed document.
    pub fn config_json(mut self, doc: &Json) -> Result<TargetSpec> {
        let p = config::partial_from_json(doc).map_err(|e| VaqfError::config(e.to_string()))?;
        if let Some(m) = p.model {
            self.file.model = Some(ModelSel::Config(m));
        }
        if let Some(d) = p.device {
            self.file.device = Some(DeviceSel::Device(d));
        }
        if let Some(f) = p.target_fps {
            self.file.target_fps = Some(f);
        }
        if let Some(b) = p.backend {
            self.file.backend = Some(b);
        }
        if let Some(t) = p.threads {
            self.file.threads = Some(t);
        }
        Ok(self)
    }

    /// CLI-layer construction: `--config FILE` plus the explicit
    /// `--model` / `--device` / `--target-fps` / `--threads` flags and the
    /// kernel-backend flag under `backend_key` (`simulate` exposes it as
    /// `--backend`, `serve` as `--kernels` since its `--backend` selects
    /// the inference backend).
    pub fn from_cli_args(args: &Args, backend_key: &str) -> Result<TargetSpec> {
        let mut spec = TargetSpec::new();
        if let Some(path) = args.get("config") {
            spec = spec.config_file(path)?;
        }
        if let Some(name) = args.get("model") {
            spec = spec.model_preset(name);
        }
        if let Some(name) = args.get("device") {
            spec = spec.device_preset(name);
        }
        if let Some(fps) = args
            .get_f64("target-fps")
            .map_err(|e| VaqfError::config(e.to_string()))?
        {
            spec = spec.target_fps(fps);
        }
        if let Some(name) = args.get(backend_key) {
            spec = spec.backend_name(name)?;
        }
        if let Some(n) = args
            .get_u64("threads")
            .map_err(|e| VaqfError::config(e.to_string()))?
        {
            spec = spec.threads(n as usize);
        }
        Ok(spec)
    }

    // ---- resolution --------------------------------------------------------

    /// Resolve against the real process environment.
    pub fn resolve(&self) -> Result<Target> {
        self.resolve_with(&|key| std::env::var(key).ok())
    }

    /// Resolve with an injectable environment lookup (tests pass closures
    /// instead of mutating process-global env vars).
    ///
    /// Each field resolves independently, highest layer first, and a
    /// malformed environment variable only errors when the env layer is
    /// the *winning* layer for that field — an explicit setter or CLI flag
    /// shadows a broken `VAQF_*` left in a shell profile.
    pub fn resolve_with(&self, env: &dyn Fn(&str) -> Option<String>) -> Result<Target> {
        let model = if let Some(sel) = self.explicit.model.as_ref() {
            resolve_model_sel(sel)?
        } else if let Some(name) = env("VAQF_MODEL") {
            VitPreset::from_name(&name)
                .map(|p| p.config())
                .ok_or_else(|| VaqfError::unknown_model(name))?
        } else if let Some(sel) = self.file.model.as_ref().or(self.defaults.model.as_ref()) {
            resolve_model_sel(sel)?
        } else {
            crate::model::deit_base()
        };
        let device = if let Some(sel) = self.explicit.device.as_ref() {
            resolve_device_sel(sel)?
        } else if let Some(name) = env("VAQF_DEVICE") {
            DevicePreset::from_name(&name)
                .map(|p| p.device())
                .ok_or_else(|| VaqfError::unknown_device(name))?
        } else if let Some(sel) = self.file.device.as_ref().or(self.defaults.device.as_ref()) {
            resolve_device_sel(sel)?
        } else {
            crate::hw::zcu102()
        };
        let target_fps = if let Some(f) = self.explicit.target_fps {
            f
        } else if let Some(v) = env("VAQF_TARGET_FPS") {
            v.parse::<f64>()
                .map_err(|e| VaqfError::config(format!("VAQF_TARGET_FPS: {e}")))?
        } else {
            self.file.target_fps.or(self.defaults.target_fps).unwrap_or(24.0)
        };
        let backend = if let Some(b) = self.explicit.backend {
            b
        } else if let Some(name) = env("VAQF_BACKEND") {
            Backend::from_name(&name).ok_or_else(|| VaqfError::unknown_backend(name))?
        } else {
            self.file.backend.or(self.defaults.backend).unwrap_or_default()
        };
        let threads = if let Some(t) = self.explicit.threads {
            t
        } else if let Some(v) = env("VAQF_THREADS") {
            v.parse::<usize>()
                .map_err(|e| VaqfError::config(format!("VAQF_THREADS: {e}")))?
        } else {
            self.file.threads.or(self.defaults.threads).unwrap_or(0)
        };

        Ok(Target {
            model,
            device,
            target_fps,
            backend,
            threads,
        })
    }

    /// Resolve, then emit the result as a config document
    /// ([`config::Target::to_json`]) — archivable and re-loadable via
    /// `--config`.
    pub fn to_json(&self) -> Result<Json> {
        Ok(self.resolve()?.to_json())
    }

    /// Resolve and open a compile session.
    pub fn session(&self) -> Result<Session> {
        Ok(Session::new(self.resolve()?))
    }
}

fn resolve_model_sel(sel: &ModelSel) -> Result<VitConfig> {
    match sel {
        ModelSel::Config(c) => Ok(c.clone()),
        ModelSel::Preset(name) => VitPreset::from_name(name)
            .map(|p| p.config())
            .ok_or_else(|| VaqfError::unknown_model(name.clone())),
    }
}

fn resolve_device_sel(sel: &DeviceSel) -> Result<Device> {
    match sel {
        DeviceSel::Device(d) => Ok(d.clone()),
        DeviceSel::Preset(name) => DevicePreset::from_name(name)
            .map(|p| p.device())
            .ok_or_else(|| VaqfError::unknown_device(name.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_env(_: &str) -> Option<String> {
        None
    }

    #[test]
    fn builtin_defaults() {
        let t = TargetSpec::new().resolve_with(&no_env).unwrap();
        assert_eq!(t.model.name, "deit-base");
        assert_eq!(t.device.name, "zcu102");
        assert_eq!(t.target_fps, 24.0);
        assert_eq!(t.backend, Backend::Packed);
        assert_eq!(t.threads, 0);
    }

    #[test]
    fn default_model_stays_below_every_other_layer() {
        let spec = TargetSpec::new().default_model(crate::model::micro());
        assert_eq!(spec.resolve_with(&no_env).unwrap().model.name, "micro");
        let spec = spec.model_preset("deit-tiny");
        assert_eq!(spec.resolve_with(&no_env).unwrap().model.name, "deit-tiny");
    }

    #[test]
    fn unknown_names_are_typed() {
        let err = TargetSpec::new()
            .model_preset("bogus")
            .resolve_with(&no_env)
            .unwrap_err();
        assert!(matches!(err, VaqfError::UnknownPreset { kind: "model", .. }));
        let err = TargetSpec::new()
            .device_preset("bogus")
            .resolve_with(&no_env)
            .unwrap_err();
        assert!(matches!(err, VaqfError::UnknownPreset { kind: "device", .. }));
        assert!(TargetSpec::new().backend_name("simd").is_err());
    }

    #[test]
    fn env_parse_failures_are_config_errors() {
        let env = |key: &str| (key == "VAQF_TARGET_FPS").then(|| "fast".to_string());
        let err = TargetSpec::new().resolve_with(&env).unwrap_err();
        assert!(matches!(err, VaqfError::Config { .. }));
    }

    #[test]
    fn explicit_setter_shadows_malformed_env() {
        // A broken VAQF_* left in a shell profile must not break
        // invocations that override that field explicitly.
        let env = |key: &str| (key == "VAQF_BACKEND").then(|| "auto".to_string());
        let t = TargetSpec::new()
            .backend(Backend::Packed)
            .resolve_with(&env)
            .unwrap();
        assert_eq!(t.backend, Backend::Packed);
        // …but it does error when the env layer is the winning layer.
        assert!(TargetSpec::new().resolve_with(&env).is_err());
    }

    #[test]
    fn cli_args_feed_the_explicit_layer() {
        let args = Args::parse(
            ["simulate", "--model", "deit-small", "--device", "zcu111", "--threads", "4"]
                .into_iter()
                .map(String::from),
        );
        let t = TargetSpec::from_cli_args(&args, "backend")
            .unwrap()
            .resolve_with(&no_env)
            .unwrap();
        assert_eq!(t.model.name, "deit-small");
        assert_eq!(t.device.name, "zcu111");
        assert_eq!(t.threads, 4);
    }
}
