//! Serving facade: the full `FrameSource → queue → backend` loop behind
//! one call, with the `sim` / `pjrt` [`InferenceBackend`] constructed
//! internally from the compiled design.

use std::rc::Rc;

use crate::coordinator::{serve, FrameSource, ServeConfig, ServingReport};
use crate::runtime::{InferenceBackend, InferenceEngine, Manifest, PjrtBackend, SimBackend};

use super::error::{Result, VaqfError};
use super::session::CompiledDesign;

/// Which inference backend serves the frames.
#[derive(Debug, Clone)]
pub enum ServeBackendOpt {
    /// The cycle-level simulated FPGA running this compiled design.
    /// `realtime` paces wall-clock to the simulated latency (realistic
    /// serving) instead of running as fast as the host allows.
    Sim { realtime: bool },
    /// PJRT CPU execution of an AOT artifact variant from the manifest in
    /// `artifacts` (requires the `pjrt` feature at build time).
    Pjrt { artifacts: String, variant: String },
}

/// Options for one serving run.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    pub backend: ServeBackendOpt,
    /// Frames the synthetic camera offers per second.
    pub offered_fps: f64,
    /// Total frames to offer.
    pub frames: u64,
    /// Queue depth before drop-oldest backpressure kicks in.
    pub queue_depth: usize,
    /// Seed for the synthetic frame source.
    pub source_seed: u64,
    /// Seed for the simulator's generated weights (sim backend only).
    pub weights_seed: u64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            backend: ServeBackendOpt::Sim { realtime: false },
            offered_fps: 30.0,
            frames: 90,
            queue_depth: 2,
            source_seed: 11,
            weights_seed: 11,
        }
    }
}

impl CompiledDesign {
    /// Run the serving loop against this design; blocks until every
    /// offered frame is served or dropped and returns the report.
    ///
    /// The `sim` backend simulates *this* compiled design (parameters,
    /// kernel backend, thread fan-out all from the resolved target); the
    /// `pjrt` backend loads and compiles the named manifest variant
    /// (independent of the design — equivalent to
    /// [`PjrtRuntime::load_variant`] + [`PjrtRuntime::server`]).
    pub fn server(&self, opts: &ServeOpts) -> Result<ServingReport> {
        let realtime = match &opts.backend {
            ServeBackendOpt::Sim { realtime } => *realtime,
            ServeBackendOpt::Pjrt { artifacts, variant } => {
                return PjrtRuntime::load_variant(artifacts, variant)?.server(variant, opts);
            }
        };
        let cfg = ServeConfig {
            offered_fps: opts.offered_fps,
            frames: opts.frames,
            queue_depth: opts.queue_depth,
            source_seed: opts.source_seed,
        };
        let executor = self.simulator_with_seed(opts.weights_seed);
        let source = FrameSource::new(
            self.target().model.clone(),
            cfg.source_seed,
            Some(cfg.offered_fps),
        );
        let backend: Box<dyn InferenceBackend> = Box::new(SimBackend { executor, realtime });
        serve(source, backend, &cfg).map_err(VaqfError::runtime)
    }
}

/// Facade over the PJRT runtime: the manifest plus one engine with every
/// variant compiled and loaded — the e2e cross-check path. Construction
/// fails with [`VaqfError::Runtime`] on builds without the `pjrt` feature
/// and with [`VaqfError::Manifest`] when the artifacts are missing.
pub struct PjrtRuntime {
    manifest: Manifest,
    engine: Rc<InferenceEngine>,
}

impl PjrtRuntime {
    /// Load `<dir>/manifest.json` and compile every variant it lists.
    pub fn load(artifacts: impl AsRef<std::path::Path>) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifacts.as_ref()).map_err(VaqfError::manifest)?;
        let mut engine = InferenceEngine::new().map_err(VaqfError::runtime)?;
        for v in &manifest.variants {
            engine.load_variant(v).map_err(VaqfError::runtime)?;
        }
        Ok(PjrtRuntime {
            manifest,
            engine: Rc::new(engine),
        })
    }

    /// Load the manifest but compile only `variant` — the serving path
    /// ([`PjrtRuntime::load`] compiles every variant for cross-checks).
    pub fn load_variant(
        artifacts: impl AsRef<std::path::Path>,
        variant: &str,
    ) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifacts.as_ref()).map_err(VaqfError::manifest)?;
        let entry = manifest.find(variant).ok_or_else(|| VaqfError::Manifest {
            message: format!("variant {variant} not in manifest"),
        })?;
        let mut engine = InferenceEngine::new().map_err(VaqfError::runtime)?;
        engine.load_variant(entry).map_err(VaqfError::runtime)?;
        Ok(PjrtRuntime {
            manifest,
            engine: Rc::new(engine),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    /// Run one frame through the named variant, returning the logits.
    pub fn infer(&self, tag: &str, patches: &[f32]) -> Result<Vec<f32>> {
        self.engine.infer(tag, patches).map_err(VaqfError::runtime)
    }

    /// Run the serving loop through one already-loaded variant, reusing
    /// this runtime's compiled engine — unlike
    /// [`CompiledDesign::server`]'s `Pjrt` option, nothing is re-loaded or
    /// re-compiled. `opts.backend` and `opts.weights_seed` are ignored
    /// (the backend is this runtime; the weights are the artifact's).
    pub fn server(&self, variant: &str, opts: &ServeOpts) -> Result<ServingReport> {
        let entry = self.manifest.find(variant).ok_or_else(|| VaqfError::Manifest {
            message: format!("variant {variant} not in manifest"),
        })?;
        let cfg = ServeConfig {
            offered_fps: opts.offered_fps,
            frames: opts.frames,
            queue_depth: opts.queue_depth,
            source_seed: opts.source_seed,
        };
        let source = FrameSource::new(entry.config.clone(), cfg.source_seed, Some(cfg.offered_fps));
        let backend: Box<dyn InferenceBackend> = Box::new(PjrtBackend {
            engine: Rc::clone(&self.engine),
            tag: variant.to_string(),
        });
        serve(source, backend, &cfg).map_err(VaqfError::runtime)
    }
}
