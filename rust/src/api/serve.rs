//! Serving facade.
//!
//! * [`CompiledDesign::server`] — a builder over the multi-stream
//!   coordinator: `design.server().streams(4).workers(2).policy("weighted-sla")
//!   .virtual_clock().run()` runs N synthetic camera streams against a
//!   pool of simulated accelerators and returns a
//!   [`MultiServingReport`].
//! * [`PjrtRuntime`] — the PJRT cross-check path (thread-affine client,
//!   single-stream loop).

use std::path::PathBuf;
use std::rc::Rc;

use crate::coordinator::{
    policy_for, serve, AnalyticWorker, DegradeRung, FrameSource, HysteresisConfig,
    MultiServingReport, Scheduler, ServeConfig, ServingReport, SimWorker, StreamConfig,
    WorkerModel, POLICY_NAMES,
};
use crate::fault::FaultPlan;
use crate::obs::{MetricsRegistry, Trace, TraceConfig, TraceSink};
use crate::runtime::{InferenceBackend, InferenceEngine, Manifest, PjrtBackend};

use super::error::{Result, VaqfError};
use super::session::CompiledDesign;

/// Which clock drives a serving run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeClock {
    /// Real time: threaded producers and workers.
    Wall,
    /// Deterministic simulated time in device-cycle units: a
    /// single-threaded discrete-event run, byte-reproducible and fast.
    Virtual,
}

/// What each pool worker runs.
#[derive(Debug, Clone, Copy)]
pub enum ServeWorker {
    /// The cycle-level functional simulator of this compiled design.
    /// `realtime` paces wall-clock service to the simulated latency
    /// (ignored under the virtual clock, where latency *is* the
    /// simulated time).
    Simulated { realtime: bool },
    /// Constant-latency workers from the design's predicted frame rate
    /// (`perf::cycles`) — no numerics, so DeiT-scale scheduling studies
    /// run in milliseconds.
    Analytic,
}

/// Builder for a multi-stream serving run over a compiled design.
/// Constructed by [`CompiledDesign::server`]; every knob has a sensible
/// single-stream default.
#[derive(Debug, Clone)]
pub struct ServerBuilder<'d> {
    design: &'d CompiledDesign,
    streams: usize,
    workers: usize,
    policy: String,
    offered_fps: f64,
    frames: u64,
    queue_depth: usize,
    sla_ms: Option<f64>,
    clock: ServeClock,
    worker: ServeWorker,
    source_seed: u64,
    weights_seed: u64,
    faults: Option<FaultPlan>,
    /// `(label, frame latency seconds)` per rung, rung 0 first.
    ladder: Option<Vec<(String, f64)>>,
    hysteresis: HysteresisConfig,
    trace_out: Option<PathBuf>,
    metrics_out: Option<PathBuf>,
    trace_cfg: TraceConfig,
}

impl CompiledDesign {
    /// Configure a serving run of this design; finish with
    /// [`ServerBuilder::run`].
    pub fn server(&self) -> ServerBuilder<'_> {
        ServerBuilder {
            design: self,
            streams: 1,
            workers: 1,
            policy: "round-robin".to_string(),
            offered_fps: 30.0,
            frames: 90,
            queue_depth: 2,
            sla_ms: None,
            clock: ServeClock::Wall,
            worker: ServeWorker::Simulated { realtime: false },
            source_seed: 11,
            weights_seed: 11,
            faults: None,
            ladder: None,
            hysteresis: HysteresisConfig::default(),
            trace_out: None,
            metrics_out: None,
            trace_cfg: TraceConfig::default(),
        }
    }
}

impl<'d> ServerBuilder<'d> {
    /// Number of independent frame sources (each with its own queue,
    /// pacing and SLA accounting).
    pub fn streams(mut self, n: usize) -> Self {
        self.streams = n;
        self
    }

    /// Size of the simulated-accelerator worker pool.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// Dispatch policy by name: `round-robin`, `least-loaded`,
    /// `weighted-sla` (validated at [`ServerBuilder::run`]).
    pub fn policy(mut self, name: &str) -> Self {
        self.policy = name.to_string();
        self
    }

    /// Frames per second each stream offers.
    pub fn offered_fps(mut self, fps: f64) -> Self {
        self.offered_fps = fps;
        self
    }

    /// Frames each stream offers in total.
    pub fn frames(mut self, n: u64) -> Self {
        self.frames = n;
        self
    }

    /// Per-stream queue depth before drop-oldest backpressure.
    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    /// End-to-end latency SLA per stream, in milliseconds.
    pub fn sla_ms(mut self, ms: f64) -> Self {
        self.sla_ms = Some(ms);
        self
    }

    pub fn clock(mut self, clock: ServeClock) -> Self {
        self.clock = clock;
        self
    }

    /// Shorthand for `.clock(ServeClock::Virtual)`.
    pub fn virtual_clock(self) -> Self {
        self.clock(ServeClock::Virtual)
    }

    /// Run cycle-level simulated workers, optionally pacing wall-clock
    /// service to the simulated latency.
    pub fn simulated(mut self, realtime: bool) -> Self {
        self.worker = ServeWorker::Simulated { realtime };
        self
    }

    /// Run constant-latency analytic workers (no numerics).
    pub fn analytic(mut self) -> Self {
        self.worker = ServeWorker::Analytic;
        self
    }

    pub fn source_seed(mut self, seed: u64) -> Self {
        self.source_seed = seed;
        self
    }

    /// Seed for the simulator's generated weights (simulated workers).
    pub fn weights_seed(mut self, seed: u64) -> Self {
        self.weights_seed = seed;
        self
    }

    /// Inject a deterministic fault plan (crashes, slow-downs, frame
    /// corruption) into the run. Virtual clock only — [`run`] rejects a
    /// plan under the wall clock.
    ///
    /// [`run`]: ServerBuilder::run
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Graceful degradation: a precision ladder of `(label, frame
    /// latency seconds)` rungs, rung 0 = this design's full precision.
    /// Sustained SLA misses demote service down the ladder (service
    /// times scale by `latency_i / latency_0`), recovery promotes back —
    /// both under the hysteresis rule configured with
    /// [`ServerBuilder::hysteresis`]. Build the rungs with
    /// [`Session::precision_ladder`](super::Session::precision_ladder).
    pub fn degrade_ladder(mut self, rungs: Vec<(String, f64)>) -> Self {
        self.ladder = Some(rungs);
        self
    }

    /// Tune the demote/promote hysteresis for
    /// [`ServerBuilder::degrade_ladder`].
    pub fn hysteresis(mut self, cfg: HysteresisConfig) -> Self {
        self.hysteresis = cfg;
        self
    }

    /// Write a Chrome/Perfetto `trace_event` JSON of the run to `path`:
    /// one track per stream and per worker, frame service spans nesting
    /// into the analytic per-layer breakdown. Deterministic feature —
    /// [`run`](ServerBuilder::run) rejects it under the wall clock.
    pub fn trace(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace_out = Some(path.into());
        self
    }

    /// Buffering and layer-detail sampling controls for
    /// [`ServerBuilder::trace`] / [`ServerBuilder::run_traced`].
    pub fn trace_config(mut self, cfg: TraceConfig) -> Self {
        self.trace_cfg = cfg;
        self
    }

    /// Write a JSON metrics snapshot (counters, gauges, latency
    /// histograms from the final report) to `path`.
    pub fn metrics_json(mut self, path: impl Into<PathBuf>) -> Self {
        self.metrics_out = Some(path.into());
        self
    }

    /// Execute the run; blocks until every offered frame is served or
    /// dropped. Writes the artifacts requested with
    /// [`ServerBuilder::trace`] / [`ServerBuilder::metrics_json`].
    pub fn run(mut self) -> Result<MultiServingReport> {
        let trace_out = self.trace_out.take();
        let metrics_out = self.metrics_out.take();
        let (report, trace) = if trace_out.is_some() {
            let (report, trace) = self.run_traced()?;
            (report, Some(trace))
        } else {
            (self.launch(None)?, None)
        };
        if let (Some(path), Some(trace)) = (&trace_out, &trace) {
            trace.save_perfetto(path).map_err(VaqfError::runtime)?;
        }
        if let Some(path) = &metrics_out {
            let mut reg = MetricsRegistry::new();
            reg.publish_serving(&report);
            std::fs::write(path, reg.to_json().pretty())
                .map_err(|e| VaqfError::io(path.display().to_string(), e))?;
        }
        Ok(report)
    }

    /// [`ServerBuilder::run`], also returning the collected [`Trace`]
    /// for in-process inspection or export. Virtual clock only: the
    /// trace is stamped in device cycles and must be byte-reproducible.
    pub fn run_traced(mut self) -> Result<(MultiServingReport, Trace)> {
        if self.clock != ServeClock::Virtual {
            return Err(VaqfError::config(
                "tracing is a deterministic feature: use .virtual_clock()",
            ));
        }
        // Artifact paths are run()'s concern; a direct run_traced()
        // caller gets the Trace and writes what it wants.
        self.trace_out = None;
        self.metrics_out = None;
        let mut sink =
            TraceSink::with_config(self.design.target().device.clock_mhz, self.trace_cfg);
        sink.set_layer_template(self.design.layer_template());
        let report = self.launch(Some(&mut sink))?;
        Ok((report, sink.finish()))
    }

    /// Validate the configuration and run the scheduler, recording into
    /// `trace` when given (virtual clock only — callers enforce it).
    fn launch(self, trace: Option<&mut TraceSink>) -> Result<MultiServingReport> {
        if self.streams == 0 || self.workers == 0 {
            return Err(VaqfError::config(
                "serving needs at least 1 stream and 1 worker",
            ));
        }
        if !(self.offered_fps > 0.0) {
            return Err(VaqfError::config("offered_fps must be positive"));
        }
        if self.queue_depth == 0 {
            return Err(VaqfError::config("queue_depth must be at least 1"));
        }
        if self.clock != ServeClock::Virtual && (self.faults.is_some() || self.ladder.is_some()) {
            return Err(VaqfError::config(
                "fault injection and degrade ladders are deterministic features: \
                 use .virtual_clock()",
            ));
        }
        if let Some(rungs) = &self.ladder {
            if rungs.is_empty() {
                return Err(VaqfError::config("degrade ladder must not be empty"));
            }
            if rungs.iter().any(|(_, lat)| !lat.is_finite() || *lat <= 0.0) {
                return Err(VaqfError::config(
                    "degrade ladder latencies must be positive and finite",
                ));
            }
        }
        let policy = policy_for(&self.policy).ok_or_else(|| {
            VaqfError::config(format!(
                "unknown dispatch policy `{}` (expected one of: {})",
                self.policy,
                POLICY_NAMES.join(", ")
            ))
        })?;

        let model = self.design.target().model.clone();
        let pairs: Vec<(StreamConfig, FrameSource)> = (0..self.streams)
            .map(|i| {
                let cfg = StreamConfig {
                    offered_fps: self.offered_fps,
                    frames: self.frames,
                    queue_depth: self.queue_depth,
                    sla_ms: self.sla_ms,
                };
                // Stagger stream phases so arrivals interleave instead of
                // colliding on every tick.
                let offset = i as f64 / (self.offered_fps * self.streams as f64);
                let src = FrameSource::new(
                    model.clone(),
                    self.source_seed.wrapping_add(i as u64),
                    Some(self.offered_fps),
                )
                .with_stream(i)
                .with_offset(offset);
                (cfg, src)
            })
            .collect();

        let summary = self.design.summary();
        let workers: Vec<Box<dyn WorkerModel>> = (0..self.workers)
            .map(|_| match self.worker {
                ServeWorker::Analytic => Box::new(AnalyticWorker {
                    latency_s: self.design.frame_latency_s(),
                    label: summary.label.clone(),
                }) as Box<dyn WorkerModel>,
                ServeWorker::Simulated { .. } => Box::new(SimWorker {
                    executor: self.design.simulator_with_seed(self.weights_seed),
                }) as Box<dyn WorkerModel>,
            })
            .collect();
        let realtime = matches!(self.worker, ServeWorker::Simulated { realtime: true });

        let mut scheduler = Scheduler::new(pairs, workers, policy).realtime(realtime);
        if let Some(plan) = self.faults {
            scheduler = scheduler.faults(plan);
        }
        if let Some(rungs) = self.ladder {
            // Rung latencies normalize to service-time scales against
            // rung 0 (this design's own latency).
            let base = rungs[0].1;
            let rungs: Vec<DegradeRung> = rungs
                .into_iter()
                .map(|(label, lat)| DegradeRung {
                    label,
                    scale: lat / base,
                })
                .collect();
            scheduler = scheduler
                .degrade(rungs, self.hysteresis)
                .map_err(|e| VaqfError::config(e.to_string()))?;
        }
        match self.clock {
            ServeClock::Virtual => scheduler
                .run_virtual_traced(self.design.target().device.clock_mhz, trace)
                .map_err(VaqfError::runtime),
            ServeClock::Wall => scheduler.run_wall().map_err(VaqfError::runtime),
        }
    }
}

/// Facade over the PJRT runtime: the manifest plus one engine with every
/// variant compiled and loaded — the e2e cross-check path. Construction
/// fails with [`VaqfError::Runtime`] on builds without the `pjrt` feature
/// and with [`VaqfError::Manifest`] when the artifacts are missing.
pub struct PjrtRuntime {
    manifest: Manifest,
    engine: Rc<InferenceEngine>,
}

impl PjrtRuntime {
    /// Load `<dir>/manifest.json` and compile every variant it lists.
    pub fn load(artifacts: impl AsRef<std::path::Path>) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifacts.as_ref()).map_err(VaqfError::manifest)?;
        let mut engine = InferenceEngine::new().map_err(VaqfError::runtime)?;
        for v in &manifest.variants {
            engine.load_variant(v).map_err(VaqfError::runtime)?;
        }
        Ok(PjrtRuntime {
            manifest,
            engine: Rc::new(engine),
        })
    }

    /// Load the manifest but compile only `variant` — the serving path
    /// ([`PjrtRuntime::load`] compiles every variant for cross-checks).
    pub fn load_variant(
        artifacts: impl AsRef<std::path::Path>,
        variant: &str,
    ) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(artifacts.as_ref()).map_err(VaqfError::manifest)?;
        let entry = manifest.find(variant).ok_or_else(|| VaqfError::Manifest {
            message: format!("variant {variant} not in manifest"),
        })?;
        let mut engine = InferenceEngine::new().map_err(VaqfError::runtime)?;
        engine.load_variant(entry).map_err(VaqfError::runtime)?;
        Ok(PjrtRuntime {
            manifest,
            engine: Rc::new(engine),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.engine.platform()
    }

    /// Run one frame through the named variant, returning the logits.
    pub fn infer(&self, tag: &str, patches: &[f32]) -> Result<Vec<f32>> {
        self.engine.infer(tag, patches).map_err(VaqfError::runtime)
    }

    /// Run the single-stream serving loop through one already-loaded
    /// variant, reusing this runtime's compiled engine. The PJRT client
    /// wraps thread-affine C pointers, so this path stays on the calling
    /// thread — multi-worker pools are a simulator-side feature
    /// ([`CompiledDesign::server`]).
    pub fn server(&self, variant: &str, cfg: &ServeConfig) -> Result<ServingReport> {
        let entry = self.manifest.find(variant).ok_or_else(|| VaqfError::Manifest {
            message: format!("variant {variant} not in manifest"),
        })?;
        let source = FrameSource::new(entry.config.clone(), cfg.source_seed, Some(cfg.offered_fps));
        let backend: Box<dyn InferenceBackend> = Box::new(PjrtBackend {
            engine: Rc::clone(&self.engine),
            tag: variant.to_string(),
        });
        serve(source, backend, cfg).map_err(VaqfError::runtime)
    }
}
