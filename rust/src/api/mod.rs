//! The typed facade over the whole co-design pipeline — the crate's front
//! door.
//!
//! VAQF's pitch is *fully automatic*: given a model structure and a frame
//! rate, everything downstream — precision, accelerator parameters,
//! generated artifacts, simulator, serving loop — is derived. This module
//! makes that one typed pipeline:
//!
//! ```text
//! TargetSpec ──resolve──► Session ──compile──► CompiledDesign
//!   (layered:                │                     ├── .codegen(dir)   HLS C++ + JSON
//!    defaults                │ compile_for_bits    ├── .simulator()    cycle-level ModelExecutor
//!    < config file           │ sweep / table5      └── .server()       serving builder:
//!    < env < explicit)       ▼                         .streams(n).workers(w).policy(p).run()
//! ```
//!
//! ```no_run
//! use vaqf::api::TargetSpec;
//!
//! let design = TargetSpec::new()
//!     .model_preset("deit-base")
//!     .device_preset("zcu102")
//!     .target_fps(24.0)
//!     .session()?
//!     .compile()?;
//! println!("chosen precision: W1A{}", design.act_bits().unwrap());
//! design.codegen("out")?;
//! # Ok::<(), vaqf::api::VaqfError>(())
//! ```
//!
//! Every facade call fails with the matchable [`VaqfError`] instead of a
//! stringly-typed error: `UnknownPreset` for typo'd names, `Infeasible`
//! for the §3 `FR_tgt > FR_max` case, `Config`/`Io` for broken inputs.
//! The CLI (`src/main.rs`), the examples and the benches are all thin
//! layers over this module.

mod error;
mod fleet;
mod serve;
mod session;
mod spec;

pub use error::{Result, VaqfError};
pub use fleet::FleetBuilder;
pub use serve::{PjrtRuntime, ServeClock, ServeWorker, ServerBuilder};
pub use session::{CodegenArtifacts, CompiledDesign, PrecisionSweep, Session, SweepPoint};
pub use spec::TargetSpec;

// Re-exports of the pipeline's data types and report renderers, so facade
// callers don't need to reach into the layer modules for what the facade
// itself hands out.
pub use crate::compiler::{
    render_table5, render_table6, table6_rows, CompileOutcome, DesignPoint, SearchRound,
};
pub use crate::config::Target;
pub use crate::coordinator::{
    DegradeRung, HysteresisConfig, MultiServingReport, ServeConfig, ServingReport, StreamReport,
};
pub use crate::fault::{
    FaultEvent, FaultKind, FaultPlan, FaultSummary, GeneratorSpec, PipelineFaultSummary,
    RecoveryConfig,
};
pub use crate::fleet::{FleetReport, FleetTopology, TraceSpec};
pub use crate::hw::Device;
pub use crate::model::VitConfig;
pub use crate::obs::{MetricsRegistry, Trace, TraceConfig};
pub use crate::perf::{AcceleratorParams, PerfSummary};
pub use crate::shard::{
    FailoverStrategy, PipelineReport, ShardPolicy, ShardReport, ShardStage, ShardedDesign,
    ShardedExecutor,
};
pub use crate::sim::Backend;
