//! Deterministic trace collection: typed span/instant events stamped in
//! integer [`Cycles`] from the shared virtual clock.
//!
//! The serving scheduler, the shard pipeline and the fleet simulator are
//! all single-threaded discrete-event loops over `(cycle, seq)`-ordered
//! heaps, so recording an event at the point the simulation processes it
//! yields a trace that is a pure function of the scenario — byte-identical
//! across runs *and* across thread counts (threads only fan out the
//! compiler search and the executor's inner loops, never the event
//! order). Timestamps are integer cycles; floating point enters only at
//! export time, and there as exact divisions by the clock rate.
//!
//! Overhead discipline: every instrumented loop holds an
//! `Option<&mut TraceSink>` — a disabled run pays one branch per event
//! and allocates nothing. An enabled sink buffers into a bounded ring
//! ([`TraceConfig::capacity`]): when full, the *oldest* event is evicted
//! (the tail of a long run is usually the interesting part) and the
//! eviction is counted, never silent.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::Cycles;

/// Which exported "process" a track belongs to. Perfetto groups tracks
/// (threads) under processes; we use one process per subsystem so a
/// fleet trace reads top-down: traffic → workers → units → control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrackKind {
    /// A traffic stream (frame emit / enqueue / drop / complete instants).
    Stream,
    /// A scheduler worker (service spans).
    Worker,
    /// A fleet serving unit (replica service spans, dispatch instants).
    Unit,
    /// A pipeline stage of a sharded unit (service + blocked spans).
    Stage,
    /// Control-plane events: faults, failover, retries, search rounds.
    Control,
}

impl TrackKind {
    /// Stable Perfetto pid for the kind's process group.
    pub fn pid(self) -> u64 {
        match self {
            TrackKind::Stream => 1,
            TrackKind::Worker => 2,
            TrackKind::Unit => 3,
            TrackKind::Stage => 4,
            TrackKind::Control => 5,
        }
    }

    pub fn process_name(self) -> &'static str {
        match self {
            TrackKind::Stream => "streams",
            TrackKind::Worker => "workers",
            TrackKind::Unit => "units",
            TrackKind::Stage => "stages",
            TrackKind::Control => "control",
        }
    }
}

/// Handle to a registered track (index into [`Trace::tracks`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrackId(pub(crate) usize);

/// One named timeline in the trace (a Perfetto "thread").
#[derive(Debug, Clone)]
pub struct Track {
    pub kind: TrackKind,
    pub name: String,
}

/// A typed event argument. Kept as a tiny enum (not `Json`) so recording
/// an event never builds a tree.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(String),
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> ArgValue {
        ArgValue::U64(v)
    }
}

impl From<usize> for ArgValue {
    fn from(v: usize) -> ArgValue {
        ArgValue::U64(v as u64)
    }
}

impl From<u32> for ArgValue {
    fn from(v: u32) -> ArgValue {
        ArgValue::U64(u64::from(v))
    }
}

impl From<f64> for ArgValue {
    fn from(v: f64) -> ArgValue {
        ArgValue::F64(v)
    }
}

impl From<&str> for ArgValue {
    fn from(v: &str) -> ArgValue {
        ArgValue::Str(v.to_string())
    }
}

impl From<String> for ArgValue {
    fn from(v: String) -> ArgValue {
        ArgValue::Str(v)
    }
}

/// One trace event: an instant (`dur == None`) or a completed span
/// `[start, start + dur]` on `track`.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub track: TrackId,
    pub name: Cow<'static, str>,
    pub start: Cycles,
    pub dur: Option<Cycles>,
    pub args: Vec<(&'static str, ArgValue)>,
}

/// Buffering and sampling controls for a [`TraceSink`].
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Ring capacity in events; the oldest event is evicted (and counted
    /// in [`Trace::evicted`]) once the buffer is full.
    pub capacity: usize,
    /// Emit the nested per-layer breakdown under every `k`-th service
    /// span (`1` = every frame, `0` = never). Layer detail multiplies the
    /// event count by the layer count, so long runs sample it down.
    pub layer_detail_every: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            capacity: 1 << 20,
            layer_detail_every: 1,
        }
    }
}

/// Collects events during a run; [`TraceSink::finish`] freezes it into a
/// [`Trace`] for export.
pub struct TraceSink {
    clock_mhz: u64,
    cfg: TraceConfig,
    tracks: Vec<Track>,
    events: VecDeque<TraceEvent>,
    evicted: u64,
    service_seq: u64,
    /// Per-frame layer template `(name, cycles)` — the analytic
    /// `LayerCycles` breakdown a service span opens into.
    layers: Vec<(String, Cycles)>,
    layers_total: Cycles,
}

impl TraceSink {
    pub fn new(clock_mhz: u64) -> TraceSink {
        TraceSink::with_config(clock_mhz, TraceConfig::default())
    }

    pub fn with_config(clock_mhz: u64, cfg: TraceConfig) -> TraceSink {
        TraceSink {
            clock_mhz: clock_mhz.max(1),
            cfg: TraceConfig {
                capacity: cfg.capacity.max(1),
                ..cfg
            },
            tracks: Vec::new(),
            events: VecDeque::new(),
            evicted: 0,
            service_seq: 0,
            layers: Vec::new(),
            layers_total: 0,
        }
    }

    pub fn clock_mhz(&self) -> u64 {
        self.clock_mhz
    }

    /// Register (or look up) the track `(kind, name)`. Tracks are few and
    /// registered once per run, so the scan is fine.
    pub fn track(&mut self, kind: TrackKind, name: &str) -> TrackId {
        if let Some(i) = self
            .tracks
            .iter()
            .position(|t| t.kind == kind && t.name == name)
        {
            return TrackId(i);
        }
        self.tracks.push(Track {
            kind,
            name: name.to_string(),
        });
        TrackId(self.tracks.len() - 1)
    }

    /// Install the per-frame layer template: `(layer name, cycles)` in
    /// execution order. Service spans recorded via [`Self::service_span`]
    /// open into child spans scaled to the span's actual duration.
    pub fn set_layer_template(&mut self, layers: Vec<(String, Cycles)>) {
        self.layers_total = layers.iter().map(|(_, c)| *c).sum();
        self.layers = layers;
    }

    fn record(&mut self, ev: TraceEvent) {
        if self.events.len() == self.cfg.capacity {
            self.events.pop_front();
            self.evicted += 1;
        }
        self.events.push_back(ev);
    }

    /// Record an instant event at `at`.
    pub fn instant(
        &mut self,
        track: TrackId,
        name: impl Into<Cow<'static, str>>,
        at: Cycles,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        self.record(TraceEvent {
            track,
            name: name.into(),
            start: at,
            dur: None,
            args,
        });
    }

    /// Record a completed span `[start, start + dur]`.
    pub fn span(
        &mut self,
        track: TrackId,
        name: impl Into<Cow<'static, str>>,
        start: Cycles,
        dur: Cycles,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        self.record(TraceEvent {
            track,
            name: name.into(),
            start,
            dur: Some(dur),
            args,
        });
    }

    /// Record a frame-service span plus (subject to
    /// [`TraceConfig::layer_detail_every`] sampling) the nested per-layer
    /// attribution, each layer's sub-span scaled from the template to the
    /// span's actual duration with exact integer arithmetic.
    pub fn service_span(
        &mut self,
        track: TrackId,
        name: impl Into<Cow<'static, str>>,
        start: Cycles,
        dur: Cycles,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        self.span(track, name, start, dur, args);
        self.service_seq += 1;
        let every = self.cfg.layer_detail_every;
        if every == 0 || self.layers_total == 0 || (self.service_seq - 1) % every != 0 {
            return;
        }
        let total = u128::from(self.layers_total);
        let mut prefix: u128 = 0;
        let layers = std::mem::take(&mut self.layers);
        for (lname, lcycles) in &layers {
            let c_start = start + (prefix * u128::from(dur) / total) as Cycles;
            prefix += u128::from(*lcycles);
            let c_end = start + (prefix * u128::from(dur) / total) as Cycles;
            self.record(TraceEvent {
                track,
                name: Cow::Owned(lname.clone()),
                start: c_start,
                dur: Some(c_end - c_start),
                args: Vec::new(),
            });
        }
        self.layers = layers;
    }

    /// Freeze into an immutable, exportable [`Trace`].
    pub fn finish(self) -> Trace {
        Trace {
            clock_mhz: self.clock_mhz,
            tracks: self.tracks,
            events: self.events.into_iter().collect(),
            evicted: self.evicted,
        }
    }
}

/// A finished trace: tracks + events in deterministic record order, ready
/// for the exporters in [`super::export`].
#[derive(Debug, Clone)]
pub struct Trace {
    pub clock_mhz: u64,
    pub tracks: Vec<Track>,
    pub events: Vec<TraceEvent>,
    /// Events lost to the ring bound (0 unless the run outgrew
    /// [`TraceConfig::capacity`]).
    pub evicted: u64,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events with the given name (ledger cross-checks count lifecycle
    /// instants against the report's conservation totals).
    pub fn count(&self, name: &str) -> u64 {
        self.events.iter().filter(|e| e.name == name).count() as u64
    }

    /// Event-name histogram in name order.
    pub fn counts(&self) -> BTreeMap<String, u64> {
        let mut m = BTreeMap::new();
        for e in &self.events {
            *m.entry(e.name.to_string()).or_insert(0u64) += 1;
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bound_evicts_oldest() {
        let mut sink = TraceSink::with_config(
            100,
            TraceConfig {
                capacity: 3,
                layer_detail_every: 1,
            },
        );
        let t = sink.track(TrackKind::Stream, "s0");
        for i in 0..5u64 {
            sink.instant(t, "emit", i, vec![("frame", i.into())]);
        }
        let trace = sink.finish();
        assert_eq!(trace.len(), 3);
        assert_eq!(trace.evicted, 2);
        assert_eq!(trace.events[0].start, 2);
    }

    #[test]
    fn layer_template_partitions_the_service_span_exactly() {
        let mut sink = TraceSink::new(100);
        let t = sink.track(TrackKind::Worker, "w0");
        sink.set_layer_template(vec![
            ("embed".to_string(), 10),
            ("enc0".to_string(), 25),
            ("head".to_string(), 5),
        ]);
        // A service span whose duration differs from the template total:
        // the children must tile [start, start+dur] without gaps.
        sink.service_span(t, "service", 1000, 97, vec![]);
        let trace = sink.finish();
        assert_eq!(trace.len(), 4);
        let kids = &trace.events[1..];
        assert_eq!(kids[0].start, 1000);
        let mut end = 1000;
        for k in kids {
            assert_eq!(k.start, end, "children tile the parent span");
            end = k.start + k.dur.unwrap();
        }
        assert_eq!(end, 1097);
    }

    #[test]
    fn layer_detail_sampling_skips_frames() {
        let mut sink = TraceSink::with_config(
            100,
            TraceConfig {
                capacity: 1 << 10,
                layer_detail_every: 2,
            },
        );
        let t = sink.track(TrackKind::Worker, "w0");
        sink.set_layer_template(vec![("embed".to_string(), 10)]);
        for i in 0..4u64 {
            sink.service_span(t, "service", i * 100, 50, vec![]);
        }
        // 4 service spans, layer detail on frames 0 and 2 only.
        assert_eq!(sink.finish().len(), 6);
    }

    #[test]
    fn track_registration_dedupes() {
        let mut sink = TraceSink::new(100);
        let a = sink.track(TrackKind::Unit, "u0");
        let b = sink.track(TrackKind::Unit, "u0");
        let c = sink.track(TrackKind::Stage, "u0");
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
