//! Trace exporters: Chrome/Perfetto `trace_event` JSON, flamegraph
//! folded stacks, and a plain-text timeline for goldens.
//!
//! All three are pure functions of a [`Trace`] — integer cycles in, the
//! only floating point being the exact division by the clock rate that
//! converts cycles to the microsecond timestamps the `trace_event` format
//! wants — so a deterministic trace exports byte-identically.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use crate::util::json::Json;
use crate::Cycles;

use super::trace::{ArgValue, Trace, TraceEvent, TrackKind};

impl ArgValue {
    fn to_json(&self) -> Json {
        match self {
            ArgValue::U64(v) => Json::from(*v),
            ArgValue::F64(v) => Json::from(*v),
            ArgValue::Str(v) => Json::from(v.as_str()),
        }
    }

    fn render(&self) -> String {
        match self {
            ArgValue::U64(v) => format!("{v}"),
            ArgValue::F64(v) => format!("{v}"),
            ArgValue::Str(v) => v.clone(),
        }
    }
}

impl Trace {
    fn us(&self, cycles: Cycles) -> f64 {
        // clock_mhz cycles per microsecond, exactly.
        cycles as f64 / self.clock_mhz as f64
    }

    /// Chrome/Perfetto `trace_event` JSON: one process per [`TrackKind`],
    /// one thread per track, `X` (complete) events for spans — nested
    /// frame→layer by time containment — and thread-scoped `i` instants.
    /// Load the file in `ui.perfetto.dev` or `chrome://tracing`.
    pub fn to_perfetto(&self) -> Json {
        let mut evs: Vec<Json> = Vec::new();
        let mut pids_seen: Vec<TrackKind> = Vec::new();
        for t in &self.tracks {
            if !pids_seen.contains(&t.kind) {
                pids_seen.push(t.kind);
                evs.push(
                    Json::obj()
                        .set("ph", "M")
                        .set("pid", t.kind.pid())
                        .set("name", "process_name")
                        .set("args", Json::obj().set("name", t.kind.process_name())),
                );
                evs.push(
                    Json::obj()
                        .set("ph", "M")
                        .set("pid", t.kind.pid())
                        .set("name", "process_sort_index")
                        .set("args", Json::obj().set("sort_index", t.kind.pid())),
                );
            }
        }
        for (i, t) in self.tracks.iter().enumerate() {
            let tid = (i + 1) as u64;
            evs.push(
                Json::obj()
                    .set("ph", "M")
                    .set("pid", t.kind.pid())
                    .set("tid", tid)
                    .set("name", "thread_name")
                    .set("args", Json::obj().set("name", t.name.as_str())),
            );
            evs.push(
                Json::obj()
                    .set("ph", "M")
                    .set("pid", t.kind.pid())
                    .set("tid", tid)
                    .set("name", "thread_sort_index")
                    .set("args", Json::obj().set("sort_index", tid)),
            );
        }
        for e in &self.events {
            let track = &self.tracks[e.track.0];
            let mut args = Json::obj();
            for (k, v) in &e.args {
                args = args.set(k, v.to_json());
            }
            let mut j = Json::obj()
                .set("pid", track.kind.pid())
                .set("tid", (e.track.0 + 1) as u64)
                .set("ts", self.us(e.start))
                .set("name", e.name.as_ref())
                .set("args", args);
            j = match e.dur {
                Some(d) => j.set("ph", "X").set("dur", self.us(d)),
                None => j.set("ph", "i").set("s", "t"),
            };
            evs.push(j);
        }
        Json::obj()
            .set("displayTimeUnit", "ms")
            .set("traceEvents", Json::Arr(evs))
            .set(
                "otherData",
                Json::obj()
                    .set("clock_mhz", self.clock_mhz)
                    .set("evicted_events", self.evicted),
            )
    }

    /// Flamegraph folded stacks: one `track;span;nested-span <cycles>`
    /// line per distinct stack, self-cycles (child time subtracted from
    /// the parent), sorted by stack path. Feed to `flamegraph.pl` or any
    /// folded-stack viewer for per-layer cycle aggregation.
    pub fn to_folded(&self) -> String {
        let mut agg: BTreeMap<String, u64> = BTreeMap::new();
        for (ti, track) in self.tracks.iter().enumerate() {
            // Spans of this track, sorted parent-first: by start, then
            // longest-duration (a parent fully contains its children).
            let mut spans: Vec<&TraceEvent> = self
                .events
                .iter()
                .filter(|e| e.track.0 == ti && e.dur.is_some())
                .collect();
            spans.sort_by(|a, b| {
                a.start
                    .cmp(&b.start)
                    .then(b.dur.unwrap().cmp(&a.dur.unwrap()))
            });
            // (name, end, self_cycles) — nesting by time containment.
            let mut stack: Vec<(String, Cycles, u64)> = Vec::new();
            let mut pop = |stack: &mut Vec<(String, Cycles, u64)>,
                           agg: &mut BTreeMap<String, u64>| {
                let (name, _, self_c) = stack.pop().expect("pop on non-empty stack");
                if self_c > 0 {
                    let mut path = track.name.clone();
                    for (n, _, _) in stack.iter() {
                        path.push(';');
                        path.push_str(n);
                    }
                    path.push(';');
                    path.push_str(&name);
                    *agg.entry(path).or_insert(0) += self_c;
                }
            };
            for s in spans {
                let dur = s.dur.unwrap();
                while stack.last().map(|&(_, end, _)| s.start >= end).unwrap_or(false) {
                    pop(&mut stack, &mut agg);
                }
                if let Some(top) = stack.last_mut() {
                    top.2 = top.2.saturating_sub(dur);
                }
                stack.push((s.name.to_string(), s.start + dur, dur));
            }
            while !stack.is_empty() {
                pop(&mut stack, &mut agg);
            }
        }
        let mut out = String::new();
        for (path, cycles) in agg {
            let _ = writeln!(out, "{path} {cycles}");
        }
        out
    }

    /// Plain-text timeline in record order — the golden-friendly dump:
    /// one line per event, integer cycles only.
    pub fn to_timeline(&self) -> String {
        let mut out = format!(
            "# vaqf trace: {} events, {} tracks, clock {} MHz, {} evicted\n",
            self.events.len(),
            self.tracks.len(),
            self.clock_mhz,
            self.evicted
        );
        for e in &self.events {
            let track = &self.tracks[e.track.0];
            let _ = write!(
                out,
                "@{:>12} {:<24} {}",
                e.start,
                format!("{}/{}", track.kind.process_name(), track.name),
                e.name
            );
            if let Some(d) = e.dur {
                let _ = write!(out, " dur={d}");
            }
            for (k, v) in &e.args {
                let _ = write!(out, " {k}={}", v.render());
            }
            out.push('\n');
        }
        out
    }

    /// Write the Perfetto JSON to `path`.
    pub fn save_perfetto(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_perfetto().pretty())
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Write the folded-stacks text to `path`.
    pub fn save_folded(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_folded())
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Write the plain-text timeline to `path`.
    pub fn save_timeline(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_timeline())
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::super::trace::{TraceSink, TrackKind};

    #[test]
    fn perfetto_export_nests_spans_and_is_deterministic() {
        let build = || {
            let mut sink = TraceSink::new(150);
            let w = sink.track(TrackKind::Worker, "worker 0");
            let s = sink.track(TrackKind::Stream, "stream 0");
            sink.set_layer_template(vec![
                ("embed".to_string(), 30),
                ("head".to_string(), 70),
            ]);
            sink.instant(s, "emit", 10, vec![("frame", 0u64.into())]);
            sink.service_span(w, "service", 100, 200, vec![("frame", 0u64.into())]);
            sink.finish()
        };
        let a = build().to_perfetto().pretty();
        let b = build().to_perfetto().pretty();
        assert_eq!(a, b, "export must be byte-identical across runs");
        assert!(a.contains("\"ph\": \"X\"") || a.contains("\"ph\":\"X\""));
        assert!(a.contains("embed") && a.contains("head"));
        assert!(a.contains("thread_name"));
    }

    #[test]
    fn folded_stacks_subtract_child_time() {
        let mut sink = TraceSink::new(100);
        let w = sink.track(TrackKind::Worker, "w0");
        sink.span(w, "service", 0, 100, vec![]);
        sink.span(w, "embed", 0, 40, vec![]);
        sink.span(w, "head", 40, 60, vec![]);
        let folded = sink.finish().to_folded();
        // service self time is fully attributed to its children.
        assert!(folded.contains("w0;service;embed 40\n"), "{folded}");
        assert!(folded.contains("w0;service;head 60\n"), "{folded}");
        assert!(!folded.contains("w0;service 100"), "{folded}");
    }

    #[test]
    fn timeline_lists_every_event() {
        let mut sink = TraceSink::new(100);
        let s = sink.track(TrackKind::Stream, "s0");
        sink.instant(s, "emit", 5, vec![]);
        sink.span(s, "wait", 5, 12, vec![("frame", 3u64.into())]);
        let text = sink.finish().to_timeline();
        assert!(text.contains("emit"));
        assert!(text.contains("dur=12"));
        assert!(text.contains("frame=3"));
        assert_eq!(text.lines().count(), 3);
    }
}
