//! `vaqf::obs` — deterministic tracing, metrics registry, and
//! Perfetto-exportable timelines across serving, pipeline, fleet, and
//! search.
//!
//! The paper's whole pitch is cycle *attribution* (Eqs. 7–11 break a
//! frame into input/weight/output/compute cycles per layer); this module
//! extends that attribution from a single analytic number to observed
//! runs. Three pieces:
//!
//! * [`TraceSink`] / [`Trace`] — typed span/instant events (frame
//!   lifecycle emit→enqueue→dispatch→service→complete/drop/retry,
//!   pipeline stage occupancy and FIFO backpressure stalls, fault
//!   inject/failover/repartition, search rounds) stamped in integer
//!   cycles from the shared virtual clock. Virtual-clock traces are
//!   byte-identical across runs and thread counts; buffering is a
//!   bounded ring with layer-detail sampling ([`TraceConfig`]) so the
//!   serving-bench overhead stays under 2%.
//! * [`MetricsRegistry`] — named counters/gauges/histograms (reusing
//!   `util::stats::Summary`) that the scheduler, fleet balancer, fault
//!   trackers and `SearchCtx` publish into; JSON snapshots are
//!   deterministic.
//! * Exporters on [`Trace`]: Chrome/Perfetto `trace_event` JSON (one
//!   track per worker/stage/unit; frame spans nest into the per-layer
//!   `LayerCycles` breakdown), flamegraph folded stacks, and a
//!   plain-text timeline for goldens.
//!
//! Surfaced as `server().trace(..)` / `fleet().trace_out(..)` /
//! `ShardedDesign::simulate_pipeline_traced`, the `vaqf trace` CLI
//! subcommand, and `--metrics-json` on the serving subcommands.
//!
//! Disabled tracing is a single `Option` branch per simulator event —
//! nothing is allocated, sampled or formatted.

mod export;
mod metrics;
mod trace;

pub use metrics::{latency_ms, latency_pair, rate, MetricsRegistry};
pub use trace::{
    ArgValue, Trace, TraceConfig, TraceEvent, TraceSink, Track, TrackId, TrackKind,
};
