//! Named counters / gauges / histograms, published by the serving
//! scheduler, the fleet balancer, the fault trackers and the compiler's
//! `SearchCtx`, snapshot-exportable as JSON — plus the one canonical
//! latency-block serializer every report shares.
//!
//! The registry is plain data over `BTreeMap`s, so a snapshot serializes
//! in deterministic key order, like every other report in the crate.

use std::collections::BTreeMap;

use crate::compiler::SearchStats;
use crate::coordinator::MultiServingReport;
use crate::fleet::FleetReport;
use crate::shard::PipelineReport;
use crate::util::json::Json;
use crate::util::stats::Summary;

/// The canonical latency block (`Summary::to_ms_json`) — the single
/// helper the coordinator, shard and fleet reports all route through, so
/// every latency object in every report JSON has the same shape.
pub fn latency_ms(s: &Summary) -> Json {
    s.to_ms_json()
}

/// Set the standard `e2e_latency_ms` / `device_latency_ms` pair on a
/// report object.
pub fn latency_pair(j: Json, e2e: &Summary, device: &Summary) -> Json {
    j.set("e2e_latency_ms", latency_ms(e2e))
        .set("device_latency_ms", latency_ms(device))
}

/// Division that returns a well-formed 0.0 instead of NaN/∞ when the
/// denominator is zero — rate fields on empty traces stay finite.
pub fn rate(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// A registry of named metrics. Counters are monotone integers, gauges
/// are point-in-time floats, histograms are frozen [`Summary`] snapshots
/// (reusing `util::stats` — the same quantile implementation every
/// report quotes).
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Summary>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to the counter `name` (creating it at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Set the gauge `name`.
    pub fn gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record the histogram `name` from a frozen summary.
    pub fn histogram(&mut self, name: &str, summary: &Summary) {
        self.histograms.insert(name.to_string(), *summary);
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Deterministic JSON snapshot: `{counters, gauges, histograms}` in
    /// key order; histograms carry the full summary in native units.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters = counters.set(k, *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges = gauges.set(k, *v);
        }
        let mut hists = Json::obj();
        for (k, s) in &self.histograms {
            hists = hists.set(
                k,
                Json::obj()
                    .set("n", s.n)
                    .set("mean", s.mean)
                    .set("min", s.min)
                    .set("p50", s.p50)
                    .set("p95", s.p95)
                    .set("p99", s.p99)
                    .set("max", s.max),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }

    /// Publish a multi-stream serving run: scheduler conservation
    /// counters, per-worker utilization gauges, latency histograms, and
    /// the fault tracker's accounting when a plan was attached.
    pub fn publish_serving(&mut self, r: &MultiServingReport) {
        let a = &r.aggregate;
        self.inc("serving.offered", a.offered);
        self.inc("serving.completed", a.completed);
        self.inc("serving.dropped", a.dropped);
        self.inc("serving.failed", a.failed);
        self.inc("serving.sla_violations", a.sla_violations);
        self.gauge("serving.achieved_fps", a.achieved_fps);
        self.gauge("serving.drop_rate", a.drop_rate);
        self.gauge("serving.elapsed_seconds", r.elapsed_seconds);
        self.histogram("serving.e2e_latency_s", &a.e2e_latency);
        self.histogram("serving.device_latency_s", &a.device_latency);
        for w in &r.workers {
            self.inc(&format!("serving.worker{}.served", w.worker), w.served);
            self.gauge(
                &format!("serving.worker{}.utilization", w.worker),
                w.utilization,
            );
        }
        if let Some(f) = &r.faults {
            self.inc("serving.faults.injected_crashes", f.injected_crashes);
            self.inc("serving.faults.injected_slowdowns", f.injected_slowdowns);
            self.inc("serving.faults.injected_corruptions", f.injected_corruptions);
            self.inc("serving.faults.retries", f.retries);
            self.inc("serving.faults.redispatches", f.redispatches);
            self.inc("serving.faults.timeouts", f.timeouts);
            self.inc("serving.faults.corrupted_frames", f.corrupted_frames);
            self.inc("serving.faults.degraded_frames", f.degraded_frames);
            self.gauge("serving.faults.availability", f.availability);
            self.gauge("serving.faults.mttr_s", f.mttr_s);
        }
    }

    /// Publish a fleet run: balancer-level conservation, per-unit served
    /// counters and utilization gauges, and fleet failover accounting.
    pub fn publish_fleet(&mut self, r: &FleetReport) {
        let a = &r.aggregate;
        self.inc("fleet.offered", a.offered);
        self.inc("fleet.completed", a.completed);
        self.inc("fleet.dropped", a.dropped);
        self.inc("fleet.failed", a.failed);
        self.inc("fleet.sla_violations", a.sla_violations);
        self.gauge("fleet.achieved_fps", a.achieved_fps);
        self.gauge("fleet.drop_rate", a.drop_rate);
        self.gauge("fleet.elapsed_seconds", r.elapsed_seconds);
        self.histogram("fleet.e2e_latency_s", &a.e2e_latency);
        for u in &r.units {
            self.inc(&format!("fleet.unit{}.served", u.unit), u.served);
            self.gauge(&format!("fleet.unit{}.utilization", u.unit), u.utilization);
        }
        if let Some(f) = &r.faults {
            self.inc("fleet.faults.injected_crashes", f.injected_crashes);
            self.inc("fleet.faults.injected_slowdowns", f.injected_slowdowns);
            self.inc("fleet.faults.injected_corruptions", f.injected_corruptions);
            self.inc("fleet.faults.hot_swaps", f.hot_swaps);
            self.inc("fleet.faults.redispatches", f.redispatches);
            self.inc("fleet.faults.retries", f.retries);
            self.inc("fleet.faults.rerun_frames", f.rerun_frames);
            self.gauge("fleet.faults.availability", f.availability);
            self.gauge("fleet.faults.mttr_s", f.mttr_s);
        }
    }

    /// Publish a shard-pipeline run: throughput gauges, per-stage
    /// occupancy, and the failover summary for faulty runs.
    pub fn publish_pipeline(&mut self, r: &PipelineReport) {
        self.inc("pipeline.frames", r.frames);
        self.gauge("pipeline.steady_fps", r.steady_fps);
        self.gauge("pipeline.overall_fps", r.overall_fps);
        self.gauge("pipeline.fill_cycles", r.fill_cycles as f64);
        self.histogram("pipeline.latency_s", &r.latency);
        for s in &r.stages {
            self.inc(&format!("pipeline.stage{}.served", s.stage), s.served);
            self.gauge(&format!("pipeline.stage{}.busy_frac", s.stage), s.busy_frac);
            self.gauge(
                &format!("pipeline.stage{}.blocked_frac", s.stage),
                s.blocked_frac,
            );
        }
        if let Some(f) = &r.faults {
            self.inc("pipeline.faults.injected_crashes", f.injected_crashes);
            self.inc("pipeline.faults.hot_swaps", f.hot_swaps);
            self.inc("pipeline.faults.repartitions", f.repartitions);
            self.inc("pipeline.faults.rerun_frames", f.rerun_frames);
            self.gauge("pipeline.faults.availability", f.availability);
            self.gauge("pipeline.faults.mttr_s", f.mttr_s);
        }
    }

    /// Publish the compiler search telemetry from a [`SearchStats`]
    /// snapshot (a `SearchCtx`'s counters are monotone, so snapshots at
    /// run boundaries compose).
    pub fn publish_search(&mut self, s: &SearchStats) {
        self.inc("search.point_evals", s.point_evals);
        self.inc("search.point_hits", s.point_hits);
        self.inc("search.design_hits", s.design_hits);
        self.inc("search.baseline_hits", s.baseline_hits);
        self.inc("search.planes_pruned", s.planes_pruned);
        self.inc("search.classes_deduped", s.classes_deduped);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_is_deterministic_and_typed() {
        let mut m = MetricsRegistry::new();
        m.inc("b.count", 2);
        m.inc("a.count", 1);
        m.inc("b.count", 3);
        m.gauge("util", 0.5);
        m.histogram("lat", &Summary::from(&[1.0, 2.0, 3.0]));
        let a = m.to_json().pretty();
        let b = m.to_json().pretty();
        assert_eq!(a, b);
        assert_eq!(m.counter("b.count"), Some(5));
        // BTreeMap order: a.count before b.count.
        assert!(a.find("a.count").unwrap() < a.find("b.count").unwrap());
        assert!(a.contains("\"p99\""));
    }

    #[test]
    fn rate_guards_zero_denominators() {
        assert_eq!(rate(5.0, 0.0), 0.0);
        assert_eq!(rate(5.0, -1.0), 0.0);
        assert_eq!(rate(6.0, 2.0), 3.0);
        assert!(rate(0.0, 0.0) == 0.0);
    }
}
