//! `artifacts/manifest.json` — what the AOT step exported.

use std::path::{Path, PathBuf};

use crate::model::VitConfig;
use crate::util::json::Json;

/// One exported model variant.
#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub tag: String,
    pub model: String,
    /// 32 for the unquantized baseline, else the activation precision.
    pub act_bits: u8,
    pub w_bits: u8,
    pub seed: u64,
    pub hlo_path: PathBuf,
    pub params_path: PathBuf,
    pub param_count: usize,
    pub patches_shape: (usize, usize),
    pub num_classes: usize,
    pub config: VitConfig,
}

impl VariantEntry {
    /// The `act_bits` in the crate's `Option` convention.
    pub fn act_bits_opt(&self) -> Option<u8> {
        if self.w_bits == 1 {
            Some(self.act_bits)
        } else {
            None
        }
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub seed: u64,
    pub variants: Vec<VariantEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("reading {}/manifest.json: {e} — run `make artifacts`", dir.display()))?;
        let j = Json::parse(&text)?;
        let seed = j
            .get("seed")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("manifest missing seed"))?;
        let mut variants = Vec::new();
        for v in j
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing variants"))?
        {
            let s = |k: &str| -> anyhow::Result<String> {
                Ok(v.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("variant missing {k}"))?
                    .to_string())
            };
            let n = |k: &str| -> anyhow::Result<u64> {
                v.get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow::anyhow!("variant missing {k}"))
            };
            let cfg = v
                .get("config")
                .ok_or_else(|| anyhow::anyhow!("variant missing config"))?;
            let cn = |k: &str| -> anyhow::Result<usize> {
                cfg.get(k)
                    .and_then(Json::as_u64)
                    .map(|x| x as usize)
                    .ok_or_else(|| anyhow::anyhow!("config missing {k}"))
            };
            let shape = v
                .get("patches_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("variant missing patches_shape"))?;
            variants.push(VariantEntry {
                tag: s("tag")?,
                model: s("model")?,
                act_bits: n("act_bits")? as u8,
                w_bits: n("w_bits")? as u8,
                seed: n("seed")?,
                hlo_path: dir.join(s("hlo")?),
                params_path: dir.join(s("params")?),
                param_count: n("param_count")? as usize,
                patches_shape: (
                    shape[0].as_u64().unwrap_or(0) as usize,
                    shape[1].as_u64().unwrap_or(0) as usize,
                ),
                num_classes: n("num_classes")? as usize,
                config: VitConfig {
                    name: s("model")?,
                    image_size: cn("image_size")?,
                    patch_size: cn("patch_size")?,
                    in_chans: cn("in_chans")?,
                    embed_dim: cn("embed_dim")?,
                    depth: cn("depth")?,
                    num_heads: cn("num_heads")?,
                    mlp_ratio: cn("mlp_ratio")?,
                    num_classes: cn("num_classes")?,
                },
            });
        }
        Ok(Manifest { seed, variants, dir })
    }

    pub fn find(&self, tag: &str) -> Option<&VariantEntry> {
        self.variants.iter().find(|v| v.tag == tag)
    }

    /// Find by (model, act_bits) in the crate convention.
    pub fn find_precision(&self, model: &str, act_bits: Option<u8>) -> Option<&VariantEntry> {
        self.variants
            .iter()
            .find(|v| v.model == model && v.act_bits_opt() == act_bits)
    }
}
