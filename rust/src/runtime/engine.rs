//! The PJRT inference engine.
//!
//! Compiled in two flavours: with the `pjrt` cargo feature the real
//! xla_extension-backed engine below; without it (the default offline
//! build) a stub with the same API whose constructor returns an error, so
//! every caller that guards on `Manifest::load`/`InferenceEngine::new`
//! skips gracefully and the rest of the crate builds with no xla dep.

use std::collections::HashMap;
use std::path::Path;

use crate::Cycles;

use super::manifest::{Manifest, VariantEntry};

/// One compiled model variant: executable + resident parameter literal.
#[cfg(feature = "pjrt")]
pub struct VariantRuntime {
    pub entry: VariantEntry,
    exe: xla::PjRtLoadedExecutable,
    /// Parameters stay on-device (CPU PJRT buffer) across calls — loading
    /// them per frame would dominate the hot path.
    params: xla::PjRtBuffer,
}

/// Multi-variant inference engine over one PJRT client.
#[cfg(feature = "pjrt")]
pub struct InferenceEngine {
    client: xla::PjRtClient,
    variants: HashMap<String, VariantRuntime>,
}

#[cfg(feature = "pjrt")]
impl InferenceEngine {
    /// Create a CPU PJRT client with no variants loaded.
    ///
    /// NOTE on stability: the image's prebuilt xla_extension 0.5.1
    /// intermittently (~20%) SIGSEGVs inside XLA's CPU compilation
    /// pipeline when compiling ViT-sized HLO modules on this host —
    /// reproducible independent of this crate. Compilation is
    /// deterministic, so the workspace installs a process-level
    /// retry-on-SIGSEGV cargo runner (`tools/flaky_xla_runner.sh`) rather
    /// than pinning `--xla_backend_optimization_level=0`, which would slow
    /// the execute hot path ~25×.
    pub fn new() -> anyhow::Result<InferenceEngine> {
        Ok(InferenceEngine {
            client: xla::PjRtClient::cpu()?,
            variants: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact variant, park its parameters on device.
    pub fn load_variant(&mut self, entry: &VariantEntry) -> anyhow::Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            entry
                .hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        let raw = std::fs::read(&entry.params_path)?;
        anyhow::ensure!(
            raw.len() == entry.param_count * 4,
            "params file {} has {} bytes, want {}",
            entry.params_path.display(),
            raw.len(),
            entry.param_count * 4
        );
        let flat: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let lit = xla::Literal::vec1(&flat);
        let params = self
            .client
            .buffer_from_host_literal(None, &lit)?;

        self.variants.insert(
            entry.tag.clone(),
            VariantRuntime {
                entry: entry.clone(),
                exe,
                params,
            },
        );
        Ok(())
    }

    /// Load every variant in a manifest.
    pub fn load_manifest(&mut self, dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let man = Manifest::load(dir)?;
        for v in &man.variants {
            self.load_variant(v)?;
        }
        Ok(man)
    }

    pub fn tags(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    pub fn variant(&self, tag: &str) -> Option<&VariantRuntime> {
        self.variants.get(tag)
    }

    /// Run one frame through `tag`: `patches` is row-major
    /// `N_p × (3·P²)`. Returns the logits.
    pub fn infer(&self, tag: &str, patches: &[f32]) -> anyhow::Result<Vec<f32>> {
        let v = self
            .variants
            .get(tag)
            .ok_or_else(|| anyhow::anyhow!("variant {tag} not loaded"))?;
        let (np, pin) = v.entry.patches_shape;
        anyhow::ensure!(
            patches.len() == np * pin,
            "patches len {} != {np}×{pin}",
            patches.len()
        );
        let lit = xla::Literal::vec1(patches).reshape(&[np as i64, pin as i64])?;
        let input = self.client.buffer_from_host_literal(None, &lit)?;
        let result = v.exe.execute_b(&[&v.params, &input])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True ⇒ 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Hot-path latency helper: run `frames` inferences, return per-frame
    /// seconds (used by the runtime_hotpath bench and the coordinator).
    pub fn time_frames(
        &self,
        tag: &str,
        patches: &[f32],
        frames: usize,
    ) -> anyhow::Result<Vec<f64>> {
        let mut out = Vec::with_capacity(frames);
        for _ in 0..frames {
            let t0 = std::time::Instant::now();
            let _ = self.infer(tag, patches)?;
            out.push(t0.elapsed().as_secs_f64());
        }
        Ok(out)
    }
}

/// Stub variant record for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub struct VariantRuntime {
    pub entry: VariantEntry,
}

/// Stub engine for builds without the `pjrt` feature: same API, but
/// [`InferenceEngine::new`] always errors, so callers fall back to the
/// simulator backend or skip (all in-tree callers check the artifacts
/// manifest and/or this constructor before doing PJRT work).
#[cfg(not(feature = "pjrt"))]
pub struct InferenceEngine {
    variants: HashMap<String, VariantRuntime>,
}

#[cfg(not(feature = "pjrt"))]
impl InferenceEngine {
    pub fn new() -> anyhow::Result<InferenceEngine> {
        anyhow::bail!(
            "PJRT runtime not compiled in: the `pjrt` feature additionally \
             requires declaring the `xla` dependency on an image that ships \
             xla_extension — see the feature notes in rust/Cargo.toml"
        )
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the pjrt feature)".to_string()
    }

    pub fn load_variant(&mut self, entry: &VariantEntry) -> anyhow::Result<()> {
        anyhow::bail!("cannot load variant {}: built without the pjrt feature", entry.tag)
    }

    pub fn load_manifest(&mut self, dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        anyhow::bail!(
            "cannot load manifest {}: built without the pjrt feature",
            dir.as_ref().display()
        )
    }

    pub fn tags(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    pub fn variant(&self, tag: &str) -> Option<&VariantRuntime> {
        self.variants.get(tag)
    }

    pub fn infer(&self, tag: &str, _patches: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::bail!("cannot infer {tag}: built without the pjrt feature")
    }

    pub fn time_frames(
        &self,
        tag: &str,
        _patches: &[f32],
        _frames: usize,
    ) -> anyhow::Result<Vec<f64>> {
        anyhow::bail!("cannot time {tag}: built without the pjrt feature")
    }
}

/// What a backend must provide to the serving coordinator: logits plus the
/// "device" latency. For the PJRT backend the latency is wall-clock; for
/// the simulated-FPGA backend it is simulated cycles at the device clock.
///
/// Deliberately NOT `Send`: the PJRT client wraps thread-affine C
/// pointers, so the coordinator keeps inference on the calling thread and
/// spawns only the frame source.
///
/// `infer` takes `&mut self` because stateful backends (the simulator's
/// prepared-plan executor, the adaptive-precision ladder) reuse an owned
/// workspace across frames.
pub trait InferenceBackend {
    fn name(&self) -> String;
    fn infer(&mut self, patches: &[f32]) -> anyhow::Result<(Vec<f32>, f64)>;
}

/// PJRT-backed implementation of [`InferenceBackend`].
pub struct PjrtBackend {
    pub engine: std::rc::Rc<InferenceEngine>,
    pub tag: String,
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> String {
        format!("pjrt:{}", self.tag)
    }

    fn infer(&mut self, patches: &[f32]) -> anyhow::Result<(Vec<f32>, f64)> {
        let t0 = std::time::Instant::now();
        let logits = self.engine.infer(&self.tag, patches)?;
        Ok((logits, t0.elapsed().as_secs_f64()))
    }
}

/// Simulated-FPGA implementation of [`InferenceBackend`] (functional
/// numerics + simulated latency at the accelerator clock).
pub struct SimBackend {
    pub executor: crate::sim::ModelExecutor,
    /// Pace wall-clock to the simulated latency (realistic serving) or run
    /// as fast as the host allows (throughput studies).
    pub realtime: bool,
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> String {
        format!(
            "sim-fpga:{}@{}",
            self.executor.config().name, self.executor.device().name
        )
    }

    fn infer(&mut self, patches: &[f32]) -> anyhow::Result<(Vec<f32>, f64)> {
        let (logits, trace) = self.executor.run_frame(patches);
        if self.realtime {
            std::thread::sleep(std::time::Duration::from_secs_f64(trace.latency_s));
        }
        Ok((logits, trace.latency_s))
    }
}

/// Convert simulated cycles to seconds at a clock (helper re-export).
pub fn cycles_to_seconds(cycles: Cycles, clock_mhz: u64) -> f64 {
    cycles as f64 / (clock_mhz as f64 * 1e6)
}
