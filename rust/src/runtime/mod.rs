//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! The Rust half of the AOT bridge (see `/opt/xla-example/load_hlo`): HLO
//! *text* from `python/compile/aot.py` → `HloModuleProto::from_text_file`
//! → `PjRtClient::cpu().compile` → `execute`. One compiled executable per
//! model variant; Python never runs on this path.

mod engine;
mod manifest;

pub use engine::{cycles_to_seconds, InferenceBackend, InferenceEngine, PjrtBackend, SimBackend, VariantRuntime};
pub use manifest::{Manifest, VariantEntry};
