//! Whole-model execution on the simulated accelerator.
//!
//! Orchestrates the compute engine over the ViT layer sequence exactly as
//! the board would: matmuls on the fabric, everything else (LayerNorm,
//! softmax, GELU, scaling, skip-adds) on the host CPU (§5.2). The forward
//! semantics are mirrored line-for-line by `python/compile/model.py`, so
//! logits from this executor can be compared against the AOT-compiled JAX
//! model run through the PJRT runtime.
//!
//! Execution is split the way the hardware splits it (see `sim::plan`):
//! the executor builds the per-model [`ExecPlan`] (packed /
//! pre-quantized weights, per-layer cycle accounting) once — lazily,
//! before the first frame, keyed on the engine's backend + parameters —
//! plus a reusable [`Workspace`]; [`ModelExecutor::run_frame`] is the steady-state
//! per-frame loop — no weight-side work, no buffer allocation, attention
//! fanned out across heads. [`ModelExecutor::run_batch`] additionally
//! fans *frames* across workers (each with its own workspace), the shape
//! the multi-stream coordinator and the benches drive. Every variant is
//! bit-identical to the original single-call path.

use std::sync::Arc;

use crate::hw::Device;
use crate::model::{VitConfig, VitStructure};
use crate::perf::AcceleratorParams;
use crate::util::parallel::for_each_task;
use crate::Cycles;

use super::engine::{Backend, ComputeEngine};
use super::plan::{ExecPlan, HeadScratch, Workspace};
use super::timing::LayerTiming;
use super::weights::VitWeights;

/// Per-layer execution record. The name is a refcounted view of the
/// plan's cached label, so recording a trace allocates no strings.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub name: Arc<str>,
    pub engine_cycles: Cycles,
    pub host_cycles: Cycles,
    pub macs: u64,
    pub timing: LayerTiming,
}

/// Whole-frame execution record.
#[derive(Debug, Clone)]
pub struct ExecTrace {
    pub layers: Vec<LayerTrace>,
    pub total_cycles: Cycles,
    /// Frame latency in seconds at the device clock.
    pub latency_s: f64,
}

impl ExecTrace {
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s
    }
}

/// Executes frames on a simulated accelerator instance.
///
/// The per-model compilation step (weight layout + cycle accounting,
/// cached in the [`ExecPlan`]) runs once, lazily, before the first
/// frame; `run_frame`/`run_batch` are the steady-state streaming loop
/// over the owned [`Workspace`].
pub struct ModelExecutor {
    // Model/device state is private: the prepared plan caches weight
    // layouts and timings derived from it, so field mutation after a
    // frame has run would silently mix stale and live state. Read access
    // goes through the accessors below; `engine` stays public because
    // `ensure_plan` re-keys the plan on its backend + parameters.
    config: VitConfig,
    structure: VitStructure,
    weights: VitWeights,
    pub engine: ComputeEngine,
    device: Device,
    /// Prepared lazily for the engine's current backend on first use, so
    /// `new(..).with_backend(..)` lays the weights out exactly once.
    plan: Option<ExecPlan>,
    ws: Workspace,
    /// Extra workspaces for `run_batch`'s frame-parallel workers (grown
    /// lazily on first use, then reused).
    batch_ws: Vec<Workspace>,
}

impl ModelExecutor {
    pub fn new(
        weights: VitWeights,
        act_bits: Option<u8>,
        params: AcceleratorParams,
        device: Device,
    ) -> ModelExecutor {
        assert_eq!(
            params.act_bits, act_bits,
            "accelerator was generated for a different precision"
        );
        let config = weights.config.clone();
        let structure = config.structure(act_bits);
        let engine = ComputeEngine::new(params, device.clone());
        let ws = Workspace::for_config(&config);
        ModelExecutor {
            structure,
            engine,
            device,
            plan: None,
            ws,
            batch_ws: Vec::new(),
            config,
            weights,
        }
    }

    /// Build the prepared plan for the engine's current configuration if
    /// it is missing or was laid out for a different backend or
    /// accelerator parameterization — `engine` is a public field, so
    /// direct mutation of either must stale the cache, not just the
    /// builder methods.
    fn ensure_plan(&mut self) {
        let backend = self.engine.backend;
        let stale = match &self.plan {
            Some(p) => p.backend != backend || p.params != self.engine.params,
            None => true,
        };
        if stale {
            self.plan = Some(ExecPlan::build(
                &self.weights,
                &self.structure,
                &self.engine.params,
                &self.device,
                backend,
            ));
        }
    }

    /// Builder-style override of the engine's kernel backend (scalar
    /// reference vs bit-packed popcount — results are identical, see
    /// `sim::kernels`). The prepared weights are (re)laid out for the new
    /// backend's datapath lazily, on the next frame.
    pub fn with_backend(mut self, backend: Backend) -> ModelExecutor {
        self.engine.backend = backend;
        self
    }

    /// Builder-style override of the engine's row-parallel worker count
    /// (`0` ⇒ environment default via `VAQF_THREADS`).
    pub fn with_threads(mut self, threads: usize) -> ModelExecutor {
        self.engine = self.engine.with_threads(threads);
        self
    }

    /// The prepared per-model execution plan (built on first access).
    pub fn plan(&mut self) -> &ExecPlan {
        self.ensure_plan();
        self.plan.as_ref().expect("plan just ensured")
    }

    pub fn config(&self) -> &VitConfig {
        &self.config
    }

    pub fn structure(&self) -> &VitStructure {
        &self.structure
    }

    pub fn weights(&self) -> &VitWeights {
        &self.weights
    }

    pub fn device(&self) -> &Device {
        &self.device
    }

    /// Run one frame (`patches`: row-major `N_p × (3·P²)`); returns logits
    /// (`num_classes`) and the cycle trace. Steady-state: reuses the
    /// executor's workspace, fans FC rows and attention heads out across
    /// `engine.threads` workers.
    pub fn run_frame(&mut self, patches: &[f32]) -> (Vec<f32>, ExecTrace) {
        self.ensure_plan();
        let plan = self.plan.as_ref().expect("plan just ensured");
        let head_threads = self.engine.threads;
        execute_frame(
            &self.engine,
            &self.structure,
            plan,
            &self.weights,
            &self.config,
            &self.device,
            &mut self.ws,
            patches,
            head_threads,
        )
    }

    // ---- stage-wise execution (the `shard` pipeline's functional path) ----
    //
    // A pipeline stage owns a contiguous run of the model's natural
    // segments — the patch embedding, whole encoder blocks, the head —
    // and hands the `F × M` residual stream to the next stage. The three
    // methods below run exactly the phases `run_frame` composes, on the
    // same workspace, so `stage_embed + stage_blocks(0..depth) +
    // stage_head` is bit-identical to one `run_frame` call (property-
    // tested in `rust/tests/property_suite.rs`).

    /// Run the patch-embedding phase (embed FC + CLS/positional add),
    /// leaving the residual stream in the workspace. Returns the per-layer
    /// traces of the phase.
    pub fn stage_embed(&mut self, patches: &[f32]) -> Vec<LayerTrace> {
        self.ensure_plan();
        let plan = self.plan.as_ref().expect("plan just ensured");
        let mut traces = Vec::with_capacity(1);
        let mut li = 0usize;
        embed_phase(
            &self.engine,
            &self.structure,
            plan,
            &self.weights,
            &self.config,
            &mut self.ws,
            patches,
            &mut li,
            &mut traces,
        );
        traces
    }

    /// Run encoder blocks `blocks` (each block is the qkv/attention/proj/
    /// MLP six-layer group) on the residual stream already in the
    /// workspace.
    pub fn stage_blocks(&mut self, blocks: std::ops::Range<usize>) -> Vec<LayerTrace> {
        assert!(
            blocks.end <= self.config.depth,
            "block range {blocks:?} exceeds model depth {}",
            self.config.depth
        );
        self.ensure_plan();
        let plan = self.plan.as_ref().expect("plan just ensured");
        let head_threads = self.engine.threads;
        let mut traces = Vec::with_capacity(6 * blocks.len());
        let mut li = 1 + 6 * blocks.start;
        for b in blocks {
            block_phase(
                &self.engine,
                &self.structure,
                plan,
                &self.config,
                &mut self.ws,
                b,
                head_threads,
                &mut li,
                &mut traces,
            );
        }
        traces
    }

    /// Run the classifier-head phase on the residual stream already in the
    /// workspace; returns the logits and the phase's traces.
    pub fn stage_head(&mut self) -> (Vec<f32>, Vec<LayerTrace>) {
        self.ensure_plan();
        let plan = self.plan.as_ref().expect("plan just ensured");
        let mut traces = Vec::with_capacity(1);
        let mut li = 1 + 6 * self.config.depth;
        let logits = head_phase(
            &self.engine,
            &self.structure,
            plan,
            &self.config,
            &mut self.ws,
            &mut li,
            &mut traces,
        );
        (logits, traces)
    }

    /// The residual stream (`F × M`) — the payload one pipeline stage
    /// hands to the next.
    pub fn residual(&self) -> &[f32] {
        &self.ws.x
    }

    /// Load a residual stream received from an upstream pipeline stage.
    pub fn set_residual(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.ws.x.len(), "residual stream shape mismatch");
        self.ws.x.copy_from_slice(x);
    }

    /// Run a batch of frames, amortizing plan + workspace + dispatch:
    /// frames fan out across up to `engine.threads` workers (one
    /// workspace each). Full batches run one thread per frame —
    /// independent frames keep every worker busy with no fork/join
    /// stalls; batches smaller than the pool hand the leftover threads
    /// to each worker's intra-frame fan-out instead of idling them.
    /// Results are bit-identical to calling
    /// [`ModelExecutor::run_frame`] per frame, in order.
    pub fn run_batch<P>(&mut self, frames: &[P]) -> Vec<(Vec<f32>, ExecTrace)>
    where
        P: AsRef<[f32]> + Sync,
    {
        if frames.is_empty() {
            return Vec::new();
        }
        self.ensure_plan();
        let workers = self.engine.threads.min(frames.len()).max(1);
        if workers == 1 {
            return frames.iter().map(|p| self.run_frame(p.as_ref())).collect();
        }
        while self.batch_ws.len() < workers - 1 {
            self.batch_ws.push(Workspace::for_config(&self.config));
        }
        // Small batches split the pool: each worker keeps its share of
        // the thread budget for intra-frame fan-out (full batches ⇒ 1).
        let per_worker = (self.engine.threads / workers).max(1);
        let engine1 = self.engine.clone().with_threads(per_worker);
        let chunk = frames.len().div_ceil(workers);
        let mut results: Vec<Option<(Vec<f32>, ExecTrace)>> =
            (0..frames.len()).map(|_| None).collect();
        let structure = &self.structure;
        let plan = self.plan.as_ref().expect("plan just ensured");
        let weights = &self.weights;
        let config = &self.config;
        let device = &self.device;
        // One job per worker: (result slots, frames, workspace) — fanned
        // out by the shared task driver. Frame work always dwarfs spawn
        // cost, so the cutoff is disabled with a saturating estimate.
        let ws_iter = std::iter::once(&mut self.ws).chain(self.batch_ws.iter_mut());
        let mut jobs: Vec<(&mut [Option<(Vec<f32>, ExecTrace)>], &[P], &mut Workspace)> = results
            .chunks_mut(chunk)
            .zip(frames.chunks(chunk))
            .zip(ws_iter)
            .map(|((slots, fr), ws)| (slots, fr, ws))
            .collect();
        let eng = &engine1;
        for_each_task(&mut jobs, workers, u64::MAX, |_, (slots, fr, ws)| {
            for (slot, p) in slots.iter_mut().zip(fr.iter()) {
                *slot = Some(execute_frame(
                    eng,
                    structure,
                    plan,
                    weights,
                    config,
                    device,
                    ws,
                    p.as_ref(),
                    per_worker,
                ));
            }
        });
        results
            .into_iter()
            .map(|r| r.expect("all frames executed"))
            .collect()
    }
}

/// Record the trace entry for structure layer `*li` and advance the
/// walk. The name is a refcounted view of the plan's cached label.
fn record_layer(
    structure: &VitStructure,
    plan: &ExecPlan,
    li: &mut usize,
    macs: u64,
    traces: &mut Vec<LayerTrace>,
) {
    debug_assert_eq!(
        macs,
        structure.layers[*li].macs(),
        "MAC mismatch for {}",
        structure.layers[*li].name
    );
    let acct = &plan.timings[*li];
    traces.push(LayerTrace {
        name: Arc::clone(&acct.name),
        engine_cycles: acct.timing.total,
        host_cycles: acct.host,
        macs,
        timing: acct.timing,
    });
    *li += 1;
}

/// Patch embedding (always fixed16) + CLS/positional add (host): fills
/// the workspace residual stream `ws.x` from raw patches. `*li` must be
/// the patch-embed layer's structure index (0).
#[allow(clippy::too_many_arguments)]
fn embed_phase(
    engine: &ComputeEngine,
    structure: &VitStructure,
    plan: &ExecPlan,
    weights: &VitWeights,
    cfg: &VitConfig,
    ws: &mut Workspace,
    patches: &[f32],
    li: &mut usize,
    traces: &mut Vec<LayerTrace>,
) {
    let m = cfg.embed_dim;
    let np = cfg.num_patches();
    let macs = engine.fc_prepared(patches, &plan.patch, np, &mut ws.fc, &mut ws.pe);
    record_layer(structure, plan, li, macs, traces);
    ws.x[..m].copy_from_slice(&weights.cls);
    ws.x[m..].copy_from_slice(&ws.pe);
    for (xi, pi) in ws.x.iter_mut().zip(&weights.pos) {
        *xi += pi;
    }
}

/// One encoder block (LN1 → QKV → attention → proj+skip → LN2 → MLP →
/// skip) over the workspace residual stream. `*li` must be the block's
/// first structure-layer index (`1 + 6·block`).
#[allow(clippy::too_many_arguments)]
fn block_phase(
    engine: &ComputeEngine,
    structure: &VitStructure,
    plan: &ExecPlan,
    cfg: &VitConfig,
    ws: &mut Workspace,
    block: usize,
    head_threads: usize,
    li: &mut usize,
    traces: &mut Vec<LayerTrace>,
) {
    let m = cfg.embed_dim;
    let f = cfg.tokens();
    let nh = cfg.num_heads;
    let mh = cfg.head_dim();
    let Workspace {
        x,
        h,
        qkv,
        attn_heads,
        attn_concat,
        proj_out,
        mlp1_out,
        gelu: gelu_buf,
        mlp2_out,
        fc,
        heads,
        ..
    } = ws;
    let lw = &plan.layers[block];

    let attn_scale = 1.0 / (mh as f32).sqrt();
    let qk_macs_per_head = (f * mh * f) as u64;
    let sv_macs_per_head = (f * f * mh) as u64;

    // LN1 (host) → QKV.
    layer_norm_into(x, f, m, h);
    let macs = engine.fc_prepared(h, &lw.qkv, f, fc, qkv);
    record_layer(structure, plan, li, macs, traces);

    // Attention, one independent task per head: head `hd` reads the
    // q/k/v column blocks [0,M), [M,2M), [2M,3M) of the shared QKV
    // output and writes its own F × M_h slice of `attn_heads` through
    // its own scratch — embarrassingly parallel, bit-identical to the
    // serial head loop.
    {
        let qkv_ro: &[f32] = qkv;
        let mut tasks: Vec<(&mut HeadScratch, &mut [f32])> = heads
            .iter_mut()
            .zip(attn_heads.chunks_mut(f * mh))
            .collect();
        let head_work = qk_macs_per_head + sv_macs_per_head;
        for_each_task(&mut tasks, head_threads, head_work, |hd, (hs, out)| {
            let qcol = hd * mh;
            let kcol = m + hd * mh;
            let vcol = 2 * m + hd * mh;
            for i in 0..f {
                let row = &qkv_ro[i * 3 * m..(i + 1) * 3 * m];
                hs.q[i * mh..(i + 1) * mh].copy_from_slice(&row[qcol..qcol + mh]);
                hs.k[i * mh..(i + 1) * mh].copy_from_slice(&row[kcol..kcol + mh]);
                hs.v[i * mh..(i + 1) * mh].copy_from_slice(&row[vcol..vcol + mh]);
            }
            // Kᵀ: mh × f.
            for i in 0..f {
                for j in 0..mh {
                    hs.kt[j * f + i] = hs.k[i * mh + j];
                }
            }
            // Q·Kᵀ on the engine, then host scaling + softmax.
            engine.attn_matmul(&hs.q, &hs.kt, f, mh, f, &mut hs.attn, &mut hs.s);
            for v in hs.s.iter_mut() {
                *v *= attn_scale;
            }
            softmax_rows(&mut hs.s, f, f);
            // S·V on the engine, straight into this head's slice.
            engine.attn_matmul(&hs.s, &hs.v, f, f, mh, &mut hs.attn, out);
        });
    }
    // Reorder head-major → row-major F × M.
    for hd in 0..nh {
        let head_out = &attn_heads[hd * f * mh..(hd + 1) * f * mh];
        for i in 0..f {
            attn_concat[i * m + hd * mh..i * m + (hd + 1) * mh]
                .copy_from_slice(&head_out[i * mh..(i + 1) * mh]);
        }
    }
    record_layer(structure, plan, li, qk_macs_per_head * nh as u64, traces);
    record_layer(structure, plan, li, sv_macs_per_head * nh as u64, traces);

    // Projection + skip.
    let macs = engine.fc_prepared(attn_concat, &lw.proj, f, fc, proj_out);
    record_layer(structure, plan, li, macs, traces);
    for (xi, pi) in x.iter_mut().zip(proj_out.iter()) {
        *xi += pi;
    }

    // LN2 → MLP → skip.
    layer_norm_into(x, f, m, h);
    let macs = engine.fc_prepared(h, &lw.mlp1, f, fc, mlp1_out);
    record_layer(structure, plan, li, macs, traces);
    for (g, &v) in gelu_buf.iter_mut().zip(mlp1_out.iter()) {
        *g = gelu(v);
    }
    let macs = engine.fc_prepared(gelu_buf, &lw.mlp2, f, fc, mlp2_out);
    record_layer(structure, plan, li, macs, traces);
    for (xi, mi) in x.iter_mut().zip(mlp2_out.iter()) {
        *xi += mi;
    }
}

/// Classifier head: LN(x[0]) @ W_out (always fixed16). `*li` must be the
/// head layer's structure index (`1 + 6·depth`).
fn head_phase(
    engine: &ComputeEngine,
    structure: &VitStructure,
    plan: &ExecPlan,
    cfg: &VitConfig,
    ws: &mut Workspace,
    li: &mut usize,
    traces: &mut Vec<LayerTrace>,
) -> Vec<f32> {
    let m = cfg.embed_dim;
    layer_norm_into(&ws.x[..m], 1, m, &mut ws.cls);
    let mut logits = vec![0.0f32; cfg.num_classes];
    let macs = engine.fc_prepared(&ws.cls, &plan.head, 1, &mut ws.fc, &mut logits);
    record_layer(structure, plan, li, macs, traces);
    logits
}

/// One frame through the prepared plan, using `ws` as the buffer arena.
/// `head_threads` caps the attention fan-out (inside batch workers it is
/// the worker's share of the thread pool — 1 for full batches).
/// Pure in everything but `ws`'s scratch contents — identical results for
/// every thread count and every workspace history.
#[allow(clippy::too_many_arguments)]
fn execute_frame(
    engine: &ComputeEngine,
    structure: &VitStructure,
    plan: &ExecPlan,
    weights: &VitWeights,
    cfg: &VitConfig,
    device: &Device,
    ws: &mut Workspace,
    patches: &[f32],
    head_threads: usize,
) -> (Vec<f32>, ExecTrace) {
    let mut traces: Vec<LayerTrace> = Vec::with_capacity(structure.layers.len());
    let mut li = 0usize;

    embed_phase(engine, structure, plan, weights, cfg, ws, patches, &mut li, &mut traces);
    for block in 0..cfg.depth {
        block_phase(
            engine,
            structure,
            plan,
            cfg,
            ws,
            block,
            head_threads,
            &mut li,
            &mut traces,
        );
    }
    let logits = head_phase(engine, structure, plan, cfg, ws, &mut li, &mut traces);
    assert_eq!(li, structure.layers.len(), "layer walk drifted");

    let total: Cycles = traces.iter().map(|t| t.engine_cycles + t.host_cycles).sum();
    let trace = ExecTrace {
        latency_s: device.cycles_to_seconds(total),
        total_cycles: total,
        layers: traces,
    };
    (logits, trace)
}

/// The pre-plan forward pass, kept verbatim as a reference oracle: the
/// self-contained engine calls ([`ComputeEngine::fc_fixed16`] /
/// [`ComputeEngine::fc_binary`] / [`ComputeEngine::qq_matmul`]) that
/// re-lay the weights out on every call, fresh `Vec`s for every buffer,
/// serial attention heads. The prepared executor must reproduce this
/// bit-for-bit (property-swept in `rust/tests/property_suite.rs`), and
/// `benches/runtime_hotpath.rs` times it as the before-side of the
/// prepared-model comparison. Whether the binary-FC path runs depends on
/// `engine.params.act_bits`, exactly like the executor.
pub fn reference_forward(engine: &ComputeEngine, w: &VitWeights, patches: &[f32]) -> Vec<f32> {
    let cfg = &w.config;
    let quantized = engine.params.act_bits.is_some();
    let m = cfg.embed_dim;
    let f = cfg.tokens();
    let np = cfg.num_patches();
    let nh = cfg.num_heads;
    let mh = cfg.head_dim();
    let hidden = m * cfg.mlp_ratio;
    let patch_in = cfg.in_chans * cfg.patch_size * cfg.patch_size;

    let pe = engine.fc_fixed16(patches, &w.patch, np, patch_in, m);
    let mut x = vec![0.0f32; f * m];
    x[..m].copy_from_slice(&w.cls);
    x[m..].copy_from_slice(&pe.out);
    for (xi, pi) in x.iter_mut().zip(&w.pos) {
        *xi += pi;
    }

    for lw in &w.layers {
        let h = layer_norm(&x, f, m);
        let qkv = if quantized {
            engine.fc_binary(&h, &lw.qkv_bin, f)
        } else {
            engine.fc_fixed16(&h, &lw.qkv, f, m, 3 * m)
        };
        let scale = 1.0 / (mh as f32).sqrt();
        let mut attn_concat = vec![0.0f32; f * m];
        for hd in 0..nh {
            let slice = |col: usize| -> Vec<f32> {
                let mut out = vec![0.0f32; f * mh];
                for i in 0..f {
                    out[i * mh..(i + 1) * mh]
                        .copy_from_slice(&qkv.out[i * 3 * m + col..i * 3 * m + col + mh]);
                }
                out
            };
            let q = slice(hd * mh);
            let k = slice(m + hd * mh);
            let v = slice(2 * m + hd * mh);
            let mut kt = vec![0.0f32; mh * f];
            for i in 0..f {
                for j in 0..mh {
                    kt[j * f + i] = k[i * mh + j];
                }
            }
            let s_raw = if quantized {
                engine.qq_matmul(&q, &kt, f, mh, f)
            } else {
                engine.fc_fixed16(&q, &kt, f, mh, f)
            };
            let mut s = s_raw.out;
            for v in s.iter_mut() {
                *v *= scale;
            }
            softmax_rows(&mut s, f, f);
            let o = if quantized {
                engine.qq_matmul(&s, &v, f, f, mh)
            } else {
                engine.fc_fixed16(&s, &v, f, f, mh)
            };
            for i in 0..f {
                attn_concat[i * m + hd * mh..i * m + (hd + 1) * mh]
                    .copy_from_slice(&o.out[i * mh..(i + 1) * mh]);
            }
        }
        let proj = if quantized {
            engine.fc_binary(&attn_concat, &lw.proj_bin, f)
        } else {
            engine.fc_fixed16(&attn_concat, &lw.proj, f, m, m)
        };
        for (xi, pi) in x.iter_mut().zip(&proj.out) {
            *xi += pi;
        }
        let h2 = layer_norm(&x, f, m);
        let m1 = if quantized {
            engine.fc_binary(&h2, &lw.mlp1_bin, f)
        } else {
            engine.fc_fixed16(&h2, &lw.mlp1, f, m, hidden)
        };
        let g: Vec<f32> = m1.out.iter().map(|&v| gelu(v)).collect();
        let m2 = if quantized {
            engine.fc_binary(&g, &lw.mlp2_bin, f)
        } else {
            engine.fc_fixed16(&g, &lw.mlp2, f, hidden, m)
        };
        for (xi, mi) in x.iter_mut().zip(&m2.out) {
            *xi += mi;
        }
    }

    let cls_repr = layer_norm(&x[..m], 1, m);
    engine
        .fc_fixed16(&cls_repr, &w.head, 1, m, cfg.num_classes)
        .out
}

/// Non-affine LayerNorm over the last dimension, eps = 1e-6 (matches
/// `model.py::layer_norm`).
pub fn layer_norm(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    layer_norm_into(x, rows, cols, &mut out);
    out
}

/// [`layer_norm`] into a caller-owned buffer (the workspace path).
pub fn layer_norm_into(x: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(out.len(), rows * cols);
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mean = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + 1e-6).sqrt();
        for c in 0..cols {
            out[r * cols + c] = (row[c] - mean) * inv;
        }
    }
}

/// Row-wise softmax (host op).
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// GELU, tanh approximation (JAX's default `approximate=True`).
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f64).tanh() as f32)
}
