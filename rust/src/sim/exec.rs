//! Whole-model execution on the simulated accelerator.
//!
//! Orchestrates the compute engine over the ViT layer sequence exactly as
//! the board would: matmuls on the fabric, everything else (LayerNorm,
//! softmax, GELU, scaling, skip-adds) on the host CPU (§5.2). The forward
//! semantics are mirrored line-for-line by `python/compile/model.py`, so
//! logits from this executor can be compared against the AOT-compiled JAX
//! model run through the PJRT runtime.

use crate::hw::Device;
use crate::model::{VitConfig, VitStructure};
use crate::perf::{layer_cycles, AcceleratorParams};
use crate::Cycles;

use super::engine::{Backend, ComputeEngine};
use super::timing::{layer_timing, LayerTiming};
use super::weights::VitWeights;

/// Per-layer execution record.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub name: String,
    pub engine_cycles: Cycles,
    pub host_cycles: Cycles,
    pub macs: u64,
    pub timing: LayerTiming,
}

/// Whole-frame execution record.
#[derive(Debug, Clone)]
pub struct ExecTrace {
    pub layers: Vec<LayerTrace>,
    pub total_cycles: Cycles,
    /// Frame latency in seconds at the device clock.
    pub latency_s: f64,
}

impl ExecTrace {
    pub fn fps(&self) -> f64 {
        1.0 / self.latency_s
    }
}

/// Executes frames on a simulated accelerator instance.
pub struct ModelExecutor {
    pub config: VitConfig,
    pub structure: VitStructure,
    pub weights: VitWeights,
    pub engine: ComputeEngine,
    pub device: Device,
    quantized: bool,
}

impl ModelExecutor {
    pub fn new(
        weights: VitWeights,
        act_bits: Option<u8>,
        params: AcceleratorParams,
        device: Device,
    ) -> ModelExecutor {
        assert_eq!(
            params.act_bits, act_bits,
            "accelerator was generated for a different precision"
        );
        let config = weights.config.clone();
        ModelExecutor {
            structure: config.structure(act_bits),
            engine: ComputeEngine::new(params, device.clone()),
            device,
            config,
            weights,
            quantized: act_bits.is_some(),
        }
    }

    /// Builder-style override of the engine's kernel backend (scalar
    /// reference vs bit-packed popcount — results are identical, see
    /// `sim::kernels`).
    pub fn with_backend(mut self, backend: Backend) -> ModelExecutor {
        self.engine.backend = backend;
        self
    }

    /// Builder-style override of the engine's row-parallel worker count
    /// (`0` ⇒ environment default via `VAQF_THREADS`).
    pub fn with_threads(mut self, threads: usize) -> ModelExecutor {
        self.engine = self.engine.with_threads(threads);
        self
    }

    /// Run one frame (`patches`: row-major `N_p × (3·P²)`); returns logits
    /// (`num_classes`) and the cycle trace.
    pub fn run_frame(&self, patches: &[f32]) -> (Vec<f32>, ExecTrace) {
        let cfg = &self.config;
        let m = cfg.embed_dim;
        let f = cfg.tokens();
        let np = cfg.num_patches();
        let nh = cfg.num_heads;
        let mh = cfg.head_dim();
        let hidden = m * cfg.mlp_ratio;
        let w = &self.weights;

        let mut traces: Vec<LayerTrace> = Vec::new();
        let mut li = 0usize; // index into structure.layers
        let mut record = |idx: &mut usize, macs: u64, executor: &ModelExecutor| {
            let desc = &executor.structure.layers[*idx];
            debug_assert_eq!(macs, desc.macs(), "MAC mismatch for {}", desc.name);
            let timing = layer_timing(desc, &executor.engine.params, &executor.device);
            let host = layer_cycles(desc, &executor.engine.params, &executor.device).host;
            let t = LayerTrace {
                name: desc.name.clone(),
                engine_cycles: timing.total,
                host_cycles: host,
                macs,
                timing,
            };
            *idx += 1;
            t
        };

        // ---- patch embedding (always fixed16) + CLS/pos (host) ----------
        let patch_in = cfg.in_chans * cfg.patch_size * cfg.patch_size;
        let pe = self.engine.fc_fixed16(patches, &w.patch, np, patch_in, m);
        traces.push(record(&mut li, pe.macs, self));
        let mut x = vec![0.0f32; f * m];
        x[..m].copy_from_slice(&w.cls);
        x[m..].copy_from_slice(&pe.out);
        for (xi, pi) in x.iter_mut().zip(&w.pos) {
            *xi += pi;
        }

        // ---- encoder layers ----------------------------------------------
        for lw in &w.layers {
            // LN1 (host) → QKV.
            let h = layer_norm(&x, f, m);
            let qkv = if self.quantized {
                self.engine.fc_binary(&h, &lw.qkv_bin, f)
            } else {
                self.engine.fc_fixed16(&h, &lw.qkv, f, m, 3 * m)
            };
            traces.push(record(&mut li, qkv.macs, self));

            // Split heads: q/k/v live at column blocks [0,M), [M,2M), [2M,3M).
            let scale = 1.0 / (mh as f32).sqrt();
            let mut attn_concat = vec![0.0f32; f * m];
            let mut qk_macs = 0u64;
            let mut sv_macs = 0u64;
            for hd in 0..nh {
                let qcol = hd * mh;
                let kcol = m + hd * mh;
                let vcol = 2 * m + hd * mh;
                let slice = |col: usize| -> Vec<f32> {
                    let mut out = vec![0.0f32; f * mh];
                    for i in 0..f {
                        out[i * mh..(i + 1) * mh]
                            .copy_from_slice(&qkv.out[i * 3 * m + col..i * 3 * m + col + mh]);
                    }
                    out
                };
                let q = slice(qcol);
                let k = slice(kcol);
                let v = slice(vcol);
                // Kᵀ: mh × f.
                let mut kt = vec![0.0f32; mh * f];
                for i in 0..f {
                    for j in 0..mh {
                        kt[j * f + i] = k[i * mh + j];
                    }
                }
                // Q·Kᵀ on the engine, then host scaling + softmax.
                let s_raw = if self.quantized {
                    self.engine.qq_matmul(&q, &kt, f, mh, f)
                } else {
                    self.engine.fc_fixed16(&q, &kt, f, mh, f)
                };
                qk_macs += s_raw.macs;
                let mut s = s_raw.out;
                for v in s.iter_mut() {
                    *v *= scale;
                }
                softmax_rows(&mut s, f, f);
                // S·V on the engine.
                let o = if self.quantized {
                    self.engine.qq_matmul(&s, &v, f, f, mh)
                } else {
                    self.engine.fc_fixed16(&s, &v, f, f, mh)
                };
                sv_macs += o.macs;
                for i in 0..f {
                    attn_concat[i * m + hd * mh..i * m + (hd + 1) * mh]
                        .copy_from_slice(&o.out[i * mh..(i + 1) * mh]);
                }
            }
            traces.push(record(&mut li, qk_macs, self));
            traces.push(record(&mut li, sv_macs, self));

            // Projection + skip.
            let proj = if self.quantized {
                self.engine.fc_binary(&attn_concat, &lw.proj_bin, f)
            } else {
                self.engine.fc_fixed16(&attn_concat, &lw.proj, f, m, m)
            };
            traces.push(record(&mut li, proj.macs, self));
            for (xi, pi) in x.iter_mut().zip(&proj.out) {
                *xi += pi;
            }

            // LN2 → MLP → skip.
            let h2 = layer_norm(&x, f, m);
            let m1 = if self.quantized {
                self.engine.fc_binary(&h2, &lw.mlp1_bin, f)
            } else {
                self.engine.fc_fixed16(&h2, &lw.mlp1, f, m, hidden)
            };
            traces.push(record(&mut li, m1.macs, self));
            let g: Vec<f32> = m1.out.iter().map(|&v| gelu(v)).collect();
            let m2 = if self.quantized {
                self.engine.fc_binary(&g, &lw.mlp2_bin, f)
            } else {
                self.engine.fc_fixed16(&g, &lw.mlp2, f, hidden, m)
            };
            traces.push(record(&mut li, m2.macs, self));
            for (xi, mi) in x.iter_mut().zip(&m2.out) {
                *xi += mi;
            }
        }

        // ---- head: LN(x[0]) @ W_out (always fixed16) ----------------------
        let cls_repr = layer_norm(&x[..m], 1, m);
        let logits = self
            .engine
            .fc_fixed16(&cls_repr, &w.head, 1, m, cfg.num_classes);
        traces.push(record(&mut li, logits.macs, self));
        assert_eq!(li, self.structure.layers.len(), "layer walk drifted");

        let total: Cycles = traces.iter().map(|t| t.engine_cycles + t.host_cycles).sum();
        let trace = ExecTrace {
            latency_s: self.device.cycles_to_seconds(total),
            total_cycles: total,
            layers: traces,
        };
        (logits.out, trace)
    }
}

/// Non-affine LayerNorm over the last dimension, eps = 1e-6 (matches
/// `model.py::layer_norm`).
pub fn layer_norm(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mean = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + 1e-6).sqrt();
        for c in 0..cols {
            out[r * cols + c] = (row[c] - mean) * inv;
        }
    }
    out
}

/// Row-wise softmax (host op).
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// GELU, tanh approximation (JAX's default `approximate=True`).
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608 * (x + 0.044715 * x * x * x)) as f64).tanh() as f32)
}
