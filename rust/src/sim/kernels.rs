//! Matmul kernels for the compute engine: the scalar reference datapaths
//! and the bit-packed XNOR/popcount datapaths (§5.1 + §5.3.1).
//!
//! Each kernel computes a contiguous block of output rows — the unit the
//! row-parallel driver (`util::parallel`) fans out across threads. Every
//! kernel takes its scratch (the per-row accumulator or bit-plane
//! decomposition) as a caller-owned buffer: the executor's
//! [`super::Workspace`] owns one scratch per attention head (zero heap
//! traffic in steady state), and the row-parallel chunk bodies own one
//! small scratch per chunk — amortized over every row in the chunk
//! instead of reallocated per row, as the pre-plan code did, so nothing
//! in the loop allocates proportionally to rows or elements.
//!
//! All integer paths accumulate exactly and convert to f32 once at the
//! end, and integer addition is associative, so **scalar, packed and
//! compact results are bit-identical** — the scalar path stays as the
//! reference oracle (`rust/tests/property_suite.rs` sweeps the
//! equivalence).
//!
//! The packed binary-FC kernel is the software analog of the LUT array:
//! weight signs live as column-major 64-lane bitmaps (`SignPlanes`), the
//! activation row is decomposed into two's-complement bit-planes, and
//! each plane's ±1 dot is `2·popcount(plane ∧ signs) − popcount(plane)`
//! (equivalently `popcount(XNOR masked to the plane)`), shift-accumulated
//! with the plane coefficient. One 64-bit AND+popcount replaces 64 scalar
//! multiply-adds, so per-output work drops from `n` MACs to
//! `bits · ⌈n/64⌉` word ops — ≥ 4× for every `bits ≤ 16`, ~8× at the
//! paper's W1A8 operating point (measured in `benches/runtime_hotpath.rs`,
//! recorded in BENCH_hotpath.json; methodology in EXPERIMENTS.md §Perf).
//! Since PR 8 the word loops themselves run on the runtime-dispatched
//! SIMD tiers of `util::simd` (scalar / AVX2 / opt-in AVX-512) and the
//! binary FC walks its operands in L1-sized row-block × column-panel
//! tiles — same sums in the same order, so bit-exactness is untouched.

use std::fmt;

use crate::quant::{
    acc_to_fixed16, from_fixed16, pack_bit_planes_into, plane_coeff, popcount_and_dot,
    xnor_sign_dot, BitPlanes, ColPlanes, SignPlanes,
};

/// Which compute datapath implementation the engine runs.
///
/// * `Scalar` — the original element-streaming integer loops: the
///   reference oracle, kept bit-exact forever.
/// * `Packed` — bit-plane + popcount kernels over `u64` lane words (the
///   default): same results, a fraction of the work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    Scalar,
    #[default]
    Packed,
}

impl Backend {
    /// Backend-name hint for error messages (keep in sync with
    /// [`Backend::from_name`]).
    pub const NAMES: &'static str = "scalar|packed";

    /// Parse a backend name (CLI/config/env surface).
    pub fn from_name(name: &str) -> Option<Backend> {
        match name.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Backend::Scalar),
            "packed" => Some(Backend::Packed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Packed => "packed",
        }
    }

    /// Default backend, overridable with `VAQF_BACKEND=scalar|packed`.
    pub fn from_env() -> Backend {
        std::env::var("VAQF_BACKEND")
            .ok()
            .and_then(|v| Backend::from_name(&v))
            .unwrap_or_default()
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Reset `acc` to `len` zeroed entries without shrinking its capacity —
/// the per-call warm-up of a reusable accumulator row.
#[inline]
fn reset_acc<T: Copy + Default>(acc: &mut Vec<T>, len: usize) {
    acc.clear();
    acc.resize(len, T::default());
}

/// Fixed-point DSP path: `xq` holds `rows × n` Q6.10 inputs, `wq` the full
/// `n × m` weight matrix; writes `rows × m` into `out`. `acc_row` is the
/// caller's reusable `m`-wide accumulator.
// Hot path (§Perf): i-p-j loop order with a per-row i64 accumulator keeps
// the inner loop streaming over the contiguous weight row — ~3.5× over the
// naive i-j-p order (see EXPERIMENTS.md §Perf).
pub(crate) fn fixed16_rows(
    xq: &[i16],
    wq: &[i16],
    n: usize,
    m: usize,
    out: &mut [f32],
    acc_row: &mut Vec<i64>,
) {
    let rows = out.len() / m;
    debug_assert_eq!(xq.len(), rows * n);
    reset_acc(acc_row, m);
    for i in 0..rows {
        acc_row.fill(0);
        let xrow = &xq[i * n..(i + 1) * n];
        for (p, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i64;
            let wrow = &wq[p * m..(p + 1) * m];
            for (acc, &wv) in acc_row.iter_mut().zip(wrow) {
                *acc += xv * wv as i64;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(acc_row.iter()) {
            *o = from_fixed16(acc_to_fixed16(acc));
        }
    }
}

/// Binary-weight FC, scalar reference: `signs` is the row-major ±1
/// materialization of the weight matrix (LUT-array analog: sign bits
/// resident in BRAM — stored as `i8`, the narrowest type the stream
/// needs), streamed contiguously in the inner loop.
pub(crate) fn binary_rows_scalar(
    xq: &[i32],
    signs: &[i8],
    n: usize,
    m: usize,
    scale: f32,
    out: &mut [f32],
    acc_row: &mut Vec<i64>,
) {
    let rows = out.len() / m;
    debug_assert_eq!(xq.len(), rows * n);
    debug_assert_eq!(signs.len(), n * m);
    reset_acc(acc_row, m);
    for i in 0..rows {
        acc_row.fill(0);
        let xrow = &xq[i * n..(i + 1) * n];
        for (p, &qv) in xrow.iter().enumerate() {
            if qv == 0 {
                continue;
            }
            let qv = qv as i64;
            let srow = &signs[p * m..(p + 1) * m];
            for (acc, &s) in acc_row.iter_mut().zip(srow) {
                *acc += qv * s as i64;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(acc_row.iter()) {
            *o = acc as f32 * scale;
        }
    }
}

/// L1 working-set target per tile operand, in bytes. Half a typical
/// 32 KiB L1d: one half for the row block's activation planes, one for
/// the column panel's weight bitmaps, leaving slack for accumulators.
const L1_TILE_BYTES: usize = 16 * 1024;

/// Upper bound on rows packed per block (also sizes the fixed on-stack
/// `row_const` array — no per-tile heap traffic).
const MAX_ROW_BLOCK: usize = 16;

/// Rows per block: as many rows' bit-plane decompositions as fit the L1
/// tile target, ≥ 1, ≤ [`MAX_ROW_BLOCK`].
#[inline]
fn row_block_len(planes_per_row: usize, words_per_plane: usize) -> usize {
    (L1_TILE_BYTES / (planes_per_row * words_per_plane * 8).max(1)).clamp(1, MAX_ROW_BLOCK)
}

/// Columns per panel: as many weight columns as fit the L1 tile target
/// (each column is `words_per_col` lane words).
#[inline]
fn col_panel_len(words_per_col: usize) -> usize {
    (L1_TILE_BYTES / (words_per_col * 8).max(1)).max(8)
}

/// Binary-weight FC, packed: activation bit-planes × column sign bitmaps.
///
/// Per row: `Σ_p q_p·s_p = Σ_b coeff(b)·(2·pop(plane_b ∧ W_j) − total_b)`
/// `= 2·Σ_b coeff(b)·pop(plane_b ∧ W_j) − row_const` — the `row_const`
/// is column-independent and hoisted. `bits == 1` degenerates to the pure
/// XNOR form (both operands ±1).
///
/// Tiling (§Perf): rows are packed in blocks of up to [`MAX_ROW_BLOCK`]
/// and columns walked in L1-sized panels, loop order row-block →
/// col-panel → row → col. Within a panel each row's planes stay L1-hot,
/// and each panel's weight columns are reused by every row of the block
/// before being evicted — cutting weight traffic from L2/L3 by the block
/// factor. The dots themselves run on the `util::simd` dispatch tier;
/// the plane buffers carry the `SIMD_PAD_WORDS` stride, so every dot is
/// whole vectors. Integer sums are order-identical to the untiled loop,
/// hence still bit-exact vs the scalar oracle. `bps` is the caller's
/// reusable block scratch (one [`BitPlanes`] per block row), grown once
/// and repacked in place thereafter.
pub(crate) fn binary_rows_packed(
    xq: &[i32],
    w: &SignPlanes,
    bits: u32,
    scale: f32,
    out: &mut [f32],
    bps: &mut Vec<BitPlanes>,
) {
    let n = w.rows;
    let m = w.cols;
    let rows = out.len() / m;
    debug_assert_eq!(xq.len(), rows * n);
    let planes_per_row = if bits == 1 { 1 } else { bits as usize };
    let block = row_block_len(planes_per_row, w.words_per_col()).min(rows.max(1));
    let panel = col_panel_len(w.words_per_col());
    if bps.len() < block {
        bps.resize_with(block, BitPlanes::empty);
    }
    let mut row_consts = [0i64; MAX_ROW_BLOCK];
    for i0 in (0..rows).step_by(block) {
        let blen = block.min(rows - i0);
        for (i, bp) in bps.iter_mut().enumerate().take(blen) {
            pack_bit_planes_into(&xq[(i0 + i) * n..(i0 + i + 1) * n], bits, bp);
            row_consts[i] = if bits == 1 {
                0
            } else {
                (0..bits).map(|b| plane_coeff(b, bits) * bp.totals[b as usize]).sum()
            };
        }
        for j0 in (0..m).step_by(panel) {
            let j1 = (j0 + panel).min(m);
            for i in 0..blen {
                let bp = &bps[i];
                let orow = &mut out[(i0 + i) * m + j0..(i0 + i) * m + j1];
                if bits == 1 {
                    let arow = bp.plane(0);
                    for (j, o) in orow.iter_mut().enumerate() {
                        let acc = xnor_sign_dot(arow, w.col(j0 + j), n);
                        *o = acc as f32 * scale;
                    }
                    continue;
                }
                for (j, o) in orow.iter_mut().enumerate() {
                    let col = w.col(j0 + j);
                    let mut plus = 0i64;
                    for b in 0..bits {
                        if bp.totals[b as usize] == 0 {
                            continue; // empty plane: popcount would be 0 anyway
                        }
                        plus += plane_coeff(b, bits) * popcount_and_dot(bp.plane(b), col);
                    }
                    let acc = 2 * plus - row_consts[i];
                    *o = acc as f32 * scale;
                }
            }
        }
    }
}

/// Quantized×quantized matmul, scalar reference (attention datapath).
pub(crate) fn qq_rows_scalar(
    aq: &[i32],
    bq: &[i32],
    k: usize,
    m: usize,
    scale: f32,
    out: &mut [f32],
    acc_row: &mut Vec<i64>,
) {
    let rows = out.len() / m;
    debug_assert_eq!(aq.len(), rows * k);
    reset_acc(acc_row, m);
    for i in 0..rows {
        acc_row.fill(0);
        let arow = &aq[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i64;
            let brow = &bq[p * m..(p + 1) * m];
            for (acc, &bv) in acc_row.iter_mut().zip(brow) {
                *acc += av * bv as i64;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(acc_row.iter()) {
            *o = acc as f32 * scale;
        }
    }
}

/// Whether the compact-accumulator qq kernel is exact for this precision
/// and reduction depth: every partial sum is a sum of ≤ `k` products each
/// bounded by `2^(bits−1) · 2^(bits−1)`, so it fits an `i32` iff
/// `k · 2^(2·bits−2) ≤ i32::MAX`. At the paper's W1A8 attention point
/// (`k ≤ 197`, products ≤ 2^14) the bound holds with ~5 decimal orders of
/// margin.
#[inline]
pub(crate) fn qq_compact_ok(bits: u32, k: usize) -> bool {
    bits >= 2 && bits <= 16 && (k as i64).saturating_mul(1i64 << (2 * bits - 2)) <= i32::MAX as i64
}

/// Quantized×quantized matmul with an `i32` accumulator — the Packed
/// backend's datapath *above* the plane crossover (see
/// [`qq_packed_profitable`]). Identical products summed in the identical
/// order as [`qq_rows_scalar`]; the narrower accumulator is exact
/// whenever [`qq_compact_ok`] holds (callers must check), and it lets the
/// compiler vectorize the inner multiply-add over 32-bit lanes, which the
/// i64-widening oracle loop defeats.
pub(crate) fn qq_rows_compact(
    aq: &[i32],
    bq: &[i32],
    k: usize,
    m: usize,
    scale: f32,
    out: &mut [f32],
    acc_row: &mut Vec<i32>,
) {
    let rows = out.len() / m;
    debug_assert_eq!(aq.len(), rows * k);
    reset_acc(acc_row, m);
    for i in 0..rows {
        acc_row.fill(0);
        let arow = &aq[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let brow = &bq[p * m..(p + 1) * m];
            for (acc, &bv) in acc_row.iter_mut().zip(brow) {
                *acc += av * bv;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(acc_row.iter()) {
            *o = acc as i64 as f32 * scale;
        }
    }
}

/// Quantized×quantized matmul, packed: both operands decompose exactly
/// into two's-complement planes, so the dot is a double shift-accumulate
/// of AND-popcounts: `Σ_p a_p·b_p = Σ_{b1,b2} c(b1)·c(b2)·pop(A_b1 ∧ B_b2)`.
/// `bp` is the caller's reusable bit-plane scratch for the left rows.
pub(crate) fn qq_rows_packed(
    aq: &[i32],
    b: &ColPlanes,
    bits: u32,
    scale: f32,
    out: &mut [f32],
    bp: &mut BitPlanes,
) {
    let k = b.rows;
    let m = b.cols;
    let rows = out.len() / m;
    debug_assert_eq!(aq.len(), rows * k);
    for i in 0..rows {
        let arow = &aq[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        pack_bit_planes_into(arow, bits, bp);
        if bits == 1 {
            let asigns = bp.plane(0);
            for (j, o) in orow.iter_mut().enumerate() {
                let acc = xnor_sign_dot(asigns, b.col_plane(j, 0), k);
                *o = acc as f32 * scale;
            }
            continue;
        }
        for (j, o) in orow.iter_mut().enumerate() {
            let mut acc = 0i64;
            for b1 in 0..bits {
                if bp.totals[b1 as usize] == 0 {
                    continue;
                }
                let pa = bp.plane(b1);
                let c1 = plane_coeff(b1, bits);
                for b2 in 0..bits {
                    let d = popcount_and_dot(pa, b.col_plane(j, b2));
                    if d != 0 {
                        acc += c1 * plane_coeff(b2, bits) * d;
                    }
                }
            }
            *o = acc as f32 * scale;
        }
    }
}

/// Whether the packed plane-pair qq datapath beats the alternatives:
/// plane-pair work is `bits² · ⌈k/64⌉` word ops per output vs `k`
/// multiply-adds for the streaming loops, so the plane form's op count
/// wins while `bits² < 64` — with margin for the per-row repack, the
/// cutoff sits at `bits² ≤ 48` (bits ≤ 6, plus the pure-XNOR 1-bit form).
///
/// Crossover rationale (tracked by the `qq_* a{8,6,4,1} speedup` rows of
/// `BENCH_hotpath.json`, which sweep both sides on the DeiT-base
/// attention shapes `197×64·64×197` and `197×197·197×64`): at `a6`
/// (36 word-ops vs 64 MACs) and below, the plane path measures clearly
/// ahead of the scalar loop; at `a8` the pair count reaches exact parity
/// (`8² = 64` word-ops per 64-deep column) *before* repack overhead, so
/// the plane path can only lose — and above the crossover the Packed
/// backend now runs [`qq_rows_compact`] (i32-accumulating, vectorizable,
/// guarded by [`qq_compact_ok`]) rather than the i64 oracle loop, raising
/// the bar further. Results are identical on every path; this is purely a
/// throughput choice.
pub(crate) fn qq_packed_profitable(bits: u32) -> bool {
    bits == 1 || bits * bits <= 48
}
