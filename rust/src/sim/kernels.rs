//! Matmul kernels for the compute engine: the scalar reference datapaths
//! and the bit-packed XNOR/popcount datapaths (§5.1 + §5.3.1).
//!
//! Each kernel computes a contiguous block of output rows — the unit the
//! row-parallel driver (`util::parallel`) fans out across threads. Both
//! backends accumulate in `i64` and convert once at the end, and integer
//! addition is associative, so **scalar and packed results are
//! bit-identical** — the scalar path stays as the reference oracle
//! (`rust/tests/property_suite.rs` sweeps the equivalence).
//!
//! The packed binary-FC kernel is the software analog of the LUT array:
//! weight signs live as column-major 64-lane bitmaps (`SignPlanes`), the
//! activation row is decomposed into two's-complement bit-planes, and
//! each plane's ±1 dot is `2·popcount(plane ∧ signs) − popcount(plane)`
//! (equivalently `popcount(XNOR masked to the plane)`), shift-accumulated
//! with the plane coefficient. One 64-bit AND+popcount replaces 64 scalar
//! multiply-adds, so per-output work drops from `n` MACs to
//! `bits · ⌈n/64⌉` word ops — ≥ 4× for every `bits ≤ 16`, ~8× at the
//! paper's W1A8 operating point (measured in `benches/runtime_hotpath.rs`,
//! recorded in BENCH_hotpath.json; methodology in EXPERIMENTS.md §Perf).

use std::fmt;

use crate::quant::{
    acc_to_fixed16, from_fixed16, pack_bit_planes, plane_coeff, popcount_and_dot, xnor_sign_dot,
    ColPlanes, SignPlanes,
};

/// Which compute datapath implementation the engine runs.
///
/// * `Scalar` — the original element-streaming integer loops: the
///   reference oracle, kept bit-exact forever.
/// * `Packed` — bit-plane + popcount kernels over `u64` lane words (the
///   default): same results, a fraction of the work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    Scalar,
    #[default]
    Packed,
}

impl Backend {
    /// Backend-name hint for error messages (keep in sync with
    /// [`Backend::from_name`]).
    pub const NAMES: &'static str = "scalar|packed";

    /// Parse a backend name (CLI/config/env surface).
    pub fn from_name(name: &str) -> Option<Backend> {
        match name.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Backend::Scalar),
            "packed" => Some(Backend::Packed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Packed => "packed",
        }
    }

    /// Default backend, overridable with `VAQF_BACKEND=scalar|packed`.
    pub fn from_env() -> Backend {
        std::env::var("VAQF_BACKEND")
            .ok()
            .and_then(|v| Backend::from_name(&v))
            .unwrap_or_default()
    }
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Fixed-point DSP path: `xq` holds `rows × n` Q6.10 inputs, `wq` the full
/// `n × m` weight matrix; writes `rows × m` into `out`.
// Hot path (§Perf): i-p-j loop order with a per-row i64 accumulator keeps
// the inner loop streaming over the contiguous weight row — ~3.5× over the
// naive i-j-p order (see EXPERIMENTS.md §Perf).
pub(crate) fn fixed16_rows(xq: &[i16], wq: &[i16], n: usize, m: usize, out: &mut [f32]) {
    let rows = out.len() / m;
    debug_assert_eq!(xq.len(), rows * n);
    let mut acc_row = vec![0i64; m];
    for i in 0..rows {
        acc_row.fill(0);
        let xrow = &xq[i * n..(i + 1) * n];
        for (p, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let xv = xv as i64;
            let wrow = &wq[p * m..(p + 1) * m];
            for (acc, &wv) in acc_row.iter_mut().zip(wrow) {
                *acc += xv * wv as i64;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(&acc_row) {
            *o = from_fixed16(acc_to_fixed16(acc));
        }
    }
}

/// Binary-weight FC, scalar reference: `signs` is the row-major ±1
/// materialization of the weight matrix (LUT-array analog: sign bits
/// resident in BRAM), streamed contiguously in the inner loop.
pub(crate) fn binary_rows_scalar(
    xq: &[i32],
    signs: &[i32],
    n: usize,
    m: usize,
    scale: f32,
    out: &mut [f32],
) {
    let rows = out.len() / m;
    debug_assert_eq!(xq.len(), rows * n);
    let mut acc_row = vec![0i64; m];
    for i in 0..rows {
        acc_row.fill(0);
        let xrow = &xq[i * n..(i + 1) * n];
        for (p, &qv) in xrow.iter().enumerate() {
            if qv == 0 {
                continue;
            }
            let qv = qv as i64;
            let srow = &signs[p * m..(p + 1) * m];
            for (acc, &s) in acc_row.iter_mut().zip(srow) {
                *acc += qv * s as i64;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(&acc_row) {
            *o = acc as f32 * scale;
        }
    }
}

/// Binary-weight FC, packed: activation bit-planes × column sign bitmaps.
///
/// Per row: `Σ_p q_p·s_p = Σ_b coeff(b)·(2·pop(plane_b ∧ W_j) − total_b)`
/// `= 2·Σ_b coeff(b)·pop(plane_b ∧ W_j) − row_const` — the `row_const`
/// is column-independent and hoisted. `bits == 1` degenerates to the pure
/// XNOR form (both operands ±1).
pub(crate) fn binary_rows_packed(
    xq: &[i32],
    w: &SignPlanes,
    bits: u32,
    scale: f32,
    out: &mut [f32],
) {
    let n = w.rows;
    let m = w.cols;
    let rows = out.len() / m;
    debug_assert_eq!(xq.len(), rows * n);
    for i in 0..rows {
        let xrow = &xq[i * n..(i + 1) * n];
        let orow = &mut out[i * m..(i + 1) * m];
        let bp = pack_bit_planes(xrow, bits);
        if bits == 1 {
            let arow = bp.plane(0);
            for (j, o) in orow.iter_mut().enumerate() {
                let acc = xnor_sign_dot(arow, w.col(j), n);
                *o = acc as f32 * scale;
            }
            continue;
        }
        let row_const: i64 = (0..bits)
            .map(|b| plane_coeff(b, bits) * bp.totals[b as usize])
            .sum();
        for (j, o) in orow.iter_mut().enumerate() {
            let col = w.col(j);
            let mut plus = 0i64;
            for b in 0..bits {
                if bp.totals[b as usize] == 0 {
                    continue; // empty plane: popcount would be 0 anyway
                }
                plus += plane_coeff(b, bits) * popcount_and_dot(bp.plane(b), col);
            }
            let acc = 2 * plus - row_const;
            *o = acc as f32 * scale;
        }
    }
}

/// Quantized×quantized matmul, scalar reference (attention datapath).
pub(crate) fn qq_rows_scalar(
    aq: &[i32],
    bq: &[i32],
    k: usize,
    m: usize,
    scale: f32,
    out: &mut [f32],
) {
    let rows = out.len() / m;
    debug_assert_eq!(aq.len(), rows * k);
    let mut acc_row = vec![0i64; m];
    for i in 0..rows {
        acc_row.fill(0);
        let arow = &aq[i * k..(i + 1) * k];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i64;
            let brow = &bq[p * m..(p + 1) * m];
            for (acc, &bv) in acc_row.iter_mut().zip(brow) {
                *acc += av * bv as i64;
            }
        }
        for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(&acc_row) {
            *o = acc as f32 * scale;
        }
    }
}

/// Quantized×quantized matmul, packed: both operands decompose exactly
/// into two's-complement planes, so the dot is a double shift-accumulate
/// of AND-popcounts: `Σ_p a_p·b_p = Σ_{b1,b2} c(b1)·c(b2)·pop(A_b1 ∧ B_b2)`.
pub(crate) fn qq_rows_packed(
    aq: &[i32],
    b: &ColPlanes,
    bits: u32,
    scale: f32,
    out: &mut [f32],
) {
    let k = b.rows;
    let m = b.cols;
    let rows = out.len() / m;
    debug_assert_eq!(aq.len(), rows * k);
    for i in 0..rows {
        let arow = &aq[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        let ap = pack_bit_planes(arow, bits);
        if bits == 1 {
            let asigns = ap.plane(0);
            for (j, o) in orow.iter_mut().enumerate() {
                let acc = xnor_sign_dot(asigns, b.col_plane(j, 0), k);
                *o = acc as f32 * scale;
            }
            continue;
        }
        for (j, o) in orow.iter_mut().enumerate() {
            let mut acc = 0i64;
            for b1 in 0..bits {
                if ap.totals[b1 as usize] == 0 {
                    continue;
                }
                let pa = ap.plane(b1);
                let c1 = plane_coeff(b1, bits);
                for b2 in 0..bits {
                    let d = popcount_and_dot(pa, b.col_plane(j, b2));
                    if d != 0 {
                        acc += c1 * plane_coeff(b2, bits) * d;
                    }
                }
            }
            *o = acc as f32 * scale;
        }
    }
}

/// Whether the packed qq datapath beats the scalar one: plane-pair work is
/// `bits² · ⌈k/64⌉` word ops per output vs `k` scalar MACs, so the packed
/// form wins while `bits² < 64` (with margin for pack overhead). Above the
/// crossover the Packed backend runs the scalar qq loop — results are
/// identical either way, this is purely a throughput choice.
pub(crate) fn qq_packed_profitable(bits: u32) -> bool {
    bits == 1 || bits * bits <= 48
}
