//! Event-timeline cycle accounting for one layer's tile schedule.
//!
//! Walks the exact loop structure of the generated accelerator (Fig. 3c):
//! for each output tile, the input/weight loads of input-tile `k+1`
//! overlap the compute of tile `k` (double buffering), and the store of
//! output tile `j` overlaps the accumulation of tile `j+1`. The analytical
//! Eqs. 7–11 are the closed form of this walk under "all tiles are full";
//! the timeline also models the ragged last tiles, so the two agree within
//! a few percent (quantified by `benches/sim_vs_model.rs`).

use crate::hw::Device;
use crate::model::LayerDesc;
use crate::perf::AcceleratorParams;
use crate::Cycles;

#[inline]
fn cdiv(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Cycle breakdown from the timeline walk.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerTiming {
    pub total: Cycles,
    /// Cycles the engine spent with loads as the critical path.
    pub load_bound: Cycles,
    /// Cycles with compute as the critical path.
    pub compute_bound: Cycles,
    /// Cycles with output stores as the critical path.
    pub store_bound: Cycles,
    pub out_tiles: u64,
    pub in_tiles: u64,
}

/// Walk the tile schedule of `layer` under `params` and return the cycle
/// accounting.
pub fn layer_timing(layer: &LayerDesc, params: &AcceleratorParams, device: &Device) -> LayerTiming {
    let alpha = layer.alpha();
    let beta = layer.beta();
    let gamma = layer.gamma() as u64;
    let n_h = layer.heads as u64;
    let f = layer.f as u64;
    let m = layer.m as u64;
    let n = layer.n as u64;

    let (t_n_eff, g_in) = if alpha {
        (params.t_n_q, params.g_q)
    } else {
        (params.t_n, params.g)
    };
    let t_m_eff = if alpha { params.t_m_q } else { params.t_m };
    let g_out = if beta { params.g_q } else { params.g };

    let in_tiles = cdiv(n, n_h * t_n_eff);
    let out_tiles = cdiv(m, t_m_eff);
    let binary_weights = matches!(layer.weights, crate::model::Precision::Binary);

    let mut t = LayerTiming {
        in_tiles,
        out_tiles,
        ..Default::default()
    };

    // Per-tile-group compute latency (Eq. 8): F tokens stream through the
    // array, one head-group per pass.
    let j_cmpt = f * cdiv(n_h, params.p_h);

    let mut now: Cycles = 0;
    let mut store_free_at: Cycles = 0; // when the store unit finishes the previous output tile

    for ot in 0..out_tiles {
        let tile_m = (m - ot * t_m_eff).min(t_m_eff);
        // Accumulate over input tiles with double-buffered loads.
        let mut compute_done = now;
        for it in 0..in_tiles {
            let tile_n = (n - it * (n_h * t_n_eff)).min(n_h * t_n_eff);
            let rows = cdiv(tile_n, n_h); // per-head input channels this tile
            let j_in = n_h * cdiv(rows, g_in) * cdiv(f, device.axi_ports_in);
            let j_wgt = if binary_weights {
                n_h * cdiv(rows * tile_m, u64::from(device.axi_port_bits) * device.axi_ports_wgt)
            } else {
                n_h * cdiv(rows, g_in) * cdiv(tile_m, device.axi_ports_wgt)
            };
            let load = j_in.max(j_wgt);
            // Double buffering: the load of tile `it` ran during compute of
            // tile `it-1`; the engine stalls on whichever is longer.
            let step = load.max(j_cmpt);
            if load >= j_cmpt {
                t.load_bound += step;
            } else {
                t.compute_bound += step;
            }
            compute_done += step;
            let _ = it;
        }
        // Pipeline drain of the last tile group.
        compute_done += j_cmpt;
        t.compute_bound += j_cmpt;

        // Store: (1+γ) head-outputs, packed g_out per word; can only start
        // once compute is done and the store unit is free.
        let j_out = (1 + gamma) * cdiv(tile_m, g_out) * cdiv(f, device.axi_ports_out);
        let store_start = compute_done.max(store_free_at);
        if store_free_at > compute_done {
            // The engine had to wait for the store unit — store-bound time.
            t.store_bound += store_free_at - compute_done;
        }
        store_free_at = store_start + j_out;
        now = store_start; // next tile's compute may proceed under the store
    }

    t.total = store_free_at;
    t
}

/// Timeline walk over a whole structure.
pub fn model_timing(
    structure: &crate::model::VitStructure,
    params: &AcceleratorParams,
    device: &Device,
) -> (Cycles, Vec<LayerTiming>) {
    let per: Vec<LayerTiming> = structure
        .layers
        .iter()
        .map(|l| layer_timing(l, params, device))
        .collect();
    (per.iter().map(|t| t.total).sum(), per)
}
