//! The general compute engine (paper §5.1): functional datapaths.
//!
//! Three matmul flavours, matching the hardware's operand types:
//!
//! * [`ComputeEngine::fc_fixed16`] — unquantized layers (patch embed,
//!   head): operands converted to Q6.10 fixed point, 32-bit accumulation
//!   on the DSP path — including the fixed-point rounding a real board
//!   would exhibit.
//! * [`ComputeEngine::fc_binary`] — binary-weight FC layers: activations
//!   quantized to `b`-bit integers, weights are ±1 signs, the MAC array is
//!   pure add/sub (LUT path), one scale multiply at the end
//!   (`act_scale · w_scale`).
//! * [`ComputeEngine::qq_matmul`] — attention matmuls (`Q·Kᵀ`, `S·V`):
//!   both operands are `b`-bit quantized activations; integer products,
//!   dequantized with the product of the two scales.
//!
//! All paths return exact f32 reconstructions of the integer/fixed-point
//! results, so the executor's outputs are what the board would produce.

use crate::hw::Device;
use crate::perf::AcceleratorParams;
use crate::quant::{acc_to_fixed16, binarize, fixed_mac, from_fixed16, to_fixed16, ActQuantizer, BinaryMatrix};

/// Functional result of one engine invocation.
#[derive(Debug, Clone)]
pub struct MatmulResult {
    /// Row-major `f × m` output.
    pub out: Vec<f32>,
    /// Number of MAC operations executed (cross-checked against
    /// `LayerDesc::macs`).
    pub macs: u64,
}

/// The compute engine: holds the accelerator parameterization (the tiling
/// doesn't change the math, but the quantization geometry — `act_bits` —
/// does).
#[derive(Debug, Clone)]
pub struct ComputeEngine {
    pub params: AcceleratorParams,
    pub device: Device,
}

impl ComputeEngine {
    pub fn new(params: AcceleratorParams, device: Device) -> ComputeEngine {
        ComputeEngine { params, device }
    }

    /// Unquantized FC on the DSP path: `x (f×n) @ w (n×m)`, Q6.10 in,
    /// 32-bit accumulate, Q6.10 out.
    pub fn fc_fixed16(&self, x: &[f32], w: &[f32], f: usize, n: usize, m: usize) -> MatmulResult {
        assert_eq!(x.len(), f * n);
        assert_eq!(w.len(), n * m);
        let xq: Vec<i16> = x.iter().map(|&v| to_fixed16(v)).collect();
        let wq: Vec<i16> = w.iter().map(|&v| to_fixed16(v)).collect();
        let mut out = vec![0.0f32; f * m];
        // Hot path (§Perf): i-p-j loop order with a per-row i64 accumulator
        // keeps the inner loop streaming over the contiguous weight row —
        // ~3.5× over the naive i-j-p order (see EXPERIMENTS.md §Perf).
        let mut acc_row = vec![0i64; m];
        for i in 0..f {
            acc_row.fill(0);
            let xrow = &xq[i * n..(i + 1) * n];
            for (p, &xv) in xrow.iter().enumerate() {
                if xv == 0 {
                    continue;
                }
                let xv = xv as i64;
                let wrow = &wq[p * m..(p + 1) * m];
                for (acc, &wv) in acc_row.iter_mut().zip(wrow) {
                    *acc += xv * wv as i64;
                }
            }
            for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(&acc_row) {
                *o = from_fixed16(acc_to_fixed16(acc));
            }
        }
        let _ = fixed_mac; // (kept for the scalar-datapath unit tests)
        MatmulResult {
            out,
            macs: (f * n * m) as u64,
        }
    }

    /// Binary-weight FC on the LUT path: activations quantized to
    /// `act_bits`, weights ±1, integer add/sub accumulation.
    pub fn fc_binary(&self, x: &[f32], w: &BinaryMatrix, f: usize) -> MatmulResult {
        let n = w.rows;
        let m = w.cols;
        assert_eq!(x.len(), f * n);
        let bits = self.params.act_bits.expect("quantized engine needs act_bits");
        let q = ActQuantizer::calibrate(bits, x);
        let xq = q.quantize(x);
        let mut out = vec![0.0f32; f * m];
        let scale = q.scale * w.scale;
        // Hot path (§Perf): materialize the signs as ±1 i32 once (LUT-array
        // analog: the sign bits are resident in BRAM), then stream the
        // contiguous sign row in the inner loop — branch-free add/sub.
        let signs: Vec<i32> = w.signs.iter().map(|&s| if s { 1 } else { -1 }).collect();
        let mut acc_row = vec![0i64; m];
        for i in 0..f {
            acc_row.fill(0);
            let xrow = &xq.q[i * n..(i + 1) * n];
            for (p, &qv) in xrow.iter().enumerate() {
                if qv == 0 {
                    continue;
                }
                let qv = qv as i64;
                let srow = &signs[p * m..(p + 1) * m];
                for (acc, &s) in acc_row.iter_mut().zip(srow) {
                    *acc += qv * s as i64;
                }
            }
            for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(&acc_row) {
                *o = acc as f32 * scale;
            }
        }
        MatmulResult {
            out,
            macs: (f * n * m) as u64,
        }
    }

    /// Quantized×quantized matmul (attention): `a (f×k) @ b (k×m)`, both
    /// operands quantized to `act_bits` with their own dynamic scales.
    pub fn qq_matmul(&self, a: &[f32], b: &[f32], f: usize, k: usize, m: usize) -> MatmulResult {
        assert_eq!(a.len(), f * k);
        assert_eq!(b.len(), k * m);
        let bits = self.params.act_bits.expect("quantized engine needs act_bits");
        let qa = ActQuantizer::calibrate(bits, a);
        let qb = ActQuantizer::calibrate(bits, b);
        let aq = qa.quantize(a);
        let bq = qb.quantize(b);
        let scale = qa.scale * qb.scale;
        let mut out = vec![0.0f32; f * m];
        // Hot path (§Perf): same i-p-j streaming order as fc_binary.
        let mut acc_row = vec![0i64; m];
        for i in 0..f {
            acc_row.fill(0);
            let arow = &aq.q[i * k..(i + 1) * k];
            for (p, &av) in arow.iter().enumerate() {
                if av == 0 {
                    continue;
                }
                let av = av as i64;
                let brow = &bq.q[p * m..(p + 1) * m];
                for (acc, &bv) in acc_row.iter_mut().zip(brow) {
                    *acc += av * bv as i64;
                }
            }
            for (o, &acc) in out[i * m..(i + 1) * m].iter_mut().zip(&acc_row) {
                *o = acc as f32 * scale;
            }
        }
        MatmulResult {
            out,
            macs: (f * k * m) as u64,
        }
    }

    /// Reference double-precision matmul (for engine self-tests).
    pub fn reference(a: &[f32], b: &[f32], f: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; f * m];
        for i in 0..f {
            for j in 0..m {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * m + j] as f64;
                }
                out[i * m + j] = acc as f32;
            }
        }
        out
    }
}

/// Convenience: binarize-then-run for tests.
pub fn binary_matmul_ref(x: &[f32], w: &[f32], f: usize, n: usize, m: usize, bits: u8) -> Vec<f32> {
    let wb = binarize(w, n, m);
    let q = ActQuantizer::calibrate(bits, x);
    let xf = q.fake_quantize(x);
    ComputeEngine::reference(&xf, &wb.to_dense(), f, n, m)
}
