//! The general compute engine (paper §5.1): functional datapaths.
//!
//! Three matmul flavours, matching the hardware's operand types:
//!
//! * fixed16 — unquantized layers (patch embed, head): operands converted
//!   to Q6.10 fixed point, 32-bit accumulation on the DSP path — including
//!   the fixed-point rounding a real board would exhibit.
//! * binary-weight FC — activations quantized to `b`-bit integers,
//!   weights are ±1 signs, the MAC array is pure add/sub (LUT path), one
//!   scale multiply at the end (`act_scale · w_scale`).
//! * quantized×quantized — attention matmuls (`Q·Kᵀ`, `S·V`): both
//!   operands are `b`-bit quantized activations; integer products,
//!   dequantized with the product of the two scales.
//!
//! All paths return exact f32 reconstructions of the integer/fixed-point
//! results, so the executor's outputs are what the board would produce.
//!
//! The engine is split the way the hardware splits its work:
//!
//! * [`ComputeEngine::fc_prepared`] executes an FC whose weight operand
//!   was laid out **once per model** ([`PreparedFc`] — packed sign
//!   planes, pre-quantized Q6.10, or materialized ±1 signs), quantizing
//!   the activations into a caller-owned [`FcScratch`]: the steady-state
//!   per-frame path, free of per-call weight work and heap allocation.
//! * [`ComputeEngine::attn_matmul`] runs one attention matmul (both
//!   operands dynamic) through a caller-owned [`AttnScratch`] on a single
//!   thread — the executor parallelizes attention across *heads* instead
//!   of rows.
//! * [`ComputeEngine::fc_fixed16`] / [`ComputeEngine::fc_binary`] /
//!   [`ComputeEngine::qq_matmul`] are the original self-contained calls,
//!   kept as thin wrappers that prepare the weight operand on the spot —
//!   the "pay per call" path benches and property tests compare the
//!   prepared path against.
//!
//! Two interchangeable kernel backends execute the integer math (see
//! [`Backend`] and `sim::kernels`): the original scalar streaming loops
//! (the reference oracle) and the bit-packed XNOR/popcount datapath that
//! models the LUT array the way the hardware actually computes — 64
//! weights per `u64` word. All backends are bit-exact; the packed one is
//! the default because it is several times faster on every quantized
//! layer. The FC flavours additionally fan out across the frame dimension
//! (`threads`, default from `VAQF_THREADS`/`available_parallelism`).

use crate::hw::Device;
use crate::perf::AcceleratorParams;
use crate::quant::{
    binarize, pack_col_planes, to_fixed16_into, ActQuantizer, BinaryMatrix, BitPlanes,
};
use crate::util::parallel::{default_threads, for_each_row_chunk, MAX_THREADS};

use super::kernels;
pub use super::kernels::Backend;
use super::plan::{AttnScratch, FcScratch, PreparedFc};

/// Functional result of one engine invocation.
#[derive(Debug, Clone)]
pub struct MatmulResult {
    /// Row-major `f × m` output.
    pub out: Vec<f32>,
    /// Number of MAC operations executed (cross-checked against
    /// `LayerDesc::macs`).
    pub macs: u64,
}

/// The compute engine: holds the accelerator parameterization (the tiling
/// doesn't change the math, but the quantization geometry — `act_bits` —
/// does) plus the host-side execution strategy (kernel backend + thread
/// fan-out), which changes throughput only, never results.
#[derive(Debug, Clone)]
pub struct ComputeEngine {
    pub params: AcceleratorParams,
    pub device: Device,
    /// Kernel implementation (scalar reference vs bit-packed popcount).
    pub backend: Backend,
    /// Row-parallel worker count (≥ 1; resolved at construction).
    pub threads: usize,
}

impl ComputeEngine {
    /// Engine with the environment-default backend (`VAQF_BACKEND`,
    /// default packed) and thread count (`VAQF_THREADS`, default
    /// available parallelism).
    pub fn new(params: AcceleratorParams, device: Device) -> ComputeEngine {
        ComputeEngine {
            params,
            device,
            backend: Backend::from_env(),
            threads: default_threads(),
        }
    }

    /// Builder-style backend override.
    pub fn with_backend(mut self, backend: Backend) -> ComputeEngine {
        self.backend = backend;
        self
    }

    /// Builder-style thread-count override (`0` ⇒ environment default;
    /// explicit values are clamped to [`MAX_THREADS`] like the defaults).
    pub fn with_threads(mut self, threads: usize) -> ComputeEngine {
        self.threads = if threads == 0 {
            default_threads()
        } else {
            threads.clamp(1, MAX_THREADS)
        };
        self
    }

    /// Execute one FC against a prepared weight operand: quantize the
    /// activations into `scratch`, then run the matching kernel across
    /// row chunks into `out` (`f × w.cols()`). Returns the MAC count.
    /// This is the steady-state per-frame path — no weight-side work, no
    /// output allocation; results are identical to the corresponding
    /// self-contained call.
    pub fn fc_prepared(
        &self,
        x: &[f32],
        w: &PreparedFc,
        f: usize,
        scratch: &mut FcScratch,
        out: &mut [f32],
    ) -> u64 {
        let n = w.rows();
        let m = w.cols();
        assert_eq!(x.len(), f * n, "input shape mismatch");
        assert_eq!(out.len(), f * m, "output shape mismatch");
        let work = (f * n * m) as u64;
        match w {
            PreparedFc::Fixed16 { wq, .. } => {
                to_fixed16_into(x, &mut scratch.x16);
                let xq = &scratch.x16;
                for_each_row_chunk(out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    let mut acc = Vec::new();
                    let xrows = &xq[row0 * n..(row0 + rows) * n];
                    kernels::fixed16_rows(xrows, wq, n, m, chunk, &mut acc);
                });
            }
            PreparedFc::BinaryPacked { planes, scale } => {
                let bits = self.params.act_bits.expect("quantized engine needs act_bits");
                let q = ActQuantizer::calibrate(bits, x);
                q.quantize_into(x, &mut scratch.xq);
                let scale = q.scale * scale;
                let xq = &scratch.xq;
                for_each_row_chunk(out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    // One block of bit-plane scratches per chunk (each
                    // worker owns its own), reused across the chunk's
                    // row blocks by the tiled kernel.
                    let mut bps = Vec::new();
                    kernels::binary_rows_packed(
                        &xq[row0 * n..(row0 + rows) * n],
                        planes,
                        bits as u32,
                        scale,
                        chunk,
                        &mut bps,
                    );
                });
            }
            PreparedFc::BinaryScalar { signs, scale, .. } => {
                let bits = self.params.act_bits.expect("quantized engine needs act_bits");
                let q = ActQuantizer::calibrate(bits, x);
                q.quantize_into(x, &mut scratch.xq);
                let scale = q.scale * scale;
                let xq = &scratch.xq;
                for_each_row_chunk(out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    let mut acc = Vec::new();
                    kernels::binary_rows_scalar(
                        &xq[row0 * n..(row0 + rows) * n],
                        signs,
                        n,
                        m,
                        scale,
                        chunk,
                        &mut acc,
                    );
                });
            }
        }
        work
    }

    /// One attention matmul (`a (f×k) @ b (k×m)` — both operands dynamic
    /// activations) through caller-owned scratch, single-threaded: the
    /// executor fans attention out across heads, each head owning one
    /// scratch, so row fan-out here would only oversubscribe. Quantized
    /// engines run the `b`-bit qq datapath; unquantized ones the fixed16
    /// DSP path. Returns the MAC count.
    pub fn attn_matmul(
        &self,
        a: &[f32],
        b: &[f32],
        f: usize,
        k: usize,
        m: usize,
        scratch: &mut AttnScratch,
        out: &mut [f32],
    ) -> u64 {
        assert_eq!(a.len(), f * k, "lhs shape mismatch");
        assert_eq!(b.len(), k * m, "rhs shape mismatch");
        assert_eq!(out.len(), f * m, "output shape mismatch");
        match self.params.act_bits {
            Some(bits) => {
                let qa = ActQuantizer::calibrate(bits, a);
                let qb = ActQuantizer::calibrate(bits, b);
                qa.quantize_into(a, &mut scratch.aq);
                qb.quantize_into(b, &mut scratch.bq);
                let scale = qa.scale * qb.scale;
                self.qq_rows(&mut scratch.dispatch(), k, m, scale, out);
            }
            None => {
                to_fixed16_into(a, &mut scratch.a16);
                to_fixed16_into(b, &mut scratch.b16);
                kernels::fixed16_rows(&scratch.a16, &scratch.b16, k, m, out, &mut scratch.acc64);
            }
        }
        (f * k * m) as u64
    }

    /// The single source of truth for the qq crossover: which kernel the
    /// backend runs at this precision and reduction depth. Packed
    /// backend: plane-pair popcounts below the `bits²` crossover, the
    /// vectorizable compact-accumulator loop above it (when exact —
    /// `qq_compact_ok`), the i64 oracle loop otherwise. Results are
    /// identical on every path.
    fn qq_kernel(&self, bits: u32, k: usize) -> QqKernel {
        if self.backend == Backend::Packed && kernels::qq_packed_profitable(bits) {
            QqKernel::Packed
        } else if self.backend == Backend::Packed && kernels::qq_compact_ok(bits, k) {
            QqKernel::Compact
        } else {
            QqKernel::Scalar
        }
    }

    /// One block of qq output rows through caller-owned scratch — shared
    /// by [`ComputeEngine::attn_matmul`]; the self-contained
    /// [`ComputeEngine::qq_matmul`] uses the same [`QqKernel`] selection
    /// with per-chunk scratch.
    fn qq_rows(&self, s: &mut QqDispatch<'_>, k: usize, m: usize, scale: f32, out: &mut [f32]) {
        let bits = u32::from(self.params.act_bits.expect("quantized engine needs act_bits"));
        match self.qq_kernel(bits, k) {
            QqKernel::Packed => {
                crate::quant::pack_col_planes_into(s.bq, k, m, bits, s.cp);
                kernels::qq_rows_packed(s.aq, s.cp, bits, scale, out, s.bp);
            }
            QqKernel::Compact => kernels::qq_rows_compact(s.aq, s.bq, k, m, scale, out, s.acc32),
            QqKernel::Scalar => kernels::qq_rows_scalar(s.aq, s.bq, k, m, scale, out, s.acc64),
        }
    }

    /// Unquantized FC on the DSP path: `x (f×n) @ w (n×m)`, Q6.10 in,
    /// 32-bit accumulate, Q6.10 out — the self-contained form: the weight
    /// matrix is re-quantized on every call. Steady-state callers prepare
    /// the weights once ([`PreparedFc::fixed16`]) and use
    /// [`ComputeEngine::fc_prepared`] instead.
    pub fn fc_fixed16(&self, x: &[f32], w: &[f32], f: usize, n: usize, m: usize) -> MatmulResult {
        assert_eq!(w.len(), n * m);
        let prepared = PreparedFc::fixed16(w, n, m);
        let mut scratch = FcScratch::default();
        let mut out = vec![0.0f32; f * m];
        let macs = self.fc_prepared(x, &prepared, f, &mut scratch, &mut out);
        MatmulResult { out, macs }
    }

    /// Binary-weight FC on the LUT path: activations quantized to
    /// `act_bits`, weights ±1, integer add/sub accumulation — the
    /// self-contained form: the sign matrix is re-laid-out (packed
    /// column-major, or ±1-materialized for the scalar oracle) on every
    /// call. Steady-state callers prepare it once ([`PreparedFc::binary`])
    /// and use [`ComputeEngine::fc_prepared`] instead.
    pub fn fc_binary(&self, x: &[f32], w: &BinaryMatrix, f: usize) -> MatmulResult {
        let prepared = PreparedFc::binary(w, self.backend);
        let mut scratch = FcScratch::default();
        let mut out = vec![0.0f32; f * w.cols];
        let macs = self.fc_prepared(x, &prepared, f, &mut scratch, &mut out);
        MatmulResult { out, macs }
    }

    /// Quantized×quantized matmul (attention): `a (f×k) @ b (k×m)`, both
    /// operands quantized to `act_bits` with their own dynamic scales —
    /// the self-contained form with row fan-out across threads.
    pub fn qq_matmul(&self, a: &[f32], b: &[f32], f: usize, k: usize, m: usize) -> MatmulResult {
        assert_eq!(a.len(), f * k);
        assert_eq!(b.len(), k * m);
        let bits = self.params.act_bits.expect("quantized engine needs act_bits");
        let qa = ActQuantizer::calibrate(bits, a);
        let qb = ActQuantizer::calibrate(bits, b);
        let aq = qa.quantize(a);
        let bq = qb.quantize(b);
        let scale = qa.scale * qb.scale;
        let mut out = vec![0.0f32; f * m];
        let work = (f * k * m) as u64;
        let bits = bits as u32;
        match self.qq_kernel(bits, k) {
            QqKernel::Packed => {
                let planes = pack_col_planes(&bq.q, k, m, bits);
                for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    let mut bp = BitPlanes::empty();
                    kernels::qq_rows_packed(
                        &aq.q[row0 * k..(row0 + rows) * k],
                        &planes,
                        bits,
                        scale,
                        chunk,
                        &mut bp,
                    );
                });
            }
            QqKernel::Compact => {
                for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    let mut acc = Vec::new();
                    kernels::qq_rows_compact(
                        &aq.q[row0 * k..(row0 + rows) * k],
                        &bq.q,
                        k,
                        m,
                        scale,
                        chunk,
                        &mut acc,
                    );
                });
            }
            QqKernel::Scalar => {
                for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    let mut acc = Vec::new();
                    kernels::qq_rows_scalar(
                        &aq.q[row0 * k..(row0 + rows) * k],
                        &bq.q,
                        k,
                        m,
                        scale,
                        chunk,
                        &mut acc,
                    );
                });
            }
        }
        MatmulResult { out, macs: work }
    }

    /// Reference double-precision matmul (for engine self-tests).
    pub fn reference(a: &[f32], b: &[f32], f: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; f * m];
        for i in 0..f {
            for j in 0..m {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * m + j] as f64;
                }
                out[i * m + j] = acc as f32;
            }
        }
        out
    }
}

/// Which qq datapath [`ComputeEngine::qq_kernel`] selected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QqKernel {
    Packed,
    Compact,
    Scalar,
}

/// Split borrows of an [`AttnScratch`] for the qq kernel dispatch (the
/// quantized operands are read while the pack/accumulator scratches are
/// written).
struct QqDispatch<'a> {
    aq: &'a [i32],
    bq: &'a [i32],
    acc64: &'a mut Vec<i64>,
    acc32: &'a mut Vec<i32>,
    bp: &'a mut BitPlanes,
    cp: &'a mut crate::quant::ColPlanes,
}

impl AttnScratch {
    fn dispatch(&mut self) -> QqDispatch<'_> {
        QqDispatch {
            aq: &self.aq,
            bq: &self.bq,
            acc64: &mut self.acc64,
            acc32: &mut self.acc32,
            bp: &mut self.bp,
            cp: &mut self.cp,
        }
    }
}

/// Convenience: binarize-then-run for tests.
pub fn binary_matmul_ref(x: &[f32], w: &[f32], f: usize, n: usize, m: usize, bits: u8) -> Vec<f32> {
    let wb = binarize(w, n, m);
    let q = ActQuantizer::calibrate(bits, x);
    let xf = q.fake_quantize(x);
    ComputeEngine::reference(&xf, &wb.to_dense(), f, n, m)
}
