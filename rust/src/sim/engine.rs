//! The general compute engine (paper §5.1): functional datapaths.
//!
//! Three matmul flavours, matching the hardware's operand types:
//!
//! * [`ComputeEngine::fc_fixed16`] — unquantized layers (patch embed,
//!   head): operands converted to Q6.10 fixed point, 32-bit accumulation
//!   on the DSP path — including the fixed-point rounding a real board
//!   would exhibit.
//! * [`ComputeEngine::fc_binary`] — binary-weight FC layers: activations
//!   quantized to `b`-bit integers, weights are ±1 signs, the MAC array is
//!   pure add/sub (LUT path), one scale multiply at the end
//!   (`act_scale · w_scale`).
//! * [`ComputeEngine::qq_matmul`] — attention matmuls (`Q·Kᵀ`, `S·V`):
//!   both operands are `b`-bit quantized activations; integer products,
//!   dequantized with the product of the two scales.
//!
//! All paths return exact f32 reconstructions of the integer/fixed-point
//! results, so the executor's outputs are what the board would produce.
//!
//! Two interchangeable kernel backends execute the integer math (see
//! [`Backend`] and `sim::kernels`): the original scalar streaming loops
//! (the reference oracle) and the bit-packed XNOR/popcount datapath that
//! models the LUT array the way the hardware actually computes — 64
//! weights per `u64` word. Both are bit-exact; the packed one is the
//! default because it is several times faster on every quantized layer.
//! All three flavours additionally fan out across the frame dimension
//! (`threads`, default from `VAQF_THREADS`/`available_parallelism`).

use crate::hw::Device;
use crate::perf::AcceleratorParams;
use crate::quant::{
    binarize, fixed_mac, pack_col_planes, to_fixed16, ActQuantizer, BinaryMatrix,
};
use crate::util::parallel::{default_threads, for_each_row_chunk, MAX_THREADS};

use super::kernels;
pub use super::kernels::Backend;

/// Functional result of one engine invocation.
#[derive(Debug, Clone)]
pub struct MatmulResult {
    /// Row-major `f × m` output.
    pub out: Vec<f32>,
    /// Number of MAC operations executed (cross-checked against
    /// `LayerDesc::macs`).
    pub macs: u64,
}

/// The compute engine: holds the accelerator parameterization (the tiling
/// doesn't change the math, but the quantization geometry — `act_bits` —
/// does) plus the host-side execution strategy (kernel backend + thread
/// fan-out), which changes throughput only, never results.
#[derive(Debug, Clone)]
pub struct ComputeEngine {
    pub params: AcceleratorParams,
    pub device: Device,
    /// Kernel implementation (scalar reference vs bit-packed popcount).
    pub backend: Backend,
    /// Row-parallel worker count (≥ 1; resolved at construction).
    pub threads: usize,
}

impl ComputeEngine {
    /// Engine with the environment-default backend (`VAQF_BACKEND`,
    /// default packed) and thread count (`VAQF_THREADS`, default
    /// available parallelism).
    pub fn new(params: AcceleratorParams, device: Device) -> ComputeEngine {
        ComputeEngine {
            params,
            device,
            backend: Backend::from_env(),
            threads: default_threads(),
        }
    }

    /// Builder-style backend override.
    pub fn with_backend(mut self, backend: Backend) -> ComputeEngine {
        self.backend = backend;
        self
    }

    /// Builder-style thread-count override (`0` ⇒ environment default;
    /// explicit values are clamped to [`MAX_THREADS`] like the defaults).
    pub fn with_threads(mut self, threads: usize) -> ComputeEngine {
        self.threads = if threads == 0 {
            default_threads()
        } else {
            threads.clamp(1, MAX_THREADS)
        };
        self
    }

    /// Unquantized FC on the DSP path: `x (f×n) @ w (n×m)`, Q6.10 in,
    /// 32-bit accumulate, Q6.10 out. Fixed16 has no sub-word planes to
    /// exploit, so both backends run the same scalar kernel; rows still
    /// fan out across threads.
    pub fn fc_fixed16(&self, x: &[f32], w: &[f32], f: usize, n: usize, m: usize) -> MatmulResult {
        assert_eq!(x.len(), f * n);
        assert_eq!(w.len(), n * m);
        let xq: Vec<i16> = x.iter().map(|&v| to_fixed16(v)).collect();
        let wq: Vec<i16> = w.iter().map(|&v| to_fixed16(v)).collect();
        let mut out = vec![0.0f32; f * m];
        let work = (f * n * m) as u64;
        for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
            let rows = chunk.len() / m;
            kernels::fixed16_rows(&xq[row0 * n..(row0 + rows) * n], &wq, n, m, chunk);
        });
        let _ = fixed_mac; // (kept for the scalar-datapath unit tests)
        MatmulResult {
            out,
            macs: (f * n * m) as u64,
        }
    }

    /// Binary-weight FC on the LUT path: activations quantized to
    /// `act_bits`, weights ±1, integer add/sub accumulation.
    pub fn fc_binary(&self, x: &[f32], w: &BinaryMatrix, f: usize) -> MatmulResult {
        let n = w.rows;
        let m = w.cols;
        assert_eq!(x.len(), f * n);
        let bits = self.params.act_bits.expect("quantized engine needs act_bits");
        let q = ActQuantizer::calibrate(bits, x);
        let xq = q.quantize(x);
        let mut out = vec![0.0f32; f * m];
        let scale = q.scale * w.scale;
        let work = (f * n * m) as u64;
        match self.backend {
            Backend::Scalar => {
                // Materialize the signs as ±1 i32 once (LUT-array analog:
                // the sign bits are resident in BRAM), then stream the
                // contiguous sign row in the inner loop — branch-free
                // add/sub.
                let signs: Vec<i32> = w.signs.iter().map(|&s| if s { 1 } else { -1 }).collect();
                for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    kernels::binary_rows_scalar(
                        &xq.q[row0 * n..(row0 + rows) * n],
                        &signs,
                        n,
                        m,
                        scale,
                        chunk,
                    );
                });
            }
            Backend::Packed => {
                // Pack the sign matrix once per call (64 weights / word);
                // the cost is one bit-sweep of W vs f bit-sweeps of
                // compute, ≤ 1/f of the matmul.
                let planes = w.packed_signs();
                for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                    let rows = chunk.len() / m;
                    kernels::binary_rows_packed(
                        &xq.q[row0 * n..(row0 + rows) * n],
                        &planes,
                        bits as u32,
                        scale,
                        chunk,
                    );
                });
            }
        }
        MatmulResult {
            out,
            macs: (f * n * m) as u64,
        }
    }

    /// Quantized×quantized matmul (attention): `a (f×k) @ b (k×m)`, both
    /// operands quantized to `act_bits` with their own dynamic scales.
    pub fn qq_matmul(&self, a: &[f32], b: &[f32], f: usize, k: usize, m: usize) -> MatmulResult {
        assert_eq!(a.len(), f * k);
        assert_eq!(b.len(), k * m);
        let bits = self.params.act_bits.expect("quantized engine needs act_bits");
        let qa = ActQuantizer::calibrate(bits, a);
        let qb = ActQuantizer::calibrate(bits, b);
        let aq = qa.quantize(a);
        let bq = qb.quantize(b);
        let scale = qa.scale * qb.scale;
        let mut out = vec![0.0f32; f * m];
        let work = (f * k * m) as u64;
        if self.backend == Backend::Packed && kernels::qq_packed_profitable(bits as u32) {
            let planes = pack_col_planes(&bq.q, k, m, bits as u32);
            for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                let rows = chunk.len() / m;
                kernels::qq_rows_packed(
                    &aq.q[row0 * k..(row0 + rows) * k],
                    &planes,
                    bits as u32,
                    scale,
                    chunk,
                );
            });
        } else {
            for_each_row_chunk(&mut out, f, m, self.threads, work, |row0, chunk| {
                let rows = chunk.len() / m;
                kernels::qq_rows_scalar(
                    &aq.q[row0 * k..(row0 + rows) * k],
                    &bq.q,
                    k,
                    m,
                    scale,
                    chunk,
                );
            });
        }
        MatmulResult {
            out,
            macs: (f * k * m) as u64,
        }
    }

    /// Reference double-precision matmul (for engine self-tests).
    pub fn reference(a: &[f32], b: &[f32], f: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; f * m];
        for i in 0..f {
            for j in 0..m {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * m + j] as f64;
                }
                out[i * m + j] = acc as f32;
            }
        }
        out
    }
}

/// Convenience: binarize-then-run for tests.
pub fn binary_matmul_ref(x: &[f32], w: &[f32], f: usize, n: usize, m: usize, bits: u8) -> Vec<f32> {
    let wb = binarize(w, n, m);
    let q = ActQuantizer::calibrate(bits, x);
    let xf = q.fake_quantize(x);
    ComputeEngine::reference(&xf, &wb.to_dense(), f, n, m)
}
