use crate::hw::zcu102;
use crate::model::{deit_base, deit_tiny, VitConfig};
use crate::perf::{model_cycles, AcceleratorParams};
use crate::quant::binarize;

use super::engine::binary_matmul_ref;
use super::timing::model_timing;
use super::*;

/// A ViT small enough for exhaustive functional simulation.
fn micro_vit() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 2,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

fn micro_params(bits: Option<u8>) -> AcceleratorParams {
    match bits {
        None => AcceleratorParams::baseline(16, 2, 4, 4),
        Some(b) => {
            let g_q = AcceleratorParams::g_q_for(64, b);
            AcceleratorParams {
                t_m: 16,
                t_n: 2,
                t_m_q: 16,
                t_n_q: 2 * g_q / 4,
                g: 4,
                g_q,
                p_h: 4,
                act_bits: Some(b),
            }
        }
    }
}

#[test]
fn engine_fixed16_matches_reference() {
    let e = ComputeEngine::new(micro_params(None), zcu102());
    let f = 5;
    let n = 16;
    let m = 8;
    let mut rng = crate::util::rng::SplitMix64::new(3);
    let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-2.0, 2.0)).collect();
    let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-1.0, 1.0)).collect();
    let got = e.fc_fixed16(&x, &w, f, n, m);
    let want = ComputeEngine::reference(&x, &w, f, n, m);
    for (g, r) in got.out.iter().zip(&want) {
        assert!((g - r).abs() < 0.05, "{g} vs {r}");
    }
    assert_eq!(got.macs, (f * n * m) as u64);
}

#[test]
fn engine_binary_matches_fake_quant_reference() {
    let e = ComputeEngine::new(micro_params(Some(8)), zcu102());
    let f = 4;
    let n = 24;
    let m = 6;
    let mut rng = crate::util::rng::SplitMix64::new(4);
    let x: Vec<f32> = (0..f * n).map(|_| rng.next_f32_range(-1.5, 1.5)).collect();
    let w: Vec<f32> = (0..n * m).map(|_| rng.next_f32_range(-0.2, 0.2)).collect();
    let wb = binarize(&w, n, m);
    let got = e.fc_binary(&x, &wb, f);
    let want = binary_matmul_ref(&x, &w, f, n, m, 8);
    for (g, r) in got.out.iter().zip(&want) {
        assert!((g - r).abs() < 1e-3, "{g} vs {r}");
    }
}

#[test]
fn executor_runs_micro_vit_all_precisions() {
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 11);
    let patches = w.synthetic_patches(0);
    for bits in [None, Some(8), Some(6), Some(4)] {
        let mut exec = ModelExecutor::new(w.clone(), bits, micro_params(bits), zcu102());
        let (logits, trace) = exec.run_frame(&patches);
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(trace.total_cycles > 0);
        assert_eq!(trace.layers.len(), 1 + 6 * 2 + 1);
        // Logits must differ across precisions but not wildly.
        assert!(logits.iter().any(|&v| v != 0.0));
    }
}

#[test]
fn quantized_logits_approach_fp_logits_with_more_bits() {
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 5);
    let patches = w.synthetic_patches(1);
    let mut fp = ModelExecutor::new(w.clone(), None, micro_params(None), zcu102());
    let (logits_fp, _) = fp.run_frame(&patches);
    // Binary weights change the function substantially (this is untrained
    // — Table 3 shows even trained models drop); what must hold is that
    // *activation* precision converges: W1A12 closer to W1A16 than W1A4 is.
    let run = |bits: u8| {
        let mut e = ModelExecutor::new(w.clone(), Some(bits), micro_params(Some(bits)), zcu102());
        e.run_frame(&patches).0
    };
    let l16 = run(16);
    let dist = |a: &[f32], b: &[f32]| -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
    };
    let d12 = dist(&run(12), &l16);
    let d4 = dist(&run(4), &l16);
    assert!(
        d12 < d4,
        "12-bit ({d12}) should be closer to 16-bit than 4-bit ({d4})"
    );
    // And the fp logits are finite & distinct from quantized ones.
    assert!(dist(&logits_fp, &l16) > 0.0);
}

#[test]
fn timeline_agrees_with_analytical_model() {
    // The event timeline and Eqs. 7–11 must agree within 15% on the
    // engine cycles for the real designs (they model the same schedule;
    // differences are ragged-tile and drain effects).
    let dev = zcu102();
    for bits in [None, Some(8), Some(6)] {
        let s = deit_base().structure(bits);
        let params = match bits {
            None => AcceleratorParams::baseline(96, 4, 4, 4),
            Some(b) => {
                let g_q = AcceleratorParams::g_q_for(64, b);
                AcceleratorParams {
                    t_m: 16,
                    t_n: 4,
                    t_m_q: 160,
                    t_n_q: 4 * g_q / 4,
                    g: 4,
                    g_q,
                    p_h: 4,
                    act_bits: bits,
                }
            }
        };
        let (analytic, per_layer) = model_cycles(&s, &params, &dev);
        let host: u64 = per_layer.iter().map(|c| c.host).sum();
        let analytic_engine = analytic - host;
        let (timeline, _) = model_timing(&s, &params, &dev);
        let ratio = timeline as f64 / analytic_engine as f64;
        assert!(
            (0.85..=1.15).contains(&ratio),
            "bits={bits:?}: timeline {timeline} vs analytic {analytic_engine} (ratio {ratio:.3})"
        );
    }
}

#[test]
fn trace_macs_match_structure() {
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 2);
    let mut exec = ModelExecutor::new(w.clone(), Some(8), micro_params(Some(8)), zcu102());
    let (_, trace) = exec.run_frame(&w.synthetic_patches(3));
    let expected = cfg.structure(Some(8)).total_macs();
    let got: u64 = trace.layers.iter().map(|l| l.macs).sum();
    assert_eq!(got, expected);
}

#[test]
fn backends_agree_bitexactly_on_whole_model() {
    // The packed XNOR/popcount backend must reproduce the scalar oracle
    // bit-for-bit through a full forward pass — logits AND cycle trace —
    // at every precision, for any thread count.
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 13);
    let patches = w.synthetic_patches(2);
    for bits in [Some(8), Some(6), Some(4), Some(1), None] {
        let mut scalar = ModelExecutor::new(w.clone(), bits, micro_params(bits), zcu102())
            .with_backend(Backend::Scalar)
            .with_threads(1);
        let mut packed = ModelExecutor::new(w.clone(), bits, micro_params(bits), zcu102())
            .with_backend(Backend::Packed)
            .with_threads(3);
        let (ls, ts) = scalar.run_frame(&patches);
        let (lp, tp) = packed.run_frame(&patches);
        assert_eq!(ls, lp, "bits={bits:?}: packed backend diverged");
        assert_eq!(ts.total_cycles, tp.total_cycles, "bits={bits:?}");
    }
}

#[test]
fn deterministic_execution() {
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 9);
    let p = w.synthetic_patches(7);
    let mut exec = ModelExecutor::new(w.clone(), Some(6), micro_params(Some(6)), zcu102());
    let (a, ta) = exec.run_frame(&p);
    let (b, tb) = exec.run_frame(&p);
    assert_eq!(a, b);
    assert_eq!(ta.total_cycles, tb.total_cycles);
}

#[test]
fn run_batch_equals_repeated_run_frame() {
    // The frame-parallel batch path (per-worker workspace, intra-frame
    // parallelism off) must reproduce the sequential per-frame path
    // bit-for-bit — logits AND traces — at every precision and worker
    // count, including batches smaller / larger than the worker pool.
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 21);
    let frames: Vec<Vec<f32>> = (0..5).map(|i| w.synthetic_patches(i)).collect();
    for bits in [Some(8), Some(1), None] {
        for threads in [1usize, 2, 3, 8] {
            let mut seq = ModelExecutor::new(w.clone(), bits, micro_params(bits), zcu102())
                .with_threads(threads);
            let want: Vec<_> = frames.iter().map(|p| seq.run_frame(p)).collect();
            let mut batch = ModelExecutor::new(w.clone(), bits, micro_params(bits), zcu102())
                .with_threads(threads);
            let got = batch.run_batch(&frames);
            assert_eq!(got.len(), want.len());
            for (i, ((gl, gt), (wl, wt))) in got.iter().zip(&want).enumerate() {
                assert_eq!(gl, wl, "bits={bits:?} threads={threads} frame {i}");
                assert_eq!(gt.total_cycles, wt.total_cycles, "frame {i}");
            }
            // Batch again on the warmed workspaces: still identical.
            let again = batch.run_batch(&frames);
            for ((gl, _), (wl, _)) in again.iter().zip(&want) {
                assert_eq!(gl, wl);
            }
        }
    }
    let mut empty_exec = ModelExecutor::new(w, Some(8), micro_params(Some(8)), zcu102());
    assert!(empty_exec.run_batch::<Vec<f32>>(&[]).is_empty());
}

#[test]
fn prepared_plan_survives_backend_swap() {
    // with_backend must re-lay the prepared weights out for the new
    // datapath: swapping to the scalar oracle and back yields identical
    // logits each way.
    let cfg = micro_vit();
    let w = generate_weights(&cfg, 23);
    let p = w.synthetic_patches(4);
    let mut packed = ModelExecutor::new(w.clone(), Some(6), micro_params(Some(6)), zcu102())
        .with_backend(Backend::Packed);
    let (lp, _) = packed.run_frame(&p);
    let mut swapped = packed.with_backend(Backend::Scalar);
    let (ls, _) = swapped.run_frame(&p);
    assert_eq!(lp, ls, "backend swap after construction diverged");
    let mut back = swapped.with_backend(Backend::Packed);
    let (lp2, _) = back.run_frame(&p);
    assert_eq!(lp, lp2);
}

#[test]
fn qq_compact_bound_exact_boundary_values() {
    // The compact qq kernel is exact iff every partial sum fits an i32:
    // k products each bounded by 2^(bits−1)·2^(bits−1), so the admissible
    // depth is exactly kmax = ⌊i32::MAX / 2^(2·bits−2)⌋. Pin the fence
    // for every width: largest k that must pass, smallest that must fall
    // back — the SIMD-era dispatch must never drift across it.
    use super::kernels::qq_compact_ok;
    for bits in 2..=16u32 {
        let kmax = (i32::MAX >> (2 * bits - 2)) as usize;
        assert!(qq_compact_ok(bits, kmax), "bits={bits}: k={kmax} must pass");
        assert!(!qq_compact_ok(bits, kmax + 1), "bits={bits}: k={} must fall back", kmax + 1);
    }
    // Spot anchors: the full-width fence (one product of 2^30 fits, two
    // don't) and the paper's W1A8 attention point, deep inside the bound.
    assert!(qq_compact_ok(16, 1) && !qq_compact_ok(16, 2));
    assert!(qq_compact_ok(8, 197));
    // 1-bit rows use the XNOR form, never the compact kernel.
    assert!(!qq_compact_ok(1, 1));
    assert!(!qq_compact_ok(17, 1));
}

#[test]
fn qq_compact_worst_case_at_the_bound_is_exact() {
    // Numeric proof at the fence: bits=15 admits kmax=7 — seven worst-
    // case products (−2^14)·(−2^14) sum to 7·2^28 = 1 879 048 192 ≤
    // i32::MAX (all partials same-signed, so no intermediate wraps
    // either). The compact kernel must agree with the i64 oracle exactly;
    // one more product would overflow, which qq_compact_ok forbids.
    use super::kernels::{qq_compact_ok, qq_rows_compact, qq_rows_scalar};
    let bits = 15u32;
    let k = (i32::MAX >> (2 * bits - 2)) as usize;
    assert_eq!(k, 7);
    let lo = -(1i32 << (bits - 1)); // −16384, the largest-magnitude code
    let aq = vec![lo; k];
    let bq = vec![lo; k]; // k×1 matrix: one output, the full-depth sum
    let scale = 1.0f32;
    let mut compact = [0.0f32; 1];
    let mut oracle = [0.0f32; 1];
    qq_rows_compact(&aq, &bq, k, 1, scale, &mut compact, &mut Vec::new());
    qq_rows_scalar(&aq, &bq, k, 1, scale, &mut oracle, &mut Vec::new());
    assert_eq!(compact, oracle);
    assert_eq!(compact[0], (k as i64 * (lo as i64 * lo as i64)) as f32);
    assert!(!qq_compact_ok(bits, k + 1), "k+1 worst case would exceed i32::MAX");
}

#[test]
fn softmax_and_layernorm_invariants() {
    let mut s = vec![1.0f32, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
    super::exec::softmax_rows(&mut s, 2, 4);
    for r in 0..2 {
        let sum: f32 = s[r * 4..(r + 1) * 4].iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }
    let x = vec![1.0f32, 2.0, 3.0, 4.0];
    let ln = super::exec::layer_norm(&x, 1, 4);
    let mean: f32 = ln.iter().sum::<f32>() / 4.0;
    let var: f32 = ln.iter().map(|v| v * v).sum::<f32>() / 4.0;
    assert!(mean.abs() < 1e-6);
    assert!((var - 1.0).abs() < 1e-3);
}

#[test]
fn tiny_model_timing_scales_with_precision() {
    // On the simulated board a W1A6 executor must finish frames faster
    // than W1A8, which must beat the fixed16 baseline (Table 5 trend at
    // micro scale).
    let cfg = deit_tiny();
    let dev = zcu102();
    let base = crate::compiler::optimize_baseline(&cfg.structure(None), &dev);
    let mut cycles_prev = u64::MAX;
    for bits in [None, Some(8), Some(6)] {
        let params = match bits {
            None => base,
            Some(b) => {
                crate::compiler::optimize_for_bits(&cfg.structure(Some(b)), &base, &dev, b)
                    .unwrap()
                    .params
            }
        };
        let (cycles, _) = model_timing(&cfg.structure(bits), &params, &dev);
        assert!(
            cycles < cycles_prev,
            "bits={bits:?} cycles={cycles} prev={cycles_prev}"
        );
        cycles_prev = cycles;
    }
}
