//! Deterministic ViT weight generation.
//!
//! Weights are drawn from a seeded [`SplitMix64`] stream in a fixed order,
//! mirrored exactly by `python/compile/prng.py` + `model.py`, so the Rust
//! simulator and the AOT-compiled JAX model compute over *identical*
//! parameters — the precondition for the sim-vs-runtime numerical
//! cross-check. Biases are zero and LayerNorms are non-affine (γ=1, β=0)
//! on both sides to keep the contract small.

use crate::model::VitConfig;
use crate::quant::{binarize, BinaryMatrix};
use crate::util::rng::SplitMix64;

/// Per-encoder-layer weights (real-valued masters + binarized views).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// `M × 3M` (row-major, input-channel major like all matrices here).
    pub qkv: Vec<f32>,
    /// `M × M`.
    pub proj: Vec<f32>,
    /// `M × 4M`.
    pub mlp1: Vec<f32>,
    /// `4M × M`.
    pub mlp2: Vec<f32>,
    pub qkv_bin: BinaryMatrix,
    pub proj_bin: BinaryMatrix,
    pub mlp1_bin: BinaryMatrix,
    pub mlp2_bin: BinaryMatrix,
}

/// All model parameters.
#[derive(Debug, Clone)]
pub struct VitWeights {
    pub config: VitConfig,
    pub seed: u64,
    /// Patch-embedding FC: `(3P²) × M`.
    pub patch: Vec<f32>,
    /// CLS token `M`.
    pub cls: Vec<f32>,
    /// Positional embedding `F × M`.
    pub pos: Vec<f32>,
    pub layers: Vec<LayerWeights>,
    /// Classifier head `M × C`.
    pub head: Vec<f32>,
}

/// Draw `len` values from `N(0, std²)`.
fn normal_vec(rng: &mut SplitMix64, len: usize, std: f32) -> Vec<f32> {
    (0..len).map(|_| rng.next_normal() as f32 * std).collect()
}

/// Generate the full parameter set for `config` from `seed`.
///
/// Draw order (must match `python/compile/model.py::init_params`):
/// patch, cls, pos, then per layer (qkv, proj, mlp1, mlp2), then head.
/// Std 0.02 everywhere (the ViT trunc-normal init, untruncated).
pub fn generate_weights(config: &VitConfig, seed: u64) -> VitWeights {
    let mut rng = SplitMix64::new(seed);
    let m = config.embed_dim;
    let f = config.tokens();
    let patch_in = config.in_chans * config.patch_size * config.patch_size;
    let hidden = m * config.mlp_ratio;
    let std = 0.02;

    let patch = normal_vec(&mut rng, patch_in * m, std);
    let cls = normal_vec(&mut rng, m, std);
    let pos = normal_vec(&mut rng, f * m, std);
    let mut layers = Vec::with_capacity(config.depth);
    for _ in 0..config.depth {
        let qkv = normal_vec(&mut rng, m * 3 * m, std);
        let proj = normal_vec(&mut rng, m * m, std);
        let mlp1 = normal_vec(&mut rng, m * hidden, std);
        let mlp2 = normal_vec(&mut rng, hidden * m, std);
        layers.push(LayerWeights {
            qkv_bin: binarize(&qkv, m, 3 * m),
            proj_bin: binarize(&proj, m, m),
            mlp1_bin: binarize(&mlp1, m, hidden),
            mlp2_bin: binarize(&mlp2, hidden, m),
            qkv,
            proj,
            mlp1,
            mlp2,
        });
    }
    let head = normal_vec(&mut rng, m * config.num_classes, std);

    VitWeights {
        config: config.clone(),
        seed,
        patch,
        cls,
        pos,
        layers,
        head,
    }
}

impl VitWeights {
    /// A deterministic synthetic input patch matrix `N_p × (3P²)` (the
    /// Fig. 4 flattened-patches view), drawn from the same PRNG family
    /// with an input-specific stream.
    pub fn synthetic_patches(&self, frame_id: u64) -> Vec<f32> {
        let np = self.config.num_patches();
        let patch_in = self.config.in_chans * self.config.patch_size * self.config.patch_size;
        let mut rng = SplitMix64::new(self.seed ^ 0x5EED_F00D ^ frame_id.wrapping_mul(0x9E37));
        (0..np * patch_in)
            .map(|_| rng.next_f32_range(-1.0, 1.0))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::deit_tiny;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut small = deit_tiny();
        small.depth = 2;
        let a = generate_weights(&small, 1);
        let b = generate_weights(&small, 1);
        let c = generate_weights(&small, 2);
        assert_eq!(a.patch, b.patch);
        assert_eq!(a.layers[1].mlp2, b.layers[1].mlp2);
        assert_ne!(a.patch, c.patch);
    }

    #[test]
    fn shapes() {
        let mut cfg = deit_tiny();
        cfg.depth = 1;
        let w = generate_weights(&cfg, 7);
        assert_eq!(w.patch.len(), 768 * 192);
        assert_eq!(w.pos.len(), 197 * 192);
        assert_eq!(w.layers[0].qkv.len(), 192 * 576);
        assert_eq!(w.head.len(), 192 * 1000);
        assert_eq!(w.layers[0].qkv_bin.rows, 192);
        assert_eq!(w.layers[0].qkv_bin.cols, 576);
    }

    #[test]
    fn known_answer_first_weight() {
        // Pinned: python/compile/prng.py asserts the same first draw.
        let cfg = deit_tiny();
        let w = generate_weights(&cfg, 42);
        // First normal from SplitMix64(42) via Box–Muller, × 0.02.
        let expected = {
            let mut r = crate::util::rng::SplitMix64::new(42);
            r.next_normal() as f32 * 0.02
        };
        assert_eq!(w.patch[0], expected);
    }
}
