//! Prepared-model execution plan + reusable per-frame workspace.
//!
//! VAQF generates the accelerator once per model and then streams frames
//! (§5, the 24/30 FPS DeiT-base targets); the simulator mirrors that
//! split here. [`ExecPlan`] is everything about a `(weights, precision,
//! backend)` triple that does **not** depend on the frame:
//!
//! * the column-major packed sign planes of every binary layer (what the
//!   BRAM-resident LUT array holds on the board) — previously repacked on
//!   every `fc_binary` call;
//! * the Q6.10 pre-quantization of every fixed16 weight matrix (patch
//!   embed, head, and all FCs of the unquantized baseline) — previously
//!   requantized on every `fc_fixed16` call;
//! * the scalar backend's ±1 sign materialization (`i8` row-major);
//! * the per-layer cycle accounting (`layer_timing` + host cycles), which
//!   is pure in `(structure, params, device)`.
//!
//! [`Workspace`] is the complementary per-frame arena: every activation
//! buffer, quantization scratch and bit-plane decomposition `run_frame`
//! needs, sized once from the [`VitConfig`] and reused across frames.
//! The steady-state loop's remaining heap traffic is a handful of small
//! per-chunk kernel scratches (one per row-parallel worker per FC call)
//! and the per-frame trace vector — the per-row and per-element
//! allocations of the pre-plan path are gone, which the hotpath bench's
//! counting allocator quantifies (≫10× fewer allocations per frame).
//! Both are owned by `ModelExecutor`; none of this changes any numeric
//! result (the plan caches exactly the values the old code recomputed),
//! which the property suite asserts bit-for-bit.

use std::sync::Arc;

use crate::hw::Device;
use crate::model::{VitConfig, VitStructure};
use crate::perf::{layer_cycles, AcceleratorParams};
use crate::quant::{to_fixed16, BinaryMatrix, BitPlanes, ColPlanes, SignPlanes};
use crate::Cycles;

use super::kernels::Backend;
use super::timing::{layer_timing, LayerTiming};
use super::weights::VitWeights;

/// One FC weight operand, laid out for its datapath.
#[derive(Debug, Clone)]
pub enum PreparedFc {
    /// Q6.10 pre-quantized dense matrix (DSP path).
    Fixed16 {
        wq: Vec<i16>,
        rows: usize,
        cols: usize,
    },
    /// Column-major 64-lane packed sign planes (LUT path, packed
    /// backend), column-strided at the `SIMD_PAD_WORDS` alignment so the
    /// dispatched popcount tiers run whole vectors — the SIMD-friendly
    /// layout is paid for once here at prepare time, never per frame.
    BinaryPacked { planes: SignPlanes, scale: f32 },
    /// Row-major ±1 materialization (LUT path, scalar oracle backend).
    BinaryScalar {
        signs: Vec<i8>,
        rows: usize,
        cols: usize,
        scale: f32,
    },
}

impl PreparedFc {
    /// Pre-quantize a dense f32 matrix for the fixed16 DSP path.
    pub fn fixed16(w: &[f32], rows: usize, cols: usize) -> PreparedFc {
        assert_eq!(w.len(), rows * cols, "shape mismatch");
        PreparedFc::Fixed16 {
            wq: w.iter().map(|&v| to_fixed16(v)).collect(),
            rows,
            cols,
        }
    }

    /// Lay a binary matrix out for `backend`'s LUT datapath.
    pub fn binary(w: &BinaryMatrix, backend: Backend) -> PreparedFc {
        match backend {
            Backend::Packed => PreparedFc::BinaryPacked {
                planes: w.packed_signs(),
                scale: w.scale,
            },
            Backend::Scalar => PreparedFc::BinaryScalar {
                signs: w.signs.iter().map(|&s| if s { 1 } else { -1 }).collect(),
                rows: w.rows,
                cols: w.cols,
                scale: w.scale,
            },
        }
    }

    /// Input dimension (`n`).
    pub fn rows(&self) -> usize {
        match self {
            PreparedFc::Fixed16 { rows, .. } => *rows,
            PreparedFc::BinaryPacked { planes, .. } => planes.rows,
            PreparedFc::BinaryScalar { rows, .. } => *rows,
        }
    }

    /// Output dimension (`m`).
    pub fn cols(&self) -> usize {
        match self {
            PreparedFc::Fixed16 { cols, .. } => *cols,
            PreparedFc::BinaryPacked { planes, .. } => planes.cols,
            PreparedFc::BinaryScalar { cols, .. } => *cols,
        }
    }
}

/// The four prepared FC operands of one encoder layer.
#[derive(Debug, Clone)]
pub struct PreparedLayer {
    pub qkv: PreparedFc,
    pub proj: PreparedFc,
    pub mlp1: PreparedFc,
    pub mlp2: PreparedFc,
}

/// Per-layer accounting cached in the plan: the layer's name (shared
/// `Arc<str>` so per-frame traces clone a refcount, not a heap string),
/// its engine timeline and its host cycles — all pure in
/// `(structure, params, device)`, so walked once here instead of on
/// every frame.
#[derive(Debug, Clone)]
pub struct LayerAccounting {
    pub name: Arc<str>,
    pub timing: LayerTiming,
    pub host: Cycles,
}

/// Everything per-model: prepared weights + per-layer cycle accounting.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    /// The backend this plan's weights are laid out for — the executor's
    /// `with_backend` rebuilds the plan when this disagrees.
    pub backend: Backend,
    /// The accelerator parameterization the plan was prepared for
    /// (precision geometry + tiling — the timings below are a pure
    /// function of it). Like `backend`, this keys the executor's
    /// staleness check: mutating `engine.params` after a frame has run
    /// triggers a rebuild instead of silently serving stale timings.
    pub params: AcceleratorParams,
    /// Patch-embedding FC — always fixed16 (§5.3).
    pub patch: PreparedFc,
    /// Classifier head — always fixed16.
    pub head: PreparedFc,
    pub layers: Vec<PreparedLayer>,
    pub timings: Vec<LayerAccounting>,
}

impl ExecPlan {
    /// Build the plan for `weights` at `act_bits` on `backend`. This is
    /// the one-time per-model compilation cost the per-frame loop
    /// amortizes away.
    pub fn build(
        weights: &VitWeights,
        structure: &VitStructure,
        params: &AcceleratorParams,
        device: &Device,
        backend: Backend,
    ) -> ExecPlan {
        let cfg = &weights.config;
        let quantized = params.act_bits.is_some();
        let m = cfg.embed_dim;
        let hidden = m * cfg.mlp_ratio;
        let patch_in = cfg.in_chans * cfg.patch_size * cfg.patch_size;
        let layers = weights
            .layers
            .iter()
            .map(|lw| {
                if quantized {
                    PreparedLayer {
                        qkv: PreparedFc::binary(&lw.qkv_bin, backend),
                        proj: PreparedFc::binary(&lw.proj_bin, backend),
                        mlp1: PreparedFc::binary(&lw.mlp1_bin, backend),
                        mlp2: PreparedFc::binary(&lw.mlp2_bin, backend),
                    }
                } else {
                    PreparedLayer {
                        qkv: PreparedFc::fixed16(&lw.qkv, m, 3 * m),
                        proj: PreparedFc::fixed16(&lw.proj, m, m),
                        mlp1: PreparedFc::fixed16(&lw.mlp1, m, hidden),
                        mlp2: PreparedFc::fixed16(&lw.mlp2, hidden, m),
                    }
                }
            })
            .collect();
        let timings = structure
            .layers
            .iter()
            .map(|desc| LayerAccounting {
                name: Arc::from(desc.name.as_str()),
                timing: layer_timing(desc, params, device),
                host: layer_cycles(desc, params, device).host,
            })
            .collect();
        ExecPlan {
            backend,
            params: *params,
            patch: PreparedFc::fixed16(&weights.patch, patch_in, m),
            head: PreparedFc::fixed16(&weights.head, m, cfg.num_classes),
            layers,
            timings,
        }
    }
}

/// Reusable quantization scratch for the engine's prepared FC calls.
#[derive(Debug, Default)]
pub struct FcScratch {
    /// `b`-bit quantized activations (LUT path).
    pub xq: Vec<i32>,
    /// Q6.10 quantized activations (DSP path).
    pub x16: Vec<i16>,
}

/// Reusable scratch for one attention matmul (quantize + pack + dot).
#[derive(Debug)]
pub struct AttnScratch {
    pub aq: Vec<i32>,
    pub bq: Vec<i32>,
    pub a16: Vec<i16>,
    pub b16: Vec<i16>,
    pub acc64: Vec<i64>,
    pub acc32: Vec<i32>,
    pub bp: BitPlanes,
    pub cp: ColPlanes,
}

impl AttnScratch {
    pub fn new() -> AttnScratch {
        AttnScratch {
            aq: Vec::new(),
            bq: Vec::new(),
            a16: Vec::new(),
            b16: Vec::new(),
            acc64: Vec::new(),
            acc32: Vec::new(),
            bp: BitPlanes::empty(),
            cp: ColPlanes::empty(),
        }
    }
}

impl Default for AttnScratch {
    fn default() -> AttnScratch {
        AttnScratch::new()
    }
}

/// Per-head working set: the q/k/v column slices, the `Kᵀ` transpose, the
/// score matrix, and the matmul scratch. One per head, so heads
/// parallelize with zero shared mutable state (each head also owns a
/// disjoint `f × M_h` slice of the workspace's head-major output buffer).
#[derive(Debug, Default)]
pub struct HeadScratch {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub kt: Vec<f32>,
    pub s: Vec<f32>,
    pub attn: AttnScratch,
}

/// The per-frame buffer arena: sized once from the [`VitConfig`], reused
/// for every frame. Integer/bit-plane scratches warm up on the first
/// frame and are stable thereafter.
#[derive(Debug)]
pub struct Workspace {
    /// Residual stream `F × M`.
    pub x: Vec<f32>,
    /// LayerNorm output `F × M` (reused for LN1 and LN2).
    pub h: Vec<f32>,
    /// Patch-embedding output `N_p × M`.
    pub pe: Vec<f32>,
    /// QKV projection output `F × 3M`.
    pub qkv: Vec<f32>,
    /// Head-major attention outputs: head `h` owns `[h·F·M_h, (h+1)·F·M_h)`.
    pub attn_heads: Vec<f32>,
    /// Row-major `F × M` reordering of `attn_heads`.
    pub attn_concat: Vec<f32>,
    /// Attention projection output `F × M`.
    pub proj_out: Vec<f32>,
    /// MLP intermediate `F × 4M` (pre-GELU).
    pub mlp1_out: Vec<f32>,
    /// GELU output `F × 4M`.
    pub gelu: Vec<f32>,
    /// MLP output `F × M`.
    pub mlp2_out: Vec<f32>,
    /// CLS representation `1 × M`.
    pub cls: Vec<f32>,
    pub fc: FcScratch,
    pub heads: Vec<HeadScratch>,
}

impl Workspace {
    /// Allocate the arena for `cfg`'s geometry.
    pub fn for_config(cfg: &VitConfig) -> Workspace {
        let m = cfg.embed_dim;
        let f = cfg.tokens();
        let np = cfg.num_patches();
        let mh = cfg.head_dim();
        let hidden = m * cfg.mlp_ratio;
        let mut heads = Vec::with_capacity(cfg.num_heads);
        for _ in 0..cfg.num_heads {
            heads.push(HeadScratch {
                q: vec![0.0; f * mh],
                k: vec![0.0; f * mh],
                v: vec![0.0; f * mh],
                kt: vec![0.0; mh * f],
                s: vec![0.0; f * f],
                attn: AttnScratch::new(),
            });
        }
        Workspace {
            x: vec![0.0; f * m],
            h: vec![0.0; f * m],
            pe: vec![0.0; np * m],
            qkv: vec![0.0; f * 3 * m],
            attn_heads: vec![0.0; f * m],
            attn_concat: vec![0.0; f * m],
            proj_out: vec![0.0; f * m],
            mlp1_out: vec![0.0; f * hidden],
            gelu: vec![0.0; f * hidden],
            mlp2_out: vec![0.0; f * m],
            cls: vec![0.0; m],
            fc: FcScratch::default(),
            heads,
        }
    }
}
