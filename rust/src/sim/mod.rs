//! Cycle-level, functional simulator of the generated ViT accelerator
//! (paper Figs. 3–4 — our substitute for the physical ZCU102, see
//! DESIGN.md §Substitutions).
//!
//! Two concerns, deliberately coupled the way the RTL couples them:
//!
//! * **Function** — [`ComputeEngine`] executes each layer's matrix
//!   multiplication through the *actual* tiled datapaths: the 16-bit
//!   fixed-point DSP path for unquantized layers and the integer
//!   add/sub path (binary weights ⇒ sign-flips) for quantized ones,
//!   with real data packing on the simulated AXI transfers. Numerics are
//!   faithful to what the emitted HLS would compute, and are cross-checked
//!   against the AOT-compiled JAX model via the PJRT runtime
//!   (`rust/tests/sim_vs_runtime.rs`).
//! * **Timing** — [`layer_timing`] walks the same tile schedule and
//!   advances an event timeline (load / compute / store with double
//!   buffering), giving per-layer cycle counts that the `sim_vs_model`
//!   bench compares against the analytical Eqs. 7–11 (they agree closely
//!   but not exactly — the timeline models pipeline fill/drain that the
//!   closed form rounds).
//!
//! [`ModelExecutor`] runs a whole ViT through the engine, handling the
//! host-CPU ops (LayerNorm, softmax, GELU, skip-adds — §5.2) exactly like
//! the embedded ARM host would, and returns logits + a cycle trace.
//!
//! Execution is split per the hardware's own lifecycle (`plan`): an
//! [`ExecPlan`] built once per model caches every frame-independent
//! artifact (packed sign planes, pre-quantized Q6.10 weights, per-layer
//! cycle accounting), and a reusable [`Workspace`] arena makes the
//! per-frame loop allocation-free; [`ModelExecutor::run_batch`]
//! additionally fans frames across workers. All of it is bit-identical to
//! the self-contained single-call engine API, which remains available.
//!
//! The engine executes its integer math through one of two bit-exact
//! kernel [`Backend`]s (`kernels`): the scalar streaming loops (reference
//! oracle) or the default bit-packed XNOR/popcount datapath, with
//! row-parallel fan-out across the frame dimension in both and
//! head-parallel fan-out across attention heads.

mod engine;
mod exec;
mod kernels;
mod plan;
mod timing;
mod weights;

pub use engine::{Backend, ComputeEngine, MatmulResult};
pub use exec::{
    gelu, layer_norm, layer_norm_into, reference_forward, softmax_rows, ExecTrace, LayerTrace,
    ModelExecutor,
};
pub use plan::{
    AttnScratch, ExecPlan, FcScratch, HeadScratch, LayerAccounting, PreparedFc, PreparedLayer,
    Workspace,
};
pub use timing::{layer_timing, model_timing, LayerTiming};
pub use weights::{generate_weights, LayerWeights, VitWeights};

#[cfg(test)]
mod tests;
