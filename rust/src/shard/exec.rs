//! Functional execution of a sharded design: one cycle-level
//! [`ModelExecutor`] per stage, frames handed stage-to-stage through the
//! `F × M` residual stream — exactly the payload the inter-stage FIFOs
//! carry.
//!
//! Stage boundaries sit between whole segments (embed / encoder blocks /
//! head), and the engine's numerics depend only on the weights, the
//! activation precision and the kernel backend — never on the tiling
//! parameters — so pushing a frame through the stages in order is
//! **bit-identical** to [`ModelExecutor::run_frame`] on the unsharded
//! model (property-swept in `rust/tests/property_suite.rs`). What *does*
//! differ per stage is the cycle accounting: each stage's trace is priced
//! by its own co-searched parameterization.

use std::ops::Range;

use crate::sim::{generate_weights, Backend, LayerTrace, ModelExecutor};
use crate::Cycles;

use super::cosearch::ShardedDesign;

/// One stage's executor plus its slice of the model.
struct StageExec {
    exec: ModelExecutor,
    /// Encoder blocks this stage runs (block = six structure layers).
    blocks: Range<usize>,
    has_embed: bool,
    has_head: bool,
}

/// Cycle accounting for one stage of a sharded frame.
#[derive(Debug, Clone)]
pub struct StageTrace {
    pub stage: usize,
    pub engine_cycles: Cycles,
    pub host_cycles: Cycles,
    pub layers: Vec<LayerTrace>,
}

/// Whole-frame record of a stage-by-stage execution.
#[derive(Debug, Clone)]
pub struct ShardedTrace {
    pub stages: Vec<StageTrace>,
}

impl ShardedTrace {
    /// Engine + host cycles summed over every stage (the *work*; the
    /// pipeline overlaps it across frames).
    pub fn total_cycles(&self) -> Cycles {
        self.stages
            .iter()
            .map(|s| s.engine_cycles + s.host_cycles)
            .sum()
    }
}

/// Runs frames through the sharded pipeline's stages in order, on the
/// functional simulator.
pub struct ShardedExecutor {
    stages: Vec<StageExec>,
    depth: usize,
}

impl ShardedExecutor {
    /// Build one executor per stage. Every stage holds the same
    /// deterministic weights (`seed`) and the design's precision; each is
    /// parameterized (and therefore cycle-priced) by its own co-searched
    /// [`crate::perf::AcceleratorParams`].
    ///
    /// Each stage executor owns a full copy of the model weights and
    /// prepares its whole `ExecPlan` lazily (N× memory and N× one-time
    /// packing cost for an N-stage pipeline). That is fine for the
    /// micro/tiny models this functional cross-check path drives; if
    /// DeiT-scale sharded *functional* execution becomes a hot path,
    /// slice the weights and plan to `stage.layer_range` (the throughput
    /// studies use the analytic pipeline DES, which carries no weights).
    pub fn new(
        design: &ShardedDesign,
        backend: Backend,
        threads: usize,
        seed: u64,
    ) -> ShardedExecutor {
        let weights = generate_weights(&design.model, seed);
        let depth = design.model.depth;
        let stages = design
            .stages
            .iter()
            .map(|stage| {
                let r = &stage.segment_range;
                // Segment indices: 0 = embed, 1..=depth = blocks,
                // depth+1 = head.
                let blocks = r.start.max(1) - 1..r.end.min(depth + 1) - 1;
                StageExec {
                    exec: ModelExecutor::new(
                        weights.clone(),
                        design.act_bits,
                        stage.params,
                        design.device.clone(),
                    )
                    .with_backend(backend)
                    .with_threads(threads),
                    blocks,
                    has_embed: r.start == 0,
                    has_head: r.end == depth + 2,
                }
            })
            .collect();
        ShardedExecutor { stages, depth }
    }

    pub fn shards(&self) -> usize {
        self.stages.len()
    }

    /// Run one frame through every stage in order: logits plus the
    /// per-stage cycle traces.
    pub fn run_frame(&mut self, patches: &[f32]) -> (Vec<f32>, ShardedTrace) {
        let mut residual: Vec<f32> = Vec::new();
        let mut logits: Option<Vec<f32>> = None;
        let mut stage_traces = Vec::with_capacity(self.stages.len());
        let last = self.stages.len() - 1;
        for (si, st) in self.stages.iter_mut().enumerate() {
            let mut layers: Vec<LayerTrace> = Vec::new();
            if st.has_embed {
                layers.extend(st.exec.stage_embed(patches));
            } else {
                st.exec.set_residual(&residual);
            }
            layers.extend(st.exec.stage_blocks(st.blocks.clone()));
            if st.has_head {
                debug_assert_eq!(si, last, "head runs on the last stage");
                debug_assert_eq!(st.blocks.end, self.depth, "head follows the final block");
                let (lg, head_traces) = st.exec.stage_head();
                layers.extend(head_traces);
                logits = Some(lg);
            } else {
                residual = st.exec.residual().to_vec();
            }
            stage_traces.push(StageTrace {
                stage: si,
                engine_cycles: layers.iter().map(|t| t.engine_cycles).sum(),
                host_cycles: layers.iter().map(|t| t.host_cycles).sum(),
                layers,
            });
        }
        (
            logits.expect("the last stage holds the classifier head"),
            ShardedTrace {
                stages: stage_traces,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{optimize_baseline, optimize_for_bits};
    use crate::hw::zcu102;
    use crate::model::micro;
    use crate::shard::{co_search, ShardPolicy};

    #[test]
    fn sharded_logits_match_unsharded_bitwise() {
        let model = micro();
        let device = zcu102();
        let baseline = optimize_baseline(&model.structure(None), &device);
        let reference =
            optimize_for_bits(&model.structure(Some(8)), &baseline, &device, 8).unwrap();
        let seed = 7;
        let weights = generate_weights(&model, seed);
        let mut whole = ModelExecutor::new(
            weights.clone(),
            Some(8),
            reference.params,
            device.clone(),
        );
        for n in 1..=3usize {
            let design =
                co_search(&model, &device, Some(8), &reference, n, ShardPolicy::Balanced)
                    .unwrap();
            let mut sharded = ShardedExecutor::new(&design, Backend::Packed, 1, seed);
            for frame in 0..2u64 {
                let patches = weights.synthetic_patches(frame);
                let (expect, _) = whole.run_frame(&patches);
                let (got, trace) = sharded.run_frame(&patches);
                assert_eq!(got, expect, "n={n} frame={frame}");
                assert_eq!(trace.stages.len(), n);
            }
        }
    }
}
