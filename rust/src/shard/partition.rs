//! Layer partitioner: contiguous min-max / even / min-variance splits of
//! the model's segment sequence.
//!
//! The unit of partitioning is a *segment* — the patch embedding, one
//! whole encoder block (six structure layers), or the classifier head —
//! because those are the points where the inter-stage payload is exactly
//! the `F × M` residual stream (cutting inside a block would ship partial
//! attention state). Each segment is costed with the per-layer
//! [`LayerCycles`] breakdown from `perf::cycles` under a reference
//! parameterization, and the partitioner splits the cost sequence into
//! `n` contiguous, non-empty ranges.

use std::ops::Range;

use crate::model::VitStructure;
use crate::perf::LayerCycles;
use crate::Cycles;

/// How the partitioner balances stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Minimize the maximum stage cost (the steady-state pipeline
    /// bottleneck) — exact DP over contiguous partitions.
    Balanced,
    /// Equal segment *counts* per stage (ignores costs; the naive split).
    Even,
    /// Minimize the sum of squared stage costs: same Σ, smoother stages —
    /// lower queue-wait jitter and per-frame latency spread than pure
    /// min-max when several partitions tie on the bottleneck.
    MinLatency,
}

impl ShardPolicy {
    /// Policy-name hint for error messages (keep in sync with
    /// [`ShardPolicy::from_name`]).
    pub const NAMES: &'static str = "balanced/even/min-latency";

    pub fn from_name(name: &str) -> Option<ShardPolicy> {
        match name.to_ascii_lowercase().as_str() {
            "balanced" => Some(ShardPolicy::Balanced),
            "even" => Some(ShardPolicy::Even),
            "min-latency" | "min_latency" => Some(ShardPolicy::MinLatency),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::Balanced => "balanced",
            ShardPolicy::Even => "even",
            ShardPolicy::MinLatency => "min-latency",
        }
    }
}

/// One partitionable unit of the model: a contiguous run of structure
/// layers with a single cycle cost.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Human-readable label (`embed`, `enc3`, `head`).
    pub label: String,
    /// The structure-layer indices this segment covers.
    pub layers: Range<usize>,
    /// Engine + host cycles under the reference parameterization.
    pub cycles: Cycles,
}

/// Split `structure` into its natural pipeline segments, costing each
/// with the matching entries of `per_layer` (the
/// [`crate::perf::model_cycles`] breakdown — one entry per structure
/// layer).
pub fn segments_for(structure: &VitStructure, per_layer: &[LayerCycles]) -> Vec<Segment> {
    assert_eq!(
        per_layer.len(),
        structure.layers.len(),
        "per-layer breakdown must cover every structure layer"
    );
    let depth = structure.config.depth;
    assert_eq!(
        structure.layers.len(),
        2 + 6 * depth,
        "unexpected layer sequence shape"
    );
    let cost = |range: &Range<usize>| -> Cycles {
        per_layer[range.clone()]
            .iter()
            .map(|c| c.total + c.host)
            .sum()
    };
    let mut segments = Vec::with_capacity(depth + 2);
    let embed = 0..1;
    segments.push(Segment {
        label: "embed".to_string(),
        cycles: cost(&embed),
        layers: embed,
    });
    for b in 0..depth {
        let range = (1 + 6 * b)..(1 + 6 * (b + 1));
        segments.push(Segment {
            label: format!("enc{b}"),
            cycles: cost(&range),
            layers: range,
        });
    }
    let head = (1 + 6 * depth)..(2 + 6 * depth);
    segments.push(Segment {
        label: "head".to_string(),
        cycles: cost(&head),
        layers: head,
    });
    segments
}

/// Partition `costs` into exactly `n` contiguous non-empty ranges under
/// `policy`. Deterministic: a pure function of its inputs (ties broken
/// toward the earliest cut).
pub fn partition(
    costs: &[Cycles],
    n: usize,
    policy: ShardPolicy,
) -> anyhow::Result<Vec<Range<usize>>> {
    anyhow::ensure!(n > 0, "cannot partition into 0 shards");
    anyhow::ensure!(
        n <= costs.len(),
        "cannot split {} segments into {n} non-empty shards",
        costs.len()
    );
    let ranges = match policy {
        ShardPolicy::Even => even_partition(costs.len(), n),
        ShardPolicy::Balanced => dp_partition(costs, n, |max: u128, _sq: u128| max),
        ShardPolicy::MinLatency => dp_partition(costs, n, |_max: u128, sq: u128| sq),
    };
    debug_assert_eq!(ranges.len(), n);
    debug_assert_eq!(ranges.first().map(|r| r.start), Some(0));
    debug_assert_eq!(ranges.last().map(|r| r.end), Some(costs.len()));
    Ok(ranges)
}

/// The bottleneck (maximum stage cost) of a partition.
pub fn max_stage_cost(costs: &[Cycles], ranges: &[Range<usize>]) -> Cycles {
    ranges
        .iter()
        .map(|r| costs[r.clone()].iter().sum::<Cycles>())
        .max()
        .unwrap_or(0)
}

/// Equal-count split: the first `len % n` stages get one extra segment.
fn even_partition(len: usize, n: usize) -> Vec<Range<usize>> {
    let base = len / n;
    let extra = len % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for i in 0..n {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Exact DP over contiguous partitions, minimizing a per-stage objective
/// folded as `(max stage cost, Σ stage cost², …)`. `objective` picks the
/// scalar to minimize from the fold of one candidate partition's last
/// stage combined with the best prefix. Stage counts here are tiny
/// (≤ depth + 2 segments), so the O(S²·n) table is free.
///
/// For `Balanced` this returns a partition whose bottleneck equals the
/// true optimum over all contiguous `n`-partitions (the property suite
/// cross-checks it against brute-force enumeration).
fn dp_partition(
    costs: &[Cycles],
    n: usize,
    objective: fn(u128, u128) -> u128,
) -> Vec<Range<usize>> {
    let s = costs.len();
    let mut prefix = vec![0u128; s + 1];
    for (i, &c) in costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c as u128;
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a]; // cost of [a, b)

    // best[k][i]: minimal objective splitting the first `i` segments into
    // `k` stages; fold state carried per cell as (max, sumsq).
    const INF: u128 = u128::MAX;
    let mut best = vec![vec![INF; s + 1]; n + 1];
    let mut state = vec![vec![(0u128, 0u128); s + 1]; n + 1]; // (max, sumsq)
    let mut cut = vec![vec![0usize; s + 1]; n + 1];
    best[0][0] = 0;
    for k in 1..=n {
        // Each of the k stages is non-empty: i ranges over k..=s, and the
        // previous cut j over (k-1)..i.
        for i in k..=s {
            for j in (k - 1)..i {
                if best[k - 1][j] == INF {
                    continue;
                }
                let c = seg(j, i);
                let (pmax, psq) = state[k - 1][j];
                let max = pmax.max(c);
                let sq = psq + c * c;
                let obj = objective(max, sq);
                if obj < best[k][i] {
                    best[k][i] = obj;
                    state[k][i] = (max, sq);
                    cut[k][i] = j;
                }
            }
        }
    }
    // Walk the cuts back.
    let mut bounds = vec![s];
    let mut i = s;
    for k in (1..=n).rev() {
        i = cut[k][i];
        bounds.push(i);
    }
    bounds.reverse();
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_counts() {
        let ranges = partition(&[1, 1, 1, 1, 1, 1, 1], 3, ShardPolicy::Even).unwrap();
        assert_eq!(ranges, vec![0..3, 3..5, 5..7]);
    }

    #[test]
    fn balanced_beats_even_on_skewed_costs() {
        let costs = [10, 1, 1, 1, 1, 1, 1];
        let bal = partition(&costs, 2, ShardPolicy::Balanced).unwrap();
        let even = partition(&costs, 2, ShardPolicy::Even).unwrap();
        assert!(max_stage_cost(&costs, &bal) <= max_stage_cost(&costs, &even));
        assert_eq!(max_stage_cost(&costs, &bal), 10);
    }

    #[test]
    fn n_equals_len_gives_singletons() {
        let costs = [3, 2, 5];
        for policy in [ShardPolicy::Balanced, ShardPolicy::Even, ShardPolicy::MinLatency] {
            let ranges = partition(&costs, 3, policy).unwrap();
            assert_eq!(ranges, vec![0..1, 1..2, 2..3]);
        }
    }

    #[test]
    fn too_many_shards_is_an_error() {
        assert!(partition(&[1, 2], 3, ShardPolicy::Balanced).is_err());
        assert!(partition(&[1, 2], 0, ShardPolicy::Balanced).is_err());
    }

    #[test]
    fn policy_names_round_trip() {
        for p in [ShardPolicy::Balanced, ShardPolicy::Even, ShardPolicy::MinLatency] {
            assert_eq!(ShardPolicy::from_name(p.name()), Some(p));
        }
        assert_eq!(ShardPolicy::from_name("bogus"), None);
    }
}
