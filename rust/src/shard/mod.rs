//! Pipeline-parallel multi-accelerator sharding (the ROADMAP's
//! "scale further via sharding" direction).
//!
//! One VAQF accelerator tops out at whatever a single board reaches at
//! the chosen precision. This module splits the ViT's layer sequence
//! across `N` accelerator instances (boards, or fully-provisioned die
//! partitions) and pipelines frames through the stages:
//!
//! ```text
//! patches ─► [stage 0: embed..enc4] ─FIFO─► [stage 1: enc5..head] ─► logits
//!                 (own AcceleratorParams)        (own AcceleratorParams)
//! ```
//!
//! * [`partition`] — contiguous min-max / even / min-variance splits of
//!   the segment sequence (embed / encoder blocks / head), costed with
//!   the per-layer [`crate::perf::LayerCycles`] breakdown;
//! * [`co_search`] — the existing compiler parameter search, run per
//!   shard over the shard's own layer slice against the per-shard
//!   resource budget, producing a [`ShardedDesign`] (one
//!   `AcceleratorParams` + analytic summary per stage, inter-stage FIFOs
//!   sized from the token-embedding transfer volume);
//! * [`simulate_pipeline`] — a discrete-event simulation of the stage
//!   pipeline on the coordinator's deterministic
//!   [`crate::coordinator::VirtualClock`]: fill, steady-state cadence,
//!   FIFO backpressure, occupancy, per-frame latency percentiles;
//! * [`ShardedExecutor`] — the functional path: per-stage cycle-level
//!   executors handing the residual stream along, bit-identical to
//!   `run_frame` on the unsharded model.
//!
//! The facade surfaces this as `api::Session::compile_sharded` /
//! `api::CompiledDesign::shards`, the CLI as `vaqf shard`.

mod cosearch;
mod exec;
mod partition;
mod pipeline;
mod report;

pub use cosearch::{co_search, co_search_with_ctx, FifoSpec, ShardStage, ShardedDesign};
pub use exec::{ShardedExecutor, ShardedTrace, StageTrace};
pub use partition::{max_stage_cost, partition, segments_for, Segment, ShardPolicy};
pub use pipeline::{
    simulate_pipeline, simulate_pipeline_faulty, FailoverStrategy, PipelineReport, StageOccupancy,
};
pub use report::ShardReport;
