//! Per-shard accelerator co-search: one `AcceleratorParams` per pipeline
//! stage, each optimized by the existing compiler search over the stage's
//! own layer slice and checked against the per-shard resource budget.
//!
//! The deployment model is an `N`-instance pipeline (N boards, or N
//! fully-provisioned die partitions): the pipeline's total budget is `N ×`
//! the device inventory and each stage must fit its `1/N` slice — i.e.
//! one device budget, DMA/control overhead included. (Slicing a *single*
//! die's budget `N` ways instead is a dead end in this resource model:
//! the fixed AXI/control LUT overhead is charged per instance, so a half
//! budget leaves almost nothing for MAC arrays — measured in
//! EXPERIMENTS.md §Sharding.)

use std::ops::Range;
use std::sync::Arc;

use crate::compiler::{DesignPoint, SearchCtx};
use crate::hw::{Device, ResourceBudget};
use crate::model::{VitConfig, VitStructure};
use crate::perf::{model_cycles, resources_for, summarize, AcceleratorParams, PerfSummary};
use crate::util::parallel;
use crate::Cycles;

use super::partition::{max_stage_cost, partition, segments_for, Segment, ShardPolicy};

/// The inter-stage FIFO feeding one pipeline stage, sized from the
/// token-embedding transfer volume (the `F × M` 16-bit residual stream —
/// stage boundaries sit between whole segments precisely so this is the
/// entire payload; stage 0 receives raw patches instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FifoSpec {
    /// Depth in frames (2 ⇒ the link is double-buffered: one frame
    /// draining into the stage while the next fills).
    pub frames: u64,
    /// Payload bits per frame.
    pub bits_per_frame: u64,
    /// BRAM18k blocks the FIFO occupies on the receiving shard.
    pub bram18k: u64,
    /// Cycles to move one frame across the link (`axi_ports_in` ports of
    /// `axi_port_bits` each, one beat per cycle).
    pub transfer_cycles: Cycles,
}

impl FifoSpec {
    fn new(bits_per_frame: u64, frames: u64, device: &Device) -> FifoSpec {
        let link_bits = u64::from(device.axi_port_bits) * device.axi_ports_in;
        FifoSpec {
            frames,
            bits_per_frame,
            bram18k: (frames * bits_per_frame).div_ceil(18 * 1024),
            transfer_cycles: bits_per_frame.div_ceil(link_bits),
        }
    }
}

/// One pipeline stage of a [`ShardedDesign`]: a contiguous segment range,
/// its co-searched accelerator parameters, and its analytic performance
/// on the stage's layer slice.
#[derive(Debug, Clone)]
pub struct ShardStage {
    pub index: usize,
    /// Segment indices (into [`ShardedDesign::segments`]) this stage runs.
    pub segment_range: Range<usize>,
    /// Structure-layer indices this stage runs.
    pub layer_range: Range<usize>,
    /// Human-readable coverage, e.g. `embed..enc3`.
    pub label: String,
    /// The stage's own co-searched accelerator parameterization.
    pub params: AcceleratorParams,
    /// Analytic summary of this stage's layer slice under `params` on the
    /// per-shard device (FPS here is the stage's isolated rate).
    pub summary: PerfSummary,
    /// Cycles per frame through this stage's layers under `params`.
    pub compute_cycles: Cycles,
    /// The FIFO feeding this stage.
    pub fifo: FifoSpec,
}

impl ShardStage {
    /// Per-frame service time: input transfer + compute. The pipeline's
    /// steady-state cadence is the maximum of this over stages.
    pub fn service_cycles(&self) -> Cycles {
        self.compute_cycles + self.fifo.transfer_cycles
    }
}

/// A model compiled onto an `n`-stage accelerator pipeline.
#[derive(Debug, Clone)]
pub struct ShardedDesign {
    pub model: VitConfig,
    /// The per-shard device (one board / fully-provisioned die slice).
    pub device: Device,
    pub act_bits: Option<u8>,
    pub policy: ShardPolicy,
    /// The partitionable segments with their reference cycle costs.
    pub segments: Vec<Segment>,
    pub stages: Vec<ShardStage>,
    /// The unsharded design the partition was costed against (and the
    /// speedup baseline).
    pub reference: DesignPoint,
    /// The search context every stage was optimized through. Carried so a
    /// live repartition (pipeline failover after a board crash) re-runs
    /// the per-stage searches against warm memo tables — stages whose
    /// layer slices survive the repartition are cache hits.
    pub(crate) ctx: Arc<SearchCtx>,
}

impl ShardedDesign {
    pub fn shards(&self) -> usize {
        self.stages.len()
    }

    /// The steady-state bottleneck: the largest per-stage service time.
    pub fn bottleneck_cycles(&self) -> Cycles {
        self.stages
            .iter()
            .map(ShardStage::service_cycles)
            .max()
            .unwrap_or(0)
    }

    /// Steady-state pipeline throughput (one frame per bottleneck
    /// cadence once the pipeline is full).
    pub fn steady_state_fps(&self) -> f64 {
        self.device.fps(self.bottleneck_cycles())
    }

    /// Zero-contention per-frame latency: one pass through every stage
    /// (queue waits come from the discrete-event simulation).
    pub fn fill_cycles(&self) -> Cycles {
        self.stages.iter().map(ShardStage::service_cycles).sum()
    }

    /// Steady-state speedup over the unsharded reference design.
    pub fn speedup_vs_unsharded(&self) -> f64 {
        self.steady_state_fps() / self.reference.summary.fps
    }

    /// The budget each stage must fit: one device inventory — the `1/N`
    /// slice of the pipeline's total (`N` boards).
    pub fn per_shard_budget(&self) -> &ResourceBudget {
        &self.device.budget
    }

    /// The partition's bottleneck in reference-parameterization cycles
    /// (what the partitioner optimized, before per-shard re-search).
    pub fn partition_bottleneck_cycles(&self) -> Cycles {
        let costs: Vec<Cycles> = self.segments.iter().map(|s| s.cycles).collect();
        let ranges: Vec<Range<usize>> = self
            .stages
            .iter()
            .map(|s| s.segment_range.clone())
            .collect();
        max_stage_cost(&costs, &ranges)
    }
}

/// Slice a structure to a contiguous layer range, keeping the config and
/// quantization regime (the resource/latency model only reads `layers`
/// and `act_bits`).
fn slice_structure(structure: &VitStructure, layers: &Range<usize>) -> VitStructure {
    VitStructure {
        config: structure.config.clone(),
        act_bits: structure.act_bits,
        layers: structure.layers[layers.clone()].to_vec(),
    }
}

/// Partition `model` into `n` pipeline stages and co-search each stage's
/// accelerator parameters under the per-shard budget.
///
/// `reference` is the unsharded design at the same precision: its
/// parameterization prices the per-layer cycle breakdown the partitioner
/// balances, and its predicted FPS is the speedup baseline.
pub fn co_search(
    model: &VitConfig,
    device: &Device,
    act_bits: Option<u8>,
    reference: &DesignPoint,
    n: usize,
    policy: ShardPolicy,
) -> anyhow::Result<ShardedDesign> {
    co_search_with_ctx(
        model,
        device,
        act_bits,
        reference,
        n,
        policy,
        Arc::new(SearchCtx::new()),
    )
}

/// [`co_search`] through a shared [`SearchCtx`]: the per-stage baseline
/// and precision searches land in (and are served from) the context's
/// memo tables, and stages are searched in parallel across the context's
/// thread budget. Stage results are collected in stage order, so the
/// design — and the first error, when one occurs — is byte-identical to
/// the serial search for every thread count.
#[allow(clippy::too_many_arguments)]
pub fn co_search_with_ctx(
    model: &VitConfig,
    device: &Device,
    act_bits: Option<u8>,
    reference: &DesignPoint,
    n: usize,
    policy: ShardPolicy,
    ctx: Arc<SearchCtx>,
) -> anyhow::Result<ShardedDesign> {
    let structure = model.structure(act_bits);
    let unquantized = model.structure(None);

    // Cost every layer under the unsharded reference parameterization,
    // fold into segments, partition.
    let (_, per_layer) = model_cycles(&structure, &reference.params, device);
    let segments = segments_for(&structure, &per_layer);
    let costs: Vec<Cycles> = segments.iter().map(|s| s.cycles).collect();
    let ranges = partition(&costs, n, policy)?;

    // Token-embedding payload between stages; raw patches into stage 0.
    let f = model.tokens() as u64;
    let m = model.embed_dim as u64;
    let residual_bits = f * m * 16;
    let patch_bits =
        (model.num_patches() * model.in_chans * model.patch_size * model.patch_size) as u64 * 16;

    // Each stage's search touches only its own layer slice, so the
    // stages fan out across the context's thread budget; collecting in
    // stage order keeps the result deterministic.
    let search_stage = |index: usize| -> anyhow::Result<ShardStage> {
        let seg_range = ranges[index].clone();
        let layer_range =
            segments[seg_range.start].layers.start..segments[seg_range.end - 1].layers.end;
        let label = if seg_range.len() == 1 {
            segments[seg_range.start].label.clone()
        } else {
            format!(
                "{}..{}",
                segments[seg_range.start].label,
                segments[seg_range.end - 1].label
            )
        };
        let sub = slice_structure(&structure, &layer_range);
        let sub_unq = slice_structure(&unquantized, &layer_range);

        // The stage's input FIFO lives in the receiving shard's BRAM, so
        // the parameter search runs against a budget with those blocks
        // already debited — compute + FIFO together must fit the board.
        let fifo_bits = if index == 0 { patch_bits } else { residual_bits };
        let fifo = FifoSpec::new(fifo_bits, 2, device);
        anyhow::ensure!(
            fifo.bram18k < device.budget.bram18k,
            "shard {index} ({label}): input FIFO alone ({} BRAM18k) exceeds {}'s BRAM",
            fifo.bram18k,
            device.name
        );
        let mut stage_device = device.clone();
        stage_device.budget.bram18k -= fifo.bram18k;

        // Guard the baseline search's panic-on-infeasible: if even the
        // smallest tiling cannot place, surface a typed error instead.
        let g = (device.axi_port_bits / 16) as u64;
        let n_h = sub_unq.layers.iter().map(|l| l.heads as u64).max().unwrap_or(1);
        let minimal = AcceleratorParams::baseline(g, 1, g, AcceleratorParams::p_h_for(n_h));
        anyhow::ensure!(
            resources_for(&sub_unq, &minimal, &stage_device).feasible(&stage_device),
            "shard {index} ({label}) cannot fit on {} even at minimal tiling",
            device.name
        );
        let baseline = ctx.optimize_baseline(&sub_unq, &stage_device);
        let params = match act_bits {
            None => baseline,
            Some(bits) => ctx.optimize_for_bits(&sub, &baseline, &stage_device, bits)?.params,
        };
        // Summarize against the undivided board inventory so every
        // stage's utilization percentages share one denominator (the
        // FIFO-debited search guarantees compute + FIFO fit it; the
        // budget never enters the cycle model, so cycles are unchanged).
        let summary = match act_bits {
            None => summarize(&sub_unq, &params, device),
            Some(_) => summarize(&sub, &params, device),
        };
        Ok(ShardStage {
            index,
            segment_range: seg_range,
            layer_range,
            label,
            params,
            compute_cycles: summary.cycles_per_frame,
            summary,
            fifo,
        })
    };
    let stages = parallel::map_tasks(
        ranges.len(),
        ctx.threads(),
        parallel::MIN_WORK_PER_THREAD,
        search_stage,
    )
    .into_iter()
    .collect::<anyhow::Result<Vec<ShardStage>>>()?;

    Ok(ShardedDesign {
        model: model.clone(),
        device: device.clone(),
        act_bits,
        policy,
        segments,
        stages,
        reference: reference.clone(),
        ctx,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{optimize_baseline, optimize_for_bits};
    use crate::hw::zcu102;
    use crate::model::micro;

    fn micro_reference(act_bits: Option<u8>) -> (VitConfig, Device, DesignPoint) {
        let model = micro();
        let device = zcu102();
        let baseline = optimize_baseline(&model.structure(None), &device);
        let design = match act_bits {
            None => DesignPoint {
                params: baseline,
                summary: summarize(&model.structure(None), &baseline, &device),
                adjustments: 0,
            },
            Some(b) => {
                optimize_for_bits(&model.structure(Some(b)), &baseline, &device, b).unwrap()
            }
        };
        (model, device, design)
    }

    #[test]
    fn micro_two_shards_cover_all_layers() {
        let (model, device, reference) = micro_reference(Some(8));
        let d = co_search(&model, &device, Some(8), &reference, 2, ShardPolicy::Balanced)
            .unwrap();
        assert_eq!(d.shards(), 2);
        assert_eq!(d.stages[0].layer_range.start, 0);
        assert_eq!(
            d.stages.last().unwrap().layer_range.end,
            model.structure(Some(8)).layers.len()
        );
        assert_eq!(d.stages[0].layer_range.end, d.stages[1].layer_range.start);
        // Every stage fits its per-shard budget — including the input
        // FIFO's BRAM, which the co-search debits before placing.
        for s in &d.stages {
            assert!(s.summary.utilization.fits(d.per_shard_budget()));
            assert!(
                s.summary.utilization.bram18k + s.fifo.bram18k
                    <= d.per_shard_budget().bram18k
            );
        }
        // Pipelining cannot be slower than the bottleneck bound says.
        assert!(d.steady_state_fps() > 0.0);
        assert!(d.fill_cycles() >= d.bottleneck_cycles());
    }

    #[test]
    fn single_shard_matches_unsharded_reference_rate() {
        let (model, device, reference) = micro_reference(Some(8));
        let d = co_search(&model, &device, Some(8), &reference, 1, ShardPolicy::Balanced)
            .unwrap();
        // One stage re-searched over the full model on the full budget:
        // same search space as the reference ⇒ same predicted cycles; the
        // only overhead is the input transfer.
        assert_eq!(d.stages[0].compute_cycles, reference.summary.cycles_per_frame);
        assert!(d.speedup_vs_unsharded() <= 1.0);
        assert!(d.speedup_vs_unsharded() > 0.9);
    }

    #[test]
    fn unquantized_sharding_works_too() {
        let (model, device, reference) = micro_reference(None);
        let d = co_search(&model, &device, None, &reference, 2, ShardPolicy::Even).unwrap();
        assert_eq!(d.shards(), 2);
        assert!(d.stages.iter().all(|s| s.params.act_bits.is_none()));
    }

    #[test]
    fn too_many_shards_for_model_errors() {
        let (model, device, reference) = micro_reference(Some(8));
        // micro has depth 2 ⇒ 4 segments; 5 shards cannot be non-empty.
        assert!(
            co_search(&model, &device, Some(8), &reference, 5, ShardPolicy::Balanced).is_err()
        );
    }
}
