//! Rendering for sharded designs: human-readable table + deterministic
//! JSON (golden-snapshotted in `rust/tests/golden_files.rs`).

use crate::obs::latency_ms;
use crate::util::json::Json;

use super::cosearch::{ShardStage, ShardedDesign};
use super::pipeline::PipelineReport;

/// A sharded design paired with one discrete-event pipeline run — what
/// the `vaqf shard` subcommand and the sharding bench report.
#[derive(Debug, Clone)]
pub struct ShardReport {
    pub design: ShardedDesign,
    pub pipeline: PipelineReport,
}

impl ShardedDesign {
    /// Run the pipeline simulation and bundle it with the design for
    /// rendering.
    pub fn report(&self, frames: u64) -> ShardReport {
        ShardReport {
            pipeline: self.simulate_pipeline(frames),
            design: self.clone(),
        }
    }

    /// [`ShardedDesign::report`] with a fault plan injected into the
    /// pipeline run (see
    /// [`simulate_pipeline_faulty`](super::simulate_pipeline_faulty)).
    pub fn report_with_faults(
        &self,
        frames: u64,
        plan: &crate::fault::FaultPlan,
        strategy: super::pipeline::FailoverStrategy,
    ) -> anyhow::Result<ShardReport> {
        Ok(ShardReport {
            pipeline: super::pipeline::simulate_pipeline_faulty(
                self, frames, None, plan, strategy,
            )?,
            design: self.clone(),
        })
    }
}

fn stage_json(stage: &ShardStage, design: &ShardedDesign) -> Json {
    let p = &stage.params;
    let u = &stage.summary.utilization_pct;
    Json::obj()
        .set("stage", stage.index)
        .set("covers", stage.label.as_str())
        .set("layers", stage.layer_range.len())
        .set("segments", stage.segment_range.len())
        .set(
            "params",
            Json::obj()
                .set("t_m", p.t_m)
                .set("t_n", p.t_n)
                .set("t_m_q", p.t_m_q)
                .set("t_n_q", p.t_n_q)
                .set("g", p.g)
                .set("g_q", p.g_q)
                .set("p_h", p.p_h),
        )
        .set("compute_cycles", stage.compute_cycles)
        .set("transfer_cycles", stage.fifo.transfer_cycles)
        .set("service_cycles", stage.service_cycles())
        .set("stage_fps", design.device.fps(stage.service_cycles()))
        .set(
            "utilization_pct",
            Json::obj()
                .set("dsp", u.dsp)
                .set("lut", u.lut)
                .set("bram18k", u.bram18k)
                .set("ff", u.ff),
        )
        .set(
            "fifo",
            Json::obj()
                .set("frames", stage.fifo.frames)
                .set("bits_per_frame", stage.fifo.bits_per_frame)
                .set("bram18k", stage.fifo.bram18k),
        )
}

impl ShardReport {
    pub fn to_json(&self) -> Json {
        let d = &self.design;
        let p = &self.pipeline;
        let mut j = Json::obj()
            .set("model", d.model.name.as_str())
            .set("device", d.device.name.as_str())
            .set("precision", d.reference.summary.label.as_str())
            .set("shards", d.shards())
            .set("policy", d.policy.name())
            .set(
                "budget_per_shard",
                Json::obj()
                    .set("dsp", d.per_shard_budget().dsp)
                    .set("lut", d.per_shard_budget().lut)
                    .set("bram18k", d.per_shard_budget().bram18k)
                    .set("ff", d.per_shard_budget().ff),
            )
            .set(
                "stages",
                Json::Arr(d.stages.iter().map(|s| stage_json(s, d)).collect()),
            )
            .set("unsharded_fps", d.reference.summary.fps)
            .set("bottleneck_cycles", d.bottleneck_cycles())
            .set("steady_state_fps", p.steady_fps)
            .set("overall_fps", p.overall_fps)
            .set("speedup_vs_unsharded", p.steady_fps / d.reference.summary.fps)
            .set("frames", p.frames)
            .set("fill_ms", d.device.cycles_to_seconds(p.fill_cycles) * 1e3)
            .set("elapsed_ms", d.device.cycles_to_seconds(p.elapsed_cycles) * 1e3)
            .set("latency_ms", latency_ms(&p.latency))
            .set(
                "occupancy",
                Json::Arr(
                    p.stages
                        .iter()
                        .map(|s| {
                            Json::obj()
                                .set("stage", s.stage)
                                .set("served", s.served)
                                .set("busy_frac", s.busy_frac)
                                .set("blocked_frac", s.blocked_frac)
                                .set("mean_queue_wait_cycles", s.mean_queue_wait_cycles)
                                .set("peak_queue", s.peak_queue)
                        })
                        .collect(),
                ),
            );
        // Only fault-injected runs carry the block, so fault-free report
        // JSON (golden-snapshotted) is byte-identical to earlier builds.
        if let Some(f) = &p.faults {
            j = j.set("faults", f.to_json());
        }
        j
    }

    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let d = &self.design;
        let p = &self.pipeline;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{} ({}) on {} × {} shards — {} partition",
            d.model.name,
            d.reference.summary.label,
            d.device.name,
            d.shards(),
            d.policy.name(),
        );
        for s in &d.stages {
            let u = &s.summary.utilization_pct;
            let _ = writeln!(
                out,
                "  stage {i}: {cov:<14} {layers:>2} layers  {kc:>7} kcycles (+{xf} xfer)  \
                 {fps:>6.1} FPS alone  DSP {dsp:>4.1}%  LUT {lut:>4.1}%  BRAM {bram:>4.1}%",
                i = s.index,
                cov = s.label,
                layers = s.layer_range.len(),
                kc = s.compute_cycles / 1000,
                xf = s.fifo.transfer_cycles,
                fps = d.device.fps(s.service_cycles()),
                dsp = u.dsp,
                lut = u.lut,
                bram = u.bram18k,
            );
        }
        let _ = writeln!(
            out,
            "  pipeline: steady {steady:.1} FPS ({speed:.2}× the {base:.1} FPS unsharded design), \
             fill {fill:.2} ms",
            steady = p.steady_fps,
            speed = p.steady_fps / d.reference.summary.fps,
            base = d.reference.summary.fps,
            fill = d.device.cycles_to_seconds(p.fill_cycles) * 1e3,
        );
        let _ = writeln!(
            out,
            "  per-frame latency  p50 {p50:.2} ms  p95 {p95:.2} ms  p99 {p99:.2} ms  \
             ({n} frames simulated)",
            p50 = p.latency.p50 * 1e3,
            p95 = p.latency.p95 * 1e3,
            p99 = p.latency.p99 * 1e3,
            n = p.frames,
        );
        for s in &p.stages {
            let _ = writeln!(
                out,
                "  occupancy stage {i}: busy {busy:.0}%  blocked {blk:.0}%  \
                 mean queue wait {qw:.0} cycles  peak queue {pk}",
                i = s.stage,
                busy = 100.0 * s.busy_frac,
                blk = 100.0 * s.blocked_frac,
                qw = s.mean_queue_wait_cycles,
                pk = s.peak_queue,
            );
        }
        if let Some(f) = &p.faults {
            out.push_str(&f.render());
        }
        out
    }
}
