//! Discrete-event simulation of the sharded pipeline on the
//! coordinator's deterministic [`VirtualClock`].
//!
//! Each stage is a single server fed by a bounded inter-stage FIFO
//! (capacity in frames, from the co-searched [`FifoSpec`]); service time
//! is the stage's transfer-in + compute cycles. The source is
//! closed-loop: it emits a frame the moment stage 0's FIFO has room, so
//! the run measures the pipeline's own capacity — fill, steady-state
//! cadence, backpressure (a stage that finishes while the downstream
//! FIFO is full *blocks*, holding its server, exactly like a stalled AXI
//! writer), and drain.
//!
//! Everything is integer cycles on a [`VirtualClock`]; the report is a
//! pure function of the design and the frame count, byte-reproducible
//! across runs and hosts. Latency percentiles reuse
//! [`crate::util::stats::Summary`] — the same quantile implementation the
//! coordinator's serving metrics use.

use std::collections::VecDeque;

use crate::coordinator::VirtualClock;
use crate::fault::{DowntimeTracker, FaultKind, FaultPlan, PipelineFaultSummary};
use crate::obs::{TraceSink, TrackKind};
use crate::util::stats::Summary;
use crate::Cycles;

use super::cosearch::{co_search_with_ctx, ShardedDesign};

/// Per-stage accounting of one pipeline run.
#[derive(Debug, Clone)]
pub struct StageOccupancy {
    pub stage: usize,
    /// Frames this stage served.
    pub served: u64,
    /// Fraction of the run the stage was computing.
    pub busy_frac: f64,
    /// Fraction of the run the stage was done but blocked on a full
    /// downstream FIFO (backpressure).
    pub blocked_frac: f64,
    /// Mean cycles a frame waited in this stage's input FIFO.
    pub mean_queue_wait_cycles: f64,
    /// Peak occupancy of this stage's input FIFO (frames).
    pub peak_queue: usize,
}

/// Result of one discrete-event pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub shards: usize,
    pub frames: u64,
    pub clock_mhz: u64,
    /// Cycle the first frame completed (pipeline fill).
    pub fill_cycles: Cycles,
    /// Cycle the last frame completed (whole run).
    pub elapsed_cycles: Cycles,
    /// Steady-state throughput: completion rate once the pipeline is
    /// full (first→last completion).
    pub steady_fps: f64,
    /// Whole-run throughput including fill and drain.
    pub overall_fps: f64,
    /// Per-frame emit→complete latency, in seconds.
    pub latency: Summary,
    pub stages: Vec<StageOccupancy>,
    /// Fault-and-recovery accounting — `Some` only for
    /// [`simulate_pipeline_faulty`] runs, so plain-run report JSON is
    /// unchanged.
    pub faults: Option<PipelineFaultSummary>,
}

/// What one stage is doing between events.
struct StageState {
    queue: VecDeque<QueuedFrame>,
    capacity: usize,
    service: Cycles,
    /// `Some((frame, done_cycle))` while serving.
    in_service: Option<(u64, Cycles)>,
    /// `Some((frame, blocked_since))` when done but downstream is full.
    blocked: Option<(u64, Cycles)>,
    busy_cycles: Cycles,
    blocked_cycles: Cycles,
    served: u64,
    queue_wait_cycles: Cycles,
    peak_queue: usize,
}

struct QueuedFrame {
    id: u64,
    enqueued_at: Cycles,
}

/// Run `frames` frames through the sharded pipeline. `fifo_frames`
/// overrides every stage's FIFO capacity (in frames); `None` uses each
/// stage's co-searched [`FifoSpec::frames`].
pub fn simulate_pipeline(
    design: &ShardedDesign,
    frames: u64,
    fifo_frames: Option<u64>,
) -> PipelineReport {
    simulate_pipeline_traced(design, frames, fifo_frames, None)
}

/// [`simulate_pipeline`] with an optional [`TraceSink`]: records frame
/// emit/complete instants on a `source` track, per-stage service spans,
/// and a `backpressure` span for every interval a stage held a finished
/// frame against a full downstream FIFO. The loop is single-threaded on
/// the virtual clock, so traces are byte-identical across runs.
pub fn simulate_pipeline_traced(
    design: &ShardedDesign,
    frames: u64,
    fifo_frames: Option<u64>,
    mut sink: Option<&mut TraceSink>,
) -> PipelineReport {
    assert!(frames > 0, "simulate at least one frame");
    let clock = VirtualClock::new(design.device.clock_mhz);
    let n = design.shards();
    let (src_track, stage_tracks) = match sink.as_deref_mut() {
        Some(s) => (
            Some(s.track(TrackKind::Stream, "source")),
            (0..n)
                .map(|i| Some(s.track(TrackKind::Stage, &format!("stage{i}"))))
                .collect::<Vec<_>>(),
        ),
        None => (None, vec![None; n]),
    };
    let mut stages: Vec<StageState> = design
        .stages
        .iter()
        .map(|s| StageState {
            queue: VecDeque::new(),
            capacity: fifo_frames.unwrap_or(s.fifo.frames).max(1) as usize,
            service: s.service_cycles().max(1),
            in_service: None,
            blocked: None,
            busy_cycles: 0,
            blocked_cycles: 0,
            served: 0,
            queue_wait_cycles: 0,
            peak_queue: 0,
        })
        .collect();

    let mut emitted = 0u64;
    let mut emit_cycle = vec![0 as Cycles; frames as usize];
    let mut latencies_s: Vec<f64> = Vec::with_capacity(frames as usize);
    let mut first_done: Option<Cycles> = None;
    let mut last_done: Cycles = 0;
    let mut completed = 0u64;

    // Settle at the current cycle: drain blocked stages downstream-first,
    // start idle servers, admit source frames — until quiescent. Fixed
    // order keeps the event system deterministic.
    let settle = |stages: &mut Vec<StageState>,
                  emitted: &mut u64,
                  emit_cycle: &mut Vec<Cycles>,
                  now: Cycles,
                  mut sink: Option<&mut TraceSink>| {
        loop {
            let mut progressed = false;
            for i in (0..n).rev() {
                // Unblock: hand the finished frame to the downstream FIFO.
                if let Some((frame, since)) = stages[i].blocked {
                    debug_assert!(i + 1 < n, "last stage never blocks");
                    if stages[i + 1].queue.len() < stages[i + 1].capacity {
                        stages[i + 1].queue.push_back(QueuedFrame {
                            id: frame,
                            enqueued_at: now,
                        });
                        let occ = stages[i + 1].queue.len();
                        stages[i + 1].peak_queue = stages[i + 1].peak_queue.max(occ);
                        stages[i].blocked = None;
                        stages[i].blocked_cycles += now - since;
                        if let Some(s) = sink.as_deref_mut() {
                            if now > since {
                                s.span(
                                    stage_tracks[i].expect("tracks registered"),
                                    "backpressure",
                                    since,
                                    now - since,
                                    vec![("frame", frame.into())],
                                );
                            }
                        }
                        progressed = true;
                    }
                }
                // Start service on the next queued frame.
                if stages[i].in_service.is_none() && stages[i].blocked.is_none() {
                    if let Some(qf) = stages[i].queue.pop_front() {
                        stages[i].queue_wait_cycles += now - qf.enqueued_at;
                        stages[i].in_service = Some((qf.id, now + stages[i].service));
                        stages[i].busy_cycles += stages[i].service;
                        progressed = true;
                    }
                }
            }
            // Closed-loop source: emit while stage 0 has room.
            while *emitted < frames && stages[0].queue.len() < stages[0].capacity {
                stages[0].queue.push_back(QueuedFrame {
                    id: *emitted,
                    enqueued_at: now,
                });
                let occ = stages[0].queue.len();
                stages[0].peak_queue = stages[0].peak_queue.max(occ);
                emit_cycle[*emitted as usize] = now;
                if let Some(s) = sink.as_deref_mut() {
                    s.instant(
                        src_track.expect("tracks registered"),
                        "emit",
                        now,
                        vec![("frame", (*emitted).into())],
                    );
                }
                *emitted += 1;
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
    };

    settle(&mut stages, &mut emitted, &mut emit_cycle, 0, sink.as_deref_mut());
    while completed < frames {
        // Next event: the earliest in-flight completion.
        let now = stages
            .iter()
            .filter_map(|s| s.in_service.map(|(_, done)| done))
            .min()
            .expect("pipeline stalled with frames outstanding");
        clock.advance_to(now);
        for i in 0..n {
            if let Some((frame, done)) = stages[i].in_service {
                if done == now {
                    stages[i].in_service = None;
                    stages[i].served += 1;
                    if let Some(s) = sink.as_deref_mut() {
                        // Plain-path service time is exactly the stage's
                        // service cycles, so the span start is recoverable
                        // at completion.
                        s.span(
                            stage_tracks[i].expect("tracks registered"),
                            "service",
                            now - stages[i].service,
                            stages[i].service,
                            vec![("frame", frame.into())],
                        );
                    }
                    if i + 1 == n {
                        let lat = now - emit_cycle[frame as usize];
                        latencies_s.push(clock.cycles_to_seconds(lat));
                        first_done.get_or_insert(now);
                        last_done = now;
                        completed += 1;
                        if let Some(s) = sink.as_deref_mut() {
                            s.instant(
                                src_track.expect("tracks registered"),
                                "complete",
                                now,
                                vec![("frame", frame.into()), ("latency_cycles", lat.into())],
                            );
                        }
                    } else {
                        // Hand off (or block) — settled below.
                        stages[i].blocked = Some((frame, now));
                    }
                }
            }
        }
        settle(&mut stages, &mut emitted, &mut emit_cycle, now, sink.as_deref_mut());
    }

    let elapsed = last_done.max(1);
    let fill = first_done.unwrap_or(elapsed);
    let steady_fps = if completed > 1 && last_done > fill {
        (completed - 1) as f64 / clock.cycles_to_seconds(last_done - fill)
    } else {
        design.device.fps(elapsed)
    };
    let occupancy = stages
        .iter()
        .enumerate()
        .map(|(i, s)| StageOccupancy {
            stage: i,
            served: s.served,
            busy_frac: s.busy_cycles as f64 / elapsed as f64,
            blocked_frac: s.blocked_cycles as f64 / elapsed as f64,
            mean_queue_wait_cycles: s.queue_wait_cycles as f64 / s.served.max(1) as f64,
            peak_queue: s.peak_queue,
        })
        .collect();
    PipelineReport {
        shards: n,
        frames,
        clock_mhz: design.device.clock_mhz,
        fill_cycles: fill,
        elapsed_cycles: elapsed,
        steady_fps,
        overall_fps: completed as f64 / clock.cycles_to_seconds(elapsed),
        latency: Summary::from(&latencies_s),
        stages: occupancy,
        faults: None,
    }
}

impl ShardedDesign {
    /// Run the discrete-event pipeline simulation for `frames` frames
    /// with the co-searched FIFO depths.
    pub fn simulate_pipeline(&self, frames: u64) -> PipelineReport {
        simulate_pipeline(self, frames, None)
    }

    /// [`ShardedDesign::simulate_pipeline`] with tracing: returns the
    /// report plus the frozen [`crate::obs::Trace`] (stage service +
    /// backpressure spans, source emit/complete instants).
    pub fn simulate_pipeline_with_trace(
        &self,
        frames: u64,
        cfg: crate::obs::TraceConfig,
    ) -> (PipelineReport, crate::obs::Trace) {
        let mut sink = TraceSink::with_config(self.device.clock_mhz, cfg);
        let report = simulate_pipeline_traced(self, frames, None, Some(&mut sink));
        (report, sink.finish())
    }
}

// ---------------------------------------------------------------------------
// Fault-injected pipeline.
// ---------------------------------------------------------------------------

/// How the pipeline reacts when a board crashes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailoverStrategy {
    /// Hot-swap the crashed slot from the spare-board inventory
    /// (`RecoveryConfig::spares`); falls back to re-partitioning when
    /// the inventory is empty.
    Spare,
    /// Re-run the min-max partition DP over the surviving boards and
    /// replay the in-pipeline frames through the new stage 0.
    Repartition,
}

impl FailoverStrategy {
    pub fn as_str(&self) -> &'static str {
        match self {
            FailoverStrategy::Spare => "spare",
            FailoverStrategy::Repartition => "repartition",
        }
    }

    /// CLI lookup (`spare` / `repartition`).
    pub fn parse(s: &str) -> Option<FailoverStrategy> {
        match s {
            "spare" => Some(FailoverStrategy::Spare),
            "repartition" | "repart" => Some(FailoverStrategy::Repartition),
            _ => None,
        }
    }
}

/// Drain blocked stages downstream-first, start idle non-down servers,
/// admit replayed then fresh frames — until quiescent (the faulty-path
/// twin of the base `settle` closure; identical order, so a plan with no
/// events replays the base schedule).
#[allow(clippy::too_many_arguments)]
fn settle_faulty(
    stages: &mut [StageState],
    slot_of_stage: &[usize],
    down_of_slot: &[Option<Cycles>],
    slow_of_slot: &[f64],
    backlog: &mut VecDeque<u64>,
    emitted: &mut u64,
    frames: u64,
    emit_cycle: &mut [Cycles],
    now: Cycles,
) {
    let n = stages.len();
    loop {
        let mut progressed = false;
        for i in (0..n).rev() {
            if let Some((frame, since)) = stages[i].blocked {
                if i + 1 < n && stages[i + 1].queue.len() < stages[i + 1].capacity {
                    stages[i + 1].queue.push_back(QueuedFrame {
                        id: frame,
                        enqueued_at: now,
                    });
                    let occ = stages[i + 1].queue.len();
                    stages[i + 1].peak_queue = stages[i + 1].peak_queue.max(occ);
                    stages[i].blocked = None;
                    stages[i].blocked_cycles += now - since;
                    progressed = true;
                }
            }
            let up = down_of_slot[slot_of_stage[i]].is_none();
            if up && stages[i].in_service.is_none() && stages[i].blocked.is_none() {
                if let Some(qf) = stages[i].queue.pop_front() {
                    stages[i].queue_wait_cycles += now - qf.enqueued_at;
                    let slow = slow_of_slot[slot_of_stage[i]];
                    let dur = ((stages[i].service as f64) * slow).ceil().max(1.0) as Cycles;
                    stages[i].in_service = Some((qf.id, now + dur));
                    stages[i].busy_cycles += dur;
                    progressed = true;
                }
            }
        }
        // Source: replayed frames first (oldest work), then fresh ones.
        while stages[0].queue.len() < stages[0].capacity {
            if let Some(id) = backlog.pop_front() {
                stages[0].queue.push_back(QueuedFrame {
                    id,
                    enqueued_at: now,
                });
            } else if *emitted < frames {
                stages[0].queue.push_back(QueuedFrame {
                    id: *emitted,
                    enqueued_at: now,
                });
                emit_cycle[*emitted as usize] = now;
                *emitted += 1;
            } else {
                break;
            }
            let occ = stages[0].queue.len();
            stages[0].peak_queue = stages[0].peak_queue.max(occ);
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
}

/// [`simulate_pipeline`] with a [`FaultPlan`] injected on the same
/// virtual clock. Crashed boards lose their in-flight frame (re-run);
/// the pipeline either hot-swaps the slot from the spare inventory
/// ([`FailoverStrategy::Spare`]: down for `swap_s` plus re-streaming the
/// input FIFO) or re-partitions the survivors with the co-search DP
/// ([`FailoverStrategy::Repartition`]: every in-pipeline frame replays
/// through the new stage 0 after a `reconfig_s` pause, original emit
/// times kept). Slow-downs multiply a board's service time; corruptions
/// discard the board's next completion and re-run the frame.
///
/// Deterministic tie-break at one cycle: completions, then board
/// restorations, then injected events. Occupancy covers the *final*
/// configuration (a re-partition resets per-stage counters).
///
/// Errors when the last board crashes with an empty spare inventory, or
/// when the surviving-board re-partition itself fails.
pub fn simulate_pipeline_faulty(
    design: &ShardedDesign,
    frames: u64,
    fifo_frames: Option<u64>,
    plan: &FaultPlan,
    strategy: FailoverStrategy,
) -> anyhow::Result<PipelineReport> {
    simulate_pipeline_faulty_traced(design, frames, fifo_frames, plan, strategy, None)
}

/// [`simulate_pipeline_faulty`] with an optional [`TraceSink`]. The
/// faulty path traces the *control plane* — fault injections, hot-swaps,
/// re-partitions, slot restorations, corrupted-frame re-runs, frame
/// completions — rather than per-stage spans, because a re-partition
/// moves stage boundaries mid-run and would orphan the stage tracks.
pub fn simulate_pipeline_faulty_traced(
    design: &ShardedDesign,
    frames: u64,
    fifo_frames: Option<u64>,
    plan: &FaultPlan,
    strategy: FailoverStrategy,
    mut sink: Option<&mut TraceSink>,
) -> anyhow::Result<PipelineReport> {
    anyhow::ensure!(frames > 0, "simulate at least one frame");
    let clock = VirtualClock::new(design.device.clock_mhz);
    let recovery = plan.recovery;
    let n0 = design.shards();
    let (src_track, ctrl_track) = match sink.as_deref_mut() {
        Some(s) => (
            Some(s.track(TrackKind::Stream, "source")),
            Some(s.track(TrackKind::Control, "faults")),
        ),
        None => (None, None),
    };

    let make_stages = |d: &ShardedDesign| -> Vec<StageState> {
        d.stages
            .iter()
            .map(|s| StageState {
                queue: VecDeque::new(),
                capacity: fifo_frames.unwrap_or(s.fifo.frames).max(1) as usize,
                service: s.service_cycles().max(1),
                in_service: None,
                blocked: None,
                busy_cycles: 0,
                blocked_cycles: 0,
                served: 0,
                queue_wait_cycles: 0,
                peak_queue: 0,
            })
            .collect()
    };

    let mut cur = design.clone();
    let mut stages = make_stages(&cur);
    // Board-slot ids of the current stages; plan events address slots.
    let mut slot_of_stage: Vec<usize> = (0..n0).collect();
    let mut down_of_slot: Vec<Option<Cycles>> = vec![None; n0];
    let mut slow_of_slot: Vec<f64> = vec![1.0; n0];
    let mut corrupt_slot: Vec<bool> = vec![false; n0];
    let mut spares = recovery.spares;
    let mut tracker = DowntimeTracker::new(n0);
    let mut summary = PipelineFaultSummary {
        strategy: strategy.as_str().to_string(),
        ..PipelineFaultSummary::default()
    };

    let fevents: Vec<(Cycles, crate::fault::FaultEvent)> = plan
        .sorted_events()
        .into_iter()
        .map(|e| (clock.seconds_to_cycles(e.at_s), e))
        .collect();
    let mut fidx = 0usize;

    let mut emitted = 0u64;
    let mut emit_cycle = vec![0 as Cycles; frames as usize];
    let mut backlog: VecDeque<u64> = VecDeque::new();
    let mut latencies_s: Vec<f64> = Vec::with_capacity(frames as usize);
    let mut first_done: Option<Cycles> = None;
    let mut last_done: Cycles = 0;
    let mut completed = 0u64;

    settle_faulty(
        &mut stages, &slot_of_stage, &down_of_slot, &slow_of_slot, &mut backlog,
        &mut emitted, frames, &mut emit_cycle, 0,
    );
    while completed < frames {
        // Next event: earliest completion, board restoration, or injection.
        let mut next: Option<Cycles> = stages
            .iter()
            .filter_map(|s| s.in_service.map(|(_, done)| done))
            .min();
        for t in down_of_slot.iter().flatten() {
            next = Some(next.map_or(*t, |c| c.min(*t)));
        }
        if fidx < fevents.len() {
            let t = fevents[fidx].0;
            next = Some(next.map_or(t, |c| c.min(t)));
        }
        let now = match next {
            Some(t) => t,
            None => anyhow::bail!(
                "pipeline stalled with {} frames outstanding: every path down \
                 and no recovery scheduled",
                frames - completed
            ),
        };
        clock.advance_to(now);

        // 1. Completions (a same-cycle crash arrives after them).
        let n = stages.len();
        for i in 0..n {
            if let Some((frame, done)) = stages[i].in_service {
                if done == now {
                    stages[i].in_service = None;
                    let slot = slot_of_stage[i];
                    if corrupt_slot[slot] {
                        // Discard the corrupted result; the frame re-runs
                        // on this stage.
                        corrupt_slot[slot] = false;
                        summary.rerun_frames += 1;
                        if let Some(s) = sink.as_deref_mut() {
                            s.instant(
                                ctrl_track.expect("tracks registered"),
                                "rerun",
                                now,
                                vec![("frame", frame.into()), ("slot", slot.into())],
                            );
                        }
                        stages[i].queue.push_front(QueuedFrame {
                            id: frame,
                            enqueued_at: now,
                        });
                        continue;
                    }
                    stages[i].served += 1;
                    if i + 1 == n {
                        let lat = now - emit_cycle[frame as usize];
                        latencies_s.push(clock.cycles_to_seconds(lat));
                        first_done.get_or_insert(now);
                        last_done = now;
                        completed += 1;
                        if let Some(s) = sink.as_deref_mut() {
                            s.instant(
                                src_track.expect("tracks registered"),
                                "complete",
                                now,
                                vec![("frame", frame.into()), ("latency_cycles", lat.into())],
                            );
                        }
                    } else {
                        stages[i].blocked = Some((frame, now));
                    }
                }
            }
        }

        // 2. Board restorations (hot-swap / reconfiguration finished).
        for slot in 0..down_of_slot.len() {
            if matches!(down_of_slot[slot], Some(t) if t <= now) {
                down_of_slot[slot] = None;
                tracker.mark_up(slot, clock.now());
                if let Some(s) = sink.as_deref_mut() {
                    s.instant(
                        ctrl_track.expect("tracks registered"),
                        "slot_up",
                        now,
                        vec![("slot", slot.into())],
                    );
                }
            }
        }

        // 3. Injected events due at this cycle.
        while fidx < fevents.len() && fevents[fidx].0 <= now {
            let ev = fevents[fidx].1.clone();
            fidx += 1;
            if ev.unit >= n0 {
                continue; // plan written for a larger fleet
            }
            if let Some(s) = sink.as_deref_mut() {
                let name = match ev.kind {
                    FaultKind::Crash => "fault_crash",
                    FaultKind::Recover => "fault_recover",
                    FaultKind::SlowDown { .. } => "fault_slowdown",
                    FaultKind::SlowEnd => "fault_slow_end",
                    FaultKind::Corrupt => "fault_corrupt",
                };
                s.instant(
                    ctrl_track.expect("tracks registered"),
                    name,
                    now,
                    vec![("slot", ev.unit.into())],
                );
            }
            match ev.kind {
                FaultKind::Crash => {
                    let Some(si) = slot_of_stage.iter().position(|&s| s == ev.unit) else {
                        continue; // board already removed by a re-partition
                    };
                    if down_of_slot[ev.unit].is_some() {
                        continue; // already mid-swap
                    }
                    summary.injected_crashes += 1;
                    tracker.mark_down(ev.unit, clock.now());
                    let use_spare = strategy == FailoverStrategy::Spare && spares > 0;
                    if use_spare {
                        // In-flight work on the crashed board is lost and
                        // re-runs on the replacement.
                        if let Some((f, _)) = stages[si].in_service.take() {
                            summary.rerun_frames += 1;
                            stages[si].queue.push_front(QueuedFrame {
                                id: f,
                                enqueued_at: now,
                            });
                        }
                        if let Some((f, since)) = stages[si].blocked.take() {
                            stages[si].blocked_cycles += now - since;
                            summary.rerun_frames += 1;
                            stages[si].queue.push_front(QueuedFrame {
                                id: f,
                                enqueued_at: now,
                            });
                        }
                        spares -= 1;
                        summary.hot_swaps += 1;
                        // Bring-up plus re-streaming the input FIFO into
                        // the replacement board.
                        let refill = cur.stages[si].fifo.transfer_cycles
                            * stages[si].queue.len() as u64;
                        let cost = clock.seconds_to_cycles(recovery.swap_s).max(1) + refill;
                        down_of_slot[ev.unit] = Some(now + cost);
                        if let Some(s) = sink.as_deref_mut() {
                            s.instant(
                                ctrl_track.expect("tracks registered"),
                                "hot_swap",
                                now,
                                vec![("slot", ev.unit.into()), ("cost_cycles", cost.into())],
                            );
                        }
                    } else {
                        let survivors = stages.len() - 1;
                        anyhow::ensure!(
                            survivors >= 1,
                            "pipeline lost its last board at t={:.6}s with no spare",
                            ev.at_s
                        );
                        summary.repartitions += 1;
                        // Pull every in-pipeline frame back for replay
                        // (stage boundaries are about to move).
                        let mut ids: Vec<u64> = backlog.drain(..).collect();
                        for (j, st) in stages.iter_mut().enumerate() {
                            if let Some((f, _)) = st.in_service.take() {
                                summary.rerun_frames += 1;
                                ids.push(f);
                            }
                            if let Some((f, since)) = st.blocked.take() {
                                st.blocked_cycles += now - since;
                                summary.rerun_frames += 1;
                                ids.push(f);
                            }
                            for qf in st.queue.drain(..) {
                                if j > 0 {
                                    summary.rerun_frames += 1;
                                }
                                ids.push(qf.id);
                            }
                        }
                        ids.sort_unstable();
                        backlog = ids.into();
                        slot_of_stage.remove(si);
                        // Re-search through the design's own context: the
                        // surviving layer slices are warm memo hits, so
                        // the live repartition costs only the genuinely
                        // new stage shapes.
                        cur = co_search_with_ctx(
                            &cur.model,
                            &cur.device,
                            cur.act_bits,
                            &cur.reference,
                            survivors,
                            cur.policy,
                            cur.ctx.clone(),
                        )?;
                        stages = make_stages(&cur);
                        // Reconfiguration drains and refills the whole
                        // chain: every survivor pauses.
                        let resume = now + clock.seconds_to_cycles(recovery.reconfig_s).max(1);
                        for &slot in &slot_of_stage {
                            tracker.mark_down(slot, clock.now());
                            down_of_slot[slot] = Some(resume);
                        }
                        if let Some(s) = sink.as_deref_mut() {
                            s.instant(
                                ctrl_track.expect("tracks registered"),
                                "repartition",
                                now,
                                vec![
                                    ("lost_slot", ev.unit.into()),
                                    ("stages", survivors.into()),
                                    ("replayed", backlog.len().into()),
                                ],
                            );
                        }
                    }
                }
                FaultKind::Recover => {
                    if strategy == FailoverStrategy::Spare {
                        // The repaired board rejoins the spare inventory.
                        spares += 1;
                    }
                }
                FaultKind::SlowDown { factor } => {
                    summary.injected_slowdowns += 1;
                    slow_of_slot[ev.unit] = factor.max(1.0);
                }
                FaultKind::SlowEnd => {
                    slow_of_slot[ev.unit] = 1.0;
                }
                FaultKind::Corrupt => {
                    summary.injected_corruptions += 1;
                    corrupt_slot[ev.unit] = true;
                }
            }
        }

        settle_faulty(
            &mut stages, &slot_of_stage, &down_of_slot, &slow_of_slot, &mut backlog,
            &mut emitted, frames, &mut emit_cycle, now,
        );
    }

    let elapsed = last_done.max(1);
    let fill = first_done.unwrap_or(elapsed);
    let steady_fps = if completed > 1 && last_done > fill {
        (completed - 1) as f64 / clock.cycles_to_seconds(last_done - fill)
    } else {
        cur.device.fps(elapsed)
    };
    let occupancy = stages
        .iter()
        .enumerate()
        .map(|(i, s)| StageOccupancy {
            stage: i,
            served: s.served,
            busy_frac: s.busy_cycles as f64 / elapsed as f64,
            blocked_frac: s.blocked_cycles as f64 / elapsed as f64,
            mean_queue_wait_cycles: s.queue_wait_cycles as f64 / s.served.max(1) as f64,
            peak_queue: s.peak_queue,
        })
        .collect();
    let elapsed_s = clock.cycles_to_seconds(elapsed);
    tracker.finish(elapsed_s);
    summary.availability = tracker.availability(elapsed_s);
    summary.mttr_s = tracker.mttr_s();
    summary.final_stages = stages.len();
    summary.spares_remaining = spares;
    Ok(PipelineReport {
        shards: n0,
        frames,
        clock_mhz: design.device.clock_mhz,
        fill_cycles: fill,
        elapsed_cycles: elapsed,
        steady_fps,
        overall_fps: completed as f64 / elapsed_s,
        latency: Summary::from(&latencies_s),
        stages: occupancy,
        faults: Some(summary),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{optimize_baseline, optimize_for_bits};
    use crate::hw::zcu102;
    use crate::model::micro;
    use crate::shard::{co_search, ShardPolicy};

    fn micro_sharded(n: usize) -> ShardedDesign {
        let model = micro();
        let device = zcu102();
        let baseline = optimize_baseline(&model.structure(None), &device);
        let reference =
            optimize_for_bits(&model.structure(Some(8)), &baseline, &device, 8).unwrap();
        co_search(&model, &device, Some(8), &reference, n, ShardPolicy::Balanced).unwrap()
    }

    #[test]
    fn steady_rate_matches_bottleneck_bound() {
        let d = micro_sharded(2);
        let r = d.simulate_pipeline(64);
        // The DES cannot beat the analytic bottleneck cadence, and with
        // double-buffered FIFOs it should achieve it exactly.
        let bound = d.steady_state_fps();
        assert!(
            (r.steady_fps - bound).abs() / bound < 1e-6,
            "steady {} vs bound {bound}",
            r.steady_fps
        );
        assert_eq!(r.frames, 64);
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages.iter().map(|s| s.served).min(), Some(64));
    }

    #[test]
    fn pipeline_run_is_deterministic() {
        let d = micro_sharded(2);
        let a = d.simulate_pipeline(32);
        let b = d.simulate_pipeline(32);
        assert_eq!(a.elapsed_cycles, b.elapsed_cycles);
        assert_eq!(a.fill_cycles, b.fill_cycles);
        assert_eq!(a.latency.p99, b.latency.p99);
    }

    #[test]
    fn fill_is_one_pass_through_every_stage() {
        let d = micro_sharded(3);
        let r = d.simulate_pipeline(16);
        assert_eq!(r.fill_cycles, d.fill_cycles());
    }

    #[test]
    fn tiny_fifo_still_completes_and_backpressures() {
        let d = micro_sharded(3);
        let r = simulate_pipeline(&d, 400, Some(1));
        assert_eq!(r.frames as usize, r.latency.n);
        // Steady cadence still equals the bottleneck bound — deterministic
        // services need no buffering beyond 1 to sustain it.
        let bound = d.steady_state_fps();
        assert!((r.steady_fps - bound).abs() / bound < 1e-6);
        // Backpressure: with capacity-1 FIFOs and a closed-loop source,
        // some stage blocks over a long run exactly when stage 0 is not
        // itself the bottleneck (a slow first stage throttles the whole
        // chain instead; queues downstream never fill).
        let first_is_bottleneck =
            d.stages[0].service_cycles() == d.bottleneck_cycles();
        let any_blocked = r.stages.iter().any(|s| s.blocked_frac > 0.0);
        assert_eq!(any_blocked, !first_is_bottleneck);
    }
}
