//! Discrete-event simulation of the sharded pipeline on the
//! coordinator's deterministic [`VirtualClock`].
//!
//! Each stage is a single server fed by a bounded inter-stage FIFO
//! (capacity in frames, from the co-searched [`FifoSpec`]); service time
//! is the stage's transfer-in + compute cycles. The source is
//! closed-loop: it emits a frame the moment stage 0's FIFO has room, so
//! the run measures the pipeline's own capacity — fill, steady-state
//! cadence, backpressure (a stage that finishes while the downstream
//! FIFO is full *blocks*, holding its server, exactly like a stalled AXI
//! writer), and drain.
//!
//! Everything is integer cycles on a [`VirtualClock`]; the report is a
//! pure function of the design and the frame count, byte-reproducible
//! across runs and hosts. Latency percentiles reuse
//! [`crate::util::stats::Summary`] — the same quantile implementation the
//! coordinator's serving metrics use.

use std::collections::VecDeque;

use crate::coordinator::VirtualClock;
use crate::util::stats::Summary;
use crate::Cycles;

use super::cosearch::ShardedDesign;

/// Per-stage accounting of one pipeline run.
#[derive(Debug, Clone)]
pub struct StageOccupancy {
    pub stage: usize,
    /// Frames this stage served.
    pub served: u64,
    /// Fraction of the run the stage was computing.
    pub busy_frac: f64,
    /// Fraction of the run the stage was done but blocked on a full
    /// downstream FIFO (backpressure).
    pub blocked_frac: f64,
    /// Mean cycles a frame waited in this stage's input FIFO.
    pub mean_queue_wait_cycles: f64,
    /// Peak occupancy of this stage's input FIFO (frames).
    pub peak_queue: usize,
}

/// Result of one discrete-event pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub shards: usize,
    pub frames: u64,
    pub clock_mhz: u64,
    /// Cycle the first frame completed (pipeline fill).
    pub fill_cycles: Cycles,
    /// Cycle the last frame completed (whole run).
    pub elapsed_cycles: Cycles,
    /// Steady-state throughput: completion rate once the pipeline is
    /// full (first→last completion).
    pub steady_fps: f64,
    /// Whole-run throughput including fill and drain.
    pub overall_fps: f64,
    /// Per-frame emit→complete latency, in seconds.
    pub latency: Summary,
    pub stages: Vec<StageOccupancy>,
}

/// What one stage is doing between events.
struct StageState {
    queue: VecDeque<QueuedFrame>,
    capacity: usize,
    service: Cycles,
    /// `Some((frame, done_cycle))` while serving.
    in_service: Option<(u64, Cycles)>,
    /// `Some((frame, blocked_since))` when done but downstream is full.
    blocked: Option<(u64, Cycles)>,
    busy_cycles: Cycles,
    blocked_cycles: Cycles,
    served: u64,
    queue_wait_cycles: Cycles,
    peak_queue: usize,
}

struct QueuedFrame {
    id: u64,
    enqueued_at: Cycles,
}

/// Run `frames` frames through the sharded pipeline. `fifo_frames`
/// overrides every stage's FIFO capacity (in frames); `None` uses each
/// stage's co-searched [`FifoSpec::frames`].
pub fn simulate_pipeline(
    design: &ShardedDesign,
    frames: u64,
    fifo_frames: Option<u64>,
) -> PipelineReport {
    assert!(frames > 0, "simulate at least one frame");
    let clock = VirtualClock::new(design.device.clock_mhz);
    let n = design.shards();
    let mut stages: Vec<StageState> = design
        .stages
        .iter()
        .map(|s| StageState {
            queue: VecDeque::new(),
            capacity: fifo_frames.unwrap_or(s.fifo.frames).max(1) as usize,
            service: s.service_cycles().max(1),
            in_service: None,
            blocked: None,
            busy_cycles: 0,
            blocked_cycles: 0,
            served: 0,
            queue_wait_cycles: 0,
            peak_queue: 0,
        })
        .collect();

    let mut emitted = 0u64;
    let mut emit_cycle = vec![0 as Cycles; frames as usize];
    let mut latencies_s: Vec<f64> = Vec::with_capacity(frames as usize);
    let mut first_done: Option<Cycles> = None;
    let mut last_done: Cycles = 0;
    let mut completed = 0u64;

    // Settle at the current cycle: drain blocked stages downstream-first,
    // start idle servers, admit source frames — until quiescent. Fixed
    // order keeps the event system deterministic.
    let settle = |stages: &mut Vec<StageState>,
                  emitted: &mut u64,
                  emit_cycle: &mut Vec<Cycles>,
                  now: Cycles| {
        loop {
            let mut progressed = false;
            for i in (0..n).rev() {
                // Unblock: hand the finished frame to the downstream FIFO.
                if let Some((frame, since)) = stages[i].blocked {
                    debug_assert!(i + 1 < n, "last stage never blocks");
                    if stages[i + 1].queue.len() < stages[i + 1].capacity {
                        stages[i + 1].queue.push_back(QueuedFrame {
                            id: frame,
                            enqueued_at: now,
                        });
                        let occ = stages[i + 1].queue.len();
                        stages[i + 1].peak_queue = stages[i + 1].peak_queue.max(occ);
                        stages[i].blocked = None;
                        stages[i].blocked_cycles += now - since;
                        progressed = true;
                    }
                }
                // Start service on the next queued frame.
                if stages[i].in_service.is_none() && stages[i].blocked.is_none() {
                    if let Some(qf) = stages[i].queue.pop_front() {
                        stages[i].queue_wait_cycles += now - qf.enqueued_at;
                        stages[i].in_service = Some((qf.id, now + stages[i].service));
                        stages[i].busy_cycles += stages[i].service;
                        progressed = true;
                    }
                }
            }
            // Closed-loop source: emit while stage 0 has room.
            while *emitted < frames && stages[0].queue.len() < stages[0].capacity {
                stages[0].queue.push_back(QueuedFrame {
                    id: *emitted,
                    enqueued_at: now,
                });
                let occ = stages[0].queue.len();
                stages[0].peak_queue = stages[0].peak_queue.max(occ);
                emit_cycle[*emitted as usize] = now;
                *emitted += 1;
                progressed = true;
            }
            if !progressed {
                break;
            }
        }
    };

    settle(&mut stages, &mut emitted, &mut emit_cycle, 0);
    while completed < frames {
        // Next event: the earliest in-flight completion.
        let now = stages
            .iter()
            .filter_map(|s| s.in_service.map(|(_, done)| done))
            .min()
            .expect("pipeline stalled with frames outstanding");
        clock.advance_to(now);
        for i in 0..n {
            if let Some((frame, done)) = stages[i].in_service {
                if done == now {
                    stages[i].in_service = None;
                    stages[i].served += 1;
                    if i + 1 == n {
                        let lat = now - emit_cycle[frame as usize];
                        latencies_s.push(clock.cycles_to_seconds(lat));
                        first_done.get_or_insert(now);
                        last_done = now;
                        completed += 1;
                    } else {
                        // Hand off (or block) — settled below.
                        stages[i].blocked = Some((frame, now));
                    }
                }
            }
        }
        settle(&mut stages, &mut emitted, &mut emit_cycle, now);
    }

    let elapsed = last_done.max(1);
    let fill = first_done.unwrap_or(elapsed);
    let steady_fps = if completed > 1 && last_done > fill {
        (completed - 1) as f64 / clock.cycles_to_seconds(last_done - fill)
    } else {
        design.device.fps(elapsed)
    };
    let occupancy = stages
        .iter()
        .enumerate()
        .map(|(i, s)| StageOccupancy {
            stage: i,
            served: s.served,
            busy_frac: s.busy_cycles as f64 / elapsed as f64,
            blocked_frac: s.blocked_cycles as f64 / elapsed as f64,
            mean_queue_wait_cycles: s.queue_wait_cycles as f64 / s.served.max(1) as f64,
            peak_queue: s.peak_queue,
        })
        .collect();
    PipelineReport {
        shards: n,
        frames,
        clock_mhz: design.device.clock_mhz,
        fill_cycles: fill,
        elapsed_cycles: elapsed,
        steady_fps,
        overall_fps: completed as f64 / clock.cycles_to_seconds(elapsed),
        latency: Summary::from(&latencies_s),
        stages: occupancy,
    }
}

impl ShardedDesign {
    /// Run the discrete-event pipeline simulation for `frames` frames
    /// with the co-searched FIFO depths.
    pub fn simulate_pipeline(&self, frames: u64) -> PipelineReport {
        simulate_pipeline(self, frames, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{optimize_baseline, optimize_for_bits};
    use crate::hw::zcu102;
    use crate::model::micro;
    use crate::shard::{co_search, ShardPolicy};

    fn micro_sharded(n: usize) -> ShardedDesign {
        let model = micro();
        let device = zcu102();
        let baseline = optimize_baseline(&model.structure(None), &device);
        let reference =
            optimize_for_bits(&model.structure(Some(8)), &baseline, &device, 8).unwrap();
        co_search(&model, &device, Some(8), &reference, n, ShardPolicy::Balanced).unwrap()
    }

    #[test]
    fn steady_rate_matches_bottleneck_bound() {
        let d = micro_sharded(2);
        let r = d.simulate_pipeline(64);
        // The DES cannot beat the analytic bottleneck cadence, and with
        // double-buffered FIFOs it should achieve it exactly.
        let bound = d.steady_state_fps();
        assert!(
            (r.steady_fps - bound).abs() / bound < 1e-6,
            "steady {} vs bound {bound}",
            r.steady_fps
        );
        assert_eq!(r.frames, 64);
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.stages.iter().map(|s| s.served).min(), Some(64));
    }

    #[test]
    fn pipeline_run_is_deterministic() {
        let d = micro_sharded(2);
        let a = d.simulate_pipeline(32);
        let b = d.simulate_pipeline(32);
        assert_eq!(a.elapsed_cycles, b.elapsed_cycles);
        assert_eq!(a.fill_cycles, b.fill_cycles);
        assert_eq!(a.latency.p99, b.latency.p99);
    }

    #[test]
    fn fill_is_one_pass_through_every_stage() {
        let d = micro_sharded(3);
        let r = d.simulate_pipeline(16);
        assert_eq!(r.fill_cycles, d.fill_cycles());
    }

    #[test]
    fn tiny_fifo_still_completes_and_backpressures() {
        let d = micro_sharded(3);
        let r = simulate_pipeline(&d, 400, Some(1));
        assert_eq!(r.frames as usize, r.latency.n);
        // Steady cadence still equals the bottleneck bound — deterministic
        // services need no buffering beyond 1 to sustain it.
        let bound = d.steady_state_fps();
        assert!((r.steady_fps - bound).abs() / bound < 1e-6);
        // Backpressure: with capacity-1 FIFOs and a closed-loop source,
        // some stage blocks over a long run exactly when stage 0 is not
        // itself the bottleneck (a slow first stage throttles the whole
        // chain instead; queues downstream never fill).
        let first_is_bottleneck =
            d.stages[0].service_cycles() == d.bottleneck_cycles();
        let any_blocked = r.stages.iter().any(|s| s.blocked_frac > 0.0);
        assert_eq!(any_blocked, !first_is_bottleneck);
    }
}
