//! Fleet run reports: per-unit utilization, per-stream and aggregate
//! latency/drop/SLA accounting, and fleet-level fault bookkeeping.
//!
//! The stream and aggregate blocks reuse the coordinator's
//! [`StreamReport`]/[`AggregateReport`] types (all latency blocks render
//! through `Summary::to_ms_json`), so fleet JSON aggregates the same
//! metrics shape as the serving scheduler and the shard pipeline.

use crate::coordinator::{AggregateReport, StreamReport};
use crate::util::json::Json;

/// One serving unit's slice of a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct UnitReport {
    pub unit: usize,
    /// `replica` or `pipeline:<depth>`.
    pub label: String,
    pub boards: usize,
    /// Frames this unit completed.
    pub served: u64,
    /// Cumulative busy seconds summed over the unit's boards.
    pub busy_seconds: f64,
    /// Per-board busy fraction of the run
    /// (`busy_seconds / (boards · elapsed)`, 0..=1).
    pub utilization: f64,
}

impl UnitReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("unit", self.unit)
            .set("label", self.label.as_str())
            .set("boards", self.boards)
            .set("served", self.served)
            .set("busy_seconds", self.busy_seconds)
            .set("utilization", self.utilization)
    }
}

/// Fleet-level fault-and-failover accounting — `Some` on a
/// [`FleetReport`] only when a fault plan was attached.
#[derive(Debug, Clone, Default)]
pub struct FleetFaultSummary {
    pub injected_crashes: u64,
    pub injected_slowdowns: u64,
    pub injected_corruptions: u64,
    /// Crashed units restored from the spare inventory after `swap_s`.
    pub hot_swaps: u64,
    /// Frames pulled out of a crashed unit and routed back through the
    /// balancer.
    pub redispatches: u64,
    /// Retry attempts scheduled (≤ `max_retries` per frame).
    pub retries: u64,
    /// Corrupted completions re-executed by their unit.
    pub rerun_frames: u64,
    pub spares_remaining: usize,
    /// Mean fraction of the run each unit was serving (1.0 = no downtime).
    pub availability: f64,
    /// Mean time-to-restore across crash episodes (seconds).
    pub mttr_s: f64,
}

impl FleetFaultSummary {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("injected_crashes", self.injected_crashes)
            .set("injected_slowdowns", self.injected_slowdowns)
            .set("injected_corruptions", self.injected_corruptions)
            .set("hot_swaps", self.hot_swaps)
            .set("redispatches", self.redispatches)
            .set("retries", self.retries)
            .set("rerun_frames", self.rerun_frames)
            .set("spares_remaining", self.spares_remaining)
            .set("availability", self.availability)
            .set("mttr_ms", self.mttr_s * 1e3)
    }

    pub fn render(&self) -> String {
        format!(
            "  faults: {c} crashes ({h} hot-swapped, {sp} spares left), \
             {s} slowdowns, {co} corruptions → {r} retries, {rd} redispatches, \
             {rr} reruns; availability {a:.4}, MTTR {m:.2} ms\n",
            c = self.injected_crashes,
            h = self.hot_swaps,
            sp = self.spares_remaining,
            s = self.injected_slowdowns,
            co = self.injected_corruptions,
            r = self.retries,
            rd = self.redispatches,
            rr = self.rerun_frames,
            a = self.availability,
            m = self.mttr_s * 1e3,
        )
    }
}

/// Final report of a fleet run. Under the virtual clock every field is a
/// pure function of (design, topology, balancer, trace, fault plan) —
/// `to_json().pretty()` is byte-identical across runs.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub backend: String,
    /// Topology label, e.g. `replicated(4)` or `2×replica+pipeline:2`.
    pub topology: String,
    pub balancer: String,
    /// Always `"virtual"` — the fleet simulator has no wall-clock mode.
    pub clock: String,
    /// Trace kind tag (`poisson`, `flash-crowd`, …).
    pub trace: String,
    pub boards: usize,
    /// Run length in simulated clock seconds.
    pub elapsed_seconds: f64,
    pub aggregate: AggregateReport,
    pub streams: Vec<StreamReport>,
    pub units: Vec<UnitReport>,
    pub faults: Option<FleetFaultSummary>,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("backend", self.backend.as_str())
            .set("topology", self.topology.as_str())
            .set("balancer", self.balancer.as_str())
            .set("clock", self.clock.as_str())
            .set("trace", self.trace.as_str())
            .set("boards", self.boards)
            .set("elapsed_seconds", self.elapsed_seconds)
            .set("aggregate", self.aggregate.to_json())
            .set(
                "streams",
                Json::Arr(self.streams.iter().map(StreamReport::to_json).collect()),
            )
            .set(
                "units",
                Json::Arr(self.units.iter().map(UnitReport::to_json).collect()),
            );
        if let Some(f) = &self.faults {
            j = j.set("faults", f.to_json());
        }
        j
    }

    pub fn render(&self) -> String {
        let a = &self.aggregate;
        let mut out = format!(
            "fleet {t}  ({b} boards, {u} units, {p} balancer, {tr} trace, {be})\n  \
             aggregate: offered {o} → completed {cmp}, dropped {d} ({dr:.1}%), \
             failed {f}, {fps:.1} FPS achieved, {v} SLA violations\n  \
             e2e latency  p50 {p50:.2} ms  p95 {p95:.2} ms  p99 {p99:.2} ms\n",
            t = self.topology,
            b = self.boards,
            u = self.units.len(),
            p = self.balancer,
            tr = self.trace,
            be = self.backend,
            o = a.offered,
            cmp = a.completed,
            d = a.dropped,
            dr = 100.0 * a.drop_rate,
            f = a.failed,
            fps = a.achieved_fps,
            v = a.sla_violations,
            p50 = a.e2e_latency.p50 * 1e3,
            p95 = a.e2e_latency.p95 * 1e3,
            p99 = a.e2e_latency.p99 * 1e3,
        );
        for u in &self.units {
            out.push_str(&format!(
                "  unit {i} ({l}, {bd} board{s}): served {n} frames, {ut:.0}% busy/board\n",
                i = u.unit,
                l = u.label,
                bd = u.boards,
                s = if u.boards == 1 { "" } else { "s" },
                n = u.served,
                ut = 100.0 * u.utilization,
            ));
        }
        if let Some(f) = &self.faults {
            out.push_str(&f.render());
        }
        out
    }
}
