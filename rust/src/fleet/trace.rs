//! Trace-driven traffic: arrival-timestamp schedules for the fleet
//! simulator.
//!
//! A [`TraceSpec`] is plain data — either an explicit timestamp list
//! (loaded from JSON, the replay path) or a seeded generator (Poisson
//! baseline, diurnal sinusoid, flash-crowd burst, on/off bursty).
//! Sampling is a pure function of the spec: generators draw from
//! `util::rng::SplitMix64` via inverse-CDF exponentials and
//! Lewis–Shedler thinning, so a given spec produces byte-identical
//! arrivals on every run, exactly like `fault::GeneratorSpec`.

use crate::util::json::Json;
use crate::util::rng::{poisson_arrivals, SplitMix64};

/// Seed salt so trace draws never collide with fault-generator draws
/// that share a user-facing seed value.
const TRACE_SALT: u64 = 0x7_2ACE_5EED;

/// The shape of offered traffic over the horizon.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceKind {
    /// Homogeneous Poisson arrivals at `rate_hz`.
    Poisson { rate_hz: f64 },
    /// Sinusoidal day/night swing:
    /// `rate(t) = base_hz + amplitude_hz · sin(2π t / period_s)`,
    /// clamped at 0.
    Diurnal {
        base_hz: f64,
        amplitude_hz: f64,
        period_s: f64,
    },
    /// Steady `base_hz` with one burst: a linear ramp to `peak_hz` over
    /// `ramp_s` starting at `at_s`, held for `hold_s`, then a symmetric
    /// ramp back down.
    FlashCrowd {
        base_hz: f64,
        peak_hz: f64,
        at_s: f64,
        ramp_s: f64,
        hold_s: f64,
    },
    /// Bursty on/off source: Poisson at `on_hz` for `on_s` seconds, then
    /// silent for `off_s`, repeating.
    OnOff { on_hz: f64, on_s: f64, off_s: f64 },
    /// Explicit arrival timestamps (clock seconds), e.g. replayed from a
    /// production log. Stored sorted ascending.
    Explicit { timestamps: Vec<f64> },
}

impl TraceKind {
    pub fn tag(&self) -> &'static str {
        match self {
            TraceKind::Poisson { .. } => "poisson",
            TraceKind::Diurnal { .. } => "diurnal",
            TraceKind::FlashCrowd { .. } => "flash-crowd",
            TraceKind::OnOff { .. } => "on-off",
            TraceKind::Explicit { .. } => "explicit",
        }
    }

    /// Instantaneous offered rate at time `t` (generator kinds only).
    fn rate_at(&self, t: f64) -> f64 {
        match self {
            TraceKind::Poisson { rate_hz } => *rate_hz,
            TraceKind::Diurnal { base_hz, amplitude_hz, period_s } => {
                (base_hz + amplitude_hz * (2.0 * std::f64::consts::PI * t / period_s).sin())
                    .max(0.0)
            }
            TraceKind::FlashCrowd { base_hz, peak_hz, at_s, ramp_s, hold_s } => {
                let up_end = at_s + ramp_s;
                let hold_end = up_end + hold_s;
                let down_end = hold_end + ramp_s;
                if t < *at_s || t >= down_end {
                    *base_hz
                } else if t < up_end {
                    base_hz + (peak_hz - base_hz) * (t - at_s) / ramp_s.max(1e-12)
                } else if t < hold_end {
                    *peak_hz
                } else {
                    peak_hz - (peak_hz - base_hz) * (t - hold_end) / ramp_s.max(1e-12)
                }
            }
            TraceKind::OnOff { on_hz, on_s, off_s } => {
                let phase = t % (on_s + off_s);
                if phase < *on_s {
                    *on_hz
                } else {
                    0.0
                }
            }
            TraceKind::Explicit { .. } => 0.0,
        }
    }

    /// Upper bound on `rate_at` over the horizon — the thinning envelope.
    fn rate_max(&self) -> f64 {
        match self {
            TraceKind::Poisson { rate_hz } => *rate_hz,
            TraceKind::Diurnal { base_hz, amplitude_hz, .. } => base_hz + amplitude_hz.abs(),
            TraceKind::FlashCrowd { base_hz, peak_hz, .. } => base_hz.max(*peak_hz),
            TraceKind::OnOff { on_hz, .. } => *on_hz,
            TraceKind::Explicit { .. } => 0.0,
        }
    }
}

/// A complete traffic schedule: kind + seed + horizon. Plain data with a
/// JSON round trip, like `FaultPlan`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub kind: TraceKind,
    /// Generator seed (ignored by `Explicit`).
    pub seed: u64,
    /// Arrivals are sampled on `[0, horizon_s)`. For `Explicit` traces
    /// this is the replay window (defaults to just past the last
    /// timestamp).
    pub horizon_s: f64,
}

impl TraceSpec {
    pub fn poisson(rate_hz: f64, horizon_s: f64, seed: u64) -> TraceSpec {
        TraceSpec { kind: TraceKind::Poisson { rate_hz }, seed, horizon_s }
    }

    pub fn diurnal(
        base_hz: f64,
        amplitude_hz: f64,
        period_s: f64,
        horizon_s: f64,
        seed: u64,
    ) -> TraceSpec {
        TraceSpec {
            kind: TraceKind::Diurnal { base_hz, amplitude_hz, period_s },
            seed,
            horizon_s,
        }
    }

    pub fn flash_crowd(
        base_hz: f64,
        peak_hz: f64,
        at_s: f64,
        ramp_s: f64,
        hold_s: f64,
        horizon_s: f64,
        seed: u64,
    ) -> TraceSpec {
        TraceSpec {
            kind: TraceKind::FlashCrowd { base_hz, peak_hz, at_s, ramp_s, hold_s },
            seed,
            horizon_s,
        }
    }

    pub fn on_off(on_hz: f64, on_s: f64, off_s: f64, horizon_s: f64, seed: u64) -> TraceSpec {
        TraceSpec {
            kind: TraceKind::OnOff { on_hz, on_s, off_s },
            seed,
            horizon_s,
        }
    }

    /// Explicit timestamp trace; sorts the list and derives the horizon
    /// from the last arrival.
    pub fn explicit(mut timestamps: Vec<f64>) -> TraceSpec {
        timestamps.sort_by(f64::total_cmp);
        let horizon_s = timestamps.last().copied().unwrap_or(0.0) + 1e-9;
        TraceSpec {
            kind: TraceKind::Explicit { timestamps },
            seed: 0,
            horizon_s,
        }
    }

    pub fn tag(&self) -> &'static str {
        self.kind.tag()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let nonneg = |v: f64, what: &str| {
            anyhow::ensure!(v.is_finite() && v >= 0.0, "trace {what} must be finite and ≥ 0");
            Ok(())
        };
        match &self.kind {
            TraceKind::Poisson { rate_hz } => nonneg(*rate_hz, "rate_hz")?,
            TraceKind::Diurnal { base_hz, amplitude_hz, period_s } => {
                nonneg(*base_hz, "base_hz")?;
                nonneg(*amplitude_hz, "amplitude_hz")?;
                anyhow::ensure!(
                    period_s.is_finite() && *period_s > 0.0,
                    "diurnal period_s must be positive"
                );
            }
            TraceKind::FlashCrowd { base_hz, peak_hz, at_s, ramp_s, hold_s } => {
                nonneg(*base_hz, "base_hz")?;
                nonneg(*peak_hz, "peak_hz")?;
                nonneg(*at_s, "at_s")?;
                nonneg(*ramp_s, "ramp_s")?;
                nonneg(*hold_s, "hold_s")?;
            }
            TraceKind::OnOff { on_hz, on_s, off_s } => {
                nonneg(*on_hz, "on_hz")?;
                nonneg(*off_s, "off_s")?;
                anyhow::ensure!(
                    on_s.is_finite() && *on_s > 0.0,
                    "on-off on_s must be positive"
                );
            }
            TraceKind::Explicit { timestamps } => {
                for &t in timestamps {
                    nonneg(t, "timestamp")?;
                }
            }
        }
        if !matches!(self.kind, TraceKind::Explicit { .. }) {
            anyhow::ensure!(
                self.horizon_s.is_finite() && self.horizon_s > 0.0,
                "trace horizon_s must be positive"
            );
        }
        Ok(())
    }

    /// Arrival timestamps on `[0, horizon_s)`, sorted ascending — a pure
    /// function of the spec.
    pub fn sample(&self) -> Vec<f64> {
        if let TraceKind::Explicit { timestamps } = &self.kind {
            let mut ts = timestamps.clone();
            ts.sort_by(f64::total_cmp);
            return ts;
        }
        let mut rng = SplitMix64::new(self.seed ^ TRACE_SALT);
        if let TraceKind::Poisson { rate_hz } = self.kind {
            // Literal reuse of the fault-generator loop: one draw per
            // arrival, no thinning overhead on the homogeneous baseline.
            return poisson_arrivals(&mut rng, rate_hz, self.horizon_s);
        }
        // Lewis–Shedler thinning against the envelope rate: sample a
        // homogeneous process at `rate_max`, accept each point with
        // probability `rate(t) / rate_max`.
        let lambda = self.kind.rate_max();
        let mut ts = Vec::new();
        if lambda <= 0.0 {
            return ts;
        }
        let mut t = 0.0_f64;
        loop {
            t += rng.next_exp(lambda);
            if t >= self.horizon_s {
                return ts;
            }
            if rng.next_f64() * lambda < self.kind.rate_at(t) {
                ts.push(t);
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let j = Json::obj()
            .set("kind", self.tag())
            .set("seed", self.seed)
            .set("horizon_s", self.horizon_s);
        match &self.kind {
            TraceKind::Poisson { rate_hz } => j.set("rate_hz", *rate_hz),
            TraceKind::Diurnal { base_hz, amplitude_hz, period_s } => j
                .set("base_hz", *base_hz)
                .set("amplitude_hz", *amplitude_hz)
                .set("period_s", *period_s),
            TraceKind::FlashCrowd { base_hz, peak_hz, at_s, ramp_s, hold_s } => j
                .set("base_hz", *base_hz)
                .set("peak_hz", *peak_hz)
                .set("at_s", *at_s)
                .set("ramp_s", *ramp_s)
                .set("hold_s", *hold_s),
            TraceKind::OnOff { on_hz, on_s, off_s } => {
                j.set("on_hz", *on_hz).set("on_s", *on_s).set("off_s", *off_s)
            }
            TraceKind::Explicit { timestamps } => {
                j.set("timestamps", Json::Arr(timestamps.iter().map(|&t| Json::Num(t)).collect()))
            }
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TraceSpec> {
        let f = |key: &str, dflt: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dflt);
        let kind = match j.get("kind").and_then(Json::as_str) {
            Some("poisson") => TraceKind::Poisson { rate_hz: f("rate_hz", 30.0) },
            Some("diurnal") => TraceKind::Diurnal {
                base_hz: f("base_hz", 30.0),
                amplitude_hz: f("amplitude_hz", 15.0),
                period_s: f("period_s", 1.0),
            },
            Some("flash-crowd") => TraceKind::FlashCrowd {
                base_hz: f("base_hz", 30.0),
                peak_hz: f("peak_hz", 90.0),
                at_s: f("at_s", 0.25),
                ramp_s: f("ramp_s", 0.05),
                hold_s: f("hold_s", 0.25),
            },
            Some("on-off") => TraceKind::OnOff {
                on_hz: f("on_hz", 60.0),
                on_s: f("on_s", 0.1),
                off_s: f("off_s", 0.1),
            },
            Some("explicit") => {
                let ts = j
                    .get("timestamps")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| {
                        anyhow::anyhow!("explicit trace needs a `timestamps` array")
                    })?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .ok_or_else(|| anyhow::anyhow!("trace timestamps must be numeric"))
                    })
                    .collect::<anyhow::Result<Vec<f64>>>()?;
                let mut ts = ts;
                ts.sort_by(f64::total_cmp);
                TraceKind::Explicit { timestamps: ts }
            }
            other => anyhow::bail!(
                "unknown trace kind {other:?} (poisson/diurnal/flash-crowd/on-off/explicit)"
            ),
        };
        let default_horizon = match &kind {
            TraceKind::Explicit { timestamps } => {
                timestamps.last().copied().unwrap_or(0.0) + 1e-9
            }
            _ => 1.0,
        };
        let spec = TraceSpec {
            kind,
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(0),
            horizon_s: f("horizon_s", default_horizon),
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Load a trace from a JSON file (the `--trace <trace.json>` path).
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<TraceSpec> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        TraceSpec::from_json(&Json::parse(&text)?)
    }
}

/// A sampled trace: the spec plus its realized arrival timestamps,
/// ready for the simulator to replay.
#[derive(Debug, Clone)]
pub struct TraceSource {
    spec: TraceSpec,
    arrivals: Vec<f64>,
}

impl TraceSource {
    pub fn from_spec(spec: TraceSpec) -> anyhow::Result<TraceSource> {
        spec.validate()?;
        let arrivals = spec.sample();
        Ok(TraceSource { spec, arrivals })
    }

    pub fn spec(&self) -> &TraceSpec {
        &self.spec
    }

    /// Sorted arrival timestamps in clock seconds.
    pub fn arrivals(&self) -> &[f64] {
        &self.arrivals
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    pub fn horizon_s(&self) -> f64 {
        self.spec.horizon_s
    }

    /// Average offered rate over the horizon.
    pub fn mean_rate_hz(&self) -> f64 {
        if self.spec.horizon_s > 0.0 {
            self.arrivals.len() as f64 / self.spec.horizon_s
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_round_trips_through_json() {
        let specs = [
            TraceSpec::poisson(120.0, 2.0, 7),
            TraceSpec::diurnal(60.0, 30.0, 0.5, 2.0, 7),
            TraceSpec::flash_crowd(40.0, 200.0, 0.5, 0.05, 0.2, 2.0, 7),
            TraceSpec::on_off(100.0, 0.1, 0.15, 2.0, 7),
            TraceSpec::explicit(vec![0.3, 0.1, 0.2]),
        ];
        for spec in specs {
            let back = TraceSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back, "{} spec must round-trip", spec.tag());
        }
    }

    #[test]
    fn sampling_is_a_pure_function_of_the_spec() {
        for spec in [
            TraceSpec::poisson(200.0, 1.0, 3),
            TraceSpec::diurnal(100.0, 80.0, 0.25, 1.0, 3),
            TraceSpec::flash_crowd(50.0, 400.0, 0.25, 0.05, 0.25, 1.0, 3),
            TraceSpec::on_off(150.0, 0.05, 0.05, 1.0, 3),
        ] {
            let a = spec.sample();
            let b = spec.sample();
            assert_eq!(a, b, "{} sampling must be deterministic", spec.tag());
            assert!(!a.is_empty(), "{} should emit arrivals", spec.tag());
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
            assert!(a.iter().all(|&t| t >= 0.0 && t < spec.horizon_s));
        }
    }

    #[test]
    fn explicit_trace_replays_sorted() {
        let spec = TraceSpec::explicit(vec![0.5, 0.1, 0.9, 0.3]);
        assert_eq!(spec.sample(), vec![0.1, 0.3, 0.5, 0.9]);
        assert!(spec.horizon_s > 0.9);
    }

    #[test]
    fn on_off_trace_respects_silent_windows() {
        let spec = TraceSpec::on_off(400.0, 0.1, 0.1, 1.0, 9);
        for t in spec.sample() {
            let phase = t % 0.2;
            assert!(phase < 0.1, "arrival {t} fell in an off window");
        }
    }

    #[test]
    fn flash_crowd_bursts_above_baseline() {
        let spec = TraceSpec::flash_crowd(20.0, 500.0, 0.4, 0.05, 0.2, 1.0, 5);
        let ts = spec.sample();
        let burst = ts.iter().filter(|&&t| t >= 0.4 && t < 0.7).count();
        let quiet = ts.iter().filter(|&&t| t < 0.3).count();
        assert!(
            burst > 2 * quiet.max(1),
            "burst window ({burst}) should dominate an equal quiet window ({quiet})"
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(TraceSpec::from_json(&Json::obj().set("kind", "sawtooth")).is_err());
        assert!(TraceSpec::poisson(-1.0, 1.0, 0).validate().is_err());
        assert!(TraceSpec::poisson(10.0, 0.0, 0).validate().is_err());
        assert!(TraceSpec::on_off(10.0, 0.0, 0.1, 1.0, 0).validate().is_err());
    }
}
