//! `vaqf::fleet` — one-clock fleet simulator: load-balanced replica
//! groups × N-board pipelines under trace-driven traffic.
//!
//! The serving scheduler (PR 3/6) answers "how do frames share one
//! accelerator"; the shard pipeline (PR 5) answers "how does one model
//! span N accelerators". This module composes both one level up: a
//! **fleet** is an ordered list of serving units — data-parallel
//! replicas and/or N-board shard pipelines ([`topology`]) — fronted by
//! a pluggable load balancer ([`balancer`]) and driven by recorded or
//! seeded arrival traces ([`trace`]) on a single shared
//! [`VirtualClock`](crate::coordinator::VirtualClock). Fault plans
//! ([`crate::fault`]) address whole serving units, so the
//! pipelining-vs-replication question can be asked under crashes,
//! slow-downs and flash crowds, not just steady state.
//!
//! Everything is deterministic: same design + topology + balancer +
//! trace + fault plan ⇒ byte-identical report JSON.
//!
//! Entry points: [`crate::api::FleetBuilder`] (via
//! `CompiledDesign::fleet()` or `Session::compile_fleet()`), the
//! `vaqf fleet` CLI subcommand, or [`simulate_fleet`] directly.

mod balancer;
mod report;
mod sim;
mod topology;
mod trace;

pub use balancer::{
    balancer_for, BalancerPolicy, JoinShortestQueue, LeastOutstanding, RoundRobinBalancer,
    SlaWeighted, UnitSnapshot, BALANCER_NAMES,
};
pub use report::{FleetFaultSummary, FleetReport, UnitReport};
pub use sim::{simulate_fleet, simulate_fleet_traced, FleetConfig, ServingUnit, StageSpec};
pub use topology::{FleetTopology, UnitKind, TOPOLOGY_PRESETS};
pub use trace::{TraceKind, TraceSource, TraceSpec};
