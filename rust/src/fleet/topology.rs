//! Fleet topology: how a fixed board count is carved into serving units.
//!
//! A serving unit is either a data-parallel **replica** (one board
//! running the whole compiled design) or an N-board **pipeline** (the
//! PR 5 shard stage model). The central deployment question — at equal
//! board count, pipeline, replicate, or mix? — is a choice of
//! [`FleetTopology`], compared under identical traffic.

/// One serving unit's shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnitKind {
    /// One board running the full compiled design.
    Replica,
    /// `depth` boards running the co-searched shard pipeline.
    Pipeline { depth: usize },
}

impl UnitKind {
    pub fn boards(&self) -> usize {
        match self {
            UnitKind::Replica => 1,
            UnitKind::Pipeline { depth } => *depth,
        }
    }

    pub fn label(&self) -> String {
        match self {
            UnitKind::Replica => "replica".to_string(),
            UnitKind::Pipeline { depth } => format!("pipeline:{depth}"),
        }
    }
}

/// An ordered list of serving units (order fixes unit indices, which
/// balancer tie-breaks and fault plans refer to).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FleetTopology {
    pub units: Vec<UnitKind>,
}

/// Preset names accepted by [`FleetTopology::preset`] and the CLI.
pub const TOPOLOGY_PRESETS: [&str; 3] = ["replicated", "pipelined", "mixed"];

impl FleetTopology {
    pub fn new() -> FleetTopology {
        FleetTopology::default()
    }

    /// Append one replica unit.
    pub fn replica(mut self) -> FleetTopology {
        self.units.push(UnitKind::Replica);
        self
    }

    /// Append `n` replica units.
    pub fn replicas(mut self, n: usize) -> FleetTopology {
        for _ in 0..n {
            self.units.push(UnitKind::Replica);
        }
        self
    }

    /// Append one pipeline unit of `depth` boards (`depth ≤ 1` collapses
    /// to a replica).
    pub fn pipeline(mut self, depth: usize) -> FleetTopology {
        self.units.push(if depth <= 1 {
            UnitKind::Replica
        } else {
            UnitKind::Pipeline { depth }
        });
        self
    }

    /// `boards` independent replicas — pure data parallelism.
    pub fn replicated(boards: usize) -> FleetTopology {
        FleetTopology::new().replicas(boards.max(1))
    }

    /// One pipeline across all `boards` — pure model parallelism.
    pub fn pipelined(boards: usize) -> FleetTopology {
        FleetTopology::new().pipeline(boards.max(1))
    }

    /// Half the boards (rounded up) as one pipeline, the rest as
    /// replicas; below 3 boards this collapses to `replicated`.
    pub fn mixed(boards: usize) -> FleetTopology {
        if boards < 3 {
            return FleetTopology::replicated(boards);
        }
        let depth = boards.div_ceil(2);
        FleetTopology::new().replicas(boards - depth).pipeline(depth)
    }

    /// Resolve a preset name at a board count.
    pub fn preset(name: &str, boards: usize) -> Option<FleetTopology> {
        match name {
            "replicated" | "rep" => Some(FleetTopology::replicated(boards)),
            "pipelined" | "pipe" => Some(FleetTopology::pipelined(boards)),
            "mixed" | "mix" => Some(FleetTopology::mixed(boards)),
            _ => None,
        }
    }

    /// Total boards across all units.
    pub fn boards(&self) -> usize {
        self.units.iter().map(UnitKind::boards).sum()
    }

    /// Number of serving units the balancer spreads over.
    pub fn len(&self) -> usize {
        self.units.len()
    }

    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    /// Human label, e.g. `replicated(4)`, `pipelined(4)`,
    /// `2×replica+pipeline:2`.
    pub fn label(&self) -> String {
        let boards = self.boards();
        if !self.units.is_empty() && self.units.iter().all(|u| *u == UnitKind::Replica) {
            return format!("replicated({boards})");
        }
        if self.units.len() == 1 {
            return format!("pipelined({boards})");
        }
        let replicas = self.units.iter().filter(|u| **u == UnitKind::Replica).count();
        let mut parts = Vec::new();
        if replicas > 0 {
            parts.push(format!("{replicas}×replica"));
        }
        for u in &self.units {
            if let UnitKind::Pipeline { depth } = u {
                parts.push(format!("pipeline:{depth}"));
            }
        }
        parts.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_conserve_board_count() {
        for boards in 1..=6 {
            for name in TOPOLOGY_PRESETS {
                let t = FleetTopology::preset(name, boards).unwrap();
                assert_eq!(t.boards(), boards, "{name} at {boards} boards");
                assert!(!t.is_empty());
            }
        }
        assert!(FleetTopology::preset("torus", 4).is_none());
    }

    #[test]
    fn mixed_splits_replicas_and_a_pipeline() {
        let t = FleetTopology::mixed(4);
        assert_eq!(
            t.units,
            vec![UnitKind::Replica, UnitKind::Replica, UnitKind::Pipeline { depth: 2 }]
        );
        assert_eq!(t.label(), "2×replica+pipeline:2");
        assert_eq!(FleetTopology::mixed(2), FleetTopology::replicated(2));
    }

    #[test]
    fn labels_identify_presets() {
        assert_eq!(FleetTopology::replicated(4).label(), "replicated(4)");
        assert_eq!(FleetTopology::pipelined(4).label(), "pipelined(4)");
        assert_eq!(FleetTopology::pipelined(1).label(), "replicated(1)");
    }

    #[test]
    fn shallow_pipelines_collapse_to_replicas() {
        assert_eq!(FleetTopology::new().pipeline(1).units, vec![UnitKind::Replica]);
    }
}
