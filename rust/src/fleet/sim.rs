//! The one-clock fleet simulator.
//!
//! A discrete-event loop on the shared [`VirtualClock`] where the
//! schedulable entity is a **serving unit**: a data-parallel replica
//! (one board, one service time for the whole compiled design) or an
//! N-board shard pipeline (the PR 5 stage model — per-stage service
//! cycles and bounded inter-stage FIFOs with downstream-first
//! backpressure). A [`BalancerPolicy`] routes every trace arrival to
//! one healthy unit; frames then flow through the unit's stages like
//! `shard::simulate_pipeline` frames flow through boards.
//!
//! Event ordering is the scheduler's: a max-heap popping the smallest
//! `(cycle, seq)`, fault events seeded with the lowest sequence numbers
//! so a same-cycle crash beats the completion racing it. Fault plans
//! address serving units (unit 0 is the first in the topology); a crash
//! pulls every frame inside the unit back through the balancer on the
//! scheduler's retry/backoff path, and the spare inventory hot-swaps
//! crashed units back after `swap_s`, mirroring the pipeline failover
//! path at fleet granularity. Conservation holds per stream and in
//! aggregate: `offered == completed + dropped + failed`.

use std::collections::{BinaryHeap, VecDeque};

use crate::coordinator::{
    AggregateReport, Clock, Frame, FrameSource, StreamReport, StreamStats, VirtualClock,
};
use crate::fault::{DowntimeTracker, FaultKind, FaultPlan, Health};
use crate::model::VitConfig;
use crate::obs::{TraceSink, TrackId, TrackKind};
use crate::util::stats::Summary;
use crate::Cycles;

use super::balancer::{BalancerPolicy, UnitSnapshot};
use super::report::{FleetFaultSummary, FleetReport, UnitReport};
use super::trace::TraceSource;

/// One pipeline stage (or the whole design, for a replica) as the
/// simulator sees it: deterministic service plus a bounded input FIFO.
#[derive(Debug, Clone, Copy)]
pub struct StageSpec {
    pub service_cycles: Cycles,
    /// Input FIFO capacity in frames (stage 0's FIFO is the unit's
    /// admission queue).
    pub capacity: usize,
}

/// A serving unit handed to [`simulate_fleet`].
#[derive(Debug, Clone)]
pub struct ServingUnit {
    /// `replica` or `pipeline:<depth>`.
    pub label: String,
    pub boards: usize,
    pub stages: Vec<StageSpec>,
}

impl ServingUnit {
    /// One board serving whole frames in `service_cycles`, admitting up
    /// to `queue_depth` waiting frames.
    pub fn replica(service_cycles: Cycles, queue_depth: usize) -> ServingUnit {
        ServingUnit {
            label: "replica".to_string(),
            boards: 1,
            stages: vec![StageSpec {
                service_cycles: service_cycles.max(1),
                capacity: queue_depth.max(1),
            }],
        }
    }

    /// An N-board pipeline; `stages[0].capacity` is the admission queue.
    pub fn pipeline(boards: usize, stages: Vec<StageSpec>) -> ServingUnit {
        ServingUnit {
            label: format!("pipeline:{boards}"),
            boards,
            stages,
        }
    }
}

/// Run-level configuration and report labels.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Backend label for the report, e.g. `analytic:W1A8`.
    pub backend: String,
    /// Topology label for the report, e.g. `replicated(4)`.
    pub topology: String,
    /// Arrivals are assigned round-robin across this many streams.
    pub streams: usize,
    pub sla_ms: Option<f64>,
    /// Seed for the per-stream `FrameSource`s (frame ids and payloads).
    pub source_seed: u64,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            backend: "analytic".to_string(),
            topology: "replicated(1)".to_string(),
            streams: 1,
            sla_ms: None,
            source_seed: 11,
        }
    }
}

// ---------------------------------------------------------------------------
// Internal state.
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct InService {
    frame: Frame,
    /// Dispatch id — a crash invalidates it, turning the pending
    /// `StageDone` into a deterministic no-op (scheduler idiom).
    dispatch: u64,
    /// Cycle service began — the span anchor when tracing.
    started: Cycles,
}

#[derive(Debug)]
struct Stage {
    service: Cycles,
    capacity: usize,
    queue: VecDeque<Frame>,
    in_service: Option<InService>,
    /// Finished this stage but waiting for room in the next FIFO, with
    /// the cycle the stall began.
    blocked: Option<(Frame, Cycles)>,
    busy_cycles: Cycles,
}

#[derive(Debug)]
struct Unit {
    label: String,
    boards: usize,
    stages: Vec<Stage>,
    health: Health,
    slow: f64,
    corrupt_next: bool,
    served: u64,
}

impl Unit {
    fn is_up(&self) -> bool {
        self.health != Health::Down
    }

    fn outstanding(&self) -> usize {
        self.stages
            .iter()
            .map(|s| {
                s.queue.len()
                    + usize::from(s.in_service.is_some())
                    + usize::from(s.blocked.is_some())
            })
            .sum()
    }

    /// Steady-state cadence: the slowest stage bounds throughput.
    fn bottleneck_cycles(&self) -> Cycles {
        self.stages.iter().map(|s| s.service).max().unwrap_or(1)
    }

    /// Nominal whole-unit compute per frame (for device-latency stats).
    fn device_cycles(&self) -> Cycles {
        self.stages.iter().map(|s| s.service).sum()
    }

    fn busy_cycles(&self) -> Cycles {
        self.stages.iter().map(|s| s.busy_cycles).sum()
    }

    fn has_room(&self) -> bool {
        self.stages[0].queue.len() < self.stages[0].capacity
    }
}

struct Event {
    cycle: Cycles,
    seq: u64,
    kind: EventKind,
}

enum EventKind {
    /// Arrival `idx` of the trace (streams are assigned round-robin).
    Arrival { idx: u64 },
    /// A stage finished its current frame.
    StageDone { unit: usize, stage: usize, dispatch: u64 },
    /// Hot-swap complete: a spare restored the crashed unit.
    UnitUp { unit: usize },
    /// Index into the sorted fault-event schedule.
    Fault { index: usize },
    /// Retry backoff elapsed: the frame re-enters the balancer.
    Retry { frame: Frame },
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.cycle == other.cycle && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Event) -> std::cmp::Ordering {
        // Reversed so `BinaryHeap` (a max-heap) pops the earliest
        // (cycle, seq) first — a deterministic total order.
        (other.cycle, other.seq).cmp(&(self.cycle, self.seq))
    }
}

fn scaled_cycles(service: Cycles, slow: f64) -> Cycles {
    ((service as f64) * slow).ceil().max(1.0) as Cycles
}

/// Registered tracks of a traced fleet run, bundled so the settle/route
/// helpers take one `Option<&mut FleetTracer>`.
struct FleetTracer<'a> {
    sink: &'a mut TraceSink,
    streams: Vec<TrackId>,
    /// `units[u][s]`: the track of unit `u`, stage `s` (a single-stage
    /// replica gets a Unit-kind track, pipeline stages Stage-kind).
    units: Vec<Vec<TrackId>>,
    ctrl: TrackId,
}

/// Let frames flow inside one unit until nothing moves: downstream-first
/// unblock, then start service on idle stages — the
/// `shard::simulate_pipeline` settle loop, driven by heap events instead
/// of a closed-loop source.
#[allow(clippy::too_many_arguments)]
fn settle_unit(
    unit_idx: usize,
    unit: &mut Unit,
    now: Cycles,
    heap: &mut BinaryHeap<Event>,
    seq: &mut u64,
    dispatch_counter: &mut u64,
    mut tracer: Option<&mut FleetTracer>,
) {
    let n = unit.stages.len();
    loop {
        let mut progressed = false;
        for i in (0..n).rev() {
            if i + 1 < n {
                if let Some((frame, since)) = unit.stages[i].blocked.take() {
                    if unit.stages[i + 1].queue.len() < unit.stages[i + 1].capacity {
                        if let Some(tr) = tracer.as_deref_mut() {
                            if now > since {
                                tr.sink.span(
                                    tr.units[unit_idx][i],
                                    "backpressure",
                                    since,
                                    now - since,
                                    vec![("frame", frame.id.into())],
                                );
                            }
                        }
                        unit.stages[i + 1].queue.push_back(frame);
                        progressed = true;
                    } else {
                        unit.stages[i].blocked = Some((frame, since));
                    }
                }
            }
            if unit.is_up()
                && unit.stages[i].in_service.is_none()
                && unit.stages[i].blocked.is_none()
            {
                if let Some(frame) = unit.stages[i].queue.pop_front() {
                    let dur = scaled_cycles(unit.stages[i].service, unit.slow);
                    *dispatch_counter += 1;
                    unit.stages[i].busy_cycles += dur;
                    unit.stages[i].in_service = Some(InService {
                        frame,
                        dispatch: *dispatch_counter,
                        started: now,
                    });
                    heap.push(Event {
                        cycle: now + dur,
                        seq: *seq,
                        kind: EventKind::StageDone {
                            unit: unit_idx,
                            stage: i,
                            dispatch: *dispatch_counter,
                        },
                    });
                    *seq += 1;
                    progressed = true;
                }
            }
        }
        if !progressed {
            break;
        }
    }
}

/// Healthy-unit snapshots in ascending unit order (the balancer
/// contract).
fn snapshots(units: &[Unit], clock: &VirtualClock) -> Vec<UnitSnapshot> {
    units
        .iter()
        .enumerate()
        .filter(|(_, u)| u.is_up())
        .map(|(i, u)| UnitSnapshot {
            unit: i,
            queued: u.stages[0].queue.len(),
            outstanding: u.outstanding(),
            busy_s: clock.cycles_to_seconds(u.busy_cycles()),
            served: u.served,
            service_s: clock.cycles_to_seconds(u.bottleneck_cycles()),
        })
        .collect()
}

#[allow(clippy::too_many_arguments)]
fn route(
    frame: Frame,
    is_retry: bool,
    units: &mut [Unit],
    balancer: &mut dyn BalancerPolicy,
    stats: &mut [StreamStats],
    clock: &VirtualClock,
    heap: &mut BinaryHeap<Event>,
    seq: &mut u64,
    dispatch_counter: &mut u64,
    mut tracer: Option<&mut FleetTracer>,
) {
    let healthy = snapshots(units, clock);
    if healthy.is_empty() {
        // Nobody to serve: fresh arrivals are shed at admission, retried
        // frames exhaust their recovery (conservation either way).
        if is_retry {
            if let Some(tr) = tracer.as_deref_mut() {
                tr.sink.instant(
                    tr.ctrl,
                    "fail",
                    clock.cycles(),
                    vec![("frame", frame.id.into()), ("stream", frame.stream.into())],
                );
            }
            stats[frame.stream].failed += 1;
        } else {
            if let Some(tr) = tracer.as_deref_mut() {
                tr.sink.instant(
                    tr.streams[frame.stream],
                    "drop",
                    clock.cycles(),
                    vec![("frame", frame.id.into())],
                );
            }
            stats[frame.stream].dropped += 1;
        }
        return;
    }
    let u = healthy[balancer.pick_unit(&healthy)].unit;
    let admitted = is_retry || units[u].has_room();
    if let Some(tr) = tracer.as_deref_mut() {
        if admitted {
            tr.sink.instant(
                tr.units[u][0],
                "dispatch",
                clock.cycles(),
                vec![
                    ("frame", frame.id.into()),
                    ("stream", frame.stream.into()),
                    ("retry", u64::from(is_retry).into()),
                ],
            );
        } else {
            tr.sink.instant(
                tr.streams[frame.stream],
                "drop",
                clock.cycles(),
                vec![("frame", frame.id.into())],
            );
        }
    }
    if is_retry {
        // Oldest work jumps the admission gate, mirroring the
        // scheduler's retry pool jumping the stream queues.
        units[u].stages[0].queue.push_front(frame);
    } else if admitted {
        units[u].stages[0].queue.push_back(frame);
    } else {
        stats[frame.stream].dropped += 1;
        return;
    }
    settle_unit(u, &mut units[u], clock.cycles(), heap, seq, dispatch_counter, tracer);
}

#[allow(clippy::too_many_arguments)]
fn schedule_retry(
    mut frame: Frame,
    recovery: &crate::fault::RecoveryConfig,
    clock: &VirtualClock,
    heap: &mut BinaryHeap<Event>,
    seq: &mut u64,
    stats: &mut [StreamStats],
    summary: &mut FleetFaultSummary,
    tracer: Option<&mut FleetTracer>,
) {
    frame.attempts += 1;
    if frame.attempts > recovery.max_retries {
        if let Some(tr) = tracer {
            tr.sink.instant(
                tr.ctrl,
                "fail",
                clock.cycles(),
                vec![("frame", frame.id.into()), ("stream", frame.stream.into())],
            );
        }
        stats[frame.stream].failed += 1;
        return;
    }
    summary.retries += 1;
    let shift = (frame.attempts - 1).min(20);
    let backoff_s = recovery.backoff_base_s * f64::from(1u32 << shift);
    heap.push(Event {
        cycle: clock.cycles() + clock.seconds_to_cycles(backoff_s).max(1),
        seq: *seq,
        kind: EventKind::Retry { frame },
    });
    *seq += 1;
}

// ---------------------------------------------------------------------------
// The simulator.
// ---------------------------------------------------------------------------

/// Drive `trace` through `units` under `balancer` on one virtual clock.
///
/// Pure function of its inputs: two calls with equal arguments render
/// byte-identical reports.
pub fn simulate_fleet(
    model: &VitConfig,
    clock_mhz: u64,
    units_spec: &[ServingUnit],
    trace: &TraceSource,
    balancer: Box<dyn BalancerPolicy>,
    cfg: &FleetConfig,
    faults: Option<&FaultPlan>,
) -> anyhow::Result<FleetReport> {
    simulate_fleet_traced(model, clock_mhz, units_spec, trace, balancer, cfg, faults, None)
}

/// [`simulate_fleet`] with an optional [`TraceSink`]: every event the
/// loop processes additionally records a typed trace event (emit/drop at
/// the streams, dispatch + per-stage service and backpressure spans at
/// the units, fault/hot-swap/redispatch/retry/fail on the control
/// track). The loop is single-threaded over a `(cycle, seq)` heap, so
/// the trace is byte-identical across runs and host thread counts.
#[allow(clippy::too_many_arguments)]
pub fn simulate_fleet_traced(
    model: &VitConfig,
    clock_mhz: u64,
    units_spec: &[ServingUnit],
    trace: &TraceSource,
    mut balancer: Box<dyn BalancerPolicy>,
    cfg: &FleetConfig,
    faults: Option<&FaultPlan>,
    mut sink: Option<&mut TraceSink>,
) -> anyhow::Result<FleetReport> {
    anyhow::ensure!(!units_spec.is_empty(), "fleet needs at least one serving unit");
    for u in units_spec {
        anyhow::ensure!(!u.stages.is_empty(), "serving unit `{}` has no stages", u.label);
    }
    let clock = VirtualClock::new(clock_mhz);
    let n_streams = cfg.streams.max(1);

    let injecting = faults.is_some();
    let plan = faults.cloned().unwrap_or_default();
    let recovery = plan.recovery;
    let fault_events = plan.sorted_events();
    let mut spares = recovery.spares;

    let mut units: Vec<Unit> = units_spec
        .iter()
        .map(|spec| Unit {
            label: spec.label.clone(),
            boards: spec.boards.max(1),
            stages: spec
                .stages
                .iter()
                .map(|s| Stage {
                    service: s.service_cycles.max(1),
                    capacity: s.capacity.max(1),
                    queue: VecDeque::new(),
                    in_service: None,
                    blocked: None,
                    busy_cycles: 0,
                })
                .collect(),
            health: Health::Up,
            slow: 1.0,
            corrupt_next: false,
            served: 0,
        })
        .collect();
    let n_units = units.len();

    // All tracks up front, so in-loop recording is an index lookup.
    let mut tracer: Option<FleetTracer> = sink.as_deref_mut().map(|sink| {
        let streams = (0..n_streams)
            .map(|s| sink.track(TrackKind::Stream, &format!("stream{s}")))
            .collect();
        let unit_tracks = units
            .iter()
            .enumerate()
            .map(|(i, u)| {
                if u.stages.len() == 1 {
                    vec![sink.track(TrackKind::Unit, &format!("unit{i}"))]
                } else {
                    (0..u.stages.len())
                        .map(|j| sink.track(TrackKind::Stage, &format!("unit{i}/stage{j}")))
                        .collect()
                }
            })
            .collect();
        let ctrl = sink.track(TrackKind::Control, "faults");
        FleetTracer {
            sink,
            streams,
            units: unit_tracks,
            ctrl,
        }
    });

    // Frame payloads replay through the existing FrameSource machinery:
    // arrival `idx` maps to stream `idx % n_streams`, frame ids count up
    // per stream, and the trace supplies the arrival timetable.
    let sources: Vec<FrameSource> = (0..n_streams)
        .map(|s| {
            FrameSource::new(model.clone(), cfg.source_seed.wrapping_add(s as u64), None)
                .with_stream(s)
        })
        .collect();
    let mut next_frame_id: Vec<u64> = vec![0; n_streams];
    let mut stats: Vec<StreamStats> = vec![StreamStats::default(); n_streams];
    let mut tracker = DowntimeTracker::new(n_units);
    let mut summary = FleetFaultSummary::default();
    let mut dispatch_counter: u64 = 0;

    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut seq: u64 = 0;
    // Fault events get the lowest seqs: at an equal cycle a crash pops
    // before the completions scheduled after it (scheduler idiom).
    for (index, ev) in fault_events.iter().enumerate() {
        heap.push(Event {
            cycle: clock.seconds_to_cycles(ev.at_s),
            seq,
            kind: EventKind::Fault { index },
        });
        seq += 1;
    }
    if !trace.is_empty() {
        heap.push(Event {
            cycle: clock.seconds_to_cycles(trace.arrivals()[0]),
            seq,
            kind: EventKind::Arrival { idx: 0 },
        });
        seq += 1;
    }

    while let Some(ev) = heap.pop() {
        clock.advance_to(ev.cycle);
        match ev.kind {
            EventKind::Arrival { idx } => {
                let stream = (idx as usize) % n_streams;
                let id = next_frame_id[stream];
                next_frame_id[stream] += 1;
                let mut frame = sources[stream].make_stub(id);
                frame.emitted_at = clock.now();
                stats[stream].offered += 1;
                if let Some(tr) = tracer.as_mut() {
                    tr.sink.instant(
                        tr.streams[stream],
                        "emit",
                        clock.cycles(),
                        vec![("frame", id.into())],
                    );
                }
                if (idx as usize) + 1 < trace.len() {
                    heap.push(Event {
                        cycle: clock.seconds_to_cycles(trace.arrivals()[idx as usize + 1]),
                        seq,
                        kind: EventKind::Arrival { idx: idx + 1 },
                    });
                    seq += 1;
                }
                route(
                    frame, false, &mut units, balancer.as_mut(), &mut stats, &clock,
                    &mut heap, &mut seq, &mut dispatch_counter, tracer.as_mut(),
                );
            }
            EventKind::StageDone { unit, stage, dispatch } => {
                let matches = units[unit].stages[stage]
                    .in_service
                    .as_ref()
                    .map(|s| s.dispatch == dispatch)
                    .unwrap_or(false);
                // A mismatch means the unit crashed under this dispatch
                // (frame already re-routed): stale event.
                if matches {
                    let done = units[unit].stages[stage]
                        .in_service
                        .take()
                        .expect("matched in-service frame");
                    let frame = done.frame;
                    let last = stage + 1 == units[unit].stages.len();
                    if let Some(tr) = tracer.as_mut() {
                        let args = vec![
                            ("frame", frame.id.into()),
                            ("stream", frame.stream.into()),
                        ];
                        let track = tr.units[unit][stage];
                        let dur = clock.cycles() - done.started;
                        // Only a single-stage replica serves the whole
                        // design per span, so only it opens into the
                        // per-layer template.
                        if units[unit].stages.len() == 1 {
                            tr.sink.service_span(track, "service", done.started, dur, args);
                        } else {
                            tr.sink.span(track, "service", done.started, dur, args);
                        }
                    }
                    if last {
                        if units[unit].corrupt_next {
                            // Corrupted completion: discard and re-run the
                            // final stage (shard-pipeline semantics).
                            units[unit].corrupt_next = false;
                            summary.rerun_frames += 1;
                            if let Some(tr) = tracer.as_mut() {
                                tr.sink.instant(
                                    tr.ctrl,
                                    "rerun",
                                    clock.cycles(),
                                    vec![("frame", frame.id.into()), ("unit", unit.into())],
                                );
                            }
                            units[unit].stages[stage].queue.push_front(frame);
                        } else {
                            units[unit].served += 1;
                            let e2e = clock.now() - frame.emitted_at;
                            let device_s =
                                clock.cycles_to_seconds(units[unit].device_cycles());
                            let violation = cfg
                                .sla_ms
                                .map(|ms| e2e > ms / 1e3)
                                .unwrap_or(false);
                            if let Some(tr) = tracer.as_mut() {
                                tr.sink.instant(
                                    tr.streams[frame.stream],
                                    "complete",
                                    clock.cycles(),
                                    vec![
                                        ("frame", frame.id.into()),
                                        ("e2e_ms", (e2e * 1e3).into()),
                                    ],
                                );
                            }
                            stats[frame.stream].record(e2e, device_s, violation);
                        }
                    } else {
                        units[unit].stages[stage].blocked = Some((frame, clock.cycles()));
                    }
                    settle_unit(
                        unit, &mut units[unit], clock.cycles(), &mut heap, &mut seq,
                        &mut dispatch_counter, tracer.as_mut(),
                    );
                }
            }
            EventKind::UnitUp { unit } => {
                if units[unit].health == Health::Down {
                    units[unit].health = if units[unit].slow > 1.0 {
                        Health::Degraded
                    } else {
                        Health::Up
                    };
                    tracker.mark_up(unit, clock.now());
                    if let Some(tr) = tracer.as_mut() {
                        tr.sink.instant(
                            tr.ctrl,
                            "unit_up",
                            clock.cycles(),
                            vec![("unit", unit.into())],
                        );
                    }
                    settle_unit(
                        unit, &mut units[unit], clock.cycles(), &mut heap, &mut seq,
                        &mut dispatch_counter, tracer.as_mut(),
                    );
                }
            }
            EventKind::Fault { index } => {
                let fev = &fault_events[index];
                let u = fev.unit;
                if u < n_units {
                    if let Some(tr) = tracer.as_mut() {
                        let name = match fev.kind {
                            FaultKind::Crash => "fault_crash",
                            FaultKind::Recover => "fault_recover",
                            FaultKind::SlowDown { .. } => "fault_slowdown",
                            FaultKind::SlowEnd => "fault_slow_end",
                            FaultKind::Corrupt => "fault_corrupt",
                        };
                        tr.sink.instant(tr.ctrl, name, clock.cycles(), vec![("unit", u.into())]);
                    }
                    match fev.kind {
                        FaultKind::Crash => {
                            if units[u].health != Health::Down {
                                units[u].health = Health::Down;
                                tracker.mark_down(u, clock.now());
                                summary.injected_crashes += 1;
                                // Pull every frame out of the unit, in
                                // stage order, and re-route it through the
                                // balancer on the retry path.
                                let mut pulled: Vec<Frame> = Vec::new();
                                for (si, st) in units[u].stages.iter_mut().enumerate() {
                                    if let Some(s) = st.in_service.take() {
                                        if let Some(tr) = tracer.as_mut() {
                                            // The crash truncates the
                                            // in-flight service span.
                                            tr.sink.span(
                                                tr.units[u][si],
                                                "aborted",
                                                s.started,
                                                clock.cycles().saturating_sub(s.started),
                                                vec![("frame", s.frame.id.into())],
                                            );
                                        }
                                        pulled.push(s.frame);
                                    }
                                    if let Some((f, _)) = st.blocked.take() {
                                        pulled.push(f);
                                    }
                                    pulled.extend(st.queue.drain(..));
                                }
                                for frame in pulled {
                                    summary.redispatches += 1;
                                    if let Some(tr) = tracer.as_mut() {
                                        tr.sink.instant(
                                            tr.ctrl,
                                            "redispatch",
                                            clock.cycles(),
                                            vec![
                                                ("frame", frame.id.into()),
                                                ("unit", u.into()),
                                            ],
                                        );
                                    }
                                    schedule_retry(
                                        frame, &recovery, &clock, &mut heap, &mut seq,
                                        &mut stats, &mut summary, tracer.as_mut(),
                                    );
                                }
                                if spares > 0 {
                                    // Hot-swap: a spare board set powers
                                    // the unit back up after `swap_s`.
                                    spares -= 1;
                                    summary.hot_swaps += 1;
                                    if let Some(tr) = tracer.as_mut() {
                                        tr.sink.instant(
                                            tr.ctrl,
                                            "hot_swap",
                                            clock.cycles(),
                                            vec![("unit", u.into())],
                                        );
                                    }
                                    heap.push(Event {
                                        cycle: clock.cycles()
                                            + clock.seconds_to_cycles(recovery.swap_s).max(1),
                                        seq,
                                        kind: EventKind::UnitUp { unit: u },
                                    });
                                    seq += 1;
                                }
                            }
                        }
                        FaultKind::Recover => {
                            if units[u].health == Health::Down {
                                units[u].health = if units[u].slow > 1.0 {
                                    Health::Degraded
                                } else {
                                    Health::Up
                                };
                                tracker.mark_up(u, clock.now());
                                settle_unit(
                                    u, &mut units[u], clock.cycles(), &mut heap, &mut seq,
                                    &mut dispatch_counter, tracer.as_mut(),
                                );
                            }
                        }
                        FaultKind::SlowDown { factor } => {
                            summary.injected_slowdowns += 1;
                            units[u].slow = factor.max(1.0);
                            if units[u].health == Health::Up {
                                units[u].health = Health::Degraded;
                            }
                        }
                        FaultKind::SlowEnd => {
                            units[u].slow = 1.0;
                            if units[u].health == Health::Degraded {
                                units[u].health = Health::Up;
                            }
                        }
                        FaultKind::Corrupt => {
                            summary.injected_corruptions += 1;
                            units[u].corrupt_next = true;
                        }
                    }
                }
            }
            EventKind::Retry { frame } => {
                if let Some(tr) = tracer.as_mut() {
                    tr.sink.instant(
                        tr.ctrl,
                        "retry",
                        clock.cycles(),
                        vec![("frame", frame.id.into()), ("stream", frame.stream.into())],
                    );
                }
                route(
                    frame, true, &mut units, balancer.as_mut(), &mut stats, &clock,
                    &mut heap, &mut seq, &mut dispatch_counter, tracer.as_mut(),
                );
            }
        }
    }

    // Conservation drain: a unit that died with no spare and no scripted
    // recovery was emptied at crash time, so nothing should remain — but
    // any stragglers are `failed`, never silently lost.
    for unit in &mut units {
        for st in unit.stages.iter_mut() {
            let mut leftovers: Vec<Frame> = Vec::new();
            if let Some(s) = st.in_service.take() {
                leftovers.push(s.frame);
            }
            if let Some((f, _)) = st.blocked.take() {
                leftovers.push(f);
            }
            leftovers.extend(st.queue.drain(..));
            for f in leftovers {
                if let Some(tr) = tracer.as_mut() {
                    tr.sink.instant(
                        tr.ctrl,
                        "fail",
                        clock.cycles(),
                        vec![("frame", f.id.into()), ("stream", f.stream.into())],
                    );
                }
                stats[f.stream].failed += 1;
            }
        }
    }
    for s in &stats {
        debug_assert_eq!(
            s.offered,
            s.completed() + s.dropped + s.failed,
            "fleet run must conserve frames per stream"
        );
    }

    let elapsed = clock.now();
    tracker.finish(elapsed);

    let per_stream_fps = trace.mean_rate_hz() / n_streams as f64;
    let streams: Vec<StreamReport> = stats
        .iter()
        .enumerate()
        .map(|(s, st)| StreamReport::from_stats(s, per_stream_fps, cfg.sla_ms, st))
        .collect();

    let mut all_e2e: Vec<f64> = Vec::new();
    let mut all_device: Vec<f64> = Vec::new();
    let (mut offered, mut completed, mut dropped, mut failed, mut violations) =
        (0u64, 0u64, 0u64, 0u64, 0u64);
    for st in &stats {
        offered += st.offered;
        completed += st.completed();
        dropped += st.dropped;
        failed += st.failed;
        violations += st.sla_violations;
        all_e2e.extend_from_slice(&st.e2e);
        all_device.extend_from_slice(&st.device);
    }
    let aggregate = AggregateReport {
        offered,
        completed,
        dropped,
        failed,
        drop_rate: dropped as f64 / offered.max(1) as f64,
        sla_violations: violations,
        achieved_fps: if elapsed > 0.0 {
            completed as f64 / elapsed
        } else {
            0.0
        },
        e2e_latency: Summary::from(&all_e2e),
        device_latency: Summary::from(&all_device),
    };

    let unit_reports: Vec<UnitReport> = units
        .iter()
        .enumerate()
        .map(|(i, u)| {
            let busy_seconds = clock.cycles_to_seconds(u.busy_cycles());
            UnitReport {
                unit: i,
                label: u.label.clone(),
                boards: u.boards,
                served: u.served,
                busy_seconds,
                utilization: if elapsed > 0.0 {
                    busy_seconds / (u.boards as f64 * elapsed)
                } else {
                    0.0
                },
            }
        })
        .collect();

    let fault_block = if injecting {
        summary.spares_remaining = spares;
        summary.availability = tracker.availability(elapsed);
        summary.mttr_s = tracker.mttr_s();
        Some(summary)
    } else {
        None
    };

    Ok(FleetReport {
        backend: cfg.backend.clone(),
        topology: cfg.topology.clone(),
        balancer: balancer.name().to_string(),
        clock: "virtual".to_string(),
        trace: trace.spec().tag().to_string(),
        boards: units.iter().map(|u| u.boards).sum(),
        elapsed_seconds: elapsed,
        aggregate,
        streams,
        units: unit_reports,
        faults: fault_block,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::balancer::balancer_for;
    use crate::fleet::trace::TraceSpec;

    fn micro_model() -> VitConfig {
        crate::model::micro()
    }

    fn run(
        units: &[ServingUnit],
        trace: TraceSpec,
        balancer: &str,
        faults: Option<&FaultPlan>,
    ) -> FleetReport {
        let source = TraceSource::from_spec(trace).unwrap();
        simulate_fleet(
            &micro_model(),
            150,
            units,
            &source,
            balancer_for(balancer).unwrap(),
            &FleetConfig {
                streams: 2,
                ..FleetConfig::default()
            },
            faults,
        )
        .unwrap()
    }

    #[test]
    fn single_replica_completes_a_light_trace() {
        // 1 ms service, 100 Hz offered: no contention, nothing dropped.
        let units = [ServingUnit::replica(150_000, 4)];
        let r = run(&units, TraceSpec::poisson(100.0, 0.5, 1), "round-robin", None);
        let a = &r.aggregate;
        assert_eq!(a.offered, a.completed);
        assert_eq!(a.dropped + a.failed, 0);
        assert!(a.e2e_latency.p50 >= 0.001, "latency includes service time");
        assert!(r.faults.is_none(), "no fault plan ⇒ no fault block");
    }

    #[test]
    fn overload_drops_at_admission_but_conserves() {
        // 10 ms service vs 1000 Hz offered: the queue sheds most frames.
        let units = [ServingUnit::replica(1_500_000, 2)];
        let r = run(&units, TraceSpec::poisson(1000.0, 0.2, 2), "least-outstanding", None);
        let a = &r.aggregate;
        assert!(a.dropped > 0, "saturated replica must shed load");
        assert_eq!(a.offered, a.completed + a.dropped + a.failed);
    }

    #[test]
    fn two_replicas_beat_one_under_load() {
        let one = [ServingUnit::replica(750_000, 2)];
        let two = [ServingUnit::replica(750_000, 2), ServingUnit::replica(750_000, 2)];
        let trace = TraceSpec::poisson(350.0, 0.5, 3);
        let r1 = run(&one, trace.clone(), "least-outstanding", None);
        let r2 = run(&two, trace, "least-outstanding", None);
        assert!(
            r2.aggregate.completed > r1.aggregate.completed,
            "2 replicas ({}) must complete more than 1 ({})",
            r2.aggregate.completed,
            r1.aggregate.completed
        );
    }

    #[test]
    fn pipeline_unit_flows_frames_through_stages() {
        let stages = vec![
            StageSpec { service_cycles: 40_000, capacity: 4 },
            StageSpec { service_cycles: 60_000, capacity: 2 },
            StageSpec { service_cycles: 50_000, capacity: 2 },
        ];
        let units = [ServingUnit::pipeline(3, stages)];
        let r = run(&units, TraceSpec::poisson(400.0, 0.5, 4), "round-robin", None);
        let a = &r.aggregate;
        assert!(a.completed > 0);
        assert_eq!(a.offered, a.completed + a.dropped + a.failed);
        // Per-frame latency ≥ sum of stage services (1 ms at 150 MHz).
        assert!(a.e2e_latency.min >= 0.001 - 1e-9);
        assert_eq!(r.units[0].boards, 3);
    }

    #[test]
    fn crash_without_recovery_fails_inflight_frames_and_conserves() {
        let units = [ServingUnit::replica(150_000, 8), ServingUnit::replica(150_000, 8)];
        let plan = FaultPlan::new().crash_at(0.05, 0);
        let r = run(&units, TraceSpec::poisson(500.0, 0.3, 5), "round-robin", Some(&plan));
        let a = &r.aggregate;
        assert_eq!(a.offered, a.completed + a.dropped + a.failed);
        let f = r.faults.as_ref().expect("fault plan ⇒ fault block");
        assert_eq!(f.injected_crashes, 1);
        assert!(f.availability < 1.0, "unit 0 stayed down");
        // The survivor kept serving.
        assert!(r.units[1].served > 0);
    }

    #[test]
    fn spare_hot_swaps_a_crashed_unit_back() {
        let units = [ServingUnit::replica(150_000, 8)];
        let plan = FaultPlan::new().crash_at(0.05, 0).recovery(
            crate::fault::RecoveryConfig {
                spares: 1,
                swap_s: 0.002,
                ..Default::default()
            },
        );
        let r = run(&units, TraceSpec::poisson(300.0, 0.3, 6), "round-robin", Some(&plan));
        let f = r.faults.as_ref().unwrap();
        assert_eq!(f.hot_swaps, 1);
        assert_eq!(f.spares_remaining, 0);
        assert!(f.availability > 0.9, "2 ms outage in 300 ms");
        // Frames keep completing after the swap.
        assert!(r.aggregate.completed > 0);
        assert_eq!(
            r.aggregate.offered,
            r.aggregate.completed + r.aggregate.dropped + r.aggregate.failed
        );
    }

    #[test]
    fn slowdown_and_corrupt_are_accounted() {
        let units = [ServingUnit::replica(150_000, 8)];
        let plan = FaultPlan::new()
            .slow_down_at(0.02, 0, 3.0)
            .slow_end_at(0.1, 0)
            .corrupt_at(0.05, 0);
        let r = run(&units, TraceSpec::poisson(200.0, 0.3, 7), "sla-weighted", Some(&plan));
        let f = r.faults.as_ref().unwrap();
        assert_eq!(f.injected_slowdowns, 1);
        assert_eq!(f.injected_corruptions, 1);
        assert_eq!(f.rerun_frames, 1, "one corrupted completion re-ran");
        assert_eq!(
            r.aggregate.offered,
            r.aggregate.completed + r.aggregate.dropped + r.aggregate.failed
        );
    }

    #[test]
    fn two_runs_render_byte_identical_reports() {
        let units = [
            ServingUnit::replica(150_000, 4),
            ServingUnit::pipeline(
                2,
                vec![
                    StageSpec { service_cycles: 80_000, capacity: 4 },
                    StageSpec { service_cycles: 90_000, capacity: 2 },
                ],
            ),
        ];
        let plan = FaultPlan::new().crash_at(0.04, 1).recover_at(0.08, 1);
        let trace = TraceSpec::flash_crowd(100.0, 600.0, 0.1, 0.02, 0.05, 0.3, 8);
        let a = run(&units, trace.clone(), "sla-weighted", Some(&plan));
        let b = run(&units, trace, "sla-weighted", Some(&plan));
        assert_eq!(
            a.to_json().pretty(),
            b.to_json().pretty(),
            "fleet runs must be byte-reproducible"
        );
    }
}
