//! Load-balancer policies: which serving unit an arriving frame joins.
//!
//! The fleet front-end extends the scheduler's [`DispatchPolicy`]
//! pattern one level up: instead of pairing frames with idle workers, a
//! [`BalancerPolicy`] routes each arrival to a whole serving unit
//! (replica or pipeline), which then queues it internally. Policies see
//! non-empty snapshot slices of the *healthy* units in ascending unit
//! order and return a position in the slice — the same contract
//! `DispatchPolicy::pick_worker` uses, and round-robin literally
//! delegates to it.

use crate::coordinator::{DispatchPolicy, RoundRobin, WorkerSnapshot};

/// A healthy serving unit, as seen by a balancer.
#[derive(Debug, Clone, Copy)]
pub struct UnitSnapshot {
    pub unit: usize,
    /// Frames waiting in the unit's entry queue.
    pub queued: usize,
    /// Everything the unit holds: entry queue + all pipeline stages.
    pub outstanding: usize,
    /// Cumulative busy seconds across the unit's boards.
    pub busy_s: f64,
    /// Frames the unit has completed.
    pub served: u64,
    /// Steady-state seconds per frame (the unit's bottleneck cadence) —
    /// what an SLA-aware balancer weighs queue length by.
    pub service_s: f64,
}

impl UnitSnapshot {
    fn as_worker(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            worker: self.unit,
            busy_s: self.busy_s,
            served: self.served,
        }
    }
}

/// Routes each arrival to one healthy serving unit. `pick_unit` receives
/// a non-empty slice and returns a position in it.
pub trait BalancerPolicy: Send {
    fn name(&self) -> &'static str;
    fn pick_unit(&mut self, healthy: &[UnitSnapshot]) -> usize;
}

/// Cycle fairly through units regardless of load — delegates to the
/// scheduler's `RoundRobin::pick_worker`, so skip-over-down-units
/// behavior is identical to worker dispatch.
#[derive(Debug, Default)]
pub struct RoundRobinBalancer {
    inner: RoundRobin,
}

impl BalancerPolicy for RoundRobinBalancer {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick_unit(&mut self, healthy: &[UnitSnapshot]) -> usize {
        let workers: Vec<WorkerSnapshot> = healthy.iter().map(UnitSnapshot::as_worker).collect();
        self.inner.pick_worker(&workers)
    }
}

/// Fewest frames anywhere inside the unit (queue + stages in flight);
/// ties go to the lowest unit index.
#[derive(Debug, Default)]
pub struct LeastOutstanding;

impl BalancerPolicy for LeastOutstanding {
    fn name(&self) -> &'static str {
        "least-outstanding"
    }

    fn pick_unit(&mut self, healthy: &[UnitSnapshot]) -> usize {
        healthy
            .iter()
            .enumerate()
            .min_by_key(|(_, u)| (u.outstanding, u.unit))
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// Classic JSQ: shortest entry queue, ignoring frames already inside the
/// pipeline; ties go to the lowest unit index.
#[derive(Debug, Default)]
pub struct JoinShortestQueue;

impl BalancerPolicy for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "join-shortest-queue"
    }

    fn pick_unit(&mut self, healthy: &[UnitSnapshot]) -> usize {
        healthy
            .iter()
            .enumerate()
            .min_by_key(|(_, u)| (u.queued, u.unit))
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// Deadline-aware: minimize the estimated completion time
/// `(outstanding + 1) · service_s`, so a short queue on a slow pipeline
/// loses to a longer queue on a fast replica; ties go to the lowest
/// unit index.
#[derive(Debug, Default)]
pub struct SlaWeighted;

impl BalancerPolicy for SlaWeighted {
    fn name(&self) -> &'static str {
        "sla-weighted"
    }

    fn pick_unit(&mut self, healthy: &[UnitSnapshot]) -> usize {
        healthy
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                let ea = (a.outstanding as f64 + 1.0) * a.service_s;
                let eb = (b.outstanding as f64 + 1.0) * b.service_s;
                ea.total_cmp(&eb).then(a.unit.cmp(&b.unit))
            })
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// Look up a balancer by CLI name (`round-robin`/`rr`,
/// `least-outstanding`/`lo`, `join-shortest-queue`/`jsq`,
/// `sla-weighted`/`sla`).
pub fn balancer_for(name: &str) -> Option<Box<dyn BalancerPolicy>> {
    match name {
        "round-robin" | "rr" => Some(Box::new(RoundRobinBalancer::default())),
        "least-outstanding" | "lo" => Some(Box::new(LeastOutstanding)),
        "join-shortest-queue" | "jsq" => Some(Box::new(JoinShortestQueue)),
        "sla-weighted" | "sla" => Some(Box::new(SlaWeighted)),
        _ => None,
    }
}

/// The balancer names [`balancer_for`] accepts (canonical spellings).
pub const BALANCER_NAMES: [&str; 4] = [
    "round-robin",
    "least-outstanding",
    "join-shortest-queue",
    "sla-weighted",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(unit: usize, queued: usize, outstanding: usize, service_s: f64) -> UnitSnapshot {
        UnitSnapshot {
            unit,
            queued,
            outstanding,
            busy_s: 0.0,
            served: 0,
            service_s,
        }
    }

    #[test]
    fn round_robin_cycles_units() {
        let mut p = RoundRobinBalancer::default();
        let snaps = [snap(0, 0, 0, 0.01), snap(1, 0, 0, 0.01), snap(2, 0, 0, 0.01)];
        let picks: Vec<usize> = (0..6).map(|_| snaps[p.pick_unit(&snaps)].unit).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_missing_units() {
        let mut p = RoundRobinBalancer::default();
        let all = [snap(0, 0, 0, 0.01), snap(1, 0, 0, 0.01)];
        assert_eq!(p.pick_unit(&all), 0);
        // Unit 1 went down: the survivor keeps serving.
        let up = [snap(0, 0, 0, 0.01)];
        assert_eq!(up[p.pick_unit(&up)].unit, 0);
    }

    #[test]
    fn least_outstanding_counts_in_flight_work() {
        let mut p = LeastOutstanding;
        let snaps = [snap(0, 0, 5, 0.01), snap(1, 2, 2, 0.01)];
        assert_eq!(snaps[p.pick_unit(&snaps)].unit, 1);
    }

    #[test]
    fn jsq_ignores_in_flight_work() {
        let mut p = JoinShortestQueue;
        let snaps = [snap(0, 0, 5, 0.01), snap(1, 2, 2, 0.01)];
        assert_eq!(snaps[p.pick_unit(&snaps)].unit, 0);
    }

    #[test]
    fn sla_weighted_prefers_faster_units() {
        let mut p = SlaWeighted;
        // Unit 0: 3 outstanding × 10 ms = 40 ms estimate. Unit 1: empty
        // but 100 ms per frame = 100 ms estimate.
        let snaps = [snap(0, 3, 3, 0.010), snap(1, 0, 0, 0.100)];
        assert_eq!(snaps[p.pick_unit(&snaps)].unit, 0);
    }

    #[test]
    fn lookup_accepts_all_names_and_aliases() {
        for name in BALANCER_NAMES {
            assert!(balancer_for(name).is_some(), "{name}");
        }
        for alias in ["rr", "lo", "jsq", "sla"] {
            assert!(balancer_for(alias).is_some(), "{alias}");
        }
        assert!(balancer_for("random").is_none());
    }
}
