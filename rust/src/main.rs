//! `vaqf` — command-line entry point for the co-design framework.
//!
//! ```text
//! vaqf compile  --model deit-base --device zcu102 --target-fps 24 [--emit-dir DIR]
//! vaqf search   --model deit-base --device zcu102          # sweep 1..=16 bits
//! vaqf report   --table5 | --table6 [--device zcu102]
//! vaqf codegen  --model deit-base --target-fps 24 --out accel.cpp
//! vaqf simulate --bits 8 --frames 3 [--backend scalar|packed] [--threads N]
//!               [--config target.json]
//! vaqf serve    --variant micro_w1a8 --backend sim|pjrt --fps 30 --frames 90
//!               [--streams N] [--workers W] [--policy round-robin|least-loaded|weighted-sla]
//!               [--clock wall|virtual] [--sla-ms MS] [--analytic] [--realtime]
//!               [--faults plan.json] [--ladder 8,6,4] [--window-len N]
//!               [--down-frac F] [--up-margin F]
//!               [--kernels scalar|packed] [--threads N] [--config target.json]
//! vaqf shard    --model deit-base --device zcu102 --shards 2
//!               [--policy balanced|even|min-latency] [--bits B] [--frames N]
//!               [--fifo-depth F] [--faults plan.json] [--failover spare|repartition]
//!               [--spares N] [--json]
//! vaqf fleet    --model deit-base --device zcu102 --boards 4
//!               [--topology replicated|pipelined|mixed] [--bits B]
//!               [--balancer round-robin|least-outstanding|join-shortest-queue|sla-weighted]
//!               [--trace trace.json | --trace-kind poisson|diurnal|flash-crowd|on-off
//!                --rate-hz R --horizon-s S --trace-seed N [--peak-hz R] [--amplitude-hz R]
//!                [--period-s S] [--at-s S] [--ramp-s S] [--hold-s S] [--on-s S] [--off-s S]]
//!               [--streams N] [--queue-depth D] [--sla-ms MS]
//!               [--shard-policy balanced|even|min-latency]
//!               [--faults plan.json] [--spares N] [--json]
//! vaqf trace    <serve|shard|fleet> --out DIR [run flags as above]
//!               # writes trace.json (Perfetto), metrics.json,
//!               # timeline.txt and folded.txt into DIR
//! ```
//!
//! `serve`, `shard` and `fleet` also take `--metrics-json PATH` (JSON
//! metrics snapshot of the final report) and — `serve --clock virtual` /
//! `fleet` only — `--trace-out PATH` (Perfetto trace of the run).
//! `compile --json` appends a machine-readable summary including the
//! session's design-space-search statistics.
//!
//! Every subcommand is a thin layer over `vaqf::api`: flags feed a
//! `TargetSpec`, which resolves model/device/backend/threads with one
//! precedence rule everywhere — defaults < `--config target.json` <
//! `VAQF_MODEL`/`VAQF_DEVICE`/`VAQF_TARGET_FPS`/`VAQF_BACKEND`/`VAQF_THREADS`
//! < explicit flags. `--backend`/`--kernels scalar|packed` selects the
//! simulator's compute kernels (bit-exact; packed is the fast default) and
//! `--threads` its row-parallel fan-out. See README.md for per-command
//! options and the config-file schema.

use vaqf::api::{
    render_table5, render_table6, table6_rows, FailoverStrategy, FaultPlan, HysteresisConfig,
    MetricsRegistry, PjrtRuntime, Result, ServeClock, ServeConfig, Session, ShardPolicy,
    TargetSpec, TraceConfig, TraceSpec, VaqfError,
};
use vaqf::model::micro;
use vaqf::runtime::Manifest;
use vaqf::shard::{simulate_pipeline, simulate_pipeline_faulty};
use vaqf::util::cli::Args;

/// Flag-parse failures (non-numeric `--fps` etc.) as typed config errors.
fn cli(e: anyhow::Error) -> VaqfError {
    VaqfError::config(e.to_string())
}

fn cli_session(args: &Args, backend_key: &str) -> Result<Session> {
    TargetSpec::from_cli_args(args, backend_key)?.session()
}

fn cmd_compile(args: &Args) -> Result<()> {
    let session = cli_session(args, "backend")?;
    let design = session.compile()?;
    let target = session.target();
    let out = design.outcome().expect("compile() records the search outcome");
    println!(
        "model {} on {} @ target {:.1} FPS",
        target.model.name, target.device.name, target.target_fps
    );
    println!("  FR_max (1-bit activations): {:.1} FPS", out.fr_max);
    for r in &out.rounds {
        println!(
            "  probe {:>2}-bit → {:>6.1} FPS  {}",
            r.bits,
            r.fps,
            if r.feasible { "meets target" } else { "too slow" }
        );
    }
    let s = design.summary();
    println!(
        "chosen precision: W1A{} — {:.1} FPS, {:.1} GOPS, {:.1} W, \
         DSP {} LUT {} BRAM36 {:.1}",
        out.act_bits,
        s.fps,
        s.gops,
        s.power_w,
        s.utilization.dsp,
        s.utilization.lut,
        s.utilization.bram18k as f64 / 2.0
    );
    let p = design.params();
    println!(
        "  params: T_m={} T_n={} T_m^q={} T_n^q={} G={} G^q={} P_h={} ({} adjustments)",
        p.t_m,
        p.t_n,
        p.t_m_q,
        p.t_n_q,
        p.g,
        p.g_q,
        p.p_h,
        design.design_point().adjustments
    );
    println!("  compilation step: {:.3}s", out.compile_seconds);

    if let Some(dir) = args.get("emit-dir") {
        let art = design.codegen(dir)?;
        println!("  emitted {}.cpp and {}.json", art.base, art.base);
    }
    if args.has_flag("json") {
        let j = vaqf::util::json::Json::obj()
            .set("model", target.model.name.as_str())
            .set("device", target.device.name.as_str())
            .set("target_fps", target.target_fps)
            .set("act_bits", u64::from(out.act_bits))
            .set("fr_max", out.fr_max)
            .set("fps", s.fps)
            .set("gops", s.gops)
            .set("power_w", s.power_w)
            .set("compile_seconds", out.compile_seconds)
            .set("search", session.search_stats().to_json());
        println!("{}", j.pretty());
    }
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let session = cli_session(args, "backend")?;
    let target = session.target();
    let sweep = session.sweep(1..=16);
    println!(
        "{} on {} — baseline W16A16: {:.1} FPS ({} DSP)",
        target.model.name, target.device.name, sweep.baseline.fps, sweep.baseline.utilization.dsp
    );
    println!(
        "{:>4} {:>8} {:>9} {:>8} {:>7} {:>7}",
        "bits", "FPS", "GOPS", "power W", "DSP", "kLUT"
    );
    for point in &sweep.points {
        match &point.design {
            Ok(d) => println!(
                "{:>4} {:>8.1} {:>9.1} {:>8.1} {:>7} {:>7.0}",
                point.bits,
                d.summary.fps,
                d.summary.gops,
                d.summary.power_w,
                d.summary.utilization.dsp,
                d.summary.utilization.lut as f64 / 1000.0
            ),
            Err(e) => println!("{:>4} infeasible: {e}", point.bits),
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let session = cli_session(args, "backend")?;
    let rows = session.table5(&[8, 6])?;
    if args.has_flag("table6") {
        println!("{}", render_table6(&table6_rows(&rows)));
    } else {
        println!("{}", render_table5(&rows, &session.target().device));
    }
    Ok(())
}

fn cmd_codegen(args: &Args) -> Result<()> {
    let session = cli_session(args, "backend")?;
    let design = session.compile()?;
    let cpp = design.hls_source();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, cpp).map_err(|e| VaqfError::io(path.to_string(), e))?;
            println!("wrote {path}");
        }
        None => println!("{cpp}"),
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    // Resolution: defaults (micro model on zcu102) < --config file <
    // VAQF_* env < explicit flags — see `vaqf::api::TargetSpec`.
    let session = TargetSpec::from_cli_args(args, "backend")?
        .default_model(micro())
        .session()?;
    let bits = args.get_u64("bits").map_err(cli)?.map(|b| b as u8);
    let frames = args.get_u64("frames").map_err(cli)?.unwrap_or(3);
    let seed = args.get_u64("seed").map_err(cli)?.unwrap_or(11);

    // The simulator runs the *compiled* design for the resolved target —
    // optimized tiling, not hardcoded micro parameters.
    let design = session.compile_for_bits(bits)?;
    let mut exec = design.simulator_with_seed(seed);
    for i in 0..frames {
        let patches = exec.weights().synthetic_patches(i);
        let (logits, trace) = exec.run_frame(&patches);
        let top = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!(
            "frame {i}: class {top}  {} cycles  {:.2} ms simulated  ({:.1} sim-FPS)",
            trace.total_cycles,
            trace.latency_s * 1e3,
            trace.fps()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let variant = args.get_or("variant", "micro_w1a8");
    let backend_kind = args.get_or("backend", "sim");
    let offered_fps = args.get_f64("fps").map_err(cli)?.unwrap_or(30.0);
    let frames = args.get_u64("frames").map_err(cli)?.unwrap_or(90);
    let queue_depth = args.get_u64("queue-depth").map_err(cli)?.unwrap_or(2) as usize;
    let source_seed = args.get_u64("seed").map_err(cli)?.unwrap_or(11);
    let streams = args.get_u64("streams").map_err(cli)?.unwrap_or(1) as usize;
    let workers = args.get_u64("workers").map_err(cli)?.unwrap_or(1) as usize;
    let policy = args.get_or("policy", "round-robin");
    let clock = match args.get_or("clock", "wall") {
        "wall" => ServeClock::Wall,
        "virtual" => ServeClock::Virtual,
        other => {
            return Err(VaqfError::config(format!(
                "unknown clock {other} (wall|virtual)"
            )))
        }
    };
    let sla_ms = args.get_f64("sla-ms").map_err(cli)?;

    match backend_kind {
        "sim" => {
            let man = Manifest::load(artifacts).map_err(VaqfError::manifest)?;
            let entry = man.find(variant).ok_or_else(|| {
                VaqfError::manifest(anyhow::anyhow!("variant {variant} not in manifest"))
            })?;
            // `--config target.json` (device/backend/threads/model) is
            // honored here exactly like `simulate`: the manifest variant
            // only supplies the fallback model and the artifact's weight
            // seed / precision.
            let session = TargetSpec::from_cli_args(args, "kernels")?
                .default_model(entry.config.clone())
                .session()?;
            // A config-file/env/flag model override is honored, but a
            // silent swap under the variant's label would be a trap.
            if session.target().model != entry.config {
                eprintln!(
                    "note: serving model `{}` (config/env/flag override) instead of \
                     variant {variant}'s `{}`",
                    session.target().model.name,
                    entry.config.name
                );
            }
            let design = session.compile_for_bits(entry.act_bits_opt())?;
            let mut builder = design
                .server()
                .streams(streams)
                .workers(workers)
                .policy(policy)
                .offered_fps(offered_fps)
                .frames(frames)
                .queue_depth(queue_depth)
                .clock(clock)
                .source_seed(source_seed)
                .weights_seed(entry.seed);
            if let Some(ms) = sla_ms {
                builder = builder.sla_ms(ms);
            }
            if let Some(path) = args.get("faults") {
                builder = builder.faults(FaultPlan::load(path).map_err(cli)?);
            }
            if let Some(spec) = args.get("ladder") {
                // `--ladder 8,6,4`: activation precisions, the serving
                // precision first (rung 0).
                let bits = spec
                    .split(',')
                    .map(|t| t.trim().parse::<u8>())
                    .collect::<std::result::Result<Vec<u8>, _>>()
                    .map_err(|_| {
                        VaqfError::config(format!(
                            "--ladder expects comma-separated bit widths, got `{spec}`"
                        ))
                    })?;
                builder = builder.degrade_ladder(session.precision_ladder(&bits)?);
                let mut h = HysteresisConfig::default();
                if let Some(w) = args.get_u64("window-len").map_err(cli)? {
                    h.window_len = w as usize;
                }
                if let Some(f) = args.get_f64("down-frac").map_err(cli)? {
                    h.down_frac = f;
                }
                if let Some(m) = args.get_f64("up-margin").map_err(cli)? {
                    h.up_margin = m;
                }
                builder = builder.hysteresis(h);
            }
            builder = if args.has_flag("analytic") {
                builder.analytic()
            } else {
                builder.simulated(args.has_flag("realtime"))
            };
            if let Some(path) = args.get("metrics-json") {
                builder = builder.metrics_json(path);
            }
            if let Some(path) = args.get("trace-out") {
                builder = builder.trace(path);
            }
            let report = builder.run()?;
            println!("{}", report.render());
            if args.has_flag("json") {
                println!("{}", report.to_json().pretty());
            }
        }
        "pjrt" => {
            // The PJRT backend executes the AOT artifact directly — no
            // design-space optimization, and the thread-affine client
            // keeps this path single-stream. Reject scheduler flags
            // instead of silently ignoring them.
            let scheduler_only = streams > 1
                || workers > 1
                || args.get("policy").is_some()
                || args.get("clock").is_some()
                || sla_ms.is_some()
                || args.get("faults").is_some()
                || args.get("ladder").is_some()
                || args.has_flag("analytic");
            if scheduler_only {
                return Err(VaqfError::config(
                    "pjrt serving is single-stream/single-worker; \
                     --streams/--workers/--policy/--clock/--sla-ms/--faults/--ladder/\
                     --analytic apply to --backend sim",
                ));
            }
            let runtime = PjrtRuntime::load_variant(artifacts, variant)?;
            let report = runtime.server(
                variant,
                &ServeConfig {
                    offered_fps,
                    frames,
                    queue_depth,
                    source_seed,
                },
            )?;
            println!("{}", report.render());
            if args.has_flag("json") {
                println!("{}", report.to_json().pretty());
            }
        }
        other => return Err(VaqfError::config(format!("unknown backend {other} (sim|pjrt)"))),
    }
    Ok(())
}

/// `vaqf shard` — partition the compiled design across N accelerator
/// instances, co-search each stage, and run the discrete-event pipeline
/// simulation on the virtual clock.
fn cmd_shard(args: &Args) -> Result<()> {
    let session = cli_session(args, "backend")?;
    let shards = args.get_u64("shards").map_err(cli)?.unwrap_or(2) as usize;
    let policy_name = args.get_or("policy", "balanced");
    let policy = ShardPolicy::from_name(policy_name).ok_or_else(|| {
        VaqfError::config(format!(
            "unknown shard policy {policy_name} (expected {})",
            ShardPolicy::NAMES
        ))
    })?;
    let frames = args.get_u64("frames").map_err(cli)?.unwrap_or(240);
    if frames == 0 {
        return Err(VaqfError::config("--frames must be at least 1"));
    }
    let fifo_depth = args.get_u64("fifo-depth").map_err(cli)?;
    let bits = args.get_u64("bits").map_err(cli)?.map(|b| b as u8);

    // `--bits` pins the precision; otherwise the §3 frame-rate search
    // picks it, exactly like `vaqf compile`.
    let design = match bits {
        Some(b) => session.compile_for_bits(Some(b))?,
        None => session.compile()?,
    };
    let sharded = design.shards_with(shards, policy)?;
    let pipeline = match args.get("faults") {
        Some(path) => {
            let mut plan = FaultPlan::load(path).map_err(cli)?;
            if let Some(n) = args.get_u64("spares").map_err(cli)? {
                plan.recovery.spares = n as usize;
            }
            let failover_name = args.get_or("failover", "spare");
            let strategy = FailoverStrategy::parse(failover_name).ok_or_else(|| {
                VaqfError::config(format!(
                    "unknown failover strategy {failover_name} (spare|repartition)"
                ))
            })?;
            simulate_pipeline_faulty(&sharded, frames, fifo_depth, &plan, strategy)
                .map_err(VaqfError::runtime)?
        }
        None => simulate_pipeline(&sharded, frames, fifo_depth),
    };
    let report = vaqf::shard::ShardReport {
        pipeline,
        design: sharded,
    };
    print!("{}", report.render());
    if let Some(path) = args.get("metrics-json") {
        let mut reg = MetricsRegistry::new();
        reg.publish_pipeline(&report.pipeline);
        std::fs::write(path, reg.to_json().pretty())
            .map_err(|e| VaqfError::io(path.to_string(), e))?;
    }
    if args.has_flag("json") {
        println!("{}", report.to_json().pretty());
    }
    Ok(())
}

/// The `--trace` / `--trace-kind` arrival-trace flags shared by
/// `vaqf fleet` and `vaqf trace fleet`: a recorded trace file, or a
/// seeded generator (poisson/diurnal/flash-crowd/on-off). `None` when
/// neither is given (callers fall back to their default load).
fn parse_trace_spec(args: &Args) -> Result<Option<TraceSpec>> {
    if let Some(path) = args.get("trace") {
        return Ok(Some(TraceSpec::load(path).map_err(cli)?));
    }
    if args.get("trace-kind").is_none() && args.get("rate-hz").is_none() {
        return Ok(None);
    }
    let horizon = args.get_f64("horizon-s").map_err(cli)?.unwrap_or(1.0);
    let seed = args.get_u64("trace-seed").map_err(cli)?.unwrap_or(11);
    let rate = args.get_f64("rate-hz").map_err(cli)?.unwrap_or(30.0);
    // Unset shape parameters default to fractions of the horizon, so
    // `--trace-kind flash-crowd --rate-hz 100` alone is a valid burst.
    let spec = match args.get_or("trace-kind", "poisson") {
        "poisson" => TraceSpec::poisson(rate, horizon, seed),
        "diurnal" => TraceSpec::diurnal(
            rate,
            args.get_f64("amplitude-hz").map_err(cli)?.unwrap_or(0.5 * rate),
            args.get_f64("period-s").map_err(cli)?.unwrap_or(horizon),
            horizon,
            seed,
        ),
        "flash-crowd" => TraceSpec::flash_crowd(
            rate,
            args.get_f64("peak-hz").map_err(cli)?.unwrap_or(4.0 * rate),
            args.get_f64("at-s").map_err(cli)?.unwrap_or(0.3 * horizon),
            args.get_f64("ramp-s").map_err(cli)?.unwrap_or(0.05 * horizon),
            args.get_f64("hold-s").map_err(cli)?.unwrap_or(0.2 * horizon),
            horizon,
            seed,
        ),
        "on-off" => TraceSpec::on_off(
            rate,
            args.get_f64("on-s").map_err(cli)?.unwrap_or(0.1 * horizon),
            args.get_f64("off-s").map_err(cli)?.unwrap_or(0.1 * horizon),
            horizon,
            seed,
        ),
        other => {
            return Err(VaqfError::config(format!(
                "unknown trace kind `{other}` (poisson|diurnal|flash-crowd|on-off)"
            )))
        }
    };
    Ok(Some(spec))
}

/// `vaqf fleet` — carve a board budget into replica / pipeline serving
/// units, front them with a load balancer, and replay a recorded or
/// generated arrival trace through the fleet on one virtual clock.
fn cmd_fleet(args: &Args) -> Result<()> {
    let session = cli_session(args, "backend")?;
    let bits = args.get_u64("bits").map_err(cli)?.map(|b| b as u8);
    // `--bits` pins the precision; otherwise the §3 frame-rate search
    // picks it, exactly like `vaqf compile` and `vaqf shard`.
    let design = match bits {
        Some(b) => session.compile_for_bits(Some(b))?,
        None => session.compile()?,
    };
    let mut builder = design
        .fleet()
        .boards(args.get_u64("boards").map_err(cli)?.unwrap_or(4) as usize)
        .topology(args.get_or("topology", "replicated"))
        .balancer(args.get_or("balancer", "round-robin"))
        .streams(args.get_u64("streams").map_err(cli)?.unwrap_or(1) as usize)
        .queue_depth(args.get_u64("queue-depth").map_err(cli)?.unwrap_or(2) as usize)
        .seed(args.get_u64("seed").map_err(cli)?.unwrap_or(11));
    if let Some(ms) = args.get_f64("sla-ms").map_err(cli)? {
        builder = builder.sla_ms(ms);
    }
    if let Some(name) = args.get("shard-policy") {
        let policy = ShardPolicy::from_name(name).ok_or_else(|| {
            VaqfError::config(format!(
                "unknown shard policy {name} (expected {})",
                ShardPolicy::NAMES
            ))
        })?;
        builder = builder.shard_policy(policy);
    }
    if let Some(spec) = parse_trace_spec(args)? {
        builder = builder.trace(spec);
    }
    if let Some(path) = args.get("faults") {
        let mut plan = FaultPlan::load(path).map_err(cli)?;
        if let Some(n) = args.get_u64("spares").map_err(cli)? {
            plan.recovery.spares = n as usize;
        }
        builder = builder.faults(plan);
    }
    if let Some(path) = args.get("metrics-json") {
        builder = builder.metrics_json(path);
    }
    if let Some(path) = args.get("trace-out") {
        builder = builder.trace_out(path);
    }
    let report = builder.run()?;
    print!("{}", report.render());
    if args.has_flag("json") {
        println!("{}", report.to_json().pretty());
    }
    Ok(())
}

/// `vaqf trace <serve|shard|fleet>` — run one deterministic
/// virtual-clock scenario and dump its observability artifacts into
/// `--out DIR`: `trace.json` (Chrome/Perfetto `trace_event`),
/// `metrics.json` (counters/gauges/histograms), `timeline.txt` (plain
/// text, golden-friendly) and `folded.txt` (flamegraph folded stacks).
/// Every knob is seeded and simulated, so two identical invocations
/// write byte-identical artifacts — CI diffs them.
fn cmd_trace(args: &Args) -> Result<()> {
    let what = args.positional.get(1).map(|s| s.as_str()).unwrap_or("fleet");
    let out = args.get_or("out", "trace-out");
    std::fs::create_dir_all(out).map_err(|e| VaqfError::io(out.to_string(), e))?;
    let session = TargetSpec::from_cli_args(args, "backend")?
        .default_model(micro())
        .session()?;
    let bits = args.get_u64("bits").map_err(cli)?.map(|b| b as u8);
    let design = match bits {
        Some(b) => session.compile_for_bits(Some(b))?,
        None => session.compile()?,
    };
    let frames = args.get_u64("frames").map_err(cli)?.unwrap_or(120);
    let faults = match args.get("faults") {
        Some(path) => Some(FaultPlan::load(path).map_err(cli)?),
        None => None,
    };
    // Full layer detail multiplies the event count by the layer count;
    // sample it down by default, the CLI is for whole-run timelines.
    let cfg = TraceConfig {
        layer_detail_every: args.get_u64("layer-detail-every").map_err(cli)?.unwrap_or(8),
        ..TraceConfig::default()
    };

    let (trace, reg, rendered) = match what {
        "serve" => {
            let mut b = design
                .server()
                .virtual_clock()
                .analytic()
                .streams(args.get_u64("streams").map_err(cli)?.unwrap_or(2) as usize)
                .workers(args.get_u64("workers").map_err(cli)?.unwrap_or(2) as usize)
                .policy(args.get_or("policy", "round-robin"))
                .offered_fps(args.get_f64("fps").map_err(cli)?.unwrap_or(30.0))
                .frames(frames)
                .queue_depth(args.get_u64("queue-depth").map_err(cli)?.unwrap_or(2) as usize)
                .source_seed(args.get_u64("seed").map_err(cli)?.unwrap_or(11))
                .trace_config(cfg);
            if let Some(ms) = args.get_f64("sla-ms").map_err(cli)? {
                b = b.sla_ms(ms);
            }
            if let Some(plan) = faults {
                b = b.faults(plan);
            }
            let (report, trace) = b.run_traced()?;
            let mut reg = MetricsRegistry::new();
            reg.publish_serving(&report);
            (trace, reg, report.render())
        }
        "shard" => {
            let shards = args.get_u64("shards").map_err(cli)?.unwrap_or(2) as usize;
            let sharded = design.shards(shards)?;
            let (pipeline, trace) = sharded.simulate_pipeline_with_trace(frames, cfg);
            let mut reg = MetricsRegistry::new();
            reg.publish_pipeline(&pipeline);
            let report = vaqf::shard::ShardReport {
                pipeline,
                design: sharded,
            };
            (trace, reg, report.render())
        }
        "fleet" => {
            let mut b = design
                .fleet()
                .boards(args.get_u64("boards").map_err(cli)?.unwrap_or(4) as usize)
                .topology(args.get_or("topology", "replicated"))
                .balancer(args.get_or("balancer", "round-robin"))
                .streams(args.get_u64("streams").map_err(cli)?.unwrap_or(1) as usize)
                .queue_depth(args.get_u64("queue-depth").map_err(cli)?.unwrap_or(2) as usize)
                .seed(args.get_u64("seed").map_err(cli)?.unwrap_or(11))
                .trace_config(cfg);
            if let Some(ms) = args.get_f64("sla-ms").map_err(cli)? {
                b = b.sla_ms(ms);
            }
            if let Some(spec) = parse_trace_spec(args)? {
                b = b.trace(spec);
            }
            if let Some(plan) = faults {
                b = b.faults(plan);
            }
            let (report, trace) = b.run_traced()?;
            let mut reg = MetricsRegistry::new();
            reg.publish_fleet(&report);
            (trace, reg, report.render())
        }
        other => {
            return Err(VaqfError::config(format!(
                "unknown trace mode `{other}` (serve|shard|fleet)"
            )))
        }
    };
    print!("{rendered}");
    let path = |name: &str| format!("{out}/{name}");
    trace.save_perfetto(path("trace.json")).map_err(VaqfError::runtime)?;
    trace.save_timeline(path("timeline.txt")).map_err(VaqfError::runtime)?;
    trace.save_folded(path("folded.txt")).map_err(VaqfError::runtime)?;
    std::fs::write(path("metrics.json"), reg.to_json().pretty())
        .map_err(|e| VaqfError::io(path("metrics.json"), e))?;
    println!(
        "wrote {out}/{{trace.json,metrics.json,timeline.txt,folded.txt}} — \
         {n} events on {t} tracks ({e} evicted)",
        n = trace.len(),
        t = trace.tracks.len(),
        e = trace.evicted,
    );
    Ok(())
}

const USAGE: &str = "usage: vaqf <compile|search|report|codegen|simulate|serve|shard|fleet|trace> [--options]
see README.md for per-command options";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "compile" => cmd_compile(&args),
        "search" => cmd_search(&args),
        "report" => cmd_report(&args),
        "codegen" => cmd_codegen(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "shard" => cmd_shard(&args),
        "fleet" => cmd_fleet(&args),
        "trace" => cmd_trace(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
