//! `vaqf` — command-line entry point for the co-design framework.
//!
//! ```text
//! vaqf compile  --model deit-base --device zcu102 --target-fps 24 [--emit-dir DIR]
//! vaqf search   --model deit-base --device zcu102          # sweep 1..=16 bits
//! vaqf report   --table5 | --table6 [--device zcu102]
//! vaqf codegen  --model deit-base --target-fps 24 --out accel.cpp
//! vaqf simulate --bits 8 --frames 3 [--backend scalar|packed] [--threads N]
//!               [--config target.json]
//! vaqf serve    --variant micro_w1a8 --backend sim|pjrt --fps 30 --frames 90
//!               [--kernels scalar|packed] [--threads N]
//! ```
//!
//! `--backend`/`--kernels scalar|packed` selects the simulator's compute
//! kernels (bit-exact; packed is the fast default) and `--threads` its
//! row-parallel fan-out — both also settable via `VAQF_BACKEND` /
//! `VAQF_THREADS`, or for `simulate` via `--config target.json`
//! (`config::Target`'s `backend`/`threads`/`model`/`device` fields).

use vaqf::compiler::{
    compile, emit_config_json, emit_hls_cpp, optimize_baseline, optimize_for_bits, render_table5,
    render_table6, table5_rows, table6_rows, CompileRequest,
};
use vaqf::coordinator::{serve, FrameSource, ServeConfig};
use vaqf::hw::DevicePreset;
use vaqf::model::{VitConfig, VitPreset};
use vaqf::perf::AcceleratorParams;
use vaqf::runtime::{InferenceBackend, InferenceEngine, Manifest, PjrtBackend, SimBackend};
use vaqf::sim::{generate_weights, Backend, ModelExecutor};
use vaqf::util::cli::Args;

fn model_arg(args: &Args) -> anyhow::Result<VitConfig> {
    let name = args.get_or("model", "deit-base");
    VitPreset::from_name(name)
        .map(|p| p.config())
        .ok_or_else(|| anyhow::anyhow!("unknown model `{name}` (deit-tiny/small/base)"))
}

fn device_arg(args: &Args) -> anyhow::Result<vaqf::hw::Device> {
    let name = args.get_or("device", "zcu102");
    DevicePreset::from_name(name)
        .map(|p| p.device())
        .ok_or_else(|| anyhow::anyhow!("unknown device `{name}` (zcu102/zcu111/generic-edge)"))
}

fn cmd_compile(args: &Args) -> anyhow::Result<()> {
    let req = CompileRequest {
        model: model_arg(args)?,
        device: device_arg(args)?,
        target_fps: args.get_f64("target-fps")?.unwrap_or(24.0),
    };
    let out = compile(&req)?;
    println!(
        "model {} on {} @ target {:.1} FPS",
        req.model.name, req.device.name, req.target_fps
    );
    println!("  FR_max (1-bit activations): {:.1} FPS", out.fr_max);
    for r in &out.rounds {
        println!(
            "  probe {:>2}-bit → {:>6.1} FPS  {}",
            r.bits,
            r.fps,
            if r.feasible { "meets target" } else { "too slow" }
        );
    }
    let s = &out.design.summary;
    println!(
        "chosen precision: W1A{} — {:.1} FPS, {:.1} GOPS, {:.1} W, \
         DSP {} LUT {} BRAM36 {:.1}",
        out.act_bits,
        s.fps,
        s.gops,
        s.power_w,
        s.utilization.dsp,
        s.utilization.lut,
        s.utilization.bram18k as f64 / 2.0
    );
    println!(
        "  params: T_m={} T_n={} T_m^q={} T_n^q={} G={} G^q={} P_h={} ({} adjustments)",
        out.design.params.t_m,
        out.design.params.t_n,
        out.design.params.t_m_q,
        out.design.params.t_n_q,
        out.design.params.g,
        out.design.params.g_q,
        out.design.params.p_h,
        out.design.adjustments
    );
    println!("  compilation step: {:.3}s", out.compile_seconds);

    if let Some(dir) = args.get("emit-dir") {
        std::fs::create_dir_all(dir)?;
        let structure = req.model.structure(Some(out.act_bits));
        let cpp = emit_hls_cpp(&out, &structure, &req.device);
        let json = emit_config_json(&out, &req.device).pretty();
        let base = format!("{}/{}_w1a{}", dir, req.model.name, out.act_bits);
        std::fs::write(format!("{base}.cpp"), cpp)?;
        std::fs::write(format!("{base}.json"), json)?;
        println!("  emitted {base}.cpp and {base}.json");
    }
    Ok(())
}

fn cmd_search(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let device = device_arg(args)?;
    let base = optimize_baseline(&model.structure(None), &device);
    let bs = vaqf::perf::summarize(&model.structure(None), &base, &device);
    println!(
        "{} on {} — baseline W16A16: {:.1} FPS ({} DSP)",
        model.name, device.name, bs.fps, bs.utilization.dsp
    );
    println!(
        "{:>4} {:>8} {:>9} {:>8} {:>7} {:>7}",
        "bits", "FPS", "GOPS", "power W", "DSP", "kLUT"
    );
    for bits in 1..=16u8 {
        match optimize_for_bits(&model.structure(Some(bits)), &base, &device, bits) {
            Ok(d) => println!(
                "{:>4} {:>8.1} {:>9.1} {:>8.1} {:>7} {:>7.0}",
                bits,
                d.summary.fps,
                d.summary.gops,
                d.summary.power_w,
                d.summary.utilization.dsp,
                d.summary.utilization.lut as f64 / 1000.0
            ),
            Err(e) => println!("{bits:>4} infeasible: {e}"),
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let model = model_arg(args)?;
    let device = device_arg(args)?;
    let rows = table5_rows(&model, &device, &[8, 6]);
    if args.has_flag("table6") {
        println!("{}", render_table6(&table6_rows(&rows)));
    } else {
        println!("{}", render_table5(&rows, &device));
    }
    Ok(())
}

fn cmd_codegen(args: &Args) -> anyhow::Result<()> {
    let req = CompileRequest {
        model: model_arg(args)?,
        device: device_arg(args)?,
        target_fps: args.get_f64("target-fps")?.unwrap_or(24.0),
    };
    let out = compile(&req)?;
    let structure = req.model.structure(Some(out.act_bits));
    let cpp = emit_hls_cpp(&out, &structure, &req.device);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, cpp)?;
            println!("wrote {path}");
        }
        None => println!("{cpp}"),
    }
    Ok(())
}

fn micro_config() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 2,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

fn micro_params(bits: Option<u8>, device: &vaqf::hw::Device) -> AcceleratorParams {
    match bits {
        None => AcceleratorParams::baseline(16, 2, 4, 4),
        Some(b) => {
            let g_q = AcceleratorParams::g_q_for(device.axi_port_bits, b);
            AcceleratorParams {
                t_m: 16,
                t_n: 2,
                t_m_q: 16,
                t_n_q: (2 * g_q / 4).max(1),
                g: 4,
                g_q,
                p_h: 4,
                act_bits: Some(b),
            }
        }
    }
}

/// Parse the simulator kernel options: backend under `key` plus
/// `--threads` (0 ⇒ environment default).
fn kernel_opts(args: &Args, key: &str) -> anyhow::Result<(Option<Backend>, usize)> {
    let backend = args
        .get(key)
        .map(|name| {
            Backend::from_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown kernel backend `{name}` (scalar|packed)"))
        })
        .transpose()?;
    let threads = args.get_u64("threads")?.unwrap_or(0) as usize;
    Ok((backend, threads))
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    // `--config target.json` supplies model/device/backend/threads
    // (config::Target); explicit CLI flags override its fields.
    let target = args.get("config").map(vaqf::config::load_target).transpose()?;
    let device = match (&target, args.get("device")) {
        (Some(t), None) => t.device.clone(),
        _ => device_arg(args)?,
    };
    let cfg = match &target {
        Some(t) => t.model.clone(),
        None => micro_config(),
    };
    let bits = args.get_u64("bits")?.map(|b| b as u8);
    let frames = args.get_u64("frames")?.unwrap_or(3);
    let (mut backend, mut threads) = kernel_opts(args, "backend")?;
    if let Some(t) = &target {
        if backend.is_none() {
            backend = Some(t.backend);
        }
        if threads == 0 {
            threads = t.threads;
        }
    }
    let weights = generate_weights(&cfg, args.get_u64("seed")?.unwrap_or(11));
    let mut exec =
        ModelExecutor::new(weights.clone(), bits, micro_params(bits, &device), device)
            .with_threads(threads);
    if let Some(b) = backend {
        exec = exec.with_backend(b);
    }
    for i in 0..frames {
        let patches = weights.synthetic_patches(i);
        let (logits, trace) = exec.run_frame(&patches);
        let top = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!(
            "frame {i}: class {top}  {} cycles  {:.2} ms simulated  ({:.1} sim-FPS)",
            trace.total_cycles,
            trace.latency_s * 1e3,
            trace.fps()
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let variant = args.get_or("variant", "micro_w1a8");
    let backend_kind = args.get_or("backend", "sim");
    let cfg = ServeConfig {
        offered_fps: args.get_f64("fps")?.unwrap_or(30.0),
        frames: args.get_u64("frames")?.unwrap_or(90),
        queue_depth: args.get_u64("queue-depth")?.unwrap_or(2) as usize,
        source_seed: args.get_u64("seed")?.unwrap_or(11),
    };
    let device = device_arg(args)?;

    let man = Manifest::load(artifacts)?;
    let entry = man
        .find(variant)
        .ok_or_else(|| anyhow::anyhow!("variant {variant} not in manifest"))?;
    let source = FrameSource::new(entry.config.clone(), cfg.source_seed, Some(cfg.offered_fps));

    let backend: Box<dyn InferenceBackend> = match backend_kind {
        "pjrt" => {
            let mut engine = InferenceEngine::new()?;
            engine.load_variant(entry)?;
            Box::new(PjrtBackend {
                engine: std::rc::Rc::new(engine),
                tag: variant.to_string(),
            })
        }
        "sim" => {
            let weights = generate_weights(&entry.config, entry.seed);
            let params = micro_params(entry.act_bits_opt(), &device);
            let (kernels, threads) = kernel_opts(args, "kernels")?;
            let mut executor =
                ModelExecutor::new(weights, entry.act_bits_opt(), params, device)
                    .with_threads(threads);
            if let Some(b) = kernels {
                executor = executor.with_backend(b);
            }
            Box::new(SimBackend {
                executor,
                realtime: args.has_flag("realtime"),
            })
        }
        other => anyhow::bail!("unknown backend {other} (sim|pjrt)"),
    };

    let report = serve(source, backend, &cfg)?;
    println!("{}", report.render());
    if args.has_flag("json") {
        println!("{}", report.to_json().pretty());
    }
    Ok(())
}

const USAGE: &str = "usage: vaqf <compile|search|report|codegen|simulate|serve> [--options]
see README.md for per-command options";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "compile" => cmd_compile(&args),
        "search" => cmd_search(&args),
        "report" => cmd_report(&args),
        "codegen" => cmd_codegen(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
