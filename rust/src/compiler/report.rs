//! Table 5 / Table 6 report generation.
//!
//! Renders the paper's evaluation tables from *our* compiled designs, with
//! the paper's published numbers alongside for comparison. The CPU/GPU/BERT
//! rows of Table 6 are closed-testbed constants quoted from the paper
//! (DESIGN.md §Substitutions).

use crate::hw::Device;
use crate::model::VitConfig;
use crate::perf::PerfSummary;

use super::baseline::optimize_baseline;
use super::params::optimize_for_bits;

/// Paper Table 5 published reference values (DeiT-base on ZCU102).
pub const PAPER_TABLE5: [(&str, f64, f64); 3] = [
    // (precision, FPS, GOPS)
    ("W32A32", 10.0, 345.8),
    ("W1A8", 24.8, 861.2),
    ("W1A6", 31.6, 1096.0),
];

/// Compute the Table 5 rows: the baseline design plus one design per
/// requested activation precision.
pub fn table5_rows(model: &VitConfig, device: &Device, precisions: &[u8]) -> Vec<PerfSummary> {
    let baseline = optimize_baseline(&model.structure(None), device);
    table5_rows_with_baseline(model, device, &baseline, precisions)
        .expect("standard precisions must be feasible on the paper's board")
}

/// Fallible [`table5_rows`] core with a precomputed baseline — the
/// `api::Session` path, where the device is arbitrary (infeasible
/// precisions error instead of panicking) and the baseline is cached.
pub fn table5_rows_with_baseline(
    model: &VitConfig,
    device: &Device,
    baseline: &crate::perf::AcceleratorParams,
    precisions: &[u8],
) -> anyhow::Result<Vec<PerfSummary>> {
    let unquant = model.structure(None);
    let mut rows = vec![crate::perf::summarize(&unquant, baseline, device)];
    for &bits in precisions {
        let s = model.structure(Some(bits));
        rows.push(optimize_for_bits(&s, baseline, device, bits)?.summary);
    }
    Ok(rows)
}

/// [`table5_rows_with_baseline`] through a [`super::SearchCtx`] — the
/// `api::Session::table5` path, where the per-precision designs land in
/// (and are served from) the session's search memos.
pub fn table5_rows_with_baseline_ctx(
    model: &VitConfig,
    device: &Device,
    baseline: &crate::perf::AcceleratorParams,
    precisions: &[u8],
    ctx: &super::SearchCtx,
) -> anyhow::Result<Vec<PerfSummary>> {
    let unquant = model.structure(None);
    let mut rows = vec![crate::perf::summarize(&unquant, baseline, device)];
    for &bits in precisions {
        let s = model.structure(Some(bits));
        rows.push(ctx.optimize_for_bits(&s, baseline, device, bits)?.summary);
    }
    Ok(rows)
}

/// Render Table 5 ("Hardware resource utilization and performance of ViT
/// accelerators with different frame rates and precisions").
pub fn render_table5(rows: &[PerfSummary], device: &Device) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Table 5 — {} accelerators on {} (paper values in parentheses)\n",
        rows.first().map(|r| r.model.as_str()).unwrap_or("?"),
        device.name
    ));
    out.push_str(
        "Precision |   DSP        |  kLUT       | BRAM36      |  kFF      |   FPS  | GOPS   | GOPS/DSP | GOPS/kLUT\n",
    );
    out.push_str(&"-".repeat(112));
    out.push('\n');
    for r in rows {
        let paper = PAPER_TABLE5.iter().find(|(l, _, _)| *l == r.label);
        let fps_note = paper
            .map(|(_, f, _)| format!(" ({f:.1})"))
            .unwrap_or_default();
        let gops_note = paper
            .map(|(_, _, g)| format!(" ({g:.0})"))
            .unwrap_or_default();
        out.push_str(&format!(
            "{:<9} | {:>4} ({:>2.0}%)  | {:>4.0} ({:>2.0}%) | {:>4.1} ({:>2.0}%) | {:>3.0} ({:>2.0}%) | {:>5.1}{fps_note} | {:>6.1}{gops_note} | {:>8.3} | {:>8.3}\n",
            r.label,
            r.utilization.dsp,
            r.utilization_pct.dsp,
            r.utilization.lut as f64 / 1000.0,
            r.utilization_pct.lut,
            r.utilization.bram18k as f64 / 2.0, // report as BRAM36 like the paper
            r.utilization_pct.bram18k,
            r.utilization.ff as f64 / 1000.0,
            r.utilization_pct.ff,
            r.fps,
            r.gops,
            r.gops_per_dsp,
            r.gops_per_klut,
        ));
    }
    out
}

/// One Table 6 row.
#[derive(Debug, Clone)]
pub struct Table6Row {
    pub implementation: String,
    pub fps: f64,
    pub power_w: f64,
    pub fps_per_w: f64,
    /// `true` if measured by this framework, `false` if quoted from the
    /// paper (closed testbeds).
    pub measured: bool,
}

/// Compute Table 6: our measured designs + the paper's comparison rows.
pub fn table6_rows(ours: &[PerfSummary]) -> Vec<Table6Row> {
    let mut rows = vec![
        Table6Row {
            implementation: "CPU i7-9800X (paper)".into(),
            fps: 15.3,
            power_w: 100.0,
            fps_per_w: 0.15,
            measured: false,
        },
        Table6Row {
            implementation: "GPU TITAN RTX (paper)".into(),
            fps: 183.4,
            power_w: 260.0,
            fps_per_w: 0.71,
            measured: false,
        },
        Table6Row {
            implementation: "BERT ZCU102 (Liu et al., paper)".into(),
            fps: 22.8,
            power_w: 9.8,
            fps_per_w: 2.32,
            measured: false,
        },
        Table6Row {
            implementation: "BERT ZCU111 (Liu et al., paper)".into(),
            fps: 42.0,
            power_w: 13.2,
            fps_per_w: 3.18,
            measured: false,
        },
    ];
    for s in ours {
        rows.push(Table6Row {
            implementation: format!("Ours {} ({})", s.label, s.device),
            fps: s.fps,
            power_w: s.power_w,
            fps_per_w: s.fps_per_w,
            measured: true,
        });
    }
    rows
}

/// Render Table 6 ("Performance comparison among FPGA accelerators, CPU and
/// GPU").
pub fn render_table6(rows: &[Table6Row]) -> String {
    let mut out = String::new();
    out.push_str("Table 6 — FPS / power / energy efficiency\n");
    out.push_str(&format!(
        "{:<34} | {:>8} | {:>9} | {:>8} | {}\n",
        "Implementation", "FPS", "Power (W)", "FPS/W", "source"
    ));
    out.push_str(&"-".repeat(80));
    out.push('\n');
    for r in rows {
        out.push_str(&format!(
            "{:<34} | {:>8.1} | {:>9.1} | {:>8.2} | {}\n",
            r.implementation,
            r.fps,
            r.power_w,
            r.fps_per_w,
            if r.measured { "measured" } else { "paper" }
        ));
    }
    out
}
