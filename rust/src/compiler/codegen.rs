//! Accelerator description emission (Fig. 1: "Accelerator description
//! (C++)" + the JSON config our cycle-level simulator consumes in place of
//! a bitstream).
//!
//! The C++ output mirrors what the paper feeds Vivado HLS 2020.1: a
//! templated compute engine with the tiling/unroll/pipeline pragmas set
//! from the chosen [`AcceleratorParams`]. We do not synthesize it (no
//! Vivado in this environment — see DESIGN.md §Substitutions); it is the
//! faithful, human-checkable artifact of the co-design flow, and its
//! parameter block is byte-identical to the JSON the simulator loads.

use crate::hw::Device;
use crate::model::VitStructure;
use crate::perf::AcceleratorParams;
use crate::util::json::Json;

use super::search::CompileOutcome;

/// Emit the JSON accelerator configuration (consumed by `sim::Accelerator`
/// and archived next to the HLS source).
pub fn emit_config_json(outcome: &CompileOutcome, device: &Device) -> Json {
    let p = &outcome.design.params;
    let s = &outcome.design.summary;
    Json::obj()
        .set("framework", "vaqf")
        .set("model", s.model.as_str())
        .set("device", device.name.as_str())
        .set("act_bits", p.act_bits.map(u64::from).unwrap_or(16))
        .set("weight_bits", if p.act_bits.is_some() { 1u64 } else { 16 })
        .set(
            "params",
            Json::obj()
                .set("t_m", p.t_m)
                .set("t_n", p.t_n)
                .set("t_m_q", p.t_m_q)
                .set("t_n_q", p.t_n_q)
                .set("g", p.g)
                .set("g_q", p.g_q)
                .set("p_h", p.p_h),
        )
        .set(
            "predicted",
            Json::obj()
                .set("cycles_per_frame", s.cycles_per_frame)
                .set("fps", s.fps)
                .set("gops", s.gops)
                .set("power_w", s.power_w)
                .set("dsp", s.utilization.dsp)
                .set("lut", s.utilization.lut)
                .set("bram18k", s.utilization.bram18k)
                .set("ff", s.utilization.ff),
        )
        .set(
            "search",
            Json::Arr(
                outcome
                    .rounds
                    .iter()
                    .map(|r| {
                        Json::obj()
                            .set("bits", u64::from(r.bits))
                            .set("fps", r.fps)
                            .set("feasible", r.feasible)
                    })
                    .collect(),
            ),
        )
        .set("target_fps", outcome.target_fps)
        .set("fr_max", outcome.fr_max)
}

/// Emit the Vivado-HLS-style C++ accelerator description.
pub fn emit_hls_cpp(
    outcome: &CompileOutcome,
    structure: &VitStructure,
    device: &Device,
) -> String {
    let p = &outcome.design.params;
    let bits = p.act_bits.unwrap_or(16);
    let f_max = structure.layers.iter().map(|l| l.f).max().unwrap_or(1);
    let n_h = structure.layers.iter().map(|l| l.heads).max().unwrap_or(1);
    format!(
        r#"// ============================================================================
// VAQF auto-generated ViT accelerator — DO NOT EDIT
// model: {model}   device: {device}   precision: W{wbits}A{abits}
// target: {target:.1} FPS   predicted: {fps:.1} FPS ({cycles} cycles/frame)
// ============================================================================
#include <ap_int.h>
#include <hls_stream.h>

// ---- accelerator parameters (paper Table 1) --------------------------------
#define T_M    {t_m}    // output-channel tile, unquantized datapath
#define T_N    {t_n}    // input-channel tile, unquantized datapath
#define T_M_Q  {t_m_q}  // output-channel tile, quantized datapath
#define T_N_Q  {t_n_q}  // input-channel tile, quantized datapath
#define G      {g}      // packing factor, 16-bit data ({port}-bit AXI ports)
#define G_Q    {g_q}    // packing factor, {abits}-bit activations
#define P_H    {p_h}    // attention heads processed in parallel
#define N_H    {n_h}    // max head count across layers
#define F_MAX  {f_max}  // max token-sequence length

typedef ap_int<16>      dtype;    // unquantized fixed-point (Q6.10)
typedef ap_int<{abits}> qtype;    // quantized activation
typedef ap_uint<1>      wtype;    // binary weight (sign bit)
typedef ap_int<32>      acctype;  // MAC accumulator
typedef ap_uint<{port}> axiword;  // packed AXI beat

// ---- on-chip tile buffers (double-buffered, Eq. 12) -------------------------
static dtype  in_buf  [2][N_H][T_N  ][F_MAX];
static qtype  in_buf_q[2][N_H][T_N_Q][F_MAX];
static dtype  wgt_buf [2][N_H][T_N  ][T_M];
static wtype  wgt_buf_q[2][N_H][T_N_Q][T_M_Q];
static acctype out_buf[N_H][T_M_Q > T_M ? T_M_Q : T_M][F_MAX];
#pragma HLS array_partition variable=in_buf   cyclic factor=G   dim=3
#pragma HLS array_partition variable=in_buf_q cyclic factor=G_Q dim=3
#pragma HLS array_partition variable=wgt_buf  complete dim=2
#pragma HLS array_partition variable=wgt_buf_q complete dim=2

// ---- general compute engine (paper §5.1, Fig. 3b) ---------------------------
// Handles both FC layers (one matmul; N split into N_H channel groups whose
// partial sums are accumulated) and multi-head attention (per-head results
// kept separate). `is_attention` is the control signal from §5.1.
void compute_engine(bool quantized, bool is_attention, int f, int n_tiles) {{
L1_token:
    for (int t = 0; t < f; ++t) {{
    L1h_headgrp:
        for (int hg = 0; hg < N_H / P_H; ++hg) {{
#pragma HLS pipeline II=1
        L2_head:
            for (int hp = 0; hp < P_H; ++hp) {{
#pragma HLS unroll
            L3_out:
                for (int m = 0; m < (quantized ? T_M_Q : T_M); ++m) {{
#pragma HLS unroll
                L4_in:
                    for (int n = 0; n < (quantized ? T_N_Q : T_N); ++n) {{
#pragma HLS unroll
                        int h = hg * P_H + hp;
                        if (quantized) {{
                            // Binary weight ⇒ add/sub, synthesized to LUTs
                            // (paper §5.1: "replaced with additions and
                            // subtractions ... implemented with LUTs").
                            acctype v = (acctype)in_buf_q[0][h][n][t];
                            out_buf[h][m][t] += wgt_buf_q[0][h][n][m] ? v : (acctype)-v;
                        }} else {{
                            // 16×16 MAC on a DSP48 slice.
                            out_buf[h][m][t] += (acctype)in_buf[0][h][n][t]
                                              * (acctype)wgt_buf[0][h][n][m];
                        }}
                    }}
                }}
            }}
        }}
    }}
    // FC layers: reduce the N_H per-group partial sums (attention keeps them).
    if (!is_attention) {{
    reduce_groups:
        for (int m = 0; m < (quantized ? T_M_Q : T_M); ++m)
            for (int t = 0; t < f; ++t)
                for (int h = 1; h < N_H; ++h)
#pragma HLS pipeline II=1
                    out_buf[0][m][t] += out_buf[h][m][t];
    }}
}}

// ---- top-level: one ViT layer (paper Fig. 3c) -------------------------------
void vit_layer(axiword *ddr_in, axiword *ddr_wgt, axiword *ddr_out,
               bool quantized, bool is_attention,
               int m_total, int n_total, int f) {{
#pragma HLS interface m_axi port=ddr_in  bundle=gmem0 depth=1<<24
#pragma HLS interface m_axi port=ddr_wgt bundle=gmem1 depth=1<<24
#pragma HLS interface m_axi port=ddr_out bundle=gmem2 depth=1<<24
    int tm = quantized ? T_M_Q : T_M;
    int tn = quantized ? T_N_Q : T_N;
    int n_tiles = (n_total + N_H * tn - 1) / (N_H * tn);
    int m_tiles = (m_total + tm - 1) / tm;
outer_m:
    for (int mt = 0; mt < m_tiles; ++mt) {{
    inner_n:
        for (int nt = 0; nt < n_tiles; ++nt) {{
            // Double buffering: loads for tile (nt+1) overlap compute on
            // tile (nt) — Eq. 9's J_lc = max(J_in, J_wgt, J_cmpt).
            // load_input(ddr_in, nt);  load_weight(ddr_wgt, mt, nt);
            compute_engine(quantized, is_attention, f, n_tiles);
        }}
        // store_output(ddr_out, mt);  // Eq. 7's J_out, packed G/G_Q-wide
    }}
}}
"#,
        model = structure.config.name,
        device = device.name,
        wbits = if p.act_bits.is_some() { 1 } else { 16 },
        abits = bits,
        target = outcome.target_fps,
        fps = outcome.design.summary.fps,
        cycles = outcome.design.summary.cycles_per_frame,
        t_m = p.t_m,
        t_n = p.t_n,
        t_m_q = p.t_m_q,
        t_n_q = p.t_n_q,
        g = p.g,
        g_q = p.g_q,
        p_h = p.p_h,
        n_h = n_h,
        f_max = f_max,
        port = device.axi_port_bits,
    )
}

/// Round-trip: parse an emitted JSON config back into parameters (used by
/// the simulator CLI path and tests).
pub fn params_from_json(j: &Json) -> anyhow::Result<AcceleratorParams> {
    let p = j
        .get("params")
        .ok_or_else(|| anyhow::anyhow!("missing params"))?;
    let field = |k: &str| -> anyhow::Result<u64> {
        p.get(k)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing params.{k}"))
    };
    let act_bits = j
        .get("act_bits")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing act_bits"))?;
    let weight_bits = j.get("weight_bits").and_then(Json::as_u64).unwrap_or(16);
    Ok(AcceleratorParams {
        t_m: field("t_m")?,
        t_n: field("t_n")?,
        t_m_q: field("t_m_q")?,
        t_n_q: field("t_n_q")?,
        g: field("g")?,
        g_q: field("g_q")?,
        p_h: field("p_h")?,
        act_bits: if weight_bits == 1 {
            Some(act_bits as u8)
        } else {
            None
        },
    })
}
