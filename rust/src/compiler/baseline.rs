//! Baseline (W16A16) accelerator parameter optimization (paper §5.3).
//!
//! The baseline design is the starting point of every quantized design:
//! `T_n = T_n^base`, `G = G^base`, and `T_m` initialized near `T_m^base`.
//! We find `T_m^base`/`T_n^base` by exhaustive search over the (small,
//! divisibility-constrained) parameter grid, minimizing the Eq. 13
//! objective Σᵢ Jᵢ subject to the Eq. 14 resource constraints.

use crate::hw::Device;
use crate::model::VitStructure;
use crate::perf::{model_cycles_total, resources_for, AcceleratorParams};

/// Exhaustively optimize the baseline accelerator for an *unquantized*
/// structure (act_bits = None).
///
/// The grid: `G` is fixed by the port width (§5.3.1: 16-bit data ⇒
/// `G = S_port/16`), `P_h` by the head-count rule, `T_m` ranges over
/// multiples of `G`, `T_n` over small values (the input-channel unroll is
/// the expensive dimension: each extra lane costs `T_m·P_h` DSPs).
pub fn optimize_baseline(structure: &VitStructure, device: &Device) -> AcceleratorParams {
    assert!(
        structure.act_bits.is_none(),
        "baseline optimization runs on the unquantized structure"
    );
    let g = (device.axi_port_bits / 16) as u64;
    let n_h = structure
        .layers
        .iter()
        .map(|l| l.heads as u64)
        .max()
        .unwrap_or(1);
    let p_h = AcceleratorParams::p_h_for(n_h);

    let mut best: Option<(u64, AcceleratorParams)> = None;
    // T_m: multiples of G up to 512; T_n: 1..=64 (DSP budget caps the
    // product well before these bounds on real devices). Every resource
    // component is monotone non-decreasing in T_m and T_n, so the
    // feasibility region is downward-closed: the scans break (rather than
    // `continue`) at their first infeasible point, visiting only the
    // feasible grid plus one boundary probe per row — the same points in
    // the same order, so the strict-`<` winner is unchanged.
    for t_m in (g..=512).step_by(g as usize) {
        let mut row_feasible = false;
        for t_n in 1..=64u64 {
            let cand = AcceleratorParams::baseline(t_m, t_n, g, p_h);
            let res = resources_for(structure, &cand, device);
            if !res.feasible(device) {
                break;
            }
            row_feasible = true;
            let cycles = model_cycles_total(structure, &cand, device);
            if best.as_ref().map(|(c, _)| cycles < *c).unwrap_or(true) {
                best = Some((cycles, cand));
            }
        }
        if !row_feasible {
            // (T_m, 1) infeasible ⇒ every larger T_m is too.
            break;
        }
    }
    best.expect("no feasible baseline design — device too small for any tiling")
        .1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{generic_edge, zcu102};
    use crate::model::{deit_base, deit_small};
    use crate::perf::summarize;

    #[test]
    fn baseline_is_feasible_and_nontrivial() {
        let dev = zcu102();
        let s = deit_base().structure(None);
        let p = optimize_baseline(&s, &dev);
        assert!(p.validate().is_ok());
        let res = resources_for(&s, &p, &dev);
        assert!(res.feasible(&dev));
        // §5.3.1: G = 4 for 16-bit data on 64-bit ports.
        assert_eq!(p.g, 4);
        assert_eq!(p.p_h, 4); // N_h = 12 ⇒ P_h = 4
        assert!(p.dsp_macs() > 100, "should use a real MAC array");
    }

    #[test]
    fn baseline_fps_near_paper_table5() {
        // Paper Table 5: W32A32 base design reaches 10.0 FPS on DeiT-base.
        // Our analytical model should land in the same regime (±40%).
        let dev = zcu102();
        let s = deit_base().structure(None);
        let p = optimize_baseline(&s, &dev);
        let sum = summarize(&s, &p, &dev);
        assert!(
            sum.fps > 6.0 && sum.fps < 14.0,
            "baseline fps = {:.1}, expected ≈10",
            sum.fps
        );
    }

    #[test]
    fn smaller_device_gets_smaller_design() {
        let s = deit_small().structure(None);
        let big = optimize_baseline(&s, &zcu102());
        let small = optimize_baseline(&s, &generic_edge());
        assert!(small.dsp_macs() < big.dsp_macs());
    }
}
