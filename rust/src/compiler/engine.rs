//! The shared design-space-search engine: memoized point evaluation,
//! branch-and-bound pruning, container dedup, and parallel candidate
//! evaluation behind one [`SearchCtx`] carried by every search caller
//! (`api::Session`, `shard::cosearch`, `shard::pipeline`'s repartition
//! failover).
//!
//! ## Why the pruning is exact
//!
//! Every component of the resource model (`perf::resources_for`) is
//! monotone non-decreasing in each tile dimension `T_m`/`T_m^q`/`T_n^q`
//! with the others held fixed: BRAM terms are products of `⌈tile/g⌉`
//! factors, DSP is `T_m·P_h·T_n`, LUT/FF are affine in the MAC-array
//! sizes. Feasibility (`Eq. 14`: every resource under budget) is
//! therefore *downward-closed* on the sweep grid — once a point is
//! infeasible, every coordinate-wise larger point is too. The phase-B
//! sweep exploits exactly that and nothing else:
//!
//! * the `T_m^q` scan breaks at its first infeasible point;
//! * a whole `T_m` plane is skipped when its coordinate-wise minimal
//!   point is infeasible;
//! * the `T_m^q` upper bound is derived per class as the largest multiple
//!   of `lcm(G, G^q)` still feasible at the grid-minimal `(T_m, T_n^q)`
//!   (replacing the old hardcoded 512 cap, which both wasted probes on
//!   small devices and silently truncated the space on big ones).
//!
//! Cycles are *not* assumed antitone (remainder-tile effects break
//! that), so no point with a chance of winning is ever skipped: pruning
//! only removes infeasible points the exhaustive scan would `continue`
//! past anyway.
//!
//! ## Why the container dedup is exact
//!
//! `optimize_for_bits` probes every storage container width
//! `c ∈ bits..=16`, but the search depends on `c` only through
//! `G^q = ⌊S_port/c⌋` and `step = lcm(G, G^q)` — resources are costed at
//! the *stored* width `⌊S_port/G^q⌋`, not the container width (see
//! `perf::resources_for`). Containers in the same `(G^q, step)` class
//! therefore produce byte-identical searches, and each class is probed
//! once. Classes are consecutive runs of the container range, so
//! first-occurrence order preserves the legacy tie-break.
//!
//! ## Why the parallel result is deterministic
//!
//! Candidates are ranked by the total order `(cycles, legacy enumeration
//! index)` — the exact order the serial strict-`<` first-seen-wins scan
//! induces. Workers only *evaluate*; selection is a serial fold over that
//! order, so the winner is byte-identical for every thread count. The
//! retained exhaustive oracle ([`optimize_for_bits_exhaustive`]) and the
//! `search_suite` property sweep enforce this.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::hw::Device;
use crate::model::{HostOp, LayerKind, Precision, VitStructure};
use crate::perf::{
    lut_cost_per_mac, model_cycles_total, resources_for, summarize, AcceleratorParams,
};
use crate::util::parallel;
use crate::Cycles;

use super::params::DesignPoint;

pub(crate) fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

pub(crate) fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

/// Everything the resource/latency models read from one layer — the
/// memo-key identity of a layer. (`name` is deliberately excluded: two
/// structures differing only in labels evaluate identically.)
#[derive(Clone, PartialEq, Eq, Hash)]
struct LayerShape {
    kind: LayerKind,
    m: usize,
    n: usize,
    f: usize,
    heads: usize,
    inputs: Precision,
    weights: Precision,
    outputs: Precision,
    /// Host-op multiset as counts of (softmax, layernorm, gelu, skip, scale).
    host_ops: [u8; 5],
}

/// Memo-key identity of a whole structure.
#[derive(Clone, PartialEq, Eq, Hash)]
struct ShapeKey {
    act_bits: Option<u8>,
    layers: Vec<LayerShape>,
}

impl ShapeKey {
    fn of(structure: &VitStructure) -> ShapeKey {
        let layers = structure
            .layers
            .iter()
            .map(|l| {
                let mut host_ops = [0u8; 5];
                for op in &l.host_ops {
                    let slot = match op {
                        HostOp::Softmax => 0,
                        HostOp::LayerNorm => 1,
                        HostOp::Gelu => 2,
                        HostOp::SkipAdd => 3,
                        HostOp::Scale => 4,
                    };
                    host_ops[slot] = host_ops[slot].saturating_add(1);
                }
                LayerShape {
                    kind: l.kind,
                    m: l.m,
                    n: l.n,
                    f: l.f,
                    heads: l.heads,
                    inputs: l.inputs,
                    weights: l.weights,
                    outputs: l.outputs,
                    host_ops,
                }
            })
            .collect();
        ShapeKey {
            act_bits: structure.act_bits,
            layers,
        }
    }
}

/// Memo-key identity of a device: every field of [`Device`] (floats as
/// bit patterns). Shard co-search debits per-stage BRAM budgets, and the
/// whole-design memo stores summaries (clock-dependent) and error text
/// (name-dependent), so nothing can be left out.
#[derive(Clone, PartialEq, Eq, Hash)]
struct DeviceKey {
    name: String,
    dsp: u64,
    lut: u64,
    bram18k: u64,
    ff: u64,
    clock_mhz: u64,
    axi_port_bits: u32,
    axi_ports_in: u64,
    axi_ports_wgt: u64,
    axi_ports_out: u64,
    r_dsp_bits: u64,
    r_lut_bits: u64,
    static_power_bits: u64,
}

impl DeviceKey {
    fn of(device: &Device) -> DeviceKey {
        DeviceKey {
            name: device.name.clone(),
            dsp: device.budget.dsp,
            lut: device.budget.lut,
            bram18k: device.budget.bram18k,
            ff: device.budget.ff,
            clock_mhz: device.clock_mhz,
            axi_port_bits: device.axi_port_bits,
            axi_ports_in: device.axi_ports_in,
            axi_ports_wgt: device.axi_ports_wgt,
            axi_ports_out: device.axi_ports_out,
            r_dsp_bits: device.r_dsp.to_bits(),
            r_lut_bits: device.r_lut.to_bits(),
            static_power_bits: device.static_power_w.to_bits(),
        }
    }
}

/// One memoized `(structure, device, params)` evaluation.
#[derive(Clone, Copy)]
struct EvalEntry {
    feasible: bool,
    /// Valid only when `feasible` (infeasible points never need cycles).
    cycles: Cycles,
}

/// Key of one grid point in the sharded eval cache.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct PointKey {
    shape: u32,
    device: u32,
    params: AcceleratorParams,
}

impl PointKey {
    /// Shard selector — a cheap mix of the fields that actually vary
    /// inside one sweep (the tile dims).
    fn shard(&self) -> usize {
        let p = &self.params;
        let mix = p
            .t_m
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(p.t_m_q.wrapping_mul(0xff51_afd7_ed55_8ccd))
            .wrapping_add(p.t_n_q.wrapping_mul(0xc4ce_b9fe_1a85_ec53))
            .wrapping_add((self.shape as u64) << 32 | self.device as u64);
        (mix >> 57) as usize % EVAL_SHARDS
    }
}

const EVAL_SHARDS: usize = 16;

/// Whole-result memo for `optimize_for_bits` — errors are memoized as
/// their rendered message so warm replays surface identical text.
type DesignMemo = HashMap<(u32, u32, AcceleratorParams, u8), Result<DesignPoint, String>>;

#[derive(Default)]
struct Interner {
    shapes: HashMap<ShapeKey, u32>,
    devices: HashMap<DeviceKey, u32>,
    baselines: HashMap<(u32, u32), AcceleratorParams>,
    designs: DesignMemo,
}

/// Cache/telemetry counters of one [`SearchCtx`] (all monotone).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Grid points actually evaluated (resource + cycle model).
    pub point_evals: u64,
    /// Grid points served from the memo.
    pub point_hits: u64,
    /// Whole `optimize_for_bits` results served from the memo.
    pub design_hits: u64,
    /// Whole baseline searches served from the memo.
    pub baseline_hits: u64,
    /// `T_m` planes skipped because their minimal point could not place
    /// (each plane is `|T_n^q cands| × |T_m^q range|` points never
    /// visited).
    pub planes_pruned: u64,
    /// Container widths folded into an already-probed `(G^q, step)`
    /// equivalence class instead of searched again.
    pub classes_deduped: u64,
}

impl SearchStats {
    /// Machine-readable snapshot — the shape `vaqf compile --json`, the
    /// search bench and [`crate::obs::MetricsRegistry`] all quote.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("point_evals", self.point_evals)
            .set("point_hits", self.point_hits)
            .set("design_hits", self.design_hits)
            .set("baseline_hits", self.baseline_hits)
            .set("planes_pruned", self.planes_pruned)
            .set("classes_deduped", self.classes_deduped)
    }
}

/// The incremental re-search context: memo tables + thread budget shared
/// by every search the same session (or sharded design) runs. Cloned
/// handles (`Arc<SearchCtx>`) share one cache, so a repartition after a
/// board crash re-optimizes warm instead of cold.
pub struct SearchCtx {
    interner: Mutex<Interner>,
    evals: [Mutex<HashMap<PointKey, EvalEntry>>; EVAL_SHARDS],
    threads: usize,
    point_evals: AtomicU64,
    point_hits: AtomicU64,
    design_hits: AtomicU64,
    baseline_hits: AtomicU64,
    planes_pruned: AtomicU64,
    classes_deduped: AtomicU64,
}

impl std::fmt::Debug for SearchCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let stats = self.stats();
        f.debug_struct("SearchCtx")
            .field("threads", &self.threads)
            .field("stats", &stats)
            .finish()
    }
}

impl Default for SearchCtx {
    fn default() -> Self {
        SearchCtx::new()
    }
}

impl SearchCtx {
    /// A fresh context with the crate's default thread fan-out
    /// (`VAQF_THREADS` / available parallelism).
    pub fn new() -> SearchCtx {
        SearchCtx::with_threads(parallel::default_threads())
    }

    /// A fresh context evaluating candidates across up to `threads`
    /// workers. `with_threads(1)` is fully serial (useful to demonstrate
    /// thread-count independence; results are identical either way).
    pub fn with_threads(threads: usize) -> SearchCtx {
        SearchCtx {
            interner: Mutex::new(Interner::default()),
            evals: std::array::from_fn(|_| Mutex::new(HashMap::new())),
            threads: threads.clamp(1, parallel::MAX_THREADS),
            point_evals: AtomicU64::new(0),
            point_hits: AtomicU64::new(0),
            design_hits: AtomicU64::new(0),
            baseline_hits: AtomicU64::new(0),
            planes_pruned: AtomicU64::new(0),
            classes_deduped: AtomicU64::new(0),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn stats(&self) -> SearchStats {
        SearchStats {
            point_evals: self.point_evals.load(Ordering::Relaxed),
            point_hits: self.point_hits.load(Ordering::Relaxed),
            design_hits: self.design_hits.load(Ordering::Relaxed),
            baseline_hits: self.baseline_hits.load(Ordering::Relaxed),
            planes_pruned: self.planes_pruned.load(Ordering::Relaxed),
            classes_deduped: self.classes_deduped.load(Ordering::Relaxed),
        }
    }

    fn intern(&self, structure: &VitStructure, device: &Device) -> (u32, u32) {
        let shape = ShapeKey::of(structure);
        let dev = DeviceKey::of(device);
        let mut guard = self.interner.lock().unwrap();
        let ns = guard.shapes.len() as u32;
        let sid = *guard.shapes.entry(shape).or_insert(ns);
        let nd = guard.devices.len() as u32;
        let did = *guard.devices.entry(dev).or_insert(nd);
        (sid, did)
    }

    /// Memoized feasibility + cycles for one grid point. Pure in its
    /// inputs, so concurrent duplicate computation is benign (both
    /// writers insert the identical entry).
    fn eval(
        &self,
        sid: u32,
        did: u32,
        structure: &VitStructure,
        device: &Device,
        params: &AcceleratorParams,
    ) -> EvalEntry {
        let key = PointKey {
            shape: sid,
            device: did,
            params: *params,
        };
        let shard = &self.evals[key.shard()];
        if let Some(e) = shard.lock().unwrap().get(&key) {
            self.point_hits.fetch_add(1, Ordering::Relaxed);
            return *e;
        }
        let feasible = resources_for(structure, params, device).feasible(device);
        let entry = EvalEntry {
            feasible,
            cycles: if feasible {
                model_cycles_total(structure, params, device)
            } else {
                0
            },
        };
        self.point_evals.fetch_add(1, Ordering::Relaxed);
        shard.lock().unwrap().insert(key, entry);
        entry
    }

    /// Memoized baseline (W16A16) search — same result as
    /// [`super::optimize_baseline`], computed at most once per distinct
    /// `(structure, device)` this context has seen.
    pub fn optimize_baseline(
        &self,
        structure: &VitStructure,
        device: &Device,
    ) -> AcceleratorParams {
        let (sid, did) = self.intern(structure, device);
        if let Some(p) = self.interner.lock().unwrap().baselines.get(&(sid, did)) {
            self.baseline_hits.fetch_add(1, Ordering::Relaxed);
            return *p;
        }
        // Compute outside the lock: the search is pure, so a racing
        // duplicate inserts the identical params.
        let params = super::baseline::optimize_baseline(structure, device);
        self.interner.lock().unwrap().baselines.insert((sid, did), params);
        params
    }

    /// Memoized, pruned, container-deduped, parallel §5.3.2 search —
    /// byte-identical results to [`optimize_for_bits_exhaustive`] (the
    /// `search_suite` property sweep holds it to that).
    pub fn optimize_for_bits(
        &self,
        structure: &VitStructure,
        baseline: &AcceleratorParams,
        device: &Device,
        bits: u8,
    ) -> anyhow::Result<DesignPoint> {
        anyhow::ensure!(
            structure.act_bits == Some(bits),
            "structure quantization ({:?}) must match requested bits ({bits})",
            structure.act_bits
        );
        let (sid, did) = self.intern(structure, device);
        let memo_key = (sid, did, *baseline, bits);
        if let Some(cached) = self.interner.lock().unwrap().designs.get(&memo_key) {
            self.design_hits.fetch_add(1, Ordering::Relaxed);
            return cached.clone().map_err(|m| anyhow::anyhow!(m));
        }
        let result = search_classes(
            Some((self, sid, did)),
            self.threads,
            structure,
            baseline,
            device,
            bits,
        );
        self.interner.lock().unwrap().designs.insert(
            memo_key,
            result
                .as_ref()
                .map(Clone::clone)
                .map_err(|e| format!("{e:#}")),
        );
        result
    }
}

/// The pruned + deduped + parallel search without a memo context — what
/// [`super::optimize_for_bits`] delegates to. One-shot callers get the
/// algorithmic speedups; repeated callers should go through a
/// [`SearchCtx`] for the caches too.
pub(crate) fn optimize_for_bits_pruned(
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
) -> anyhow::Result<DesignPoint> {
    anyhow::ensure!(
        structure.act_bits == Some(bits),
        "structure quantization ({:?}) must match requested bits ({bits})",
        structure.act_bits
    );
    search_classes(
        None,
        parallel::default_threads(),
        structure,
        baseline,
        device,
        bits,
    )
}

/// Container dedup + class fan-out + deterministic selection — the body
/// shared by the context-backed and one-shot pruned searches.
fn search_classes(
    ctx: Option<(&SearchCtx, u32, u32)>,
    threads: usize,
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
) -> anyhow::Result<DesignPoint> {
    // Container dedup: the search depends on the container width only
    // through (G^q, step) — probe each equivalence class once, in
    // first-occurrence (ascending-container) order so the legacy
    // first-seen-wins tie-break is preserved.
    let g = baseline.g;
    let mut classes: Vec<(u64, u64)> = Vec::new();
    for container in bits..=16 {
        let g_q = AcceleratorParams::g_q_for(device.axi_port_bits, container);
        let step = lcm(g, g_q);
        if classes.last() != Some(&(g_q, step)) {
            // g_q is non-increasing in the container width, so equal
            // classes are consecutive runs.
            classes.push((g_q, step));
        }
    }
    if let Some((ctx, _, _)) = ctx {
        let scanned = (17 - bits as usize) as u64;
        ctx.classes_deduped
            .fetch_add(scanned - classes.len() as u64, Ordering::Relaxed);
    }

    // Evaluate every class, fanning out across the thread budget.
    // Selection below is a serial fold in class order, so the winner is
    // independent of the fan-out.
    let outcomes = parallel::map_tasks(classes.len(), threads, parallel::MIN_WORK_PER_THREAD, |i| {
        let (g_q, step) = classes[i];
        optimize_class(ctx, structure, baseline, device, bits, g_q, step)
    });

    let mut best: Option<ClassResult> = None;
    let mut last_err = None;
    for outcome in outcomes {
        match outcome {
            Ok(r) => {
                if best.as_ref().map(|b| r.cycles < b.cycles).unwrap_or(true) {
                    best = Some(r);
                }
            }
            Err(e) => last_err = Some(e),
        }
    }
    match best {
        Some(r) => finish_design(structure, device, r),
        None => Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no container feasible"))),
    }
}

/// The winning candidate of one container class, before summarization.
struct ClassResult {
    cycles: Cycles,
    params: AcceleratorParams,
    adjustments: u32,
}

fn finish_design(
    structure: &VitStructure,
    device: &Device,
    r: ClassResult,
) -> anyhow::Result<DesignPoint> {
    r.params.validate()?;
    Ok(DesignPoint {
        summary: summarize(structure, &r.params, device),
        params: r.params,
        adjustments: r.adjustments,
    })
}

/// Feasibility of one grid point — through the context's memo when one is
/// supplied, direct otherwise (the oracle path).
fn point_eval(
    ctx: Option<(&SearchCtx, u32, u32)>,
    structure: &VitStructure,
    device: &Device,
    params: &AcceleratorParams,
) -> EvalEntry {
    match ctx {
        Some((ctx, sid, did)) => ctx.eval(sid, did, structure, device, params),
        None => {
            let feasible = resources_for(structure, params, device).feasible(device);
            EvalEntry {
                feasible,
                cycles: if feasible {
                    model_cycles_total(structure, params, device)
                } else {
                    0
                },
            }
        }
    }
}

/// §5.3.2 phases A and B for one `(G^q, step)` container class: the
/// feasibility descent, then the pruned `(T_m, T_m^q, T_n^q)` sweep with
/// selection by `(cycles, legacy enumeration index)` and the legacy
/// improvement count replayed from the visited feasible points.
fn optimize_class(
    ctx: Option<(&SearchCtx, u32, u32)>,
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
    g_q: u64,
    step: u64,
) -> anyhow::Result<ClassResult> {
    let g = baseline.g;
    // Rule 2: T_m near T_m^base, divisible by G and G^q.
    let t_m0 = ((baseline.t_m + step - 1) / step * step).max(step);
    // Rule 3.
    let t_n = baseline.t_n;
    let t_n_q = (t_n * g_q / g).max(1);

    let mut params = AcceleratorParams {
        t_m: t_m0,
        t_n,
        t_m_q: t_m0,
        t_n_q,
        g,
        g_q,
        p_h: baseline.p_h,
        act_bits: Some(bits),
    };

    let mut adjustments = 0u32;

    // Phase A: if the initial try does not "place and route"
    // (resource-model infeasibility), shrink the tile that owns the
    // oversubscribed resource: LUT/FF pressure comes from the quantized
    // array (T_m^q), DSP pressure from the unquantized array (T_m).
    loop {
        let res = resources_for(structure, &params, device);
        if res.feasible(device) {
            break;
        }
        let lut_over = res.lut as f64 > device.budget.lut as f64 * device.r_lut
            || res.ff > device.budget.ff;
        let dsp_over = res.dsp as f64 > device.budget.dsp as f64 * device.r_dsp;
        // LUT pressure is only relieved by shrinking the quantized array if
        // that array is actually a significant consumer. The array is
        // costed at the *stored* width ⌊S_port/G^q⌋ (what resources_for
        // charges), which also makes the whole class search a pure
        // function of (G^q, step) — the dedup above relies on that.
        let b_q = (u64::from(device.axi_port_bits) / g_q).max(1);
        let q_array_luts = lut_cost_per_mac(b_q.min(16) as u8) * params.lut_macs();
        let q_array_significant = q_array_luts * 8 > res.lut;
        // DSP pressure can only come from the unquantized array — relieve
        // it first (it also sheds the LUT glue around the DSP lanes).
        let shrink_q =
            !dsp_over && ((lut_over && q_array_significant) || params.t_m_q >= params.t_m);
        if shrink_q {
            if params.t_m_q > step {
                params.t_m_q -= step;
            } else if params.t_n_q > 1 {
                // Last resort: narrow the quantized input unroll below the
                // §5.3.2 rule value (costs BRAM efficiency, saves LUTs).
                params.t_n_q = (params.t_n_q / 2).max(1);
            } else {
                anyhow::bail!(
                    "no feasible design for {bits}-bit activations on {} (LUT-bound)",
                    device.name
                );
            }
        } else {
            anyhow::ensure!(
                params.t_m > step,
                "no feasible design for {bits}-bit activations on {}",
                device.name
            );
            params.t_m -= step;
        }
        adjustments += 1;
    }

    // Phase B: sweep the (T_m, T_m^q, T_n^q) grid for the latency argmin.
    let init = params;
    let init_cycles = point_eval(ctx, structure, device, &init).cycles;

    // T_n^q candidates: multiples of the §5.3.2 rule value (and G^q below
    // it) — the input unroll must stay word-aligned. Legacy order.
    let mut cands: Vec<u64> = (1..=8).map(|k| k * t_n_q).collect();
    cands.push(g_q);
    let n_cands = cands.len() as u64;
    let min_cand = *cands.iter().min().expect("candidate list is non-empty");

    // Derived T_m^q bound: the largest multiple of `step` feasible at the
    // grid-minimal other coordinates. Everything above it is infeasible
    // at *every* grid point (monotonicity), so the bound loses nothing —
    // unlike the old hardcoded 512 cap.
    let mut t_m_q_hi = 0u64;
    let mut q = step;
    loop {
        let probe = AcceleratorParams {
            t_m: step,
            t_m_q: q,
            t_n_q: min_cand,
            ..init
        };
        if !point_eval(ctx, structure, device, &probe).feasible {
            break;
        }
        t_m_q_hi = q;
        q += step;
    }
    let n_tmq = t_m_q_hi / step;

    // The pruned sweep: visit exactly the feasible grid points (plus one
    // boundary probe per scan), recording each with its legacy
    // enumeration index.
    let mut visited: Vec<(u64, Cycles, AcceleratorParams)> = Vec::new();
    let t_m_range: Vec<u64> = (1..=init.t_m / step).map(|k| k * step).collect();
    'planes: for (tm_i, &t_m) in t_m_range.iter().enumerate() {
        if n_tmq == 0 {
            break;
        }
        // Skip the whole plane when its minimal point cannot place.
        let plane_min = AcceleratorParams {
            t_m,
            t_m_q: step,
            t_n_q: min_cand,
            ..init
        };
        if !point_eval(ctx, structure, device, &plane_min).feasible {
            // Every remaining plane is infeasible too (monotone in T_m).
            if let Some((ctx, _, _)) = ctx {
                ctx.planes_pruned
                    .fetch_add((t_m_range.len() - tm_i) as u64, Ordering::Relaxed);
            }
            break 'planes;
        }
        for (ci, &t_n_q_c) in cands.iter().enumerate() {
            for tmq_i in 0..n_tmq {
                let t_m_q = (tmq_i + 1) * step;
                let cand = AcceleratorParams {
                    t_m,
                    t_m_q,
                    t_n_q: t_n_q_c,
                    ..init
                };
                let e = point_eval(ctx, structure, device, &cand);
                if !e.feasible {
                    // Monotone in T_m^q: the rest of this scan is
                    // infeasible too.
                    break;
                }
                let legacy_index = (tm_i as u64 * n_tmq + tmq_i) * n_cands + ci as u64;
                visited.push((legacy_index, e.cycles, cand));
            }
        }
    }

    // Selection: minimum under the total order (cycles, legacy index),
    // with the phase-A params ranked before every sweep candidate — the
    // exact winner of the serial strict-`<` scan.
    let mut best = ClassResult {
        cycles: init_cycles,
        params: init,
        adjustments: 0,
    };
    let mut best_index = None::<u64>;
    for &(index, cycles, cand) in &visited {
        let better = cycles < best.cycles
            || (cycles == best.cycles && best_index.map(|b| index < b).unwrap_or(false));
        if better {
            best.cycles = cycles;
            best.params = cand;
            best_index = Some(index);
        }
    }

    // Legacy `adjustments` accounting: the number of strict improvements
    // the serial scan would have made, replayed in enumeration order.
    visited.sort_unstable_by_key(|&(index, _, _)| index);
    let mut cur = init_cycles;
    for &(_, cycles, _) in &visited {
        if cycles < cur {
            cur = cycles;
            adjustments += 1;
        }
    }
    best.adjustments = adjustments;
    Ok(best)
}

/// The retained exhaustive oracle: the literal pre-engine triple loop
/// (no memo, no pruning, no dedup, no parallelism) — the ground truth the
/// property sweep holds [`SearchCtx::optimize_for_bits`] to.
pub fn optimize_for_bits_exhaustive(
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
) -> anyhow::Result<DesignPoint> {
    anyhow::ensure!(
        structure.act_bits == Some(bits),
        "structure quantization ({:?}) must match requested bits ({bits})",
        structure.act_bits
    );
    let mut best: Option<ClassResult> = None;
    let mut last_err = None;
    for container in bits..=16 {
        let g_q = AcceleratorParams::g_q_for(device.axi_port_bits, container);
        let step = lcm(baseline.g, g_q);
        match exhaustive_class(structure, baseline, device, bits, g_q, step) {
            Ok(d) => {
                if best.as_ref().map(|b| d.cycles < b.cycles).unwrap_or(true) {
                    best = Some(d);
                }
            }
            Err(e) => last_err = Some(e),
        }
    }
    match best {
        Some(r) => finish_design(structure, device, r),
        None => Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no container feasible"))),
    }
}

/// One container's exhaustive search: phase A (shared with the engine),
/// then the unpruned serial strict-`<` sweep over the full grid.
fn exhaustive_class(
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
    g_q: u64,
    step: u64,
) -> anyhow::Result<ClassResult> {
    // Phase A and the derived bound are identical by construction; reuse
    // them (unmemoized), then redo phase B the slow way.
    let pruned = optimize_class(None, structure, baseline, device, bits, g_q, step)?;
    let g = baseline.g;
    let t_m0 = ((baseline.t_m + step - 1) / step * step).max(step);
    let t_n = baseline.t_n;
    let t_n_q = (t_n * g_q / g).max(1);
    let mut params = AcceleratorParams {
        t_m: t_m0,
        t_n,
        t_m_q: t_m0,
        t_n_q,
        g,
        g_q,
        p_h: baseline.p_h,
        act_bits: Some(bits),
    };
    let mut adjustments = 0u32;
    loop {
        let res = resources_for(structure, &params, device);
        if res.feasible(device) {
            break;
        }
        let lut_over = res.lut as f64 > device.budget.lut as f64 * device.r_lut
            || res.ff > device.budget.ff;
        let dsp_over = res.dsp as f64 > device.budget.dsp as f64 * device.r_dsp;
        let b_q = (u64::from(device.axi_port_bits) / g_q).max(1);
        let q_array_luts = lut_cost_per_mac(b_q.min(16) as u8) * params.lut_macs();
        let q_array_significant = q_array_luts * 8 > res.lut;
        let shrink_q =
            !dsp_over && ((lut_over && q_array_significant) || params.t_m_q >= params.t_m);
        if shrink_q {
            if params.t_m_q > step {
                params.t_m_q -= step;
            } else if params.t_n_q > 1 {
                params.t_n_q = (params.t_n_q / 2).max(1);
            } else {
                anyhow::bail!(
                    "no feasible design for {bits}-bit activations on {} (LUT-bound)",
                    device.name
                );
            }
        } else {
            anyhow::ensure!(
                params.t_m > step,
                "no feasible design for {bits}-bit activations on {}",
                device.name
            );
            params.t_m -= step;
        }
        adjustments += 1;
    }

    let mut best_cycles = model_cycles_total(structure, &params, device);
    let init = params;
    // Same derived bound as the engine (the oracle checks pruning and
    // parallelism, not the bound — the bound's own regression test lives
    // in compiler::params::tests).
    let mut cands: Vec<u64> = (1..=8).map(|k| k * t_n_q).collect();
    cands.push(g_q);
    let min_cand = *cands.iter().min().expect("candidate list is non-empty");
    let mut t_m_q_hi = 0u64;
    let mut q = step;
    loop {
        let probe = AcceleratorParams {
            t_m: step,
            t_m_q: q,
            t_n_q: min_cand,
            ..init
        };
        if !resources_for(structure, &probe, device).feasible(device) {
            break;
        }
        t_m_q_hi = q;
        q += step;
    }

    for t_m in (1..=init.t_m / step).map(|k| k * step) {
        for t_m_q in (1..=t_m_q_hi / step).map(|k| k * step) {
            for &t_n_q_c in &cands {
                let cand = AcceleratorParams {
                    t_m,
                    t_m_q,
                    t_n_q: t_n_q_c,
                    ..init
                };
                if !resources_for(structure, &cand, device).feasible(device) {
                    continue;
                }
                let c = model_cycles_total(structure, &cand, device);
                if c < best_cycles {
                    params = cand;
                    best_cycles = c;
                    adjustments += 1;
                }
            }
        }
    }
    let result = ClassResult {
        cycles: best_cycles,
        params,
        adjustments,
    };
    // The pruned class search must agree with the literal scan; catching
    // a divergence here (debug builds/tests) beats shipping it.
    debug_assert_eq!(pruned.cycles, result.cycles, "pruned class diverged");
    debug_assert_eq!(pruned.params, result.params, "pruned class diverged");
    debug_assert_eq!(pruned.adjustments, result.adjustments, "pruned class diverged");
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{zcu102, Device, ResourceBudget};
    use crate::model::{deit_base, micro};

    fn mega_device(scale: u64) -> Device {
        let mut dev = zcu102();
        dev.name = format!("mega{scale}");
        dev.budget = ResourceBudget {
            dsp: dev.budget.dsp * scale,
            lut: dev.budget.lut * scale,
            bram18k: dev.budget.bram18k * scale,
            ff: dev.budget.ff * scale,
        };
        dev
    }

    #[test]
    fn container_classes_collapse() {
        // Port 64, bits 8: containers 8..=16 → g_q ∈ {8,7,6,5,4} ⇒ 5
        // classes instead of 9 probes.
        let g = 4u64;
        let mut classes = Vec::new();
        for container in 8u8..=16 {
            let g_q = AcceleratorParams::g_q_for(64, container);
            let key = (g_q, lcm(g, g_q));
            if classes.last() != Some(&key) {
                classes.push(key);
            }
        }
        assert_eq!(classes.len(), 5);
        // Runs are consecutive, so first-occurrence dedup caught them all.
        let mut uniq: Vec<_> = classes.clone();
        uniq.dedup();
        assert_eq!(uniq, classes);
    }

    #[test]
    fn ctx_matches_exhaustive_oracle_on_micro() {
        let dev = zcu102();
        let base = super::super::baseline::optimize_baseline(&micro().structure(None), &dev);
        for bits in [1u8, 4, 6, 8] {
            let s = micro().structure(Some(bits));
            let want = optimize_for_bits_exhaustive(&s, &base, &dev, bits).unwrap();
            for threads in [1usize, 2, 8] {
                let ctx = SearchCtx::with_threads(threads);
                let got = ctx.optimize_for_bits(&s, &base, &dev, bits).unwrap();
                assert_eq!(got.params, want.params, "bits={bits} threads={threads}");
                assert_eq!(
                    got.summary.cycles_per_frame, want.summary.cycles_per_frame,
                    "bits={bits} threads={threads}"
                );
                assert_eq!(got.adjustments, want.adjustments, "bits={bits} threads={threads}");
            }
        }
    }

    #[test]
    fn warm_result_is_identical_and_hits_the_memo() {
        let dev = zcu102();
        let ctx = SearchCtx::new();
        let base = ctx.optimize_baseline(&micro().structure(None), &dev);
        let s = micro().structure(Some(8));
        let cold = ctx.optimize_for_bits(&s, &base, &dev, 8).unwrap();
        let stats_cold = ctx.stats();
        let warm = ctx.optimize_for_bits(&s, &base, &dev, 8).unwrap();
        let stats_warm = ctx.stats();
        assert_eq!(cold.params, warm.params);
        assert_eq!(cold.adjustments, warm.adjustments);
        assert_eq!(stats_warm.design_hits, stats_cold.design_hits + 1);
        assert_eq!(
            stats_warm.point_evals, stats_cold.point_evals,
            "warm replay must not re-evaluate any grid point"
        );
    }

    #[test]
    fn derived_bound_unlocks_big_devices() {
        // On a 4× zcu102 the old hardcoded cap (t_m_q ≤ 512) binds: the
        // envelope-derived bound must find a strictly faster design with
        // t_m_q > 512. (The satellite regression test for the 512 bug.)
        let dev = mega_device(4);
        let base = super::super::baseline::optimize_baseline(&deit_base().structure(None), &dev);
        let s = deit_base().structure(Some(8));
        let d = optimize_for_bits_exhaustive(&s, &base, &dev, 8).unwrap();
        assert!(
            d.params.t_m_q > 512,
            "expected the derived bound to pass 512 on mega4, got {:?}",
            d.params
        );
        let ctx = SearchCtx::new();
        let fast = ctx.optimize_for_bits(&s, &base, &dev, 8).unwrap();
        assert_eq!(fast.params, d.params);
    }

    #[test]
    fn shape_key_ignores_names_but_not_dims() {
        let a = micro().structure(Some(8));
        let mut renamed = a.clone();
        for l in &mut renamed.layers {
            l.name = format!("x-{}", l.name);
        }
        assert!(ShapeKey::of(&a) == ShapeKey::of(&renamed));
        let mut grown = a.clone();
        grown.layers[0].m += 1;
        assert!(ShapeKey::of(&a) != ShapeKey::of(&grown));
    }
}
