//! Quantized-design parameter optimization (paper §5.3.2).
//!
//! Starting from the baseline parameters, for a candidate activation
//! precision `b`:
//!
//! 1. `T_n = T_n^base`, `G = G^base`; `G^q = ⌊S_port/b⌋`;
//! 2. `T_m` initialized near `T_m^base`, rounded to a multiple of
//!    `lcm(G, G^q)` (divisibility by both packing factors, §5.3.2);
//! 3. `T_n^q = ⌊T_n · G^q / G⌋` (maximum BRAM utilization for quantized
//!    data); `P_h` unchanged; `T_m^q = T_m` initially;
//! 4. "Implementation": if the resource model says the design cannot place
//!    (LUT overutilization, the §3 failure mode), shrink `T_m`; then grow
//!    `T_m^q` while the design stays feasible and the predicted latency
//!    improves — "T_m is reduced and T_m^q is increased until the FPGA
//!    resources are fully exploited".

use crate::hw::Device;
use crate::model::VitStructure;
use crate::perf::{model_cycles, resources_for, summarize, AcceleratorParams, PerfSummary};

/// One fully-optimized accelerator design for a specific precision.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub params: AcceleratorParams,
    pub summary: PerfSummary,
    /// Number of adjustment iterations the implementation loop took
    /// (the paper: "parameters may be slightly adjusted once or twice").
    pub adjustments: u32,
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

fn lcm(a: u64, b: u64) -> u64 {
    a / gcd(a, b) * b
}

/// Optimize the accelerator parameters for activation precision `bits`,
/// starting from the baseline design (§5.3.2).
///
/// Activations of width `b` may be stored in a wider *container* (e.g.
/// 3-bit values in 4-bit nibbles): an awkward packing factor like
/// `⌊64/3⌋ = 21` forces `lcm(G, G^q) = 84`-aligned tiles that waste the
/// whole fabric, while nibble-padding costs only the unused bit. We probe
/// every container width `c ∈ bits..=16` and keep the fastest design —
/// this also guarantees FR(b) is monotone in `b` (a `b`-bit model can
/// always ride a `c ≥ b` container), which the §3 binary search relies on.
pub fn optimize_for_bits(
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
) -> anyhow::Result<DesignPoint> {
    anyhow::ensure!(
        structure.act_bits == Some(bits),
        "structure quantization ({:?}) must match requested bits ({bits})",
        structure.act_bits
    );
    let mut best: Option<DesignPoint> = None;
    let mut last_err = None;
    for container in bits..=16 {
        match optimize_with_container(structure, baseline, device, bits, container) {
            Ok(d) => {
                if best
                    .as_ref()
                    .map(|b| d.summary.cycles_per_frame < b.summary.cycles_per_frame)
                    .unwrap_or(true)
                {
                    best = Some(d);
                }
            }
            Err(e) => last_err = Some(e),
        }
    }
    best.ok_or_else(|| last_err.unwrap_or_else(|| anyhow::anyhow!("no container feasible")))
}

/// §5.3.2 optimization for one specific storage container width.
fn optimize_with_container(
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
    container: u8,
) -> anyhow::Result<DesignPoint> {
    let g = baseline.g;
    let g_q = AcceleratorParams::g_q_for(device.axi_port_bits, container);
    let step = lcm(g, g_q);

    // Rule 2: T_m near T_m^base, divisible by G and G^q.
    let t_m0 = ((baseline.t_m + step - 1) / step * step).max(step);
    // Rule 3.
    let t_n = baseline.t_n;
    let t_n_q = (t_n * g_q / g).max(1);

    let mut params = AcceleratorParams {
        t_m: t_m0,
        t_n,
        t_m_q: t_m0,
        t_n_q,
        g,
        g_q,
        p_h: baseline.p_h,
        act_bits: Some(bits),
    };

    let mut adjustments = 0u32;

    // Adjustment phase A: if the initial try does not "place and route"
    // (resource-model infeasibility), shrink the tile that owns the
    // oversubscribed resource: LUT/FF pressure comes from the quantized
    // array (T_m^q), DSP pressure from the unquantized array (T_m), BRAM
    // from both (shrink the larger).
    loop {
        let res = resources_for(structure, &params, device);
        if res.feasible(device) {
            break;
        }
        let lut_over = res.lut as f64 > device.budget.lut as f64 * device.r_lut
            || res.ff > device.budget.ff;
        let dsp_over = res.dsp as f64 > device.budget.dsp as f64 * device.r_dsp;
        // LUT pressure is only relieved by shrinking the quantized array if
        // that array is actually a significant consumer — otherwise the
        // pressure comes from the glue around the DSP lanes and T_m must
        // shrink instead.
        let q_array_luts =
            crate::perf::lut_cost_per_mac(container) * params.lut_macs();
        let q_array_significant = q_array_luts * 8 > res.lut;
        // DSP pressure can only come from the unquantized array — relieve
        // it first (it also sheds the LUT glue around the DSP lanes).
        let shrink_q =
            !dsp_over && ((lut_over && q_array_significant) || params.t_m_q >= params.t_m);
        if shrink_q {
            if params.t_m_q > step {
                params.t_m_q -= step;
            } else if params.t_n_q > 1 {
                // Last resort: narrow the quantized input unroll below the
                // §5.3.2 rule value (costs BRAM efficiency, saves LUTs).
                params.t_n_q = (params.t_n_q / 2).max(1);
            } else {
                anyhow::bail!(
                    "no feasible design for {bits}-bit activations on {} (LUT-bound)",
                    device.name
                );
            }
        } else {
            anyhow::ensure!(
                params.t_m > step,
                "no feasible design for {bits}-bit activations on {}",
                device.name
            );
            params.t_m -= step;
        }
        adjustments += 1;
    }

    // Adjustment phase B: "T_m is reduced and T_m^q is increased until the
    // FPGA resources are fully exploited" (§5.3.2). The paper walks this by
    // repeated Vivado runs; our resource model is cheap enough to sweep the
    // whole (T_m, T_m^q, T_n^q) grid exhaustively and take the latency
    // argmin — the same fixed point the paper's iteration converges to.
    let mut best_cycles = model_cycles(structure, &params, device).0;
    let t_m_candidates: Vec<u64> = (step..=params.t_m).step_by(step as usize).collect();
    let init = params;
    for &t_m in &t_m_candidates {
        for t_m_q in (step..=512).step_by(step as usize) {
            // T_n^q: multiples of the §5.3.2 rule value (and of G^q below
            // it) — the input unroll must stay word-aligned.
            let mut t_n_q_cands: Vec<u64> = (1..=8).map(|k| k * t_n_q).collect();
            t_n_q_cands.push(g_q);
            for t_n_q_c in t_n_q_cands {
                let cand = AcceleratorParams {
                    t_m,
                    t_m_q,
                    t_n_q: t_n_q_c,
                    ..init
                };
                if !resources_for(structure, &cand, device).feasible(device) {
                    continue;
                }
                let c = model_cycles(structure, &cand, device).0;
                if c < best_cycles {
                    params = cand;
                    best_cycles = c;
                    adjustments += 1;
                }
            }
        }
    }

    params.validate()?;
    Ok(DesignPoint {
        summary: summarize(structure, &params, device),
        params,
        adjustments,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::optimize_baseline;
    use crate::hw::zcu102;
    use crate::model::deit_base;

    #[test]
    fn init_rules_follow_paper() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s8 = deit_base().structure(Some(8));
        let d8 = optimize_for_bits(&s8, &base, &dev, 8).unwrap();
        // T_n preserved from the baseline; G^q = 8; T_n^q = T_n·G^q/G.
        assert_eq!(d8.params.t_n, base.t_n);
        assert_eq!(d8.params.g_q, 8);
        assert_eq!(d8.params.t_n_q, base.t_n * 8 / base.g);
        assert_eq!(d8.params.p_h, base.p_h);
        // Divisibility invariants.
        assert!(d8.params.validate().is_ok());
    }

    #[test]
    fn six_bit_packing_special_case() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s6 = deit_base().structure(Some(6));
        let d6 = optimize_for_bits(&s6, &base, &dev, 6).unwrap();
        assert_eq!(d6.params.g_q, 10, "⌊64/6⌋ = 10");
        // T_m and T_m^q divisible by both G=4 and G^q=10 ⇒ by 20.
        assert_eq!(d6.params.t_m % 20, 0);
        assert_eq!(d6.params.t_m_q % 20, 0);
    }

    #[test]
    fn quantized_design_grows_lut_array() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s8 = deit_base().structure(Some(8));
        let d8 = optimize_for_bits(&s8, &base, &dev, 8).unwrap();
        assert!(
            d8.params.t_m_q >= d8.params.t_m,
            "T_m^q should grow past T_m ({:?})",
            d8.params
        );
        assert!(d8.adjustments > 0, "adjustment loop should have run");
    }

    #[test]
    fn mismatched_structure_rejected() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s8 = deit_base().structure(Some(8));
        assert!(optimize_for_bits(&s8, &base, &dev, 6).is_err());
    }
}
