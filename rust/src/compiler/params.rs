//! Quantized-design parameter optimization (paper §5.3.2).
//!
//! Starting from the baseline parameters, for a candidate activation
//! precision `b`:
//!
//! 1. `T_n = T_n^base`, `G = G^base`; `G^q = ⌊S_port/b⌋`;
//! 2. `T_m` initialized near `T_m^base`, rounded to a multiple of
//!    `lcm(G, G^q)` (divisibility by both packing factors, §5.3.2);
//! 3. `T_n^q = ⌊T_n · G^q / G⌋` (maximum BRAM utilization for quantized
//!    data); `P_h` unchanged; `T_m^q = T_m` initially;
//! 4. "Implementation": if the resource model says the design cannot place
//!    (LUT overutilization, the §3 failure mode), shrink `T_m`; then grow
//!    `T_m^q` while the design stays feasible and the predicted latency
//!    improves — "T_m is reduced and T_m^q is increased until the FPGA
//!    resources are fully exploited".
//!
//! The sweep itself lives in [`super::engine`]: container widths are
//! deduped by `(G^q, lcm(G, G^q))` class, the `(T_m, T_m^q, T_n^q)` grid
//! is pruned using the monotone resource structure, classes are evaluated
//! in parallel, and the `T_m^q` upper bound is derived from the device's
//! resource envelope rather than the old hardcoded 512 (which silently
//! truncated the search space on large devices — see
//! `engine::tests::derived_bound_unlocks_big_devices`). Results are
//! byte-identical to the retained exhaustive oracle
//! ([`super::optimize_for_bits_exhaustive`]); repeated callers should go
//! through a [`super::SearchCtx`] to add memoization on top.

use crate::hw::Device;
use crate::model::VitStructure;
use crate::perf::{AcceleratorParams, PerfSummary};

/// One fully-optimized accelerator design for a specific precision.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub params: AcceleratorParams,
    pub summary: PerfSummary,
    /// Number of adjustment iterations the implementation loop took
    /// (the paper: "parameters may be slightly adjusted once or twice").
    pub adjustments: u32,
}

/// Optimize the accelerator parameters for activation precision `bits`,
/// starting from the baseline design (§5.3.2).
///
/// Activations of width `b` may be stored in a wider *container* (e.g.
/// 3-bit values in 4-bit nibbles): an awkward packing factor like
/// `⌊64/3⌋ = 21` forces `lcm(G, G^q) = 84`-aligned tiles that waste the
/// whole fabric, while nibble-padding costs only the unused bit. We probe
/// every container width `c ∈ bits..=16` (one probe per `(G^q, lcm)`
/// equivalence class) and keep the fastest design — this also guarantees
/// FR(b) is monotone in `b` (a `b`-bit model can always ride a `c ≥ b`
/// container), which the §3 binary search relies on.
pub fn optimize_for_bits(
    structure: &VitStructure,
    baseline: &AcceleratorParams,
    device: &Device,
    bits: u8,
) -> anyhow::Result<DesignPoint> {
    super::engine::optimize_for_bits_pruned(structure, baseline, device, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::optimize_baseline;
    use crate::hw::zcu102;
    use crate::model::deit_base;

    #[test]
    fn init_rules_follow_paper() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s8 = deit_base().structure(Some(8));
        let d8 = optimize_for_bits(&s8, &base, &dev, 8).unwrap();
        // T_n preserved from the baseline; G^q = 8; T_n^q = T_n·G^q/G.
        assert_eq!(d8.params.t_n, base.t_n);
        assert_eq!(d8.params.g_q, 8);
        assert_eq!(d8.params.t_n_q, base.t_n * 8 / base.g);
        assert_eq!(d8.params.p_h, base.p_h);
        // Divisibility invariants.
        assert!(d8.params.validate().is_ok());
    }

    #[test]
    fn six_bit_packing_special_case() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s6 = deit_base().structure(Some(6));
        let d6 = optimize_for_bits(&s6, &base, &dev, 6).unwrap();
        assert_eq!(d6.params.g_q, 10, "⌊64/6⌋ = 10");
        // T_m and T_m^q divisible by both G=4 and G^q=10 ⇒ by 20.
        assert_eq!(d6.params.t_m % 20, 0);
        assert_eq!(d6.params.t_m_q % 20, 0);
    }

    #[test]
    fn quantized_design_grows_lut_array() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s8 = deit_base().structure(Some(8));
        let d8 = optimize_for_bits(&s8, &base, &dev, 8).unwrap();
        assert!(
            d8.params.t_m_q >= d8.params.t_m,
            "T_m^q should grow past T_m ({:?})",
            d8.params
        );
        assert!(d8.adjustments > 0, "adjustment loop should have run");
    }

    #[test]
    fn mismatched_structure_rejected() {
        let dev = zcu102();
        let base = optimize_baseline(&deit_base().structure(None), &dev);
        let s8 = deit_base().structure(Some(8));
        assert!(optimize_for_bits(&s8, &base, &dev, 6).is_err());
    }
}
