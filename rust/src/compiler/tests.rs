use crate::hw::{generic_edge, zcu102};
use crate::model::{deit_base, deit_small};
use crate::util::json::Json;

use super::codegen::params_from_json;
use super::*;

fn req(fps: f64) -> CompileRequest {
    CompileRequest {
        model: deit_base(),
        device: zcu102(),
        target_fps: fps,
    }
}

#[test]
fn paper_headline_24fps_needs_8bit() {
    // §6.3.1: "a frame rate requirement of 24 FPS is satisfied with 8-bit
    // quantization for activations". Our compiler must pick a precision in
    // the same neighbourhood (7..=9 bits) for the 24 FPS target.
    let out = compile(&req(24.0)).unwrap();
    assert!(
        (7..=9).contains(&out.act_bits),
        "picked {} bits for 24 FPS (fps={:.1})",
        out.act_bits,
        out.design.summary.fps
    );
    assert!(out.design.summary.fps >= 24.0);
}

#[test]
fn paper_headline_30fps_needs_6bit() {
    // §6.3.1: "a target of 30 FPS is met with 6-bit activation
    // quantization" ⇒ 5..=7 bits acceptable for our model.
    let out = compile(&req(30.0)).unwrap();
    assert!(
        (5..=7).contains(&out.act_bits),
        "picked {} bits for 30 FPS (fps={:.1})",
        out.act_bits,
        out.design.summary.fps
    );
    assert!(out.design.summary.fps >= 30.0);
}

#[test]
fn binary_search_at_most_four_rounds() {
    // §3: "up to four rounds of search" after the FR_max probe.
    for fps in [5.0, 12.0, 24.0, 30.0, 40.0] {
        let out = compile(&req(fps)).unwrap();
        let search_rounds = out.rounds.len() - 1; // minus the FR_max probe
        assert!(
            search_rounds <= 4,
            "{fps} FPS took {search_rounds} rounds"
        );
    }
}

#[test]
fn higher_targets_get_lower_precision() {
    // Monotonicity of the search outcome.
    let mut last_bits = 17u8;
    for fps in [5.0, 15.0, 25.0, 35.0] {
        let out = compile(&req(fps)).unwrap();
        assert!(
            out.act_bits <= last_bits,
            "{fps} FPS got {} bits, previous {last_bits}",
            out.act_bits
        );
        last_bits = out.act_bits;
    }
}

#[test]
fn infeasible_target_rejected_with_fr_max() {
    let out = compile(&req(10_000.0));
    let err = format!("{:#}", out.unwrap_err());
    assert!(err.contains("FR_max"), "error should cite FR_max: {err}");
}

#[test]
fn feasible_target_on_small_device_may_be_infeasible() {
    // The generic edge device cannot hit 30 FPS on DeiT-base at any
    // precision — the feasibility gate must fire.
    let r = CompileRequest {
        model: deit_base(),
        device: generic_edge(),
        target_fps: 30.0,
    };
    assert!(compile(&r).is_err());
    // But DeiT-small at a modest rate works.
    let r2 = CompileRequest {
        model: deit_small(),
        device: generic_edge(),
        target_fps: 2.0,
    };
    assert!(compile(&r2).is_ok());
}

#[test]
fn chosen_design_meets_target_and_validates() {
    let out = compile(&req(24.0)).unwrap();
    assert!(out.design.summary.fps >= out.target_fps);
    assert!(out.design.params.validate().is_ok());
    assert!(out.fr_max >= out.design.summary.fps);
    assert!(out.compile_seconds < 60.0, "compilation step should be fast");
}

#[test]
fn config_json_roundtrip() {
    let out = compile(&req(24.0)).unwrap();
    let dev = zcu102();
    let j = emit_config_json(&out, &dev);
    let text = j.pretty();
    let back = Json::parse(&text).unwrap();
    let params = params_from_json(&back).unwrap();
    assert_eq!(params, out.design.params);
}

#[test]
fn hls_codegen_contains_parameters() {
    let out = compile(&req(24.0)).unwrap();
    let dev = zcu102();
    let s = deit_base().structure(Some(out.act_bits));
    let cpp = emit_hls_cpp(&out, &s, &dev);
    for needle in [
        &format!("#define T_M    {}", out.design.params.t_m),
        &format!("#define T_M_Q  {}", out.design.params.t_m_q),
        &format!("#define G_Q    {}", out.design.params.g_q),
        &format!("#define P_H    {}", out.design.params.p_h),
        &"#pragma HLS pipeline II=1".to_string(),
        &"compute_engine".to_string(),
    ] {
        assert!(cpp.contains(needle.as_str()), "missing `{needle}`");
    }
}

#[test]
fn table5_reproduces_paper_shape() {
    // The qualitative claims of §6.3.1 (who wins, roughly by how much):
    //  * W1A8 ≈ 2.48× the W32A32 FPS, W1A6 ≈ 3.16× — we accept 1.8–4.5×;
    //  * GOPS/DSP strictly increasing with lower precision;
    //  * W1A6 uses markedly fewer DSPs than the baseline.
    let dev = zcu102();
    let rows = table5_rows(&deit_base(), &dev, &[8, 6]);
    assert_eq!(rows.len(), 3);
    let (base, w1a8, w1a6) = (&rows[0], &rows[1], &rows[2]);
    assert_eq!(base.label, "W32A32");
    assert_eq!(w1a8.label, "W1A8");
    assert_eq!(w1a6.label, "W1A6");

    let r8 = w1a8.fps / base.fps;
    let r6 = w1a6.fps / base.fps;
    assert!(r8 > 1.8 && r8 < 4.5, "W1A8 speedup {r8:.2} (paper 2.48)");
    assert!(r6 > r8, "W1A6 ({r6:.2}) must beat W1A8 ({r8:.2})");
    assert!(r6 < 6.0, "W1A6 speedup {r6:.2} (paper 3.16)");

    assert!(w1a8.gops_per_dsp > base.gops_per_dsp);
    assert!(w1a6.gops_per_dsp > base.gops_per_dsp);
    // Compute-efficiency per kLUT ordering matches the paper (Table 5:
    // 2.88 → 6.02 → 6.60): W1A6 > W1A8 > W32A32.
    assert!(w1a8.gops_per_klut > base.gops_per_klut);
    assert!(w1a6.gops_per_klut > w1a8.gops_per_klut);

    // Power ordering (Table 6): W32A32 > W1A8 > W1A6.
    assert!(base.power_w > w1a8.power_w);
    assert!(w1a8.power_w > w1a6.power_w);

    let t = render_table5(&rows, &dev);
    assert!(t.contains("W1A8") && t.contains("GOPS/DSP"));
}

#[test]
fn table6_has_measured_and_quoted_rows() {
    let dev = zcu102();
    let rows5 = table5_rows(&deit_base(), &dev, &[8, 6]);
    let rows6 = table6_rows(&rows5);
    assert_eq!(rows6.iter().filter(|r| !r.measured).count(), 4);
    assert_eq!(rows6.iter().filter(|r| r.measured).count(), 3);
    // W1A6 should have the best FPS/W among our rows (paper: 4.05, the
    // best of all implementations).
    let ours: Vec<_> = rows6.iter().filter(|r| r.measured).collect();
    let best = ours
        .iter()
        .max_by(|a, b| a.fps_per_w.total_cmp(&b.fps_per_w))
        .unwrap();
    assert!(best.implementation.contains("W1A6"), "{}", best.implementation);
    let t = render_table6(&rows6);
    assert!(t.contains("TITAN RTX"));
}
