//! The precision search (paper §3).
//!
//! "The theoretical maximum frame rate ... FR_max can be obtained supposing
//! the activation precision is 1-bit. ... FR_tgt ≤ FR_max means the
//! accelerator supporting a frame rate no lower than FR_tgt can be
//! implemented, and the appropriate precision is found through a binary
//! search procedure. With a selection range of 1 to 16 bits, up to four
//! rounds of search are conducted."

use std::time::Instant;

use crate::hw::Device;
use crate::model::VitConfig;
use crate::perf::AcceleratorParams;
use crate::util::parallel;

use super::baseline::optimize_baseline;
use super::engine::SearchCtx;
use super::params::{optimize_for_bits, DesignPoint};

/// What the user hands to `vaqf compile`.
#[derive(Debug, Clone)]
pub struct CompileRequest {
    pub model: VitConfig,
    pub device: Device,
    /// Desired frame rate (`FR_tgt`).
    pub target_fps: f64,
}

/// One probe of the binary search.
#[derive(Debug, Clone)]
pub struct SearchRound {
    pub bits: u8,
    pub fps: f64,
    pub feasible: bool,
}

/// The result of the compilation step.
#[derive(Debug, Clone)]
pub struct CompileOutcome {
    /// Chosen activation precision (highest precision meeting the target —
    /// higher precision ⇒ higher accuracy, §3 picks the least-destructive
    /// quantization that satisfies the frame rate).
    pub act_bits: u8,
    /// The optimized design at that precision.
    pub design: DesignPoint,
    /// The baseline (W16A16) parameters the search started from.
    pub baseline: AcceleratorParams,
    /// Theoretical maximum frame rate (1-bit activations).
    pub fr_max: f64,
    /// The target that was requested.
    pub target_fps: f64,
    /// Probe log (≤ 1 + 4 entries: the FR_max probe + binary search).
    pub rounds: Vec<SearchRound>,
    /// Wall-clock cost of the compilation step (paper: minutes–hours with
    /// Vivado in the loop; here the analytical model makes it milliseconds).
    pub compile_seconds: f64,
}

/// Run the VAQF compilation step.
///
/// Errors if `FR_tgt > FR_max` — the §3 infeasibility case ("the
/// accelerator supporting a frame rate no lower than FR_tgt can be
/// implemented" only when `FR_tgt ≤ FR_max`).
pub fn compile(req: &CompileRequest) -> anyhow::Result<CompileOutcome> {
    let t0 = Instant::now();
    let baseline = optimize_baseline(&req.model.structure(None), &req.device);
    compile_inner(req, baseline, t0, None)
}

/// [`compile`] through a [`SearchCtx`]: the baseline and every probed
/// precision are memoized, so repeated compiles for one (model, device) —
/// and the co-search/repartition paths that share the context — re-search
/// warm instead of cold.
pub fn compile_with_ctx(req: &CompileRequest, ctx: &SearchCtx) -> anyhow::Result<CompileOutcome> {
    let t0 = Instant::now();
    let baseline = ctx.optimize_baseline(&req.model.structure(None), &req.device);
    compile_inner(req, baseline, t0, Some(ctx))
}

/// [`compile`] with a precomputed baseline parameterization — the facade's
/// `api::Session` caches the baseline design-space search across calls, so
/// repeated compiles for one (model, device) don't redo it.
pub fn compile_with_baseline(
    req: &CompileRequest,
    baseline: AcceleratorParams,
) -> anyhow::Result<CompileOutcome> {
    compile_inner(req, baseline, Instant::now(), None)
}

/// [`compile_with_baseline`] through a [`SearchCtx`] (both caches: the
/// caller's baseline short-circuit and the context's design/point memos).
pub fn compile_with_baseline_ctx(
    req: &CompileRequest,
    baseline: AcceleratorParams,
    ctx: &SearchCtx,
) -> anyhow::Result<CompileOutcome> {
    compile_inner(req, baseline, Instant::now(), Some(ctx))
}

fn compile_inner(
    req: &CompileRequest,
    baseline: AcceleratorParams,
    t0: Instant,
    ctx: Option<&SearchCtx>,
) -> anyhow::Result<CompileOutcome> {
    let probe = |bits: u8| -> anyhow::Result<DesignPoint> {
        let s = req.model.structure(Some(bits));
        match ctx {
            Some(ctx) => ctx.optimize_for_bits(&s, &baseline, &req.device, bits),
            None => optimize_for_bits(&s, &baseline, &req.device, bits),
        }
    };

    let mut rounds = Vec::new();

    // Feasibility: FR_max at 1-bit activations.
    let d1 = probe(1)?;
    let fr_max = d1.summary.fps;
    rounds.push(SearchRound {
        bits: 1,
        fps: fr_max,
        feasible: fr_max >= req.target_fps,
    });
    anyhow::ensure!(
        req.target_fps <= fr_max,
        "target {:.1} FPS exceeds FR_max = {:.1} FPS for {} on {} — \
         no activation precision can satisfy it",
        req.target_fps,
        fr_max,
        req.model.name,
        req.device.name
    );

    // Binary search over 1..=16 for the highest precision still meeting
    // the target. Invariant: lo always feasible, hi+1 not (or untested).
    let mut lo = 1u8;
    let mut hi = 16u8;
    let mut best: (u8, DesignPoint) = (1, d1);
    while lo < hi {
        // Bias the midpoint up: we want the *largest* feasible bits.
        let mid = (lo + hi + 1) / 2;
        let d = probe(mid)?;
        let ok = d.summary.fps >= req.target_fps;
        rounds.push(SearchRound {
            bits: mid,
            fps: d.summary.fps,
            feasible: ok,
        });
        if ok {
            best = (mid, d);
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }

    let (act_bits, design) = best;
    Ok(CompileOutcome {
        act_bits,
        design,
        baseline,
        fr_max,
        target_fps: req.target_fps,
        rounds,
        compile_seconds: t0.elapsed().as_secs_f64(),
    })
}

/// Multi-target compilation (paper §3: "if there exist multiple frame rate
/// targets, all the possible precisions can be evaluated").
///
/// Evaluates every precision 1..=16 once, then assigns each target the
/// highest precision meeting it. Infeasible targets map to `None`. The
/// shared sweep costs one design-optimization per precision instead of one
/// binary search per target.
pub fn compile_multi(
    model: &VitConfig,
    device: &Device,
    targets: &[f64],
) -> anyhow::Result<Vec<(f64, Option<CompileOutcome>)>> {
    compile_multi_inner(model, device, targets, None)
}

/// [`compile_multi`] through a [`SearchCtx`] — the per-precision sweep
/// fans out across the context's thread budget and lands in its memos.
pub fn compile_multi_with_ctx(
    model: &VitConfig,
    device: &Device,
    targets: &[f64],
    ctx: &SearchCtx,
) -> anyhow::Result<Vec<(f64, Option<CompileOutcome>)>> {
    compile_multi_inner(model, device, targets, Some(ctx))
}

fn compile_multi_inner(
    model: &VitConfig,
    device: &Device,
    targets: &[f64],
    ctx: Option<&SearchCtx>,
) -> anyhow::Result<Vec<(f64, Option<CompileOutcome>)>> {
    let t0 = Instant::now();
    let unquant = model.structure(None);
    let baseline = match ctx {
        Some(ctx) => ctx.optimize_baseline(&unquant, device),
        None => optimize_baseline(&unquant, device),
    };

    // One sweep over the precision range, one worker per precision
    // (collected in bits order, so the assignment below is deterministic
    // for every thread count).
    let threads = ctx.map(|c| c.threads()).unwrap_or_else(parallel::default_threads);
    let sweep = parallel::map_tasks(16, threads, parallel::MIN_WORK_PER_THREAD, |i| {
        let bits = (i + 1) as u8;
        let s = model.structure(Some(bits));
        match ctx {
            Some(ctx) => ctx.optimize_for_bits(&s, &baseline, device, bits),
            None => optimize_for_bits(&s, &baseline, device, bits),
        }
        .ok()
        .map(|d| (bits, d))
    });
    let designs: Vec<(u8, DesignPoint)> = sweep.into_iter().flatten().collect();
    anyhow::ensure!(!designs.is_empty(), "no feasible design at any precision");
    let fr_max = designs
        .iter()
        .map(|(_, d)| d.summary.fps)
        .fold(0.0f64, f64::max);

    let mut out = Vec::with_capacity(targets.len());
    for &target in targets {
        // Highest precision meeting the target.
        let pick = designs
            .iter()
            .filter(|(_, d)| d.summary.fps >= target)
            .max_by_key(|(bits, _)| *bits);
        out.push((
            target,
            pick.map(|(bits, d)| CompileOutcome {
                act_bits: *bits,
                design: d.clone(),
                baseline,
                fr_max,
                target_fps: target,
                rounds: designs
                    .iter()
                    .map(|(b, dd)| SearchRound {
                        bits: *b,
                        fps: dd.summary.fps,
                        feasible: dd.summary.fps >= target,
                    })
                    .collect(),
                compile_seconds: t0.elapsed().as_secs_f64(),
            }),
        ));
    }
    Ok(out)
}
