//! The VAQF compilation step (paper §3 + §5.3).
//!
//! Input: a ViT structure and a target frame rate. Output: the activation
//! quantization precision (weights are binary) plus the accelerator
//! parameter settings that satisfy the target, an HLS-style C++ accelerator
//! description, and a JSON accelerator config consumed by the cycle-level
//! simulator.
//!
//! Pipeline (Fig. 1):
//!
//! 1. [`optimize_baseline`] — derive `T_m^base`, `T_n^base`, `G^base` for
//!    the unquantized W16A16 accelerator (§5.3).
//! 2. [`compile`] — compute `FR_max` (activation precision 1 bit), check
//!    feasibility against `FR_tgt`, then binary-search the precision range
//!    1..=16 (≤ 4 rounds, §3) for the highest precision whose optimized
//!    design still meets the target.
//! 3. For each probed precision, [`optimize_for_bits`] applies the §5.3.2
//!    initialization rules and the implementation-failure adjustment loop
//!    (LUT overutilization ⇒ shrink `T_m` / grow `T_m^q`).
//! 4. [`emit_hls_cpp`] / [`emit_config_json`] — emit the accelerator
//!    description (Fig. 1's "accelerator description in C++ format").

mod baseline;
mod codegen;
mod engine;
mod params;
mod report;
mod search;

pub use baseline::optimize_baseline;
pub use codegen::{emit_config_json, emit_hls_cpp, params_from_json};
pub use engine::{optimize_for_bits_exhaustive, SearchCtx, SearchStats};
pub use params::{optimize_for_bits, DesignPoint};
pub use report::{
    render_table5, render_table6, table5_rows, table5_rows_with_baseline,
    table5_rows_with_baseline_ctx, table6_rows, Table6Row, PAPER_TABLE5,
};
pub use search::{
    compile, compile_multi, compile_multi_with_ctx, compile_with_baseline,
    compile_with_baseline_ctx, compile_with_ctx, CompileOutcome, CompileRequest, SearchRound,
};

#[cfg(test)]
mod tests;
