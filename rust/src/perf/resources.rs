//! Resource-utilization model — Eq. 12 (BRAM), the DSP count rule and the
//! LUT cost model of Eq. 14, plus an FF estimate.
//!
//! BRAM is sized for the worst-case layer (the same physical buffers are
//! reused by quantized and unquantized layers, §5.3.2, so each of
//! `B_in`/`B_wgt`/`B_out` is the max over both datapaths and over layers).

use crate::hw::{Device, Utilization};
use crate::model::VitStructure;

use super::params::AcceleratorParams;

const BRAM_BITS: u64 = 18 * 1024;

#[inline]
fn cdiv(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// LUT cost `C_lut` for one MAC with quantized operands (Eq. 14).
///
/// A binary-weight MAC is a `b`-bit conditional add/sub feeding a guarded
/// accumulator: roughly one LUT per operand bit plus carry/select overhead.
/// For binary×binary (the FR_max probe) an XNOR+popcount lane costs ~2 LUTs.
/// Coefficients calibrated so the generated W1A8/W1A6 designs land near the
/// paper's Table 5 utilization (see EXPERIMENTS.md).
pub fn lut_cost_per_mac(act_bits: u8) -> u64 {
    match act_bits {
        1 => 2,
        b => b as u64 + 4,
    }
}

/// Full utilization estimate for a design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceModel {
    /// BRAM18k for input/weight/output tile buffers (Eq. 12, incl. the ×2
    /// double-buffering factor).
    pub bram_in: u64,
    pub bram_wgt: u64,
    pub bram_out: u64,
    /// DSPs for the unquantized MAC array: `T_m·P_h·T_n` (§5.3.3).
    pub dsp: u64,
    /// LUTs: control/AXI base + DSP-array glue + the quantized MAC array
    /// `C_lut·T_m^q·P_h·T_n^q` + datapath-select muxing.
    pub lut: u64,
    /// Flip-flop estimate (pipeline registers scale with both MAC arrays).
    pub ff: u64,
}

/// Fixed LUT overhead: AXI DMA engines, FSM control, host interface.
const LUT_BASE: u64 = 42_000;
/// LUT glue per DSP MAC lane (operand muxes, accumulator select).
const LUT_PER_DSP: u64 = 46;
/// FF base + per-lane pipeline registers.
const FF_BASE: u64 = 28_000;
const FF_PER_DSP: u64 = 42;
const FF_PER_LUT_MAC: u64 = 6;

impl ResourceModel {
    pub fn total_bram(&self) -> u64 {
        self.bram_in + self.bram_wgt + self.bram_out
    }

    pub fn utilization(&self) -> Utilization {
        Utilization {
            dsp: self.dsp,
            lut: self.lut,
            bram18k: self.total_bram(),
            ff: self.ff,
        }
    }

    /// The feasibility constraints of Eq. 14. LUT overutilization is what
    /// makes Vivado placement/routing fail in the paper (§3) — here it is
    /// the predicate the compiler's adjustment loop reacts to.
    pub fn feasible(&self, device: &Device) -> bool {
        self.total_bram() <= device.budget.bram18k
            && self.dsp as f64 <= device.budget.dsp as f64 * device.r_dsp
            && self.lut as f64 <= device.budget.lut as f64 * device.r_lut
            && self.ff <= device.budget.ff
    }
}

/// Evaluate Eq. 12 + the DSP/LUT/FF models for `params` over `structure`.
pub fn resources_for(
    structure: &VitStructure,
    params: &AcceleratorParams,
    device: &Device,
) -> ResourceModel {
    let g = params.g;
    let g_q = params.g_q;
    let (t_m, t_n, t_m_q, t_n_q) = (params.t_m, params.t_n, params.t_m_q, params.t_n_q);
    // Stored activation width: derived from the packing factor, so designs
    // that pad b-bit values into wider containers (compiler::params) are
    // costed at the container width.
    let b_q = if params.act_bits.is_some() {
        (u64::from(device.axi_port_bits) / g_q).max(1)
    } else {
        16
    };
    let quantized = params.act_bits.is_some();

    // Worst-case F and N_h across layers (buffers are shared, §5.3.2).
    let f_max = structure.layers.iter().map(|l| l.f as u64).max().unwrap_or(1);
    let n_h = structure.layers.iter().map(|l| l.heads as u64).max().unwrap_or(1);

    // Eq. 12. The unquantized term always exists (first/last layers); the
    // quantized term only if the design has a quantized datapath.
    let unq_in = cdiv(t_n, g) * cdiv(f_max * g * 16, BRAM_BITS);
    let q_in = cdiv(t_n_q, g_q) * cdiv(f_max * g_q * b_q, BRAM_BITS);
    let bram_in = 2 * n_h * if quantized { unq_in.max(q_in) } else { unq_in };

    let unq_wgt = cdiv(t_n, g) * cdiv(t_m * g * 16, BRAM_BITS);
    // Quantized weights are binary: G^q packed sign bits per word.
    let q_wgt = cdiv(t_n_q, g_q) * cdiv(t_m_q * g_q, BRAM_BITS);
    let bram_wgt = 2 * n_h * if quantized { unq_wgt.max(q_wgt) } else { unq_wgt };

    let unq_out = cdiv(t_m, g) * cdiv(f_max * g * 16, BRAM_BITS);
    let q_out = cdiv(t_m_q, g_q) * cdiv(f_max * g_q * b_q, BRAM_BITS);
    let bram_out = 2 * n_h * if quantized { unq_out.max(q_out) } else { unq_out };

    let dsp = params.dsp_macs();
    let lut_macs = if quantized { params.lut_macs() } else { 0 };
    let c_lut = lut_cost_per_mac(b_q.min(16) as u8);
    let lut = LUT_BASE
        + LUT_PER_DSP * dsp
        + c_lut * lut_macs
        // Datapath-select logic when both paths exist (§6.3.1 mentions the
        // "extra logic to select between unquantized or quantized
        // operations").
        + if quantized { 8_000 } else { 0 };
    let ff = FF_BASE + FF_PER_DSP * dsp + FF_PER_LUT_MAC * lut_macs;

    ResourceModel {
        bram_in,
        bram_wgt,
        bram_out,
        dsp,
        lut,
        ff,
    }
}
