//! Design-level performance summary: the quantities Table 5 reports.



use crate::hw::{Device, Utilization, UtilizationPct};
use crate::model::VitStructure;

use super::cycles::model_cycles;
use super::params::AcceleratorParams;
use super::power::{power_watts, PowerModel};
use super::resources::resources_for;

/// Everything Table 5 / Table 6 need for one accelerator design.
#[derive(Debug, Clone)]
pub struct PerfSummary {
    /// Design label, e.g. `W1A8`.
    pub label: String,
    pub model: String,
    pub device: String,
    pub params: AcceleratorParams,
    /// Predicted cycles per frame (Σᵢ Jᵢ + host).
    pub cycles_per_frame: u64,
    /// Frames per second at the device clock.
    pub fps: f64,
    /// Throughput in GOPS (ops = 2·MACs, the paper's accounting).
    pub gops: f64,
    /// Compute efficiency: GOPS per DSP.
    pub gops_per_dsp: f64,
    /// Compute efficiency: GOPS per thousand LUTs.
    pub gops_per_klut: f64,
    /// Board power (W) and energy efficiency (FPS/W) for Table 6.
    pub power_w: f64,
    pub fps_per_w: f64,
    pub utilization: Utilization,
    pub utilization_pct: UtilizationPct,
}

/// Precision label in the paper's `W{q_w}A{q_a}` convention.
pub fn precision_label(act_bits: Option<u8>) -> String {
    match act_bits {
        None => "W32A32".into(),
        Some(b) => format!("W1A{b}"),
    }
}

/// Build the full summary for one design.
pub fn summarize(
    structure: &VitStructure,
    params: &AcceleratorParams,
    device: &Device,
) -> PerfSummary {
    let (cycles, _) = model_cycles(structure, params, device);
    let res = resources_for(structure, params, device);
    let fps = device.fps(cycles);
    let gops = structure.total_ops() as f64 * fps / 1e9;
    let power = power_watts(structure, params, &res, device, &PowerModel::default());
    let util = res.utilization();
    PerfSummary {
        label: precision_label(params.act_bits),
        model: structure.config.name.clone(),
        device: device.name.clone(),
        params: *params,
        cycles_per_frame: cycles,
        fps,
        gops,
        gops_per_dsp: gops / res.dsp.max(1) as f64,
        gops_per_klut: gops / (res.lut as f64 / 1000.0),
        power_w: power,
        fps_per_w: fps / power,
        utilization: util,
        utilization_pct: util.percent(&device.budget),
    }
}
