//! Analytical resource / latency model (paper §5.3.3, Eqs. 7–14).
//!
//! This module is the quantitative core of VAQF's compilation step: given a
//! [`crate::model::VitStructure`], an accelerator parameterization
//! ([`AcceleratorParams`]) and a [`crate::hw::Device`], it predicts
//!
//! * per-layer and per-frame clock cycles (Eqs. 7–11, [`cycles`]),
//! * BRAM / DSP / LUT / FF utilization (Eq. 12 + §5.3.3, [`resources`]),
//! * frame rate, throughput and compute efficiency ([`summary`]),
//! * board power for the Table 6 comparison ([`power`]).
//!
//! The same equations drive the compiler's precision search and are
//! cross-validated against the cycle-level simulator (`benches/sim_vs_model`).

mod cycles;
mod params;
mod power;
mod resources;
mod summary;

pub use cycles::{
    layer_cycles, layer_cycles_opt, model_cycles, model_cycles_opt, model_cycles_total,
    LayerCycles, ModelOptions,
};
pub use params::AcceleratorParams;
pub use power::{power_watts, PowerModel};
pub use resources::{lut_cost_per_mac, resources_for, ResourceModel};
pub use summary::{summarize, PerfSummary};

#[cfg(test)]
mod tests;
