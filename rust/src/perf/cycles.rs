//! Per-layer latency model — Eqs. 7–11 of the paper, implemented verbatim.
//!
//! All counts are in accelerator clock cycles. The layer's α (inputs &
//! weights quantized), β (outputs quantized) and γ (attention head output
//! replication) flags come from the [`LayerDesc`] quantization assignment.

use crate::hw::Device;
use crate::model::{HostOp, LayerDesc, VitStructure};
use crate::Cycles;

use super::params::AcceleratorParams;

/// Ceiling division.
#[inline]
fn cdiv(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

/// Ablation switches for the latency model (benches/ablations.rs).
///
/// Defaults reproduce the paper's design; each switch disables one of the
/// §5 optimization techniques so its contribution can be quantified —
/// the design-choice ablations DESIGN.md §3 calls out.
#[derive(Debug, Clone, Copy)]
pub struct ModelOptions {
    /// §5.3.1 data packing. Off ⇒ one value per AXI beat (G = G^q = 1 for
    /// transfer purposes).
    pub data_packing: bool,
    /// Eq. 9 double buffering. Off ⇒ loads and compute serialize
    /// (`J_lc = J_in + J_wgt + J_cmpt`).
    pub double_buffering: bool,
    /// Tight 64-per-beat packing of binary weight tiles (our refinement of
    /// Eq. 7 — see DESIGN.md §Model-Refinements). Off ⇒ the printed
    /// formula (binary weights charged like activations).
    pub binary_weight_packing: bool,
    /// Overlap of host ops with the next layer's tile pipeline. Off ⇒
    /// host ops fully serialize.
    pub host_overlap: bool,
}

impl Default for ModelOptions {
    fn default() -> Self {
        ModelOptions {
            data_packing: true,
            double_buffering: true,
            binary_weight_packing: true,
            host_overlap: true,
        }
    }
}

/// The cycle breakdown for one layer (Eqs. 7–11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerCycles {
    /// Input-tile load cycles `J_in` (Eq. 7).
    pub j_in: Cycles,
    /// Weight-tile load cycles `J_wgt` (Eq. 7).
    pub j_wgt: Cycles,
    /// Output-tile store cycles `J_out` (Eq. 7).
    pub j_out: Cycles,
    /// Compute cycles for one tile group `J_cmpt` (Eq. 8).
    pub j_cmpt: Cycles,
    /// Overlapped load/compute cycles `J_lc` (Eq. 9).
    pub j_lc: Cycles,
    /// Cycles for one whole output tile `J_s` (Eq. 10).
    pub j_s: Cycles,
    /// Total cycles for the layer `J_i` (Eq. 11).
    pub total: Cycles,
    /// Host-CPU overhead for the trailing host ops (§5.2 runs softmax /
    /// GELU / scaling on the host; small but accounted).
    pub host: Cycles,
}

/// Eqs. 7–11 for one layer under `params` on `device` (paper defaults).
pub fn layer_cycles(layer: &LayerDesc, params: &AcceleratorParams, device: &Device) -> LayerCycles {
    layer_cycles_opt(layer, params, device, &ModelOptions::default())
}

/// Eqs. 7–11 with explicit [`ModelOptions`] (ablation entry point).
pub fn layer_cycles_opt(
    layer: &LayerDesc,
    params: &AcceleratorParams,
    device: &Device,
    opts: &ModelOptions,
) -> LayerCycles {
    let alpha = layer.alpha();
    let beta = layer.beta();
    let gamma = layer.gamma() as u64;
    let n_h = layer.heads as u64;
    let f = layer.f as u64;
    let m = layer.m as u64;
    let n = layer.n as u64;

    let (t_m, t_n, mut g, mut g_q) = (params.t_m, params.t_n, params.g, params.g_q);
    let (t_m_q, t_n_q) = (params.t_m_q, params.t_n_q);
    if !opts.data_packing {
        // Ablation: one value per AXI beat on every transfer.
        g = 1;
        g_q = 1;
    }

    // Input-channel words per tile: (1−α)·⌈T_n/G⌉ + α·⌈T_n^q/G^q⌉.
    let in_words = if alpha { cdiv(t_n_q, g_q) } else { cdiv(t_n, g) };
    // Output-channel tile width is a property of the *datapath* executing
    // the layer (the LUT array produces T_m^q channels per pass, the DSP
    // array T_m) — α selects it. β selects only the *packing* of the
    // stores (quantized outputs pack G^q per word, 16-bit outputs G).
    // This is a refinement of the printed Eq. 7/11, where β selects both;
    // see DESIGN.md §Model-Refinements.
    let t_m_eff = if alpha { t_m_q } else { t_m };
    let store_words = |tile_width: u64| {
        if beta {
            cdiv(tile_width, g_q)
        } else {
            cdiv(tile_width, g)
        }
    };
    let out_words = store_words(t_m_eff);

    // Eq. 7. One refinement over the printed formula (documented in
    // DESIGN.md §Model-Refinements): when the weights are *binary* (α=1 and
    // the layer has true weight parameters), the weight tile is T_n^q×T_m
    // sign bits and a 64-bit AXI beat carries 64 of them — the printed
    // ⌈T_n^q/G^q⌉·⌈T_m/p_wgt⌉ form would charge 1-bit weights the same
    // transfer time as b-bit activations and caps the W1A8/W1A6 speedup
    // far below the paper's own measured 2.48×/3.16×. Attention layers
    // (whose "weights" are b-bit activation tiles) keep the printed form.
    let j_in = n_h * in_words * cdiv(f, device.axi_ports_in);
    let binary_weights = opts.binary_weight_packing
        && matches!(layer.weights, crate::model::Precision::Binary);
    let j_wgt = if binary_weights {
        n_h * cdiv(
            t_n_q * t_m_eff,
            u64::from(device.axi_port_bits) * device.axi_ports_wgt,
        )
    } else {
        n_h * in_words * cdiv(t_m_eff, device.axi_ports_wgt)
    };
    let j_out = (1 + gamma) * out_words * cdiv(f, device.axi_ports_out);

    // Eq. 8.
    let j_cmpt = f * cdiv(n_h, params.p_h);

    // Eq. 9 — double buffering overlaps loads with compute.
    let j_lc = if opts.double_buffering {
        j_in.max(j_wgt).max(j_cmpt)
    } else {
        j_in + j_wgt + j_cmpt
    };

    // Eq. 10 — accumulate over input-channel tiles; the trailing +J_cmpt is
    // the pipeline drain of the last tile; J_out can dominate if stores are
    // slower than the whole accumulate.
    let in_tiles = if alpha {
        cdiv(n, n_h * t_n_q)
    } else {
        cdiv(n, n_h * t_n)
    };
    let accumulate = j_lc * in_tiles + j_cmpt;
    let j_s = accumulate.max(j_out);

    // Eq. 11 — loop over output-channel tiles, plus the final store. The
    // last (remainder) tile only stores its `m mod T_m` valid channels
    // (matters a lot for attention layers where M = F ≪ T_m^q·2).
    let full_tiles = m / t_m_eff;
    let rem = m % t_m_eff;
    let total = if rem == 0 {
        full_tiles * j_s + j_out
    } else {
        // Each full tile costs j_s; the remainder tile's store bound is
        // proportional to its own width; the trailing term is the final
        // (non-overlapped) store of that last tile.
        let j_out_rem = (1 + gamma) * store_words(rem) * cdiv(f, device.axi_ports_out);
        full_tiles * j_s + accumulate.max(j_out_rem) + j_out_rem
    };

    let host = host_cycles(layer, device) * if opts.host_overlap { 1 } else { 2 };

    LayerCycles {
        j_in,
        j_wgt,
        j_out,
        j_cmpt,
        j_lc,
        j_s,
        total,
        host,
    }
}

/// Host-CPU op latency expressed in accelerator cycles.
///
/// The paper states these introduce "very small latency overhead" (§5.2);
/// we model the embedded ARM host (quad A53 + NEON, ~1.2 GHz, vectorized:
/// 4 cores × 4 f32 lanes × 8× clock ratio ≈ 128, derated 2× for memory
/// traffic) at ~64 elementwise ops per 150 MHz fabric cycle, softmax
/// costing 4 passes over the data and LayerNorm 3. Half of the host work
/// overlaps with the accelerator's tile pipeline of the *next* layer
/// (token rows finish in order), so only half is charged to the critical
/// path.
fn host_cycles(layer: &LayerDesc, _device: &Device) -> Cycles {
    const OPS_PER_CYCLE: u64 = 64;
    const OVERLAP_CREDIT: u64 = 2;
    let elems = (layer.f * layer.m) as u64
        * if layer.kind.is_attention() {
            layer.heads as u64
        } else {
            1
        };
    layer
        .host_ops
        .iter()
        .map(|op| match op {
            HostOp::Softmax => elems * 4 / OPS_PER_CYCLE,
            HostOp::LayerNorm => elems * 3 / OPS_PER_CYCLE,
            HostOp::Gelu => elems * 2 / OPS_PER_CYCLE,
            HostOp::SkipAdd | HostOp::Scale => elems / OPS_PER_CYCLE,
        })
        .sum::<u64>()
        / OVERLAP_CREDIT
}

/// Whole-model cycles: Σᵢ Jᵢ plus host overhead (Eq. 13's objective).
pub fn model_cycles(
    structure: &VitStructure,
    params: &AcceleratorParams,
    device: &Device,
) -> (Cycles, Vec<LayerCycles>) {
    model_cycles_opt(structure, params, device, &ModelOptions::default())
}

/// Whole-model cycles under explicit [`ModelOptions`].
pub fn model_cycles_opt(
    structure: &VitStructure,
    params: &AcceleratorParams,
    device: &Device,
    opts: &ModelOptions,
) -> (Cycles, Vec<LayerCycles>) {
    let per_layer: Vec<LayerCycles> = structure
        .layers
        .iter()
        .map(|l| layer_cycles_opt(l, params, device, opts))
        .collect();
    let total = per_layer.iter().map(|c| c.total + c.host).sum();
    (total, per_layer)
}

/// Whole-model total cycles without materializing the per-layer
/// breakdown — the design-space search evaluates tens of thousands of
/// grid points and only ever reads the sum, so skipping the `Vec`
/// allocation keeps the hot loop allocation-free.
pub fn model_cycles_total(
    structure: &VitStructure,
    params: &AcceleratorParams,
    device: &Device,
) -> Cycles {
    let opts = ModelOptions::default();
    structure
        .layers
        .iter()
        .map(|l| {
            let c = layer_cycles_opt(l, params, device, &opts);
            c.total + c.host
        })
        .sum()
}
