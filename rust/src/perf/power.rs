//! Board power model for the Table 6 comparison.
//!
//! The paper measured 9.9 W (W32A32), 8.7 W (W1A8) and 7.8 W (W1A6) on the
//! ZCU102. Power *decreases* as precision drops even though LUT usage
//! grows, because work migrates from the power-hungry DSP datapath to the
//! LUT add/sub datapath and each resource is only active during the cycles
//! its datapath is executing. We therefore model
//!
//! `P = P_static + p_dsp·N_dsp·a_dsp + p_lut·N_lut·a_lut + p_bram·N_bram`
//!
//! where the activity factors `a_dsp`/`a_lut` are the fraction of frame
//! cycles spent in unquantized / quantized layers respectively. The three
//! coefficients are calibrated against the paper's three measurements
//! (see `tests.rs::power_model_matches_paper_within_tolerance`).

use crate::hw::Device;
use crate::model::VitStructure;

use super::cycles::model_cycles;
use super::params::AcceleratorParams;
use super::resources::ResourceModel;

/// Calibrated unit powers (watts per resource at 100% activity, 150 MHz).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub per_dsp_w: f64,
    pub per_klut_w: f64,
    pub per_bram18_w: f64,
    /// Dynamic power of one LUT MAC lane *per operand bit of width*, at
    /// full activity — an 8-bit add/sub lane toggles ~8/6 the logic of a
    /// 6-bit one, which is how the paper's W1A8 burns more watts than
    /// W1A6 despite similar LUT counts.
    pub per_lutmac_bit_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        // Calibrated to the ZCU102 measurements in Table 6 (±0.6 W):
        // 9.9 W (W32A32), 8.7 W (W1A8), 7.8 W (W1A6).
        PowerModel {
            per_dsp_w: 3.4e-3,
            per_klut_w: 14.0e-3,
            per_bram18_w: 1.6e-3,
            per_lutmac_bit_w: 0.056e-3,
        }
    }
}

/// Estimate average board power for a design executing `structure`.
pub fn power_watts(
    structure: &VitStructure,
    params: &AcceleratorParams,
    resources: &ResourceModel,
    device: &Device,
    model: &PowerModel,
) -> f64 {
    // Activity split: fraction of cycles in quantized vs unquantized layers.
    let (total, per_layer) = model_cycles(structure, params, device);
    let q_cycles: u64 = structure
        .layers
        .iter()
        .zip(&per_layer)
        .filter(|(l, _)| l.alpha())
        .map(|(_, c)| c.total)
        .sum();
    let a_lut = if total > 0 { q_cycles as f64 / total as f64 } else { 0.0 };
    let a_dsp = 1.0 - a_lut;

    let lut_macs = if params.act_bits.is_some() {
        params.lut_macs()
    } else {
        0
    };
    // Stored activation width (container-aware, same derivation as the
    // resource model).
    let b_eff = if params.act_bits.is_some() {
        (u64::from(device.axi_port_bits) / params.g_q).max(1) as f64
    } else {
        16.0
    };

    device.static_power_w
        + model.per_dsp_w * resources.dsp as f64 * (0.25 + 0.75 * a_dsp)
        + model.per_klut_w * (resources.lut as f64 / 1000.0) * (0.35 + 0.65 * a_lut.min(1.0))
        + model.per_lutmac_bit_w * lut_macs as f64 * b_eff * a_lut
        + model.per_bram18_w * resources.total_bram() as f64
}
