//! Accelerator parameterization (paper Table 1).



use crate::quant::pack_factor;

/// The tunable parameters of a generated accelerator.
///
/// Two groups (paper §5.3.2): `t_m`/`t_n`/`g` drive the unquantized (16-bit,
/// DSP) datapath; `t_m_q`/`t_n_q`/`g_q` drive the quantized (binary-weight,
/// LUT add/sub) datapath. `p_h` — the number of attention heads processed in
/// parallel — is shared. `act_bits` records the activation precision the
/// design was generated for (`None` = unquantized baseline accelerator).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AcceleratorParams {
    /// Output-channel tile for unquantized data (`T_m`).
    pub t_m: u64,
    /// Input-channel tile for unquantized data (`T_n`).
    pub t_n: u64,
    /// Output-channel tile for quantized data (`T_m^q`).
    pub t_m_q: u64,
    /// Input-channel tile for quantized data (`T_n^q`).
    pub t_n_q: u64,
    /// Packing factor for unquantized (16-bit) data (`G`).
    pub g: u64,
    /// Packing factor for quantized data (`G^q`).
    pub g_q: u64,
    /// Heads processed in parallel (`P_h`).
    pub p_h: u64,
    /// Activation precision this design supports (1..=16), `None` for the
    /// unquantized baseline.
    pub act_bits: Option<u8>,
}

impl AcceleratorParams {
    /// The baseline (W16A16) accelerator parameterization: no quantized
    /// datapath, so the quantized-group parameters alias the unquantized
    /// ones (the equations then degenerate correctly since α=β=0 for every
    /// layer).
    pub fn baseline(t_m: u64, t_n: u64, g: u64, p_h: u64) -> AcceleratorParams {
        AcceleratorParams {
            t_m,
            t_n,
            t_m_q: t_m,
            t_n_q: t_n,
            g,
            g_q: g,
            p_h,
            act_bits: None,
        }
    }

    /// Derive the quantized-group packing factor from the port width and
    /// activation precision (§5.3.1), e.g. `⌊64/8⌋ = 8`, `⌊64/6⌋ = 10`.
    pub fn g_q_for(port_bits: u32, act_bits: u8) -> u64 {
        pack_factor(port_bits, act_bits as u32) as u64
    }

    /// The paper's `P_h` rule (§5.3.2): "usually a value that can divide
    /// N_h exactly. If N_h = 6, P_h is set to 3; if N_h = 8 or 12, P_h is 4"
    /// — i.e. the largest divisor of `n_h` that is ≤ 4.
    pub fn p_h_for(n_h: u64) -> u64 {
        (1..=4u64.min(n_h)).rev().find(|p| n_h % p == 0).unwrap_or(1)
    }

    /// Parallel MAC lanes on the DSP (unquantized) datapath: `T_m·P_h·T_n`.
    pub fn dsp_macs(&self) -> u64 {
        self.t_m * self.p_h * self.t_n
    }

    /// Parallel MAC lanes on the LUT (quantized) datapath:
    /// `T_m^q·P_h·T_n^q`.
    pub fn lut_macs(&self) -> u64 {
        self.t_m_q * self.p_h * self.t_n_q
    }

    /// Sanity-check structural invariants the compiler must maintain
    /// (§5.3.2: `T_m`, `T_m^q` divisible by `G` and `G^q` for output
    /// storage).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.t_m > 0 && self.t_n > 0, "empty tiles");
        anyhow::ensure!(self.g > 0 && self.g_q > 0, "empty packing factors");
        anyhow::ensure!(self.p_h > 0, "p_h must be positive");
        anyhow::ensure!(
            self.t_m % self.g == 0,
            "T_m={} not divisible by G={}",
            self.t_m,
            self.g
        );
        if self.act_bits.is_some() {
            anyhow::ensure!(
                self.t_m % self.g_q == 0,
                "T_m={} not divisible by G^q={}",
                self.t_m,
                self.g_q
            );
            anyhow::ensure!(
                self.t_m_q % self.g_q == 0,
                "T_m^q={} not divisible by G^q={}",
                self.t_m_q,
                self.g_q
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p_h_rule_matches_paper_examples() {
        assert_eq!(AcceleratorParams::p_h_for(6), 3);
        assert_eq!(AcceleratorParams::p_h_for(8), 4);
        assert_eq!(AcceleratorParams::p_h_for(12), 4);
        assert_eq!(AcceleratorParams::p_h_for(3), 3);
        assert_eq!(AcceleratorParams::p_h_for(1), 1);
        assert_eq!(AcceleratorParams::p_h_for(7), 1);
    }

    #[test]
    fn g_q_examples() {
        assert_eq!(AcceleratorParams::g_q_for(64, 8), 8);
        assert_eq!(AcceleratorParams::g_q_for(64, 6), 10);
        assert_eq!(AcceleratorParams::g_q_for(64, 1), 64);
    }

    #[test]
    fn validate_divisibility() {
        let mut p = AcceleratorParams::baseline(32, 16, 4, 4);
        assert!(p.validate().is_ok());
        p.t_m = 33;
        assert!(p.validate().is_err());
    }
}
