use crate::hw::zcu102;
use crate::model::{deit_base, LayerKind};

use super::*;

fn base_params() -> AcceleratorParams {
    AcceleratorParams::baseline(96, 4, 4, 4)
}

fn quant_params(bits: u8) -> AcceleratorParams {
    let g_q = AcceleratorParams::g_q_for(64, bits);
    AcceleratorParams {
        t_m: 80,
        t_n: 4,
        t_m_q: 160,
        t_n_q: 4 * g_q / 4,
        g: 4,
        g_q,
        p_h: 4,
        act_bits: Some(bits),
    }
}

#[test]
fn eq7_manual_check_mlp1() {
    // Hand-evaluated Eq. 7–11 for DeiT-base enc0.mlp1 (M=3072, N=768,
    // F=197, N_h=12) under the baseline params on ZCU102
    // (p_in=4, p_wgt=2, p_out=2).
    let dev = zcu102();
    let s = deit_base().structure(None);
    let mlp1 = s.layers.iter().find(|l| l.name == "enc0.mlp1").unwrap();
    let c = layer_cycles(mlp1, &base_params(), &dev);
    // j_in = 12 · ⌈4/4⌉ · ⌈197/4⌉ = 12·1·50 = 600
    assert_eq!(c.j_in, 600);
    // j_wgt = 12 · 1 · ⌈96/2⌉ = 576
    assert_eq!(c.j_wgt, 576);
    // j_cmpt = 197 · ⌈12/4⌉ = 591
    assert_eq!(c.j_cmpt, 591);
    assert_eq!(c.j_lc, 600);
    // in_tiles = ⌈768/(12·4)⌉ = 16 ⇒ j_s = 16·600 + 591 = 10191
    assert_eq!(c.j_s, 10191);
    // out_tiles = ⌈3072/96⌉ = 32, j_out = ⌈96/4⌉·⌈197/2⌉ = 24·99 = 2376
    assert_eq!(c.j_out, 2376);
    assert_eq!(c.total, 32 * 10191 + 2376);
}

#[test]
fn attention_gamma_inflates_output_stores() {
    let dev = zcu102();
    let s = deit_base().structure(None);
    let qk = s.layers.iter().find(|l| l.kind == LayerKind::AttnQk).unwrap();
    let fc = s.layers.iter().find(|l| l.name == "enc0.proj").unwrap();
    let cqk = layer_cycles(qk, &base_params(), &dev);
    let cfc = layer_cycles(fc, &base_params(), &dev);
    // Same T_m/G/F ⇒ j_out ratio is exactly (1+γ) = N_h.
    assert_eq!(cqk.j_out, cfc.j_out * 12);
}

#[test]
fn quantization_reduces_cycles() {
    let dev = zcu102();
    let base = deit_base().structure(None);
    let (c_base, _) = model_cycles(&base, &base_params(), &dev);
    for bits in [8u8, 6] {
        let s = deit_base().structure(Some(bits));
        let (c_q, _) = model_cycles(&s, &quant_params(bits), &dev);
        assert!(
            c_q < c_base,
            "W1A{bits} ({c_q}) should be faster than baseline ({c_base})"
        );
    }
    // And 6-bit beats 8-bit (more packing, bigger T_m^q possible).
    let (c8, _) = model_cycles(&deit_base().structure(Some(8)), &quant_params(8), &dev);
    let (c6, _) = model_cycles(&deit_base().structure(Some(6)), &quant_params(6), &dev);
    assert!(c6 < c8, "W1A6 ({c6}) should beat W1A8 ({c8})");
}

#[test]
fn bram_model_counts_double_buffering() {
    let dev = zcu102();
    let s = deit_base().structure(None);
    let r = resources_for(&s, &base_params(), &dev);
    // Every buffer count is even (the ×2 in Eq. 12).
    assert_eq!(r.bram_in % 2, 0);
    assert_eq!(r.bram_wgt % 2, 0);
    assert_eq!(r.bram_out % 2, 0);
    assert!(r.total_bram() > 0);
}

#[test]
fn dsp_count_is_tm_ph_tn() {
    let dev = zcu102();
    let s = deit_base().structure(None);
    let p = base_params();
    let r = resources_for(&s, &p, &dev);
    assert_eq!(r.dsp, p.t_m * p.p_h * p.t_n);
}

#[test]
fn lut_cost_monotone_in_bits() {
    assert!(lut_cost_per_mac(1) < lut_cost_per_mac(6));
    assert!(lut_cost_per_mac(6) < lut_cost_per_mac(8));
    assert!(lut_cost_per_mac(8) < lut_cost_per_mac(16));
}

#[test]
fn feasibility_rejects_oversized_designs() {
    let dev = zcu102();
    let s = deit_base().structure(Some(8));
    let mut p = quant_params(8);
    p.t_m_q = 4000;
    p.t_n_q = 512;
    let r = resources_for(&s, &p, &dev);
    assert!(!r.feasible(&dev), "absurd design must not fit");
}

#[test]
fn summary_consistency() {
    let dev = zcu102();
    let s = deit_base().structure(Some(8));
    let sum = summarize(&s, &quant_params(8), &dev);
    assert_eq!(sum.label, "W1A8");
    // FPS and cycles must be consistent with the clock.
    let fps_from_cycles = 150e6 / sum.cycles_per_frame as f64;
    assert!((sum.fps - fps_from_cycles).abs() < 1e-9);
    // GOPS = ops/frame × fps.
    let gops = s.total_ops() as f64 * sum.fps / 1e9;
    assert!((sum.gops - gops).abs() < 1e-9);
    assert!(sum.power_w > dev.static_power_w);
    assert!(sum.fps_per_w > 0.0);
}

#[test]
fn power_decreases_with_lower_precision() {
    // Table 6 trend: 9.9 W (W32A32) > 8.7 W (W1A8) > 7.8 W (W1A6): moving
    // work from DSPs to LUT add/sub lowers power.
    let dev = zcu102();
    let p32 = summarize(&deit_base().structure(None), &base_params(), &dev);
    let p8 = summarize(&deit_base().structure(Some(8)), &quant_params(8), &dev);
    let mut qp6 = quant_params(6);
    // W1A6 frees DSPs (paper: 673 used): shrink the unquantized array.
    qp6.t_m = 40;
    let p6 = summarize(&deit_base().structure(Some(6)), &qp6, &dev);
    assert!(p8.power_w < p32.power_w, "{} !< {}", p8.power_w, p32.power_w);
    assert!(p6.power_w < p8.power_w, "{} !< {}", p6.power_w, p8.power_w);
}

#[test]
fn host_cycles_are_small_fraction() {
    // §5.2: host ops introduce "very small latency overhead".
    let dev = zcu102();
    let s = deit_base().structure(None);
    let (total, per_layer) = model_cycles(&s, &base_params(), &dev);
    let host: u64 = per_layer.iter().map(|c| c.host).sum();
    assert!(
        (host as f64) < 0.12 * total as f64,
        "host {host} vs total {total}"
    );
}
