use std::sync::Arc;

use crate::hw::zcu102;
use crate::model::VitConfig;
use crate::perf::AcceleratorParams;
use crate::runtime::{InferenceBackend, SimBackend};
use crate::sim::{generate_weights, ModelExecutor};

use super::*;

fn micro() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 1,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

fn sim_backend(realtime: bool) -> Box<dyn InferenceBackend> {
    let cfg = micro();
    let w = generate_weights(&cfg, 11);
    let g_q = AcceleratorParams::g_q_for(64, 8);
    let params = AcceleratorParams {
        t_m: 16,
        t_n: 2,
        t_m_q: 16,
        t_n_q: 2 * g_q / 4,
        g: 4,
        g_q,
        p_h: 4,
        act_bits: Some(8),
    };
    Box::new(SimBackend {
        executor: ModelExecutor::new(w, Some(8), params, zcu102()),
        realtime,
    })
}

#[test]
fn queue_drop_oldest() {
    let q: BoundedQueue<u32> = BoundedQueue::new(2);
    assert!(!q.push(1));
    assert!(!q.push(2));
    assert!(q.push(3)); // drops 1
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), Some(3));
    q.close();
    assert_eq!(q.pop(), None);
    assert_eq!(q.dropped(), 1);
    assert_eq!(q.pushed(), 3);
}

#[test]
fn queue_close_drains() {
    let q: BoundedQueue<u32> = BoundedQueue::new(4);
    q.push(1);
    q.push(2);
    q.close();
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), None);
    assert!(!q.push(9), "push after close is refused");
    assert_eq!(q.len(), 0);
}

#[test]
fn source_frames_are_deterministic() {
    let s1 = FrameSource::new(micro(), 7, None);
    let s2 = FrameSource::new(micro(), 7, None);
    assert_eq!(s1.make_frame(3).patches, s2.make_frame(3).patches);
    assert_ne!(s1.make_frame(3).patches, s1.make_frame(4).patches);
}

#[test]
fn source_paces_offered_rate() {
    let mut s = FrameSource::new(micro(), 1, Some(200.0));
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        let _ = s.next_frame();
    }
    // 5 frames at 200 FPS ≥ 20 ms.
    assert!(t0.elapsed().as_secs_f64() >= 0.015);
}

#[test]
fn serve_completes_all_frames_when_backend_is_fast() {
    // queue_depth = frames: no eviction possible, every frame completes
    // (shedding behaviour is covered by the next test).
    let cfg = ServeConfig {
        offered_fps: 500.0,
        frames: 20,
        queue_depth: 20,
        source_seed: 11,
    };
    let source = FrameSource::new(micro(), 11, Some(cfg.offered_fps));
    let report = serve(source, sim_backend(false), &cfg).unwrap();
    assert_eq!(report.completed + report.dropped, 20);
    assert_eq!(report.dropped, 0, "deep queue must not drop");
    assert!(report.e2e_latency.p50 > 0.0);
    let j = report.to_json().pretty();
    assert!(j.contains("achieved_fps"));
}

#[test]
fn serve_sheds_load_when_backend_is_slow() {
    // Offered far above what the real-time simulated accelerator can do:
    // drops must occur and achieved FPS ≈ the accelerator's rate.
    struct SlowBackend;
    impl InferenceBackend for SlowBackend {
        fn name(&self) -> String {
            "slow".into()
        }
        fn infer(&self, _p: &[f32]) -> anyhow::Result<(Vec<f32>, f64)> {
            std::thread::sleep(std::time::Duration::from_millis(20));
            Ok((vec![0.0; 10], 0.02))
        }
    }
    let cfg = ServeConfig {
        offered_fps: 400.0,
        frames: 40,
        queue_depth: 2,
        source_seed: 1,
    };
    let source = FrameSource::new(micro(), 1, Some(cfg.offered_fps));
    let report = serve(source, Box::new(SlowBackend), &cfg).unwrap();
    assert!(report.dropped > 0, "must shed load: {report:?}");
    assert!(
        report.achieved_fps < 80.0,
        "achieved {} should be near 50",
        report.achieved_fps
    );
    assert_eq!(report.completed + report.dropped, 40);
}

#[test]
fn realtime_sim_backend_paces_to_device_latency() {
    let b = sim_backend(true);
    let s = FrameSource::new(micro(), 11, None);
    let frame = s.make_frame(0);
    let t0 = std::time::Instant::now();
    let (_, device_s) = b.infer(&frame.patches).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert!(
        wall >= device_s,
        "realtime backend must not finish before the simulated device ({wall} < {device_s})"
    );
    let _ = Arc::new(());
}
