use std::sync::Arc;

use crate::hw::zcu102;
use crate::model::VitConfig;
use crate::perf::AcceleratorParams;
use crate::runtime::{InferenceBackend, SimBackend};
use crate::sim::{generate_weights, ModelExecutor};

use super::*;

fn micro() -> VitConfig {
    VitConfig {
        name: "micro".into(),
        image_size: 32,
        patch_size: 8,
        in_chans: 3,
        embed_dim: 32,
        depth: 1,
        num_heads: 4,
        mlp_ratio: 4,
        num_classes: 10,
    }
}

fn micro_executor() -> ModelExecutor {
    let cfg = micro();
    let w = generate_weights(&cfg, 11);
    let g_q = AcceleratorParams::g_q_for(64, 8);
    let params = AcceleratorParams {
        t_m: 16,
        t_n: 2,
        t_m_q: 16,
        t_n_q: 2 * g_q / 4,
        g: 4,
        g_q,
        p_h: 4,
        act_bits: Some(8),
    };
    ModelExecutor::new(w, Some(8), params, zcu102())
}

fn sim_backend(realtime: bool) -> Box<dyn InferenceBackend> {
    Box::new(SimBackend {
        executor: micro_executor(),
        realtime,
    })
}

// ---------------------------------------------------------------------------
// Queue.
// ---------------------------------------------------------------------------

#[test]
fn queue_drop_oldest() {
    let q: BoundedQueue<u32> = BoundedQueue::new(2);
    assert_eq!(q.push(1), PushOutcome::Admitted);
    assert_eq!(q.push(2), PushOutcome::Admitted);
    assert_eq!(q.push(3), PushOutcome::AdmittedDroppedOldest); // drops 1
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), Some(3));
    q.close();
    assert_eq!(q.pop(), None);
    assert_eq!(q.dropped(), 1);
    assert_eq!(q.pushed(), 3);
    assert_eq!(q.popped(), 2);
}

#[test]
fn queue_close_drains() {
    let q: BoundedQueue<u32> = BoundedQueue::new(4);
    q.push(1);
    q.push(2);
    q.close();
    assert!(q.is_closed());
    assert_eq!(q.pop(), Some(1));
    assert_eq!(q.pop(), Some(2));
    assert_eq!(q.pop(), None);
    assert_eq!(
        q.push(9),
        PushOutcome::RejectedClosed,
        "push after close is refused"
    );
    assert_eq!(q.len(), 0);
    assert_eq!(q.pushed(), 2, "rejected pushes are not admissions");
}

#[test]
fn queue_try_pop_and_peek() {
    let q: BoundedQueue<u32> = BoundedQueue::new(2);
    assert_eq!(q.try_pop(), None);
    q.push(7);
    assert_eq!(q.peek_front(|v| *v), Some(7));
    assert_eq!(q.len(), 1, "peek does not remove");
    assert_eq!(q.try_pop(), Some(7));
    assert_eq!(q.try_pop(), None);
}

#[test]
fn queue_conservation_counters() {
    let q: BoundedQueue<u32> = BoundedQueue::new(2);
    for i in 0..10 {
        assert!(q.push(i).admitted());
    }
    let mut popped = 0;
    while q.try_pop().is_some() {
        popped += 1;
    }
    assert_eq!(q.pushed(), 10);
    assert_eq!(q.popped(), popped);
    assert_eq!(q.pushed(), q.popped() + q.dropped());
}

// ---------------------------------------------------------------------------
// Source.
// ---------------------------------------------------------------------------

#[test]
fn source_frames_are_deterministic() {
    let s1 = FrameSource::new(micro(), 7, None);
    let s2 = FrameSource::new(micro(), 7, None);
    assert_eq!(s1.make_frame(3).patches, s2.make_frame(3).patches);
    assert_ne!(s1.make_frame(3).patches, s1.make_frame(4).patches);
}

#[test]
fn source_paces_offered_rate() {
    let clock = WallClock::new();
    let mut s = FrameSource::new(micro(), 1, Some(200.0));
    for _ in 0..5 {
        let _ = s.next_frame(&clock);
    }
    // Frame 0 is due at t=0; frames 1..=4 wait one 5 ms interval each.
    assert!(clock.now() >= 0.015);
}

#[test]
fn source_paces_against_virtual_clock_without_blocking() {
    let clock = VirtualClock::new(100);
    let mut s = FrameSource::new(micro(), 1, Some(30.0)).with_stream(3);
    let f0 = s.next_frame(&clock);
    let f1 = s.next_frame(&clock);
    assert_eq!(f0.stream, 3);
    assert_eq!(f0.emitted_at, 0.0);
    assert!((f1.emitted_at - 1.0 / 30.0).abs() < 1e-6);
    assert!(clock.now() < 0.05, "virtual pacing must not block");
}

#[test]
fn source_due_times_follow_offset_and_rate() {
    let s = FrameSource::new(micro(), 1, Some(10.0)).with_offset(0.25);
    assert_eq!(s.due_at(0), 0.25);
    assert!((s.due_at(4) - 0.65).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// Single-stream serve loop.
// ---------------------------------------------------------------------------

#[test]
fn serve_completes_all_frames_when_backend_is_fast() {
    // queue_depth = frames: no eviction possible, every frame completes
    // (shedding behaviour is covered by the next test).
    let cfg = ServeConfig {
        offered_fps: 500.0,
        frames: 20,
        queue_depth: 20,
        source_seed: 11,
    };
    let source = FrameSource::new(micro(), 11, Some(cfg.offered_fps));
    let report = serve(source, sim_backend(false), &cfg).unwrap();
    assert_eq!(report.completed + report.dropped, 20);
    assert_eq!(report.dropped, 0, "deep queue must not drop");
    assert!(report.e2e_latency.p50 > 0.0);
    let j = report.to_json().pretty();
    assert!(j.contains("achieved_fps"));
}

#[test]
fn serve_sheds_load_when_backend_is_slow() {
    // Offered far above what the real-time simulated accelerator can do:
    // drops must occur and achieved FPS ≈ the accelerator's rate.
    struct SlowBackend;
    impl InferenceBackend for SlowBackend {
        fn name(&self) -> String {
            "slow".into()
        }
        fn infer(&mut self, _p: &[f32]) -> anyhow::Result<(Vec<f32>, f64)> {
            std::thread::sleep(std::time::Duration::from_millis(20));
            Ok((vec![0.0; 10], 0.02))
        }
    }
    let cfg = ServeConfig {
        offered_fps: 400.0,
        frames: 40,
        queue_depth: 2,
        source_seed: 1,
    };
    let source = FrameSource::new(micro(), 1, Some(cfg.offered_fps));
    let report = serve(source, Box::new(SlowBackend), &cfg).unwrap();
    assert!(report.dropped > 0, "must shed load: {report:?}");
    assert!(
        report.achieved_fps < 80.0,
        "achieved {} should be near 50",
        report.achieved_fps
    );
    assert_eq!(report.completed + report.dropped, 40);
}

#[test]
fn realtime_sim_backend_paces_to_device_latency() {
    let mut b = sim_backend(true);
    let s = FrameSource::new(micro(), 11, None);
    let frame = s.make_frame(0);
    let t0 = std::time::Instant::now();
    let (_, device_s) = b.infer(&frame.patches).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert!(
        wall >= device_s,
        "realtime backend must not finish before the simulated device ({wall} < {device_s})"
    );
    let _ = Arc::new(());
}

// ---------------------------------------------------------------------------
// Dispatch policies (fed snapshots directly).
// ---------------------------------------------------------------------------

fn snap(stream: usize, queued: usize, emitted: f64, deadline: f64) -> StreamSnapshot {
    StreamSnapshot {
        stream,
        queued,
        head_emitted_at: emitted,
        head_deadline: deadline,
    }
}

#[test]
fn round_robin_cycles_streams_and_workers() {
    let mut p = RoundRobin::default();
    let ready = [
        snap(0, 1, 0.0, f64::INFINITY),
        snap(1, 1, 0.0, f64::INFINITY),
        snap(2, 1, 0.0, f64::INFINITY),
    ];
    let picks: Vec<usize> = (0..6).map(|_| ready[p.pick_stream(&ready)].stream).collect();
    assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    // Skips streams with nothing waiting.
    let sparse = [snap(1, 1, 0.0, f64::INFINITY)];
    assert_eq!(sparse[p.pick_stream(&sparse)].stream, 1);
}

#[test]
fn least_loaded_picks_deepest_queue_and_least_busy_worker() {
    let mut p = LeastLoaded;
    let ready = [
        snap(0, 1, 0.0, f64::INFINITY),
        snap(1, 5, 0.0, f64::INFINITY),
        snap(2, 5, 0.0, f64::INFINITY),
    ];
    // Deepest queue wins; ties resolve to the lower stream index.
    assert_eq!(ready[p.pick_stream(&ready)].stream, 1);
    let idle = [
        WorkerSnapshot {
            worker: 0,
            busy_s: 2.0,
            served: 4,
        },
        WorkerSnapshot {
            worker: 1,
            busy_s: 0.5,
            served: 1,
        },
    ];
    assert_eq!(idle[p.pick_worker(&idle)].worker, 1);
}

#[test]
fn weighted_sla_prefers_earliest_deadline() {
    let mut p = WeightedSla;
    let ready = [
        snap(0, 3, 0.0, f64::INFINITY), // best-effort
        snap(1, 1, 0.2, 0.9),
        snap(2, 1, 0.1, 0.5), // tightest deadline
    ];
    assert_eq!(ready[p.pick_stream(&ready)].stream, 2);
    // Among best-effort streams, the oldest head frame goes first.
    let be = [snap(0, 1, 0.4, f64::INFINITY), snap(1, 1, 0.1, f64::INFINITY)];
    assert_eq!(be[p.pick_stream(&be)].stream, 1);
}

#[test]
fn policy_lookup_by_name() {
    for name in POLICY_NAMES {
        assert!(policy_for(name).is_some(), "{name} must resolve");
    }
    assert!(policy_for("rr").is_some());
    assert!(policy_for("nope").is_none());
}

// ---------------------------------------------------------------------------
// Scheduler: virtual (deterministic) mode.
// ---------------------------------------------------------------------------

fn analytic_scheduler(
    n_streams: usize,
    n_workers: usize,
    latency_s: f64,
    policy: &str,
) -> Scheduler {
    let streams: Vec<(StreamConfig, FrameSource)> = (0..n_streams)
        .map(|i| {
            let cfg = StreamConfig {
                offered_fps: 100.0,
                frames: 50,
                queue_depth: 4,
                sla_ms: Some(40.0),
            };
            let src = FrameSource::new(micro(), 11 + i as u64, Some(cfg.offered_fps))
                .with_stream(i)
                .with_offset(i as f64 * 1e-3);
            (cfg, src)
        })
        .collect();
    let workers: Vec<Box<dyn WorkerModel>> = (0..n_workers)
        .map(|_| {
            Box::new(AnalyticWorker {
                latency_s,
                label: "W1A8".into(),
            }) as Box<dyn WorkerModel>
        })
        .collect();
    Scheduler::new(streams, workers, policy_for(policy).unwrap())
}

#[test]
fn virtual_run_is_byte_identical_across_three_runs() {
    let render = || {
        analytic_scheduler(3, 2, 0.008, "weighted-sla")
            .run_virtual(150)
            .unwrap()
            .to_json()
            .pretty()
    };
    let a = render();
    let b = render();
    let c = render();
    assert_eq!(a, b, "virtual scheduling must be deterministic");
    assert_eq!(b, c, "virtual scheduling must be deterministic");
    assert!(a.contains("\"clock\": \"virtual\""));
}

#[test]
fn virtual_run_conserves_every_frame() {
    for policy in POLICY_NAMES {
        let r = analytic_scheduler(4, 2, 0.004, policy).run_virtual(150).unwrap();
        let a = &r.aggregate;
        assert_eq!(a.offered, 4 * 50, "{policy}: all frames offered");
        assert_eq!(
            a.completed + a.dropped,
            a.offered,
            "{policy}: conservation violated"
        );
        for s in &r.streams {
            assert_eq!(s.completed + s.dropped, s.offered, "{policy} stream {}", s.stream);
        }
        let served: u64 = r.workers.iter().map(|w| w.served).sum();
        assert_eq!(served, a.completed, "{policy}: worker accounting");
    }
}

#[test]
fn virtual_throughput_monotone_in_workers() {
    // 4 streams × 100 FPS offered with an 8 ms service time: one worker
    // saturates at 125 FPS, so adding workers must raise throughput.
    let mut last = 0.0;
    for workers in 1..=4 {
        let r = analytic_scheduler(4, workers, 0.008, "round-robin")
            .run_virtual(150)
            .unwrap();
        assert!(
            r.aggregate.achieved_fps >= last,
            "throughput fell from {last} at {workers} workers"
        );
        last = r.aggregate.achieved_fps;
    }
    assert!(last > 300.0, "4 workers should clear 300 FPS, got {last}");
}

#[test]
fn virtual_run_counts_sla_violations() {
    // Service time 10 ms against a 5 ms SLA: every completed frame
    // violates.
    let streams = vec![(
        StreamConfig {
            offered_fps: 20.0,
            frames: 10,
            queue_depth: 10,
            sla_ms: Some(5.0),
        },
        FrameSource::new(micro(), 1, Some(20.0)),
    )];
    let workers: Vec<Box<dyn WorkerModel>> = vec![Box::new(AnalyticWorker {
        latency_s: 0.010,
        label: "slow".into(),
    })];
    let r = Scheduler::new(streams, workers, policy_for("weighted-sla").unwrap())
        .run_virtual(150)
        .unwrap();
    assert_eq!(r.aggregate.completed, 10);
    assert_eq!(r.aggregate.sla_violations, 10);
}

#[test]
fn virtual_overload_sheds_via_drop_oldest() {
    // One worker at 20 ms against 4 × 100 FPS offered: deep overload —
    // shallow queues must shed most frames instead of growing latency.
    let r = analytic_scheduler(4, 1, 0.020, "least-loaded").run_virtual(150).unwrap();
    assert!(r.aggregate.dropped > 0, "overload must drop: {r:?}");
    // While arrivals keep coming, drop-oldest keeps waits short (typical
    // frames clear well under 6 service times); the absolute worst case
    // is the residual backlog (streams × depth frames) draining after
    // the last arrival, one service time each.
    assert!(
        r.aggregate.e2e_latency.p50 < 0.020 * 6.0,
        "drop-oldest must bound typical queueing delay, got p50 = {} s",
        r.aggregate.e2e_latency.p50
    );
    assert!(
        r.aggregate.e2e_latency.max < 0.020 * (4.0 * 4.0 + 1.0),
        "e2e must never exceed the full-backlog drain, got max = {} s",
        r.aggregate.e2e_latency.max
    );
}

#[test]
fn virtual_run_with_sim_workers_is_deterministic() {
    let run = || {
        let streams = vec![(
            StreamConfig {
                offered_fps: 200.0,
                frames: 6,
                queue_depth: 6,
                sla_ms: None,
            },
            FrameSource::new(micro(), 5, Some(200.0)),
        )];
        let workers: Vec<Box<dyn WorkerModel>> = vec![Box::new(SimWorker {
            executor: micro_executor(),
        })];
        Scheduler::new(streams, workers, policy_for("round-robin").unwrap())
            .run_virtual(150)
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.to_json().pretty(), b.to_json().pretty());
    assert_eq!(a.aggregate.completed, 6);
    assert!(a.aggregate.device_latency.mean > 0.0);
}

// ---------------------------------------------------------------------------
// Scheduler: wall (threaded) mode.
// ---------------------------------------------------------------------------

#[test]
fn wall_run_completes_under_capacity() {
    let streams: Vec<(StreamConfig, FrameSource)> = (0..2)
        .map(|i| {
            let cfg = StreamConfig {
                offered_fps: 300.0,
                frames: 30,
                queue_depth: 30,
                sla_ms: None,
            };
            let src =
                FrameSource::new(micro(), 3 + i as u64, Some(cfg.offered_fps)).with_stream(i);
            (cfg, src)
        })
        .collect();
    let workers: Vec<Box<dyn WorkerModel>> = (0..2)
        .map(|_| {
            Box::new(AnalyticWorker {
                latency_s: 0.0,
                label: "fast".into(),
            }) as Box<dyn WorkerModel>
        })
        .collect();
    let r = Scheduler::new(streams, workers, policy_for("round-robin").unwrap())
        .run_wall()
        .unwrap();
    assert_eq!(r.aggregate.offered, 60);
    assert_eq!(r.aggregate.completed + r.aggregate.dropped, 60);
    assert_eq!(r.aggregate.dropped, 0, "deep queues under capacity: no drops");
    assert_eq!(r.clock, "wall");
}

#[test]
fn wall_run_with_sim_workers_serves_all_streams() {
    let streams: Vec<(StreamConfig, FrameSource)> = (0..3)
        .map(|i| {
            let cfg = StreamConfig {
                offered_fps: 500.0,
                frames: 8,
                queue_depth: 8,
                sla_ms: Some(250.0),
            };
            let src =
                FrameSource::new(micro(), 7 + i as u64, Some(cfg.offered_fps)).with_stream(i);
            (cfg, src)
        })
        .collect();
    let workers: Vec<Box<dyn WorkerModel>> = (0..2)
        .map(|_| {
            Box::new(SimWorker {
                executor: micro_executor(),
            }) as Box<dyn WorkerModel>
        })
        .collect();
    let r = Scheduler::new(streams, workers, policy_for("least-loaded").unwrap())
        .run_wall()
        .unwrap();
    assert_eq!(r.aggregate.completed + r.aggregate.dropped, 24);
    for s in &r.streams {
        assert!(s.completed > 0, "every stream must make progress: {r:?}");
    }
    let served: u64 = r.workers.iter().map(|w| w.served).sum();
    assert_eq!(served, r.aggregate.completed);
}

#[test]
fn wall_run_propagates_worker_errors() {
    struct FailingWorker;
    impl WorkerModel for FailingWorker {
        fn name(&self) -> String {
            "failing".into()
        }
        fn needs_patches(&self) -> bool {
            false
        }
        fn service(&mut self, _frame: &Frame) -> anyhow::Result<f64> {
            anyhow::bail!("injected fault")
        }
    }
    let streams = vec![(
        StreamConfig {
            offered_fps: 1000.0,
            frames: 4,
            queue_depth: 4,
            sla_ms: None,
        },
        FrameSource::new(micro(), 1, Some(1000.0)),
    )];
    let workers: Vec<Box<dyn WorkerModel>> = vec![Box::new(FailingWorker)];
    let err = Scheduler::new(streams, workers, policy_for("round-robin").unwrap())
        .run_wall()
        .unwrap_err();
    assert!(format!("{err}").contains("injected fault"));
}
