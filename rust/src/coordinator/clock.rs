//! Time abstraction for the serving path.
//!
//! Every latency, pacing and scheduling decision in the coordinator goes
//! through a [`Clock`] so the same code runs in two regimes:
//!
//! * [`WallClock`] — real time, for live serving and wall-clock benches;
//! * [`VirtualClock`] — deterministic simulated time stepping in
//!   accelerator-cycle units (the same unit `perf::cycles` predicts and
//!   the simulator's `ExecTrace` reports), so a scheduling test over N
//!   streams and W workers is reproducible to the byte and runs as fast
//!   as the host allows, independent of the simulated rates.
//!
//! Timestamps are `f64` seconds since the clock's epoch (construction).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::Cycles;

/// A monotonic clock the serving path can pace against.
pub trait Clock: Send + Sync {
    /// Seconds elapsed since this clock's epoch.
    fn now(&self) -> f64;

    /// Block (wall time) or advance (virtual time) until `t` seconds.
    /// A `t` in the past is a no-op; time never goes backwards.
    fn sleep_until(&self, t: f64);

    /// `true` when time is simulated (no real blocking ever happens).
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Real time, anchored at construction.
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock {
            start: Instant::now(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn sleep_until(&self, t: f64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_secs_f64(t - now));
        }
    }
}

/// Deterministic virtual clock counting simulated accelerator cycles.
///
/// The integer cycle counter is the source of truth — seconds are a
/// derived view at the device clock rate — so event ordering never
/// depends on float rounding and a run's timeline is bit-reproducible.
pub struct VirtualClock {
    clock_mhz: u64,
    cycles: AtomicU64,
}

impl VirtualClock {
    /// A virtual clock ticking at `clock_mhz` (the device clock, so one
    /// tick is one simulated accelerator cycle).
    pub fn new(clock_mhz: u64) -> VirtualClock {
        assert!(clock_mhz > 0, "virtual clock needs a positive rate");
        VirtualClock {
            clock_mhz,
            cycles: AtomicU64::new(0),
        }
    }

    /// Current simulated cycle.
    pub fn cycles(&self) -> Cycles {
        self.cycles.load(Ordering::SeqCst)
    }

    pub fn clock_mhz(&self) -> u64 {
        self.clock_mhz
    }

    /// Convert a duration in seconds to whole cycles (rounded up, so a
    /// nonzero duration is never squashed to zero).
    pub fn seconds_to_cycles(&self, seconds: f64) -> Cycles {
        let c = (seconds * self.clock_mhz as f64 * 1e6).ceil();
        if c <= 0.0 {
            0
        } else {
            c as Cycles
        }
    }

    pub fn cycles_to_seconds(&self, cycles: Cycles) -> f64 {
        cycles as f64 / (self.clock_mhz as f64 * 1e6)
    }

    /// Advance the clock to `cycle` (monotone: earlier targets are no-ops).
    pub fn advance_to(&self, cycle: Cycles) {
        self.cycles.fetch_max(cycle, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.cycles_to_seconds(self.cycles())
    }

    fn sleep_until(&self, t: f64) {
        self.advance_to(self.seconds_to_cycles(t));
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_advances() {
        let c = WallClock::new();
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now() > t0);
        assert!(!c.is_virtual());
    }

    #[test]
    fn wall_clock_sleep_until_past_is_noop() {
        let c = WallClock::new();
        c.sleep_until(-1.0); // must not panic or block
    }

    #[test]
    fn virtual_clock_is_monotone_and_exact() {
        let c = VirtualClock::new(150);
        assert_eq!(c.cycles(), 0);
        c.advance_to(150_000_000); // 1 simulated second at 150 MHz
        assert_eq!(c.now(), 1.0);
        c.advance_to(75_000_000); // backwards target: no-op
        assert_eq!(c.cycles(), 150_000_000);
        assert!(c.is_virtual());
    }

    #[test]
    fn virtual_clock_roundtrips_cycle_units() {
        let c = VirtualClock::new(150);
        assert_eq!(c.seconds_to_cycles(1.0), 150_000_000);
        assert_eq!(c.seconds_to_cycles(0.0), 0);
        // Rounding up: a sub-cycle duration still costs one cycle.
        assert_eq!(c.seconds_to_cycles(1e-12), 1);
        c.sleep_until(0.5);
        assert_eq!(c.cycles(), 75_000_000);
    }
}
