//! The single-stream serving loop: source thread → bounded queue → worker.
//!
//! Kept alongside the multi-stream [`super::Scheduler`] because the PJRT
//! backend wraps thread-affine C pointers — inference must stay on the
//! calling thread, so this loop spawns only the frame source.

use std::sync::Arc;
use std::time::Instant;

use crate::runtime::InferenceBackend;

use super::clock::{Clock, WallClock};
use super::metrics::{Metrics, ServingReport};
use super::queue::BoundedQueue;
use super::source::{Frame, FrameSource};

/// Serve-run configuration.
pub struct ServeConfig {
    /// Frames the source offers per second.
    pub offered_fps: f64,
    /// Total frames to offer.
    pub frames: u64,
    /// Queue depth before drop-oldest kicks in (a real-time pipeline keeps
    /// this small — 2 means "at most one stale frame waiting").
    pub queue_depth: usize,
    pub source_seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            offered_fps: 30.0,
            frames: 90,
            queue_depth: 2,
            source_seed: 11,
        }
    }
}

/// Run the full serving pipeline against `backend`; blocks until all
/// offered frames are either served or dropped.
pub fn serve(
    mut source: FrameSource,
    mut backend: Box<dyn InferenceBackend>,
    cfg: &ServeConfig,
) -> anyhow::Result<ServingReport> {
    let queue: Arc<BoundedQueue<Frame>> = Arc::new(BoundedQueue::new(cfg.queue_depth));
    let clock: Arc<WallClock> = Arc::new(WallClock::new());
    let started = Instant::now();

    // Source thread: paced frame production with drop-oldest admission.
    let q_prod = Arc::clone(&queue);
    let c_prod = Arc::clone(&clock);
    let frames = cfg.frames;
    let producer = std::thread::spawn(move || {
        for _ in 0..frames {
            let frame = source.next_frame(c_prod.as_ref());
            q_prod.push(frame);
        }
        q_prod.close();
    });

    // Worker: single consumer (the accelerator executes layers serially;
    // batching across frames is not part of the paper's design, which
    // targets frame latency).
    let mut metrics = Metrics::default();
    while let Some(frame) = queue.pop() {
        let (logits, device_s) = backend.infer(&frame.patches)?;
        debug_assert!(logits.iter().all(|v| v.is_finite()));
        metrics.record(clock.now() - frame.emitted_at, device_s);
    }
    producer
        .join()
        .map_err(|_| anyhow::anyhow!("source thread panicked"))?;

    metrics.offered = queue.pushed();
    metrics.dropped = queue.dropped();
    Ok(ServingReport::build(
        backend.name(),
        &metrics,
        started,
        cfg.offered_fps,
    ))
}
