//! Serving metrics: latency distributions, achieved FPS, drop and SLA
//! accounting — single-stream ([`ServingReport`]) and multi-stream
//! ([`MultiServingReport`], per stream + per worker + aggregate).

use std::time::Instant;

use crate::obs::{latency_pair, rate};
use crate::util::json::Json;
use crate::util::stats::{LatencyHistogram, Summary};

/// Collected during a serve run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// End-to-end (emit → logits) latency per completed frame.
    pub e2e: Vec<f64>,
    /// Backend (device) latency per completed frame.
    pub device: Vec<f64>,
    pub completed: u64,
    pub dropped: u64,
    pub offered: u64,
}

impl Metrics {
    pub fn record(&mut self, e2e_s: f64, device_s: f64) {
        self.e2e.push(e2e_s);
        self.device.push(device_s);
        self.completed += 1;
    }
}

/// Final report of a single-stream serving run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub backend: String,
    pub offered_fps: f64,
    pub achieved_fps: f64,
    pub completed: u64,
    pub dropped: u64,
    pub drop_rate: f64,
    pub e2e_latency: Summary,
    pub device_latency: Summary,
    pub wall_seconds: f64,
}

impl ServingReport {
    pub fn build(
        backend: String,
        metrics: &Metrics,
        started: Instant,
        offered_fps: f64,
    ) -> ServingReport {
        let wall = started.elapsed().as_secs_f64();
        let mut hist = LatencyHistogram::default();
        for &l in &metrics.e2e {
            hist.record(l);
        }
        ServingReport {
            backend,
            offered_fps,
            // Rate fields stay finite on empty traces: zero offered
            // frames (or a zero-length wall interval) is a well-formed
            // zero report, not NaN.
            achieved_fps: rate(metrics.completed as f64, wall),
            completed: metrics.completed,
            dropped: metrics.dropped,
            drop_rate: rate(metrics.dropped as f64, metrics.offered as f64),
            e2e_latency: Summary::from(&metrics.e2e),
            device_latency: Summary::from(&metrics.device),
            wall_seconds: wall,
        }
    }

    pub fn to_json(&self) -> Json {
        let j = Json::obj()
            .set("backend", self.backend.as_str())
            .set("offered_fps", self.offered_fps)
            .set("achieved_fps", self.achieved_fps)
            .set("completed", self.completed)
            .set("dropped", self.dropped)
            .set("drop_rate", self.drop_rate);
        latency_pair(j, &self.e2e_latency, &self.device_latency)
            .set("wall_seconds", self.wall_seconds)
    }

    pub fn render(&self) -> String {
        format!(
            "backend {b}\n  offered {o:.1} FPS → achieved {a:.1} FPS  \
             (completed {c}, dropped {d} = {dr:.1}%)\n  \
             e2e latency  p50 {p50:.2} ms  p95 {p95:.2} ms  p99 {p99:.2} ms\n  \
             device latency  mean {dm:.2} ms\n",
            b = self.backend,
            o = self.offered_fps,
            a = self.achieved_fps,
            c = self.completed,
            d = self.dropped,
            dr = 100.0 * self.drop_rate,
            p50 = self.e2e_latency.p50 * 1e3,
            p95 = self.e2e_latency.p95 * 1e3,
            p99 = self.e2e_latency.p99 * 1e3,
            dm = self.device_latency.mean * 1e3,
        )
    }
}

// ---------------------------------------------------------------------------
// Multi-stream serving (scheduler path).
// ---------------------------------------------------------------------------

/// Per-stream accumulator while a scheduler run is in flight.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub offered: u64,
    pub dropped: u64,
    /// Frames lost to fault recovery: retry budget exhausted, or still
    /// undeliverable when the run drained (always 0 without a fault
    /// plan). Conservation: `offered == completed + dropped + failed`.
    pub failed: u64,
    pub sla_violations: u64,
    pub e2e: Vec<f64>,
    pub device: Vec<f64>,
}

impl StreamStats {
    /// Record a completed frame.
    pub fn record(&mut self, e2e_s: f64, device_s: f64, sla_violation: bool) {
        self.e2e.push(e2e_s);
        self.device.push(device_s);
        if sla_violation {
            self.sla_violations += 1;
        }
    }

    pub fn completed(&self) -> u64 {
        self.e2e.len() as u64
    }
}

/// One stream's slice of a [`MultiServingReport`].
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub stream: usize,
    pub offered_fps: f64,
    pub sla_ms: Option<f64>,
    pub offered: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Frames lost to fault recovery (retry budget / drained in-flight).
    pub failed: u64,
    pub drop_rate: f64,
    pub sla_violations: u64,
    pub e2e_latency: Summary,
    pub device_latency: Summary,
}

impl StreamReport {
    pub fn from_stats(
        stream: usize,
        offered_fps: f64,
        sla_ms: Option<f64>,
        stats: &StreamStats,
    ) -> StreamReport {
        StreamReport {
            stream,
            offered_fps,
            sla_ms,
            offered: stats.offered,
            completed: stats.completed(),
            dropped: stats.dropped,
            failed: stats.failed,
            drop_rate: rate(stats.dropped as f64, stats.offered as f64),
            sla_violations: stats.sla_violations,
            e2e_latency: Summary::from(&stats.e2e),
            device_latency: Summary::from(&stats.device),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("stream", self.stream)
            .set("offered_fps", self.offered_fps)
            .set("offered", self.offered)
            .set("completed", self.completed)
            .set("dropped", self.dropped)
            .set("failed", self.failed)
            .set("drop_rate", self.drop_rate)
            .set("sla_violations", self.sla_violations);
        j = latency_pair(j, &self.e2e_latency, &self.device_latency);
        if let Some(sla) = self.sla_ms {
            j = j.set("sla_ms", sla);
        }
        j
    }
}

/// One worker's slice of a [`MultiServingReport`].
#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub worker: usize,
    pub name: String,
    pub served: u64,
    pub busy_seconds: f64,
    /// Busy fraction of the run (0..=1).
    pub utilization: f64,
}

impl WorkerReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("worker", self.worker)
            .set("name", self.name.as_str())
            .set("served", self.served)
            .set("busy_seconds", self.busy_seconds)
            .set("utilization", self.utilization)
    }
}

/// Whole-run totals of a [`MultiServingReport`].
#[derive(Debug, Clone)]
pub struct AggregateReport {
    pub offered: u64,
    pub completed: u64,
    pub dropped: u64,
    /// Frames lost to fault recovery (0 without a fault plan).
    pub failed: u64,
    pub drop_rate: f64,
    pub sla_violations: u64,
    /// Completed frames per second over the run (virtual or wall).
    pub achieved_fps: f64,
    pub e2e_latency: Summary,
    pub device_latency: Summary,
}

impl AggregateReport {
    pub fn to_json(&self) -> Json {
        let j = Json::obj()
            .set("offered", self.offered)
            .set("completed", self.completed)
            .set("dropped", self.dropped)
            .set("failed", self.failed)
            .set("drop_rate", self.drop_rate)
            .set("sla_violations", self.sla_violations)
            .set("achieved_fps", self.achieved_fps);
        latency_pair(j, &self.e2e_latency, &self.device_latency)
    }
}

/// Final report of a multi-stream, multi-worker scheduler run.
///
/// Under a `VirtualClock` every field is a pure function of the
/// configuration — `to_json().pretty()` is byte-identical across runs.
#[derive(Debug, Clone)]
pub struct MultiServingReport {
    pub backend: String,
    pub policy: String,
    /// `"wall"` or `"virtual"`.
    pub clock: String,
    /// Run length in clock seconds (simulated for `VirtualClock`).
    pub elapsed_seconds: f64,
    pub aggregate: AggregateReport,
    pub streams: Vec<StreamReport>,
    pub workers: Vec<WorkerReport>,
    /// Fault-and-recovery accounting — `Some` only when a fault plan was
    /// attached, so fault-free report JSON carries no `faults` key.
    pub faults: Option<crate::fault::FaultSummary>,
}

impl MultiServingReport {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("backend", self.backend.as_str())
            .set("policy", self.policy.as_str())
            .set("clock", self.clock.as_str())
            .set("elapsed_seconds", self.elapsed_seconds)
            .set("aggregate", self.aggregate.to_json())
            .set(
                "streams",
                Json::Arr(self.streams.iter().map(StreamReport::to_json).collect()),
            )
            .set(
                "workers",
                Json::Arr(self.workers.iter().map(WorkerReport::to_json).collect()),
            );
        if let Some(f) = &self.faults {
            j = j.set("faults", f.to_json());
        }
        j
    }

    pub fn render(&self) -> String {
        let a = &self.aggregate;
        let mut out = format!(
            "backend {b}  ({s} streams × {w} workers, {p} dispatch, {c} clock)\n  \
             aggregate: offered {o} → completed {cmp}, dropped {d} ({dr:.1}%), \
             {fps:.1} FPS achieved, {v} SLA violations\n  \
             e2e latency  p50 {p50:.2} ms  p95 {p95:.2} ms  p99 {p99:.2} ms\n",
            b = self.backend,
            s = self.streams.len(),
            w = self.workers.len(),
            p = self.policy,
            c = self.clock,
            o = a.offered,
            cmp = a.completed,
            d = a.dropped,
            dr = 100.0 * a.drop_rate,
            fps = a.achieved_fps,
            v = a.sla_violations,
            p50 = a.e2e_latency.p50 * 1e3,
            p95 = a.e2e_latency.p95 * 1e3,
            p99 = a.e2e_latency.p99 * 1e3,
        );
        for s in &self.streams {
            out.push_str(&format!(
                "  stream {i}: offered {o} completed {c} dropped {d}  \
                 p99 {p99:.2} ms  sla_violations {v}\n",
                i = s.stream,
                o = s.offered,
                c = s.completed,
                d = s.dropped,
                p99 = s.e2e_latency.p99 * 1e3,
                v = s.sla_violations,
            ));
        }
        for w in &self.workers {
            out.push_str(&format!(
                "  worker {i}: served {n} frames, {u:.0}% busy\n",
                i = w.worker,
                n = w.served,
                u = 100.0 * w.utilization,
            ));
        }
        if let Some(f) = &self.faults {
            out.push_str(&f.render());
        }
        out
    }
}
