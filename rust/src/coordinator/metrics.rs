//! Serving metrics: latency distribution, achieved FPS, drop accounting.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::{LatencyHistogram, Summary};

/// Collected during a serve run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// End-to-end (emit → logits) latency per completed frame.
    pub e2e: Vec<f64>,
    /// Backend (device) latency per completed frame.
    pub device: Vec<f64>,
    pub completed: u64,
    pub dropped: u64,
    pub offered: u64,
}

impl Metrics {
    pub fn record(&mut self, e2e_s: f64, device_s: f64) {
        self.e2e.push(e2e_s);
        self.device.push(device_s);
        self.completed += 1;
    }
}

/// Final report of a serving run.
#[derive(Debug, Clone)]
pub struct ServingReport {
    pub backend: String,
    pub offered_fps: f64,
    pub achieved_fps: f64,
    pub completed: u64,
    pub dropped: u64,
    pub drop_rate: f64,
    pub e2e_latency: Summary,
    pub device_latency: Summary,
    pub wall_seconds: f64,
}

impl ServingReport {
    pub fn build(
        backend: String,
        metrics: &Metrics,
        started: Instant,
        offered_fps: f64,
    ) -> ServingReport {
        let wall = started.elapsed().as_secs_f64();
        let mut hist = LatencyHistogram::default();
        for &l in &metrics.e2e {
            hist.record(l);
        }
        ServingReport {
            backend,
            offered_fps,
            achieved_fps: metrics.completed as f64 / wall,
            completed: metrics.completed,
            dropped: metrics.dropped,
            drop_rate: metrics.dropped as f64 / metrics.offered.max(1) as f64,
            e2e_latency: Summary::from(&metrics.e2e),
            device_latency: Summary::from(&metrics.device),
            wall_seconds: wall,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("backend", self.backend.as_str())
            .set("offered_fps", self.offered_fps)
            .set("achieved_fps", self.achieved_fps)
            .set("completed", self.completed)
            .set("dropped", self.dropped)
            .set("drop_rate", self.drop_rate)
            .set(
                "e2e_latency_ms",
                Json::obj()
                    .set("p50", self.e2e_latency.p50 * 1e3)
                    .set("p95", self.e2e_latency.p95 * 1e3)
                    .set("p99", self.e2e_latency.p99 * 1e3)
                    .set("mean", self.e2e_latency.mean * 1e3),
            )
            .set(
                "device_latency_ms",
                Json::obj()
                    .set("p50", self.device_latency.p50 * 1e3)
                    .set("mean", self.device_latency.mean * 1e3),
            )
            .set("wall_seconds", self.wall_seconds)
    }

    pub fn render(&self) -> String {
        format!(
            "backend {b}\n  offered {o:.1} FPS → achieved {a:.1} FPS  \
             (completed {c}, dropped {d} = {dr:.1}%)\n  \
             e2e latency  p50 {p50:.2} ms  p95 {p95:.2} ms  p99 {p99:.2} ms\n  \
             device latency  mean {dm:.2} ms\n",
            b = self.backend,
            o = self.offered_fps,
            a = self.achieved_fps,
            c = self.completed,
            d = self.dropped,
            dr = 100.0 * self.drop_rate,
            p50 = self.e2e_latency.p50 * 1e3,
            p95 = self.e2e_latency.p95 * 1e3,
            p99 = self.e2e_latency.p99 * 1e3,
            dm = self.device_latency.mean * 1e3,
        )
    }
}
